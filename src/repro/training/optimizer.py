"""AdamW with warmup+cosine schedule, global-norm clipping and a gradient
compression hook — self-contained (no optax).

Optimizer state is a pytree mirroring the params, so the same PartitionSpecs
apply (moments shard exactly like their parameter). ``compress_grads``
round-trips gradients through bf16 before the moment update — the cast is
where a cross-replica all-reduce picks up the halved payload (the
distributed-optimization trick; exact fp32 master weights are kept).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_grads: bool = False


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros(), "nu": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step -> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    if cfg.compress_grads:
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics

"""Train-step factory: grad accumulation, loss scaling, metrics.

``make_train_step(loss_fn, opt_cfg, grad_accum)`` returns a jit-able
``step(params, opt_state, batch) -> (params, opt_state, metrics)``.
Microbatching runs as a ``lax.scan`` over the leading split of the batch —
each microbatch's backward overlaps the next microbatch's forward in XLA's
schedule, and only one microbatch of activations is ever live (the
activation-memory knob for the big train shapes).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from . import optimizer as opt_lib


def make_train_step(
    loss_fn: Callable,
    opt_cfg: opt_lib.OptimizerConfig,
    *,
    grad_accum: int = 1,
):
    """loss_fn(params, batch) -> scalar. Batch leaves must have leading dim
    divisible by ``grad_accum``."""

    def split(batch):
        return jax.tree.map(
            lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
            batch,
        )

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = split(batch)

            def body(acc, mb):
                loss_acc, grad_acc = acc
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                return (
                    loss_acc + loss,
                    jax.tree.map(jnp.add, grad_acc, grads),
                ), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), micro)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        params, opt_state, metrics = opt_lib.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def run(
    step_fn,
    params,
    opt_state,
    data_iter,
    *,
    n_steps: int,
    log_every: int = 10,
    checkpoint_manager=None,
    checkpoint_every: int = 0,
    start_step: int = 0,
    log_fn=print,
):
    """Host-side loop: data, jitted step, periodic checkpoint. Returns final
    (params, opt_state, history)."""
    jstep = jax.jit(step_fn)
    history = []
    for i in range(start_step, n_steps):
        batch = next(data_iter)
        params, opt_state, metrics = jstep(params, opt_state, batch)
        if log_every and (i % log_every == 0 or i == n_steps - 1):
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            log_fn(f"step {i}: " + " ".join(f"{k}={v:.4g}" for k, v in m.items()))
        if checkpoint_manager and checkpoint_every and (i + 1) % checkpoint_every == 0:
            checkpoint_manager.save(
                i + 1, {"params": params, "opt_state": opt_state}
            )
    return params, opt_state, history

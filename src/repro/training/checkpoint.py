"""Sharded checkpointing with elastic restore (no orbax dependency).

Layout: ``<dir>/step_<n>/`` holding one ``.npy`` per pytree leaf (named by
its flattened key path) + ``manifest.json`` (treedef, shapes, dtypes, step).
Writes are atomic (tmp dir + rename) so a preempted save never corrupts the
latest checkpoint. ``restore`` rebuilds onto *any* mesh: leaves are
device_put with the target sharding, so scaling from N to M hosts/devices is
a restore-time concern only (elastic resharding).

On a true multi-host pod each process would write only the addressable
shards of its leaves (the manifest records global shapes; assembly is by
global index) — single-process here, same file format.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .. import faults


class CheckpointCorruptError(Exception):
    """A checkpoint leaf failed integrity verification (CRC32 mismatch or
    unreadable file). Names the bad leaf so operators know *what* is
    corrupt, not just that something is."""

    def __init__(self, leaf: str, path: str, reason: str = "crc32 mismatch"):
        super().__init__(f"corrupt checkpoint leaf {leaf!r} at {path}: {reason}")
        self.leaf = leaf
        self.path = path
        self.reason = reason


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _checked_load(d: str, name: str, crc: int | None) -> np.ndarray:
    """np.load + CRC32 verification (skipped for pre-CRC checkpoints)."""
    p = os.path.join(d, name + ".npy")
    try:
        arr = np.load(p)
    except Exception as e:  # truncated / unreadable file
        raise CheckpointCorruptError(name, p, f"unreadable: {e}") from e
    if crc is not None and _crc(arr) != crc:
        raise CheckpointCorruptError(name, p)
    return arr


# Orphan-tmp GC: a crash between tempfile.mkdtemp and os.rename leaks the
# tmp dir forever (it is invisible to step GC and the index swap). Swept at
# CheckpointManager construction and save_index entry — single-writer
# discipline assumed, same as the atomic-rename scheme itself.
_TMP_PREFIXES = (".tmp_ckpt_", ".tmp_index_")


def sweep_orphan_tmp(directory: str) -> int:
    """Remove leaked ``.tmp_ckpt_*`` / ``.tmp_index_*`` dirs; returns the
    number removed."""
    if not os.path.isdir(directory):
        return 0
    removed = 0
    for name in os.listdir(directory):
        if name.startswith(_TMP_PREFIXES):
            p = os.path.join(directory, name)
            if os.path.isdir(p):
                shutil.rmtree(p, ignore_errors=True)
                removed += 1
    return removed


def _apply_write_fault(tmp: str, leaf_names: list[str]):
    """Checkpoint-write fault hook: ``truncate``/``torn_write`` corrupts one
    leaf file in the tmp dir (payload ``{"leaf": name}``, default the last
    leaf written) *before* the atomic rename — modelling a torn write that
    survives the rename. Returns the spec for site-specific handling."""
    spec = faults.fire(faults.CHECKPOINT_WRITE)
    if spec is not None and spec.mode in ("truncate", "torn_write"):
        payload = spec.payload or {}
        leaf = payload.get("leaf") or leaf_names[-1]
        p = os.path.join(tmp, leaf + ".npy")
        size = os.path.getsize(p)
        with open(p, "r+b") as f:
            f.truncate(max(size // 2, 1))
    return spec


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def save(directory: str, step: int, tree: Any) -> str:
    """Atomically write ``tree`` under ``directory/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        name = f"{i:04d}__{_leaf_name(path)}"
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {
                "name": name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                # Per-leaf integrity: verified on restore, so a torn write
                # is detected by leaf name instead of served silently.
                "crc32": _crc(arr),
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    _apply_write_fault(tmp, [m["name"] for m in manifest["leaves"]])
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isdir(os.path.join(directory, d))
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any, shardings: Any | None = None) -> Any:
    """Load ``step`` into the structure of ``like``; optionally device_put
    each leaf with the matching sharding (elastic restore onto a new mesh).

    Every leaf is CRC32-verified against the manifest (checkpoints written
    before CRCs existed skip the check); a mismatch raises
    :class:`CheckpointCorruptError` naming the bad leaf."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves_like) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, target structure "
        f"has {len(leaves_like)}"
    )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    out = []
    for i, meta in enumerate(manifest["leaves"]):
        arr = _checked_load(d, meta["name"], meta.get("crc32"))
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Index checkpointing (serving lifecycle: build once, serve anywhere)
# ---------------------------------------------------------------------------
#
# ``save``/``restore`` above need a ``like`` template for the treedef; a
# serving process that *loads* an index has nothing to template from, so the
# index format also records the static (meta) fields and ``load_index``
# reassembles the LiderParams dataclasses explicitly. Same atomic-write
# discipline and one .npy per leaf (named by key path, no ordinal prefix —
# load addresses leaves by path, not position).

_INDEX_DIRNAME = "index"
_INDEX_META = "index_meta.json"


def save_index(directory: str, params: Any) -> str:
    """Atomically persist a ``LiderParams`` index under ``directory/index``.

    An existing index is renamed aside (``index.old``) before the new one is
    renamed in, so no crash window ever leaves zero copies on disk — a kill
    mid-save leaves either the old index in place or, at worst, the finished
    new index plus a recoverable ``index.old`` (``load_index`` falls back to
    it automatically when the new index fails verification).
    """
    os.makedirs(directory, exist_ok=True)
    sweep_orphan_tmp(directory)
    final = os.path.join(directory, _INDEX_DIRNAME)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_index_")
    crcs: dict[str, int] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        arr = np.asarray(jax.device_get(leaf))
        name = _leaf_name(path)
        np.save(os.path.join(tmp, name + ".npy"), arr)
        crcs[name] = _crc(arr)
    rescore_tier = getattr(params.bank, "rescore_tier", "device")
    if rescore_tier == "host":
        # The host tier lives outside the pytree (DESIGN.md §Tiered
        # embedding store) — persist it under the SAME leaf name a
        # device-tier index uses, so checkpoints are tier-portable: a
        # device-tier save loads as host-tier and vice versa.
        host_rows = params.bank.store._concrete()
        np.save(os.path.join(tmp, "bank__rescore_embs.npy"), host_rows)
        crcs["bank__rescore_embs"] = _crc(host_rows)
    meta = {
        # Per-leaf CRC32s, verified by load_index.
        "leaves": crcs,
        "format": "lider_index_v1",
        # Embedding storage dtype (DESIGN.md §Quantized bank); int8 indexes
        # additionally persist bank__emb_scales / bank__rescore_embs leaves.
        "storage_dtype": params.bank.storage_dtype,
        # Which tier the rescore table was served from at save time; load
        # defaults to it but any tier can be requested (load_index).
        "rescore_tier": rescore_tier,
        "in_lsh": {
            "n_arrays": params.bank.lsh.n_arrays,
            "key_len": params.bank.lsh.key_len,
        },
        "in_rmi_n_leaves": params.bank.rmi.n_leaves,
        "centroid_lsh": {
            "n_arrays": params.centroid_cm.lsh.n_arrays,
            "key_len": params.centroid_cm.lsh.key_len,
        },
        "centroid_rmi_n_leaves": params.centroid_cm.rmi.n_leaves,
    }
    with open(os.path.join(tmp, _INDEX_META), "w") as f:
        json.dump(meta, f)
    spec = _apply_write_fault(tmp, sorted(crcs))
    old = final + ".old"
    if os.path.exists(final):
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(final, old)
    os.rename(tmp, final)
    if spec is not None and spec.mode == "torn_write":
        # Simulated crash inside the swap window: the (corrupted) new index
        # is in place and ``index.old`` survives — exactly the state
        # load_index recovers from.
        raise faults.InjectedFault(
            faults.CHECKPOINT_WRITE, "torn write: crashed in index.old swap"
        )
    if os.path.exists(old):
        shutil.rmtree(old)
    return final


def load_index(directory: str, *, rescore_tier: str | None = None) -> Any:
    """Load a ``LiderParams`` index saved by :func:`save_index`.

    ``rescore_tier`` overrides where the rescore table lands ("device" or
    "host"); default is whatever tier the index was saved from. The on-disk
    format is tier-agnostic (one ``bank__rescore_embs.npy`` either way), so
    a device-tier checkpoint loads as host-tier and vice versa.

    Every leaf is CRC32-verified against the ``leaves`` map in the meta
    file. If the index fails verification (a torn write) and a leftover
    ``index.old`` from the swap window exists, the load recovers from it
    automatically; otherwise :class:`CheckpointCorruptError` names the bad
    leaf.
    """
    d = os.path.join(directory, _INDEX_DIRNAME)
    if not os.path.isdir(d):
        d = directory  # accept the index dir itself
    try:
        return _load_index_dir(d, rescore_tier=rescore_tier)
    except (CheckpointCorruptError, FileNotFoundError) as e:
        old = d + ".old"
        if not os.path.isdir(old):
            raise
        params = _load_index_dir(old, rescore_tier=rescore_tier)
        # Recovery succeeded: promote the survivor back to ``index`` so the
        # next load doesn't depend on the torn dir again.
        shutil.rmtree(d, ignore_errors=True)
        os.rename(old, d)
        return params


def _load_index_dir(d: str, *, rescore_tier: str | None = None) -> Any:
    from ..core.bank import ClusterBank, EmbStore
    from ..core.core_model import CoreModelParams
    from ..core.lider import LiderParams
    from ..core.lsh import LSHParams
    from ..core.rescale import RescaleParams
    from ..core.rmi import RMIParams

    with open(os.path.join(d, _INDEX_META)) as f:
        meta = json.load(f)
    if meta.get("format") != "lider_index_v1":
        raise ValueError(f"not a lider index checkpoint: {d}")
    crcs = meta.get("leaves", {})  # absent on pre-CRC indexes

    def leaf(*path: str) -> jnp.ndarray:
        name = "__".join(path)
        return jnp.asarray(_checked_load(d, name, crcs.get(name)))

    def rescale_of(prefix) -> RescaleParams:
        return RescaleParams(
            key_min=leaf(*prefix, "key_min"),
            key_max=leaf(*prefix, "key_max"),
            length=leaf(*prefix, "length"),
        )

    def rmi_of(prefix, n_leaves: int) -> RMIParams:
        return RMIParams(
            root_w=leaf(*prefix, "root_w"),
            root_b=leaf(*prefix, "root_b"),
            leaf_w=leaf(*prefix, "leaf_w"),
            leaf_b=leaf(*prefix, "leaf_b"),
            length=leaf(*prefix, "length"),
            max_err=leaf(*prefix, "max_err"),
            n_leaves=n_leaves,
        )

    def lsh_of(prefix, cfg) -> LSHParams:
        return LSHParams(
            projections=leaf(*prefix, "projections"),
            n_arrays=cfg["n_arrays"],
            key_len=cfg["key_len"],
        )

    centroid_cm = CoreModelParams(
        lsh=lsh_of(("centroid_cm", "lsh"), meta["centroid_lsh"]),
        rescale=rescale_of(("centroid_cm", "rescale")),
        rmi=rmi_of(("centroid_cm", "rmi"), meta["centroid_rmi_n_leaves"]),
        sorted_keys=leaf("centroid_cm", "sorted_keys"),
        sorted_ids=leaf("centroid_cm", "sorted_ids"),
    )
    storage_dtype = meta.get("storage_dtype", "float32")
    quantized = storage_dtype in ("int8", "int4")
    tier = rescore_tier or meta.get("rescore_tier", "device")
    if tier not in ("device", "host"):
        raise ValueError(f"rescore_tier must be 'device' or 'host', got {tier!r}")
    if tier == "host" and not quantized:
        raise ValueError(
            "rescore_tier='host' requires a quantized (int8/int4) index "
            "(float banks have no rescore table)"
        )
    rescore = store = sketches = None
    if quantized:
        gids_arr = _checked_load(d, "bank__gids", crcs.get("bank__gids"))
        rescore_arr = _checked_load(
            d, "bank__rescore_embs", crcs.get("bank__rescore_embs")
        )
        if tier == "host":
            store = EmbStore("host", rescore=rescore_arr, gids=gids_arr)
        else:
            rescore = jnp.asarray(rescore_arr)
        if os.path.exists(os.path.join(d, "bank__sketches.npy")):
            sketches = leaf("bank", "sketches")
        else:
            # Pre-sketch checkpoint: the sign sketches are a pure function
            # of the raw rows, and the rescore table *is* the raw rows — so
            # recomputing here is byte-exact with what save-time packing
            # would have produced (DESIGN.md §Binary sketch tier).
            from ..kernels.quant import sketch_rows

            sketches = sketch_rows(jnp.asarray(rescore_arr))
    bank = ClusterBank(
        lsh=lsh_of(("bank", "lsh"), meta["in_lsh"]),
        rescale=rescale_of(("bank", "rescale")),
        rmi=rmi_of(("bank", "rmi"), meta["in_rmi_n_leaves"]),
        sorted_keys=leaf("bank", "sorted_keys"),
        sorted_pos=leaf("bank", "sorted_pos"),
        embs=leaf("bank", "embs"),
        gids=leaf("bank", "gids"),
        sizes=leaf("bank", "sizes"),
        tombstones=leaf("bank", "tombstones"),
        next_gid=leaf("bank", "next_gid"),
        emb_scales=leaf("bank", "emb_scales") if quantized else None,
        rescore_embs=rescore,
        sketches=sketches,
        store=store,
        code_dtype=storage_dtype if quantized else "int8",
    )
    return LiderParams(
        centroid_cm=centroid_cm, centroids=leaf("centroids"), bank=bank
    )


class CheckpointManager:
    """Keep-last-N manager with preemption-safe atomic saves.

    Construction sweeps orphaned tmp dirs (a crash between mkdtemp and
    rename would otherwise leak them forever); ``restore_latest`` verifies
    integrity and falls back to the newest step that passes."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        sweep_orphan_tmp(directory)

    def save(self, step: int, tree: Any) -> str:
        path = save(self.directory, step, tree)
        self._gc()
        return path

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def _steps(self) -> list[int]:
        if not os.path.isdir(self.directory):
            return []
        return sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
            and os.path.isdir(os.path.join(self.directory, d))
        )

    def restore_latest(self, like: Any, shardings: Any | None = None):
        """Restore the newest *verified* step.

        A step whose manifest or leaves fail verification (torn write,
        CRC mismatch) is skipped and the next-newest is tried; if every
        step is corrupt the newest step's error propagates."""
        last_err = None
        for step in reversed(self._steps()):
            try:
                return step, restore(self.directory, step, like, shardings)
            except (CheckpointCorruptError, OSError, json.JSONDecodeError) as e:
                if last_err is None:
                    last_err = e
        if last_err is not None:
            raise last_err
        return None, None

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

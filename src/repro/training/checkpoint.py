"""Sharded checkpointing with elastic restore (no orbax dependency).

Layout: ``<dir>/step_<n>/`` holding one ``.npy`` per pytree leaf (named by
its flattened key path) + ``manifest.json`` (treedef, shapes, dtypes, step).
Writes are atomic (tmp dir + rename) so a preempted save never corrupts the
latest checkpoint. ``restore`` rebuilds onto *any* mesh: leaves are
device_put with the target sharding, so scaling from N to M hosts/devices is
a restore-time concern only (elastic resharding).

On a true multi-host pod each process would write only the addressable
shards of its leaves (the manifest records global shapes; assembly is by
global index) — single-process here, same file format.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def save(directory: str, step: int, tree: Any) -> str:
    """Atomically write ``tree`` under ``directory/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        name = f"{i:04d}__{_leaf_name(path)}"
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isdir(os.path.join(directory, d))
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any, shardings: Any | None = None) -> Any:
    """Load ``step`` into the structure of ``like``; optionally device_put
    each leaf with the matching sharding (elastic restore onto a new mesh)."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves_like) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, target structure "
        f"has {len(leaves_like)}"
    )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    out = []
    for i, meta in enumerate(manifest["leaves"]):
        arr = np.load(os.path.join(d, meta["name"] + ".npy"))
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Keep-last-N manager with preemption-safe atomic saves."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree: Any) -> str:
        path = save(self.directory, step, tree)
        self._gc()
        return path

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def restore_latest(self, like: Any, shardings: Any | None = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore(self.directory, step, like, shardings)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

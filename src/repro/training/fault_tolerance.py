"""Fault tolerance: restart-on-failure harness + determinism contracts.

At 1000+ nodes the recovery model is: (a) any step may die (preemption, ICI
flap, host OOM); (b) training must resume from the last checkpoint with a
*bitwise-identical* data stream; (c) replacement nodes may change the device
count (elastic).

This module supplies the harness half:
- ``run_with_restarts``: drives a step loop, catches ``Preemption`` (tests
  inject it) or any transient error, restores from the CheckpointManager and
  replays — the data pipeline is step-indexed so replay is exact.
- capacity-padded static shapes (LIDER clusters, MoE buffers) are the
  straggler story: every device executes the same program on the same byte
  count per step, so there is no data-dependent long pole; the remaining
  stragglers (hardware) are handled by restart.
"""
from __future__ import annotations

from typing import Callable

from .checkpoint import CheckpointManager


class Preemption(Exception):
    """Injected/observed node loss."""


def run_with_restarts(
    make_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    *,
    n_steps: int,
    manager: CheckpointManager,
    checkpoint_every: int = 10,
    max_restarts: int = 10,
    on_restart: Callable[[int], None] | None = None,
):
    """Run ``step_fn(state, step) -> state`` to ``n_steps`` with restart
    recovery. ``make_state`` builds the step-0 state (params, opt, rng...).

    Returns (final_state, n_restarts). Restore picks the latest checkpoint;
    steps re-execute from there (the step index keys the data pipeline, so
    replayed batches are identical).
    """
    restarts = 0
    while True:
        latest = manager.latest_step()
        if latest is None:
            state, start = make_state(), 0
        else:
            _, state = manager.restore_latest(make_state())
            start = latest
        try:
            for i in range(start, n_steps):
                state = step_fn(state, i)
                if (i + 1) % checkpoint_every == 0:
                    manager.save(i + 1, state)
            if n_steps % checkpoint_every != 0:
                manager.save(n_steps, state)
            return state, restarts
        except Preemption:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts)

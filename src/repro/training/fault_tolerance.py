"""Fault tolerance: restart-on-failure harness + determinism contracts.

At 1000+ nodes the recovery model is: (a) any step may die (preemption, ICI
flap, host OOM); (b) training must resume from the last checkpoint with a
*bitwise-identical* data stream; (c) replacement nodes may change the device
count (elastic).

This module supplies the harness half:
- ``run_with_restarts``: drives a step loop, catches any exception in its
  ``retryable`` tuple (``Preemption`` by default; add e.g. ``OSError`` for
  flaky storage), restores from the CheckpointManager and replays — the data
  pipeline is step-indexed so replay is exact. Restarts back off
  exponentially with deterministic (seeded) jitter so a thundering herd of
  restarting workers decorrelates the same way on every replay.
- capacity-padded static shapes (LIDER clusters, MoE buffers) are the
  straggler story: every device executes the same program on the same byte
  count per step, so there is no data-dependent long pole; the remaining
  stragglers (hardware) are handled by restart.
"""
from __future__ import annotations

import random
import time
from typing import Callable

from .checkpoint import CheckpointManager


class Preemption(Exception):
    """Injected/observed node loss."""


def run_with_restarts(
    make_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    *,
    n_steps: int,
    manager: CheckpointManager,
    checkpoint_every: int = 10,
    max_restarts: int = 10,
    on_restart: Callable[[int], None] | None = None,
    retryable: tuple[type[BaseException], ...] = (Preemption,),
    backoff_s: float = 0.0,
    backoff_mult: float = 2.0,
    max_backoff_s: float = 30.0,
    jitter_seed: int = 0,
):
    """Run ``step_fn(state, step) -> state`` to ``n_steps`` with restart
    recovery. ``make_state`` builds the step-0 state (params, opt, rng...).

    Only exceptions in ``retryable`` trigger a restart — anything else
    (a real bug) propagates immediately. Each restart sleeps
    ``backoff_s * backoff_mult**(restart-1)`` (capped at ``max_backoff_s``)
    scaled by a deterministic jitter in [1, 2) drawn from ``jitter_seed``.

    Returns (final_state, n_restarts). Restore picks the newest *verified*
    checkpoint (corrupt steps are skipped — see
    ``CheckpointManager.restore_latest``) and steps re-execute from there
    (the step index keys the data pipeline, so replayed batches are
    identical).
    """
    restarts = 0
    rng = random.Random(jitter_seed)
    while True:
        state0 = make_state()
        step, state = manager.restore_latest(state0)
        if step is None:
            state, start = state0, 0
        else:
            start = step
        try:
            for i in range(start, n_steps):
                state = step_fn(state, i)
                if (i + 1) % checkpoint_every == 0:
                    manager.save(i + 1, state)
            if n_steps % checkpoint_every != 0:
                manager.save(n_steps, state)
            return state, restarts
        except retryable:
            restarts += 1
            if restarts > max_restarts:
                raise
            if backoff_s > 0:
                delay = min(
                    backoff_s * backoff_mult ** (restarts - 1), max_backoff_s
                )
                time.sleep(delay * (1.0 + rng.random()))
            if on_restart:
                on_restart(restarts)

from . import checkpoint, fault_tolerance, optimizer, train_loop

__all__ = ["checkpoint", "fault_tolerance", "optimizer", "train_loop"]

"""Production mesh definitions.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 topology).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis extends
data parallelism across the DCN/ICI boundary (cluster/batch sharding only —
no tensor-parallel traffic crosses pods).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import math

import jax
import numpy as np

from .. import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run via "
            "launch/dryrun.py (sets xla_force_host_platform_device_count)"
        )
    # The single-pod mesh uses the first 256 of the dry-run's 512 devices.
    return compat.mesh_from_devices(np.asarray(devs[:need]).reshape(shape), axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests)."""
    return compat.make_mesh(shape, axes)


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size

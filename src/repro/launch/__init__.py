# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and must
# only be imported as the entry point of a fresh process.
from . import mesh, steps

__all__ = ["mesh", "steps"]

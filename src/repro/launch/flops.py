"""Analytic MODEL_FLOPS per (arch x shape) — the §Roofline numerator.

Counts the *algorithmically necessary* flops: parameter matmuls (6ND train /
2ND inference, N = active params), attention score+value products, and the
model-defining interactions (in-batch softmax for two-tower, CIN outer
products, GNN message matmuls). Embedding lookups are excluded (they are
bytes, not flops).
"""
from __future__ import annotations

import math

import jax

from ..configs.base import ArchSpec, ShapeSpec


def _matmul_params(params_struct, vocab_cutoff: int = 100_000) -> int:
    total = 0
    for leaf in jax.tree.leaves(params_struct):
        if leaf.ndim >= 2 and leaf.shape[0] < vocab_cutoff:
            total += math.prod(leaf.shape[-2:]) * math.prod(leaf.shape[:-2])
    return total


def lm_flops(cfg, tokens: int, *, train: bool, seq_len: int | None = None,
             batch: int | None = None, decode_cache: int | None = None) -> float:
    n = cfg.flops_params()
    f = (6.0 if train else 2.0) * n * tokens
    if decode_cache is not None:  # one-token attention against the cache
        f += 4.0 * cfg.n_layers * (batch or 1) * cfg.n_heads * decode_cache * cfg.head_dim
    elif seq_len is not None:  # causal attention ~ S^2/2 per layer
        mult = 3.0 if train else 1.0
        f += mult * 2.0 * cfg.n_layers * tokens * seq_len * cfg.n_heads * cfg.head_dim
    return f


def gnn_flops(cfg, n: int, e: int, *, train: bool) -> float:
    h = cfg.d_hidden
    per_layer = 2 * h * h * (3 * e + 2 * n)
    io = 2 * n * cfg.d_feat * h + 2 * n * h * cfg.n_classes
    return (3.0 if train else 1.0) * (cfg.n_layers * per_layer + io)


def recsys_flops(cfg, params_struct, batch: int, *, kind_shape: str) -> float:
    mult = 3.0 if kind_shape == "train" else 1.0
    f = mult * 2.0 * batch * _matmul_params(params_struct)
    if cfg.kind == "sasrec":
        f += mult * cfg.n_blocks * 4.0 * batch * cfg.seq_len**2 * cfg.embed_dim
    if cfg.kind == "two_tower" and kind_shape == "train":
        dout = cfg.tower_dims[-1]
        f += mult * 2.0 * batch * batch * dout  # in-batch softmax logits
    if cfg.kind == "din":
        d = cfg.embed_dim
        attn = 4 * d * cfg.attn_dims[0] + cfg.attn_dims[0] * cfg.attn_dims[1]
        f += mult * 2.0 * batch * cfg.seq_len * attn
    if cfg.kind == "xdeepfm":
        m, dd = cfg.n_sparse, cfg.embed_dim
        cin = sum(
            2 * h_prev * m * dd * h
            for h_prev, h in zip((m,) + cfg.cin_dims[:-1], cfg.cin_dims)
        )
        f += mult * batch * cin
    return f


def lider_search_flops(rcfg, batch: int) -> float:
    cfg = rcfg.lider
    d = rcfg.dim
    hash_f = 2.0 * batch * d * (
        cfg.n_arrays * (cfg.key_len or 16)
        + cfg.n_arrays_centroid * (cfg.key_len_centroid or 10)
    )
    cen_verify = 2.0 * batch * cfg.r0_centroid * cfg.n_probe * cfg.n_arrays_centroid * d
    r = cfg.r0 * rcfg.k
    verify = 2.0 * batch * cfg.n_probe * cfg.n_arrays * r * d
    return hash_f + cen_verify + verify


def model_flops(arch: ArchSpec, shape: ShapeSpec) -> float:
    """Dispatch on family; shapes as assigned."""
    if arch.family == "lm":
        cfg = arch.config
        b = shape.dims["global_batch"]
        s = shape.dims["seq_len"]
        if shape.kind == "train":
            return lm_flops(cfg, b * s, train=True, seq_len=s)
        if shape.kind == "prefill":
            return lm_flops(cfg, b * s, train=False, seq_len=s)
        return lm_flops(cfg, b, train=False, batch=b, decode_cache=s)
    if arch.family == "gnn":
        import dataclasses

        from ..models.gnn import GNNConfig

        d = shape.dims
        cfg: GNNConfig = dataclasses.replace(
            arch.config,
            d_feat=d["d_feat"],
            n_classes=1 if d.get("regression") else d.get("n_classes", 7),
        )
        if shape.name == "minibatch_lg":
            bn = d["batch_nodes"]
            f1, f2 = d["fanout"]
            n = bn + bn * f1 + bn * f1 * f2
            e = bn * f1 + bn * f1 * f2
        elif shape.name == "molecule":
            n = d["batch"] * d["n_nodes"]
            e = d["batch"] * d["n_edges"]
        else:
            n, e = d["n_nodes"], d["n_edges"]
        return gnn_flops(cfg, n, e, train=True)
    if arch.family == "recsys":
        from ..models import recsys as R

        cfg = arch.config
        params_s = jax.eval_shape(
            lambda k: R.INIT[cfg.kind](k, cfg), jax.random.PRNGKey(0)
        )
        b = shape.dims.get("batch", shape.dims.get("n_candidates", 1))
        if shape.kind == "retrieval":
            b = shape.dims["n_candidates"]
            kind = "serve"
            if cfg.kind == "two_tower":
                return 2.0 * b * cfg.tower_dims[-1]
            if cfg.kind == "sasrec":
                return 2.0 * b * cfg.embed_dim
        else:
            kind = "train" if shape.kind == "train" else "serve"
        return recsys_flops(cfg, params_s, b, kind_shape=kind)
    # retrieval (the paper's arch)
    rcfg = arch.config
    if shape.kind == "build":
        return 2.0 * rcfg.corpus_size * rcfg.lider.n_clusters * rcfg.dim
    return lider_search_flops(rcfg, shape.dims["batch"])

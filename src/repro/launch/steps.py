"""Step construction: (arch x shape x mesh) -> lowerable jitted computation.

``make_bundle`` returns everything the dry-run needs: the step function, its
abstract inputs (ShapeDtypeStructs — **no allocation**), the in/out
shardings, and the analytic MODEL_FLOPS for the roofline's useful-compute
ratio. Train shapes lower ``train_step`` (fwd+bwd+AdamW); decode shapes lower
``serve_step`` (one token against a full KV cache); retrieval shapes lower
the candidate-scoring / LIDER-search computations.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchSpec, ShapeSpec
from ..core import bank as bank_lib
from ..core import distributed as dist
from ..core import lider as lider_lib
from ..core import lsh as lsh_lib
from ..kernels import quant as quant_lib
from ..core import rescale as rescale_lib
from ..core import rmi as rmi_lib
from ..core.core_model import CoreModelParams
from ..models import gnn as gnn_lib
from ..models import recsys as recsys_lib
from ..models import transformer as tfm
from ..training import optimizer as opt_lib
from .mesh import data_axes

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    model_flops: float
    donate_argnums: tuple = ()
    # XLA cost_analysis counts while-loop bodies ONCE; this is the dominant
    # static trip count (layer scan x grad-accum scan) used by
    # benchmarks/roofline.py to correct HLO flops/bytes (§Roofline method).
    loop_factor: float = 1.0
    # Retrieval cells only: per-storage-config index bytes split by tier
    # (device HBM vs host RAM — DESIGN.md §Tiered embedding store), recorded
    # into the dry-run JSON so memory_analysis is read against the real
    # device-resident footprint.
    tier_memory: dict | None = None


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _dense_flops(params, batch: int, *, factor: float = 2.0) -> float:
    """2*B*sum(matmul param sizes) — the analytic MODEL_FLOPS for MLP-ish
    models (factor 6 for train: fwd + 2x bwd). Embedding tables (huge first
    dim) are lookups, not matmuls — excluded."""
    total = 0
    for leaf in jax.tree.leaves(params):
        if leaf.ndim >= 2 and leaf.shape[0] < 100_000:
            total += math.prod(leaf.shape[-2:]) * math.prod(leaf.shape[:-2])
    return factor * batch * total


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _lm_param_structs(cfg: tfm.LMConfig):
    return jax.eval_shape(lambda k: tfm.init(k, cfg), jax.random.PRNGKey(0))


def _lm_flops(cfg: tfm.LMConfig, tokens: int, *, train: bool) -> float:
    n = cfg.flops_params()
    return (6.0 if train else 2.0) * n * tokens


def make_lm_bundle(
    arch: ArchSpec,
    shape: ShapeSpec,
    mesh,
    *,
    fsdp: bool = True,
    grad_accum: int | None = None,
    cfg_override: tfm.LMConfig | None = None,
) -> StepBundle:
    """``fsdp``/``grad_accum``/``cfg_override`` are the §Perf iteration
    knobs; defaults are the recorded baseline."""
    cfg: tfm.LMConfig = cfg_override or arch.config
    dp = data_axes(mesh)
    b = shape.dims["global_batch"]
    s = shape.dims["seq_len"]
    params_s = _lm_param_structs(cfg)
    pspecs = tfm.param_specs(cfg, mesh.axis_names, fsdp=fsdp)
    params_ns = _ns(mesh, pspecs)

    if shape.kind == "train":
        opt_cfg = opt_lib.OptimizerConfig()
        opt_s = jax.eval_shape(opt_lib.init_state, params_s)
        opt_ns = {"mu": params_ns, "nu": params_ns, "step": NamedSharding(mesh, P())}
        dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
        # Microbatch so one sequence per device is live at a time (the
        # activation-memory knob; grads accumulate sharded per FSDP specs).
        if grad_accum is None:
            grad_accum = max(1, b // max(dp_size, 1))
        from ..training.train_loop import make_train_step

        train_step = make_train_step(
            lambda p, mb: tfm.train_loss(p, cfg, mb), opt_cfg, grad_accum=grad_accum
        )

        batch_s = {
            "tokens": SDS((b, s), jnp.int32),
            "targets": SDS((b, s), jnp.int32),
        }
        batch_ns = _ns(mesh, {"tokens": P(dp, None), "targets": P(dp, None)})
        return StepBundle(
            name=f"{arch.arch_id}:{shape.name}",
            fn=train_step,
            args=(params_s, opt_s, batch_s),
            in_shardings=(params_ns, opt_ns, batch_ns),
            out_shardings=(params_ns, opt_ns, None),
            model_flops=_lm_flops(cfg, b * s, train=True),
            donate_argnums=(0, 1),
            loop_factor=float(cfg.n_layers * grad_accum),
        )

    if shape.kind == "prefill":
        def prefill_step(params, tokens):
            return tfm.prefill(params, cfg, tokens)

        tokens_s = SDS((b, s), jnp.int32)
        cache_out = _ns(
            mesh, tfm.cache_specs(cfg, mesh.axis_names, seq_sharded=False)
        )
        # prefill cache: batch over data, sequence over model (tfm.prefill
        # constrains the same layout internally).
        return StepBundle(
            name=f"{arch.arch_id}:{shape.name}",
            fn=prefill_step,
            args=(params_s, tokens_s),
            in_shardings=(params_ns, NamedSharding(mesh, P(dp, None))),
            out_shardings=(None, cache_out),
            model_flops=_lm_flops(cfg, b * s, train=False),
            donate_argnums=(),
            loop_factor=float(cfg.n_layers),
        )

    # decode: one new token against a seq_len KV cache. Batch-1 long-context
    # shards the cache sequence axis (flash-decoding); batched decode shards
    # the batch axis.
    seq_sharded = b < math.prod(mesh.shape[a] for a in dp)
    cache_s = {
        "k": SDS((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
        "v": SDS((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
        "length": SDS((), jnp.int32),
    }
    cache_ns = _ns(
        mesh, tfm.cache_specs(cfg, mesh.axis_names, seq_sharded=seq_sharded)
    )
    token_s = SDS((b, 1), jnp.int32)
    token_sharding = NamedSharding(mesh, P(dp if not seq_sharded else None, None))

    def serve_step(params, cache, token):
        return tfm.decode_step(params, cfg, cache, token)

    attn_flops = (
        4.0 * cfg.n_layers * b * cfg.n_heads * s * cfg.head_dim
    )  # QK^T + PV against the cache
    return StepBundle(
        name=f"{arch.arch_id}:{shape.name}",
        fn=serve_step,
        args=(params_s, cache_s, token_s),
        in_shardings=(params_ns, cache_ns, token_sharding),
        out_shardings=None,
        model_flops=_lm_flops(cfg, b, train=False) + attn_flops,
        donate_argnums=(1,),
        loop_factor=float(cfg.n_layers),
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def _gnn_cfg_for_shape(base: gnn_lib.GNNConfig, shape: ShapeSpec) -> gnn_lib.GNNConfig:
    d = shape.dims
    return dataclasses.replace(
        base,
        d_feat=d["d_feat"],
        d_edge=d.get("d_edge", 0),
        n_classes=1 if d.get("regression") else d.get("n_classes", base.n_classes),
        readout="graph" if d.get("regression") else "node",
    )


def _gnn_flops(cfg: gnn_lib.GNNConfig, n: int, e: int, *, train: bool) -> float:
    h = cfg.d_hidden
    per_layer = 2 * h * h * (3 * e + 2 * n)  # A,B,C on edges; U,V on nodes
    io = 2 * n * cfg.d_feat * h + 2 * n * h * cfg.n_classes
    return (3.0 if train else 1.0) * (cfg.n_layers * per_layer + io)


def make_gnn_bundle(arch: ArchSpec, shape: ShapeSpec, mesh) -> StepBundle:
    base: gnn_lib.GNNConfig = arch.config
    cfg = _gnn_cfg_for_shape(base, shape)
    dp = data_axes(mesh)
    d = shape.dims
    opt_cfg = opt_lib.OptimizerConfig()

    if shape.name == "minibatch_lg":
        # Input is the sampled block (sampler runs in the data pipeline).
        bn = d["batch_nodes"]
        f1, f2 = d["fanout"]
        n = bn + bn * f1 + bn * f1 * f2
        e = bn * f1 + bn * f1 * f2
        graph_s = {
            "node_feat": SDS((n, cfg.d_feat), jnp.float32),
            "edge_index": SDS((2, e), jnp.int32),
            "labels": SDS((n,), jnp.int32),
            "label_mask": SDS((n,), jnp.float32),
        }
        graph_spec = {
            "node_feat": P(),
            "edge_index": P(None, dp),
            "labels": P(),
            "label_mask": P(),
        }
    elif shape.name == "molecule":
        g = d["batch"]
        n = g * d["n_nodes"]
        e = g * d["n_edges"]
        graph_s = {
            "node_feat": SDS((n, cfg.d_feat), jnp.float32),
            "edge_index": SDS((2, e), jnp.int32),
            "edge_feat": SDS((e, cfg.d_edge), jnp.float32),
            "graph_ids": SDS((n,), jnp.int32),
            "n_graphs": g,
            "graph_targets": SDS((g,), jnp.float32),
        }
        graph_spec = {
            "node_feat": P(dp, None),
            "edge_index": P(None, dp),
            "edge_feat": P(dp, None),
            "graph_ids": P(dp),
            "n_graphs": None,
            "graph_targets": P(),
        }
    else:  # full-batch: full_graph_sm / ogb_products
        n_raw, e_raw = d["n_nodes"], d["n_edges"]
        # Pad nodes+edges to shard evenly on any mesh (jit *arguments* need
        # exact divisibility; padded nodes carry label_mask=0, padded edges
        # edge_mask=0, so training is exact). Edges shard over EVERY axis
        # (the model axis is otherwise idle for GNNs); node states shard over
        # 'model' inside the layer scan (gnn.forward constraints).
        n = math.ceil(n_raw / 1024) * 1024
        e = math.ceil(e_raw / 1024) * 1024
        tp = ("model",) if "model" in mesh.axis_names else ()
        all_axes = dp + tp
        graph_s = {
            "node_feat": SDS((n, cfg.d_feat), jnp.float32),
            "edge_index": SDS((2, e), jnp.int32),
            "edge_mask": SDS((e,), jnp.float32),
            "labels": SDS((n,), jnp.int32),
            "label_mask": SDS((n,), jnp.float32),
        }
        graph_spec = {
            "node_feat": P(tp if tp else None, None),
            "edge_index": P(None, all_axes),
            "edge_mask": P(all_axes),
            "labels": P(tp if tp else None),
            "label_mask": P(tp if tp else None),
        }

    params_s = jax.eval_shape(lambda k: gnn_lib.init(k, cfg), jax.random.PRNGKey(0))
    params_ns = jax.tree.map(lambda _: NamedSharding(mesh, P()), params_s)
    opt_s = jax.eval_shape(opt_lib.init_state, params_s)
    opt_ns = {"mu": params_ns, "nu": params_ns, "step": NamedSharding(mesh, P())}

    def train_step(params, opt_state, graph):
        loss, grads = jax.value_and_grad(gnn_lib.train_loss)(params, cfg, graph)
        params, opt_state, metrics = opt_lib.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics["loss"] = loss
        return params, opt_state, metrics

    # Static leaves (n_graphs) are not shardable args — bind via closure.
    static = {k: v for k, v in graph_s.items() if not isinstance(v, SDS)}
    dyn_s = {k: v for k, v in graph_s.items() if isinstance(v, SDS)}
    dyn_spec = {k: graph_spec[k] for k in dyn_s}

    def step(params, opt_state, graph):
        return train_step(params, opt_state, {**graph, **static})

    return StepBundle(
        name=f"{arch.arch_id}:{shape.name}",
        fn=step,
        args=(params_s, opt_s, dyn_s),
        in_shardings=(params_ns, opt_ns, _ns(mesh, dyn_spec)),
        out_shardings=(params_ns, opt_ns, None),
        model_flops=_gnn_flops(cfg, n, e, train=True),
        donate_argnums=(0, 1),
        loop_factor=float(cfg.n_layers),
    )


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def _recsys_batch_structs(cfg: recsys_lib.RecsysConfig, batch: int) -> dict:
    k = cfg.kind
    if k == "sasrec":
        return {
            "seq": SDS((batch, cfg.seq_len), jnp.int32),
            "pos": SDS((batch, cfg.seq_len), jnp.int32),
            "neg": SDS((batch, cfg.seq_len), jnp.int32),
        }
    if k == "two_tower":
        return {
            "user_fields": SDS((batch, cfg.n_user_fields), jnp.int32),
            "item_fields": SDS((batch, cfg.n_item_fields), jnp.int32),
        }
    if k == "din":
        return {
            "history": SDS((batch, cfg.seq_len), jnp.int32),
            "target": SDS((batch,), jnp.int32),
            "label": SDS((batch,), jnp.float32),
        }
    if k == "xdeepfm":
        return {
            "fields": SDS((batch, cfg.n_sparse), jnp.int32),
            "label": SDS((batch,), jnp.float32),
        }
    raise ValueError(k)


def _recsys_forward(cfg: recsys_lib.RecsysConfig):
    k = cfg.kind
    if k == "sasrec":
        return lambda p, b: recsys_lib.sasrec_forward(p, cfg, b["seq"])[:, -1]
    if k == "two_tower":
        return lambda p, b: recsys_lib.user_embed(p, cfg, b["user_fields"])
    if k == "din":
        return lambda p, b: recsys_lib.din_forward(p, cfg, b)
    if k == "xdeepfm":
        return lambda p, b: recsys_lib.xdeepfm_forward(p, cfg, b)
    raise ValueError(k)


def make_recsys_bundle(arch: ArchSpec, shape: ShapeSpec, mesh) -> StepBundle:
    cfg: recsys_lib.RecsysConfig = arch.config
    dp = data_axes(mesh)
    init_fn = recsys_lib.INIT[cfg.kind]
    params_s = jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.PRNGKey(0))
    pspecs = recsys_lib.param_specs(params_s)
    params_ns = _ns(mesh, pspecs)
    name = f"{arch.arch_id}:{shape.name}"

    if shape.kind == "train":
        b = shape.dims["batch"]
        opt_cfg = opt_lib.OptimizerConfig()
        loss_fn = recsys_lib.LOSS[cfg.kind]
        opt_s = jax.eval_shape(opt_lib.init_state, params_s)
        opt_ns = {"mu": params_ns, "nu": params_ns, "step": NamedSharding(mesh, P())}

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
            params, opt_state, metrics = opt_lib.apply_updates(
                params, grads, opt_state, opt_cfg
            )
            metrics["loss"] = loss
            return params, opt_state, metrics

        batch_s = _recsys_batch_structs(cfg, b)
        batch_ns = _ns(
            mesh,
            jax.tree.map(
                lambda x: P(dp, *([None] * (x.ndim - 1))), batch_s
            ),
        )
        return StepBundle(
            name=name,
            fn=train_step,
            args=(params_s, opt_s, batch_s),
            in_shardings=(params_ns, opt_ns, batch_ns),
            out_shardings=(params_ns, opt_ns, None),
            model_flops=_dense_flops(params_s, b, factor=6.0),
            donate_argnums=(0, 1),
        )

    if shape.kind == "serve":
        b = shape.dims["batch"]
        fwd = _recsys_forward(cfg)
        batch_s = _recsys_batch_structs(cfg, b)
        batch_s.pop("label", None)
        batch_s.pop("pos", None)
        batch_s.pop("neg", None)
        batch_ns = _ns(
            mesh,
            jax.tree.map(lambda x: P(dp, *([None] * (x.ndim - 1))), batch_s),
        )
        return StepBundle(
            name=name,
            fn=lambda p, b_: fwd(p, b_),
            args=(params_s, batch_s),
            in_shardings=(params_ns, batch_ns),
            out_shardings=None,
            model_flops=_dense_flops(params_s, b, factor=2.0),
            donate_argnums=(),
        )

    # retrieval_cand: one query context scored against n_candidates items.
    c = shape.dims["n_candidates"]
    k_top = 100
    if cfg.kind == "two_tower":
        cand_s = SDS((c, cfg.tower_dims[-1]), jnp.float32)
        user_s = SDS((1, cfg.n_user_fields), jnp.int32)

        def retrieval_step(params, user_fields, cand_embs):
            return recsys_lib.two_tower_score_candidates(
                params, cfg, user_fields, cand_embs, k_top
            )

        args = (params_s, user_s, cand_s)
        shardings = (
            params_ns,
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(dp, None)),
        )
        flops = 2.0 * c * cfg.tower_dims[-1] + _dense_flops(
            {"t": params_s["user_tower"]}, 1, factor=2.0
        )
    elif cfg.kind == "sasrec":
        seq_s = SDS((1, cfg.seq_len), jnp.int32)
        cand_ids = SDS((c,), jnp.int32)

        def retrieval_step(params, seq, cands):
            h = recsys_lib.sasrec_forward(params, cfg, seq)[:, -1]  # (1, d)
            emb = recsys_lib.embedding_lookup(params["item_emb"], cands)
            scores = (emb @ h[0]).astype(jnp.float32)
            return jax.lax.top_k(scores, k_top)

        args = (params_s, seq_s, cand_ids)
        shardings = (
            params_ns,
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(dp)),
        )
        flops = 2.0 * c * cfg.embed_dim
    elif cfg.kind == "din":
        hist_s = SDS((1, cfg.seq_len), jnp.int32)
        cand_ids = SDS((c,), jnp.int32)

        def retrieval_step(params, history, cands):
            hist = jnp.broadcast_to(history, (c, cfg.seq_len))
            logits = recsys_lib.din_forward(
                params, cfg, {"history": hist, "target": cands}
            )
            return jax.lax.top_k(logits, k_top)

        args = (params_s, hist_s, cand_ids)
        shardings = (
            params_ns,
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(dp)),
        )
        flops = 2.0 * c * cfg.seq_len * (
            4 * cfg.embed_dim * cfg.attn_dims[0]
            + cfg.attn_dims[0] * cfg.attn_dims[1]
        ) + _dense_flops({"m": params_s["mlp"]}, c, factor=2.0)
    else:  # xdeepfm
        fields_s = SDS((c, cfg.n_sparse), jnp.int32)

        def retrieval_step(params, fields):
            logits = recsys_lib.xdeepfm_forward(params, cfg, {"fields": fields})
            return jax.lax.top_k(logits, k_top)

        args = (params_s, fields_s)
        shardings = (params_ns, NamedSharding(mesh, P(dp, None)))
        m, dd = cfg.n_sparse, cfg.embed_dim
        cin = sum(
            2 * h_prev * m * dd * h
            for h_prev, h in zip((m,) + cfg.cin_dims[:-1], cfg.cin_dims)
        )
        flops = c * (cin + 2 * m * dd * cfg.dnn_dims[0])

    return StepBundle(
        name=name,
        fn=retrieval_step,
        args=args,
        in_shardings=shardings,
        out_shardings=None,
        model_flops=float(flops),
        donate_argnums=(),
    )


# ---------------------------------------------------------------------------
# Retrieval family (the paper's own arch)
# ---------------------------------------------------------------------------


def lider_param_structs(
    rcfg,
    emb_dtype=jnp.float32,
    storage_dtype: str | None = None,
    rescore_tier: str | None = None,
) -> lider_lib.LiderParams:
    """Abstract LiderParams for the dry-run (no 38 GB corpus allocation).

    ``storage_dtype`` (default: the arch config's ``lider.storage_dtype``)
    shapes the bank's storage representation; "int8" / "int4" add the
    abstract ``emb_scales``/``rescore_embs`` leaves so the quantized sharded
    search lowers and compiles in the dry-run (DESIGN.md §Quantized bank) —
    int4 codes are packed two per byte, so the abstract ``embs`` leaf is
    (c, Lp, d//2) int8. Quantized banks also carry the abstract packed
    1-bit ``sketches`` leaf — (c, Lp, ceil(d/32)) uint32 — so searches with
    ``sketch_factor`` set lower in the dry-run and the memory model counts
    the sketch table (DESIGN.md §Binary sketch tier).

    ``rescore_tier="host"`` (quantized only) attaches an *abstract*
    host-tier ``EmbStore`` instead of the ``rescore_embs`` leaf — the pytree
    the jit'd device program sees shrinks to codes + scales, which is
    exactly what the dry-run's ``memory_analysis`` / per-tier accounting
    should reflect (DESIGN.md §Tiered embedding store).
    """
    cfg: lider_lib.LiderConfig = rcfg.lider
    storage_dtype = storage_dtype or cfg.storage_dtype
    rescore_tier = rescore_tier or cfg.rescore_tier
    quantized = storage_dtype in ("int8", "int4")
    if rescore_tier == "host" and not quantized:
        raise ValueError(
            "rescore_tier='host' requires storage_dtype='int8' or 'int4'"
        )
    c, d, lp = cfg.n_clusters, rcfg.dim, rcfg.capacity
    if storage_dtype == "int4" and d % 2:
        raise ValueError(f"int4 packing requires even dim, got d={d}")
    h, hc = cfg.n_arrays, cfg.n_arrays_centroid
    m, mc = cfg.key_len, cfg.key_len_centroid
    w, wc = cfg.n_leaves, cfg.n_leaves_centroid

    def rmi_s(lead, nl):
        return rmi_lib.RMIParams(
            root_w=SDS(lead, jnp.float32),
            root_b=SDS(lead, jnp.float32),
            leaf_w=SDS(lead + (nl,), jnp.float32),
            leaf_b=SDS(lead + (nl,), jnp.float32),
            length=SDS(lead, jnp.float32),
            max_err=SDS(lead + (nl,), jnp.float32),
            n_leaves=nl,
        )

    def resc_s(lead):
        return rescale_lib.RescaleParams(
            key_min=SDS(lead, jnp.uint32),
            key_max=SDS(lead, jnp.uint32),
            length=SDS(lead, jnp.float32),
        )

    centroid_cm = CoreModelParams(
        lsh=lsh_lib.LSHParams(
            projections=SDS((d, hc * mc), jnp.float32), n_arrays=hc, key_len=mc
        ),
        rescale=resc_s((hc,)),
        rmi=rmi_s((hc,), wc),
        sorted_keys=SDS((hc, c), jnp.uint32),
        sorted_ids=SDS((hc, c), jnp.int32),
    )
    return lider_lib.LiderParams(
        centroid_cm=centroid_cm,
        centroids=SDS((c, d), jnp.float32),
        bank=bank_lib.ClusterBank(
            lsh=lsh_lib.LSHParams(
                projections=SDS((d, h * m), jnp.float32), n_arrays=h, key_len=m
            ),
            rescale=resc_s((c, h)),
            rmi=rmi_s((c, h), w),
            sorted_keys=SDS((c, h, lp), jnp.uint32),
            sorted_pos=SDS((c, h, lp), jnp.int32),
            embs=SDS(
                (c, lp, d // 2 if storage_dtype == "int4" else d),
                jnp.int8 if quantized else emb_dtype,
            ),
            gids=SDS((c, lp), jnp.int32),
            sizes=SDS((c,), jnp.int32),
            tombstones=SDS((c,), jnp.int32),
            next_gid=SDS((), jnp.int32),
            emb_scales=(SDS((c, lp), jnp.float32) if quantized else None),
            rescore_embs=(
                SDS((c, lp, d), emb_dtype)
                if quantized and rescore_tier == "device"
                else None
            ),
            store=(
                bank_lib.EmbStore("host", shape=(c, lp, d))
                if quantized and rescore_tier == "host"
                else None
            ),
            sketches=(
                SDS((c, lp, quant_lib.sketch_width(d)), jnp.uint32)
                if quantized
                else None
            ),
            code_dtype=storage_dtype if quantized else "int8",
        ),
    )


def _lider_flops(rcfg, batch: int) -> float:
    cfg = rcfg.lider
    d = rcfg.dim
    hash_f = 2.0 * batch * d * (
        cfg.n_arrays * (cfg.key_len or 16)
        + cfg.n_arrays_centroid * (cfg.key_len_centroid or 10)
    )
    cen_verify = 2.0 * batch * cfg.r0_centroid * cfg.n_probe * cfg.n_arrays_centroid * d
    r = cfg.r0 * rcfg.k
    verify = 2.0 * batch * cfg.n_probe * cfg.n_arrays * r * d
    return hash_f + cen_verify + verify


def lider_tier_memory(rcfg) -> dict:
    """Per-tier index bytes for the storage configs the memory story
    compares at this arch's shape: f32 (the baseline), int8/int4 with a
    device-resident rescore table (*more* HBM than f32), and int8/int4 with
    the host tier (codes + scales only on device). Asserts the tiering
    actually pays: quantized+host device bytes must drop vs both, and the
    packed int4 codes must halve the code table vs int8+host."""
    variants = {
        "float32_device": lider_param_structs(
            rcfg, storage_dtype="float32", rescore_tier="device"
        ),
        "int8_device": lider_param_structs(
            rcfg, storage_dtype="int8", rescore_tier="device"
        ),
        "int8_host": lider_param_structs(
            rcfg, storage_dtype="int8", rescore_tier="host"
        ),
        "int4_device": lider_param_structs(
            rcfg, storage_dtype="int4", rescore_tier="device"
        ),
        "int4_host": lider_param_structs(
            rcfg, storage_dtype="int4", rescore_tier="host"
        ),
    }
    out = {name: p.bank.nbytes_by_tier() for name, p in variants.items()}
    # The 1-bit sketch table rides along on every quantized variant; record
    # its bytes explicitly so the memory story can show what the pre-filter
    # tier costs (1/8 of the int8 code table — §Binary sketch tier).
    c, lp = rcfg.lider.n_clusters, rcfg.capacity
    sketch_bytes = c * lp * quant_lib.sketch_width(rcfg.dim) * 4
    out["sketch_table"] = {"device": int(sketch_bytes), "host": 0}
    assert (
        out["int8_host"]["device"]
        - variants["int8_host"].bank.embs.size  # codes
        - variants["int8_host"].bank.emb_scales.size * 4  # scales
        >= sketch_bytes
    ), "quantized device bytes must include the sketch table"
    assert out["int8_host"]["device"] < out["int8_device"]["device"], (
        "host tier must shrink the device-resident index"
    )
    assert out["int8_host"]["device"] < out["float32_device"]["device"], (
        "int8+host must beat the f32 device footprint"
    )
    assert out["int4_host"]["device"] < out["int8_host"]["device"], (
        "packed int4 codes must shrink the device-resident index vs int8"
    )
    return out


def make_retrieval_bundle(
    arch: ArchSpec,
    shape: ShapeSpec,
    mesh,
    *,
    emb_dtype=jnp.float32,
    r0: int | None = None,
    refine: bool = False,
    capacity_factor: float = 2.0,
    storage_dtype: str | None = None,
    rescore_tier: str | None = None,
) -> StepBundle:
    """``emb_dtype``/``r0``/``refine`` are §Perf iteration knobs;
    ``storage_dtype``/``rescore_tier`` override the arch config's embedding
    storage layout (the dry-run's tier axis)."""
    rcfg = arch.config
    cfg: lider_lib.LiderConfig = rcfg.lider
    dp = data_axes(mesh)
    name = f"{arch.arch_id}:{shape.name}"

    if shape.kind == "build":
        step = dist.make_sharded_kmeans_step(
            mesh, n_clusters=cfg.n_clusters, data_axes=dp
        )
        x_s = SDS((rcfg.corpus_size, rcfg.dim), jnp.float32)
        cen_s = SDS((cfg.n_clusters, rcfg.dim), jnp.float32)
        dp_size = math.prod(mesh.shape[a] for a in dp)
        return StepBundle(
            name=name,
            fn=step,
            args=(x_s, cen_s),
            in_shardings=(
                NamedSharding(mesh, P(dp, None)),
                NamedSharding(mesh, P()),
            ),
            out_shardings=None,
            model_flops=2.0 * rcfg.corpus_size * cfg.n_clusters * rcfg.dim,
            donate_argnums=(),
            loop_factor=float(rcfg.corpus_size // dp_size // 4096),
        )

    b = shape.dims["batch"]
    q_axes = ("model",) if ("model" in mesh.axis_names and b % mesh.shape["model"] == 0) else ()
    params_s = lider_param_structs(
        rcfg,
        emb_dtype=emb_dtype,
        storage_dtype=storage_dtype,
        rescore_tier=rescore_tier,
    )
    search = dist.make_sharded_search(
        mesh,
        params_s,
        k=rcfg.k,
        n_probe=cfg.n_probe,
        r0=r0 or cfg.r0,
        r0_centroid=cfg.r0_centroid,
        cluster_axes=dp,
        query_axes=q_axes,
        capacity_factor=capacity_factor,
        refine=refine,
    )
    specs = dist.lider_param_specs(params_s, dp)
    # Host-tier searches are two device phases around a host fetch; the
    # lowerable device program is stage1 (the compressed pass + merge).
    fn = getattr(search, "stage1", search)
    return StepBundle(
        name=name,
        fn=fn,
        args=(params_s, SDS((b, rcfg.dim), jnp.float32)),
        in_shardings=(
            _ns(mesh, specs),
            NamedSharding(mesh, P(q_axes if q_axes else None, None)),
        ),
        out_shardings=None,
        model_flops=_lider_flops(rcfg, b),
        donate_argnums=(),
        tier_memory=lider_tier_memory(rcfg),
    )


FAMILY_BUILDERS = {
    "lm": make_lm_bundle,
    "gnn": make_gnn_bundle,
    "recsys": make_recsys_bundle,
    "retrieval": make_retrieval_bundle,
}


def make_bundle(arch: ArchSpec, shape: ShapeSpec, mesh) -> StepBundle:
    return FAMILY_BUILDERS[arch.family](arch, shape, mesh)

"""Serving launcher: build a LIDER (or baseline) index over a corpus and
serve batched queries.

``python -m repro.launch.serve --backend lider --corpus-size 100000 --queries 1024``

Reports AQT (the paper's efficiency metric) and recall@k vs the Flat exact
search — the end-to-end serving driver for the paper's system.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..core import lider as lider_lib
from ..core.baselines import build_ivfpq, build_mplsh, build_pq, build_sklsh, flat_search
from ..core.utils import recall_at_k
from ..data import synthetic
from ..serving import RetrievalEngine, make_backend


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--backend",
        choices=["lider", "flat", "pq", "ivfpq", "sklsh", "mplsh"],
        default="lider",
    )
    ap.add_argument("--corpus-size", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--n-clusters", type=int, default=64)
    ap.add_argument("--n-probe", type=int, default=8)
    ap.add_argument("--refine", action="store_true")
    ap.add_argument(
        "--use-fused",
        choices=["auto", "on", "off"],
        default="auto",
        help="verification kernel: fused Pallas pass (on), materialized "
        "reference (off), or backend-dispatch (auto; DESIGN.md "
        "§Verification-kernel)",
    )
    ap.add_argument("--embeddings", default=None, help=".npy drop-in corpus")
    args = ap.parse_args()
    use_fused = {"auto": None, "on": True, "off": False}[args.use_fused]

    if args.embeddings:
        embs = synthetic.load_embeddings(args.embeddings)
    else:
        embs = synthetic.retrieval_corpus(0, args.corpus_size, args.dim)
    queries, _ = synthetic.retrieval_queries(1, embs, args.queries)

    t0 = time.time()
    index = None
    if args.backend == "lider":
        cfg = lider_lib.LiderConfig(
            n_clusters=args.n_clusters,
            n_probe=args.n_probe,
            refine=args.refine,
            use_fused=use_fused,
        )
        index = lider_lib.build_lider(jax.random.PRNGKey(0), embs, cfg)
        # Config is the single source for the search-time knobs below
        # (same convention as n_probe/refine).
        use_fused = cfg.use_fused
    elif args.backend == "pq":
        index = build_pq(jax.random.PRNGKey(0), embs)
    elif args.backend == "ivfpq":
        index = build_ivfpq(jax.random.PRNGKey(0), embs)
    elif args.backend == "sklsh":
        index = build_sklsh(jax.random.PRNGKey(0), embs)
    elif args.backend == "mplsh":
        index = build_mplsh(jax.random.PRNGKey(0), embs)
    build_s = time.time() - t0
    print(f"[serve] backend={args.backend} build={build_s:.1f}s")

    search = make_backend(
        args.backend,
        index,
        embs,
        n_probe=args.n_probe,
        refine=args.refine,
        use_fused=use_fused,
    )
    engine = RetrievalEngine(
        search, batch_size=args.batch_size, k=args.k, dim=embs.shape[1]
    )
    engine.warmup()
    rids = [engine.submit(q) for q in jax.device_get(queries)]
    engine.drain()
    print(
        f"[serve] {engine.stats.n_queries} queries in "
        f"{engine.stats.total_time_s:.3f}s -> AQT={engine.stats.aqt*1e3:.3f} ms "
        f"(padding {engine.stats.padding_fraction:.1%})"
    )

    gt = flat_search(embs, queries, k=args.k)
    got = jnp.stack([engine.result(r)[0] for r in rids])
    rec = recall_at_k(got, gt.ids)
    print(f"[serve] recall@{args.k} vs Flat = {float(rec):.4f}")


if __name__ == "__main__":
    main()

"""Serving launcher: build (or load) a LIDER/baseline index over a corpus and
serve batched queries, optionally with mixed update/search traffic.

``python -m repro.launch.serve --backend lider --corpus-size 100000 --queries 1024``

Index lifecycle (LIDER only — DESIGN.md §Index lifecycle):

- ``--load-index DIR`` serves a checkpointed index instead of building;
- ``--save-index DIR`` persists the served index (post-updates) on exit;
- ``--update-fraction F`` holds out an F fraction of the corpus, builds on
  the rest, serves half the queries, upserts the holdout between batches via
  ``RetrievalEngine.apply_updates`` (recompiling only if capacity grew), then
  serves the remaining queries — the online-corpus scenario.

Reports AQT (the paper's efficiency metric) and recall@k vs the Flat exact
search — the end-to-end serving driver for the paper's system.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from .. import faults
from ..core import lider as lider_lib
from ..core import update as update_lib
from ..core.baselines import build_ivfpq, build_mplsh, build_pq, build_sklsh, flat_search
from ..core.utils import recall_at_k
from ..data import synthetic
from ..serving import (
    DegradePolicy,
    QueryResult,
    QueryRouter,
    RetrievalEngine,
    RouterConfig,
    SchedulerConfig,
    clone_params,
    make_backend,
)
from ..serving import traffic
from ..serving.engine import EngineStats
from ..training import checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--backend",
        choices=["lider", "flat", "pq", "ivfpq", "sklsh", "mplsh"],
        default="lider",
    )
    ap.add_argument("--corpus-size", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--n-clusters", type=int, default=64)
    ap.add_argument("--n-probe", type=int, default=8)
    ap.add_argument("--refine", action="store_true")
    ap.add_argument(
        "--prune-margin", type=float, default=None,
        help="adaptive probe pruning: mask probes scoring more than this "
        "margin below the per-query best (LIDER only; DESIGN.md §Adaptive)",
    )
    ap.add_argument(
        "--recall-target", type=float, default=None,
        help="autotune (n_probe, prune_margin) on held-out queries and serve "
        "the cheapest operating point meeting this recall@k (LIDER only; "
        "overrides --n-probe/--prune-margin)",
    )
    ap.add_argument(
        "--storage-dtype",
        choices=["float32", "bfloat16", "int8", "int4"],
        default="float32",
        help="embedding storage dtype for the LIDER bank (DESIGN.md "
        "§Quantized bank); int8/int4 add an exact rescore of the "
        "provisional top-(rescore_factor*k); int4 packs two codes per byte",
    )
    ap.add_argument(
        "--rescore-factor", type=int, default=4,
        help="k' = rescore_factor * k provisional candidates exactly "
        "rescored on quantized (int8/int4) banks (LIDER only)",
    )
    ap.add_argument(
        "--rescore-tier",
        choices=["device", "host"],
        default=None,
        help="where the quantized bank's full-precision rescore table lives "
        "(DESIGN.md §Tiered embedding store): device (resident next to the "
        "codes) or host (process-local RAM; the engine pipelines the "
        "fetch->rescore stages). Default: device on build, the saved tier "
        "on --load-index",
    )
    ap.add_argument(
        "--block-c", type=int, default=None,
        help="verification-kernel candidate block size (default: kernel "
        "default, 256)",
    )
    ap.add_argument(
        "--block-q", type=int, default=None,
        help="cluster-major query-tile width: queries probing the same "
        "cluster share one DMA of its rows (quantized banks only; "
        "DESIGN.md §Cluster-major schedule). Default: per-query schedule",
    )
    ap.add_argument(
        "--sketch-factor", type=int, default=None,
        help="1-bit Hamming pre-filter ahead of the quantized first pass, "
        "keeping sketch_factor * k' survivor rows per query (quantized "
        "banks only; DESIGN.md §Binary sketch tier). Default: no pre-filter",
    )
    ap.add_argument(
        "--use-fused",
        choices=["auto", "on", "off"],
        default="auto",
        help="verification kernel: fused Pallas pass (on), materialized "
        "reference (off), or backend-dispatch (auto; DESIGN.md "
        "§Verification-kernel)",
    )
    ap.add_argument("--embeddings", default=None, help=".npy drop-in corpus")
    ap.add_argument(
        "--save-index", default=None, metavar="DIR",
        help="persist the (post-update) LIDER index before exit",
    )
    ap.add_argument(
        "--load-index", default=None, metavar="DIR",
        help="serve a checkpointed LIDER index instead of building",
    )
    ap.add_argument(
        "--update-fraction", type=float, default=0.0,
        help="hold out this corpus fraction and upsert it mid-traffic "
        "(LIDER only; exercises RetrievalEngine.apply_updates)",
    )
    ap.add_argument(
        "--stats-json", default=None, metavar="PATH",
        help="write engine stats + recall + per-tier index bytes as JSON "
        "(what the CI serve smoke job uploads)",
    )
    ap.add_argument(
        "--fault-plan", default=None, metavar="JSON",
        help="chaos testing: a faults.FaultPlan JSON file (or inline JSON "
        "object) injected into drain/apply_updates — the engine retries, "
        "degrades, or rolls back instead of failing (DESIGN.md §Failure "
        "model)",
    )
    ap.add_argument(
        "--deadline-s", type=float, default=None,
        help="per-request answer deadline driving the engine's degradation "
        "controller and deadline-miss accounting",
    )
    # Async front-end knobs (DESIGN.md §Serving front end).
    ap.add_argument(
        "--arrival", choices=["closed", "zipf", "burst"], default="closed",
        help="traffic shape: closed (submit-all/drain, the legacy loop), "
        "zipf (open-loop Poisson arrivals, Zipf-popular queries), or burst "
        "(zipf + alternating high-rate episodes)",
    )
    ap.add_argument(
        "--arrival-rate", type=float, default=None, metavar="QPS",
        help="open-loop mean arrival rate; default: 2x the measured warm "
        "full-batch throughput (mild overload)",
    )
    ap.add_argument(
        "--tenants", type=int, default=1,
        help="number of tenants; submits are spread across per-tenant "
        "weighted-fair queues",
    )
    ap.add_argument(
        "--slo-ms", type=float, default=None,
        help="per-request latency SLO (milliseconds): drives the "
        "scheduler's load signal, dynamic batch-size cap, and — with a "
        "degradation ladder — online frontier navigation",
    )
    ap.add_argument(
        "--cache-size", type=int, default=0,
        help="result-cache capacity (entries); hits are bit-identical to a "
        "fresh search and invalidated on apply_updates",
    )
    ap.add_argument(
        "--dynamic-batch", action="store_true",
        help="size each dispatch from the pre-warmed pow2 batch ladder "
        "(queue depth + SLO headroom) instead of always padding to "
        "--batch-size",
    )
    # Multi-replica serving fabric (DESIGN.md §Replica fabric).
    ap.add_argument(
        "--replicas", type=int, default=1,
        help="serve through a health-checked QueryRouter over this many "
        "replica engines (each with its own params/generation) instead of "
        "one engine",
    )
    ap.add_argument(
        "--hedge-quantile", type=float, default=0.95,
        help="router hedging deadline as a quantile of recent batch "
        "latencies; values outside (0, 1) disable hedging",
    )
    ap.add_argument(
        "--rolling-update", action="store_true",
        help="apply the --update-fraction holdout upsert as a rolling "
        "update (RouterControl.apply_updates): replicas drain and update "
        "one at a time behind the health mask — zero downtime, zero "
        "wrong-generation answers (needs --replicas >= 2)",
    )
    args = ap.parse_args()
    use_fused = {"auto": None, "on": True, "off": False}[args.use_fused]
    lifecycle = args.save_index or args.load_index or args.update_fraction > 0
    if lifecycle and args.backend != "lider":
        raise SystemExit("--save-index/--load-index/--update-fraction need --backend lider")
    adaptive = args.prune_margin is not None or args.recall_target is not None
    if adaptive and args.backend != "lider":
        raise SystemExit("--prune-margin/--recall-target need --backend lider")
    if args.rescore_tier is not None and args.backend != "lider":
        raise SystemExit("--rescore-tier needs --backend lider")
    if (
        args.rescore_tier == "host"
        and args.storage_dtype not in ("int8", "int4")
        and not args.load_index
    ):
        # Build path only: a loaded checkpoint carries its own storage dtype
        # (load_index validates the tier against it).
        raise SystemExit("--rescore-tier host needs --storage-dtype int8/int4")
    if args.block_q is not None and args.backend != "lider":
        raise SystemExit("--block-q needs --backend lider")
    if (
        args.block_q is not None
        and args.storage_dtype not in ("int8", "int4")
        and not args.load_index
    ):
        raise SystemExit("--block-q needs --storage-dtype int8/int4")
    if args.sketch_factor is not None and args.backend != "lider":
        raise SystemExit("--sketch-factor needs --backend lider")
    if (
        args.sketch_factor is not None
        and args.storage_dtype not in ("int8", "int4")
        and not args.load_index
    ):
        raise SystemExit("--sketch-factor needs --storage-dtype int8/int4")
    if not 0.0 <= args.update_fraction < 1.0:
        raise SystemExit("--update-fraction must be in [0, 1)")
    if args.tenants < 1:
        raise SystemExit("--tenants must be >= 1")
    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.rolling_update and args.replicas < 2:
        raise SystemExit("--rolling-update needs --replicas >= 2")

    if args.embeddings:
        embs = synthetic.load_embeddings(args.embeddings)
    else:
        embs = synthetic.retrieval_corpus(0, args.corpus_size, args.dim)
    queries, _ = synthetic.retrieval_queries(1, embs, args.queries)

    n_held = int(embs.shape[0] * args.update_fraction)
    base_embs, held_embs = (embs[:-n_held], embs[-n_held:]) if n_held else (embs, None)

    t0 = time.time()
    index = None
    if args.backend == "lider":
        cfg = lider_lib.LiderConfig(
            n_clusters=args.n_clusters,
            n_probe=args.n_probe,
            refine=args.refine,
            use_fused=use_fused,
            storage_dtype=args.storage_dtype,
            rescore_factor=args.rescore_factor,
            block_c=args.block_c,
            rescore_tier=args.rescore_tier or "device",
        )
        if args.load_index:
            index = checkpoint.load_index(
                args.load_index, rescore_tier=args.rescore_tier
            )
        else:
            index, build_stats = lider_lib.build_lider(
                jax.random.PRNGKey(0), base_embs, cfg, return_stats=True
            )
            if build_stats.n_dropped:
                print(
                    f"[serve] WARNING: capacity overflow dropped "
                    f"{build_stats.n_dropped} passages at build"
                )
        # Config is the single source for the search-time knobs below
        # (same convention as n_probe/refine).
        use_fused = cfg.use_fused
    elif args.backend == "pq":
        index = build_pq(jax.random.PRNGKey(0), embs)
    elif args.backend == "ivfpq":
        index = build_ivfpq(jax.random.PRNGKey(0), embs)
    elif args.backend == "sklsh":
        index = build_sklsh(jax.random.PRNGKey(0), embs)
    elif args.backend == "mplsh":
        index = build_mplsh(jax.random.PRNGKey(0), embs)
    build_s = time.time() - t0
    built_how = "loaded" if args.load_index else "built"
    print(f"[serve] backend={args.backend} {built_how} in {build_s:.1f}s")
    tier_bytes = None
    if args.backend == "lider":
        tier_bytes = index.bank.nbytes_by_tier()
        print(
            f"[serve] index tiers: rescore_tier={index.bank.rescore_tier} "
            f"device={tier_bytes['device'] / 2**20:.1f} MiB "
            f"host={tier_bytes['host'] / 2**20:.1f} MiB"
        )

    # Operating point: explicit knobs, or autotuned for a recall target on a
    # held-out query set (DESIGN.md §Adaptive speed-quality control plane).
    n_probe, prune_margin = args.n_probe, args.prune_margin
    if args.recall_target is not None:
        from ..tuning import pareto as pareto_lib

        held_q, _ = synthetic.retrieval_queries(2, base_embs, 128)
        held_gt = flat_search(base_embs, held_q, k=args.k)
        # Sweep with the same rescore/block knobs the engine will serve —
        # otherwise an int8 bank would be validated at one quality setting
        # and served at another.
        grid = pareto_lib.default_grid(
            n_probes=tuple(
                p for p in (2, 4, 8, 16, 32) if p <= args.n_clusters
            ),
            refine=args.refine,
            rescore_factors=(args.rescore_factor,),
            block_cs=(args.block_c,),
            block_qs=(args.block_q,),
            sketch_factors=(args.sketch_factor,),
        )
        t0 = time.time()
        results = pareto_lib.sweep(
            index, held_q, held_gt.ids, grid, k=args.k, repeats=2,
            use_fused=use_fused,
        )
        sel = pareto_lib.select_operating_point(results, args.recall_target)
        n_probe, prune_margin = sel.point.n_probe, sel.point.prune_margin
        print(
            f"[serve] autotuned operating point for recall@{args.k}>="
            f"{args.recall_target}: {sel.point.label()} "
            f"(held-out recall={sel.recall:.4f}, aqt={sel.aqt_s * 1e6:.1f}us, "
            f"{time.time() - t0:.1f}s sweep)"
        )

    backend_kw = {
        "lider": dict(
            n_probe=n_probe, refine=args.refine, use_fused=use_fused,
            prune_margin=prune_margin, rescore_factor=args.rescore_factor,
            block_c=args.block_c, block_q=args.block_q,
            sketch_factor=args.sketch_factor,
        ),
        "ivfpq": dict(n_probe=args.n_probe),
        "mplsh": dict(n_probe=args.n_probe),
    }.get(args.backend, {})
    fault_plan = None
    if args.fault_plan:
        fault_plan = faults.FaultPlan.from_json(args.fault_plan)
        print(
            f"[serve] fault plan active: {len(fault_plan.specs)} spec(s), "
            f"seed={fault_plan.seed}"
        )
    policy = DegradePolicy(deadline_s=args.deadline_s)
    sched_cfg = SchedulerConfig(
        dynamic_batch=args.dynamic_batch,
        min_batch=max(1, args.batch_size // 8),
        cache_size=args.cache_size,
        slo_s=args.slo_ms / 1e3 if args.slo_ms is not None else None,
    )
    def build_one_engine(i: int) -> RetrievalEngine:
        if args.backend == "lider":
            search = make_backend("lider", None, updatable=True, **backend_kw)
            # Replica 0 serves the built params; further replicas get an
            # independent clone (in-place host-tier updates must not bleed
            # across replica generations).
            return RetrievalEngine(
                search, batch_size=args.batch_size, k=args.k,
                dim=embs.shape[1],
                params=index if i == 0 else clone_params(index),
                policy=policy, fault_plan=fault_plan, scheduler=sched_cfg,
            )
        search = make_backend(args.backend, index, embs, **backend_kw)
        return RetrievalEngine(
            search, batch_size=args.batch_size, k=args.k, dim=embs.shape[1],
            policy=policy, fault_plan=fault_plan, scheduler=sched_cfg,
        )

    engines = [build_one_engine(i) for i in range(args.replicas)]
    engine = engines[0]
    router = None
    if args.replicas > 1:
        hq = args.hedge_quantile
        router = QueryRouter(
            engines,
            config=RouterConfig(
                hedge_quantile=hq if 0.0 < hq < 1.0 else None,
                deadline_s=args.deadline_s,
            ),
            scheduler=sched_cfg,
            fault_plan=fault_plan,
        )
        print(
            f"[serve] router over {args.replicas} replicas "
            f"(hedge_quantile={hq if 0.0 < hq < 1.0 else None})"
        )
    server = router if router is not None else engine
    server.warmup()

    qs = jax.device_get(queries)
    tenant_of = lambda i: f"tenant{i % args.tenants}"
    got_rows = []  # (gt row index, answered ids) — shed requests excluded

    def apply_holdout_upsert() -> None:
        t0 = time.time()
        up_fn = lambda p: update_lib.upsert(p, held_embs)
        if args.rolling_update:
            # Zero-downtime roll: RouterControl drains and updates one
            # replica at a time behind the health mask; traffic keeps
            # being served by the rest of the fleet meanwhile.
            router.control.apply_updates(up_fn, block=True)
            dt = time.time() - t0
            lo, hi = router.generation_window()
            print(
                f"[serve] rolling upsert of {n_held} passages in {dt:.3f}s "
                f"({router.stats.n_roll_replicas_updated} replicas updated, "
                f"{router.stats.n_roll_replicas_skipped} skipped, "
                f"generation_window=[{lo}, {hi}], "
                f"wrong_generation={router.stats.n_wrong_generation})"
            )
            return
        grew = False
        for eng in engines:
            try:
                grew = eng.apply_updates(up_fn)
            except faults.InjectedFault as e:
                # Transactional apply_updates already rolled the host tier
                # back; keep serving the pre-update generation, then retry
                # the upsert once (the fault schedule has moved on).
                print(f"[serve] update failed ({e}); rolled back, retrying")
                grew = eng.apply_updates(up_fn)
        dt = time.time() - t0
        print(
            f"[serve] upserted {n_held} passages in {dt:.3f}s "
            f"({n_held / max(dt, 1e-9):.0f}/s), generation="
            f"{engine.generation}, capacity_grew={grew} "
            f"(recompiles={engine.recompiles}, "
            f"rollbacks={engine.stats.n_update_rollbacks})"
        )

    if args.arrival == "closed":
        # Submit/drain/collect in windows sized under the engine's results
        # bound: result() pops, and the results map is a bounded FIFO —
        # queueing a whole large --queries run before collecting would evict
        # the oldest answers mid-drain.
        window = min(4096, engine.max_results)

        def serve_chunk(chunk, base) -> None:
            for start in range(0, len(chunk), window):
                rids = [
                    server.submit(q, tenant=tenant_of(base + start + j))
                    for j, q in enumerate(chunk[start:start + window])
                ]
                while server.pending_requests:
                    server.drain()
                for j, r in enumerate(rids):
                    res = server.result(r)
                    if isinstance(res, QueryResult):
                        got_rows.append((base + start + j, res.ids))

        if held_embs is not None:
            # Mixed traffic: serve half, upsert the holdout, serve the rest.
            half = len(qs) // 2
            serve_chunk(qs[:half], 0)
            apply_holdout_upsert()
            serve_chunk(qs[half:], half)
        else:
            serve_chunk(qs, 0)
    else:
        # Open loop (DESIGN.md §Serving front end): seeded Zipf[+burst]
        # arrivals over the query set as a popularity pool, replayed in
        # real time against the engine; with --update-fraction the holdout
        # upsert lands between the two halves of the trace.
        rate = args.arrival_rate
        if rate is None:
            qw = jnp.zeros((args.batch_size, embs.shape[1]), jnp.float32)
            t0 = time.perf_counter()
            out, _ = engine._split_out(engine._search(qw))
            jax.block_until_ready((out.ids, out.scores))
            rate = 2.0 * args.batch_size / (time.perf_counter() - t0)
        trace = traffic.make_trace(
            seed=3, n_arrivals=len(qs), pool_size=len(qs), mean_rate=rate,
            pattern=args.arrival, n_tenants=args.tenants,
        )
        print(
            f"[serve] open loop: {len(trace)} {args.arrival} arrivals at "
            f"{rate:.0f} qps across {args.tenants} tenant(s)"
        )

        def replay(part) -> None:
            t_base = part[0].t if part else 0.0
            shifted = [
                dataclasses.replace(a, t=a.t - t_base) for a in part
            ]
            rids = traffic.run_open_loop(server, shifted, qs)
            for a, r in zip(shifted, rids):
                res = server.result(r)
                if isinstance(res, QueryResult):
                    got_rows.append((a.query_idx, res.ids))

        if held_embs is not None:
            half = len(trace) // 2
            replay(trace[:half])
            apply_holdout_upsert()
            replay(trace[half:])
        else:
            replay(trace)
    if router is not None:
        router.close()  # quiesce hedge losers before reading stats
    if len(engines) == 1:
        stats = engine.stats
    else:
        # Fleet-wide engine accounting: sum counters, merge the bounded
        # recent-window traces (router-level counters live on router.stats).
        stats = EngineStats()
        for eng in engines:
            for fld in dataclasses.fields(EngineStats):
                v = getattr(eng.stats, fld.name)
                cur = getattr(stats, fld.name)
                if hasattr(cur, "extend"):
                    cur.extend(v)
                else:
                    setattr(stats, fld.name, cur + v)
    pruned_note = ""
    if stats.n_probes_total:
        per_batch = ", ".join(
            f"{f:.0%}" for f in list(stats.batch_pruned_fraction)[:8]
        )
        pruned_note = (
            f", pruned probes {stats.pruned_probe_fraction:.1%} "
            f"(per batch: {per_batch}"
            + (", ..." if stats.n_batches > 8 else "")
            + ")"
        )
    host_note = ""
    if stats.n_host_fetches:
        host_note = (
            f", host fetch {stats.host_fetch_us / 1e3:.1f} ms total "
            f"over {stats.n_host_fetches} batches, overlap "
            f"{stats.overlap_fraction:.0%}"
        )
    print(
        f"[serve] {stats.n_queries} queries in "
        f"{stats.total_time_s:.3f}s -> AQT={stats.aqt*1e3:.3f} ms "
        f"(padding {stats.padding_fraction:.1%}{pruned_note}{host_note})"
    )
    if router is not None:
        rs = router.stats
        print(
            f"[serve] router: availability={rs.availability:.4f} "
            f"hedges={rs.n_hedges} (won {rs.n_hedge_wins}) "
            f"failovers={rs.n_failovers} kills={rs.n_replica_kills} "
            f"wrong_generation={rs.n_wrong_generation} shed={rs.n_shed}"
        )

    if args.save_index:
        path = checkpoint.save_index(args.save_index, engine.params)
        print(f"[serve] index saved -> {path}")

    gt = flat_search(embs, queries, k=args.k)
    got = jnp.stack([jnp.asarray(ids) for _, ids in got_rows])
    gt_rows = gt.ids[jnp.asarray([i for i, _ in got_rows])]
    rec = recall_at_k(got, gt_rows)
    print(
        f"[serve] recall@{args.k} vs Flat = {float(rec):.4f} "
        f"({len(got_rows)} answered)"
    )

    if args.stats_json:
        import json

        s = stats
        # Record what was actually served — a loaded checkpoint's dtype/tier,
        # not the CLI defaults (which the load path ignores).
        served_bank = getattr(engine.params, "bank", None)
        record = {
            "backend": args.backend,
            "storage_dtype": (
                served_bank.storage_dtype
                if served_bank is not None
                else args.storage_dtype
            ),
            "rescore_tier": (
                served_bank.rescore_tier if served_bank is not None else None
            ),
            "n_queries": s.n_queries,
            "n_batches": s.n_batches,
            "aqt_s": s.aqt,
            "padding_fraction": s.padding_fraction,
            "host_fetch_us": s.host_fetch_us,
            "n_host_fetches": s.n_host_fetches,
            "overlap_fraction": s.overlap_fraction,
            "generation": engine.generation,
            "device_generation": engine.device_generation,
            "host_generation": engine.host_generation,
            "recompiles": engine.recompiles,
            "recall_at_k": float(rec),
            "k": args.k,
            "block_q": args.block_q,
            "sketch_factor": args.sketch_factor,
            "tier_bytes": tier_bytes,
            # Fault-tolerance accounting (DESIGN.md §Failure model).
            "n_update_rollbacks": s.n_update_rollbacks,
            "n_fetch_retries": s.n_fetch_retries,
            "n_fetch_failures": s.n_fetch_failures,
            "n_degraded": s.n_degraded,
            "n_shed": s.n_shed + (
                router.stats.n_shed if router is not None else 0
            ),
            "n_deadline_misses": s.n_deadline_misses,
            "n_faults_fired": (
                fault_plan.n_fired if fault_plan is not None else 0
            ),
            # Per-site firing counts, zero-filled over every configured
            # site (canonical + plan-specific) — a site that never fired
            # reports 0, so chaos CI stats diffs are stable run-to-run.
            "fault_sites": (
                fault_plan.site_counts()
                if fault_plan is not None
                else {site: 0 for site in faults.SITES}
            ),
            # Front-end scheduler counters (DESIGN.md §Serving front end).
            "arrival": args.arrival,
            "tenants": args.tenants,
            "slo_ms": args.slo_ms,
            "cache_size": args.cache_size,
            "dynamic_batch": args.dynamic_batch,
            "n_cache_hits": s.n_cache_hits,
            "n_cache_misses": s.n_cache_misses,
            "cache_hit_rate": s.cache_hit_rate,
            "n_rung_steps": s.n_rung_steps,
            "batch_size_trace_tail": list(s.batch_size_trace)[-16:],
            "p50_latency_s": s.latency_quantile(0.5),
            "p99_latency_s": s.latency_quantile(0.99),
            # Replica fabric (DESIGN.md §Replica fabric).
            "replicas": args.replicas,
            "hedge_quantile": args.hedge_quantile,
            "rolling_update": args.rolling_update,
            "router": router.stats_dict() if router is not None else None,
        }
        with open(args.stats_json, "w") as f:
            json.dump(record, f, indent=1)
        print(f"[serve] stats -> {args.stats_json}")


if __name__ == "__main__":
    main()

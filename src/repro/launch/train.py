"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the local device(s) at a reduced scale (``--preset
smoke``) or the full config (on real hardware). Wires together: config
registry -> synthetic data pipeline -> train step -> checkpoint manager ->
restart harness. The dry-run (launch/dryrun.py) is the scale proof; this is
the runnable driver.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..data import pipeline as pipe_lib
from ..data import synthetic
from ..models import gnn as gnn_lib
from ..models import recsys as recsys_lib
from ..models import transformer as tfm
from ..training import checkpoint as ckpt_lib
from ..training import optimizer as opt_lib
from ..training import train_loop


def reduced_lm(cfg: tfm.LMConfig) -> tfm.LMConfig:
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, cfg.n_kv_heads * 4 // cfg.n_heads),
        d_head=32,
        d_ff=256,
        vocab=512,
        moe=dataclasses.replace(cfg.moe, n_experts=4, top_k=min(2, cfg.moe.top_k), d_ff_expert=64)
        if cfg.moe
        else None,
        dtype=jnp.float32,
    )


def reduced_recsys(cfg: recsys_lib.RecsysConfig) -> recsys_lib.RecsysConfig:
    return dataclasses.replace(
        cfg,
        item_vocab=2048,
        field_vocab=256,
        seq_len=min(cfg.seq_len, 20),
        tower_dims=(64, 32),
        cin_dims=(16, 16),
        dnn_dims=(32, 32),
        n_sparse=min(cfg.n_sparse, 13),
    )


def reduced_gnn(cfg: gnn_lib.GNNConfig) -> gnn_lib.GNNConfig:
    return dataclasses.replace(cfg, n_layers=3, d_hidden=32, d_feat=16, n_classes=5)


def build_task(arch_id: str, preset: str, batch: int, seq: int):
    """-> (params, loss_fn, batch_at). Smoke preset shrinks the config."""
    arch = get_arch(arch_id)
    rng = jax.random.PRNGKey(0)
    if arch.family == "lm":
        cfg = reduced_lm(arch.config) if preset == "smoke" else arch.config
        params = tfm.init(rng, cfg)
        loss_fn = lambda p, b: tfm.train_loss(p, cfg, b)
        batch_at = lambda s: synthetic.lm_batch(
            0, s, batch=batch, seq=seq, vocab=cfg.vocab
        )
        return params, loss_fn, batch_at
    if arch.family == "recsys":
        cfg = reduced_recsys(arch.config) if preset == "smoke" else arch.config
        params = recsys_lib.INIT[cfg.kind](rng, cfg)
        loss = recsys_lib.LOSS[cfg.kind]
        loss_fn = lambda p, b: loss(p, cfg, b)
        batch_at = lambda s: synthetic.recsys_batch(
            0, s, kind=cfg.kind, batch=batch, cfg=cfg
        )
        return params, loss_fn, batch_at
    if arch.family == "gnn":
        cfg = reduced_gnn(arch.config) if preset == "smoke" else arch.config
        params = gnn_lib.init(rng, cfg)
        graph = synthetic.random_graph(0, 512, 4096, cfg.d_feat, cfg.n_classes)
        loss_fn = lambda p, b: gnn_lib.train_loss(p, cfg, b)
        g = {k: graph[k] for k in ("node_feat", "edge_index", "labels")}
        batch_at = lambda s: g  # full-batch
        return params, loss_fn, batch_at
    raise ValueError(f"{arch_id}: family {arch.family} has no training driver")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    params, loss_fn, batch_at = build_task(args.arch, args.preset, args.batch, args.seq)
    opt_cfg = opt_lib.OptimizerConfig(
        peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1), decay_steps=args.steps
    )
    opt_state = opt_lib.init_state(params)
    step = train_loop.make_train_step(loss_fn, opt_cfg, grad_accum=args.grad_accum)
    mgr = ckpt_lib.CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    pipe = pipe_lib.DataPipeline(batch_at, prefetch=2)
    try:
        train_loop.run(
            step,
            params,
            opt_state,
            pipe,
            n_steps=args.steps,
            checkpoint_manager=mgr,
            checkpoint_every=args.ckpt_every,
        )
    finally:
        pipe.close()


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: ``jax.jit(step).lower(**abstract inputs).compile()`` on the
production mesh — success proves the sharding config is coherent (no
sharding mismatches, no OOM at compile, supported collectives). The compiled
artifact yields ``memory_analysis()`` (fits-per-device proof),
``cost_analysis()`` (FLOPs/bytes) and the optimized HLO text from which
per-device collective traffic is parsed — the three roofline inputs
(EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--mesh single|multi|both]
        [--arch ID] [--shape NAME] [--out experiments/dryrun.json]
"""
import argparse
import json
import re
import time
import traceback

import jax

from .. import compat
from ..configs import ARCHS, get_arch
from .mesh import make_production_mesh
from .steps import make_bundle

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# e.g.:  %ag = bf16[2,128,512]{2,1,0} all-gather(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" + "|".join(COLLECTIVES) + r")[\(-]"
)
# tuple-result collectives:  (bf16[...], bf16[...]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+(" + "|".join(COLLECTIVES) + r")[\(-]"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective byte counts by op kind, from optimized HLO."""
    out: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        shapes: list[tuple[str, str]] = []
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE_RE.findall(mt.group(1))
        if kind is None:
            continue
        b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        ent = out.setdefault(kind, {"count": 0, "bytes": 0})
        ent["count"] += 1
        ent["bytes"] += b
    return out


def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str) -> dict:
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": int(mesh.devices.size),
    }
    if shape_name in arch.skip_shapes:
        rec["status"] = "skipped"
        rec["reason"] = arch.notes
        return rec
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):
            bundle = make_bundle(arch, shape, mesh)
            jf = jax.jit(
                bundle.fn,
                in_shardings=bundle.in_shardings,
                out_shardings=bundle.out_shardings,
                donate_argnums=bundle.donate_argnums,
            )
            lowered = jf.lower(*bundle.args)
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 1)
            try:
                mem = compiled.memory_analysis()
                rec["memory"] = {
                    "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                    "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                    "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                    "generated_code_bytes": int(
                        getattr(mem, "generated_code_size_in_bytes", 0)
                    ),
                }
            except Exception as e:  # noqa: BLE001 — backend-dependent
                rec["memory"] = {"error": str(e)}
            try:
                cost = compiled.cost_analysis()
                if isinstance(cost, (list, tuple)):
                    cost = cost[0]
                rec["cost"] = {
                    "flops": float(cost.get("flops", -1)),
                    "bytes_accessed": float(cost.get("bytes accessed", -1)),
                }
            except Exception as e:  # noqa: BLE001
                rec["cost"] = {"error": str(e)}
            rec["collectives"] = collective_stats(compiled.as_text())
            rec["model_flops"] = bundle.model_flops
            if bundle.tier_memory is not None:
                # Retrieval cells: index bytes by storage tier (device HBM
                # vs host RAM) per storage config, so memory_analysis above
                # is read against the true device-resident footprint of an
                # int8+host index (DESIGN.md §Tiered embedding store). The
                # bundle asserts int8+host device bytes < int8-device (and
                # < f32) before this record is written.
                rec["tier_memory"] = bundle.tier_memory
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def iter_cells(arch_filter=None, shape_filter=None):
    for arch_id, arch in ARCHS.items():
        if arch_filter and arch_id != arch_filter:
            continue
        for shape in arch.shapes:
            if shape_filter and shape.name != shape_filter:
                continue
            yield arch_id, shape.name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            prior = json.load(f)
        # keep ok/skipped records; failed cells re-run after fixes
        results = [r for r in prior if r["status"] in ("ok", "skipped")]
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for mesh_name, mesh in meshes:
        for arch_id, shape_name in iter_cells(args.arch, args.shape):
            if (arch_id, shape_name, mesh_name) in done:
                continue
            print(f"[dryrun] {arch_id} x {shape_name} x {mesh_name} ...", flush=True)
            rec = run_cell(arch_id, shape_name, mesh, mesh_name)
            status = rec["status"]
            extra = (
                f" compile={rec.get('compile_s')}s"
                if status == "ok"
                else f" ({rec.get('error', rec.get('reason', ''))[:120]})"
            )
            print(f"[dryrun]   -> {status}{extra}", flush=True)
            if status == "ok":
                print(
                    f"[dryrun]   mem(temp)={rec['memory'].get('temp_bytes', 0)/2**30:.2f}GiB/dev "
                    f"flops={rec['cost'].get('flops', -1):.3g} "
                    f"coll={ {k: round(v['bytes']/2**20, 1) for k, v in rec['collectives'].items()} }MiB",
                    flush=True,
                )
            results.append(rec)
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed -> {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

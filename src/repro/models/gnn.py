"""GatedGCN (Bresson & Laurent 2017; benchmarking-GNNs arXiv:2003.00982).

Message passing is ``jax.ops.segment_sum`` over an explicit edge list — the
JAX-native SpMM formulation (no CSR kernels; see kernel_taxonomy §GNN). Node
states are replicated, edge lists shard over the data axes: each shard
scatter-adds its partial aggregate and SPMD inserts the psum.

Norm note: the reference uses BatchNorm; we use batch statistics computed on
the fly (train == eval semantics, no running stats) — equivalent at full
batch, documented adaptation for sampled batches.

Includes the real 2-hop neighbour sampler for the ``minibatch_lg`` shape
(GraphSAGE fanout sampling over CSR, static shapes, jit-able).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .sharding import ALL, DP, TP, maybe_shard

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 16
    d_hidden: int = 70
    d_feat: int = 1433
    d_edge: int = 0  # 0 -> constant edge features
    n_classes: int = 7
    readout: str = "node"  # "node" (classification) | "graph" (regression)
    dtype: Any = jnp.float32


def _dense(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / (shape[0] ** 0.5)).astype(
        dtype
    )


def init(rng: jax.Array, cfg: GNNConfig) -> Params:
    h = cfg.d_hidden
    k_in, k_e, k_out, k_layers = jax.random.split(rng, 4)

    def layer_init(key):
        ks = jax.random.split(key, 5)
        return {
            "A": _dense(ks[0], (h, h), cfg.dtype),  # edge: src term
            "B": _dense(ks[1], (h, h), cfg.dtype),  # edge: dst term
            "C": _dense(ks[2], (h, h), cfg.dtype),  # edge: edge term
            "U": _dense(ks[3], (h, h), cfg.dtype),  # node: self term
            "V": _dense(ks[4], (h, h), cfg.dtype),  # node: neighbour term
            "bn_h": jnp.ones((h,), cfg.dtype),
            "bn_e": jnp.ones((h,), cfg.dtype),
        }
    stacked = jax.vmap(layer_init)(jax.random.split(k_layers, cfg.n_layers))
    return {
        "w_in": _dense(k_in, (cfg.d_feat, h), cfg.dtype),
        "w_edge": _dense(k_e, (max(cfg.d_edge, 1), h), cfg.dtype),
        "w_out": _dense(k_out, (h, cfg.n_classes), cfg.dtype),
        "layers": stacked,
    }


def _batch_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    mu = jnp.mean(x, axis=0, keepdims=True)
    var = jnp.var(x, axis=0, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale


def forward(params: Params, cfg: GNNConfig, graph: dict) -> jnp.ndarray:
    """graph: node_feat (N, F), edge_index (2, E) int32, optional edge_feat
    (E, Fe), optional graph_ids (N,) for batched small graphs.

    Returns node logits (N, C) or graph outputs (G, C).
    """
    n = graph["node_feat"].shape[0]
    src, dst = graph["edge_index"]
    h = graph["node_feat"].astype(cfg.dtype) @ params["w_in"]
    if cfg.d_edge and "edge_feat" in graph:
        e = graph["edge_feat"].astype(cfg.dtype) @ params["w_edge"]
    else:
        e = jnp.zeros((src.shape[0], cfg.d_hidden), cfg.dtype) + params["w_edge"][0]

    # Optional mask for padded edges (inputs are padded to shard evenly).
    edge_mask = graph.get("edge_mask")

    def body(carry, lp):
        h, e = carry
        h_src = h[src]
        h_dst = h[dst]
        e_new = e + jax.nn.relu(
            _batch_norm(h_src @ lp["A"] + h_dst @ lp["B"] + e @ lp["C"], lp["bn_e"])
        )
        eta = jax.nn.sigmoid(e_new)
        if edge_mask is not None:
            eta = eta * edge_mask[:, None]
        msg = eta * (h_src @ lp["V"])
        num = jax.ops.segment_sum(msg, dst, num_segments=n)
        den = jax.ops.segment_sum(eta, dst, num_segments=n)
        agg = num / (den + 1e-6)
        h_new = h + jax.nn.relu(_batch_norm(h @ lp["U"] + agg, lp["bn_h"]))
        # Node states shard over 'model', edge states over every axis — the
        # per-layer scan carries stay small (DESIGN.md: GNN on the 2D mesh).
        h_new = maybe_shard(h_new, TP, None)
        e_new = maybe_shard(e_new, ALL, None)
        return (h_new, e_new), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, _), _ = jax.lax.scan(body, (h, e), params["layers"])
    out = h @ params["w_out"]
    if cfg.readout == "graph":
        gids = graph["graph_ids"]
        g = int(graph["n_graphs"])
        pooled = jax.ops.segment_sum(out, gids, num_segments=g)
        counts = jax.ops.segment_sum(jnp.ones((n, 1), cfg.dtype), gids, num_segments=g)
        return pooled / jnp.maximum(counts, 1.0)
    return out


def train_loss(params: Params, cfg: GNNConfig, graph: dict) -> jnp.ndarray:
    out = forward(params, cfg, graph)
    if cfg.readout == "graph":
        return jnp.mean((out[:, 0] - graph["graph_targets"]) ** 2)  # ZINC-style MAE->MSE
    labels = graph["labels"]
    mask = graph.get("label_mask")
    logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Neighbour sampler (minibatch_lg shape): 2-hop fanout sampling over CSR.
# ---------------------------------------------------------------------------


def neighbor_sample(
    rng: jax.Array,
    indptr: jnp.ndarray,  # (N+1,)
    indices: jnp.ndarray,  # (E,)
    node_feat: jnp.ndarray,  # (N, F)
    labels: jnp.ndarray,  # (N,)
    seeds: jnp.ndarray,  # (B,)
    fanouts: tuple[int, ...],
) -> dict:
    """GraphSAGE-style sampled block with static shapes.

    Sampled-with-replacement via random offsets mod degree; zero-degree
    frontier nodes self-loop. Block node order: [seeds, hop-1, hop-2, ...];
    edges point sampled-neighbour -> parent. Works inside jit (static B,
    fanouts).
    """
    frontier = seeds
    all_nodes = [seeds]
    srcs, dsts = [], []
    offset = seeds.shape[0]
    parent_base = 0
    for hop, f in enumerate(fanouts):
        rng, sub = jax.random.split(rng)
        deg = indptr[frontier + 1] - indptr[frontier]
        draw = jax.random.randint(
            sub, (frontier.shape[0], f), 0, 1 << 30, dtype=jnp.int32
        )
        off = draw % jnp.maximum(deg, 1)[:, None]
        neigh = indices[indptr[frontier][:, None] + off]  # (|F|, f)
        neigh = jnp.where(deg[:, None] > 0, neigh, frontier[:, None])  # self-loop
        n_new = frontier.shape[0] * f
        src = offset + jnp.arange(n_new, dtype=jnp.int32)  # block-local ids
        dst = parent_base + jnp.repeat(
            jnp.arange(frontier.shape[0], dtype=jnp.int32), f
        )
        srcs.append(src)
        dsts.append(dst)
        all_nodes.append(neigh.reshape(-1))
        parent_base = offset
        offset += n_new
        frontier = neigh.reshape(-1)

    block_nodes = jnp.concatenate(all_nodes)  # global ids, (Nb,)
    return {
        "node_feat": node_feat[block_nodes],
        "edge_index": jnp.stack([jnp.concatenate(srcs), jnp.concatenate(dsts)]),
        "labels": labels[block_nodes],
        "label_mask": (
            jnp.arange(block_nodes.shape[0]) < seeds.shape[0]
        ).astype(jnp.float32),
        "block_nodes": block_nodes,
    }

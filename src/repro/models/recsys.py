"""RecSys model zoo: SASRec, two-tower retrieval, DIN, xDeepFM.

The shared substrate is the **sharded embedding layer**: JAX has no
EmbeddingBag, so lookups are ``jnp.take`` + ``jax.ops.segment_sum`` and the
huge tables are row(vocab)-sharded over the ``model`` mesh axis. Under a mesh
the lookup runs as an explicit shard_map (local masked take + psum) — the
classic model-parallel embedding — so the table is never all-gathered; on a
single device it degrades to a plain take.

The two-tower ``retrieval_cand`` path is the paper's own workload (score one
query against ~1e6 candidates): it is served either brute-force (one matmul)
or through a LIDER index over the item-tower embeddings (``--index lider``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat
from . import layers
from .sharding import ALL, DP, TP, maybe_shard

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Sharded embedding substrate
# ---------------------------------------------------------------------------


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Row-sharded embedding lookup.

    Under an ambient mesh with a ``model`` axis: shard_map over the vocab
    rows — each shard takes its local rows (masked) and the partials are
    psum'd. Otherwise a plain take. Differentiable (scatter-add transpose).
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return table[ids]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if ids.shape[0] % max(dp_size, 1):
        dp = ()  # batch-1 / ragged leading dim: replicate the ids instead

    def local_lookup(tab, idx):
        shard = jax.lax.axis_index("model")
        rows = tab.shape[0]  # local rows
        local = idx - rows * shard
        inside = (local >= 0) & (local < rows)
        got = tab[jnp.clip(local, 0, rows - 1)]
        got = jnp.where(inside[..., None], got, 0.0)
        return jax.lax.psum(got, "model")

    id_spec = P(dp if dp else None, *([None] * (ids.ndim - 1)))
    out_spec = P(dp if dp else None, *([None] * ids.ndim))
    return compat.shard_map(
        local_lookup,
        mesh=mesh,
        in_specs=(P("model", None), id_spec),
        out_specs=out_spec,
    )(table, ids)


def embedding_bag(
    table: jnp.ndarray, ids: jnp.ndarray, segment_ids: jnp.ndarray, n_bags: int
) -> jnp.ndarray:
    """EmbeddingBag(sum): multi-hot ids reduced per bag (JAX-native)."""
    rows = embedding_lookup(table, ids)
    return jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)


def _dense(key, shape, dtype=jnp.float32, scale=None):
    scale = scale or (1.0 / (shape[0] ** 0.5))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _mlp_init(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": _dense(ks[i], (dims[i], dims[i + 1]), dtype)
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)
    }


def _mlp_apply(p, x, n, act=jax.nn.relu, final_act=False):
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str  # sasrec | two_tower | din | xdeepfm
    embed_dim: int
    item_vocab: int = 1_048_576
    seq_len: int = 50
    # two-tower
    n_user_fields: int = 4
    n_item_fields: int = 2
    field_vocab: int = 131_072
    tower_dims: tuple[int, ...] = (1024, 512, 256)
    # din
    attn_dims: tuple[int, ...] = (80, 40)
    mlp_dims: tuple[int, ...] = (200, 80)
    # xdeepfm
    n_sparse: int = 39
    cin_dims: tuple[int, ...] = (200, 200, 200)
    dnn_dims: tuple[int, ...] = (400, 400)
    # sasrec
    n_blocks: int = 2
    n_heads: int = 1
    dtype: Any = jnp.float32


# ---------------------------------------------------------------------------
# SASRec (Kang & McAuley 2018)
# ---------------------------------------------------------------------------


def sasrec_init(rng: jax.Array, cfg: RecsysConfig) -> Params:
    d = cfg.embed_dim
    ks = jax.random.split(rng, 3 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[3 + i], 6)
        blocks.append(
            {
                "wq": _dense(kb[0], (d, d)),
                "wk": _dense(kb[1], (d, d)),
                "wv": _dense(kb[2], (d, d)),
                "wo": _dense(kb[3], (d, d)),
                "w1": _dense(kb[4], (d, d)),
                "w2": _dense(kb[5], (d, d)),
                "ln1": jnp.ones((d,)),
                "ln2": jnp.ones((d,)),
            }
        )
    return {
        "item_emb": _dense(ks[0], (cfg.item_vocab, d), scale=0.02),
        "pos_emb": _dense(ks[1], (cfg.seq_len, d), scale=0.02),
        "ln_f": jnp.ones((d,)),
        "blocks": blocks,
    }


def sasrec_forward(params: Params, cfg: RecsysConfig, seq: jnp.ndarray) -> jnp.ndarray:
    """seq (B, S) item ids (0 = padding) -> hidden states (B, S, d)."""
    b, s = seq.shape
    d = cfg.embed_dim
    h = embedding_lookup(params["item_emb"], seq) + params["pos_emb"][None, :s]
    h = maybe_shard(h, DP, None, None)
    nh = cfg.n_heads
    for blk in params["blocks"]:
        x = layers.rms_norm(h, blk["ln1"])
        q = (x @ blk["wq"]).reshape(b, s, nh, d // nh)
        k = (x @ blk["wk"]).reshape(b, s, nh, d // nh)
        v = (x @ blk["wv"]).reshape(b, s, nh, d // nh)
        o = layers.flash_attention(q, k, v, causal=True, q_chunk=s, kv_chunk=s)
        h = h + o.reshape(b, s, d) @ blk["wo"]
        x = layers.rms_norm(h, blk["ln2"])
        h = h + jax.nn.relu(x @ blk["w1"]) @ blk["w2"]
    return layers.rms_norm(h, params["ln_f"])


def sasrec_loss(params: Params, cfg: RecsysConfig, batch: dict) -> jnp.ndarray:
    """BCE with one positive (next item) and one sampled negative per step."""
    h = sasrec_forward(params, cfg, batch["seq"])  # (B, S, d)
    pos = embedding_lookup(params["item_emb"], batch["pos"])  # (B, S, d)
    neg = embedding_lookup(params["item_emb"], batch["neg"])
    pos_s = jnp.sum(h * pos, -1)
    neg_s = jnp.sum(h * neg, -1)
    mask = (batch["pos"] > 0).astype(jnp.float32)
    loss = -jax.nn.log_sigmoid(pos_s) - jax.nn.log_sigmoid(-neg_s)
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Two-tower retrieval (Yi et al., RecSys'19)
# ---------------------------------------------------------------------------


def two_tower_init(rng: jax.Array, cfg: RecsysConfig) -> Params:
    d = cfg.embed_dim
    ks = jax.random.split(rng, 4)
    user_in = cfg.n_user_fields * d
    item_in = cfg.n_item_fields * d
    return {
        "user_emb": _dense(ks[0], (cfg.field_vocab * cfg.n_user_fields, d), scale=0.02),
        "item_emb": _dense(ks[1], (cfg.item_vocab, d), scale=0.02),
        "user_tower": _mlp_init(ks[2], (user_in,) + cfg.tower_dims),
        "item_tower": _mlp_init(ks[3], (item_in,) + cfg.tower_dims),
    }


def user_embed(params: Params, cfg: RecsysConfig, user_fields: jnp.ndarray):
    """user_fields (B, n_user_fields) int32 -> (B, d_out) normalised."""
    b, f = user_fields.shape
    offset = jnp.arange(f, dtype=user_fields.dtype) * cfg.field_vocab
    rows = embedding_lookup(params["user_emb"], user_fields + offset)  # (B,F,d)
    x = rows.reshape(b, -1)
    x = _mlp_apply(params["user_tower"], x, len(cfg.tower_dims))
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def item_embed(params: Params, cfg: RecsysConfig, item_fields: jnp.ndarray):
    """item_fields (B, n_item_fields): column 0 = item id, rest categorical."""
    b, f = item_fields.shape
    rows0 = embedding_lookup(params["item_emb"], item_fields[:, 0])
    rest = embedding_lookup(
        params["user_emb"],
        item_fields[:, 1:] + jnp.arange(1, f, dtype=item_fields.dtype) * cfg.field_vocab,
    ).reshape(b, -1)
    x = jnp.concatenate([rows0, rest], axis=-1)
    x = _mlp_apply(params["item_tower"], x, len(cfg.tower_dims))
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def two_tower_loss(params: Params, cfg: RecsysConfig, batch: dict) -> jnp.ndarray:
    """In-batch sampled softmax with logQ correction."""
    u = user_embed(params, cfg, batch["user_fields"])  # (B, dout)
    i = item_embed(params, cfg, batch["item_fields"])  # (B, dout)
    logits = (u @ i.T) / 0.05  # temperature
    logq = batch.get("sampling_logq")
    if logq is not None:
        logits = logits - logq[None, :]
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def two_tower_score_candidates(
    params: Params, cfg: RecsysConfig, user_fields: jnp.ndarray, cand_embs: jnp.ndarray, k: int
):
    """retrieval_cand: (B, F) users x (N_cand, dout) precomputed item
    embeddings -> top-k. This is the LIDER-served workload; the brute-force
    path here is the Flat baseline."""
    u = user_embed(params, cfg, user_fields)
    scores = u @ cand_embs.T  # (B, N_cand)
    return jax.lax.top_k(scores, k)


# ---------------------------------------------------------------------------
# DIN (Zhou et al. 2018)
# ---------------------------------------------------------------------------


def din_init(rng: jax.Array, cfg: RecsysConfig) -> Params:
    d = cfg.embed_dim
    ks = jax.random.split(rng, 4)
    return {
        "item_emb": _dense(ks[0], (cfg.item_vocab, d), scale=0.02),
        "attn": _mlp_init(ks[1], (4 * d,) + cfg.attn_dims + (1,)),
        "mlp": _mlp_init(ks[2], (3 * d,) + cfg.mlp_dims + (1,)),
    }


def din_forward(params: Params, cfg: RecsysConfig, batch: dict) -> jnp.ndarray:
    """history (B, S), target (B,) -> CTR logits (B,)."""
    hist = embedding_lookup(params["item_emb"], batch["history"])  # (B, S, d)
    tgt = embedding_lookup(params["item_emb"], batch["target"])  # (B, d)
    t = jnp.broadcast_to(tgt[:, None, :], hist.shape)
    a_in = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    w = _mlp_apply(params["attn"], a_in, len(cfg.attn_dims) + 1)[..., 0]  # (B, S)
    mask = (batch["history"] > 0).astype(w.dtype)
    w = w * mask  # DIN: no softmax, preserve intensity
    pooled = jnp.einsum("bs,bsd->bd", w, hist) / jnp.maximum(
        jnp.sum(mask, -1, keepdims=True), 1.0
    )
    x = jnp.concatenate([pooled, tgt, pooled * tgt], axis=-1)
    return _mlp_apply(params["mlp"], x, len(cfg.mlp_dims) + 1)[..., 0]


def din_loss(params: Params, cfg: RecsysConfig, batch: dict) -> jnp.ndarray:
    logits = din_forward(params, cfg, batch)
    y = batch["label"].astype(jnp.float32)
    return -jnp.mean(
        y * jax.nn.log_sigmoid(logits) + (1 - y) * jax.nn.log_sigmoid(-logits)
    )


# ---------------------------------------------------------------------------
# xDeepFM (Lian et al. 2018)
# ---------------------------------------------------------------------------


def xdeepfm_init(rng: jax.Array, cfg: RecsysConfig) -> Params:
    d, m = cfg.embed_dim, cfg.n_sparse
    ks = jax.random.split(rng, 6)
    cin = []
    h_prev = m
    for i, h in enumerate(cfg.cin_dims):
        cin.append(_dense(jax.random.fold_in(ks[2], i), (h_prev * m, h)))
        h_prev = h
    return {
        "emb": _dense(ks[0], (cfg.field_vocab * m, d), scale=0.02),
        "linear": _dense(ks[1], (cfg.field_vocab * m, 1), scale=0.01),
        "cin": cin,
        "cin_out": _dense(ks[3], (sum(cfg.cin_dims), 1)),
        "dnn": _mlp_init(ks[4], (m * d,) + cfg.dnn_dims + (1,)),
    }


def xdeepfm_forward(params: Params, cfg: RecsysConfig, batch: dict) -> jnp.ndarray:
    """fields (B, n_sparse) int32 per-field ids -> CTR logits (B,)."""
    fields = batch["fields"]
    b, m = fields.shape
    offset = jnp.arange(m, dtype=fields.dtype) * cfg.field_vocab
    flat_ids = fields + offset
    x0 = embedding_lookup(params["emb"], flat_ids)  # (B, m, d)
    # Re-shard the batch over every axis after the (model-sharded) lookup:
    # the CIN outer-product tensor (B, H_k*m, d) is the footprint driver for
    # huge offline/retrieval batches.
    x0 = maybe_shard(x0, ALL, None, None)
    linear = jnp.sum(embedding_lookup(params["linear"], flat_ids), axis=(1, 2))

    # CIN: x^{k+1}_h = sum_{i,j} W^k_{h,ij} (x^k_i * x^0_j)
    xk = x0
    pools = []
    for w in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)  # (B, Hk, m, d)
        z = z.reshape(b, -1, cfg.embed_dim)  # (B, Hk*m, d)
        xk = jnp.einsum("bzd,zh->bhd", z, w)  # (B, Hk+1, d)
        pools.append(jnp.sum(xk, axis=-1))  # (B, Hk+1)
    cin_logit = (jnp.concatenate(pools, axis=-1) @ params["cin_out"])[:, 0]

    dnn_logit = _mlp_apply(params["dnn"], x0.reshape(b, -1), len(cfg.dnn_dims) + 1)[
        :, 0
    ]
    return linear + cin_logit + dnn_logit


def xdeepfm_loss(params: Params, cfg: RecsysConfig, batch: dict) -> jnp.ndarray:
    logits = xdeepfm_forward(params, cfg, batch)
    y = batch["label"].astype(jnp.float32)
    return -jnp.mean(
        y * jax.nn.log_sigmoid(logits) + (1 - y) * jax.nn.log_sigmoid(-logits)
    )


# ---------------------------------------------------------------------------
# Shared entry points
# ---------------------------------------------------------------------------

INIT = {
    "sasrec": sasrec_init,
    "two_tower": two_tower_init,
    "din": din_init,
    "xdeepfm": xdeepfm_init,
}

LOSS = {
    "sasrec": sasrec_loss,
    "two_tower": two_tower_loss,
    "din": din_loss,
    "xdeepfm": xdeepfm_loss,
}


def param_specs(params: Params) -> Params:
    """Vocab-sharded tables over 'model'; everything else replicated."""
    def spec_for(path, leaf):
        names = [getattr(p, "name", getattr(p, "key", None)) for p in path]
        if any(n in ("item_emb", "user_emb", "emb", "linear") for n in names):
            return P("model", *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, params)

"""Mesh-aware sharding helpers usable from model code.

Model code calls ``maybe_shard(x, "dp", None, ...)`` with *logical* axis
names; under an ambient mesh (``jax.sharding.use_mesh``) they resolve to the
physical axes present — ``"dp"`` -> ("pod", "data") (whichever exist),
``"tp"`` -> ("model",). Outside a mesh the call is a no-op, so the same model
runs on a laptop and on the production mesh unchanged.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from .. import compat

DP = "dp"  # logical data-parallel axis -> ("pod", "data")
TP = "tp"  # logical tensor/expert-parallel axis -> ("model",)
ALL = "all"  # every mesh axis (edge-parallel GNN aggregation)

_LOGICAL = {
    DP: ("pod", "data"),
    TP: ("model",),
    ALL: ("pod", "data", "model"),
}


def physical_axes(logical: str, mesh_axis_names) -> tuple[str, ...]:
    return tuple(a for a in _LOGICAL[logical] if a in mesh_axis_names)


def resolve_spec(spec_entries, mesh_axis_names) -> P:
    out = []
    for e in spec_entries:
        if e is None:
            out.append(None)
        elif e in _LOGICAL:
            phys = physical_axes(e, mesh_axis_names)
            out.append(phys if phys else None)
        else:
            out.append(e if e in mesh_axis_names else None)
    return P(*out)


def maybe_shard(x: jax.Array, *spec_entries) -> jax.Array:
    """with_sharding_constraint under an ambient mesh; identity otherwise."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, resolve_spec(spec_entries, mesh.axis_names)
    )

"""Decoder-only transformer LM: dense or MoE, GQA, RoPE, optional
local/global interleaved attention (llama4-scout iRoPE style).

Pure-function design: ``init`` builds a nested param dict (layers stacked on
a leading axis for ``lax.scan``), ``forward`` returns final hidden states,
``lm_loss`` computes sequence-chunked softmax cross-entropy (logits never
materialise beyond a (B, chunk, V) tile), ``prefill``/``decode_step`` serve
with a KV cache. ``param_specs`` gives the Megatron-style TP layout used by
the dry-run and launcher.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers
from .sharding import DP, TP, maybe_shard

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared (always-on) experts, llama4 style
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    qkv_bias: bool = False
    moe: MoEConfig | None = None
    # every `local_ratio`-th layer is global, the rest use `window` (llama4);
    # window=None -> all layers full attention.
    window: int | None = None
    local_ratio: int = 4
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    loss_chunk: int = 128
    # Sequence parallelism for activations: residual-stream carries shard
    # (batch x seq) over (dp x model) instead of batch-only — cuts per-layer
    # activation memory TP-fold, at the cost of a per-layer seq all-gather
    # before attention (§Perf iteration C2).
    seq_shard_activations: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def flops_params(self) -> int:
        """Parameter count N for the 6*N*D model-FLOPs estimate (active
        params for MoE)."""
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
        if self.moe:
            ff = 3 * d * self.moe.d_ff_expert * (self.moe.top_k + self.moe.n_shared)
        else:
            ff = 3 * d * self.d_ff
        return self.n_layers * (attn + ff) + 2 * self.vocab * d


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _dense(key, shape, dtype, scale=None):
    scale = scale or (1.0 / (shape[0] ** 0.5))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init(rng: jax.Array, cfg: LMConfig) -> Params:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    pd = cfg.param_dtype
    k_emb, k_head, k_layers = jax.random.split(rng, 3)

    def layer_init(key):
        ks = jax.random.split(key, 12)
        p: Params = {
            "ln_attn": jnp.ones((d,), pd),
            "ln_mlp": jnp.ones((d,), pd),
            "wq": _dense(ks[0], (d, hq * dh), pd),
            "wk": _dense(ks[1], (d, hkv * dh), pd),
            "wv": _dense(ks[2], (d, hkv * dh), pd),
            "wo": _dense(ks[3], (hq * dh, d), pd),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((hq * dh,), pd)
            p["bk"] = jnp.zeros((hkv * dh,), pd)
            p["bv"] = jnp.zeros((hkv * dh,), pd)
        if cfg.moe:
            e, ffe = cfg.moe.n_experts, cfg.moe.d_ff_expert
            p["moe"] = {
                "router": _dense(ks[4], (d, e), jnp.float32),
                "w_gate": _dense(ks[5], (e, d, ffe), pd),
                "w_up": _dense(ks[6], (e, d, ffe), pd),
                "w_down": _dense(ks[7], (e, ffe, d), pd),
            }
            if cfg.moe.n_shared:
                ffs = cfg.moe.d_ff_expert * cfg.moe.n_shared
                p["shared"] = {
                    "w_gate": _dense(ks[8], (d, ffs), pd),
                    "w_up": _dense(ks[9], (d, ffs), pd),
                    "w_down": _dense(ks[10], (ffs, d), pd),
                }
        else:
            p["mlp"] = {
                "w_gate": _dense(ks[5], (d, cfg.d_ff), pd),
                "w_up": _dense(ks[6], (d, cfg.d_ff), pd),
                "w_down": _dense(ks[7], (cfg.d_ff, d), pd),
            }
        return p

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(layer_init)(layer_keys)
    return {
        "embed": _dense(k_emb, (cfg.vocab, d), pd, scale=0.02),
        "lm_head": _dense(k_head, (d, cfg.vocab), pd),
        "ln_final": jnp.ones((d,), pd),
        "layers": stacked,
    }


def layer_windows(cfg: LMConfig, seq_len: int) -> jnp.ndarray:
    """Per-layer attention window (traced through the layer scan). Full
    attention = seq_len (mask never fires)."""
    if cfg.window is None:
        return jnp.full((cfg.n_layers,), jnp.int32(2**30))
    idx = jnp.arange(cfg.n_layers)
    is_global = (idx % cfg.local_ratio) == (cfg.local_ratio - 1)
    return jnp.where(is_global, jnp.int32(2**30), jnp.int32(cfg.window))


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _attn_block(lp: Params, cfg: LMConfig, x: jnp.ndarray, positions, window):
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = layers.rms_norm(x, lp["ln_attn"])
    q = jnp.einsum("bsd,dh->bsh", h, lp["wq"])
    k = jnp.einsum("bsd,dh->bsh", h, lp["wk"])
    v = jnp.einsum("bsd,dh->bsh", h, lp["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = layers.rope(q.reshape(b, s, hq, dh), positions, theta=cfg.rope_theta)
    k = layers.rope(k.reshape(b, s, hkv, dh), positions, theta=cfg.rope_theta)
    v = v.reshape(b, s, hkv, dh)
    o = layers.flash_attention(q, k, v, causal=True, window=window)
    o = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, hq * dh), lp["wo"])
    return o, (k, v)


MOE_SEQ_CHUNK = 8192  # cap MoE dispatch-buffer length for long prefills


def _mlp_block(lp: Params, cfg: LMConfig, x: jnp.ndarray):
    h = layers.rms_norm(x, lp["ln_mlp"])
    aux = jnp.float32(0.0)
    if cfg.moe:
        b, s, d = h.shape
        moe = lambda hx: layers.moe_mlp(
            lp["moe"],
            hx,
            top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
        )
        if s > MOE_SEQ_CHUNK and s % MOE_SEQ_CHUNK == 0:
            # Dispatch sequence chunks *sequentially* (lax.map): only one
            # chunk's expert buffers are live — the 32k-prefill memory knob.
            nc = s // MOE_SEQ_CHUNK
            hc = jnp.moveaxis(
                h.reshape(b, nc, MOE_SEQ_CHUNK, d), 1, 0
            )  # (nc, B, chunk, d)
            out, aux = jax.lax.map(moe, hc)
            out = jnp.moveaxis(out, 0, 1).reshape(b, s, d)
            aux = jnp.sum(aux)
        else:
            out, aux = moe(h)
        if cfg.moe.n_shared:
            out = out + layers.swiglu_mlp(lp["shared"], h)
    else:
        out = layers.swiglu_mlp(lp["mlp"], h)
    return out, aux


def forward(
    params: Params, cfg: LMConfig, tokens: jnp.ndarray, *, collect_cache: bool = False
):
    """tokens (B, S) -> hidden (B, S, d); optionally also per-layer (k, v)."""
    b, s = tokens.shape
    act_spec = (DP, TP, None) if cfg.seq_shard_activations else (DP, None, None)
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = maybe_shard(x, *act_spec)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    windows = layer_windows(cfg, s)

    def body(x, inp):
        lp, win = inp
        lp = layers.cast_floats(lp, cfg.dtype)
        attn_out, kv = _attn_block(lp, cfg, x, positions, win)
        x = maybe_shard(x + attn_out, *act_spec)
        mlp_out, aux = _mlp_block(lp, cfg, x)
        x = maybe_shard(x + mlp_out, *act_spec)
        if collect_cache:
            # Pin the per-layer cache slice layout inside the scan (batch
            # over data, sequence over model) — without this the stacked
            # (L, B, S, Hkv, Dh) cache replicates over 'model' (GQA heads
            # can't shard 16-way) and blows the prefill memory budget.
            kv = tuple(maybe_shard(t, DP, TP, None, None) for t in kv)
            ys = (kv, aux)
        else:
            ys = aux
        return x, ys

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, ys = jax.lax.scan(body, x, (params["layers"], windows))
    x = layers.rms_norm(x, params["ln_final"])
    if collect_cache:
        (ks, vs), aux = ys
        return x, (ks, vs), jnp.sum(aux)
    return x, jnp.sum(ys)


def lm_loss(
    params: Params, cfg: LMConfig, hidden: jnp.ndarray, targets: jnp.ndarray
) -> jnp.ndarray:
    """Sequence-chunked softmax cross-entropy (B, chunk, V) tiles only."""
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk, s)
    assert s % chunk == 0
    hs = jnp.moveaxis(hidden.reshape(b, s // chunk, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, s // chunk, chunk), 1, 0)
    head = params["lm_head"].astype(cfg.dtype)

    def body(acc, inp):
        h, t = inp
        logits = jnp.einsum("bcd,dv->bcv", h, head).astype(jnp.float32)
        logits = maybe_shard(logits, DP, None, TP)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hs, ts))
    return total / (b * s)


def train_loss(params: Params, cfg: LMConfig, batch: dict) -> jnp.ndarray:
    hidden, aux = forward(params, cfg, batch["tokens"])
    loss = lm_loss(params, cfg, hidden, batch["targets"])
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def prefill(params: Params, cfg: LMConfig, tokens: jnp.ndarray):
    """Run the prompt; returns (last-token logits, cache)."""
    hidden, (ks, vs), _ = forward(params, cfg, tokens, collect_cache=True)
    last = hidden[:, -1:, :]
    logits = jnp.einsum(
        "bsd,dv->bsv", last, params["lm_head"].astype(cfg.dtype)
    ).astype(jnp.float32)
    # Cache layout matches decode: batch over data, *sequence* over model
    # (KV heads are too few to shard 16-way under GQA).
    cache = {
        "k": maybe_shard(ks, None, DP, TP, None, None),
        "v": maybe_shard(vs, None, DP, TP, None, None),
        "length": jnp.int32(tokens.shape[1]),
    }
    return logits[:, 0], cache


def decode_step(params: Params, cfg: LMConfig, cache: dict, token: jnp.ndarray):
    """One autoregressive step. token: (B, 1) -> (logits (B, V), new cache).

    Attention is expressed as plain reductions over the cache S axis so a
    sequence-sharded cache (batch-1 long-context) lowers to flash-decoding
    style partial-softmax + psum.
    """
    b = token.shape[0]
    x = params["embed"].astype(cfg.dtype)[token]  # (B, 1, d)
    length = cache["length"]
    positions = jnp.full((b, 1), length, jnp.int32)
    windows = layer_windows(cfg, int(cache["k"].shape[2]))
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def body(x, inp):
        lp, ck, cv, win = inp  # ck/cv: (B, S, Hkv, Dh)
        lp = layers.cast_floats(lp, cfg.dtype)
        h = layers.rms_norm(x, lp["ln_attn"])
        q = jnp.einsum("bsd,dh->bsh", h, lp["wq"])
        k = jnp.einsum("bsd,dh->bsh", h, lp["wk"])
        v = jnp.einsum("bsd,dh->bsh", h, lp["wv"])
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        q = layers.rope(q.reshape(b, 1, hq, dh), positions, theta=cfg.rope_theta)
        k = layers.rope(k.reshape(b, 1, hkv, dh), positions, theta=cfg.rope_theta)
        v = v.reshape(b, 1, hkv, dh)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, length, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, length, 0, 0))
        o = layers.decode_attention(q, ck, cv, length=length + 1, window=win)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, hq * dh), lp["wo"])
        mlp_out, _ = _mlp_block(lp, cfg, x)
        return x + mlp_out, (k.astype(ck.dtype), v.astype(cv.dtype))

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], windows)
    )
    x = layers.rms_norm(x, params["ln_final"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(cfg.dtype)
    ).astype(jnp.float32)[:, 0]
    # new_k/new_v from scan are already (L, B, 1, Hkv, Dh).
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], new_k, (0, 0, length, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], new_v, (0, 0, length, 0, 0)),
        "length": length + 1,
    }
    return logits, cache


# ---------------------------------------------------------------------------
# Partitioning
# ---------------------------------------------------------------------------


def param_specs(cfg: LMConfig, mesh_axis_names, *, fsdp: bool = True) -> Params:
    """Megatron TP layout + FSDP: the non-TP matrix dim additionally shards
    over the data axes (ZeRO-3 — params, grads and optimizer moments all
    follow these specs, so per-device state is param_bytes/(dp*tp)). XLA
    inserts the per-layer all-gather inside the layer scan; ``fsdp=False``
    gives the pure-TP baseline (the §Perf before/after)."""
    tp = "model" if "model" in mesh_axis_names else None
    dp: Any = tuple(a for a in ("pod", "data") if a in mesh_axis_names)
    if not dp or not fsdp:
        dp = None

    def spec(*entries):
        return P(*entries)

    layer: Params = {
        "ln_attn": spec(None, None),
        "ln_mlp": spec(None, None),
        "wq": spec(None, dp, tp),
        "wk": spec(None, dp, tp),
        "wv": spec(None, dp, tp),
        "wo": spec(None, tp, dp),
    }
    if cfg.qkv_bias:
        layer["bq"] = spec(None, tp)
        layer["bk"] = spec(None, tp)
        layer["bv"] = spec(None, tp)
    if cfg.moe:
        layer["moe"] = {
            "router": spec(None, None, None),
            "w_gate": spec(None, tp, dp, None),  # expert parallel + FSDP on d
            "w_up": spec(None, tp, dp, None),
            "w_down": spec(None, tp, None, dp),
        }
        if cfg.moe.n_shared:
            layer["shared"] = {
                "w_gate": spec(None, dp, tp),
                "w_up": spec(None, dp, tp),
                "w_down": spec(None, tp, dp),
            }
    else:
        layer["mlp"] = {
            "w_gate": spec(None, dp, tp),
            "w_up": spec(None, dp, tp),
            "w_down": spec(None, tp, dp),
        }
    return {
        "embed": spec(tp, dp),
        "lm_head": spec(dp, tp),
        "ln_final": spec(None),
        "layers": layer,
    }


def cache_specs(cfg: LMConfig, mesh_axis_names, *, seq_sharded: bool):
    """KV-cache layout (L, B, S, Hkv, Dh).

    KV heads cannot shard over a 16-way model axis (GQA: Hkv in {2..8}), so
    decode shards the cache **sequence** axis over 'model' — flash-decoding
    style split-KV; the softmax lowers to partial max/sum + psum. Batched
    decode additionally shards B over data; batch-1 long-context shards S
    over every axis.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh_axis_names)
    tp = "model" if "model" in mesh_axis_names else None
    if seq_sharded:
        all_axes = dp + ((tp,) if tp else ())
        kv = P(None, None, all_axes if all_axes else None, None, None)
    else:
        kv = P(None, dp if dp else None, tp, None, None)
    return {"k": kv, "v": kv, "length": P()}

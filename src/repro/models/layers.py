"""Shared transformer layers: norms, RoPE, GQA flash attention, MLP, MoE.

Everything is a pure function over param dicts (nested pytrees of arrays) so
jit/pjit/vmap compose without framework machinery. Attention is a pure-jnp
blockwise (flash-style) implementation — scores never materialise beyond a
(q_chunk, kv_chunk) tile, which is what lets 32k prefill fit the dry-run
memory budget; the Pallas kernel slot for it is deliberately NOT taken:
XLA:TPU already emits fused flash attention for this pattern, the paper's own
kernels live in ``repro.kernels``.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .sharding import DP, TP, maybe_shard

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Norms / embeddings / positional
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(
        dt
    ) + bias.astype(dt)


def cast_floats(tree, dtype, *, exempt: tuple[str, ...] = ("router",)):
    """Cast floating leaves of a param subtree to the compute dtype (fp32
    master weights stay in the optimizer; ``exempt`` names stay fp32 —
    router logits are precision-sensitive)."""

    def walk(node):
        if isinstance(node, dict):
            return {
                k: node[k] if k in exempt else walk(node[k]) for k in node
            }
        if hasattr(node, "dtype") and jnp.issubdtype(node.dtype, jnp.floating):
            return node.astype(dtype)
        return node

    return walk(tree)


def rope(
    x: jnp.ndarray, positions: jnp.ndarray, *, theta: float = 10000.0
) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, D), positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

_NEG = -1e30


def flash_attention(
    q: jnp.ndarray,  # (B, S, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D)
    v: jnp.ndarray,  # (B, S, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,  # local (chunked) attention span
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Blockwise softmax attention with GQA, numerically-stable streaming.

    ``window=w`` restricts attention to keys with ``qpos - w < kpos <= qpos``
    (llama4-scout local layers). Memory high-water: one (q_chunk, kv_chunk)
    score tile per (batch, head).
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    assert s % q_chunk == 0 and s % kv_chunk == 0
    nq, nk = s // q_chunk, s // kv_chunk
    scale = 1.0 / (d**0.5)

    qr = q.reshape(b, nq, q_chunk, hkv, g, d)
    kr = k.reshape(b, nk, kv_chunk, hkv, d)
    vr = v.reshape(b, nk, kv_chunk, hkv, d)

    def q_block(qi, q_tile):  # q_tile: (B, qc, Hkv, G, D)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, inp):
            m, l, acc = carry
            ki, k_tile, v_tile = inp
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s_ = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_tile, k_tile, preferred_element_type=jnp.float32
            ) * scale  # (B, Hkv, G, qc, kc)
            mask = jnp.ones((q_chunk, kv_chunk), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s_ = jnp.where(mask, s_, _NEG)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, g, q_chunk), _NEG, jnp.float32),
            jnp.zeros((b, hkv, g, q_chunk), jnp.float32),
            jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, Hkv, G, qc, D)
        return jnp.moveaxis(out, 3, 1)  # (B, qc, Hkv, G, D)

    out = jax.lax.map(
        lambda args: q_block(*args), (jnp.arange(nq), jnp.moveaxis(qr, 1, 0))
    )  # (nq, B, qc, Hkv, G, D)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, hq, d)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    cache_k: jnp.ndarray,  # (B, S, Hkv, D)
    cache_v: jnp.ndarray,  # (B, S, Hkv, D)
    *,
    length: jnp.ndarray | int,  # valid cache length (scalar or (B,))
    window: int | None = None,
) -> jnp.ndarray:
    """Single-token attention against a KV cache.

    Written as plain reductions over the S axis so that when the cache is
    sequence-sharded (long-context batch-1 decode) SPMD lowers the softmax to
    partial max/sum + psum — flash-decoding parallelism for free.
    """
    b, s, hkv, d = cache_k.shape
    hq = q.shape[2]
    g = hq // hkv
    scale = 1.0 / (d**0.5)
    qr = q.reshape(b, hkv, g, d)
    s_ = jnp.einsum(
        "bhgd,bkhd->bhgk", qr, cache_k, preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(s)
    length = jnp.asarray(length)
    if length.ndim == 0:
        length = jnp.broadcast_to(length, (b,))
    valid = pos[None, :] < length[:, None]  # (B, S)
    if window is not None:
        valid &= pos[None, :] >= (length[:, None] - window)
    s_ = jnp.where(valid[:, None, None, :], s_, _NEG)
    m = jnp.max(s_, axis=-1, keepdims=True)
    p = jnp.exp(s_ - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", (p / jnp.maximum(l, 1e-30)).astype(cache_v.dtype), cache_v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def swiglu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(gate) * up
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def moe_mlp(
    p: Params,
    x: jnp.ndarray,  # (B, S, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based token-choice MoE with per-batch-row dispatch.

    Each batch row sorts its own (token, expert-choice) pairs into per-expert
    capacity slots — dispatch is *local to the data shard by construction*
    (no global sort collective). Expert buffers are (B, E, C, d): B rides the
    data axis, E the model axis (expert parallelism); SPMD inserts the
    dispatch all-to-all at the scatter. Returns (output, aux load-balance
    loss).
    """
    b, s, d = x.shape
    e = p["w_gate"].shape[0]
    ff = p["w_gate"].shape[2]
    cap = int(max(top_k, round(s * top_k / e * capacity_factor)))
    cap = min(cap, s * top_k)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)  # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Aux loss (Switch-style): mean fraction routed vs mean router prob.
    density = jnp.mean(
        jax.nn.one_hot(experts[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    density_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_prob) * e

    def dispatch_row(x_row, experts_row, gates_row):
        # x_row: (S, d); experts_row/gates_row: (S, K)
        flat_e = experts_row.reshape(-1)  # (S*K,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(s * top_k, dtype=jnp.int32) - starts[sorted_e]
        keep = pos < cap
        slot = jnp.where(keep, sorted_e * cap + pos, e * cap)
        tok = order // top_k
        buf = (
            jnp.zeros((e * cap + 1, d), x_row.dtype)
            .at[slot]
            .set(x_row[tok])
        )
        return buf[:-1].reshape(e, cap, d), slot, tok, order

    expert_in, slot, tok, order = jax.vmap(dispatch_row)(x, experts, gate_vals)
    # Expert buffers ride (data, expert-parallel) — SPMD inserts the dispatch
    # collective at the scatter above. Every buffer is pinned: without the
    # constraints SPMD resolves the (FSDP-d weights x row-sharded buffer)
    # einsum by replicating the buffers (observed on the multi-pod mesh).
    expert_in = maybe_shard(expert_in, DP, TP, None, None)

    h = maybe_shard(
        jnp.einsum("becd,edf->becf", expert_in, p["w_gate"]), DP, TP, None, None
    )
    u = maybe_shard(
        jnp.einsum("becd,edf->becf", expert_in, p["w_up"]), DP, TP, None, None
    )
    expert_out = maybe_shard(
        jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u, p["w_down"]),
        DP, TP, None, None,
    )  # (B, E, C, d)

    def combine_row(out_row, slot_row, tok_row, order_row, gates_row):
        flat = out_row.reshape(e * cap, d)
        safe = jnp.minimum(slot_row, e * cap - 1)
        y = jnp.where((slot_row < e * cap)[:, None], flat[safe], 0.0)
        gsel = gates_row.reshape(-1)[order_row]  # gate per sorted pair
        y = y * gsel[:, None]
        return jax.ops.segment_sum(y, tok_row, num_segments=s)

    out = jax.vmap(combine_row)(expert_out, slot, tok, order, gate_vals)
    return out.astype(x.dtype), aux

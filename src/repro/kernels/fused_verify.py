"""Fused gather-score-reduce verification kernel (the LIDER hot path).

LIDER's end-to-end AQT is dominated by candidate verification (paper
Sec. 3.1/3.3.2): after the RMI predicts positions, each query gathers its
``C = P*H*R`` candidate embeddings and scores them exactly. The materialized
formulation (``ref.verify_topk_ref``) writes a ``(B, C, d)`` candidate tensor
to HBM, re-reads it for the einsum, and round-trips a ``(B, C)`` score matrix
through the dedup/top-k — all traffic a fused kernel never needs to emit
(DESIGN.md §Verification-kernel has the byte model).

This kernel makes verification a single VMEM-resident pass per query:

- candidate row ids are **scalar-prefetched** (SMEM) so the kernel can steer
  row-granularity DMAs itself;
- each grid step streams ``block_c`` embedding rows HBM->VMEM with
  **double-buffered async copies** (``pltpu.make_async_copy``): block ``j+1``
  is in flight while block ``j`` is scored;
- scoring runs on the MXU in the embedding storage dtype (bf16 stays bf16;
  int8 code tables run **int8×int8→int32** with the per-candidate combined
  scale folded in afterwards — DESIGN.md §Quantized bank) with full-width
  accumulation; packed int4 tables (``code_dtype="int4"``) DMA half the
  bytes and unpack to int8 **in VMEM** (two arithmetic shifts) before the
  same int8×int8→int32 pass — the HBM stream is 0.5 B/elem;
- a masked **streaming top-k accumulator** lives in VMEM and merges each
  block with duplicate suppression (same semantics as
  ``core.utils.dedup_topk``: duplicates of one id carry equal scores, so
  keeping the first-selected occurrence is exact).

Only the ``(B, k)`` result ever leaves the chip; neither the candidate tensor
nor the score matrix exists in HBM.

``row_ids`` index the embedding table (what to gather); ``out_ids`` are the
ids to *report and dedup by* (defaults to ``row_ids``). LIDER passes flat
``(cluster, slot)`` rows as ``row_ids`` and global passage ids as
``out_ids``. ``out_ids < 0`` marks padding (scored ``-inf``).

A second scalar-prefetch array carries per-(row, block) valid-candidate
counts so fully-dead blocks (all probes pruned by the adaptive margin rule,
or pure padding) skip their DMA issue/wait and MXU pass under ``pl.when`` —
the mechanism that turns probe pruning into wall-clock savings (DESIGN.md
§Adaptive speed-quality control plane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import resolve_interpret

NEG_INF = float("-inf")  # python float: jnp scalars would init the backend


def _unpack_int4_vmem(rows: jnp.ndarray) -> jnp.ndarray:
    """In-VMEM nibble unpack: ``(..., d//2)`` packed int8 -> ``(..., d)`` int8.

    Emits the *deinterleaved* element order ``[x0, x2, ..., x1, x3, ...]``
    (``concat([low_nibbles, high_nibbles], -1)``) — two arithmetic shifts and
    a concat, no lane-crossing re-interleave. The query side is permuted to
    match outside the kernel (``quant.deinterleave_query_codes``), so the
    dot product over the full width is exact.
    """
    lo = jnp.right_shift(jnp.left_shift(rows, 4).astype(jnp.int8), 4)
    hi = jnp.right_shift(rows, 4)
    return jnp.concatenate([lo, hi], axis=-1)


def _clamp_block_c(block_c: int, c: int) -> int:
    """Effective candidate-block width: ``min(block_c, c)`` rounded down to a
    sublane-aligned multiple of 8 (floor 8). The round-down keeps the VMEM
    scratch and the MXU operand shapes aligned when ``c`` is not a multiple
    of the requested ``block_c``; the wrapper pads the candidate axis up to a
    multiple of the result, so a ragged last block is always well-formed
    rather than relying on caller-side padding being exact.
    """
    return max(8, (min(block_c, c) // 8) * 8)


def _fused_verify_kernel(
    # scalar prefetch
    row_ids_s,
    blk_live_s,
    # inputs: q_ref, oid_ref, [scl_ref if quantized], emb_hbm
    q_ref,
    oid_ref,
    *rest,
    block_c: int,
    k: int,
    n_blocks: int,
    quantized: bool,
    code_dtype: str,
):
    # Quantized banks carry one extra blocked input: the (1, block_c)
    # combined per-candidate scale (row scale × query scale) folded into the
    # int32 scores just before the top-k merge.
    if quantized:
        scl_ref, emb_hbm, ids_out, sc_out, cand, acc_ids, acc_sc, sem = rest
    else:
        scl_ref = None
        emb_hbm, ids_out, sc_out, cand, acc_ids, acc_sc, sem = rest
    bi = pl.program_id(0)
    cj = pl.program_id(1)
    slot = jax.lax.rem(cj, 2)
    nslot = jax.lax.rem(cj + 1, 2)

    # Block-skip contract (DESIGN.md §Adaptive): ``blk_live_s[bi, j]`` is the
    # number of valid (out_id >= 0) candidates in block j of query row bi,
    # known before the kernel runs (scalar prefetch). A dead block — every
    # candidate pruned or padding — would only contribute -inf scores, so we
    # skip its DMA issue/wait and its MXU pass entirely; the accumulator
    # simply carries over. Probe pruning therefore saves wall-clock, not just
    # emits -inf.
    live = blk_live_s[bi, cj] > 0

    def row_dma(blk, s, i):
        row = row_ids_s[bi, blk * block_c + i]
        return pltpu.make_async_copy(emb_hbm.at[row], cand.at[s, i], sem.at[s])

    def start_block(blk, s):
        def body(i, _):
            row_dma(blk, s, i).start()
            return 0

        jax.lax.fori_loop(0, block_c, body, 0)

    @pl.when(cj == 0)
    def _():
        # New query row: reset the accumulator.
        acc_sc[...] = jnp.full_like(acc_sc, NEG_INF)
        acc_ids[...] = jnp.full_like(acc_ids, -1)

    @pl.when((cj == 0) & live)
    def _():
        start_block(0, slot)  # warm up the first live block

    # Double buffering: block cj+1 goes in flight before we block on cj (dead
    # blocks issue nothing). The nslot buffer's last DMA — from the previous
    # live block on that slot — was waited at that block's own step, so the
    # overwrite is safe.
    nxt = jnp.minimum(cj + 1, n_blocks - 1)  # clamp: SMEM read is unguarded
    @pl.when((cj + 1 < n_blocks) & (blk_live_s[bi, nxt] > 0))
    def _():
        start_block(cj + 1, nslot)

    @pl.when(live)
    def _():
        def wait_body(i, _):
            row_dma(cj, slot, i).wait()
            return 0

        jax.lax.fori_loop(0, block_c, wait_body, 0)

        # Score the resident block: storage-dtype MXU inputs — int8×int8
        # with int32 accumulation on a quantized bank (the per-candidate
        # scale is folded in after, one f32 multiply per score), fp32
        # accumulation otherwise.
        rows = cand[slot]  # (block_c, d_store)
        if code_dtype == "int4":
            rows = _unpack_int4_vmem(rows)  # (block_c, d) deinterleaved
        q = q_ref[...].astype(rows.dtype)  # (1, d)
        scores = jax.lax.dot_general(
            q,
            rows,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32 if quantized else jnp.float32,
        )  # (1, block_c)
        if quantized:
            scores = scores.astype(jnp.float32) * scl_ref[...]
        oid = oid_ref[...]  # (1, block_c)
        scores = jnp.where(oid >= 0, scores, NEG_INF)

        # Streaming top-k merge with duplicate suppression: select the max k
        # times from [accumulator ++ block]; each selection kills every copy
        # of the selected id (duplicates carry equal scores, so this is
        # exact). Score ties between distinct ids break toward the smallest
        # id — the order ``dedup_topk`` produces (stable top_k over id-sorted
        # candidates).
        csc0 = jnp.concatenate([acc_sc[...], scores], axis=1)  # (1, L)
        cid = jnp.concatenate([acc_ids[...], oid], axis=1)  # (1, L)
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

        def sel_body(i, carry):
            csc, asc, aid = carry
            m = jnp.max(csc)
            tie = csc == m  # all copies of the winner are ties (equal scores)
            sid = jnp.min(jnp.where(tie, cid, jnp.int32(2**31 - 1)))
            sid = jnp.where(
                jnp.isneginf(m), jnp.int32(-1), sid
            ).astype(jnp.int32)
            kill = (cid == sid) & (sid >= 0)
            csc = jnp.where(kill, NEG_INF, csc)
            asc = jnp.where(iota_k == i, m, asc)
            aid = jnp.where(iota_k == i, sid, aid)
            return csc, asc, aid

        init = (
            csc0,
            jnp.full((1, k), NEG_INF, jnp.float32),
            jnp.full((1, k), -1, jnp.int32),
        )
        _, asc, aid = jax.lax.fori_loop(0, k, sel_body, init)
        acc_sc[...] = asc
        acc_ids[...] = aid

    @pl.when(cj == n_blocks - 1)
    def _():
        ids_out[...] = acc_ids[...]
        sc_out[...] = acc_sc[...]


@functools.partial(
    jax.jit, static_argnames=("k", "block_c", "code_dtype", "interpret")
)
def fused_verify(
    embs: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
    scales: jnp.ndarray | None = None,
    block_c: int = 256,
    code_dtype: str = "int8",
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(N, d) table, (B, C) rows, (B, d) queries -> ((B, k) ids, (B, k) f32).

    Returns the deduplicated top-k by ``out_ids`` (default ``row_ids``),
    scores descending, padded with (-1, -inf) when fewer than ``k`` unique
    valid candidates exist. ``out_ids < 0`` marks invalid slots.

    With ``scales`` ((N,) f32) set, ``embs`` is an int8 code table
    (DESIGN.md §Quantized bank): queries are quantized per row with the same
    symmetric scheme (``quant.quantize_rows``), the MXU pass runs
    int8×int8→int32, and the combined per-candidate scale (row × query)
    rides a third blocked input so folding it in costs one f32 multiply per
    score inside the merge — candidate row traffic drops to 1 byte/elem
    while dedup/top-k semantics are unchanged.

    With ``code_dtype="int4"`` (requires ``scales``), ``embs`` is a *packed*
    int4 code table of width ``d//2`` (two nibbles per byte —
    ``quant.pack_int4``): row DMAs move half the bytes again (0.5 B/elem),
    the block is unpacked to int8 in VMEM, and the query codes are
    deinterleaved outside the kernel so the same int8×int8→int32 MXU pass
    applies unchanged.

    Blocks whose candidates are *all* invalid — e.g. every probe feeding them
    was pruned by the adaptive margin rule, or they are pure C-padding — are
    skipped entirely (no DMA, no MXU pass): a per-block valid count rides the
    scalar prefetch so the kernel knows a block is dead before touching it.
    Output is bit-identical with or without skipping (dead candidates score
    -inf either way); an all-invalid row returns all (-1, -inf).
    """
    from .quant import deinterleave_query_codes, quantize_rows

    interpret = resolve_interpret(interpret)
    if out_ids is None:
        out_ids = row_ids
    quantized = scales is not None
    if code_dtype not in ("int8", "int4"):
        raise ValueError(f"code_dtype must be 'int8' or 'int4', got {code_dtype!r}")
    if code_dtype == "int4" and not quantized:
        raise ValueError("code_dtype='int4' requires scales (a packed code table)")
    b, c = row_ids.shape
    n, d = embs.shape  # d is the STORED width (d_model//2 for packed int4)
    d_q = d * 2 if code_dtype == "int4" else d  # query/logical width
    bc = _clamp_block_c(block_c, c)
    pad = (-c) % bc
    if pad:
        row_ids = jnp.pad(row_ids, ((0, 0), (0, pad)))
        out_ids = jnp.pad(out_ids, ((0, 0), (0, pad)), constant_values=-1)
    n_blocks = (c + pad) // bc
    safe_rows = jnp.clip(row_ids, 0, n - 1).astype(jnp.int32)
    out_ids = out_ids.astype(jnp.int32)
    # Per-(row, block) valid-candidate counts for the block-skip path.
    blk_live = jnp.sum(
        (out_ids >= 0).reshape(b, n_blocks, bc), axis=-1, dtype=jnp.int32
    )

    idx_q = lambda bi, cj, ids, live: (bi, 0)
    idx_blk = lambda bi, cj, ids, live: (bi, cj)
    in_specs = [
        pl.BlockSpec((1, d_q), idx_q),
        pl.BlockSpec((1, bc), idx_blk),
    ]
    inputs = [queries, out_ids]
    if quantized:
        q_codes, q_scales = quantize_rows(queries)
        if code_dtype == "int4":
            # Match the kernel's concat([lo, hi]) unpack order (see
            # _unpack_int4_vmem) — queries stay int8-quantized, only their
            # element order changes, so the int32 dot is still exact.
            q_codes = deinterleave_query_codes(q_codes)
        inputs[0] = q_codes
        # Combined per-candidate scale, gathered outside the kernel: O(B·C)
        # f32 against the O(B·C·d) row bytes the int8 path saves. Invalid
        # slots gather row 0's scale — harmless, their score is masked -inf.
        comb = scales[safe_rows].astype(jnp.float32) * q_scales[:, None]
        in_specs.append(pl.BlockSpec((1, bc), idx_blk))
        inputs.append(comb)
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # embs stay in HBM
    inputs.append(embs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_blocks),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k), idx_q),
            pl.BlockSpec((1, k), idx_q),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, bc, d), embs.dtype),  # double-buffered rows
            pltpu.VMEM((1, k), jnp.int32),  # top-k id accumulator
            pltpu.VMEM((1, k), jnp.float32),  # top-k score accumulator
            pltpu.SemaphoreType.DMA((2,)),  # one shared sem per buffer slot
        ],
    )
    ids, scores = pl.pallas_call(
        functools.partial(
            _fused_verify_kernel,
            block_c=bc,
            k=k,
            n_blocks=n_blocks,
            quantized=quantized,
            code_dtype=code_dtype,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=interpret,
    )(safe_rows, blk_live, *inputs)
    return ids, scores


# ---------------------------------------------------------------------------
# Binary-sketch pre-filter (DESIGN.md §Binary sketch tier)
# ---------------------------------------------------------------------------


def _sketch_filter_kernel(
    # scalar prefetch
    row_ids_s,
    blk_live_s,
    # inputs
    q_ref,  # (1, w) uint32 — the query's packed sign sketch
    oid_ref,  # (1, block_c) candidate ids (-1 = padding/pruned)
    sk_hbm,  # (N, w) uint32 sketch table, stays in HBM
    # outputs
    ids_out,
    sc_out,
    # scratch
    cand,
    acc_ids,
    acc_sc,
    sem,
    *,
    block_c: int,
    k: int,
    n_blocks: int,
):
    """1-bit Hamming first pass: same grid, DMA steering, block-skip, and
    streaming top-k merge as ``_fused_verify_kernel``, but the score is the
    negated XOR+popcount Hamming distance against the query sketch — 1/8 of
    the int8 row bytes per candidate, no MXU pass at all (the VPU popcount
    replaces the dot product)."""
    bi = pl.program_id(0)
    cj = pl.program_id(1)
    slot = jax.lax.rem(cj, 2)
    nslot = jax.lax.rem(cj + 1, 2)
    live = blk_live_s[bi, cj] > 0

    def row_dma(blk, s, i):
        row = row_ids_s[bi, blk * block_c + i]
        return pltpu.make_async_copy(sk_hbm.at[row], cand.at[s, i], sem.at[s])

    def start_block(blk, s):
        def body(i, _):
            row_dma(blk, s, i).start()
            return 0

        jax.lax.fori_loop(0, block_c, body, 0)

    @pl.when(cj == 0)
    def _():
        acc_sc[...] = jnp.full_like(acc_sc, NEG_INF)
        acc_ids[...] = jnp.full_like(acc_ids, -1)

    @pl.when((cj == 0) & live)
    def _():
        start_block(0, slot)

    nxt = jnp.minimum(cj + 1, n_blocks - 1)
    @pl.when((cj + 1 < n_blocks) & (blk_live_s[bi, nxt] > 0))
    def _():
        start_block(cj + 1, nslot)

    @pl.when(live)
    def _():
        def wait_body(i, _):
            row_dma(cj, slot, i).wait()
            return 0

        jax.lax.fori_loop(0, block_c, wait_body, 0)

        rows = cand[slot]  # (block_c, w) uint32
        x = jnp.bitwise_xor(rows, q_ref[...])  # broadcast (block_c, w)
        ham = jnp.sum(
            jax.lax.population_count(x).astype(jnp.int32),
            axis=-1,
            keepdims=True,
        )  # (block_c, 1)
        # Negated Hamming as f32 is exact (<= d < 2^24), so the identical
        # sel_body merge — and its smallest-id tie-break — applies unchanged.
        scores = -ham.astype(jnp.float32).T  # (1, block_c)
        oid = oid_ref[...]
        scores = jnp.where(oid >= 0, scores, NEG_INF)

        csc0 = jnp.concatenate([acc_sc[...], scores], axis=1)
        cid = jnp.concatenate([acc_ids[...], oid], axis=1)
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

        def sel_body(i, carry):
            csc, asc, aid = carry
            m = jnp.max(csc)
            tie = csc == m
            sid = jnp.min(jnp.where(tie, cid, jnp.int32(2**31 - 1)))
            sid = jnp.where(
                jnp.isneginf(m), jnp.int32(-1), sid
            ).astype(jnp.int32)
            kill = (cid == sid) & (sid >= 0)
            csc = jnp.where(kill, NEG_INF, csc)
            asc = jnp.where(iota_k == i, m, asc)
            aid = jnp.where(iota_k == i, sid, aid)
            return csc, asc, aid

        init = (
            csc0,
            jnp.full((1, k), NEG_INF, jnp.float32),
            jnp.full((1, k), -1, jnp.int32),
        )
        _, asc, aid = jax.lax.fori_loop(0, k, sel_body, init)
        acc_sc[...] = asc
        acc_ids[...] = aid

    @pl.when(cj == n_blocks - 1)
    def _():
        ids_out[...] = acc_ids[...]
        sc_out[...] = acc_sc[...]


@functools.partial(jax.jit, static_argnames=("k", "block_c", "interpret"))
def sketch_prefilter(
    sketches: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
    block_c: int = 256,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(N, w) packed sketch table, (B, C) rows, (B, d) queries ->
    ((B, k) ids, (B, k) negated-Hamming f32 scores).

    The 1-bit first pass of the sketch→code→rescore ladder (DESIGN.md
    §Binary sketch tier): queries are sign-sketched outside the kernel
    (``quant.sketch_rows`` — the same packer that built the table), candidate
    sketch rows stream HBM->VMEM at 1/8 the int8 code bytes, and scoring is
    XOR + popcount on the VPU. Dedup/top-k semantics — including padding
    (``out_ids < 0`` -> (-1, -inf)), dead-block skipping, and the
    smallest-id tie-break — are identical to ``fused_verify``, so the
    surviving top-``k`` rows feed the int4/int8 pass as an ordinary
    ``row_ids``/``out_ids`` pair.
    """
    from .quant import sketch_rows

    interpret = resolve_interpret(interpret)
    if out_ids is None:
        out_ids = row_ids
    b, c = row_ids.shape
    n, w = sketches.shape
    q_sk = sketch_rows(queries)  # (B, w) uint32
    bc = _clamp_block_c(block_c, c)
    pad = (-c) % bc
    if pad:
        row_ids = jnp.pad(row_ids, ((0, 0), (0, pad)))
        out_ids = jnp.pad(out_ids, ((0, 0), (0, pad)), constant_values=-1)
    n_blocks = (c + pad) // bc
    safe_rows = jnp.clip(row_ids, 0, n - 1).astype(jnp.int32)
    out_ids = out_ids.astype(jnp.int32)
    blk_live = jnp.sum(
        (out_ids >= 0).reshape(b, n_blocks, bc), axis=-1, dtype=jnp.int32
    )

    idx_q = lambda bi, cj, ids, live: (bi, 0)
    idx_blk = lambda bi, cj, ids, live: (bi, cj)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, w), idx_q),
            pl.BlockSpec((1, bc), idx_blk),
            pl.BlockSpec(memory_space=pltpu.ANY),  # sketches stay in HBM
        ],
        out_specs=[
            pl.BlockSpec((1, k), idx_q),
            pl.BlockSpec((1, k), idx_q),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, bc, w), jnp.uint32),  # double-buffered sketches
            pltpu.VMEM((1, k), jnp.int32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    ids, scores = pl.pallas_call(
        functools.partial(
            _sketch_filter_kernel, block_c=bc, k=k, n_blocks=n_blocks
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=interpret,
    )(safe_rows, blk_live, q_sk, out_ids, sketches)
    return ids, scores


# ---------------------------------------------------------------------------
# Cluster-major multi-query schedule (DESIGN.md §Cluster-major schedule)
# ---------------------------------------------------------------------------


def _fused_verify_grouped_kernel(
    # scalar prefetch
    sched_cids_s,
    blk_live_s,
    # blocked inputs
    emb_ref,  # (1, bc, d_store) — steered to cluster sched_cids[s], block j
    scl_ref,  # (1, bc) per-row scales of the same block
    q_ref,  # (1, block_q, d_q) query-code tile of step s
    qscl_ref,  # (1, block_q) query scales of step s
    oid_ref,  # (1, block_q, bc) per-(slot, row) candidate ids (-1 = not cand)
    # outputs
    ids_out,
    sc_out,
    # scratch
    acc_ids,
    acc_sc,
    *,
    block_q: int,
    kp: int,
    n_blocks: int,
    code_dtype: str,
):
    s = pl.program_id(0)
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _():
        acc_sc[...] = jnp.full_like(acc_sc, NEG_INF)
        acc_ids[...] = jnp.full_like(acc_ids, -1)

    # Dead step-blocks (no candidate of any query in this tile touches these
    # rows — e.g. pruned probes or schedule padding) skip the MXU pass; the
    # block's rows still stream through the automatic pipeline, but scoring
    # and the k' merge are the dominant per-block cost at block_q > 1.
    @pl.when(blk_live_s[s, cj] > 0)
    def _():
        rows = emb_ref[0]  # (bc, d_store)
        if code_dtype == "int4":
            rows = _unpack_int4_vmem(rows)  # (bc, d) deinterleaved
        qt = q_ref[0].astype(rows.dtype)  # (block_q, d)
        # ONE MXU pass scores the whole query tile against the resident
        # cluster block — this is the DMA-sharing win: per-query scheduling
        # would re-stream these rows once per query in the tile.
        int_scores = jax.lax.dot_general(
            qt,
            rows,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # (block_q, bc)
        # Combined scale as an in-kernel outer product (f32 multiply is
        # commutative, so this is bit-identical to the per-query path's
        # pre-gathered row×query scale).
        comb = qscl_ref[0][:, None] * scl_ref[0][None, :]
        scores = int_scores.astype(jnp.float32) * comb
        oid = oid_ref[0]  # (block_q, bc)
        scores = jnp.where(oid >= 0, scores, NEG_INF)

        # Row-vectorized streaming top-k' merge: same selection order and
        # smallest-id tie-break as the per-query kernel / dedup_topk, applied
        # to all block_q slots at once.
        csc0 = jnp.concatenate([acc_sc[...], scores], axis=1)  # (bq, kp+bc)
        cid0 = jnp.concatenate([acc_ids[...], oid], axis=1)
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (block_q, kp), 1)

        def sel_body(i, carry):
            csc, asc, aid = carry
            m = jnp.max(csc, axis=1, keepdims=True)  # (bq, 1)
            tie = csc == m
            sid = jnp.min(
                jnp.where(tie, cid0, jnp.int32(2**31 - 1)),
                axis=1,
                keepdims=True,
            )
            sid = jnp.where(jnp.isneginf(m), jnp.int32(-1), sid).astype(
                jnp.int32
            )
            kill = (cid0 == sid) & (sid >= 0)
            csc = jnp.where(kill, NEG_INF, csc)
            asc = jnp.where(iota_k == i, m, asc)
            aid = jnp.where(iota_k == i, sid, aid)
            return csc, asc, aid

        init = (
            csc0,
            jnp.full((block_q, kp), NEG_INF, jnp.float32),
            jnp.full((block_q, kp), -1, jnp.int32),
        )
        _, asc, aid = jax.lax.fori_loop(0, kp, sel_body, init)
        acc_sc[...] = asc
        acc_ids[...] = aid

    @pl.when(cj == n_blocks - 1)
    def _():
        ids_out[0] = acc_ids[...]
        sc_out[0] = acc_sc[...]


def _grouped_block_c(block_c: int, lp: int) -> int:
    """Cluster-row tile width for the grouped kernel: the largest multiple
    of 8 that DIVIDES ``lp`` and is <= min(block_c, lp). ``lp`` (the bank
    slot capacity) is always a multiple of 8 (``pad_multiple``), so a
    sublane-aligned divisor exists and no table padding is ever needed —
    the BlockSpec can slice ``embs[(cid, j)]`` directly. Falls back to any
    divisor for oddly-shaped test tables.
    """
    cap = min(block_c, lp)
    for v in range(cap - cap % 8, 7, -8):
        if lp % v == 0:
            return v
    for v in range(cap, 0, -1):
        if lp % v == 0:
            return v
    return lp


@functools.partial(
    jax.jit,
    static_argnames=("kp", "block_q", "block_c", "code_dtype", "interpret"),
)
def fused_verify_grouped(
    embs: jnp.ndarray,
    row_scales: jnp.ndarray,
    queries: jnp.ndarray,
    sched_cids: jnp.ndarray,
    sched_qids: jnp.ndarray,
    step_slot_ids: jnp.ndarray,
    *,
    kp: int,
    block_q: int,
    block_c: int = 256,
    code_dtype: str = "int8",
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Cluster-major first pass: one cluster DMA serves a whole query tile.

    The per-query ``fused_verify`` grid re-streams a cluster's rows once per
    (query, probe) that touches it. This kernel flips the grid to
    **cluster-major**: a host pre-pass (``schedule.build_cluster_schedule``)
    groups the batch's (query, probe) pairs by cluster into steps of
    ``block_q`` query slots, and each grid step streams one ``block_c`` row
    tile of ONE cluster and scores it against the step's whole query tile on
    the MXU — under skewed (Zipf) probe traffic the same rows serve many
    queries per DMA (DESIGN.md §Cluster-major schedule).

    Quantized banks only (int8 / packed int4 codes + per-row scales):

    - ``embs``: ``(c, Lp, d_store)`` stored codes (``d_store = d//2`` packed
      int4); ``row_scales``: ``(c, Lp)`` f32.
    - ``sched_cids``: ``(S,)`` int32 — the cluster each step scores.
    - ``sched_qids``: ``(S, block_q)`` int32 — query per tile slot (-1 pad).
    - ``step_slot_ids``: ``(S, block_q, Lp)`` int32 — per (step, slot,
      cluster row) the id to report, or -1 where that row is not a candidate
      of that query (the dense union of the pair's H·R window candidates —
      duplicates collapse for free).

    Returns ``(ids, scores)`` of shape ``(S, block_q, kp)``: each (query,
    cluster) pair's dedup-top-k' *within that cluster*, same ordering and
    tie-break as ``fused_verify``. Because every global top-k' winner from a
    cluster is inside its pair's per-cluster top-k', scattering these back
    per query and merging with ``dedup_topk`` reproduces the per-query
    schedule's provisional top-k' bit-exactly (tests/test_fused_verify.py).

    Rows are streamed by BlockSpec index maps steered with the
    scalar-prefetched ``sched_cids`` — cluster rows are contiguous in
    ``embs``, so the automatic pipeline double-buffers tiles with no manual
    DMA loop.
    """
    from .quant import deinterleave_query_codes, quantize_rows

    interpret = resolve_interpret(interpret)
    if code_dtype not in ("int8", "int4"):
        raise ValueError(f"code_dtype must be 'int8' or 'int4', got {code_dtype!r}")
    c, lp, d_store = embs.shape
    s_steps = sched_cids.shape[0]
    d_q = d_store * 2 if code_dtype == "int4" else d_store
    bc = _grouped_block_c(block_c, lp)
    n_blocks = lp // bc

    q_codes, q_scales = quantize_rows(queries)
    if code_dtype == "int4":
        q_codes = deinterleave_query_codes(q_codes)
    safe_q = jnp.maximum(sched_qids, 0)
    q_tiles = q_codes[safe_q]  # (S, block_q, d_q)
    # Pad slots get scale 1.0 (their candidates are all -1 -> -inf anyway).
    qscl_tiles = jnp.where(sched_qids >= 0, q_scales[safe_q], 1.0).astype(
        jnp.float32
    )
    step_slot_ids = step_slot_ids.astype(jnp.int32)
    sched_cids = jnp.clip(sched_cids, 0, c - 1).astype(jnp.int32)
    # Per-(step, block) candidate counts: a block is dead if no query in the
    # tile has a candidate among its rows.
    blk_live = jnp.sum(
        (step_slot_ids >= 0).reshape(s_steps, block_q, n_blocks, bc),
        axis=(1, 3),
        dtype=jnp.int32,
    )

    idx_emb = lambda s, j, cids, live: (cids[s], j, 0)
    idx_scl = lambda s, j, cids, live: (cids[s], j)
    idx_step = lambda s, j, cids, live: (s, 0, 0)
    idx_qscl = lambda s, j, cids, live: (s, 0)
    idx_oid = lambda s, j, cids, live: (s, 0, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s_steps, n_blocks),
        in_specs=[
            pl.BlockSpec((1, bc, d_store), idx_emb),
            pl.BlockSpec((1, bc), idx_scl),
            pl.BlockSpec((1, block_q, d_q), idx_step),
            pl.BlockSpec((1, block_q), idx_qscl),
            pl.BlockSpec((1, block_q, bc), idx_oid),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, kp), idx_step),
            pl.BlockSpec((1, block_q, kp), idx_step),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, kp), jnp.int32),
            pltpu.VMEM((block_q, kp), jnp.float32),
        ],
    )
    ids, scores = pl.pallas_call(
        functools.partial(
            _fused_verify_grouped_kernel,
            block_q=block_q,
            kp=kp,
            n_blocks=n_blocks,
            code_dtype=code_dtype,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s_steps, block_q, kp), jnp.int32),
            jax.ShapeDtypeStruct((s_steps, block_q, kp), jnp.float32),
        ],
        interpret=interpret,
    )(sched_cids, blk_live, embs, row_scales, q_tiles, qscl_tiles, step_slot_ids)
    return ids, scores

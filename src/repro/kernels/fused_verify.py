"""Fused gather-score-reduce verification kernel (the LIDER hot path).

LIDER's end-to-end AQT is dominated by candidate verification (paper
Sec. 3.1/3.3.2): after the RMI predicts positions, each query gathers its
``C = P*H*R`` candidate embeddings and scores them exactly. The materialized
formulation (``ref.verify_topk_ref``) writes a ``(B, C, d)`` candidate tensor
to HBM, re-reads it for the einsum, and round-trips a ``(B, C)`` score matrix
through the dedup/top-k — all traffic a fused kernel never needs to emit
(DESIGN.md §Verification-kernel has the byte model).

This kernel makes verification a single VMEM-resident pass per query:

- candidate row ids are **scalar-prefetched** (SMEM) so the kernel can steer
  row-granularity DMAs itself;
- each grid step streams ``block_c`` embedding rows HBM->VMEM with
  **double-buffered async copies** (``pltpu.make_async_copy``): block ``j+1``
  is in flight while block ``j`` is scored;
- scoring runs on the MXU in the embedding storage dtype (bf16 stays bf16;
  int8 code tables run **int8×int8→int32** with the per-candidate combined
  scale folded in afterwards — DESIGN.md §Quantized bank) with full-width
  accumulation;
- a masked **streaming top-k accumulator** lives in VMEM and merges each
  block with duplicate suppression (same semantics as
  ``core.utils.dedup_topk``: duplicates of one id carry equal scores, so
  keeping the first-selected occurrence is exact).

Only the ``(B, k)`` result ever leaves the chip; neither the candidate tensor
nor the score matrix exists in HBM.

``row_ids`` index the embedding table (what to gather); ``out_ids`` are the
ids to *report and dedup by* (defaults to ``row_ids``). LIDER passes flat
``(cluster, slot)`` rows as ``row_ids`` and global passage ids as
``out_ids``. ``out_ids < 0`` marks padding (scored ``-inf``).

A second scalar-prefetch array carries per-(row, block) valid-candidate
counts so fully-dead blocks (all probes pruned by the adaptive margin rule,
or pure padding) skip their DMA issue/wait and MXU pass under ``pl.when`` —
the mechanism that turns probe pruning into wall-clock savings (DESIGN.md
§Adaptive speed-quality control plane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import resolve_interpret

NEG_INF = float("-inf")  # python float: jnp scalars would init the backend


def _fused_verify_kernel(
    # scalar prefetch
    row_ids_s,
    blk_live_s,
    # inputs: q_ref, oid_ref, [scl_ref if quantized], emb_hbm
    q_ref,
    oid_ref,
    *rest,
    block_c: int,
    k: int,
    n_blocks: int,
    quantized: bool,
):
    # Quantized banks carry one extra blocked input: the (1, block_c)
    # combined per-candidate scale (row scale × query scale) folded into the
    # int32 scores just before the top-k merge.
    if quantized:
        scl_ref, emb_hbm, ids_out, sc_out, cand, acc_ids, acc_sc, sem = rest
    else:
        scl_ref = None
        emb_hbm, ids_out, sc_out, cand, acc_ids, acc_sc, sem = rest
    bi = pl.program_id(0)
    cj = pl.program_id(1)
    slot = jax.lax.rem(cj, 2)
    nslot = jax.lax.rem(cj + 1, 2)

    # Block-skip contract (DESIGN.md §Adaptive): ``blk_live_s[bi, j]`` is the
    # number of valid (out_id >= 0) candidates in block j of query row bi,
    # known before the kernel runs (scalar prefetch). A dead block — every
    # candidate pruned or padding — would only contribute -inf scores, so we
    # skip its DMA issue/wait and its MXU pass entirely; the accumulator
    # simply carries over. Probe pruning therefore saves wall-clock, not just
    # emits -inf.
    live = blk_live_s[bi, cj] > 0

    def row_dma(blk, s, i):
        row = row_ids_s[bi, blk * block_c + i]
        return pltpu.make_async_copy(emb_hbm.at[row], cand.at[s, i], sem.at[s])

    def start_block(blk, s):
        def body(i, _):
            row_dma(blk, s, i).start()
            return 0

        jax.lax.fori_loop(0, block_c, body, 0)

    @pl.when(cj == 0)
    def _():
        # New query row: reset the accumulator.
        acc_sc[...] = jnp.full_like(acc_sc, NEG_INF)
        acc_ids[...] = jnp.full_like(acc_ids, -1)

    @pl.when((cj == 0) & live)
    def _():
        start_block(0, slot)  # warm up the first live block

    # Double buffering: block cj+1 goes in flight before we block on cj (dead
    # blocks issue nothing). The nslot buffer's last DMA — from the previous
    # live block on that slot — was waited at that block's own step, so the
    # overwrite is safe.
    nxt = jnp.minimum(cj + 1, n_blocks - 1)  # clamp: SMEM read is unguarded
    @pl.when((cj + 1 < n_blocks) & (blk_live_s[bi, nxt] > 0))
    def _():
        start_block(cj + 1, nslot)

    @pl.when(live)
    def _():
        def wait_body(i, _):
            row_dma(cj, slot, i).wait()
            return 0

        jax.lax.fori_loop(0, block_c, wait_body, 0)

        # Score the resident block: storage-dtype MXU inputs — int8×int8
        # with int32 accumulation on a quantized bank (the per-candidate
        # scale is folded in after, one f32 multiply per score), fp32
        # accumulation otherwise.
        q = q_ref[...].astype(cand.dtype)  # (1, d)
        scores = jax.lax.dot_general(
            q,
            cand[slot],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32 if quantized else jnp.float32,
        )  # (1, block_c)
        if quantized:
            scores = scores.astype(jnp.float32) * scl_ref[...]
        oid = oid_ref[...]  # (1, block_c)
        scores = jnp.where(oid >= 0, scores, NEG_INF)

        # Streaming top-k merge with duplicate suppression: select the max k
        # times from [accumulator ++ block]; each selection kills every copy
        # of the selected id (duplicates carry equal scores, so this is
        # exact). Score ties between distinct ids break toward the smallest
        # id — the order ``dedup_topk`` produces (stable top_k over id-sorted
        # candidates).
        csc0 = jnp.concatenate([acc_sc[...], scores], axis=1)  # (1, L)
        cid = jnp.concatenate([acc_ids[...], oid], axis=1)  # (1, L)
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

        def sel_body(i, carry):
            csc, asc, aid = carry
            m = jnp.max(csc)
            tie = csc == m  # all copies of the winner are ties (equal scores)
            sid = jnp.min(jnp.where(tie, cid, jnp.int32(2**31 - 1)))
            sid = jnp.where(
                jnp.isneginf(m), jnp.int32(-1), sid
            ).astype(jnp.int32)
            kill = (cid == sid) & (sid >= 0)
            csc = jnp.where(kill, NEG_INF, csc)
            asc = jnp.where(iota_k == i, m, asc)
            aid = jnp.where(iota_k == i, sid, aid)
            return csc, asc, aid

        init = (
            csc0,
            jnp.full((1, k), NEG_INF, jnp.float32),
            jnp.full((1, k), -1, jnp.int32),
        )
        _, asc, aid = jax.lax.fori_loop(0, k, sel_body, init)
        acc_sc[...] = asc
        acc_ids[...] = aid

    @pl.when(cj == n_blocks - 1)
    def _():
        ids_out[...] = acc_ids[...]
        sc_out[...] = acc_sc[...]


@functools.partial(jax.jit, static_argnames=("k", "block_c", "interpret"))
def fused_verify(
    embs: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
    scales: jnp.ndarray | None = None,
    block_c: int = 256,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(N, d) table, (B, C) rows, (B, d) queries -> ((B, k) ids, (B, k) f32).

    Returns the deduplicated top-k by ``out_ids`` (default ``row_ids``),
    scores descending, padded with (-1, -inf) when fewer than ``k`` unique
    valid candidates exist. ``out_ids < 0`` marks invalid slots.

    With ``scales`` ((N,) f32) set, ``embs`` is an int8 code table
    (DESIGN.md §Quantized bank): queries are quantized per row with the same
    symmetric scheme (``quant.quantize_rows``), the MXU pass runs
    int8×int8→int32, and the combined per-candidate scale (row × query)
    rides a third blocked input so folding it in costs one f32 multiply per
    score inside the merge — candidate row traffic drops to 1 byte/elem
    while dedup/top-k semantics are unchanged.

    Blocks whose candidates are *all* invalid — e.g. every probe feeding them
    was pruned by the adaptive margin rule, or they are pure C-padding — are
    skipped entirely (no DMA, no MXU pass): a per-block valid count rides the
    scalar prefetch so the kernel knows a block is dead before touching it.
    Output is bit-identical with or without skipping (dead candidates score
    -inf either way); an all-invalid row returns all (-1, -inf).
    """
    from .quant import quantize_rows

    interpret = resolve_interpret(interpret)
    if out_ids is None:
        out_ids = row_ids
    quantized = scales is not None
    b, c = row_ids.shape
    n, d = embs.shape
    bc = min(block_c, c)
    pad = (-c) % bc
    if pad:
        row_ids = jnp.pad(row_ids, ((0, 0), (0, pad)))
        out_ids = jnp.pad(out_ids, ((0, 0), (0, pad)), constant_values=-1)
    n_blocks = (c + pad) // bc
    safe_rows = jnp.clip(row_ids, 0, n - 1).astype(jnp.int32)
    out_ids = out_ids.astype(jnp.int32)
    # Per-(row, block) valid-candidate counts for the block-skip path.
    blk_live = jnp.sum(
        (out_ids >= 0).reshape(b, n_blocks, bc), axis=-1, dtype=jnp.int32
    )

    idx_q = lambda bi, cj, ids, live: (bi, 0)
    idx_blk = lambda bi, cj, ids, live: (bi, cj)
    in_specs = [
        pl.BlockSpec((1, d), idx_q),
        pl.BlockSpec((1, bc), idx_blk),
    ]
    inputs = [queries, out_ids]
    if quantized:
        q_codes, q_scales = quantize_rows(queries)
        inputs[0] = q_codes
        # Combined per-candidate scale, gathered outside the kernel: O(B·C)
        # f32 against the O(B·C·d) row bytes the int8 path saves. Invalid
        # slots gather row 0's scale — harmless, their score is masked -inf.
        comb = scales[safe_rows].astype(jnp.float32) * q_scales[:, None]
        in_specs.append(pl.BlockSpec((1, bc), idx_blk))
        inputs.append(comb)
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # embs stay in HBM
    inputs.append(embs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_blocks),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k), idx_q),
            pl.BlockSpec((1, k), idx_q),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, bc, d), embs.dtype),  # double-buffered rows
            pltpu.VMEM((1, k), jnp.int32),  # top-k id accumulator
            pltpu.VMEM((1, k), jnp.float32),  # top-k score accumulator
            pltpu.SemaphoreType.DMA((2,)),  # one shared sem per buffer slot
        ],
    )
    ids, scores = pl.pallas_call(
        functools.partial(
            _fused_verify_kernel,
            block_c=bc,
            k=k,
            n_blocks=n_blocks,
            quantized=quantized,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=interpret,
    )(safe_rows, blk_live, *inputs)
    return ids, scores

"""Candidate-verification kernel: scalar-prefetched gather + dot product.

LIDER's verification step scores each query against the H*R candidate rows
its sorted arrays produced — a data-dependent gather followed by a dot, the
same block-table indirection pattern as paged attention. Candidate ids are
scalar-prefetched (SMEM) so the BlockSpec index_map can steer each DMA to
``embs[ids[b, c]]`` directly: the embedding table never moves wholesale, only
the touched rows cross HBM->VMEM.

This one-row-per-step formulation is the canonical/minimal form; a
production variant batches ``block_c`` DMAs per step with double-buffering
(``pltpu.make_async_copy``) to hide latency — the HBM byte count (the
roofline term) is identical, so the analysis in EXPERIMENTS.md uses this
kernel's traffic model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _score_gather_kernel(ids_ref, q_ref, emb_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)  # (1, d)
    e = emb_ref[...].astype(jnp.float32)  # (1, d)
    out_ref[...] = jnp.sum(q * e, axis=-1, keepdims=True)  # (1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_gather(
    embs: jnp.ndarray,
    cand_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """(N, d) table, (B, C) int32 ids, (B, d) queries -> (B, C) IP scores.

    Ids < 0 (padding) score -inf.
    """
    b, c = cand_ids.shape
    n, d = embs.shape
    safe_ids = jnp.maximum(cand_ids, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, c),
        in_specs=[
            pl.BlockSpec((1, d), lambda bi, ci, ids: (bi, 0)),
            pl.BlockSpec((1, d), lambda bi, ci, ids: (ids[bi, ci], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bi, ci, ids: (bi, ci)),
    )
    scores = pl.pallas_call(
        _score_gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(safe_ids, queries, embs)
    return jnp.where(cand_ids < 0, -jnp.inf, scores)

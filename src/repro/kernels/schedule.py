"""Cluster-major schedule construction for multi-query batched verification.

The per-query fused-verify grid DMAs every probed cluster's rows once per
(query, probe) pair: a batch of B queries each probing P clusters issues
B·P cluster-tile streams even when the batch concentrates on a handful of
hot clusters — under production (Zipf-skewed) traffic most of that is the
same bytes moved again. The cluster-major schedule fixes the loop order:
group the batch's (query, probe) pairs BY CLUSTER into steps of up to
``block_q`` query slots, stream each cluster's rows once per step, and score
them against the whole query tile on the MXU (DESIGN.md §Cluster-major
schedule). The kernel side is ``fused_verify.fused_verify_grouped``; this
module is the host pre-pass that turns routed probe lists into its schedule
arrays.

The schedule is pure bookkeeping over small host integers (the ``(B, P)``
routed cluster ids — already host-visible in the staged search), so it runs
in NumPy between the routing jit and the verification jit. Step count is
padded to a power of two to bound recompiles of the downstream kernel, the
same policy as ``core.update``'s dirty-cluster batches.

Determinism contract: pairs are ordered by (cluster asc, query asc, probe
asc) and packed greedily into ``block_q``-slot steps, so the schedule — and
therefore the kernel's compiled shape and its bit-exact outputs — depends
only on the routed probe lists, never on query order within a step (scores
are per-slot) or on hash iteration order.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _pad_pow2(m: int, lo: int = 1) -> int:
    """Next power of two >= max(m, lo) — bounds kernel recompiles over
    variable schedule sizes (same policy as core.update's batch padding)."""
    return max(lo, 1 << (max(m, 1) - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class ClusterSchedule:
    """The cluster→query-tile schedule for one routed batch.

    ``sched_cids``: (S,) int32 — the cluster each step streams (padding
    steps carry cluster 0 with an all-empty tile; the kernel skips them).
    ``sched_qids``: (S, block_q) int32 — query index per tile slot (-1 pad).
    ``pair_step`` / ``pair_slot``: (B, P) int32 — where each (query, probe)
    pair landed, -1 for pairs excluded from the schedule (pruned probes);
    the per-query merge gathers its pairs' per-cluster top-k' through these.
    ``n_steps``: real (unpadded) step count.
    ``n_pairs``: scheduled (unpruned) pair count.
    ``cluster_loads``: number of distinct (step, cluster) streams — the
    cluster-tile DMA count the schedule actually issues; the per-query
    schedule issues ``n_pairs`` of them, so ``n_pairs / n_steps`` is the
    DMA-sharing ratio the Zipf benchmark gates on.
    """

    sched_cids: np.ndarray
    sched_qids: np.ndarray
    pair_step: np.ndarray
    pair_slot: np.ndarray
    block_q: int
    n_steps: int
    n_pairs: int

    @property
    def n_padded_steps(self) -> int:
        return int(self.sched_cids.shape[0])

    @property
    def sharing_ratio(self) -> float:
        """Cluster-tile streams saved vs the per-query schedule:
        ``n_pairs / n_steps`` (>= 1; 1.0 means no sharing happened)."""
        return self.n_pairs / max(self.n_steps, 1)


def build_cluster_schedule(
    cids: np.ndarray,
    *,
    block_q: int,
    pruned: np.ndarray | None = None,
    pad_to: int | None = None,
) -> ClusterSchedule:
    """Group a batch's routed (query, probe) pairs by cluster into steps.

    ``cids``: (B, P) int32 routed cluster ids (< 0 = invalid probe).
    ``pruned``: optional (B, P) bool — True excludes the pair (the adaptive
    ``prune_margin`` rule); excluded pairs get ``pair_step = -1`` and their
    candidates never enter the kernel, mirroring the per-query path's
    masked-to--1 candidates.

    Pairs probing the same cluster fill a step's ``block_q`` query slots in
    (query asc, probe asc) order; a cluster with more pairs than ``block_q``
    spans consecutive steps. Steps are ordered by cluster id ascending.

    ``pad_to`` overrides the power-of-two step padding with a FIXED padded
    step count — the online block_q autotuner passes the worst case
    ``_pad_pow2(B * P)`` (n_steps <= n_pairs <= B·P always) so every batch
    of the same (B, block_q) compiles exactly one downstream kernel shape
    regardless of the observed probe distribution (zero query-path
    retraces). Padding steps are dead (empty tiles, ``blk_live = 0``), so
    results are unchanged. Values below the real step count fall back to
    the power-of-two policy.
    """
    cids = np.asarray(cids, np.int32)
    b, p = cids.shape
    keep = cids >= 0
    if pruned is not None:
        keep &= ~np.asarray(pruned, bool)
    qid, pid = np.nonzero(keep)  # row-major: (query asc, probe asc)
    pcid = cids[qid, pid]
    # Stable sort by cluster keeps the (query asc, probe asc) order within
    # each cluster group — the determinism contract.
    order = np.argsort(pcid, kind="stable")
    qid, pid, pcid = qid[order], pid[order], pcid[order]
    n_pairs = int(pcid.shape[0])

    # Slot index within the cluster group, then split groups into
    # block_q-wide steps.
    if n_pairs:
        starts = np.r_[True, pcid[1:] != pcid[:-1]]
        group_start = np.maximum.accumulate(np.where(starts, np.arange(n_pairs), 0))
        within = np.arange(n_pairs) - group_start
        step_of_group = within // block_q
        slot = (within % block_q).astype(np.int32)
        # Global step index: new step whenever the (cluster, step_of_group)
        # pair changes.
        step_key = starts | (np.r_[False, step_of_group[1:] != step_of_group[:-1]])
        step = (np.cumsum(step_key) - 1).astype(np.int32)
        n_steps = int(step[-1]) + 1
    else:
        slot = step = np.zeros((0,), np.int32)
        n_steps = 0

    s_padded = _pad_pow2(n_steps)
    if pad_to is not None and pad_to >= n_steps:
        s_padded = max(int(pad_to), 1)
    sched_cids = np.zeros((s_padded,), np.int32)
    sched_qids = np.full((s_padded, block_q), -1, np.int32)
    if n_pairs:
        sched_cids[step] = pcid
        sched_qids[step, slot] = qid
    pair_step = np.full((b, p), -1, np.int32)
    pair_slot = np.full((b, p), -1, np.int32)
    if n_pairs:
        pair_step[qid, pid] = step
        pair_slot[qid, pid] = slot
    return ClusterSchedule(
        sched_cids=sched_cids,
        sched_qids=sched_qids,
        pair_step=pair_step,
        pair_slot=pair_slot,
        block_q=int(block_q),
        n_steps=n_steps,
        n_pairs=n_pairs,
    )

"""Per-row symmetric int8 quantization (the bank storage scheme).

One scheme, one home: the quantized :class:`~repro.core.bank.ClusterBank`
representation, the fused kernel's query-side quantization, and the CPU
oracle (`ref.verify_topk_ref`) all call these helpers, so the stored codes
and the scores computed from them can never drift between layers
(DESIGN.md §Quantized bank).

Scheme: for each row ``x`` (an embedding or a query),

    scale = max(|x|) / 127        (1.0 for all-zero rows, so pads stay 0)
    code  = round(x / scale)  ∈ [-127, 127]   (int8; -128 is never produced)

and a dot product of two quantized rows is exact int arithmetic:

    <xq, yq> ≈ <x, y> / (scale_x · scale_y)   with int8×int8→int32 accum.

The scheme is *stateless per row* — no global calibration — which is what
makes incremental upsert exactly equivalent to a full rebuild: quantizing a
row depends on nothing but the row.
"""
from __future__ import annotations

import jax.numpy as jnp

INT8_MAX = 127.0


def quantize_rows(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``(..., d)`` float -> (codes ``(..., d)`` int8, scales ``(...,)`` f32).

    Symmetric per-row scaling to ±127. All-zero rows get scale 1.0 so their
    codes are exactly 0 and dequantization returns exact zeros (padded bank
    slots stay padding).
    """
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    # Multiply by the pre-rounded reciprocal instead of dividing by 127:
    # XLA strength-reduces constant divisions differently inside and outside
    # fused jits (1-ulp drift), and bank scales must be bit-identical
    # between the eager offline build and the jit'd upsert append.
    scales = jnp.where(
        amax > 0, amax * jnp.float32(1.0 / INT8_MAX), 1.0
    ).astype(jnp.float32)
    codes = jnp.clip(
        jnp.round(x / scales[..., None]), -INT8_MAX, INT8_MAX
    ).astype(jnp.int8)
    return codes, scales


def dequantize_rows(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_rows` (up to rounding): f32 rows."""
    return codes.astype(jnp.float32) * scales[..., None].astype(jnp.float32)

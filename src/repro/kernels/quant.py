"""Per-row symmetric int8 / packed int4 quantization (the bank storage
schemes).

One scheme, one home: the quantized :class:`~repro.core.bank.ClusterBank`
representation, the fused kernel's query-side quantization, and the CPU
oracle (`ref.verify_topk_ref`) all call these helpers, so the stored codes
and the scores computed from them can never drift between layers
(DESIGN.md §Quantized bank).

Scheme: for each row ``x`` (an embedding or a query),

    scale = max(|x|) / 127        (1.0 for all-zero rows, so pads stay 0)
    code  = round(x / scale)  ∈ [-127, 127]   (int8; -128 is never produced)

and a dot product of two quantized rows is exact int arithmetic:

    <xq, yq> ≈ <x, y> / (scale_x · scale_y)   with int8×int8→int32 accum.

The scheme is *stateless per row* — no global calibration — which is what
makes incremental upsert exactly equivalent to a full rebuild: quantizing a
row depends on nothing but the row.

int4 (``storage_dtype="int4"``) is the same scheme at 4-bit resolution:
``scale = max|x|/7``, codes in [-7, 7], packed two-nibbles-per-byte into an
int8 carrier of width ``d//2`` (element ``2j`` in the low nibble of byte
``j``, element ``2j+1`` in the high nibble). Unpacking is two arithmetic
shifts per byte, which the fused kernel performs in VMEM — the HBM stream
stays at 0.5 B/elem. Queries are never stored, so the query side of an int4
dot product keeps the int8 scheme: the MXU pass is still int8×int8→int32
(exact), only the *table* side carries 4-bit resolution.
"""
from __future__ import annotations

import jax.numpy as jnp

INT8_MAX = 127.0
INT4_MAX = 7.0


def quantize_rows(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``(..., d)`` float -> (codes ``(..., d)`` int8, scales ``(...,)`` f32).

    Symmetric per-row scaling to ±127. All-zero rows get scale 1.0 so their
    codes are exactly 0 and dequantization returns exact zeros (padded bank
    slots stay padding).
    """
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    # Multiply by the pre-rounded reciprocal instead of dividing by 127:
    # XLA strength-reduces constant divisions differently inside and outside
    # fused jits (1-ulp drift), and bank scales must be bit-identical
    # between the eager offline build and the jit'd upsert append.
    scales = jnp.where(
        amax > 0, amax * jnp.float32(1.0 / INT8_MAX), 1.0
    ).astype(jnp.float32)
    codes = jnp.clip(
        jnp.round(x / scales[..., None]), -INT8_MAX, INT8_MAX
    ).astype(jnp.int8)
    return codes, scales


def dequantize_rows(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_rows` (up to rounding): f32 rows."""
    return codes.astype(jnp.float32) * scales[..., None].astype(jnp.float32)


# ---------------------------------------------------------------------------
# int4: the same per-row symmetric scheme at 4-bit, packed 2 nibbles/byte
# ---------------------------------------------------------------------------


def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """``(..., d)`` int8 codes in [-8, 7] -> ``(..., d//2)`` packed int8.

    Byte ``j`` carries element ``2j`` in its low nibble and element ``2j+1``
    in its high nibble (two's-complement nibbles). ``d`` must be even.
    """
    if codes.shape[-1] % 2:
        raise ValueError(
            f"int4 packing needs an even row width, got d={codes.shape[-1]}"
        )
    lo = jnp.bitwise_and(codes[..., 0::2], jnp.int8(0x0F))
    hi = jnp.left_shift(codes[..., 1::2], 4).astype(jnp.int8)
    return jnp.bitwise_or(hi, lo).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """``(..., d//2)`` packed int8 -> ``(..., d)`` int8 codes in [-8, 7].

    Arithmetic shifts recover the signed nibbles: ``lo = (b << 4) >> 4``,
    ``hi = b >> 4`` (jnp right shifts are arithmetic on signed ints).
    Exact inverse of :func:`pack_int4`.
    """
    packed = packed.astype(jnp.int8)
    lo = jnp.right_shift(jnp.left_shift(packed, 4).astype(jnp.int8), 4)
    hi = jnp.right_shift(packed, 4)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def quantize_rows_int4(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``(..., d)`` float -> (packed codes ``(..., d//2)`` int8, scales f32).

    Per-row symmetric scaling to ±7 with the identical pre-rounded-reciprocal
    trick as :func:`quantize_rows` (``amax * float32(1/7)``), so the eager
    offline build and the jit'd upsert append quantize bit-identically.
    All-zero rows get scale 1.0 and pack to exact zero bytes.
    """
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scales = jnp.where(
        amax > 0, amax * jnp.float32(1.0 / INT4_MAX), 1.0
    ).astype(jnp.float32)
    codes = jnp.clip(
        jnp.round(x / scales[..., None]), -INT4_MAX, INT4_MAX
    ).astype(jnp.int8)
    return pack_int4(codes), scales


def dequantize_rows_int4(packed: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_rows_int4` (up to rounding): f32 rows."""
    return dequantize_rows(unpack_int4(packed), scales)


def dequantize_codes(
    codes: jnp.ndarray, scales: jnp.ndarray, code_dtype: str = "int8"
) -> jnp.ndarray:
    """Dequantize stored bank codes, dispatching on the code dtype.

    The one helper the fit paths (build / refit / compaction) call so they
    never need to know whether ``ClusterBank.embs`` holds int8 codes or
    packed int4 nibbles.
    """
    if code_dtype == "int4":
        return dequantize_rows_int4(codes, scales)
    return dequantize_rows(codes, scales)


# ---------------------------------------------------------------------------
# 1-bit binary sketches: the pre-filter tier below int4
# (DESIGN.md §Binary sketch tier)
# ---------------------------------------------------------------------------

SKETCH_WORD_BITS = 32


def sketch_width(d: int) -> int:
    """Packed words per row: ``ceil(d / 32)``."""
    return -(-d // SKETCH_WORD_BITS)


def sketch_rows(x: jnp.ndarray) -> jnp.ndarray:
    """``(..., d)`` float -> ``(..., ceil(d/32))`` uint32 sign sketches.

    Bit ``j`` of word ``w`` is ``x[..., w*32 + j] > 0`` (little-endian within
    the word). The strict ``> 0`` predicate makes all-zero rows — padded bank
    slots, tombstone-cleared rows, grow_bank zero-fill — pack to exact zero
    words, and rows past ``d`` (when ``d`` is not a multiple of 32) carry
    zero bits on both the table and the query side, so they contribute
    nothing to any XOR. Like the quantizers above, the sketch is *stateless
    per row*, which keeps incremental upsert byte-identical to a rebuild.
    """
    x = jnp.asarray(x)
    d = x.shape[-1]
    w = sketch_width(d)
    bits = (x > 0).astype(jnp.uint32)
    pad = w * SKETCH_WORD_BITS - d
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    bits = bits.reshape(*x.shape[:-1], w, SKETCH_WORD_BITS)
    weights = jnp.left_shift(
        jnp.uint32(1), jnp.arange(SKETCH_WORD_BITS, dtype=jnp.uint32)
    )
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def unpack_sketch(words: jnp.ndarray, d: int) -> jnp.ndarray:
    """``(..., ceil(d/32))`` uint32 -> ``(..., d)`` bool. Exact inverse of
    the bit extraction in :func:`sketch_rows` (round-trip tested over all
    bit patterns in tests/test_sketch.py)."""
    words = jnp.asarray(words, jnp.uint32)
    shifts = jnp.arange(SKETCH_WORD_BITS, dtype=jnp.uint32)
    bits = jnp.bitwise_and(
        jnp.right_shift(words[..., None], shifts), jnp.uint32(1)
    )
    return bits.reshape(*words.shape[:-1], -1)[..., :d].astype(bool)


def deinterleave_query_codes(q_codes: jnp.ndarray) -> jnp.ndarray:
    """Reorder query codes to match in-VMEM int4 unpacking.

    The fused kernel unpacks a packed block as ``concat([low_nibbles,
    high_nibbles], -1)`` — i.e. ``[x0, x2, ..., x1, x3, ...]`` — instead of
    re-interleaving along the minor axis (a lane-crossing shuffle the VPU
    would pay for). Deinterleaving the *query* outside the kernel makes the
    dot product exact against that layout: ``concat([q_even, q_odd], -1)``.
    """
    return jnp.concatenate([q_codes[..., 0::2], q_codes[..., 1::2]], axis=-1)

"""Pallas TPU kernels for LIDER's compute hot spots.

- ``lsh_hash``      — fused projection + sign + bit-pack (build & query hash)
- ``kmeans_assign`` — tiled distance + running argmin (Stage-1 Lloyd)
- ``fused_verify``  — gather-score-reduce candidate verification: scalar-
  prefetched ids steer double-buffered row DMAs, scores stay in VMEM, and a
  streaming dedup top-k is the only HBM output (DESIGN.md
  §Verification-kernel)

``ops`` holds the jit'd dispatchers (TPU -> kernel, CPU -> ``ref`` oracle);
``ref`` holds the pure-jnp oracles the tests sweep against.
"""
from .lsh_hash import lsh_hash
from .kmeans_assign import kmeans_assign
from .fused_verify import fused_verify, fused_verify_grouped
from . import ops, ref, schedule

__all__ = [
    "lsh_hash",
    "kmeans_assign",
    "fused_verify",
    "fused_verify_grouped",
    "ops",
    "ref",
    "schedule",
]

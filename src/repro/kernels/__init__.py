"""Pallas TPU kernels for LIDER's compute hot spots.

- ``lsh_hash``      — fused projection + sign + bit-pack (build & query hash)
- ``kmeans_assign`` — tiled distance + running argmin (Stage-1 Lloyd)
- ``score_gather``  — scalar-prefetch gather + dot (candidate verification)

``ops`` holds the jit'd dispatchers (TPU -> kernel, CPU -> ``ref`` oracle);
``ref`` holds the pure-jnp oracles the tests sweep against.
"""
from .lsh_hash import lsh_hash
from .kmeans_assign import kmeans_assign
from .score_gather import score_gather
from . import ops, ref

__all__ = ["lsh_hash", "kmeans_assign", "score_gather", "ops", "ref"]

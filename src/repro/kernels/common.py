"""Backend-dispatch policy shared by every kernel and the op wrappers."""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> compile on TPU, interpret elsewhere (the kernels are TPU
    targets; off-TPU they only run for validation)."""
    if interpret is None:
        return not on_tpu()
    return interpret

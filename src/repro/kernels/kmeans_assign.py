"""Fused k-means assignment kernel: tiled distances + running argmin.

Stage-1 of the LIDER build runs Lloyd iterations over the full corpus; the
assignment step naively writes an (N, c) distance matrix to HBM (MS-8.8M at
c=1000: 35 GB per iteration). This kernel streams centroid tiles against a
VMEM-resident point tile and keeps only the running (best distance, best id)
pair — HBM traffic drops to reading X and C once plus writing 8 bytes/point.

Grid is (N tiles, c tiles) with the c axis innermost ("arbitrary" semantics:
the output block for row-tile i is revisited across j, accumulating the
running min — the standard Pallas reduction idiom).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import resolve_interpret

_F32_MAX = 3.4e38  # python float: jnp scalars would be captured consts


def _kmeans_assign_kernel(x_ref, c_ref, best_d_ref, best_i_ref, *, block_c: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_d_ref[...] = jnp.full(best_d_ref.shape, _F32_MAX, jnp.float32)
        best_i_ref[...] = jnp.zeros(best_i_ref.shape, jnp.int32)

    x = x_ref[...].astype(jnp.float32)  # (block_n, d)
    c = c_ref[...].astype(jnp.float32)  # (block_c, d)
    x_sq = jnp.sum(x * x, axis=-1, keepdims=True)  # (block_n, 1)
    c_sq = jnp.sum(c * c, axis=-1)  # (block_c,)
    d2 = x_sq - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32) + c_sq

    local_i = jnp.argmin(d2, axis=-1).astype(jnp.int32)  # (block_n,)
    local_d = jnp.min(d2, axis=-1)
    global_i = local_i + j * block_c

    prev_d = best_d_ref[...][:, 0]
    prev_i = best_i_ref[...][:, 0]
    better = local_d < prev_d
    best_d_ref[...] = jnp.where(better, local_d, prev_d)[:, None]
    best_i_ref[...] = jnp.where(better, global_i, prev_i)[:, None]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_c", "interpret")
)
def kmeans_assign(
    x: jnp.ndarray,
    centroids: jnp.ndarray,
    *,
    block_n: int = 512,
    block_c: int = 128,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(N, d), (c, d) -> (assignment (N,) int32, min squared-L2 (N,) f32).

    ``interpret=None`` resolves to "not on TPU" (matching ``kernels/ops.py``)
    so direct calls compile on TPU instead of silently interpreting.
    """
    interpret = resolve_interpret(interpret)
    n, d = x.shape
    c = centroids.shape[0]
    block_n = min(block_n, max(8, n))
    block_c = min(block_c, max(8, c))
    pad_n = (-n) % block_n
    pad_c = (-c) % block_c
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    if pad_c:
        # Padded centroids at +inf distance: fill with a huge coordinate.
        centroids = jnp.pad(
            centroids, ((0, pad_c), (0, 0)), constant_values=1e18
        )
    grid = (x.shape[0] // block_n, centroids.shape[0] // block_c)

    best_d, best_i = pl.pallas_call(
        functools.partial(_kmeans_assign_kernel, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((x.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, centroids)
    return best_i[:n, 0], best_d[:n, 0]

"""Fused LSH hashing kernel: matmul + sign + bit-pack in one VMEM pass.

Hashing the corpus is LIDER's build-time hot spot and the first step of every
query: ``bits = sign(X @ P)`` packed big-endian into uint32. Done naively XLA
materialises the (N, H*M) float projection tensor in HBM (for MS-8.8M at
H=10, M=24: 8.4 GB written + re-read). This kernel tiles N into VMEM-resident
blocks, keeps the projection bank resident (d*H*M*4 B — ~1 MB at paper
scales), and writes only the (N, H) uint32 keys back: a ~(32*M)x reduction in
HBM write traffic for the pack stage.

TPU notes: the matmul tile (block_n x d)@(d x HM) feeds the MXU; pick
``block_n`` a multiple of 8 (f32 sublane) and pad HM to a lane multiple for
peak efficiency — correctness does not depend on it (compiler pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import resolve_interpret


def _lsh_hash_kernel(x_ref, proj_ref, out_ref, *, n_arrays: int, key_len: int):
    x = x_ref[...].astype(jnp.float32)  # (block_n, d)
    proj = proj_ref[...].astype(jnp.float32)  # (d, H*M)
    acc = jnp.dot(x, proj, preferred_element_type=jnp.float32)
    bits = (acc >= 0.0).astype(jnp.uint32)  # (block_n, H*M)
    bits = bits.reshape(x.shape[0], n_arrays, key_len)
    # big-endian weights 2**(M-1-i), built with iota (no captured constants)
    pos = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, key_len), 2)
    weights = jnp.uint32(1) << (jnp.uint32(key_len - 1) - pos)
    out_ref[...] = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


@functools.partial(
    jax.jit, static_argnames=("n_arrays", "key_len", "block_n", "interpret")
)
def lsh_hash(
    x: jnp.ndarray,
    proj: jnp.ndarray,
    *,
    n_arrays: int,
    key_len: int,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(N, d) float x (d, H*M) float -> (N, H) uint32 packed hashkeys.

    ``interpret=None`` resolves to "not on TPU" (matching ``kernels/ops.py``)
    so direct calls compile on TPU instead of silently interpreting.
    """
    interpret = resolve_interpret(interpret)
    n, d = x.shape
    hm = proj.shape[1]
    assert hm == n_arrays * key_len
    block_n = min(block_n, max(8, n))
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (x.shape[0] // block_n,)

    out = pl.pallas_call(
        functools.partial(_lsh_hash_kernel, n_arrays=n_arrays, key_len=key_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((d, hm), lambda i: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec(
            (block_n, n_arrays), lambda i: (i, 0), memory_space=pltpu.VMEM
        ),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], n_arrays), jnp.uint32),
        interpret=interpret,
    )(x, proj)
    return out[:n]

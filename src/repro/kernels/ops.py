"""Jit'd dispatch wrappers for the Pallas kernels.

``use_pallas=None`` (default) picks the Pallas path on TPU and the pure-jnp
reference on CPU/GPU — the kernels are *TPU targets*; on CPU they are only
executed for validation via ``interpret=True`` (tests do this explicitly).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .common import on_tpu as _on_tpu
from .fused_verify import fused_verify, fused_verify_grouped, sketch_prefilter
from .kmeans_assign import kmeans_assign
from .lsh_hash import lsh_hash


def lsh_hash_op(
    x: jnp.ndarray,
    proj: jnp.ndarray,
    *,
    n_arrays: int,
    key_len: int,
    use_pallas: bool | None = None,
) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return lsh_hash(
            x, proj, n_arrays=n_arrays, key_len=key_len, interpret=not _on_tpu()
        )
    return ref.lsh_hash_ref(x, proj, n_arrays, key_len)


def kmeans_assign_op(
    x: jnp.ndarray, centroids: jnp.ndarray, *, use_pallas: bool | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kmeans_assign(x, centroids, interpret=not _on_tpu())
    return ref.kmeans_assign_ref(x, centroids)


def verify_topk_op(
    embs: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
    scales: jnp.ndarray | None = None,
    block_c: int | None = None,
    code_dtype: str = "int8",
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate verification -> deduplicated top-k, (B, k) ids + scores.

    Pallas: single VMEM-resident gather-score-reduce pass (``fused_verify``),
    which additionally *skips* blocks whose candidates are all invalid —
    pruned probes cost no DMA or MXU time (DESIGN.md §Adaptive). Reference:
    materialize-then-einsum (``ref.verify_topk_ref``). Both share exact
    semantics — dedup by ``out_ids`` (< 0 == padding), descending scores,
    (-1, -inf) fill past the unique-valid count.

    ``scales`` ((N,) f32) marks ``embs`` as a quantized code table with
    per-row symmetric scales; both paths then score int8×int8→int32 with
    the combined scale folded in afterwards (DESIGN.md §Quantized bank).
    ``code_dtype="int4"`` marks the table as *packed* int4 (width d//2;
    unpacked in VMEM by the kernel / on gather by the reference).
    ``block_c`` is the kernel's candidate-block size (None -> the kernel
    default) — a tunable the Pareto autotuner sweeps.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return fused_verify(
            embs,
            row_ids,
            queries,
            k=k,
            out_ids=out_ids,
            scales=scales,
            block_c=block_c if block_c is not None else 256,
            code_dtype=code_dtype,
            interpret=not _on_tpu(),
        )
    return ref.verify_topk_ref(
        embs,
        row_ids,
        queries,
        k=k,
        out_ids=out_ids,
        scales=scales,
        code_dtype=code_dtype,
    )


def sketch_topk_op(
    sketches: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
    block_c: int | None = None,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Binary-sketch pre-filter -> deduplicated top-k survivor rows.

    Pallas: ``sketch_prefilter`` — the 1-bit Hamming pass (XOR + popcount in
    VMEM, 1/8 the int8 row bytes, dead blocks skipped). Reference:
    ``ref.sketch_topk_ref`` (natural-order Hamming). Scores are the negated
    Hamming distance as f32 (exact — Hamming <= d < 2^24), so dedup/top-k
    semantics, padding, and the smallest-id tie-break match ``verify_topk_op``
    and the survivors slot straight into the int4/int8 pass as its
    ``row_ids``/``out_ids`` (DESIGN.md §Binary sketch tier).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return sketch_prefilter(
            sketches,
            row_ids,
            queries,
            k=k,
            out_ids=out_ids,
            block_c=block_c if block_c is not None else 256,
            interpret=not _on_tpu(),
        )
    return ref.sketch_topk_ref(
        sketches, row_ids, queries, k=k, out_ids=out_ids
    )


def verify_topk_grouped_op(
    embs: jnp.ndarray,
    row_scales: jnp.ndarray,
    queries: jnp.ndarray,
    sched_cids: jnp.ndarray,
    sched_qids: jnp.ndarray,
    step_slot_ids: jnp.ndarray,
    *,
    kp: int,
    block_q: int,
    block_c: int | None = None,
    code_dtype: str = "int8",
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Cluster-major verification -> per-(step, slot) dedup top-k'.

    Pallas: ``fused_verify_grouped`` — each grid step streams ONE cluster's
    rows once and scores them against a ``block_q`` query tile, so queries
    probing the same cluster share its DMA (DESIGN.md §Cluster-major
    schedule). Reference: ``ref.verify_topk_grouped_ref``. The schedule
    arrays come from ``schedule.build_cluster_schedule``; quantized banks
    only (``row_scales`` required, ``code_dtype`` int8/int4).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return fused_verify_grouped(
            embs,
            row_scales,
            queries,
            sched_cids,
            sched_qids,
            step_slot_ids,
            kp=kp,
            block_q=block_q,
            block_c=block_c if block_c is not None else 256,
            code_dtype=code_dtype,
            interpret=not _on_tpu(),
        )
    return ref.verify_topk_grouped_ref(
        embs,
        row_scales,
        queries,
        sched_cids,
        sched_qids,
        step_slot_ids,
        kp=kp,
        code_dtype=code_dtype,
    )

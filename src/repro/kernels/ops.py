"""Jit'd dispatch wrappers for the Pallas kernels.

``use_pallas=None`` (default) picks the Pallas path on TPU and the pure-jnp
reference on CPU/GPU — the kernels are *TPU targets*; on CPU they are only
executed for validation via ``interpret=True`` (tests do this explicitly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .kmeans_assign import kmeans_assign
from .lsh_hash import lsh_hash
from .score_gather import score_gather


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lsh_hash_op(
    x: jnp.ndarray,
    proj: jnp.ndarray,
    *,
    n_arrays: int,
    key_len: int,
    use_pallas: bool | None = None,
) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return lsh_hash(
            x, proj, n_arrays=n_arrays, key_len=key_len, interpret=not _on_tpu()
        )
    return ref.lsh_hash_ref(x, proj, n_arrays, key_len)


def kmeans_assign_op(
    x: jnp.ndarray, centroids: jnp.ndarray, *, use_pallas: bool | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kmeans_assign(x, centroids, interpret=not _on_tpu())
    return ref.kmeans_assign_ref(x, centroids)


def score_gather_op(
    embs: jnp.ndarray,
    cand_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return score_gather(embs, cand_ids, queries, interpret=not _on_tpu())
    return ref.score_gather_ref(embs, cand_ids, queries)

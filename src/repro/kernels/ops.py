"""Jit'd dispatch wrappers for the Pallas kernels.

``use_pallas=None`` (default) picks the Pallas path on TPU and the pure-jnp
reference on CPU/GPU — the kernels are *TPU targets*; on CPU they are only
executed for validation via ``interpret=True`` (tests do this explicitly).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .common import on_tpu as _on_tpu
from .fused_verify import fused_verify
from .kmeans_assign import kmeans_assign
from .lsh_hash import lsh_hash


def lsh_hash_op(
    x: jnp.ndarray,
    proj: jnp.ndarray,
    *,
    n_arrays: int,
    key_len: int,
    use_pallas: bool | None = None,
) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return lsh_hash(
            x, proj, n_arrays=n_arrays, key_len=key_len, interpret=not _on_tpu()
        )
    return ref.lsh_hash_ref(x, proj, n_arrays, key_len)


def kmeans_assign_op(
    x: jnp.ndarray, centroids: jnp.ndarray, *, use_pallas: bool | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kmeans_assign(x, centroids, interpret=not _on_tpu())
    return ref.kmeans_assign_ref(x, centroids)


def verify_topk_op(
    embs: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
    scales: jnp.ndarray | None = None,
    block_c: int | None = None,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate verification -> deduplicated top-k, (B, k) ids + scores.

    Pallas: single VMEM-resident gather-score-reduce pass (``fused_verify``),
    which additionally *skips* blocks whose candidates are all invalid —
    pruned probes cost no DMA or MXU time (DESIGN.md §Adaptive). Reference:
    materialize-then-einsum (``ref.verify_topk_ref``). Both share exact
    semantics — dedup by ``out_ids`` (< 0 == padding), descending scores,
    (-1, -inf) fill past the unique-valid count.

    ``scales`` ((N,) f32) marks ``embs`` as an int8 code table with per-row
    symmetric scales; both paths then score int8×int8→int32 with the
    combined scale folded in afterwards (DESIGN.md §Quantized bank).
    ``block_c`` is the kernel's candidate-block size (None -> the kernel
    default) — a tunable the Pareto autotuner sweeps.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return fused_verify(
            embs,
            row_ids,
            queries,
            k=k,
            out_ids=out_ids,
            scales=scales,
            block_c=block_c if block_c is not None else 256,
            interpret=not _on_tpu(),
        )
    return ref.verify_topk_ref(
        embs, row_ids, queries, k=k, out_ids=out_ids, scales=scales
    )

"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each ``*_ref`` is the mathematically transparent version of the kernel with
identical signature and semantics; tests sweep shapes/dtypes and assert the
kernels (interpret mode on CPU, compiled on TPU) match these exactly
(integer outputs) or to fp tolerance (scores).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lsh_hash_ref(
    x: jnp.ndarray, proj: jnp.ndarray, n_arrays: int, key_len: int
) -> jnp.ndarray:
    """(N, d) x (d, H*M) -> (N, H) packed big-endian uint32 hashkeys."""
    acc = x.astype(jnp.float32) @ proj.astype(jnp.float32)
    bits = (acc >= 0.0).astype(jnp.uint32)
    bits = bits.reshape(x.shape[0], n_arrays, key_len)
    weights = (jnp.uint32(1) << jnp.arange(key_len - 1, -1, -1, dtype=jnp.uint32))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def kmeans_assign_ref(
    x: jnp.ndarray, centroids: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(N, d), (c, d) -> (assignment (N,) int32, min squared-L2 (N,) f32)."""
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, -1, keepdims=True)
        - 2.0 * x @ c.T
        + jnp.sum(c * c, -1)[None, :]
    )
    return jnp.argmin(d2, -1).astype(jnp.int32), jnp.min(d2, -1)


def verify_topk_ref(
    embs: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
    scales: jnp.ndarray | None = None,
    code_dtype: str = "int8",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize-then-einsum verification: the oracle for ``fused_verify``.

    Gathers a (B, C, d) candidate tensor, scores it (storage-dtype MXU
    inputs, fp32 accumulation — identical math to the fused kernel), then
    dedup-top-ks by ``out_ids`` (default ``row_ids``; < 0 marks padding).
    This is exactly the HBM-materialized path the fused kernel replaces, so
    it doubles as the unfused baseline in benchmarks/kernel_verify.py.

    With ``scales`` set, ``embs`` is an int8 code table with per-row
    symmetric scales (DESIGN.md §Quantized bank): queries are quantized with
    the same ``quant.quantize_rows`` scheme the kernel wrapper uses, scoring
    is exact int8×int8→int32, and the combined per-candidate scale
    (row × query) is folded in as a single f32 multiply — the identical op
    sequence to the fused kernel's quantized path, so ids match exactly.

    ``code_dtype="int4"`` (with ``scales``): ``embs`` is a packed int4 table
    (width d//2); candidates are unpacked to int8 here in natural element
    order — int32 accumulation is exact regardless of summation order, so
    this matches the kernel's deinterleaved in-VMEM unpack bit-for-bit.

    Block-skip semantics mirror: the fused kernel skips blocks whose
    candidates are all invalid (adaptive probe pruning); here they are
    simply scored -inf — the outputs are bit-identical, including the
    all-candidates-invalid row, which returns all (-1, -inf).
    """
    from ..core.utils import NEG_INF, dedup_topk
    from .quant import quantize_rows, unpack_int4

    if out_ids is None:
        out_ids = row_ids
    safe = jnp.maximum(row_ids, 0)
    cand = embs[safe]  # (B, C, d) — the materialization being eliminated
    if scales is not None and code_dtype == "int4":
        cand = unpack_int4(cand)
    if scales is None:
        scores = jnp.einsum(
            "bcd,bd->bc",
            cand,
            queries.astype(cand.dtype),
            preferred_element_type=jnp.float32,
        )
    else:
        q_codes, q_scales = quantize_rows(queries)
        int_scores = jnp.einsum(
            "bcd,bd->bc", cand, q_codes, preferred_element_type=jnp.int32
        )
        comb = scales[safe].astype(jnp.float32) * q_scales[:, None]
        scores = int_scores.astype(jnp.float32) * comb
    scores = jnp.where(out_ids < 0, NEG_INF, scores)
    return dedup_topk(out_ids, scores, k)


def sketch_topk_ref(
    sketches: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Natural-order Hamming oracle for the binary-sketch pre-filter
    (``sketch_prefilter``; DESIGN.md §Binary sketch tier).

    ``sketches`` is the packed ``(N, ceil(d/32))`` uint32 sign-sketch table;
    queries are sketched here with the same ``quant.sketch_rows`` packer the
    kernel wrapper uses. The score is the *negated* Hamming distance between
    the row and query sketches — XOR + popcount summed over the words, cast
    to f32 (exact: Hamming <= d < 2^24) so the shared dedup/top-k merge and
    its smallest-id tie-break apply unchanged. Popcount over uint32 words is
    order-independent, so this natural-order sum matches the kernel's
    in-VMEM reduction bit-for-bit.
    """
    from ..core.utils import NEG_INF, dedup_topk
    from .quant import sketch_rows

    if out_ids is None:
        out_ids = row_ids
    safe = jnp.maximum(row_ids, 0)
    cand = sketches[safe]  # (B, C, w)
    q_sk = sketch_rows(queries)  # (B, w)
    x = jnp.bitwise_xor(cand, q_sk[:, None, :])
    ham = jnp.sum(
        jax.lax.population_count(x).astype(jnp.int32), axis=-1
    )  # (B, C)
    scores = jnp.where(out_ids < 0, NEG_INF, -ham.astype(jnp.float32))
    return dedup_topk(out_ids, scores, k)


def verify_topk_grouped_ref(
    embs: jnp.ndarray,
    row_scales: jnp.ndarray,
    queries: jnp.ndarray,
    sched_cids: jnp.ndarray,
    sched_qids: jnp.ndarray,
    step_slot_ids: jnp.ndarray,
    *,
    kp: int,
    code_dtype: str = "int8",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Materialized oracle for ``fused_verify_grouped`` (identical signature
    semantics; see that docstring for the schedule-array contract).

    Gathers each step's whole cluster ``(S, Lp, d)``, scores it against the
    step's query tile with exact int8×int8→int32 accumulation, folds the
    (query × row) scale product, masks non-candidates via ``step_slot_ids``,
    and dedup-top-k's each (step, slot) stream — the same math in
    materialized form, so ids AND scores match the kernel bit-for-bit.
    """
    from ..core.utils import NEG_INF, dedup_topk
    from .quant import quantize_rows, unpack_int4

    c = embs.shape[0]
    s_steps, block_q, lp = step_slot_ids.shape
    safe_c = jnp.clip(sched_cids, 0, c - 1)
    rows = embs[safe_c]  # (S, Lp, d_store)
    if code_dtype == "int4":
        rows = unpack_int4(rows)
    q_codes, q_scales = quantize_rows(queries)
    safe_q = jnp.maximum(sched_qids, 0)
    qt = q_codes[safe_q]  # (S, block_q, d) — natural order; int32 dot exact
    qscl = jnp.where(sched_qids >= 0, q_scales[safe_q], 1.0).astype(jnp.float32)
    int_scores = jnp.einsum(
        "sqd,sld->sql", qt, rows, preferred_element_type=jnp.int32
    )
    comb = qscl[:, :, None] * row_scales[safe_c][:, None, :].astype(jnp.float32)
    scores = int_scores.astype(jnp.float32) * comb
    scores = jnp.where(step_slot_ids >= 0, scores, NEG_INF)
    ids, scores = dedup_topk(
        step_slot_ids.reshape(s_steps * block_q, lp),
        scores.reshape(s_steps * block_q, lp),
        kp,
    )
    return (
        ids.reshape(s_steps, block_q, kp),
        scores.reshape(s_steps, block_q, kp),
    )

"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each ``*_ref`` is the mathematically transparent version of the kernel with
identical signature and semantics; tests sweep shapes/dtypes and assert the
kernels (interpret mode on CPU, compiled on TPU) match these exactly
(integer outputs) or to fp tolerance (scores).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lsh_hash_ref(
    x: jnp.ndarray, proj: jnp.ndarray, n_arrays: int, key_len: int
) -> jnp.ndarray:
    """(N, d) x (d, H*M) -> (N, H) packed big-endian uint32 hashkeys."""
    acc = x.astype(jnp.float32) @ proj.astype(jnp.float32)
    bits = (acc >= 0.0).astype(jnp.uint32)
    bits = bits.reshape(x.shape[0], n_arrays, key_len)
    weights = (jnp.uint32(1) << jnp.arange(key_len - 1, -1, -1, dtype=jnp.uint32))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def kmeans_assign_ref(
    x: jnp.ndarray, centroids: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(N, d), (c, d) -> (assignment (N,) int32, min squared-L2 (N,) f32)."""
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, -1, keepdims=True)
        - 2.0 * x @ c.T
        + jnp.sum(c * c, -1)[None, :]
    )
    return jnp.argmin(d2, -1).astype(jnp.int32), jnp.min(d2, -1)


def verify_topk_ref(
    embs: jnp.ndarray,
    row_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    out_ids: jnp.ndarray | None = None,
    scales: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize-then-einsum verification: the oracle for ``fused_verify``.

    Gathers a (B, C, d) candidate tensor, scores it (storage-dtype MXU
    inputs, fp32 accumulation — identical math to the fused kernel), then
    dedup-top-ks by ``out_ids`` (default ``row_ids``; < 0 marks padding).
    This is exactly the HBM-materialized path the fused kernel replaces, so
    it doubles as the unfused baseline in benchmarks/kernel_verify.py.

    With ``scales`` set, ``embs`` is an int8 code table with per-row
    symmetric scales (DESIGN.md §Quantized bank): queries are quantized with
    the same ``quant.quantize_rows`` scheme the kernel wrapper uses, scoring
    is exact int8×int8→int32, and the combined per-candidate scale
    (row × query) is folded in as a single f32 multiply — the identical op
    sequence to the fused kernel's quantized path, so ids match exactly.

    Block-skip semantics mirror: the fused kernel skips blocks whose
    candidates are all invalid (adaptive probe pruning); here they are
    simply scored -inf — the outputs are bit-identical, including the
    all-candidates-invalid row, which returns all (-1, -inf).
    """
    from ..core.utils import NEG_INF, dedup_topk
    from .quant import quantize_rows

    if out_ids is None:
        out_ids = row_ids
    safe = jnp.maximum(row_ids, 0)
    cand = embs[safe]  # (B, C, d) — the materialization being eliminated
    if scales is None:
        scores = jnp.einsum(
            "bcd,bd->bc",
            cand,
            queries.astype(cand.dtype),
            preferred_element_type=jnp.float32,
        )
    else:
        q_codes, q_scales = quantize_rows(queries)
        int_scores = jnp.einsum(
            "bcd,bd->bc", cand, q_codes, preferred_element_type=jnp.int32
        )
        comb = scales[safe].astype(jnp.float32) * q_scales[:, None]
        scores = int_scores.astype(jnp.float32) * comb
    scores = jnp.where(out_ids < 0, NEG_INF, scores)
    return dedup_topk(out_ids, scores, k)

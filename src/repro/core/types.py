"""Small pytree/dataclass helpers shared across the core library.

Every parameter container in repro is a frozen dataclass registered as a JAX
pytree via :func:`jax.tree_util.register_dataclass`, with static (non-array)
configuration split into ``meta_fields`` so jit caches key on them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Type, TypeVar

import jax

T = TypeVar("T")


def pytree_dataclass(cls: Type[T] | None = None, *, meta_fields: tuple = ()) -> Any:
    """Decorator: frozen dataclass registered as a pytree.

    ``meta_fields`` are treated as static aux data (ints, tuples, strings);
    everything else is a child (arrays / nested pytrees).
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        fields = [f.name for f in dataclasses.fields(c)]
        data_fields = tuple(f for f in fields if f not in meta_fields)
        jax.tree_util.register_dataclass(
            c, data_fields=list(data_fields), meta_fields=list(meta_fields)
        )
        return c

    if cls is None:
        return wrap
    return wrap(cls)


def replace(obj: T, **kwargs) -> T:
    """dataclasses.replace that works through the pytree registration."""
    return dataclasses.replace(obj, **kwargs)

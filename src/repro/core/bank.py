"""ClusterBank: the stacked per-cluster index state + staged build primitives.

LIDER's layer-2 state (one in-cluster retriever per cluster, stacked into
dense padded tensors — DESIGN.md §1/§2) used to live as seven loose fields on
``LiderParams``. This module makes it a first-class pytree so the build, the
incremental-update path (``core.update``), checkpointing, and the distributed
partition-spec derivation all share one structure:

    sorted_keys  (c, H, Lp) uint32   per-cluster sorted hashkey arrays
    sorted_pos   (c, H, Lp) int32    sorted position -> cluster-local row (-1 = pad/dead)
    embs         (c, Lp, d)          embeddings grouped by cluster (zero at pads)
    gids         (c, Lp)    int32    cluster-local row -> global id (-1 = free/tombstone)
    sizes        (c,)       int32    live rows per cluster
    tombstones   (c,)       int32    dead rows awaiting compaction
    next_gid     ()         int32    next global passage id to assign

Each dataclass field carries ``cluster_axis`` metadata: 0 for tensors whose
leading axis is the cluster axis (sharded over the cluster mesh axes by
``core.distributed``), ``None`` for replicated state (the shared LSH bank and
scalar bank metadata). ``core.distributed.lider_param_specs`` derives its
PartitionSpecs from this metadata instead of a hard-coded name list.

Build is staged (paper Sec. 3.3.2 Stage 3, decomposed):

    assign (k-means / nearest-centroid)  ->  pack (capacity slots)
        ->  hash + sort + fit, one cluster at a time: :func:`refit_cluster`

Full build is just ``vmap(refit_cluster)`` over all clusters
(:func:`build_bank`); incremental maintenance (``core.update``) re-runs the
*same* ``refit_cluster`` on only the dirty clusters — there is no separate
"online" fitting code path to drift from the offline one.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import clustering, lsh as lsh_lib, rescale as rescale_lib, rmi as rmi_lib
from .. import faults
from ..kernels.quant import (
    dequantize_codes,
    dequantize_rows,
    quantize_rows,
    quantize_rows_int4,
    sketch_rows,
)
from .types import pytree_dataclass

# dataclasses.field metadata key: leading cluster axis (int) or None for
# replicated leaves. core.distributed reads this to build PartitionSpecs.
CLUSTER_AXIS = "cluster_axis"

# Supported embedding storage dtypes (LiderConfig.storage_dtype). The
# quantized dtypes ("int8", and "int4" — packed two-nibbles-per-byte in an
# int8 carrier of width d//2) additionally populate ``emb_scales`` +
# ``rescore_embs`` (DESIGN.md §Quantized bank).
STORAGE_DTYPES = ("float32", "bfloat16", "int8", "int4")

# The quantized subset: storage dtypes that carry per-row scales + an exact
# rescore table and run the two-stage compressed-first search.
QUANTIZED_DTYPES = ("int8", "int4")

# Where the full-precision rescore side table lives
# (LiderConfig.rescore_tier; DESIGN.md §Tiered embedding store).
RESCORE_TIERS = ("device", "host")


class EmbStore:
    """Tiered store for the full-precision rescore table.

    ``tier="device"``: a shape-only marker — the table is the
    ``ClusterBank.rescore_embs`` pytree leaf and travels through jit/sharding
    like any other device array (the PR-4 layout).

    ``tier="host"``: the table lives HERE, as a process-local contiguous
    ("pinned" in the DMA sense — page-aligned C-contiguous NumPy, the layout
    the runtime can transfer without staging) float32 array of shape
    ``(c, Lp, d)``, *outside* the jit pytree. The jit'd index then carries
    only codes + scales; search fetches the exact rows of the provisional
    top-k' with :meth:`fetch` (a host ``np.take``) and ships ``B·k'·d``
    floats H2D instead of keeping all ``c·Lp·d`` resident (DESIGN.md §Tiered
    embedding store). A synced copy of ``gids`` rides along so the
    distributed front-end can map flat rows to passage ids without touching
    the cluster-sharded device tables.

    The store is **mutable shared state**: the index lifecycle
    (``core.update``) writes both tiers in lockstep — content writes
    (``write_rows`` / ``compact_clusters``) mutate the table in place (like
    any in-place update store, retained pre-update snapshots observe them),
    while capacity growth is copy-on-grow (``grown``) because it changes the
    flat-row arithmetic old snapshots still use. ``version`` bumps on every
    host write so serving can track host-tier generations separately from
    device recompiles. Because
    it rides the ClusterBank pytree as *static* aux data, ``__eq__`` /
    ``__hash__`` key on (tier, shape, dtype) only — content mutation never
    invalidates a compiled search, and two same-shape indexes share one
    compilation (the host data never enters the traced program).

    A store constructed with ``rescore=None`` is *abstract* (shape/dtype
    accounting only — what the dry-run memory model uses); ``fetch`` and the
    write paths require a concrete one.
    """

    def __init__(
        self,
        tier: str,
        *,
        rescore: np.ndarray | None = None,
        shape: tuple[int, ...] | None = None,
        dtype=np.float32,
        gids: np.ndarray | None = None,
    ):
        if tier not in RESCORE_TIERS:
            raise ValueError(f"tier must be one of {RESCORE_TIERS}, got {tier!r}")
        if rescore is not None:
            rescore = np.ascontiguousarray(rescore, dtype=np.float32)
            if not rescore.flags.writeable:  # device_get hands back views
                rescore = rescore.copy()
            shape = rescore.shape
            dtype = rescore.dtype
        if shape is None:
            raise ValueError("EmbStore needs rescore rows or an explicit shape")
        self.tier = tier
        self.rescore = rescore
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        self.gids = None if gids is None else np.ascontiguousarray(gids, np.int32)
        self.version = 0  # bumped on every host-tier content write
        self._txn = None  # undo journal while a transaction is open

    # -- pytree aux-data contract: stable across content mutation ----------
    def _key(self):
        return (self.tier, self.shape, str(self.dtype))

    def __eq__(self, other):
        return isinstance(other, EmbStore) and self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    def __repr__(self):
        kind = "abstract" if self.rescore is None else f"v{self.version}"
        return f"EmbStore({self.tier}, {self.shape}, {self.dtype}, {kind})"

    @property
    def nbytes(self) -> int:
        return math.prod(self.shape) * self.dtype.itemsize

    def _concrete(self) -> np.ndarray:
        if self.rescore is None:
            raise ValueError("abstract EmbStore (shape only) has no rows to access")
        return self.rescore

    # -- host-tier access ---------------------------------------------------
    def fetch(self, rows: np.ndarray) -> np.ndarray:
        """Gather flat bank rows ``(..., )`` -> ``(..., d)`` float32.

        ``rows < 0`` (provisional padding) gather row 0; callers pass the
        row array as ``out_ids`` downstream, so padded gathers are never
        surfaced (same convention as the device-tier rescore gather).
        """
        faults.fire(faults.HOST_FETCH)
        rows = np.asarray(rows)
        table = self._concrete().reshape(-1, self.shape[-1])
        return table.take(np.maximum(rows, 0).reshape(-1), axis=0).reshape(
            rows.shape + (self.shape[-1],)
        )

    def take_gids(self, rows: np.ndarray) -> np.ndarray:
        """Map flat bank rows -> global passage ids via the synced gid copy."""
        rows = np.asarray(rows)
        if self.gids is None:
            raise ValueError("EmbStore has no synced gids (call sync_gids)")
        out = self.gids.reshape(-1).take(np.maximum(rows, 0).reshape(-1))
        return np.where(rows.reshape(-1) < 0, -1, out).reshape(rows.shape)

    # -- transactions -------------------------------------------------------
    # The index lifecycle mutates the host table IN PLACE (write_rows /
    # compact_clusters / sync_gids), so an exception mid-``update_fn`` leaves
    # a mixed-generation store. A transaction keeps an undo journal of
    # first-touch pre-images; ``rollback`` replays it in reverse, restoring
    # table bytes, the synced gid copy, and ``version`` exactly. Growth is
    # already copy-on-grow (``grown`` returns a NEW store), so rolling back
    # a grown update is just discarding the new params — the journal only
    # needs to cover in-place writes to *this* store.

    def begin_txn(self) -> None:
        """Open a transaction; subsequent in-place writes are journaled."""
        if self._txn is not None:
            raise RuntimeError("EmbStore transaction already open")
        self._txn = {
            "log": [],
            "gids": None if self.gids is None else self.gids.copy(),
            "version": self.version,
        }

    def commit(self) -> None:
        """Close the transaction, keeping all writes."""
        if self._txn is None:
            raise RuntimeError("no open EmbStore transaction")
        self._txn = None

    def rollback(self) -> None:
        """Undo every journaled write since ``begin_txn`` (reverse order)."""
        txn = self._txn
        if txn is None:
            raise RuntimeError("no open EmbStore transaction")
        table = None if self.rescore is None else self.rescore.reshape(
            -1, self.shape[-1]
        )
        for kind, key, old in reversed(txn["log"]):
            if kind == "rows":
                table[key] = old
            else:  # "clusters"
                self.rescore[key] = old
        self.gids = txn["gids"]
        self.version = txn["version"]
        self._txn = None

    @property
    def in_txn(self) -> bool:
        return self._txn is not None

    # -- host-tier lifecycle writes (lockstep with the device tier) ---------
    def sync_gids(self, gids: np.ndarray) -> None:
        self.gids = np.ascontiguousarray(gids, np.int32)

    def write_rows(self, flat_slots: np.ndarray, rows: np.ndarray) -> None:
        """Scatter ``rows`` at ``flat_slots``; out-of-range slots drop (the
        same ``mode="drop"`` contract as the device-tier append)."""
        table = self._concrete().reshape(-1, self.shape[-1])
        flat_slots = np.asarray(flat_slots).reshape(-1)
        rows = np.asarray(rows, np.float32).reshape(-1, self.shape[-1])
        keep = (flat_slots >= 0) & (flat_slots < table.shape[0])
        sel = flat_slots[keep]
        if self._txn is not None:
            self._txn["log"].append(("rows", sel.copy(), table[sel].copy()))
        table[sel] = rows[keep]
        self.version += 1
        # Fires AFTER the in-place mutation: models an update_fn crash that
        # leaves the host tier advanced while the device tier is not.
        faults.fire(faults.HOST_WRITE)

    def grown(self, new_capacity: int) -> "EmbStore":
        """A new store with the slot axis ``Lp`` grown (zeros, like the
        device pad). Copy-on-grow, NOT in place: growth changes the flat-row
        arithmetic (``cid*Lp + slot``), so mutating the shared store would
        silently corrupt fetches from any retained pre-growth bank snapshot
        — the new table is a fresh allocation anyway, so returning a new
        store costs nothing and keeps old snapshots consistent."""
        c, lp, d = self.shape
        if new_capacity < lp:
            raise ValueError(f"cannot shrink capacity {lp} -> {new_capacity}")
        if new_capacity == lp:
            return self
        gids = self.gids
        if gids is not None:
            # Pad the synced gid copy like the device pad (-1 = free slot)
            # so take_gids' flat-row arithmetic matches the grown table
            # immediately, not only after the next sync_gids.
            gids = np.pad(
                gids, ((0, 0), (0, new_capacity - lp)), constant_values=-1
            )
        out = EmbStore("host", shape=(c, new_capacity, d), dtype=self.dtype,
                       gids=gids)
        if self.rescore is not None:
            table = np.zeros((c, new_capacity, d), np.float32)
            table[:, :lp] = self.rescore
            out.rescore = table
        out.version = self.version + 1
        return out

    def compact_clusters(self, cids: np.ndarray, gid_rows: np.ndarray) -> None:
        """Mirror of ``update._compact_clusters`` for the host tier: stable
        repack of live rows to the slot prefix. ``gid_rows`` are the
        *pre-compaction* per-cluster gid rows (live = ``gid >= 0``)."""
        table = self._concrete()
        cids = np.asarray(cids)
        if self._txn is not None:
            self._txn["log"].append(("clusters", cids.copy(), table[cids].copy()))
        for cid, g in zip(cids, np.asarray(gid_rows)):
            order = np.argsort(g < 0, kind="stable")
            rows = table[cid][order]
            rows[g[order] < 0] = 0.0
            table[cid] = rows
        self.version += 1


def _f(cluster_axis: int | None, default=dataclasses.MISSING):
    return dataclasses.field(
        metadata={CLUSTER_AXIS: cluster_axis}, default=default
    )


@pytree_dataclass(meta_fields=("store", "code_dtype"))
class ClusterBank:
    lsh: lsh_lib.LSHParams = _f(None)  # shared across clusters (DESIGN.md §2)
    rescale: rescale_lib.RescaleParams = _f(0)  # leaves (c, H)
    rmi: rmi_lib.RMIParams = _f(0)  # leaves (c, H) / (c, H, W)
    sorted_keys: jnp.ndarray = _f(0)  # (c, H, Lp) uint32
    sorted_pos: jnp.ndarray = _f(0)  # (c, H, Lp) int32
    embs: jnp.ndarray = _f(0)  # (c, Lp, d) — storage dtype (d//2 for int4)
    gids: jnp.ndarray = _f(0)  # (c, Lp) int32
    sizes: jnp.ndarray = _f(0)  # (c,) int32 — live rows
    tombstones: jnp.ndarray = _f(0)  # (c,) int32 — dead rows awaiting compaction
    next_gid: jnp.ndarray = _f(None)  # () int32 — bank metadata, replicated
    # Quantized storage only (None otherwise): per-row symmetric scales and
    # the full-precision side table the exact-rescore pass gathers its
    # top-k' rows from (DESIGN.md §Quantized bank).
    emb_scales: jnp.ndarray | None = _f(0, default=None)  # (c, Lp) f32
    rescore_embs: jnp.ndarray | None = _f(0, default=None)  # (c, Lp, d)
    # 1-bit sign-sketch table (quantized storage only; DESIGN.md §Binary
    # sketch tier): per-row sign bits packed 32-per-word. The optional
    # pre-filter pass (LiderConfig.sketch_factor) Hamming-scores these at
    # 1/8 the int8 code bytes before the int4/int8 MXU pass. Built,
    # upserted, and compacted in lockstep with ``embs`` — the sketch is
    # row-local (sign of the raw row), like the quantizers.
    sketches: jnp.ndarray | None = _f(0, default=None)  # (c, Lp, ceil(d/32)) u32
    # Host-tier handle (DESIGN.md §Tiered embedding store). None = device
    # tier. Registered as *static* pytree aux data: the host table never
    # enters traced programs — the staged search fetches from it between its
    # two jit'd stages — and EmbStore hashes by (tier, shape, dtype), so
    # host-content writes never invalidate a compiled search.
    store: EmbStore | None = _f(None, default=None)
    # Code representation of ``embs`` when quantized: "int8" (one code per
    # byte) or "int4" (two nibbles per byte — embs width is d//2). Static
    # pytree aux data like ``store``: it selects a compiled kernel variant,
    # so two banks differing only here must not share a compilation.
    # Ignored (kept at the default) for float banks.
    code_dtype: str = _f(None, default="int8")

    @property
    def n_clusters(self) -> int:
        return self.gids.shape[0]

    @property
    def capacity(self) -> int:
        return self.gids.shape[1]

    @property
    def dim(self) -> int:
        """Embedding dimensionality d (NOT the stored row width — int4 packs
        two elements per stored byte, so ``embs.shape[-1]`` is d//2)."""
        if self.quantized and self.code_dtype == "int4":
            return self.embs.shape[-1] * 2
        return self.embs.shape[-1]

    @property
    def quantized(self) -> bool:
        return self.emb_scales is not None

    @property
    def storage_dtype(self) -> str:
        return self.code_dtype if self.quantized else str(self.embs.dtype)

    @property
    def rescore_tier(self) -> str:
        """Where the full-precision rescore table lives (§Tiered store)."""
        return "host" if self.store is not None else "device"

    def nbytes_by_tier(self) -> dict[str, int]:
        """Index bytes by storage tier: ``device`` (every pytree leaf — what
        must be HBM-resident to search) vs ``host`` (the off-device rescore
        table). The accounting the dry-run memory model and the memory
        benchmarks report; works on abstract (ShapeDtypeStruct) banks too.
        """
        device = sum(
            math.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
            for leaf in jax.tree_util.tree_leaves(self)
        )
        host = self.store.nbytes if self.store is not None else 0
        return {"device": int(device), "host": int(host)}

    def float_rows(self) -> jnp.ndarray:
        """(c, Lp, d) rows as first-pass verification scores them —
        dequantized codes for quantized storage, the stored rows otherwise.
        Convenience accessor for consumers/tests; the fit paths apply the
        same ``dequantize_codes`` to their gathered sub-banks (build_bank,
        update._refit_clusters, update._compact_clusters) rather than
        materializing the whole bank through here."""
        if self.quantized:
            return dequantize_codes(self.embs, self.emb_scales, self.code_dtype)
        return self.embs


def replicated_field_names() -> tuple[str, ...]:
    """Bank fields whose leaves are replicated (no cluster axis)."""
    return tuple(
        f.name
        for f in dataclasses.fields(ClusterBank)
        if f.metadata.get(CLUSTER_AXIS) is None
    )


# ---------------------------------------------------------------------------
# Staged build primitives
# ---------------------------------------------------------------------------


def fit_sorted_array(
    sorted_keys: jnp.ndarray, valid: jnp.ndarray, *, n_leaves: int
) -> tuple[rescale_lib.RescaleParams, rmi_lib.RMIParams]:
    """Fit re-scale stats + RMI on one sorted hashkey array ``(L,)``.

    The single learned-fit primitive shared by standalone core models, the
    full bank build, and incremental refits. ``valid`` masks padded slots
    (padding must sort last — the UINT32_PAD sentinel guarantees it).
    """
    resc = rescale_lib.fit_rescale(sorted_keys, valid)
    scaled = rescale_lib.rescale(resc, sorted_keys)
    r = rmi_lib.fit_rmi(scaled, valid.astype(jnp.float32), n_leaves=n_leaves)
    return resc, r


def refit_cluster(
    lsh: lsh_lib.LSHParams,
    row_embs: jnp.ndarray,
    row_valid: jnp.ndarray,
    *,
    n_leaves: int,
):
    """Hash + sort + fit ONE cluster from its packed embedding rows.

    ``row_embs``: (Lp, d); ``row_valid``: (Lp,) bool — live rows. Returns
    ``(sorted_keys (H, Lp), sorted_pos (H, Lp), rescale (H,), rmi (H,))``.
    The unit of both the offline build (``vmap`` over all clusters) and the
    online dirty-cluster refit (``core.update``).
    """
    keys = lsh_lib.hash_vectors(lsh, row_embs)  # (Lp, H)
    keys = lsh_lib.mask_padded(keys, row_valid[:, None]).T  # (H, Lp)
    sorted_keys, order = lsh_lib.sort_hashkeys(keys)
    sorted_pos = jnp.where(
        sorted_keys == jnp.uint32(lsh_lib.UINT32_PAD), -1, order
    ).astype(jnp.int32)
    resc, r = jax.vmap(partial(fit_sorted_array, n_leaves=n_leaves))(
        sorted_keys, sorted_pos >= 0
    )
    return sorted_keys, sorted_pos, resc, r


@partial(jax.jit, static_argnames=("n_leaves",))
def _fit_all_clusters(lsh, row_embs, row_valid, *, n_leaves):
    return jax.vmap(partial(refit_cluster, lsh, n_leaves=n_leaves))(
        row_embs, row_valid
    )


def gather_cluster_rows(embs: jnp.ndarray, gids: jnp.ndarray) -> jnp.ndarray:
    """Pack corpus rows into ``(c, Lp, d)`` per-cluster slots (zero at pads)."""
    valid = gids >= 0
    return embs[jnp.maximum(gids, 0)] * valid[..., None]


def store_rows(
    raw_rows: jnp.ndarray, storage_dtype: str
) -> tuple[
    jnp.ndarray, jnp.ndarray | None, jnp.ndarray | None, jnp.ndarray | None
]:
    """Raw packed float rows -> ``(embs, emb_scales, rescore_embs, sketches)``.

    The single conversion point from float rows to bank storage, shared by
    the offline build and the upsert append (so both quantize identically —
    the scheme is row-local, which is what keeps upsert slot-identical to a
    rebuild). For the quantized dtypes the raw rows are also kept as the
    full-precision rescore side table and additionally sign-sketched into the
    packed 1-bit pre-filter table (DESIGN.md §Binary sketch tier); zero
    (padded) rows quantize to exact zeros (int4 rows pack to exact zero
    bytes, sketches to exact zero words).
    """
    if storage_dtype == "int8":
        codes, scales = quantize_rows(raw_rows)
        return codes, scales, raw_rows, sketch_rows(raw_rows)
    if storage_dtype == "int4":
        codes, scales = quantize_rows_int4(raw_rows)
        return codes, scales, raw_rows, sketch_rows(raw_rows)
    if storage_dtype == "bfloat16":
        return raw_rows.astype(jnp.bfloat16), None, None, None
    if storage_dtype == "float32":
        return raw_rows.astype(jnp.float32), None, None, None
    raise ValueError(
        f"storage_dtype must be one of {STORAGE_DTYPES}, got {storage_dtype!r}"
    )


def set_rescore_tier(bank: ClusterBank, tier: str) -> ClusterBank:
    """Move the full-precision rescore table between storage tiers.

    ``device -> host``: the ``rescore_embs`` leaf leaves the pytree and
    becomes a process-local host array (the jit'd index shrinks to codes +
    scales). ``host -> device``: the inverse. Search results are
    bit-identical across the move (same rows, same kernel, same tie-break —
    tested in tests/test_tiered.py); only *where* the rows live changes.
    """
    if tier not in RESCORE_TIERS:
        raise ValueError(f"rescore_tier must be one of {RESCORE_TIERS}, got {tier!r}")
    if tier == bank.rescore_tier:
        return bank
    if not bank.quantized:
        raise ValueError(
            "rescore_tier='host' requires quantized (int8/int4) storage — "
            "float banks have no rescore side table to move off-device"
        )
    if tier == "host":
        store = EmbStore(
            "host",
            rescore=np.asarray(jax.device_get(bank.rescore_embs), np.float32),
            gids=np.asarray(jax.device_get(bank.gids)),
        )
        return dataclasses.replace(bank, rescore_embs=None, store=store)
    return dataclasses.replace(
        bank, rescore_embs=jnp.asarray(bank.store._concrete()), store=None
    )


class CapacityOverflowError(ValueError):
    """A pack dropped passages because ``capacity`` < max cluster size.

    Dropped passages never get a slot, so they are permanently unretrievable
    — silent data loss unless the caller explicitly opted in
    (``allow_drops=True``). ``n_dropped`` carries the count.
    """

    def __init__(self, n_dropped: int, capacity: int):
        self.n_dropped = n_dropped
        self.capacity = capacity
        super().__init__(
            f"capacity={capacity} drops {n_dropped} overflow passages "
            "(they become permanently unretrievable); raise capacity or "
            "pass allow_drops=True to accept the recall loss"
        )


def build_bank(
    rng: jax.Array,
    embs: jnp.ndarray,
    assignment: jnp.ndarray,
    *,
    n_clusters: int,
    capacity: int,
    n_arrays: int,
    key_len: int,
    n_leaves: int,
    allow_drops: bool = False,
    storage_dtype: str = "float32",
    rescore_tier: str = "device",
) -> tuple[ClusterBank, int]:
    """Stage-3 build: pack -> store -> hash/sort -> fit, all clusters at once.

    ``assignment`` is the Stage-1 point->cluster map; the fit itself is
    ``vmap(refit_cluster)``, so an incremental refit of a single cluster
    (``core.update``) runs byte-identical math.

    ``storage_dtype`` selects the embedding storage representation; the fit
    runs on the *storage-effective* rows (``ClusterBank.float_rows`` — e.g.
    dequantized int8), so an online refit reading rows back from the bank
    reproduces the offline fit bit-for-bit.

    Returns ``(bank, n_dropped)``. Packing into ``capacity`` slots drops
    per-cluster overflow; a lossy pack raises :class:`CapacityOverflowError`
    unless ``allow_drops=True`` (the count is always returned so callers can
    surface it either way).

    ``rescore_tier="host"`` (int8 only — DESIGN.md §Tiered embedding store)
    builds the full-precision rescore table as a process-local host array
    instead of a device-resident pytree leaf.
    """
    if rescore_tier not in RESCORE_TIERS:
        raise ValueError(
            f"rescore_tier must be one of {RESCORE_TIERS}, got {rescore_tier!r}"
        )
    if rescore_tier == "host" and storage_dtype not in QUANTIZED_DTYPES:
        raise ValueError(
            "rescore_tier='host' requires quantized storage "
            f"({QUANTIZED_DTYPES}) — float banks have no rescore side "
            "table to move off-device"
        )
    raw_sizes = jnp.bincount(assignment, length=n_clusters)
    n_dropped = int(
        jax.device_get(jnp.sum(jnp.maximum(raw_sizes - capacity, 0)))
    )
    if n_dropped and not allow_drops:
        raise CapacityOverflowError(n_dropped, capacity)
    gids, sizes = clustering.group_by_cluster(assignment, n_clusters, capacity)
    raw_rows = gather_cluster_rows(embs, gids)
    stored, emb_scales, rescore_embs, sketches = store_rows(
        raw_rows, storage_dtype
    )
    lsh = lsh_lib.make_lsh(rng, embs.shape[-1], n_arrays, key_len)
    fit_rows = (
        dequantize_codes(stored, emb_scales, storage_dtype)
        if emb_scales is not None
        else stored
    )
    sorted_keys, sorted_pos, resc, r = _fit_all_clusters(
        lsh, fit_rows, gids >= 0, n_leaves=n_leaves
    )
    store = None
    if rescore_tier == "host":
        store = EmbStore(
            "host",
            rescore=np.asarray(jax.device_get(rescore_embs), np.float32),
            gids=np.asarray(jax.device_get(gids)),
        )
        rescore_embs = None
    bank = ClusterBank(
        lsh=lsh,
        rescale=resc,
        rmi=r,
        sorted_keys=sorted_keys,
        sorted_pos=sorted_pos,
        embs=stored,
        gids=gids,
        sizes=sizes,
        tombstones=jnp.zeros((n_clusters,), jnp.int32),
        next_gid=jnp.int32(embs.shape[0]),
        emb_scales=emb_scales,
        rescore_embs=rescore_embs,
        sketches=sketches,
        store=store,
        code_dtype=storage_dtype if storage_dtype in QUANTIZED_DTYPES else "int8",
    )
    return bank, n_dropped


def grow_bank(bank: ClusterBank, new_capacity: int) -> ClusterBank:
    """Grow the per-cluster slot axis ``Lp`` to ``new_capacity``.

    Pads sorted arrays with the UINT32_PAD sentinel / -1 (padding sorts last,
    so sortedness and every fit statistic are preserved — no refit needed).
    Shapes change, so downstream jits recompile: callers batch growth in
    ``pad_multiple`` steps and serving recompiles only on this event
    (``RetrievalEngine.apply_updates``).
    """
    lp = bank.capacity
    if new_capacity < lp:
        raise ValueError(f"cannot shrink capacity {lp} -> {new_capacity}")
    if new_capacity == lp:
        return bank
    extra = new_capacity - lp
    if bank.store is not None:
        # Host tier grows in lockstep — copy-on-grow, so prior bank
        # snapshots keep a consistent (old-Lp) view of their store.
        bank = dataclasses.replace(bank, store=bank.store.grown(new_capacity))
    return dataclasses.replace(
        bank,
        sorted_keys=jnp.pad(
            bank.sorted_keys,
            ((0, 0), (0, 0), (0, extra)),
            constant_values=jnp.uint32(lsh_lib.UINT32_PAD),
        ),
        sorted_pos=jnp.pad(
            bank.sorted_pos, ((0, 0), (0, 0), (0, extra)), constant_values=-1
        ),
        embs=jnp.pad(bank.embs, ((0, 0), (0, extra), (0, 0))),
        gids=jnp.pad(bank.gids, ((0, 0), (0, extra)), constant_values=-1),
        # Pad scale 1.0, the all-zero-row convention, so grown slots
        # dequantize to exact zeros (same as a fresh pack's padding).
        emb_scales=(
            None
            if bank.emb_scales is None
            else jnp.pad(
                bank.emb_scales, ((0, 0), (0, extra)), constant_values=1.0
            )
        ),
        rescore_embs=(
            None
            if bank.rescore_embs is None
            else jnp.pad(bank.rescore_embs, ((0, 0), (0, extra), (0, 0)))
        ),
        # Zero words: exactly what sketch_rows packs for an all-zero row,
        # so grown slots match a fresh pack's padding byte-for-byte.
        sketches=(
            None
            if bank.sketches is None
            else jnp.pad(bank.sketches, ((0, 0), (0, extra), (0, 0)))
        ),
    )

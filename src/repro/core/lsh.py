"""ESK-LSH: extended SortingKeys-LSH for cosine similarity (paper Sec. 4).

A hashkey is ``M`` sign bits of random hyperplane projections (Charikar
random-projection LSH), packed big-endian into a ``uint32`` — the first
hash bit is the most significant bit, so *numeric order of the packed key ==
the SK-LSH lexicographic linear order*. A core model keeps ``H`` independent
sorted arrays (one per compound hash function).

The extended hashkey distance (paper Eq. 6/7)::

    dist_e(K1, K2) = KL(K1, K2) + KD_e(K1, K2) / 2**B

with ``KL`` the non-prefix length and ``KD_e`` the absolute difference of the
``B``-bit windows immediately after the common prefix, fixes the "low
resolution problem" of binary alphabets while preserving the linear order
(paper Lemmas 4.3/4.4 — property-tested in ``tests/test_lsh.py``).

TPU adaptation: hashing a corpus is a single fused ``X @ P`` matmul + sign +
bit-pack; the Pallas kernel ``repro.kernels.lsh_hash`` streams this without
materialising the ``(N, H*M)`` float tensor. Pure-jnp path below is the
oracle and the default on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import pytree_dataclass

# Sentinel key for padded slots (requires M <= 31). Python int, not a jnp
# scalar: module-level device constants would init the backend at import
# time and break the dry-run's XLA_FLAGS device-count override.
UINT32_PAD = 0xFFFFFFFF
MAX_KEY_LEN = 31


@pytree_dataclass(meta_fields=("n_arrays", "key_len"))
class LSHParams:
    """Bank of ``n_arrays`` compound hash functions of ``key_len`` bits each."""

    projections: jnp.ndarray  # (dim, n_arrays * key_len) float32
    n_arrays: int
    key_len: int


def make_lsh(key: jax.Array, dim: int, n_arrays: int, key_len: int) -> LSHParams:
    if not (1 <= key_len <= MAX_KEY_LEN):
        raise ValueError(f"key_len must be in [1, {MAX_KEY_LEN}], got {key_len}")
    proj = jax.random.normal(key, (dim, n_arrays * key_len), dtype=jnp.float32)
    return LSHParams(projections=proj, n_arrays=n_arrays, key_len=key_len)


def suggest_key_len(n_points: int) -> int:
    """Paper setting ``M = ceil(log2 N)``, clamped to the packable range."""
    import math

    return max(4, min(MAX_KEY_LEN, math.ceil(math.log2(max(2, n_points)))))


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack (..., M) {0,1} bits big-endian into uint32 compact keys."""
    m = bits.shape[-1]
    weights = (jnp.uint32(1) << jnp.arange(m - 1, -1, -1, dtype=jnp.uint32)).astype(
        jnp.uint32
    )
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(keys: jnp.ndarray, key_len: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`: (...,) uint32 -> (..., M) uint32 bits."""
    shifts = jnp.arange(key_len - 1, -1, -1, dtype=jnp.uint32)
    return (keys[..., None] >> shifts) & jnp.uint32(1)


def hash_vectors(params: LSHParams, x: jnp.ndarray) -> jnp.ndarray:
    """Hash (..., dim) vectors into (..., H) packed uint32 hashkeys."""
    proj = x.astype(jnp.float32) @ params.projections  # (..., H*M)
    bits = (proj >= 0.0).astype(jnp.uint32)
    bits = bits.reshape(*x.shape[:-1], params.n_arrays, params.key_len)
    return pack_bits(bits)


def mask_padded(keys: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Replace keys of padded/dead slots with the UINT32_PAD sentinel.

    The sentinel is the largest uint32, so masked slots sort to the end of
    every array — the invariant the bank build/refit and the rescale fit
    rely on (padding sorts last).
    """
    return jnp.where(valid, keys.astype(jnp.uint32), jnp.uint32(UINT32_PAD))


def _clz32(x: jnp.ndarray) -> jnp.ndarray:
    """Count leading zeros of uint32 (branchless smear + popcount)."""
    x = x.astype(jnp.uint32)
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return jnp.uint32(32) - jax.lax.population_count(x)


def common_prefix_len(k1: jnp.ndarray, k2: jnp.ndarray, key_len: int) -> jnp.ndarray:
    """Length of the common bit prefix of two compact keys (0..key_len)."""
    a1 = k1.astype(jnp.uint32) << (32 - key_len)
    a2 = k2.astype(jnp.uint32) << (32 - key_len)
    lead = _clz32(a1 ^ a2)
    return jnp.minimum(lead, jnp.uint32(key_len)).astype(jnp.int32)


def dist_e(
    k1: jnp.ndarray, k2: jnp.ndarray, key_len: int, window_bits: int = 8
) -> jnp.ndarray:
    """Extended hashkey distance (paper Eq. 7). Broadcasting elementwise.

    ``dist_e = KL + KD_e / 2**B`` where ``KD_e`` reads the ``B``-bit window
    right after the common prefix (zero-padded past the key end, matching the
    sub-sequence definition in Eq. 6 with C = 2**B).
    """
    b = int(window_bits)
    m = int(key_len)
    l = common_prefix_len(k1, k2, m)  # (..., ) int32
    kl = (m - l).astype(jnp.float32)
    a1 = k1.astype(jnp.uint32) << (32 - m)
    a2 = k2.astype(jnp.uint32) << (32 - m)
    shift = jnp.minimum(l, 31).astype(jnp.uint32)
    s1 = ((a1 << shift) >> jnp.uint32(32 - b)).astype(jnp.int32)
    s2 = ((a2 << shift) >> jnp.uint32(32 - b)).astype(jnp.int32)
    kd = jnp.where(l >= m, 0, jnp.abs(s1 - s2)).astype(jnp.float32)
    return kl + kd / float(2**b)


def sort_hashkeys(keys: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort one array of compact keys by the SK-LSH linear order.

    Returns ``(sorted_keys, order)`` where ``order[i]`` is the original index
    of the i-th sorted key. For packed big-endian binary keys the linear order
    is plain numeric order.
    """
    order = jnp.argsort(keys, axis=-1)
    return jnp.take_along_axis(keys, order, axis=-1), order


def query_position(sorted_keys: jnp.ndarray, qkey: jnp.ndarray) -> jnp.ndarray:
    """Exact insertion position of qkey in a sorted key array (binary search).

    Used by the SK-LSH baseline and by LIDER's optional "last-mile refine"
    (beyond-paper optimisation) — the paper's RMI replaces this lookup with a
    prediction.
    """
    return jnp.searchsorted(sorted_keys, qkey, side="left").astype(jnp.int32)

"""K-means clustering (paper Sec. 3.2 — LIDER Stage 1).

Lloyd's algorithm in pure JAX. The assignment step is chunked over points so
the (N, c) distance matrix never materialises (N-chunk x c tiles stay in
cache/VMEM); on TPU the fused ``repro.kernels.kmeans_assign`` Pallas kernel
implements the same tile as matmul + running argmin.

``kmeans_step`` is a single jit-able Lloyd iteration so the distributed
builder (``core.distributed.sharded_kmeans_step``) can wrap it in shard_map
with a psum on the sufficient statistics.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class KMeansResult(NamedTuple):
    centroids: jnp.ndarray  # (c, d)
    assignment: jnp.ndarray  # (N,) int32


def assign_chunked(
    x: jnp.ndarray, centroids: jnp.ndarray, *, chunk: int = 4096
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Nearest-centroid assignment, scanning over N in fixed chunks.

    Returns (assignment (N,), min_dist (N,)). Squared-L2 computed via the
    ``|x|^2 - 2 x.c + |c|^2`` expansion so each tile is one matmul.
    """
    n, d = x.shape
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xs = xp.reshape(-1, chunk, d)
    c_sq = jnp.sum(centroids * centroids, axis=-1)  # (c,)

    def body(_, xc):
        x_sq = jnp.sum(xc * xc, axis=-1, keepdims=True)  # (chunk, 1)
        d2 = x_sq - 2.0 * (xc @ centroids.T) + c_sq  # (chunk, c)
        return None, (jnp.argmin(d2, axis=-1).astype(jnp.int32), jnp.min(d2, axis=-1))

    _, (a, md) = jax.lax.scan(body, None, xs)
    return a.reshape(-1)[:n], md.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("n_clusters", "chunk"))
def kmeans_step(
    x: jnp.ndarray, centroids: jnp.ndarray, *, n_clusters: int, chunk: int = 4096
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One Lloyd iteration -> (sums (c,d), counts (c,), assignment (N,)).

    Callers combine sums/counts (possibly across shards via psum) and call
    :func:`update_centroids`.
    """
    assignment, _ = assign_chunked(x, centroids, chunk=chunk)
    sums = jax.ops.segment_sum(x, assignment, num_segments=n_clusters)
    counts = jax.ops.segment_sum(
        jnp.ones((x.shape[0],), jnp.float32), assignment, num_segments=n_clusters
    )
    return sums, counts, assignment


def update_centroids(
    centroids: jnp.ndarray, sums: jnp.ndarray, counts: jnp.ndarray
) -> jnp.ndarray:
    """New centroids; empty clusters keep their previous centroid."""
    safe = jnp.maximum(counts, 1.0)[:, None]
    new = sums / safe
    return jnp.where(counts[:, None] > 0.5, new, centroids)


def init_centroids(rng: jax.Array, x: jnp.ndarray, n_clusters: int) -> jnp.ndarray:
    """Seeded init from distinct corpus points (k-means++ costs c sequential
    passes — deliberately skipped; Lloyd from a seeded sample is deterministic
    and clusters dense-retrieval embeddings well in practice)."""
    n = x.shape[0]
    if n < n_clusters:
        raise ValueError(
            f"cannot draw {n_clusters} distinct centroids from {n} points; "
            f"pass n_clusters <= {n} (or grow the corpus)"
        )
    idx = jax.random.choice(rng, n, (n_clusters,), replace=False)
    return x[idx]


def group_by_cluster(
    assignment: jnp.ndarray, n_clusters: int, capacity: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack point ids into capacity-padded per-cluster slots.

    Returns ``(gids (c, capacity) int32 with -1 padding, sizes (c,) int32)``.
    Points past ``capacity`` in a cluster are dropped (MoE-style capacity
    overflow — size the capacity so this never fires, or accept the recall
    hit; ``sizes`` is clamped so callers can count drops).
    """
    n = assignment.shape[0]
    c = n_clusters
    sizes = jnp.bincount(assignment, length=c).astype(jnp.int32)
    order = jnp.argsort(assignment, stable=True).astype(jnp.int32)
    sorted_assign = assignment[order]
    starts = jnp.cumsum(sizes) - sizes
    rank = jnp.arange(n, dtype=jnp.int32) - starts[sorted_assign]
    keep = rank < capacity
    flat = jnp.where(keep, sorted_assign * capacity + rank, c * capacity)
    buf = jnp.full((c * capacity + 1,), -1, dtype=jnp.int32).at[flat].set(order)
    return buf[:-1].reshape(c, capacity), jnp.minimum(sizes, capacity)


def kmeans(
    rng: jax.Array,
    x: jnp.ndarray,
    n_clusters: int,
    *,
    iters: int = 20,
    chunk: int = 4096,
) -> KMeansResult:
    """Full Lloyd loop on one host/device (the offline Stage-1 builder)."""
    centroids = init_centroids(rng, x, n_clusters)

    def body(c, _):
        sums, counts, _ = kmeans_step(x, c, n_clusters=n_clusters, chunk=chunk)
        return update_centroids(c, sums, counts), None

    centroids, _ = jax.lax.scan(body, centroids, None, length=iters)
    _, _, assignment = kmeans_step(x, centroids, n_clusters=n_clusters, chunk=chunk)
    return KMeansResult(centroids=centroids, assignment=assignment)

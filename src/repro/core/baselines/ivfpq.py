"""IVF-PQ (IVFADC, Jégou et al. 2011) — paper baseline 5, the fastest one.

Coarse k-means into C inverted lists + PQ on the residuals. Lists are stored
capacity-padded like LIDER's clusters so a probed search is pure gather.
Score(x) = <q, centroid(x)> + ADC(<q, residual codes>).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .. import clustering
from ..core_model import TopK
from ..types import pytree_dataclass
from ..utils import NEG_INF, dedup_topk
from .pq import PQParams, _encode, _train_codebooks, adc_lut


@pytree_dataclass(meta_fields=("n_lists", "n_subspaces", "n_codes"))
class IVFPQParams:
    centroids: jnp.ndarray  # (C, d)
    list_gids: jnp.ndarray  # (C, Lp) int32, -1 pad
    list_codes: jnp.ndarray  # (C, Lp, m) int32
    codebooks: jnp.ndarray  # (m, n_codes, ds)
    n_lists: int
    n_subspaces: int
    n_codes: int


def build_ivfpq(
    rng: jax.Array,
    embs: jnp.ndarray,
    *,
    n_lists: int | None = None,
    n_subspaces: int = 8,
    bits: int = 8,
    kmeans_iters: int = 15,
    pad_multiple: int = 8,
) -> IVFPQParams:
    n, d = embs.shape
    c = n_lists or max(4, int(math.sqrt(n)))  # paper: C = sqrt(N)
    rng_c, rng_pq = jax.random.split(rng)
    km = clustering.kmeans(rng_c, embs, c, iters=kmeans_iters)
    residuals = embs - km.centroids[km.assignment]
    codebooks = _train_codebooks(rng_pq, residuals, n_subspaces, 2**bits, kmeans_iters)
    codes = _encode(codebooks, residuals)  # (N, m)

    sizes = jnp.bincount(km.assignment, length=c)
    cap = int(jax.device_get(jnp.max(sizes)))
    cap = max(pad_multiple, math.ceil(cap / pad_multiple) * pad_multiple)
    gids, _ = clustering.group_by_cluster(km.assignment, c, cap)
    safe = jnp.maximum(gids, 0)
    list_codes = codes[safe] * (gids >= 0)[..., None]
    return IVFPQParams(
        centroids=km.centroids,
        list_gids=gids,
        list_codes=list_codes,
        codebooks=codebooks,
        n_lists=c,
        n_subspaces=n_subspaces,
        n_codes=2**bits,
    )


@partial(jax.jit, static_argnames=("k", "n_probe"))
def ivfpq_search(
    params: IVFPQParams, queries: jnp.ndarray, *, k: int, n_probe: int = 8
) -> TopK:
    b = queries.shape[0]
    c, lp, m = params.list_codes.shape
    coarse = queries @ params.centroids.T  # (B, C) IP scores
    c_scores, cids = jax.lax.top_k(coarse, n_probe)  # (B, p)

    pq_for_lut = PQParams(
        codebooks=params.codebooks,
        codes=params.list_codes.reshape(-1, m)[:1],
        rotation=None,
        n_subspaces=params.n_subspaces,
        n_codes=params.n_codes,
    )
    lut = adc_lut(pq_for_lut, queries)  # (B, m, n_codes)

    codes = params.list_codes[cids]  # (B, p, Lp, m)
    gids = params.list_gids[cids]  # (B, p, Lp)
    # Per-query LUT gather: scores[b,p,l] = sum_j lut[b, j, codes[b,p,l,j]].
    gathered = jnp.take_along_axis(
        lut[:, None, None, :, :],  # (B,1,1,m,K)
        codes[..., None],  # (B,p,Lp,m,1)
        axis=-1,
    )[..., 0]
    scores = jnp.sum(gathered, axis=-1) + c_scores[..., None]  # residual + coarse
    scores = jnp.where(gids < 0, NEG_INF, scores)
    ids, sc = dedup_topk(gids.reshape(b, -1), scores.reshape(b, -1), k)
    return TopK(ids=ids, scores=sc)

"""ANN baselines the paper evaluates against (Sec. 7.1.2), in JAX.

Flat (exact), PQ, IVF-PQ, original SK-LSH, and a FALCONN-style multi-probe
LSH. All share the TopK return convention of the core library. OPQ / PCA-PQ
are PQ with a learned rotation / PCA projection — exposed as options on PQ.
HNSW graph search is pointer-chasing with data-dependent frontier shapes
(no TPU-idiomatic equivalent at batch granularity; see DESIGN.md) — its
quantization half (IVFPQ) is implemented, the graph half is not.
"""
from .flat import flat_search
from .pq import PQParams, build_pq, pq_search
from .ivfpq import IVFPQParams, build_ivfpq, ivfpq_search
from .sklsh import SKLSHParams, build_sklsh, sklsh_search
from .mplsh import MPLSHParams, build_mplsh, mplsh_search

__all__ = [
    "flat_search",
    "PQParams",
    "build_pq",
    "pq_search",
    "IVFPQParams",
    "build_ivfpq",
    "ivfpq_search",
    "SKLSHParams",
    "build_sklsh",
    "sklsh_search",
    "MPLSHParams",
    "build_mplsh",
    "mplsh_search",
]

"""Flat (exact brute-force) search — the quality upper bound (paper Table 2).

Chunked over the corpus so the (B, N) score matrix never materialises; the
running top-k merge is the same pattern the ``flat_topk`` Pallas kernel fuses
on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core_model import TopK
from ..utils import merge_topk


@partial(jax.jit, static_argnames=("k", "chunk"))
def flat_search(
    embs: jnp.ndarray, queries: jnp.ndarray, *, k: int, chunk: int = 8192
) -> TopK:
    n, d = embs.shape
    b = queries.shape[0]
    pad = (-n) % chunk
    ep = jnp.pad(embs, ((0, pad), (0, 0)))
    n_chunks = ep.shape[0] // chunk
    ec = ep.reshape(n_chunks, chunk, d)

    def body(carry, args):
        ids, scores = carry  # (B, k) running top-k
        chunk_embs, chunk_start = args
        s = queries @ chunk_embs.T  # (B, chunk)
        cand_ids = chunk_start + jnp.arange(chunk, dtype=jnp.int32)
        cand_ids = jnp.where(cand_ids < n, cand_ids, -1)
        s = jnp.where(cand_ids[None, :] < 0, -jnp.inf, s)
        top_s, top_i = jax.lax.top_k(s, min(k, chunk))
        top_ids = cand_ids[top_i]
        all_ids = jnp.concatenate([ids, top_ids], axis=-1)
        all_s = jnp.concatenate([scores, top_s], axis=-1)
        m_s, m_i = jax.lax.top_k(all_s, k)
        m_ids = jnp.take_along_axis(all_ids, m_i, axis=-1)
        return (m_ids, m_s), None

    init = (
        jnp.full((b, k), -1, dtype=jnp.int32),
        jnp.full((b, k), -jnp.inf, dtype=jnp.float32),
    )
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
    (ids, scores), _ = jax.lax.scan(body, init, (ec, starts))
    return TopK(ids=ids, scores=scores)

"""Multi-probe LSH baseline (Lv et al. 2007; FALCONN-style) — paper baseline 7.

L hash tables of M-bit hyperplane keys. Buckets are equality ranges in a
sorted (key, id) array. Probing flips low-|margin| bits of the query key:
the probe sequence enumerates subsets of the ``n_flip_bits`` smallest-margin
bits, ordered by summed margin penalty (the standard query-directed probing
approximation), and scans each probed bucket up to ``bucket_cap`` entries.
"""
from __future__ import annotations

import itertools
from functools import partial

import jax
import jax.numpy as jnp

from .. import lsh as lsh_lib
from ..core_model import TopK
from ..types import pytree_dataclass
from ..utils import NEG_INF, dedup_topk


@pytree_dataclass
class MPLSHParams:
    lsh: lsh_lib.LSHParams
    sorted_keys: jnp.ndarray  # (L, N) uint32
    sorted_ids: jnp.ndarray  # (L, N) int32


def build_mplsh(
    rng: jax.Array,
    embs: jnp.ndarray,
    *,
    n_tables: int = 24,
    key_len: int | None = None,
) -> MPLSHParams:
    n, dim = embs.shape
    key_len = key_len or lsh_lib.suggest_key_len(n)
    lsh = lsh_lib.make_lsh(rng, dim, n_tables, key_len)
    keys = lsh_lib.hash_vectors(lsh, embs).T
    sorted_keys, order = jax.vmap(lsh_lib.sort_hashkeys)(keys)
    return MPLSHParams(
        lsh=lsh, sorted_keys=sorted_keys, sorted_ids=order.astype(jnp.int32)
    )


@partial(jax.jit, static_argnames=("k", "n_probes", "n_flip_bits", "bucket_cap"))
def mplsh_search(
    params: MPLSHParams,
    embs: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    n_probes: int = 8,
    n_flip_bits: int = 4,
    bucket_cap: int = 64,
) -> TopK:
    l, n = params.sorted_keys.shape
    m = params.lsh.key_len
    b = queries.shape[0]
    f = min(n_flip_bits, m)

    proj = queries @ params.lsh.projections  # (B, L*M)
    proj = proj.reshape(b, l, m)
    bits = (proj >= 0.0).astype(jnp.uint32)
    qkeys = lsh_lib.pack_bits(bits)  # (B, L)
    margins = jnp.abs(proj)  # (B, L, M)

    # Smallest-margin bit positions per (query, table).
    _, flip_pos = jax.lax.top_k(-margins, f)  # (B, L, f) bit indices (0 = MSB)
    flip_masks = (jnp.uint32(1) << (m - 1 - flip_pos).astype(jnp.uint32)).astype(
        jnp.uint32
    )
    flip_margin = jnp.take_along_axis(margins, flip_pos, axis=-1)  # (B, L, f)

    # Static probe pattern: all subsets of the f candidate bits; rank by
    # summed margin penalty per (query, table), take the best n_probes.
    subsets = jnp.asarray(
        [list(s) for s in itertools.product((0, 1), repeat=f)], dtype=jnp.float32
    )  # (2^f, f); row 0 = no flips
    penalties = jnp.einsum("blf,sf->bls", flip_margin, subsets)  # (B, L, 2^f)
    _, probe_sel = jax.lax.top_k(-penalties, min(n_probes, 2**f))  # (B, L, P)
    subset_bits = subsets.astype(jnp.uint32)  # (2^f, f)
    probe_subsets = subset_bits[probe_sel]  # (B, L, P, f)
    xor = jnp.sum(
        probe_subsets * flip_masks[:, :, None, :], axis=-1, dtype=jnp.uint32
    )  # (B, L, P)
    probe_keys = qkeys[:, :, None] ^ xor  # (B, L, P)

    # Bucket = equality range in the sorted array; scan up to bucket_cap.
    def table_lookup(skeys, sids, pkeys):  # (N,), (N,), (B, P)
        flatp = pkeys.reshape(-1)
        lo = jnp.searchsorted(skeys, flatp, side="left")
        hi = jnp.searchsorted(skeys, flatp, side="right")
        idx = lo[:, None] + jnp.arange(bucket_cap)  # (BP, cap)
        valid = idx < hi[:, None]
        ids = jnp.take(sids, jnp.clip(idx, 0, n - 1))
        return jnp.where(valid, ids, -1)  # (BP, cap)

    cand = jax.vmap(table_lookup, in_axes=(0, 0, 1))(
        params.sorted_keys, params.sorted_ids, probe_keys
    )  # (L, B*P, cap)
    cand = jnp.moveaxis(cand.reshape(l, b, -1), 0, 1).reshape(b, -1)

    emb = embs[jnp.maximum(cand, 0)]
    scores = jnp.einsum("bcd,bd->bc", emb, queries)
    scores = jnp.where(cand < 0, NEG_INF, scores)
    ids, sc = dedup_topk(cand, scores, k)
    return TopK(ids=ids, scores=sc)

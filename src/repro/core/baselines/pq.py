"""Product quantization baselines: PQ, OPQ (learned rotation), PCA-PQ.

PQ [Jégou et al. 2010]: split d into m subspaces, k-means 2**bits codewords
per subspace, score by asymmetric distance computation (ADC) — for the
inner-product/cosine metric the ADC table is ``LUT[j, code] = <q_j, c_{j,code}>``
and a corpus score is a sum of m table lookups.

OPQ [Ge et al. 2013]: alternate (encode, procrustes-rotate) to learn R.
PCA-PQ: project to a lower dim with PCA before PQ (paper baseline 4).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import clustering
from ..core_model import TopK
from ..types import pytree_dataclass
from ..utils import dedup_topk


@pytree_dataclass(meta_fields=("n_subspaces", "n_codes"))
class PQParams:
    codebooks: jnp.ndarray  # (m, n_codes, ds)
    codes: jnp.ndarray  # (N, m) int32
    rotation: jnp.ndarray | None  # (d, d_proj) — OPQ rotation or PCA projection
    n_subspaces: int
    n_codes: int


def _encode(codebooks: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(N, d_proj) -> (N, m) nearest-codeword ids per subspace."""
    m, n_codes, ds = codebooks.shape
    xs = x.reshape(x.shape[0], m, ds)

    def per_sub(xsub, cb):  # (N, ds), (n_codes, ds)
        d2 = (
            jnp.sum(xsub * xsub, -1, keepdims=True)
            - 2.0 * xsub @ cb.T
            + jnp.sum(cb * cb, -1)
        )
        return jnp.argmin(d2, axis=-1).astype(jnp.int32)

    return jax.vmap(per_sub, in_axes=(1, 0), out_axes=1)(xs, codebooks)


def _decode(codebooks: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    m, _, ds = codebooks.shape
    rows = jax.vmap(lambda cb, c: cb[c], in_axes=(0, 1), out_axes=1)(codebooks, codes)
    return rows.reshape(codes.shape[0], m * ds)


def _train_codebooks(
    rng: jax.Array, x: jnp.ndarray, m: int, n_codes: int, iters: int
) -> jnp.ndarray:
    n, d = x.shape
    ds = d // m
    xs = x.reshape(n, m, ds)
    keys = jax.random.split(rng, m)

    def per_sub(key, xsub):
        return clustering.kmeans(key, xsub, n_codes, iters=iters).centroids

    return jax.vmap(per_sub, in_axes=(0, 1))(keys, xs)


def _pca(x: jnp.ndarray, out_dim: int) -> jnp.ndarray:
    mu = x.mean(0)
    cov = (x - mu).T @ (x - mu) / x.shape[0]
    _, vecs = jnp.linalg.eigh(cov)
    return vecs[:, ::-1][:, :out_dim]  # (d, out_dim), descending eigenvalues


def build_pq(
    rng: jax.Array,
    embs: jnp.ndarray,
    *,
    n_subspaces: int = 8,
    bits: int = 8,
    kmeans_iters: int = 15,
    opq_iters: int = 0,
    pca_dim: int | None = None,
) -> PQParams:
    n_codes = 2**bits
    rotation = None
    x = embs
    if pca_dim is not None:
        rotation = _pca(embs, pca_dim)
        x = embs @ rotation
    if opq_iters > 0:
        d = x.shape[1]
        r = jnp.eye(d) if rotation is None else rotation
        xr = embs @ r if rotation is not None else x
        cbs = _train_codebooks(rng, xr, n_subspaces, n_codes, kmeans_iters)
        for _ in range(opq_iters):
            codes = _encode(cbs, xr)
            recon = _decode(cbs, codes)
            # Procrustes: R = argmin ||X R - recon|| = U V^T of X^T recon.
            u, _, vt = jnp.linalg.svd(embs.T @ recon, full_matrices=False)
            r = u @ vt
            xr = embs @ r
            cbs = _train_codebooks(rng, xr, n_subspaces, n_codes, kmeans_iters)
        rotation = r
        x = xr
        codebooks = cbs
    else:
        codebooks = _train_codebooks(rng, x, n_subspaces, n_codes, kmeans_iters)
    codes = _encode(codebooks, x)
    return PQParams(
        codebooks=codebooks,
        codes=codes,
        rotation=rotation,
        n_subspaces=n_subspaces,
        n_codes=n_codes,
    )


def adc_lut(params: PQParams, queries: jnp.ndarray) -> jnp.ndarray:
    """Inner-product ADC lookup tables (B, m, n_codes)."""
    q = queries if params.rotation is None else queries @ params.rotation
    m, n_codes, ds = params.codebooks.shape
    qs = q.reshape(q.shape[0], m, ds)
    return jnp.einsum("bms,mks->bmk", qs, params.codebooks)


def adc_scores(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Sum of per-subspace LUT entries -> (B, C) approximate IP scores."""
    m = codes.shape[-1]
    lut_t = lut.transpose(1, 2, 0)  # (m, n_codes, B)
    gathered = lut_t[jnp.arange(m)[:, None], codes.T]  # (m, C, B)
    return jnp.sum(gathered, axis=0).T


@partial(jax.jit, static_argnames=("k", "chunk"))
def pq_search(
    params: PQParams, queries: jnp.ndarray, *, k: int, chunk: int = 65536
) -> TopK:
    n = params.codes.shape[0]
    b = queries.shape[0]
    lut = adc_lut(params, queries)
    pad = (-n) % chunk
    codes = jnp.pad(params.codes, ((0, pad), (0, 0)))
    n_chunks = codes.shape[0] // chunk

    def body(carry, args):
        ids, scores = carry
        ck, start = args
        s = adc_scores(lut, ck)
        cand = start + jnp.arange(chunk, dtype=jnp.int32)
        s = jnp.where(cand[None, :] < n, s, -jnp.inf)
        cand = jnp.where(cand < n, cand, -1)
        top_s, top_i = jax.lax.top_k(s, min(k, chunk))
        all_ids = jnp.concatenate([ids, cand[top_i]], axis=-1)
        all_s = jnp.concatenate([scores, top_s], axis=-1)
        m_s, m_i = jax.lax.top_k(all_s, k)
        return (jnp.take_along_axis(all_ids, m_i, -1), m_s), None

    init = (
        jnp.full((b, k), -1, dtype=jnp.int32),
        jnp.full((b, k), -jnp.inf, dtype=jnp.float32),
    )
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
    (ids, scores), _ = jax.lax.scan(
        body, init, (codes.reshape(n_chunks, chunk, -1), starts)
    )
    return TopK(ids=ids, scores=scores)

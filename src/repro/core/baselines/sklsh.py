"""Original SK-LSH baseline (Liu et al. 2014) — paper baseline 8.

One flat index over the whole corpus: H sorted hashkey arrays, exact binary
search for the query position (no RMI), then the *global* iterative
expansion: SK-LSH repeatedly takes the globally closest hashkey (by dist_e)
across all arrays. A data-dependent per-query loop is hostile to TPU
batching, so we compute the same fixed point in one shot: take a 2T window
per array around the query position, rank all H*2T candidates by dist_e, and
verify the best T — exactly the candidate set the iteration would visit
(DESIGN.md §2, "faithful to outcome, not to the loop").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import lsh as lsh_lib
from ..core_model import TopK
from ..types import pytree_dataclass
from ..utils import NEG_INF, dedup_topk


@pytree_dataclass
class SKLSHParams:
    lsh: lsh_lib.LSHParams
    sorted_keys: jnp.ndarray  # (H, N) uint32
    sorted_ids: jnp.ndarray  # (H, N) int32


def build_sklsh(
    rng: jax.Array,
    embs: jnp.ndarray,
    *,
    n_arrays: int = 24,
    key_len: int | None = None,
) -> SKLSHParams:
    n, dim = embs.shape
    key_len = key_len or lsh_lib.suggest_key_len(n)
    lsh = lsh_lib.make_lsh(rng, dim, n_arrays, key_len)
    keys = lsh_lib.hash_vectors(lsh, embs).T  # (H, N)
    sorted_keys, order = jax.vmap(lsh_lib.sort_hashkeys)(keys)
    return SKLSHParams(
        lsh=lsh, sorted_keys=sorted_keys, sorted_ids=order.astype(jnp.int32)
    )


@partial(jax.jit, static_argnames=("k", "n_candidates", "window_bits"))
def sklsh_search(
    params: SKLSHParams,
    embs: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    n_candidates: int | None = None,
    window_bits: int = 8,
) -> TopK:
    h, n = params.sorted_keys.shape
    m = params.lsh.key_len
    b = queries.shape[0]
    t = n_candidates or 4 * k  # paper: "several times k"
    width = min(2 * t, n)

    qkeys = lsh_lib.hash_vectors(params.lsh, queries)  # (B, H)
    pos = jax.vmap(lsh_lib.query_position)(params.sorted_keys, qkeys.T)  # (H, B)
    start = jnp.clip(pos - width // 2, 0, n - width)
    idx = start[..., None] + jnp.arange(width, dtype=jnp.int32)  # (H, B, W)
    win_keys = jax.vmap(jnp.take)(params.sorted_keys, idx)  # (H, B, W)
    win_ids = jax.vmap(jnp.take)(params.sorted_ids, idx)

    # Rank the pooled window by extended hashkey distance to the query key,
    # keep the T globally closest (the iterative expansion's visit set).
    d = lsh_lib.dist_e(win_keys, qkeys.T[..., None], m, window_bits)  # (H, B, W)
    d = jnp.moveaxis(d, 0, 1).reshape(b, -1)  # (B, H*W)
    ids = jnp.moveaxis(win_ids, 0, 1).reshape(b, -1)
    _, sel = jax.lax.top_k(-d, min(t, d.shape[-1]))  # smallest dist_e
    cand_ids = jnp.take_along_axis(ids, sel, axis=-1)  # (B, T)

    cand = embs[jnp.maximum(cand_ids, 0)]
    scores = jnp.einsum("btd,bd->bt", cand, queries)
    scores = jnp.where(cand_ids < 0, NEG_INF, scores)
    out_ids, out_sc = dedup_topk(cand_ids, scores, k)
    return TopK(ids=out_ids, scores=out_sc)

"""Key re-scaling (paper Sec. 5.1).

Packed hashkeys are huge integers (up to 2**M); RMI labels are array
positions in ``[0, L-1]``. Min-max normalising the keys onto the label range
removes the out-of-range predictions that otherwise dominate RMI error
(paper Table 4 — reproduced in ``benchmarks/table4_rescaling.py``).

All math is done on ``uint32`` differences (exact) then cast to float32; the
2**-24 relative rounding maps to a position error of ``L * 2**-24`` — well
under one slot for any realistic array length.
"""
from __future__ import annotations

import jax.numpy as jnp

from .types import pytree_dataclass


@pytree_dataclass
class RescaleParams:
    """Per-array min/max statistics + the target range length.

    Shapes are whatever the caller vmaps over — a standalone core model keeps
    ``(H,)`` stats, LIDER's stacked in-cluster retrievers keep ``(c, H)``.
    ``length`` is the number of *valid* slots (float32 so it vmaps).
    """

    key_min: jnp.ndarray  # uint32
    key_max: jnp.ndarray  # uint32
    length: jnp.ndarray  # float32, rescale target is [0, length - 1]


def fit_rescale(
    sorted_keys: jnp.ndarray, valid: jnp.ndarray | None = None
) -> RescaleParams:
    """Fit min/max over one sorted key array ``(L,)`` (mask-aware).

    ``valid`` is a bool mask for padded arrays (padding must sort to the end,
    which the UINT32_PAD sentinel guarantees).
    """
    if valid is None:
        kmin = sorted_keys[0]
        kmax = sorted_keys[-1]
        length = jnp.float32(sorted_keys.shape[-1])
    else:
        n = jnp.sum(valid.astype(jnp.int32), axis=-1)
        kmin = sorted_keys[0]  # valid entries sort first
        last = jnp.maximum(n - 1, 0)
        kmax = sorted_keys[last]
        length = n.astype(jnp.float32)
    return RescaleParams(key_min=kmin, key_max=kmax, length=length)


def rescale(params: RescaleParams, keys: jnp.ndarray) -> jnp.ndarray:
    """uint32 keys -> float32 RMI keys in [0, length-1] (clipped)."""
    keys = keys.astype(jnp.uint32)
    kmin = params.key_min.astype(jnp.uint32)
    kmax = params.key_max.astype(jnp.uint32)
    # Exact unsigned differences; queries may fall outside [kmin, kmax].
    clipped = jnp.clip(keys, kmin, kmax)
    diff = (clipped - kmin).astype(jnp.float32)
    span = (kmax - kmin).astype(jnp.float32)
    span = jnp.maximum(span, 1.0)
    hi = jnp.maximum(params.length - 1.0, 0.0)
    return jnp.clip(diff / span * hi, 0.0, hi)

"""Shared numeric helpers: masked top-k with duplicate suppression, etc."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")  # python float: module-level jnp scalars would
# initialize the backend at import time (breaking the dry-run's XLA_FLAGS).


def l2_normalize(x: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """Row-normalise so inner product == cosine similarity (paper Sec. 7.1.1)."""
    n = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    return x / jnp.maximum(n, eps)


def dedup_topk(
    ids: jnp.ndarray, scores: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k over the last axis with duplicate/invalid candidates suppressed.

    ``ids``: (..., C) int32 candidate ids, -1 == invalid (padding).
    ``scores``: (..., C) float32; duplicates of the same id carry equal scores
    (same vector), so keeping any one occurrence is exact.

    Returns ``(top_ids, top_scores)`` of shape (..., k); slots beyond the
    number of unique valid candidates have id -1 and score -inf.
    """
    invalid = ids < 0
    # Sort by id so duplicates become adjacent; mask all but the first.
    order = jnp.argsort(ids, axis=-1, stable=True)
    sid = jnp.take_along_axis(ids, order, axis=-1)
    ssc = jnp.take_along_axis(scores, order, axis=-1)
    sinv = jnp.take_along_axis(invalid, order, axis=-1)
    prev = jnp.concatenate(
        [jnp.full(sid.shape[:-1] + (1,), -2, dtype=sid.dtype), sid[..., :-1]], axis=-1
    )
    dup = sid == prev
    masked = jnp.where(dup | sinv, NEG_INF, ssc)
    kk = min(k, masked.shape[-1])
    top_scores, idx = jax.lax.top_k(masked, kk)
    top_ids = jnp.take_along_axis(sid, idx, axis=-1)
    top_ids = jnp.where(jnp.isneginf(top_scores), -1, top_ids)
    if kk < k:  # fewer candidates than k: pad the tail
        pad = [(0, 0)] * (top_ids.ndim - 1) + [(0, k - kk)]
        top_ids = jnp.pad(top_ids, pad, constant_values=-1)
        top_scores = jnp.pad(top_scores, pad, constant_values=NEG_INF)
    return top_ids, top_scores


def merge_topk(
    ids_list: jnp.ndarray, scores_list: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge per-shard top-k lists (..., S, k) -> global (..., k)."""
    flat_ids = ids_list.reshape(*ids_list.shape[:-2], -1)
    flat_scores = scores_list.reshape(*scores_list.shape[:-2], -1)
    return dedup_topk(flat_ids, flat_scores, k)


def recall_at_k(pred_ids: jnp.ndarray, true_ids: jnp.ndarray) -> jnp.ndarray:
    """Mean recall@k: |pred ∩ true| / |true| per row, averaged."""
    hits = (pred_ids[..., :, None] == true_ids[..., None, :]) & (
        true_ids[..., None, :] >= 0
    )
    per_row = hits.any(axis=-2).sum(axis=-1) / jnp.maximum(
        (true_ids >= 0).sum(axis=-1), 1
    )
    return per_row.mean()


def mrr_at_10(pred_ids, relevant) -> float:
    """Mean reciprocal rank of the known-relevant id within the top 10.

    Host-side (numpy) — the single definition shared by the offline
    benchmarks (``benchmarks.common``) and the Pareto autotuner, so the
    paper's headline quality metric cannot drift between reports.
    """
    import numpy as np

    pred = np.asarray(pred_ids)[:, :10]
    rr = []
    for row, r in zip(pred, np.asarray(relevant)):
        pos = np.nonzero(row == r)[0]
        rr.append(1.0 / (pos[0] + 1) if len(pos) else 0.0)
    return float(np.mean(rr))

"""LIDER core model (paper Sec. 3.1): ESK-LSH + key re-scaling + RMI.

Indexes one embedding space (the whole corpus for a standalone model, the
centroid set or one cluster inside LIDER). Holds ``H`` sorted hashkey arrays
and one RMI per array; search is::

    query -> H hashkeys -> re-scale -> RMI position -> bi-directional window
          -> gather candidate embeddings -> exact scores -> dedup top-k

The bi-directional expansion is a *contiguous* ``R = r0*k`` slice of each
sorted array — the TPU-native replacement for the paper's pointer walk.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import bank as bank_lib
from . import lsh as lsh_lib
from . import rescale as rescale_lib
from . import rmi as rmi_lib
from ..kernels.ops import verify_topk_op
from .types import pytree_dataclass


class TopK(NamedTuple):
    ids: jnp.ndarray  # (..., k) int32, -1 for empty slots
    scores: jnp.ndarray  # (..., k) float32


@pytree_dataclass
class CoreModelParams:
    lsh: lsh_lib.LSHParams
    rescale: rescale_lib.RescaleParams  # leaves shaped (H,)
    rmi: rmi_lib.RMIParams  # leaves shaped (H,) / (H, W)
    sorted_keys: jnp.ndarray  # (H, L) uint32
    sorted_ids: jnp.ndarray  # (H, L) int32 — indices into the embedding table

    @property
    def n_arrays(self) -> int:
        return self.lsh.n_arrays

    @property
    def array_len(self) -> int:
        return self.sorted_keys.shape[-1]


def build_core_model(
    rng: jax.Array,
    embs: jnp.ndarray,
    *,
    n_arrays: int,
    key_len: int | None = None,
    n_leaves: int = 10,
) -> CoreModelParams:
    """Index ``embs`` (L, d). Embeddings should be L2-normalised for cosine."""
    n, dim = embs.shape
    key_len = key_len or lsh_lib.suggest_key_len(n)
    lsh = lsh_lib.make_lsh(rng, dim, n_arrays, key_len)
    keys = lsh_lib.hash_vectors(lsh, embs).T  # (H, L)
    sorted_keys, order = jax.vmap(lsh_lib.sort_hashkeys)(keys)
    # Same fit primitive as the cluster-bank build/refit (no padded slots
    # here, so the mask is all-ones).
    resc, rmi = jax.vmap(partial(bank_lib.fit_sorted_array, n_leaves=n_leaves))(
        sorted_keys, jnp.ones(sorted_keys.shape, bool)
    )
    return CoreModelParams(
        lsh=lsh,
        rescale=resc,
        rmi=rmi,
        sorted_keys=sorted_keys,
        sorted_ids=order.astype(jnp.int32),
    )


def predict_positions(
    cm: CoreModelParams, queries: jnp.ndarray, *, refine: bool = False
) -> jnp.ndarray:
    """(B, d) queries -> (H, B) float32 predicted positions in each array.

    ``refine=True`` replaces the RMI prediction with an exact binary search —
    the beyond-paper "last-mile" variant (trades H log L searchsorted work for
    zero prediction error; see EXPERIMENTS.md §Perf).
    """
    qkeys = lsh_lib.hash_vectors(cm.lsh, queries)  # (B, H)
    if refine:
        return jax.vmap(lsh_lib.query_position)(cm.sorted_keys, qkeys.T).astype(
            jnp.float32
        )
    scaled = jax.vmap(rescale_lib.rescale)(cm.rescale, qkeys.T)  # (H, B)
    return jax.vmap(rmi_lib.predict)(cm.rmi, scaled)


def candidate_windows(
    cm: CoreModelParams, positions: jnp.ndarray, width: int
) -> jnp.ndarray:
    """Bi-directional expansion: (H, B) positions -> (B, H*width) candidate ids."""
    arr_len = cm.array_len
    width = min(width, arr_len)
    start = jnp.clip(
        jnp.round(positions).astype(jnp.int32) - width // 2, 0, arr_len - width
    )
    idx = start[..., None] + jnp.arange(width, dtype=jnp.int32)  # (H, B, R)
    cand = jax.vmap(jnp.take)(cm.sorted_ids, idx)  # (H, B, R)
    return jnp.moveaxis(cand, 0, 1).reshape(positions.shape[1], -1)


def search_core_model(
    cm: CoreModelParams,
    embs: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    r0: int = 4,
    refine: bool = False,
    use_fused: bool | None = None,
    block_c: int | None = None,
    scales: jnp.ndarray | None = None,
    rescore_embs: jnp.ndarray | None = None,
    rescore_factor: int = 4,
) -> TopK:
    """Full paper search path on a single core model.

    Verification (gather candidate rows -> exact scores -> dedup top-k) runs
    through ``verify_topk_op``: a single fused VMEM-resident Pallas pass on
    TPU, the materialized reference elsewhere (``use_fused`` overrides;
    DESIGN.md §Verification-kernel). ``block_c`` tunes the kernel's
    candidate block size.

    With ``scales`` set, ``embs`` is an int8 code table (per-row symmetric,
    ``kernels.quant``): the first pass scores in the compressed domain and
    the provisional top-``rescore_factor * k`` is exactly rescored from
    ``rescore_embs`` (the full-precision table) — the standalone-model
    spelling of the quantized ClusterBank search (DESIGN.md §Quantized
    bank). Candidate ids here *are* corpus row ids, so no row/id mapping is
    needed between the passes.
    """
    positions = predict_positions(cm, queries, refine=refine)
    cand_ids = candidate_windows(cm, positions, width=r0 * k)
    if scales is not None:
        if rescore_embs is None:
            raise ValueError("quantized search needs rescore_embs")
        kp = min(max(rescore_factor, 1) * k, cand_ids.shape[-1])
        prov, _ = verify_topk_op(
            embs, cand_ids, queries, k=kp, scales=scales, block_c=block_c,
            use_pallas=use_fused,
        )
        ids, sc = verify_topk_op(
            rescore_embs,
            jnp.maximum(prov, 0),
            queries,
            k=k,
            out_ids=prov,
            block_c=block_c,
            use_pallas=use_fused,
        )
        return TopK(ids=ids, scores=sc)
    ids, sc = verify_topk_op(
        embs, cand_ids, queries, k=k, block_c=block_c, use_pallas=use_fused
    )
    return TopK(ids=ids, scores=sc)

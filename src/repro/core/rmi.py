"""Simplified recursive-model index (paper Sec. 5.2).

Two layers, linear-regression only: after key re-scaling the key→position
distribution is near-linear (paper Fig. 3), so the root is a linear model
that partitions ``[0, L)`` into ``n_leaves`` equal prediction ranges and each
leaf is an independent linear model. Fitting is closed-form weighted least
squares computed with centered segment-sums (one `segment_sum` pass per
moment) — no gradient loop, exactly reproducible, vmap-able across the ``H``
arrays of a core model and across LIDER's thousands of clusters.

No hybrid B-tree fallback (paper deliberately drops it for speed); instead
the per-leaf max training error is recorded — it feeds diagnostics
(Table 4 reproduction) and the beyond-paper error-bounded refinement.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .types import pytree_dataclass

_EPS = 1e-12


@pytree_dataclass(meta_fields=("n_leaves",))
class RMIParams:
    root_w: jnp.ndarray  # () f32
    root_b: jnp.ndarray  # () f32
    leaf_w: jnp.ndarray  # (n_leaves,) f32
    leaf_b: jnp.ndarray  # (n_leaves,) f32
    length: jnp.ndarray  # () f32 — number of valid slots; labels in [0, length-1]
    max_err: jnp.ndarray  # (n_leaves,) f32 — max |pred - true| seen at fit time
    n_leaves: int


def _wls(x, y, w):
    """Weighted least squares slope/intercept with centered moments."""
    n = jnp.sum(w)
    mx = jnp.sum(w * x) / jnp.maximum(n, _EPS)
    my = jnp.sum(w * y) / jnp.maximum(n, _EPS)
    cov = jnp.sum(w * (x - mx) * (y - my))
    var = jnp.sum(w * (x - mx) ** 2)
    slope = jnp.where(var > _EPS, cov / jnp.maximum(var, _EPS), 0.0)
    return slope, my - slope * mx


def _leaf_of(root_w, root_b, x, length, n_leaves):
    hi = jnp.maximum(length - 1.0, 0.0)
    pred = jnp.clip(root_w * x + root_b, 0.0, hi)
    leaf = jnp.floor(pred * n_leaves / jnp.maximum(length, 1.0)).astype(jnp.int32)
    return jnp.clip(leaf, 0, n_leaves - 1)


@partial(jax.jit, static_argnames=("n_leaves",))
def fit_rmi(
    keys: jnp.ndarray, weights: jnp.ndarray, n_leaves: int
) -> RMIParams:
    """Fit a 2-layer linear RMI on one sorted (re-scaled) key array.

    ``keys``: (Lp,) float32 ascending over valid entries (padding at the end).
    ``weights``: (Lp,) {0,1} mask; position labels are 0..n_valid-1 because
    padding sorts last.
    """
    lp = keys.shape[0]
    w = weights.astype(jnp.float32)
    y = jnp.arange(lp, dtype=jnp.float32)
    length = jnp.sum(w)

    root_w, root_b = _wls(keys, y, w)
    leaf = _leaf_of(root_w, root_b, keys, length, n_leaves)

    # Per-leaf weighted LS via two segment passes (centered for fp32 safety).
    seg = partial(jax.ops.segment_sum, segment_ids=leaf, num_segments=n_leaves)
    n_l = seg(w)
    mx_l = seg(w * keys) / jnp.maximum(n_l, _EPS)
    my_l = seg(w * y) / jnp.maximum(n_l, _EPS)
    dx = keys - mx_l[leaf]
    dy = y - my_l[leaf]
    cov_l = seg(w * dx * dy)
    var_l = seg(w * dx * dx)
    slope_l = jnp.where(var_l > _EPS, cov_l / jnp.maximum(var_l, _EPS), 0.0)
    inter_l = my_l - slope_l * mx_l
    # Empty leaves fall back to the root model.
    empty = n_l < 0.5
    leaf_w = jnp.where(empty, root_w, slope_l)
    leaf_b = jnp.where(empty, root_b, inter_l)

    hi = jnp.maximum(length - 1.0, 0.0)
    pred = jnp.clip(leaf_w[leaf] * keys + leaf_b[leaf], 0.0, hi)
    err = jnp.abs(pred - y) * w
    max_err = jax.ops.segment_max(
        err, leaf, num_segments=n_leaves, indices_are_sorted=False
    )
    max_err = jnp.where(jnp.isfinite(max_err), max_err, 0.0)

    return RMIParams(
        root_w=root_w,
        root_b=root_b,
        leaf_w=leaf_w,
        leaf_b=leaf_b,
        length=length,
        max_err=max_err,
        n_leaves=n_leaves,
    )


def predict(params: RMIParams, x: jnp.ndarray) -> jnp.ndarray:
    """Predict positions (float32, clipped to [0, length-1]) for scaled keys."""
    leaf = _leaf_of(params.root_w, params.root_b, x, params.length, params.n_leaves)
    hi = jnp.maximum(params.length - 1.0, 0.0)
    return jnp.clip(params.leaf_w[leaf] * x + params.leaf_b[leaf], 0.0, hi)


def predict_banked(params: RMIParams, x: jnp.ndarray) -> jnp.ndarray:
    """Predict when every RMI leaf carries batch dims matching ``x``.

    The banked form of :func:`predict`: LIDER gathers per-(query, probed
    cluster, array) models out of the stacked ``(c, H)`` bank, so ``root_w``/
    ``root_b``/``length`` have shape ``x.shape`` and ``leaf_w``/``leaf_b``
    have ``x.shape + (n_leaves,)`` — the leaf pick becomes a
    ``take_along_axis`` over the trailing axis instead of a fancy index.
    """
    hi = jnp.maximum(params.length - 1.0, 0.0)
    pred = jnp.clip(params.root_w * x + params.root_b, 0.0, hi)
    leaf = jnp.floor(
        pred * params.n_leaves / jnp.maximum(params.length, 1.0)
    ).astype(jnp.int32)
    leaf = jnp.clip(leaf, 0, params.n_leaves - 1)
    lw = jnp.take_along_axis(params.leaf_w, leaf[..., None], axis=-1)[..., 0]
    lb = jnp.take_along_axis(params.leaf_b, leaf[..., None], axis=-1)[..., 0]
    return jnp.clip(lw * x + lb, 0.0, hi)


def gather_banked(params: RMIParams, idx: jnp.ndarray) -> RMIParams:
    """Gather per-index models out of a stacked bank: leaves ``(c, ...)`` ->
    ``idx.shape + (...,)``. Output feeds :func:`predict_banked`."""
    return jax.tree.map(lambda leaf: leaf[idx], params)


def predict_raw(params: RMIParams, x: jnp.ndarray) -> jnp.ndarray:
    """Unclipped prediction — used by the Table 4 out-of-range diagnostics."""
    leaf = _leaf_of(params.root_w, params.root_b, x, params.length, params.n_leaves)
    return params.leaf_w[leaf] * x + params.leaf_b[leaf]

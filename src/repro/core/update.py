"""Incremental index maintenance: upsert / delete without a full rebuild.

The paper specifies a one-shot offline build (Sec. 3.3.2); a production
retrieval service needs to add, remove, and persist passages while serving.
LIDER's per-cluster core models are well-shaped for that: the unit of the
offline build is a single-cluster ``bank.refit_cluster``, so incremental
maintenance is "edit the packed rows of the touched clusters, then re-run the
exact same refit on only those clusters".

**Upsert** routes each new embedding through layer 1 (exact nearest-centroid
by default — the same rule Stage 1 applies, so an upserted index is
slot-for-slot identical to a layer-1-frozen rebuild over the combined corpus;
``route="learned"`` uses the centroids-retriever ANN instead, trading that
guarantee for hashing cost at scale), appends into the free capacity slots of
the target clusters, grows the slot axis ``Lp`` in ``pad_multiple`` steps on
overflow (the only shape change — serving recompiles only then), and refits
the dirty clusters.

**Delete** tombstones: the global ids are cleared from ``bank.gids`` and the
``sorted_pos`` entries pointing at dead rows are set to -1, so verification
can never surface them (dead candidates carry ``out_id = -1``, which both the
fused kernel and ``dedup_topk`` treat as padding). Dead rows waste capacity
and window slots until a cluster's tombstone fraction crosses
``refit_threshold``; then the cluster is compacted (live rows repacked to the
slot prefix) and refit.

Dirty-cluster refits run under jit with the cluster list padded to a power of
two (sentinel -1, scattered with ``mode="drop"``), so recompile count is
O(log max-dirty-batch), not O(distinct batch sizes).

**Tiers** (DESIGN.md §Tiered embedding store): on a host-tier bank the
full-precision rescore table lives outside the jit pytree, so every lifecycle
op writes both tiers in lockstep — the jit'd append/compact returns (or is
mirrored by) the exact slot scatter / stable permutation it applied, and the
Python wrappers replay it against the host ``EmbStore`` (``write_rows`` /
``compact_clusters`` / ``grow``), then re-sync the host gid copy. Refits need
no host work at all: hash/sort/fit reads the *dequantized codes* on both
tiers, never the rescore rows.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.quant import dequantize_codes
from . import bank as bank_lib
from . import clustering
from .bank import ClusterBank
from .lider import LiderParams, padded_capacity, route_queries


@dataclasses.dataclass(frozen=True)
class UpdateStats:
    """Host-side accounting for one upsert/delete call."""

    n_added: int = 0
    n_deleted: int = 0
    n_refit: int = 0  # clusters re-fit (dirty or compacted)
    capacity: int = 0  # Lp after the call
    capacity_grew: bool = False  # shape change -> serving must recompile


def tombstone_fraction(bank: ClusterBank) -> jnp.ndarray:
    """(c,) fraction of occupied slots that are dead."""
    used = bank.sizes + bank.tombstones
    return bank.tombstones / jnp.maximum(used, 1)


def _pad_pow2(m: int, lo: int = 8) -> int:
    """Next power of two >= m (>= lo) — bounds jit recompiles of the
    dirty-cluster refit to O(log max-batch)."""
    return max(lo, 1 << (max(m, 1) - 1).bit_length())


def _pad_ids(values, fill: int = -1) -> jnp.ndarray:
    """Pad an int id list to the next power of two with ``fill`` sentinels —
    the one place the recompile-bounding batch policy lives."""
    values = jnp.asarray(values, jnp.int32)
    n = int(values.shape[0])
    return jnp.full((_pad_pow2(n),), fill, jnp.int32).at[:n].set(values)


def _scatter_fit(bank: ClusterBank, tgt, sorted_keys, sorted_pos, resc, rmi):
    """Write per-cluster fit results back at rows ``tgt`` (OOB = dropped)."""
    put = lambda old, new: old.at[tgt].set(new, mode="drop")
    return dataclasses.replace(
        bank,
        sorted_keys=put(bank.sorted_keys, sorted_keys),
        sorted_pos=put(bank.sorted_pos, sorted_pos),
        rescale=jax.tree.map(put, bank.rescale, resc),
        rmi=jax.tree.map(put, bank.rmi, rmi),
    )


@jax.jit
def _refit_clusters(bank: ClusterBank, cids: jnp.ndarray) -> ClusterBank:
    """Re-run the build-unit refit on clusters ``cids`` ((m,) int32, -1 pad)."""
    safe = jnp.maximum(cids, 0)
    rows = bank.embs[safe]
    if bank.quantized:
        # Fit on what verification scores: the dequantized stored rows —
        # identical to the rows the offline build fit (DESIGN.md §Quantized
        # bank), so online and offline fits cannot drift.
        rows = dequantize_codes(rows, bank.emb_scales[safe], bank.code_dtype)
    valid = bank.gids[safe] >= 0
    sk, sp, resc, rmi = jax.vmap(
        partial(bank_lib.refit_cluster, bank.lsh, n_leaves=bank.rmi.n_leaves)
    )(rows, valid)
    tgt = jnp.where(cids >= 0, cids, bank.n_clusters)
    return _scatter_fit(bank, tgt, sk, sp, resc, rmi)


@jax.jit
def _append_rows(
    bank: ClusterBank, new_embs: jnp.ndarray, assignment: jnp.ndarray
) -> tuple[ClusterBank, jnp.ndarray, jnp.ndarray]:
    """Scatter ``new_embs`` into the free slot prefix of their clusters.

    ``assignment == n_clusters`` marks batch-padding rows (the caller pads
    batches to a power of two to bound recompiles) — they rank past every
    real point and scatter out of range, i.e. are dropped. New global ids
    continue from ``bank.next_gid`` in input order — the same ids a
    layer-1-frozen rebuild over ``concat(old corpus, new_embs)`` would
    assign. Caller guarantees capacity (grow first).

    Returns ``(bank, flat_slot, order)`` — the slot each (input-ordered)
    row landed in and the batch permutation that ordered it, so a host-tier
    caller can replay the identical scatter against the off-device rescore
    table (``EmbStore.write_rows``); the device tier ignores them."""
    c, lp = bank.gids.shape
    n = new_embs.shape[0]
    used = bank.sizes + bank.tombstones  # occupied slot prefix per cluster
    counts = jnp.bincount(assignment, length=c).astype(jnp.int32)  # pads drop
    # Slot per point: used[cluster] + rank among this batch's same-cluster
    # points (in input order), via the group_by_cluster ranking trick.
    order = jnp.argsort(assignment, stable=True).astype(jnp.int32)
    sorted_a = assignment[order]
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n, dtype=jnp.int32) - starts[jnp.minimum(sorted_a, c - 1)]
    flat_slot = jnp.where(
        sorted_a < c, sorted_a * lp + used[jnp.minimum(sorted_a, c - 1)] + rank,
        c * lp,  # batch padding -> out of range, dropped by mode="drop"
    )
    new_gids = bank.next_gid + order
    ordered = new_embs[order]
    # bank.store_rows is the single float-rows-to-storage conversion point
    # (same call the offline pack makes), so an upserted slot is
    # bit-identical to the slot a full rebuild over the combined corpus
    # would produce.
    stored, scl, res, sk = bank_lib.store_rows(ordered, bank.storage_dtype)
    extra = {}
    if bank.quantized:
        extra = dict(
            emb_scales=bank.emb_scales.reshape(-1)
            .at[flat_slot]
            .set(scl, mode="drop")
            .reshape(c, lp),
        )
        if bank.rescore_embs is not None:  # device tier; host writes outside
            extra["rescore_embs"] = (
                bank.rescore_embs.reshape(c * lp, -1)
                .at[flat_slot]
                .set(res.astype(bank.rescore_embs.dtype), mode="drop")
                .reshape(c, lp, -1)
            )
        if bank.sketches is not None:
            # Sketches are row-local (sign of the raw row — same rows
            # store_rows just packed), so the append scatter keeps them
            # byte-identical to a layer-1-frozen rebuild's sketch table.
            extra["sketches"] = (
                bank.sketches.reshape(c * lp, -1)
                .at[flat_slot]
                .set(sk, mode="drop")
                .reshape(c, lp, -1)
            )
    bank = dataclasses.replace(
        bank,
        gids=bank.gids.reshape(-1)
        .at[flat_slot]
        .set(new_gids, mode="drop")
        .reshape(c, lp),
        embs=bank.embs.reshape(c * lp, -1)
        .at[flat_slot]
        .set(stored, mode="drop")
        .reshape(c, lp, -1),
        sizes=bank.sizes + counts,
        next_gid=bank.next_gid + jnp.sum(assignment < c, dtype=jnp.int32),
        **extra,
    )
    return bank, flat_slot, order


def upsert(
    params: LiderParams,
    new_embs: jnp.ndarray,
    *,
    pad_multiple: int = 8,
    route: str = "exact",
    n_probe_route: int = 1,
) -> tuple[LiderParams, UpdateStats]:
    """Add ``new_embs`` (n, d) to the index; refit only the touched clusters.

    ``route="exact"`` assigns by nearest centroid (Stage-1 rule — keeps the
    rebuild-parity guarantee); ``route="learned"`` asks the centroids
    retriever for the top-1 cluster. Layer 1 (centroids + retriever) is never
    refit — the paper's centroid geometry drifts only with distribution shift,
    which calls for a full rebuild anyway.

    Returns the updated params and stats; ``stats.capacity_grew`` tells the
    serving layer whether compiled search functions must be re-traced.
    """
    bank = params.bank
    c = bank.n_clusters
    new_embs = jnp.asarray(new_embs)
    if route == "exact":
        assignment, _ = clustering.assign_chunked(new_embs, params.centroids)
    elif route == "learned":
        routed = route_queries(params, new_embs, n_probe=n_probe_route)
        assignment = routed.ids[:, 0].astype(jnp.int32)
    else:
        raise ValueError(f"route must be 'exact' or 'learned', got {route!r}")

    counts = jnp.bincount(assignment, length=c).astype(jnp.int32)
    needed = int(jax.device_get(jnp.max(bank.sizes + bank.tombstones + counts)))
    grew = needed > bank.capacity
    if grew:
        bank = bank_lib.grow_bank(
            bank, padded_capacity(needed, None, pad_multiple)
        )

    # Pad the batch to a power of two (sentinel cluster c) so repeated
    # variable-size upserts reuse a bounded set of compiled appends.
    n = int(new_embs.shape[0])
    m = _pad_pow2(n)
    embs_p = jnp.zeros((m, new_embs.shape[1]), new_embs.dtype).at[:n].set(new_embs)
    assign_p = jnp.full((m,), c, jnp.int32).at[:n].set(assignment)
    bank, flat_slot, order = _append_rows(bank, embs_p, assign_p)

    if bank.store is not None:
        # Host tier writes in lockstep: replay the exact append scatter
        # against the off-device rescore table (same slots, same rows —
        # DESIGN.md §Tiered embedding store), then refresh the synced gid
        # copy the distributed front-end maps rows through.
        rows = np.asarray(jax.device_get(embs_p), np.float32)[
            np.asarray(jax.device_get(order))
        ]
        bank.store.write_rows(np.asarray(jax.device_get(flat_slot)), rows)
        bank.store.sync_gids(np.asarray(jax.device_get(bank.gids)))

    dirty = np.unique(np.asarray(jax.device_get(assignment)))
    dirty = dirty[(dirty >= 0) & (dirty < c)]
    n_dirty = int(dirty.shape[0])
    bank = _refit_clusters(bank, _pad_ids(dirty))

    stats = UpdateStats(
        n_added=n,
        n_refit=n_dirty,
        capacity=bank.capacity,
        capacity_grew=grew,
    )
    return dataclasses.replace(params, bank=bank), stats


@jax.jit
def _tombstone(bank: ClusterBank, dead_gids: jnp.ndarray):
    """Mark global ids dead: clear ``gids`` rows and the ``sorted_pos``
    entries that point at them. Returns (bank, newly-dead count per cluster)."""
    c, h, lp = bank.sorted_pos.shape
    # Membership via sort + searchsorted: O(c·Lp·log g), not the (c, Lp, g)
    # broadcast compare. The -1 batch-pad sentinels sort first and are
    # excluded by the gids >= 0 guard.
    sorted_dead = jnp.sort(dead_gids)
    at = jnp.minimum(
        jnp.searchsorted(sorted_dead, bank.gids), sorted_dead.shape[0] - 1
    )
    dead = (sorted_dead[at] == bank.gids) & (bank.gids >= 0)  # (c, Lp)
    n_dead = dead.sum(-1).astype(jnp.int32)
    sp = bank.sorted_pos.reshape(c, h * lp)
    dead_at = jax.vmap(lambda sd, s: sd[jnp.maximum(s, 0)])(dead, sp) & (sp >= 0)
    bank = dataclasses.replace(
        bank,
        gids=jnp.where(dead, -1, bank.gids),
        sorted_pos=jnp.where(dead_at, -1, sp).reshape(c, h, lp),
        sizes=bank.sizes - n_dead,
        tombstones=bank.tombstones + n_dead,
    )
    return bank, n_dead


@jax.jit
def _compact_clusters(bank: ClusterBank, cids: jnp.ndarray) -> ClusterBank:
    """Repack live rows of clusters ``cids`` to the slot prefix and refit.

    Live rows keep their relative order (stable sort), so a compacted cluster
    is row-for-row what a fresh pack of its surviving points would produce."""
    safe = jnp.maximum(cids, 0)
    gid_rows = bank.gids[safe]  # (m, Lp)
    live = gid_rows >= 0
    order = jnp.argsort(~live, axis=-1, stable=True)
    gid_p = jnp.take_along_axis(gid_rows, order, axis=-1)
    live_p = gid_p >= 0
    # Permute the *stored* representation (codes stay codes — quantization
    # is row-local, so moving a row never re-rounds it; a compacted cluster
    # is byte-for-byte what a fresh pack of its survivors would store).
    emb_p = jnp.where(
        live_p[..., None],
        jnp.take_along_axis(bank.embs[safe], order[..., None], axis=1),
        0,
    ).astype(bank.embs.dtype)
    extra = {}
    if bank.quantized:
        scl_p = jnp.where(
            live_p,
            jnp.take_along_axis(bank.emb_scales[safe], order, axis=-1),
            1.0,  # the all-zero-row convention (matches a fresh pack's pads)
        )
        # Host-tier banks permute the off-device table in delete() instead
        # (EmbStore.compact_clusters — same stable order, outside the jit).
        res_p = None
        if bank.rescore_embs is not None:
            res_p = jnp.where(
                live_p[..., None],
                jnp.take_along_axis(
                    bank.rescore_embs[safe], order[..., None], axis=1
                ),
                0,
            ).astype(bank.rescore_embs.dtype)
        # Sketches permute like the codes (row-local — moving a row never
        # re-packs it); dead slots revert to zero words, the fresh-pack pad.
        sk_p = None
        if bank.sketches is not None:
            sk_p = jnp.where(
                live_p[..., None],
                jnp.take_along_axis(
                    bank.sketches[safe], order[..., None], axis=1
                ),
                jnp.uint32(0),
            ).astype(bank.sketches.dtype)
        fit_rows = dequantize_codes(emb_p, scl_p, bank.code_dtype)
    else:
        scl_p = res_p = sk_p = None
        fit_rows = emb_p
    sk, sp, resc, rmi = jax.vmap(
        partial(bank_lib.refit_cluster, bank.lsh, n_leaves=bank.rmi.n_leaves)
    )(fit_rows, live_p)
    tgt = jnp.where(cids >= 0, cids, bank.n_clusters)
    put = lambda old, new: old.at[tgt].set(new, mode="drop")
    bank = _scatter_fit(bank, tgt, sk, sp, resc, rmi)
    if bank.quantized:
        extra = dict(emb_scales=put(bank.emb_scales, scl_p))
        if res_p is not None:
            extra["rescore_embs"] = put(bank.rescore_embs, res_p)
        if sk_p is not None:
            extra["sketches"] = put(bank.sketches, sk_p)
    return dataclasses.replace(
        bank,
        embs=put(bank.embs, emb_p),
        gids=put(bank.gids, gid_p),
        tombstones=bank.tombstones.at[tgt].set(0, mode="drop"),
        **extra,
    )


def delete(
    params: LiderParams,
    gids: jnp.ndarray,
    *,
    refit_threshold: float = 0.25,
) -> tuple[LiderParams, UpdateStats]:
    """Tombstone global ids ``gids`` ((g,) int32); lazily compact + refit.

    Tombstoned ids can never be surfaced (their candidates carry ``out_id =
    -1`` — kernel-level padding). Clusters whose dead fraction exceeds
    ``refit_threshold`` are compacted immediately; pass ``0.0`` to force
    eager compaction, ``1.0`` to defer indefinitely. Capacity never changes.
    """
    bank, n_dead = _tombstone(params.bank, _pad_ids(gids))
    n_deleted = int(jax.device_get(n_dead.sum()))

    frac = tombstone_fraction(bank)
    to_compact = np.nonzero(
        np.asarray(jax.device_get((frac > refit_threshold) & (bank.tombstones > 0)))
    )[0]
    n_compact = int(to_compact.shape[0])
    if n_compact:
        if bank.store is not None:
            # Host tier compacts in lockstep: same stable live-rows-first
            # order, derived from the same pre-compaction gid rows.
            bank.store.compact_clusters(
                to_compact, np.asarray(jax.device_get(bank.gids))[to_compact]
            )
        bank = _compact_clusters(bank, _pad_ids(to_compact))
    if bank.store is not None:
        bank.store.sync_gids(np.asarray(jax.device_get(bank.gids)))

    stats = UpdateStats(
        n_deleted=n_deleted,
        n_refit=n_compact,
        capacity=bank.capacity,
        capacity_grew=False,
    )
    return dataclasses.replace(params, bank=bank), stats

"""LIDER: the clustering-based two-layer learned index (paper Sec. 3).

Layer 1: a *centroids retriever* (one core model over the k-means centroids)
routes each query to ``n_probe`` (= paper c0) clusters. Layer 2: a
:class:`~repro.core.bank.ClusterBank` — the per-cluster retrievers stacked
into dense padded tensors so a (query x probed-cluster) batch is pure gather
+ matmul dataflow (see ``core/bank.py`` for the layout).

Build is staged (paper Sec. 3.3.2): ``assign`` (k-means or nearest-centroid
against precomputed centroids) -> ``pack`` (capacity slots) -> ``hash/sort/
fit`` via ``vmap(bank.refit_cluster)``. The same ``refit_cluster`` unit
powers the incremental upsert/delete path in ``core.update``, so online
maintenance and offline build cannot drift.

``search_lider`` is the single-device reference; ``core.distributed`` wraps
the same ``incluster_search`` math in a shard_map with capacity-based
query->cluster-shard dispatch for the production mesh.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import bank as bank_lib
from . import clustering, lsh as lsh_lib, rescale as rescale_lib, rmi as rmi_lib
from ..kernels.ops import sketch_topk_op, verify_topk_grouped_op, verify_topk_op
from .bank import ClusterBank
from .core_model import CoreModelParams, TopK, build_core_model, search_core_model
from .types import pytree_dataclass
from .utils import dedup_topk


@dataclasses.dataclass(frozen=True)
class LiderConfig:
    """Static build/search configuration (paper Sec. 7.2.1 defaults)."""

    n_clusters: int = 1000  # c
    n_probe: int = 20  # c0
    n_arrays: int = 10  # H (in-cluster)
    n_arrays_centroid: int = 10  # H (centroids retriever)
    key_len: int | None = None  # M (in-cluster); None -> ceil(log2 Lp)
    key_len_centroid: int | None = None  # M (centroids); None -> ceil(log2 c)
    n_leaves: int = 5  # RMI width W_i
    n_leaves_centroid: int = 10  # RMI width W_c
    r0: int = 4  # expansion range factor, R = r0 * k
    r0_centroid: int = 4
    kmeans_iters: int = 20
    capacity: int | None = None  # Lp cap; None -> max cluster size (no drops)
    pad_multiple: int = 8
    refine: bool = False  # beyond-paper last-mile searchsorted correction
    # Verification-kernel escape hatch: None -> fused Pallas pass on TPU,
    # materialized reference elsewhere; True/False forces either path.
    # Like n_probe/refine, search entry points take this as a kwarg and
    # launchers feed it from the config (DESIGN.md §Verification-kernel).
    use_fused: bool | None = None
    # Embedding storage dtype (DESIGN.md §Quantized bank): "float32",
    # "bfloat16", "int8", or "int4". int8 cuts the compulsory candidate-row
    # gather 4x vs f32; int4 packs two codes per byte (8x, 0.5 B/elem).
    # Both quantized dtypes add an exact rescore pass over the provisional
    # top-(rescore_factor * k) from the full-precision side table.
    storage_dtype: str = "float32"
    rescore_factor: int = 4  # k' = rescore_factor * k (quantized storage only)
    # Where the full-precision rescore side table lives (quantized storage
    # only; DESIGN.md §Tiered embedding store). "device": a pytree leaf next to
    # the codes (PR-4 layout — costs ~25% more HBM than f32). "host": a
    # process-local pinned host array outside the pytree; search becomes
    # the staged fetch->rescore pipeline and the device-resident index
    # shrinks to codes + scales (~0.25x of f32).
    rescore_tier: str = "device"
    # Verification-kernel candidate block size; None -> kernel default (256).
    # Swept by the Pareto autotuner alongside the quantization knobs.
    block_c: int | None = None
    # Cluster-major multi-query batching (DESIGN.md §Cluster-major schedule;
    # quantized banks only): queries in a batch probing the same cluster are
    # grouped into block_q-wide tiles so the cluster's rows are streamed
    # once per tile instead of once per query — the big first-pass DMA win
    # under Zipf-skewed traffic. None keeps the per-query schedule.
    # Bit-identical results either way; swept by the Pareto autotuner.
    block_q: int | None = None
    # Binary-sketch pre-filter tier (DESIGN.md §Binary sketch tier;
    # quantized banks only): a 1-bit Hamming first pass over the packed
    # sign-sketch table (1/8 the int8 row bytes) keeps the top
    # ``sketch_factor * k'`` survivor rows per query, so the int4/int8 code
    # DMA + MXU pass touches only survivors. None disables the tier; a
    # factor large enough to cover every candidate is bit-identical to the
    # unfiltered pass (tests gate this). Swept by the Pareto autotuner.
    sketch_factor: int | None = None
    # Adaptive probe pruning (DESIGN.md §Adaptive speed-quality control
    # plane): probes whose layer-1 centroid score falls more than this
    # margin below the per-query best are masked to -1 before layer 2.
    # None disables pruning (bit-identical to the fixed-n_probe search).
    prune_margin: float | None = None
    # Capacity overflow policy: when ``capacity`` is below the max cluster
    # size, overflow passages are silently unretrievable unless this is set
    # (bank.build_bank raises CapacityOverflowError otherwise).
    allow_drops: bool = False


@pytree_dataclass
class LiderParams:
    centroid_cm: CoreModelParams
    centroids: jnp.ndarray  # (c, d)
    bank: ClusterBank  # stacked per-cluster state (core/bank.py)

    @property
    def n_clusters(self) -> int:
        return self.bank.n_clusters

    @property
    def capacity(self) -> int:
        return self.bank.capacity

    @property
    def dim(self) -> int:
        return self.bank.dim


# ---------------------------------------------------------------------------
# Build (paper Sec. 3.3.2: Stage 1 clustering, Stage 2 CR, Stage 3 IRs)
# ---------------------------------------------------------------------------


def padded_capacity(max_size: int, cap: int | None, pad_multiple: int) -> int:
    """Slot count per cluster: requested (or max) size, padded for the TPU."""
    cap = cap or max_size
    return max(pad_multiple, math.ceil(cap / pad_multiple) * pad_multiple)


def assign_points(
    rng: jax.Array,
    embs: jnp.ndarray,
    config: LiderConfig,
    *,
    centroids: jnp.ndarray | None = None,
) -> clustering.KMeansResult:
    """Stage 1: k-means, or nearest-centroid against precomputed centroids.

    The ``centroids`` override is the layer-1-frozen rebuild used by the
    update lifecycle (and by multi-stage corpora that share one routing
    layer): assignment is the exact nearest centroid, the same rule the final
    Lloyd step applies — so an index built this way is slot-for-slot
    comparable with one grown by ``core.update.upsert``.
    """
    if centroids is None:
        return clustering.kmeans(rng, embs, config.n_clusters, iters=config.kmeans_iters)
    assignment, _ = clustering.assign_chunked(embs, centroids)
    return clustering.KMeansResult(centroids=centroids, assignment=assignment)


@dataclasses.dataclass(frozen=True)
class BuildStats:
    """Host-side accounting for one offline build."""

    n_indexed: int  # passages that got a slot
    n_dropped: int  # capacity-overflow drops (0 unless allow_drops=True)
    capacity: int  # padded per-cluster slot count Lp


def build_lider(
    rng: jax.Array,
    embs: jnp.ndarray,
    config: LiderConfig,
    *,
    centroids: jnp.ndarray | None = None,
    return_stats: bool = False,
) -> LiderParams | tuple[LiderParams, BuildStats]:
    n, dim = embs.shape
    c = config.n_clusters
    rng_km, rng_cen, rng_in = jax.random.split(rng, 3)

    # Stage 1: clustering (or routing against supplied centroids).
    km = assign_points(rng_km, embs, config, centroids=centroids)
    sizes = jnp.bincount(km.assignment, length=c).astype(jnp.int32)
    max_size = int(jax.device_get(jnp.max(sizes)))
    cap = padded_capacity(max_size, config.capacity, config.pad_multiple)

    # Stage 3: pack -> hash/sort -> fit (vmap of the single-cluster refit).
    # Packing counts capacity-overflow drops; unless the config opts in via
    # allow_drops, a lossy pack raises instead of silently losing passages.
    bank, n_dropped = bank_lib.build_bank(
        rng_in,
        embs,
        km.assignment,
        n_clusters=c,
        capacity=cap,
        n_arrays=config.n_arrays,
        key_len=config.key_len or lsh_lib.suggest_key_len(cap),
        n_leaves=config.n_leaves,
        allow_drops=config.allow_drops,
        storage_dtype=config.storage_dtype,
        rescore_tier=config.rescore_tier,
    )

    # Stage 2: centroids retriever.
    centroid_cm = build_core_model(
        rng_cen,
        km.centroids,
        n_arrays=config.n_arrays_centroid,
        key_len=config.key_len_centroid or lsh_lib.suggest_key_len(c),
        n_leaves=config.n_leaves_centroid,
    )

    params = LiderParams(centroid_cm=centroid_cm, centroids=km.centroids, bank=bank)
    if return_stats:
        return params, BuildStats(
            n_indexed=n - n_dropped, n_dropped=n_dropped, capacity=cap
        )
    return params


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


def prune_probes(
    cids: jnp.ndarray, scores: jnp.ndarray, prune_margin: float | None
) -> jnp.ndarray:
    """Margin rule of the adaptive control plane (DESIGN.md §Adaptive).

    ``cids``/``scores``: (B, P) layer-1 routing output. Probes whose centroid
    score falls more than ``prune_margin`` below the per-query best are
    masked to -1 — shapes stay static (no recompiles per margin value; the
    margin itself is traced), downstream layers treat -1 as an unused probe
    slot. ``None`` returns ``cids`` untouched (bit-identical fixed-probe
    search).
    """
    if prune_margin is None:
        return cids
    valid = cids >= 0
    best = jnp.max(
        jnp.where(valid, scores, -jnp.inf), axis=-1, keepdims=True
    )  # (B, 1)
    keep = scores >= best - prune_margin
    return jnp.where(valid & keep, cids, -1)


def route_queries(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    n_probe: int,
    r0: int = 4,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    block_c: int | None = None,
) -> TopK:
    """Layer-1: centroids retriever -> (B, n_probe) cluster ids + scores.

    With ``prune_margin`` set, low-confidence probes come back masked to
    (-1, -inf) — the slot count stays ``n_probe`` so downstream shapes are
    static. The centroid table itself always stays full precision (it is
    KB–MB sized; quantizing it would risk routing quality for no traffic
    win).
    """
    routed = search_core_model(
        params.centroid_cm, params.centroids, queries, k=n_probe, r0=r0,
        use_fused=use_fused, block_c=block_c,
    )
    if prune_margin is None:
        return routed
    cids = prune_probes(routed.ids, routed.scores, prune_margin)
    return TopK(
        ids=cids, scores=jnp.where(cids >= 0, routed.scores, -jnp.inf)
    )


def set_rescore_tier(params: LiderParams, tier: str) -> LiderParams:
    """Move the index's rescore table between storage tiers (§Tiered store).

    Search results are bit-identical across the move; only where the
    full-precision rows live — and therefore which search pipeline runs —
    changes (``bank.set_rescore_tier``).
    """
    return dataclasses.replace(
        params, bank=bank_lib.set_rescore_tier(params.bank, tier)
    )


def _bank_candidates(
    bank: ClusterBank,
    queries: jnp.ndarray,
    cids: jnp.ndarray,
    *,
    k: int,
    r0: int,
    refine: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate generation over the probed clusters (hash -> rescale -> RMI
    -> window expansion). Returns ``(flat_emb, gids)``, both (B, P, H, R):
    flat ``(cluster, slot)`` rows into the ``(c*Lp, ...)`` tables and the
    matching global passage ids (-1 at dead/invalid candidates). Shared by
    the single-pass float search, the quantized two-stage search, and the
    tiered first pass (§Tiered embedding store)."""
    c, h, lp = bank.sorted_keys.shape
    b, p = cids.shape
    r = min(r0 * k, lp)

    qkeys = lsh_lib.hash_vectors(bank.lsh, queries)  # (B, H)
    safe_cid = jnp.clip(cids, 0, c - 1)
    cvalid = cids >= 0  # (B, P)

    # Gather per-(query, probe) rescale + RMI models out of the bank, then
    # predict positions with the banked RMI form.
    resc = jax.tree.map(lambda leaf: leaf[safe_cid], bank.rescale)  # (B, P, H)
    scaled = rescale_lib.rescale(resc, qkeys[:, None, :])  # (B, P, H)
    pos = rmi_lib.predict_banked(
        rmi_lib.gather_banked(bank.rmi, safe_cid), scaled
    )  # (B, P, H)

    h_idx = jnp.arange(h, dtype=jnp.int32)[None, None, :, None]
    if refine:
        # Beyond-paper last-mile: gather a 2R key window around the RMI
        # prediction (keys are 4 B vs d*4 B embeddings) and binary-search the
        # exact position inside it, then expand only R around the truth.
        w1 = min(2 * r, lp)
        start1 = jnp.clip(jnp.round(pos).astype(jnp.int32) - w1 // 2, 0, lp - w1)
        idx1 = start1[..., None] + jnp.arange(w1, dtype=jnp.int32)
        flat1 = (safe_cid[:, :, None, None] * h + h_idx) * lp + idx1
        keys_win = jnp.take(bank.sorted_keys.reshape(-1), flat1)  # (B,P,H,W1)
        qk = jnp.broadcast_to(qkeys[:, None, :], (b, p, h)).reshape(-1)
        rows = keys_win.reshape(-1, w1)
        off = jax.vmap(lambda row, q: jnp.searchsorted(row, q))(rows, qk)
        pos = (start1 + off.reshape(b, p, h).astype(jnp.int32)).astype(jnp.float32)

    start = jnp.clip(jnp.round(pos).astype(jnp.int32) - r // 2, 0, lp - r)
    idx = start[..., None] + jnp.arange(r, dtype=jnp.int32)  # (B, P, H, R)
    flat = (safe_cid[:, :, None, None] * h + h_idx) * lp + idx
    local_pos = jnp.take(bank.sorted_pos.reshape(-1), flat)  # (B, P, H, R)

    valid = (local_pos >= 0) & cvalid[:, :, None, None]
    flat_emb = safe_cid[:, :, None, None] * lp + jnp.maximum(local_pos, 0)
    gids = jnp.take(bank.gids.reshape(-1), flat_emb)
    gids = jnp.where(valid, gids, -1)
    return flat_emb, gids


def _verify_bank_rows(
    bank: ClusterBank,
    flat_rows: jnp.ndarray,
    out_gids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    rescore_factor: int,
    block_c: int | None,
    use_pallas: bool | None,
    sketch_factor: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Verify ``(Bq, C)`` flat bank rows -> gid-space top-k ids + scores
    (device-tier rescore table).

    The single verification funnel for both ``incluster_search`` shapes
    (merged and per-pair). On a float bank this is one ``verify_topk_op``
    call deduped by global id. On a quantized bank it is the two-stage pass
    (DESIGN.md §Quantized bank):

    1. int8 first pass over the code table, deduped by *flat row* — exact
       within the bank, since a passage occupies exactly one (cluster, slot)
       — keeping the provisional top-``k' = rescore_factor*k``;
    2. exact rescore of those k' rows from the full-precision side table
       (a gather k'/C the size of the first pass), reusing the same fused
       kernel; final rows map back to global ids through ``bank.gids``.

    On a *host-tier* bank the rescore table is not device-resident, so the
    second stage cannot be traced here; stage 1 is :func:`provisional_rows`
    and the fetch + rescore run between jits (:func:`search_lider` /
    ``serving.RetrievalEngine`` pipeline) — this function is device-tier
    only.

    Score ties between distinct passages break by smallest flat row on the
    quantized path (vs smallest gid on the float path) — both deterministic.
    """
    c, lp = bank.gids.shape
    flat_table = bank.embs.reshape(c * lp, -1)
    if not bank.quantized:
        return verify_topk_op(
            flat_table,
            flat_rows,
            queries,
            k=k,
            out_ids=out_gids,
            block_c=block_c,
            use_pallas=use_pallas,
        )
    out_rows = jnp.where(out_gids >= 0, flat_rows, -1)
    kp = min(max(rescore_factor, 1) * k, out_rows.shape[-1])
    if sketch_factor is not None and bank.sketches is not None:
        # Binary-sketch pre-filter (DESIGN.md §Binary sketch tier): 1-bit
        # Hamming pass over the packed sign sketches keeps the top
        # ``sketch_factor * k'`` survivor rows (deduped by flat row, same
        # tie-break as the int pass), so the code-table DMA below streams
        # only survivors. A factor covering every distinct candidate is
        # bit-identical to the unfiltered pass: survivors then hold all
        # valid rows, per-row int scores are unchanged, and dedup collapses
        # the duplicates the sketch pass already collapsed.
        m = min(max(sketch_factor, 1) * kp, out_rows.shape[-1])
        surv, _ = sketch_topk_op(
            bank.sketches.reshape(c * lp, -1),
            flat_rows,
            queries,
            k=m,
            out_ids=out_rows,
            block_c=block_c,
            use_pallas=use_pallas,
        )
        flat_rows = jnp.maximum(surv, 0)
        out_rows = surv
    prov_rows, _ = verify_topk_op(
        flat_table,
        flat_rows,
        queries,
        k=kp,
        out_ids=out_rows,
        scales=bank.emb_scales.reshape(-1),
        block_c=block_c,
        code_dtype=bank.code_dtype,
        use_pallas=use_pallas,
    )
    rescore_table = bank.rescore_embs.reshape(c * lp, -1)
    rows, scores = verify_topk_op(
        rescore_table,
        jnp.maximum(prov_rows, 0),
        queries,
        k=k,
        out_ids=prov_rows,
        block_c=block_c,
        use_pallas=use_pallas,
    )
    ids = jnp.where(rows >= 0, bank.gids.reshape(-1)[jnp.maximum(rows, 0)], -1)
    return ids, scores


def incluster_search(
    params: LiderParams,
    queries: jnp.ndarray,
    cids: jnp.ndarray,
    *,
    k: int,
    r0: int = 4,
    refine: bool = False,
    merge: bool = True,
    use_fused: bool | None = None,
    cid_scores: jnp.ndarray | None = None,
    prune_margin: float | None = None,
    rescore_factor: int = 4,
    block_c: int | None = None,
    sketch_factor: int | None = None,
) -> TopK:
    """Layer-2: search the probed clusters for each query.

    ``queries``: (B, d); ``cids``: (B, P) cluster ids (-1 = unused probe slot).
    With ``merge=False`` returns the per-pair top-k (B, P, k) — the shape the
    distributed capacity-dispatch path scatters back before merging.
    With ``cid_scores`` (the layer-1 routing scores) and ``prune_margin``
    both set, probes outside the margin are masked to -1 here instead of by
    the caller — either spelling yields the same candidate mask.

    Verification goes through ``verify_topk_op`` (``use_fused`` as in
    ``LiderConfig``): the fused kernel streams the gathered rows through VMEM
    and emits only the (B, k) result, instead of materializing the
    (B, P, H, R, d) candidate tensor in HBM before the einsum. On an int8
    bank the pass runs in the compressed domain and is followed by an exact
    rescore of the provisional top-``rescore_factor * k`` rows
    (:func:`_verify_bank_rows`); ``block_c`` tunes the kernel's candidate
    block size.
    """
    if prune_margin is not None:
        if cid_scores is None:
            raise ValueError("prune_margin needs cid_scores (layer-1 scores)")
        cids = prune_probes(cids, cid_scores, prune_margin)
    bank = params.bank
    if bank.rescore_tier == "host":
        raise ValueError(
            "incluster_search cannot complete on a host-tier bank — the "
            "rescore table is off-device; use search_lider (staged "
            "fetch->rescore pipeline) or provisional_rows + "
            "rescore_fetched_rows directly (DESIGN.md §Tiered embedding "
            "store)"
        )
    b, p = cids.shape
    flat_emb, gids = _bank_candidates(
        bank, queries, cids, k=k, r0=r0, refine=refine
    )

    # Verification: gather rows from the flat (c*Lp, d) table (row_ids =
    # flat_emb), dedup/report by global passage id (out_ids = gids, -1 where
    # invalid — tombstoned rows carry gid -1 and are suppressed here).
    # Scoring happens in the embedding storage dtype (bf16 stays bf16 on the
    # MXU, int8 runs int8xint8->int32 + exact rescore) with fp32 accumulation
    # for a stable top-k ordering.
    if merge:
        ids, sc = _verify_bank_rows(
            bank,
            flat_emb.reshape(b, -1),
            gids.reshape(b, -1),
            queries,
            k=k,
            rescore_factor=rescore_factor,
            block_c=block_c,
            use_pallas=use_fused,
            sketch_factor=sketch_factor,
        )
        return TopK(ids=ids, scores=sc)
    # Per-pair top-k: flatten (query, probe) pairs into the batch axis so the
    # same kernel covers the shape the distributed path scatters back.
    pair_q = jnp.broadcast_to(queries[:, None, :], (b, p, queries.shape[-1]))
    ids, sc = _verify_bank_rows(
        bank,
        flat_emb.reshape(b * p, -1),
        gids.reshape(b * p, -1),
        pair_q.reshape(b * p, -1),
        k=k,
        rescore_factor=rescore_factor,
        block_c=block_c,
        use_pallas=use_fused,
        sketch_factor=sketch_factor,
    )
    return TopK(ids=ids.reshape(b, p, k), scores=sc.reshape(b, p, k))


@partial(
    jax.jit,
    static_argnames=(
        "k", "n_probe", "r0", "r0_centroid", "refine", "use_fused",
        "with_stats", "rescore_factor", "block_c", "sketch_factor",
    ),
)
def _search_lider_device(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    k: int,
    n_probe: int = 20,
    r0: int = 4,
    r0_centroid: int = 4,
    refine: bool = False,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    with_stats: bool = False,
    rescore_factor: int = 4,
    block_c: int | None = None,
    sketch_factor: int | None = None,
) -> TopK | tuple[TopK, jnp.ndarray]:
    """Single-jit search for device-tier banks (float, or int8 with the
    rescore table resident next to the codes)."""
    routed = route_queries(
        params, queries, n_probe=n_probe, r0=r0_centroid, use_fused=use_fused,
        block_c=block_c,
    )
    cids = prune_probes(routed.ids, routed.scores, prune_margin)
    out = incluster_search(
        params, queries, cids, k=k, r0=r0, refine=refine,
        use_fused=use_fused, rescore_factor=rescore_factor, block_c=block_c,
        sketch_factor=sketch_factor,
    )
    if with_stats:
        pruned = (routed.ids >= 0) & (cids < 0)
        return out, pruned
    return out


# ---------------------------------------------------------------------------
# Tiered (host-resident rescore table) search: three explicit stages
# (DESIGN.md §Tiered embedding store)
# ---------------------------------------------------------------------------


def provisional_rows(
    params: LiderParams,
    queries: jnp.ndarray,
    cids: jnp.ndarray,
    *,
    k: int,
    r0: int = 4,
    refine: bool = False,
    merge: bool = True,
    use_fused: bool | None = None,
    rescore_factor: int = 4,
    block_c: int | None = None,
    sketch_factor: int | None = None,
) -> TopK:
    """Stage 1 of the tiered search: compressed-domain first pass only.

    Same candidate generation and int8 first pass as the device-tier
    quantized search — deduped by flat bank row, same tie-break — but stops
    at the provisional top-``k' = rescore_factor*k``: ``ids`` are *flat bank
    rows* (-1 padding) and ``scores`` are the compressed-domain scores. The
    caller fetches those rows from the host tier (``bank.store.fetch``) and
    finishes with :func:`rescore_fetched_rows` / :func:`host_rescore`.
    ``merge=False`` keeps the per-(query, probe) pair shape for the
    distributed capacity-dispatch path.
    """
    bank = params.bank
    if not bank.quantized:
        raise ValueError("provisional_rows needs a quantized (int8/int4) bank")
    b, p = cids.shape
    flat_emb, gids = _bank_candidates(
        bank, queries, cids, k=k, r0=r0, refine=refine
    )
    c, lp = bank.gids.shape
    flat_table = bank.embs.reshape(c * lp, -1)
    scales = bank.emb_scales.reshape(-1)
    if merge:
        fr = flat_emb.reshape(b, -1)
        og = gids.reshape(b, -1)
        q = queries
    else:
        pair_q = jnp.broadcast_to(queries[:, None, :], (b, p, queries.shape[-1]))
        fr = flat_emb.reshape(b * p, -1)
        og = gids.reshape(b * p, -1)
        q = pair_q.reshape(b * p, -1)
    out_rows = jnp.where(og >= 0, fr, -1)
    kp = min(max(rescore_factor, 1) * k, fr.shape[-1])
    if sketch_factor is not None and bank.sketches is not None:
        # Sketch pre-filter, same contract as the device-tier funnel
        # (_verify_bank_rows): survivors replace the candidate list so the
        # code pass below streams sketch_factor*k' rows instead of all C.
        m = min(max(sketch_factor, 1) * kp, fr.shape[-1])
        surv, _ = sketch_topk_op(
            bank.sketches.reshape(c * lp, -1), fr, q, k=m, out_ids=out_rows,
            block_c=block_c, use_pallas=use_fused,
        )
        fr = jnp.maximum(surv, 0)
        out_rows = surv
    rows, sc = verify_topk_op(
        flat_table, fr, q, k=kp, out_ids=out_rows, scales=scales,
        block_c=block_c, code_dtype=bank.code_dtype, use_pallas=use_fused,
    )
    if not merge:
        return TopK(ids=rows.reshape(b, p, kp), scores=sc.reshape(b, p, kp))
    return TopK(ids=rows, scores=sc)


def rescore_fetched_rows(
    fetched: jnp.ndarray,
    out_ids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    use_fused: bool | None = None,
    block_c: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stage 3 of the tiered search: exact rescore over host-fetched rows.

    ``fetched``: (B, k', d) full-precision rows (the H2D payload — only
    ``B*k'*d`` floats); ``out_ids``: (B, k') the ids to dedup/report by
    (flat bank rows on the single-device path — the device-tier tie-break —
    or global ids on the distributed path). Runs the *same* fused kernel as
    the device-tier rescore with the fetched block as its table, so scores
    and tie-breaks are bit-identical to scoring against the resident table.
    """
    b, kp, d = fetched.shape
    table = fetched.reshape(b * kp, d)
    row_ids = jnp.arange(b * kp, dtype=jnp.int32).reshape(b, kp)
    return verify_topk_op(
        table, row_ids, queries, k=k, out_ids=out_ids,
        block_c=block_c, use_pallas=use_fused,
    )


@partial(
    jax.jit,
    static_argnames=(
        "k", "n_probe", "r0", "r0_centroid", "refine", "use_fused",
        "rescore_factor", "block_c", "sketch_factor",
    ),
)
def host_first_pass(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    k: int,
    n_probe: int = 20,
    r0: int = 4,
    r0_centroid: int = 4,
    refine: bool = False,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    rescore_factor: int = 4,
    block_c: int | None = None,
    sketch_factor: int | None = None,
) -> tuple[TopK, jnp.ndarray]:
    """Jit'd stage 1+2a of the tiered search: route + prune + compressed
    first pass. Returns ``(prov, pruned_mask (B, n_probe))`` where ``prov``
    is the provisional top-k' as ``TopK(ids=flat bank rows (B, k'),
    scores=compressed-domain scores)``; the host fetch and the rescore jit
    complete the query (:func:`search_lider`, or pipelined across batches by
    the serving engine). The provisional scores ride along so a degraded
    engine can answer compressed-only (:func:`compressed_only_topk`) when
    the host fetch is unavailable."""
    routed = route_queries(
        params, queries, n_probe=n_probe, r0=r0_centroid, use_fused=use_fused,
        block_c=block_c,
    )
    cids = prune_probes(routed.ids, routed.scores, prune_margin)
    prov = provisional_rows(
        params, queries, cids, k=k, r0=r0, refine=refine, use_fused=use_fused,
        rescore_factor=rescore_factor, block_c=block_c,
        sketch_factor=sketch_factor,
    )
    pruned = (routed.ids >= 0) & (cids < 0)
    return prov, pruned


def host_fetch(params: LiderParams, prov_rows) -> np.ndarray:
    """Stage 2 of the tiered search: host-side exact-row gather.

    A NumPy ``take`` on the process-local host tier — no device involvement;
    the result is the only H2D payload the rescore needs (``B·k'·d``
    floats vs the first pass's ``B·C`` candidate traffic)."""
    return params.bank.store.fetch(np.asarray(prov_rows))


@partial(jax.jit, static_argnames=("k", "use_fused", "block_c"))
def host_rescore(
    gids: jnp.ndarray,
    fetched: jnp.ndarray,
    prov_rows: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    use_fused: bool | None = None,
    block_c: int | None = None,
) -> TopK:
    """Jit'd stage 3: exact rescore of the fetched rows + row->gid mapping.

    Dedup/tie-break by flat bank row — identical to the device-tier
    quantized path — then the surviving rows map to global ids through the
    bank's ``gids`` table (a device-resident (c, Lp) int32 leaf)."""
    rows, scores = rescore_fetched_rows(
        fetched, prov_rows, queries, k=k, use_fused=use_fused, block_c=block_c
    )
    ids = jnp.where(rows >= 0, gids.reshape(-1)[jnp.maximum(rows, 0)], -1)
    return TopK(ids=ids, scores=scores)


@partial(jax.jit, static_argnames=("k",))
def compressed_only_topk(
    gids: jnp.ndarray, prov: TopK, *, k: int
) -> TopK:
    """Degraded-mode answer from stage 1 alone: no fetch, no exact rescore.

    The provisional top-k' from :func:`host_first_pass` is already sorted
    descending by compressed-domain score and deduped by flat bank row, so
    the compressed-only answer is its first ``k`` entries mapped through the
    bank's (c, Lp) gid table. Quality is the int8 first pass's — the
    degradation ladder's last rung (DESIGN.md §Failure model)."""
    rows = prov.ids[..., :k]
    scores = prov.scores[..., :k]
    ids = jnp.where(rows >= 0, gids.reshape(-1)[jnp.maximum(rows, 0)], -1)
    return TopK(ids=ids, scores=scores)


# ---------------------------------------------------------------------------
# Cluster-major multi-query search (DESIGN.md §Cluster-major schedule)
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("n_probe", "r0_centroid", "use_fused", "block_c"),
)
def _route_pruned(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    n_probe: int,
    r0_centroid: int = 4,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    block_c: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Jit'd routing stage of the cluster-major search: layer-1 route +
    margin prune. Returns ``(cids (B, P), pruned_mask (B, P))`` — the probe
    lists the host schedule pre-pass groups by cluster."""
    routed = route_queries(
        params, queries, n_probe=n_probe, r0=r0_centroid, use_fused=use_fused,
        block_c=block_c,
    )
    cids = prune_probes(routed.ids, routed.scores, prune_margin)
    pruned = (routed.ids >= 0) & (cids < 0)
    return cids, pruned


@partial(
    jax.jit,
    static_argnames=(
        "k", "r0", "refine", "use_fused", "rescore_factor", "block_c",
        "block_q", "sketch_factor",
    ),
)
def _cluster_major_first_pass(
    params: LiderParams,
    queries: jnp.ndarray,
    cids: jnp.ndarray,
    sched_cids: jnp.ndarray,
    sched_qids: jnp.ndarray,
    pair_step: jnp.ndarray,
    pair_slot: jnp.ndarray,
    *,
    k: int,
    r0: int = 4,
    refine: bool = False,
    use_fused: bool | None = None,
    rescore_factor: int = 4,
    block_c: int | None = None,
    block_q: int = 8,
    sketch_factor: int | None = None,
) -> TopK:
    """Jit'd compressed first pass on the cluster-major schedule.

    Candidate generation is the same ``_bank_candidates`` the per-query path
    runs; its (B, P, H, R) windows are scattered into the dense per-(step,
    query-slot) candidate masks the grouped kernel scores
    (``step_slot_ids``), each (query, probe) pair's per-cluster top-k' is
    gathered back through ``pair_step``/``pair_slot``, and a final
    ``dedup_topk`` merge yields the provisional top-k' — bit-identical ids
    AND scores to the per-query first pass (every global top-k' winner from
    a cluster is inside that pair's per-cluster top-k'; flat rows are unique
    across clusters; the selection order and smallest-id tie-break are
    shared — tests/test_fused_verify.py gates this).
    """
    bank = params.bank
    b, p = cids.shape
    c, lp = bank.gids.shape
    flat_emb, gids = _bank_candidates(
        bank, queries, cids, k=k, r0=r0, refine=refine
    )
    out_rows = jnp.where(gids >= 0, flat_emb, -1)  # (B, P, H, R)
    s_steps = sched_cids.shape[0]
    n_cand = p * flat_emb.shape[2] * flat_emb.shape[3]
    kp = min(max(rescore_factor, 1) * k, n_cand)

    if sketch_factor is not None and bank.sketches is not None:
        # Sketch pre-filter on the cluster-major path: the per-query Hamming
        # pass sees the SAME merged candidate list as the per-query funnel
        # (_verify_bank_rows), so it selects the same survivors — then the
        # per-(step, slot) candidate mask is rebuilt from survivors only.
        # Each survivor maps back to its (query, probe) pair through its
        # cluster id (flat row // Lp; probe lists hold distinct clusters),
        # and from there to the pair's (step, slot) — so the grouped kernel
        # streams the same survivor set the per-query filtered pass scores.
        m = min(max(sketch_factor, 1) * kp, n_cand)
        surv, _ = sketch_topk_op(
            bank.sketches.reshape(c * lp, -1),
            flat_emb.reshape(b, -1),
            queries,
            k=m,
            out_ids=out_rows.reshape(b, -1),
            block_c=block_c,
            use_pallas=use_fused,
        )
        surv_cid = surv // lp  # (B, m); -1 survivors masked below
        match = (cids[:, None, :] == surv_cid[:, :, None]) & (
            surv[:, :, None] >= 0
        )  # (B, m, P)
        has = jnp.any(match, axis=-1)
        pidx = jnp.argmax(match, axis=-1)  # (B, m)
        brow = jnp.arange(b, dtype=jnp.int32)[:, None]
        st_s = jnp.where(has, pair_step[brow, pidx], -1)
        sl_s = jnp.maximum(pair_slot[brow, pidx], 0)
        valid_s = has & (st_s >= 0)
        tgt = jnp.where(
            valid_s,
            (st_s * block_q + sl_s) * lp + surv % lp,
            s_steps * block_q * lp,
        )
        scat_src = surv
    else:
        # Dense per-(step, slot) candidate mask over the step cluster's Lp
        # rows: the union of each pair's H·R window candidates (duplicates
        # collapse). Invalid candidates / unscheduled (pruned) pairs scatter
        # out of range.
        local = flat_emb % lp
        st = pair_step[:, :, None, None]
        sl = pair_slot[:, :, None, None]
        valid = (out_rows >= 0) & (st >= 0)
        tgt = jnp.where(
            valid, (st * block_q + sl) * lp + local, s_steps * block_q * lp
        )
        scat_src = out_rows
    step_slot_ids = (
        jnp.full((s_steps * block_q * lp,), -1, jnp.int32)
        .at[tgt.reshape(-1)]
        .set(scat_src.reshape(-1), mode="drop")
        .reshape(s_steps, block_q, lp)
    )

    kp_pair = min(kp, lp)  # a pair has at most Lp distinct rows
    ids_g, sc_g = verify_topk_grouped_op(
        bank.embs,
        bank.emb_scales,
        queries,
        sched_cids,
        sched_qids,
        step_slot_ids,
        kp=kp_pair,
        block_q=block_q,
        block_c=block_c,
        code_dtype=bank.code_dtype,
        use_pallas=use_fused,
    )

    # Scatter-back: gather each query's pairs' per-cluster top-k' streams
    # and merge. Dead pairs (pruned probes / padding) contribute (-1, -inf).
    safe_st = jnp.maximum(pair_step, 0)
    safe_sl = jnp.maximum(pair_slot, 0)
    pids = ids_g[safe_st, safe_sl]  # (B, P, kp_pair)
    psc = sc_g[safe_st, safe_sl]
    dead = (pair_step < 0)[..., None]
    pids = jnp.where(dead, -1, pids)
    psc = jnp.where(dead, -jnp.inf, psc)
    # dedup_topk pads (-1, -inf) past the candidate count, so degenerate
    # tiny-bank shapes (kp > P·kp_pair) match the per-query pass's padding.
    prov_rows, prov_sc = dedup_topk(
        pids.reshape(b, -1), psc.reshape(b, -1), kp
    )
    return TopK(ids=prov_rows, scores=prov_sc)


@partial(jax.jit, static_argnames=("k", "use_fused", "block_c"))
def _rescore_provisional(
    gids: jnp.ndarray,
    rescore_embs: jnp.ndarray,
    prov_rows: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    use_fused: bool | None = None,
    block_c: int | None = None,
) -> TopK:
    """Device-tier exact rescore of a provisional top-k' (the same stage-2
    math as ``_verify_bank_rows``, split out so the cluster-major first pass
    can feed it between jits)."""
    rescore_table = rescore_embs.reshape(-1, rescore_embs.shape[-1])
    rows, scores = verify_topk_op(
        rescore_table,
        jnp.maximum(prov_rows, 0),
        queries,
        k=k,
        out_ids=prov_rows,
        block_c=block_c,
        use_pallas=use_fused,
    )
    ids = jnp.where(rows >= 0, gids.reshape(-1)[jnp.maximum(rows, 0)], -1)
    return TopK(ids=ids, scores=scores)


def host_first_pass_cluster_major(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    k: int,
    n_probe: int = 20,
    r0: int = 4,
    r0_centroid: int = 4,
    refine: bool = False,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    rescore_factor: int = 4,
    block_c: int | None = None,
    block_q: int = 8,
    sketch_factor: int | None = None,
    stats_out: dict | None = None,
) -> tuple[TopK, jnp.ndarray]:
    """Cluster-major spelling of :func:`host_first_pass` — same
    ``(prov, pruned)`` contract, so the serving engine's double-buffered
    fetch->rescore pipeline works unchanged with ``block_q`` set.

    Not one jit (the schedule pre-pass is host-side and data-dependent), but
    both device stages inside it are jits, so stage-1 dispatch still returns
    before the device finishes and the pipeline's overlap is preserved.

    ``stats_out`` (the online block_q autotuner's hook) does two things:
    the dict is filled with the drained schedule's measured sharing
    (``n_pairs``/``n_steps``) plus the batch's per-cluster pair counts, AND
    the schedule is padded to the fixed worst case ``_pad_pow2(B·n_probe)``
    instead of the data-dependent power of two — so every batch of the same
    (B, block_q) hits ONE compiled kernel shape and the autotuner can swap
    ``block_q`` between drains with zero query-path retraces (padding steps
    are dead; results unchanged).
    """
    from ..kernels.schedule import _pad_pow2, build_cluster_schedule

    cids, pruned = _route_pruned(
        params, queries, n_probe=n_probe, r0_centroid=r0_centroid,
        use_fused=use_fused, prune_margin=prune_margin, block_c=block_c,
    )
    pad_to = None
    if stats_out is not None:
        pad_to = _pad_pow2(queries.shape[0] * n_probe)
    cids_np = np.asarray(jax.device_get(cids))
    sched = build_cluster_schedule(cids_np, block_q=block_q, pad_to=pad_to)
    if stats_out is not None:
        stats_out["n_pairs"] = sched.n_pairs
        stats_out["n_steps"] = sched.n_steps
        stats_out["cluster_counts"] = np.unique(
            cids_np[cids_np >= 0], return_counts=True
        )[1]
    prov = _cluster_major_first_pass(
        params,
        queries,
        cids,
        jnp.asarray(sched.sched_cids),
        jnp.asarray(sched.sched_qids),
        jnp.asarray(sched.pair_step),
        jnp.asarray(sched.pair_slot),
        k=k,
        r0=r0,
        refine=refine,
        use_fused=use_fused,
        rescore_factor=rescore_factor,
        block_c=block_c,
        block_q=block_q,
        sketch_factor=sketch_factor,
    )
    return prov, pruned


def _search_lider_cluster_major(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    k: int,
    n_probe: int,
    r0: int,
    r0_centroid: int,
    refine: bool,
    use_fused: bool | None,
    prune_margin: float | None,
    with_stats: bool,
    rescore_factor: int,
    block_c: int | None,
    block_q: int,
    sketch_factor: int | None = None,
) -> TopK | tuple[TopK, jnp.ndarray]:
    """Staged cluster-major search: route (jit) -> host schedule pre-pass ->
    grouped first pass (jit) -> exact rescore (tier-appropriate).

    The schedule is data-dependent (it groups the batch's routed probe lists
    by cluster), so it cannot live inside one jit — the same staging pattern
    as the host-tier search. Step counts are padded to powers of two, so the
    grouped kernel's compile count stays O(log batch-pairs).
    """
    bank = params.bank
    if not bank.quantized:
        raise ValueError(
            "block_q (cluster-major schedule) requires a quantized "
            "(int8/int4) bank — the grouped kernel streams code tiles; "
            "use the per-query schedule (block_q=None) for float banks"
        )
    prov, pruned = host_first_pass_cluster_major(
        params, queries, k=k, n_probe=n_probe, r0=r0,
        r0_centroid=r0_centroid, refine=refine, use_fused=use_fused,
        prune_margin=prune_margin, rescore_factor=rescore_factor,
        block_c=block_c, block_q=block_q, sketch_factor=sketch_factor,
    )
    if bank.rescore_tier == "host":
        fetched = host_fetch(params, prov.ids)
        out = host_rescore(
            bank.gids, jnp.asarray(fetched), prov.ids, queries, k=k,
            use_fused=use_fused, block_c=block_c,
        )
    else:
        out = _rescore_provisional(
            bank.gids, bank.rescore_embs, prov.ids, queries, k=k,
            use_fused=use_fused, block_c=block_c,
        )
    return (out, pruned) if with_stats else out


def search_lider(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    k: int,
    n_probe: int = 20,
    r0: int = 4,
    r0_centroid: int = 4,
    refine: bool = False,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    with_stats: bool = False,
    rescore_factor: int = 4,
    block_c: int | None = None,
    block_q: int | None = None,
    sketch_factor: int | None = None,
) -> TopK | tuple[TopK, jnp.ndarray]:
    """End-to-end LIDER ANN search (paper Sec. 3.3.2), single device.

    ``prune_margin`` enables adaptive probe pruning (see :func:`prune_probes`;
    traced, so sweeping margins does not recompile; ``None`` is bit-identical
    to the fixed-probe search). ``with_stats=True`` additionally returns the
    (B, n_probe) bool mask of probes that were routed but pruned — serving
    aggregates it into the per-batch pruned-probe fraction.

    On an int8 bank (``LiderConfig.storage_dtype="int8"``) layer-2
    verification runs compressed-domain first, then exactly rescores the
    provisional top-``rescore_factor * k``; the knobs are static so each
    (rescore_factor, block_c) pair is one compile.

    Tier dispatch (DESIGN.md §Tiered embedding store): on a device-tier bank
    the whole search is one jit. On a *host-tier* bank it runs as three
    explicit stages — jit'd compressed first pass (:func:`host_first_pass`),
    host-side exact-row fetch (:func:`host_fetch`: ``np.take`` on the
    process-local tier, H2D of only ``B·k'·d`` floats), jit'd fused rescore
    (:func:`host_rescore`) — returning bit-identical (ids, scores) to the
    device tier on the same bank.

    ``block_q`` (quantized banks only) switches the first pass to the
    cluster-major multi-query schedule (§Cluster-major schedule): queries
    probing the same cluster share one DMA of its rows. Results are
    bit-identical to the per-query schedule; only the loop order — and the
    HBM traffic under skewed probe distributions — changes.

    ``sketch_factor`` (quantized banks only) turns on the binary-sketch
    pre-filter (§Binary sketch tier): a 1-bit Hamming pass keeps the top
    ``sketch_factor * k'`` rows, so the code pass streams only survivors. A
    covering factor is bit-identical to the unfiltered search; small
    factors trade recall for ~16x less first-pass traffic than int4.
    """
    if block_q is not None:
        return _search_lider_cluster_major(
            params, queries, k=k, n_probe=n_probe, r0=r0,
            r0_centroid=r0_centroid, refine=refine, use_fused=use_fused,
            prune_margin=prune_margin, with_stats=with_stats,
            rescore_factor=rescore_factor, block_c=block_c, block_q=block_q,
            sketch_factor=sketch_factor,
        )
    if params.bank.rescore_tier == "host":
        prov, pruned = host_first_pass(
            params, queries, k=k, n_probe=n_probe, r0=r0,
            r0_centroid=r0_centroid, refine=refine, use_fused=use_fused,
            prune_margin=prune_margin, rescore_factor=rescore_factor,
            block_c=block_c, sketch_factor=sketch_factor,
        )
        fetched = host_fetch(params, prov.ids)
        out = host_rescore(
            params.bank.gids, jnp.asarray(fetched), prov.ids, queries, k=k,
            use_fused=use_fused, block_c=block_c,
        )
        return (out, pruned) if with_stats else out
    return _search_lider_device(
        params, queries, k=k, n_probe=n_probe, r0=r0,
        r0_centroid=r0_centroid, refine=refine, use_fused=use_fused,
        prune_margin=prune_margin, with_stats=with_stats,
        rescore_factor=rescore_factor, block_c=block_c,
        sketch_factor=sketch_factor,
    )


# Every jit on the serving query path (all tiers + the degraded fallback).
# The cache-size sum below is the recompile detector behind the serving
# front end's zero-retrace gate.
_QUERY_PATH_JITS = (
    "_search_lider_device",
    "host_first_pass",
    "host_rescore",
    "compressed_only_topk",
    "_route_pruned",
    "_cluster_major_first_pass",
    "_rescore_provisional",
)


def query_path_cache_size() -> int:
    """Total compiled-trace count across every jit the serving query path
    can touch. After ``RetrievalEngine.warmup()`` this number must stay
    flat across any mix of batch sizes and ladder rungs — a delta means a
    query ate an XLA re-trace (tests + ``benchmarks.serve_scale`` gate on
    delta == 0). Uses the jit cache-size introspection when this jax
    version exposes it; contributes 0 per function otherwise."""
    total = 0
    for name in _QUERY_PATH_JITS:
        fn = globals()[name]
        if hasattr(fn, "_cache_size"):
            total += fn._cache_size()
    return total

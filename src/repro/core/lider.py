"""LIDER: the clustering-based two-layer learned index (paper Sec. 3).

Layer 1: a *centroids retriever* (one core model over the k-means centroids)
routes each query to ``n_probe`` (= paper c0) clusters. Layer 2: one
*in-cluster retriever* per cluster. On TPU the per-cluster retrievers are
**stacked into dense padded tensors** so a (query x probed-cluster) batch is
pure gather + matmul dataflow:

    sorted_keys   (c, H, Lp) uint32   per-cluster sorted hashkey arrays
    sorted_pos    (c, H, Lp) int32    position -> cluster-local row (-1 = pad)
    cluster_embs  (c, Lp, d) float32  embeddings grouped by cluster
    cluster_gids  (c, Lp)    int32    cluster-local row -> global id (-1 = pad)

The in-cluster LSH projection bank is shared across clusters (DESIGN.md §2);
re-scale stats and RMIs are per-cluster (the learned parts), matching the
paper's per-cluster core models.

``search_lider`` is the single-device reference; ``core.distributed`` wraps
the same ``incluster_search`` math in a shard_map with capacity-based
query->cluster-shard dispatch for the production mesh.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from . import clustering, lsh as lsh_lib, rescale as rescale_lib, rmi as rmi_lib
from ..kernels.ops import verify_topk_op
from .core_model import CoreModelParams, TopK, build_core_model, search_core_model
from .types import pytree_dataclass


@dataclasses.dataclass(frozen=True)
class LiderConfig:
    """Static build/search configuration (paper Sec. 7.2.1 defaults)."""

    n_clusters: int = 1000  # c
    n_probe: int = 20  # c0
    n_arrays: int = 10  # H (in-cluster)
    n_arrays_centroid: int = 10  # H (centroids retriever)
    key_len: int | None = None  # M (in-cluster); None -> ceil(log2 Lp)
    key_len_centroid: int | None = None  # M (centroids); None -> ceil(log2 c)
    n_leaves: int = 5  # RMI width W_i
    n_leaves_centroid: int = 10  # RMI width W_c
    r0: int = 4  # expansion range factor, R = r0 * k
    r0_centroid: int = 4
    kmeans_iters: int = 20
    capacity: int | None = None  # Lp cap; None -> max cluster size (no drops)
    pad_multiple: int = 8
    refine: bool = False  # beyond-paper last-mile searchsorted correction
    # Verification-kernel escape hatch: None -> fused Pallas pass on TPU,
    # materialized reference elsewhere; True/False forces either path.
    # Like n_probe/refine, search entry points take this as a kwarg and
    # launchers feed it from the config (DESIGN.md §Verification-kernel).
    use_fused: bool | None = None


@pytree_dataclass
class LiderParams:
    centroid_cm: CoreModelParams
    centroids: jnp.ndarray  # (c, d)
    in_lsh: lsh_lib.LSHParams
    in_rescale: rescale_lib.RescaleParams  # leaves (c, H)
    in_rmi: rmi_lib.RMIParams  # leaves (c, H) / (c, H, W)
    sorted_keys: jnp.ndarray  # (c, H, Lp) uint32
    sorted_pos: jnp.ndarray  # (c, H, Lp) int32
    cluster_embs: jnp.ndarray  # (c, Lp, d)
    cluster_gids: jnp.ndarray  # (c, Lp) int32
    cluster_sizes: jnp.ndarray  # (c,) int32

    @property
    def n_clusters(self) -> int:
        return self.cluster_gids.shape[0]

    @property
    def capacity(self) -> int:
        return self.cluster_gids.shape[1]

    @property
    def dim(self) -> int:
        return self.cluster_embs.shape[-1]


# ---------------------------------------------------------------------------
# Build (paper Sec. 3.3.2: Stage 1 clustering, Stage 2 CR, Stage 3 IRs)
# ---------------------------------------------------------------------------


def build_lider(
    rng: jax.Array, embs: jnp.ndarray, config: LiderConfig
) -> LiderParams:
    n, dim = embs.shape
    c = config.n_clusters
    rng_km, rng_cen, rng_in = jax.random.split(rng, 3)

    # Stage 1: clustering.
    km = clustering.kmeans(rng_km, embs, c, iters=config.kmeans_iters)
    sizes = jnp.bincount(km.assignment, length=c).astype(jnp.int32)
    max_size = int(jax.device_get(jnp.max(sizes)))
    cap = config.capacity or max_size
    cap = max(config.pad_multiple, math.ceil(cap / config.pad_multiple) * config.pad_multiple)
    cluster_gids, cluster_sizes = clustering.group_by_cluster(km.assignment, c, cap)

    valid_local = cluster_gids >= 0  # (c, Lp)
    safe_gid = jnp.maximum(cluster_gids, 0)
    cluster_embs = embs[safe_gid] * valid_local[..., None]

    # Stage 3 prep: shared in-cluster LSH bank, per-cluster sorted arrays.
    key_len = config.key_len or lsh_lib.suggest_key_len(cap)
    in_lsh = lsh_lib.make_lsh(rng_in, dim, config.n_arrays, key_len)
    all_keys = lsh_lib.hash_vectors(in_lsh, embs)  # (N, H)
    keys_cl = jnp.where(
        valid_local[..., None], all_keys[safe_gid], jnp.uint32(lsh_lib.UINT32_PAD)
    )  # (c, Lp, H)
    keys_cl = jnp.moveaxis(keys_cl, -1, 1)  # (c, H, Lp)
    sorted_keys, local_order = lsh_lib.sort_hashkeys(keys_cl)
    sorted_pos = jnp.where(
        sorted_keys == jnp.uint32(lsh_lib.UINT32_PAD), -1, local_order
    ).astype(jnp.int32)

    def _fit_one(skeys: jnp.ndarray, spos: jnp.ndarray):
        valid = spos >= 0
        resc = rescale_lib.fit_rescale(skeys, valid)
        scaled = rescale_lib.rescale(resc, skeys)
        r = rmi_lib.fit_rmi(scaled, valid.astype(jnp.float32), n_leaves=config.n_leaves)
        return resc, r

    in_rescale, in_rmi = jax.vmap(jax.vmap(_fit_one))(sorted_keys, sorted_pos)

    # Stage 2: centroids retriever.
    centroid_cm = build_core_model(
        rng_cen,
        km.centroids,
        n_arrays=config.n_arrays_centroid,
        key_len=config.key_len_centroid or lsh_lib.suggest_key_len(c),
        n_leaves=config.n_leaves_centroid,
    )

    return LiderParams(
        centroid_cm=centroid_cm,
        centroids=km.centroids,
        in_lsh=in_lsh,
        in_rescale=in_rescale,
        in_rmi=in_rmi,
        sorted_keys=sorted_keys,
        sorted_pos=sorted_pos,
        cluster_embs=cluster_embs,
        cluster_gids=cluster_gids,
        cluster_sizes=cluster_sizes,
    )


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


def route_queries(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    n_probe: int,
    r0: int = 4,
    use_fused: bool | None = None,
) -> TopK:
    """Layer-1: centroids retriever -> (B, n_probe) cluster ids + scores."""
    return search_core_model(
        params.centroid_cm, params.centroids, queries, k=n_probe, r0=r0,
        use_fused=use_fused,
    )


def _batched_rmi_predict(root_w, root_b, leaf_w, leaf_b, length, n_leaves, x):
    """RMI predict where every model parameter carries batch dims (gathered
    per (query, probed cluster, array))."""
    hi = jnp.maximum(length - 1.0, 0.0)
    pred = jnp.clip(root_w * x + root_b, 0.0, hi)
    leaf = jnp.floor(pred * n_leaves / jnp.maximum(length, 1.0)).astype(jnp.int32)
    leaf = jnp.clip(leaf, 0, n_leaves - 1)
    lw = jnp.take_along_axis(leaf_w, leaf[..., None], axis=-1)[..., 0]
    lb = jnp.take_along_axis(leaf_b, leaf[..., None], axis=-1)[..., 0]
    return jnp.clip(lw * x + lb, 0.0, hi)


def incluster_search(
    params: LiderParams,
    queries: jnp.ndarray,
    cids: jnp.ndarray,
    *,
    k: int,
    r0: int = 4,
    refine: bool = False,
    merge: bool = True,
    use_fused: bool | None = None,
) -> TopK:
    """Layer-2: search the probed clusters for each query.

    ``queries``: (B, d); ``cids``: (B, P) cluster ids (-1 = unused probe slot).
    With ``merge=False`` returns the per-pair top-k (B, P, k) — the shape the
    distributed capacity-dispatch path scatters back before merging.

    Verification goes through ``verify_topk_op`` (``use_fused`` as in
    ``LiderConfig``): the fused kernel streams the gathered rows through VMEM
    and emits only the (B, k) result, instead of materializing the
    (B, P, H, R, d) candidate tensor in HBM before the einsum.
    """
    c, h, lp = params.sorted_keys.shape
    w = params.in_rmi.n_leaves
    b, p = cids.shape
    r = min(r0 * k, lp)

    qkeys = lsh_lib.hash_vectors(params.in_lsh, queries)  # (B, H)
    safe_cid = jnp.clip(cids, 0, c - 1)
    cvalid = cids >= 0  # (B, P)

    # Gather per-pair rescale + RMI parameters, then predict positions.
    resc = rescale_lib.RescaleParams(
        key_min=params.in_rescale.key_min[safe_cid],
        key_max=params.in_rescale.key_max[safe_cid],
        length=params.in_rescale.length[safe_cid],
    )  # leaves (B, P, H)
    scaled = rescale_lib.rescale(resc, qkeys[:, None, :])  # (B, P, H)
    pos = _batched_rmi_predict(
        params.in_rmi.root_w[safe_cid],
        params.in_rmi.root_b[safe_cid],
        params.in_rmi.leaf_w[safe_cid],
        params.in_rmi.leaf_b[safe_cid],
        params.in_rmi.length[safe_cid],
        w,
        scaled,
    )  # (B, P, H)

    h_idx = jnp.arange(h, dtype=jnp.int32)[None, None, :, None]
    if refine:
        # Beyond-paper last-mile: gather a 2R key window around the RMI
        # prediction (keys are 4 B vs d*4 B embeddings) and binary-search the
        # exact position inside it, then expand only R around the truth.
        w1 = min(2 * r, lp)
        start1 = jnp.clip(jnp.round(pos).astype(jnp.int32) - w1 // 2, 0, lp - w1)
        idx1 = start1[..., None] + jnp.arange(w1, dtype=jnp.int32)
        flat1 = (safe_cid[:, :, None, None] * h + h_idx) * lp + idx1
        keys_win = jnp.take(params.sorted_keys.reshape(-1), flat1)  # (B,P,H,W1)
        qk = jnp.broadcast_to(qkeys[:, None, :], (b, p, h)).reshape(-1)
        rows = keys_win.reshape(-1, w1)
        off = jax.vmap(lambda row, q: jnp.searchsorted(row, q))(rows, qk)
        pos = (start1 + off.reshape(b, p, h).astype(jnp.int32)).astype(jnp.float32)

    start = jnp.clip(jnp.round(pos).astype(jnp.int32) - r // 2, 0, lp - r)
    idx = start[..., None] + jnp.arange(r, dtype=jnp.int32)  # (B, P, H, R)
    flat = (safe_cid[:, :, None, None] * h + h_idx) * lp + idx
    local_pos = jnp.take(params.sorted_pos.reshape(-1), flat)  # (B, P, H, R)

    valid = (local_pos >= 0) & cvalid[:, :, None, None]
    flat_emb = safe_cid[:, :, None, None] * lp + jnp.maximum(local_pos, 0)
    gids = jnp.take(params.cluster_gids.reshape(-1), flat_emb)
    gids = jnp.where(valid, gids, -1)

    # Verification: gather rows from the flat (c*Lp, d) table (row_ids =
    # flat_emb), dedup/report by global passage id (out_ids = gids, -1 where
    # invalid). Scoring happens in the embedding storage dtype (bf16 stays
    # bf16 on the MXU) with fp32 accumulation for a stable top-k ordering.
    flat_table = params.cluster_embs.reshape(c * lp, -1)
    if merge:
        ids, sc = verify_topk_op(
            flat_table,
            flat_emb.reshape(b, -1),
            queries,
            k=k,
            out_ids=gids.reshape(b, -1),
            use_pallas=use_fused,
        )
        return TopK(ids=ids, scores=sc)
    # Per-pair top-k: flatten (query, probe) pairs into the batch axis so the
    # same kernel covers the shape the distributed path scatters back.
    pair_q = jnp.broadcast_to(queries[:, None, :], (b, p, queries.shape[-1]))
    ids, sc = verify_topk_op(
        flat_table,
        flat_emb.reshape(b * p, -1),
        pair_q.reshape(b * p, -1),
        k=k,
        out_ids=gids.reshape(b * p, -1),
        use_pallas=use_fused,
    )
    return TopK(ids=ids.reshape(b, p, k), scores=sc.reshape(b, p, k))


@partial(
    jax.jit,
    static_argnames=("k", "n_probe", "r0", "r0_centroid", "refine", "use_fused"),
)
def search_lider(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    k: int,
    n_probe: int = 20,
    r0: int = 4,
    r0_centroid: int = 4,
    refine: bool = False,
    use_fused: bool | None = None,
) -> TopK:
    """End-to-end LIDER ANN search (paper Sec. 3.3.2), single device."""
    routed = route_queries(
        params, queries, n_probe=n_probe, r0=r0_centroid, use_fused=use_fused
    )
    return incluster_search(
        params, queries, routed.ids, k=k, r0=r0, refine=refine,
        use_fused=use_fused,
    )

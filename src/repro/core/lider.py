"""LIDER: the clustering-based two-layer learned index (paper Sec. 3).

Layer 1: a *centroids retriever* (one core model over the k-means centroids)
routes each query to ``n_probe`` (= paper c0) clusters. Layer 2: a
:class:`~repro.core.bank.ClusterBank` — the per-cluster retrievers stacked
into dense padded tensors so a (query x probed-cluster) batch is pure gather
+ matmul dataflow (see ``core/bank.py`` for the layout).

Build is staged (paper Sec. 3.3.2): ``assign`` (k-means or nearest-centroid
against precomputed centroids) -> ``pack`` (capacity slots) -> ``hash/sort/
fit`` via ``vmap(bank.refit_cluster)``. The same ``refit_cluster`` unit
powers the incremental upsert/delete path in ``core.update``, so online
maintenance and offline build cannot drift.

``search_lider`` is the single-device reference; ``core.distributed`` wraps
the same ``incluster_search`` math in a shard_map with capacity-based
query->cluster-shard dispatch for the production mesh.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from . import bank as bank_lib
from . import clustering, lsh as lsh_lib, rescale as rescale_lib, rmi as rmi_lib
from ..kernels.ops import verify_topk_op
from .bank import ClusterBank
from .core_model import CoreModelParams, TopK, build_core_model, search_core_model
from .types import pytree_dataclass


@dataclasses.dataclass(frozen=True)
class LiderConfig:
    """Static build/search configuration (paper Sec. 7.2.1 defaults)."""

    n_clusters: int = 1000  # c
    n_probe: int = 20  # c0
    n_arrays: int = 10  # H (in-cluster)
    n_arrays_centroid: int = 10  # H (centroids retriever)
    key_len: int | None = None  # M (in-cluster); None -> ceil(log2 Lp)
    key_len_centroid: int | None = None  # M (centroids); None -> ceil(log2 c)
    n_leaves: int = 5  # RMI width W_i
    n_leaves_centroid: int = 10  # RMI width W_c
    r0: int = 4  # expansion range factor, R = r0 * k
    r0_centroid: int = 4
    kmeans_iters: int = 20
    capacity: int | None = None  # Lp cap; None -> max cluster size (no drops)
    pad_multiple: int = 8
    refine: bool = False  # beyond-paper last-mile searchsorted correction
    # Verification-kernel escape hatch: None -> fused Pallas pass on TPU,
    # materialized reference elsewhere; True/False forces either path.
    # Like n_probe/refine, search entry points take this as a kwarg and
    # launchers feed it from the config (DESIGN.md §Verification-kernel).
    use_fused: bool | None = None
    # Embedding storage dtype (DESIGN.md §Quantized bank): "float32",
    # "bfloat16", or "int8". int8 cuts the compulsory candidate-row gather
    # 4x vs f32 and adds an exact rescore pass over the provisional
    # top-(rescore_factor * k) from the full-precision side table.
    storage_dtype: str = "float32"
    rescore_factor: int = 4  # k' = rescore_factor * k (int8 storage only)
    # Verification-kernel candidate block size; None -> kernel default (256).
    # Swept by the Pareto autotuner alongside the quantization knobs.
    block_c: int | None = None
    # Adaptive probe pruning (DESIGN.md §Adaptive speed-quality control
    # plane): probes whose layer-1 centroid score falls more than this
    # margin below the per-query best are masked to -1 before layer 2.
    # None disables pruning (bit-identical to the fixed-n_probe search).
    prune_margin: float | None = None
    # Capacity overflow policy: when ``capacity`` is below the max cluster
    # size, overflow passages are silently unretrievable unless this is set
    # (bank.build_bank raises CapacityOverflowError otherwise).
    allow_drops: bool = False


@pytree_dataclass
class LiderParams:
    centroid_cm: CoreModelParams
    centroids: jnp.ndarray  # (c, d)
    bank: ClusterBank  # stacked per-cluster state (core/bank.py)

    @property
    def n_clusters(self) -> int:
        return self.bank.n_clusters

    @property
    def capacity(self) -> int:
        return self.bank.capacity

    @property
    def dim(self) -> int:
        return self.bank.dim


# ---------------------------------------------------------------------------
# Build (paper Sec. 3.3.2: Stage 1 clustering, Stage 2 CR, Stage 3 IRs)
# ---------------------------------------------------------------------------


def padded_capacity(max_size: int, cap: int | None, pad_multiple: int) -> int:
    """Slot count per cluster: requested (or max) size, padded for the TPU."""
    cap = cap or max_size
    return max(pad_multiple, math.ceil(cap / pad_multiple) * pad_multiple)


def assign_points(
    rng: jax.Array,
    embs: jnp.ndarray,
    config: LiderConfig,
    *,
    centroids: jnp.ndarray | None = None,
) -> clustering.KMeansResult:
    """Stage 1: k-means, or nearest-centroid against precomputed centroids.

    The ``centroids`` override is the layer-1-frozen rebuild used by the
    update lifecycle (and by multi-stage corpora that share one routing
    layer): assignment is the exact nearest centroid, the same rule the final
    Lloyd step applies — so an index built this way is slot-for-slot
    comparable with one grown by ``core.update.upsert``.
    """
    if centroids is None:
        return clustering.kmeans(rng, embs, config.n_clusters, iters=config.kmeans_iters)
    assignment, _ = clustering.assign_chunked(embs, centroids)
    return clustering.KMeansResult(centroids=centroids, assignment=assignment)


@dataclasses.dataclass(frozen=True)
class BuildStats:
    """Host-side accounting for one offline build."""

    n_indexed: int  # passages that got a slot
    n_dropped: int  # capacity-overflow drops (0 unless allow_drops=True)
    capacity: int  # padded per-cluster slot count Lp


def build_lider(
    rng: jax.Array,
    embs: jnp.ndarray,
    config: LiderConfig,
    *,
    centroids: jnp.ndarray | None = None,
    return_stats: bool = False,
) -> LiderParams | tuple[LiderParams, BuildStats]:
    n, dim = embs.shape
    c = config.n_clusters
    rng_km, rng_cen, rng_in = jax.random.split(rng, 3)

    # Stage 1: clustering (or routing against supplied centroids).
    km = assign_points(rng_km, embs, config, centroids=centroids)
    sizes = jnp.bincount(km.assignment, length=c).astype(jnp.int32)
    max_size = int(jax.device_get(jnp.max(sizes)))
    cap = padded_capacity(max_size, config.capacity, config.pad_multiple)

    # Stage 3: pack -> hash/sort -> fit (vmap of the single-cluster refit).
    # Packing counts capacity-overflow drops; unless the config opts in via
    # allow_drops, a lossy pack raises instead of silently losing passages.
    bank, n_dropped = bank_lib.build_bank(
        rng_in,
        embs,
        km.assignment,
        n_clusters=c,
        capacity=cap,
        n_arrays=config.n_arrays,
        key_len=config.key_len or lsh_lib.suggest_key_len(cap),
        n_leaves=config.n_leaves,
        allow_drops=config.allow_drops,
        storage_dtype=config.storage_dtype,
    )

    # Stage 2: centroids retriever.
    centroid_cm = build_core_model(
        rng_cen,
        km.centroids,
        n_arrays=config.n_arrays_centroid,
        key_len=config.key_len_centroid or lsh_lib.suggest_key_len(c),
        n_leaves=config.n_leaves_centroid,
    )

    params = LiderParams(centroid_cm=centroid_cm, centroids=km.centroids, bank=bank)
    if return_stats:
        return params, BuildStats(
            n_indexed=n - n_dropped, n_dropped=n_dropped, capacity=cap
        )
    return params


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


def prune_probes(
    cids: jnp.ndarray, scores: jnp.ndarray, prune_margin: float | None
) -> jnp.ndarray:
    """Margin rule of the adaptive control plane (DESIGN.md §Adaptive).

    ``cids``/``scores``: (B, P) layer-1 routing output. Probes whose centroid
    score falls more than ``prune_margin`` below the per-query best are
    masked to -1 — shapes stay static (no recompiles per margin value; the
    margin itself is traced), downstream layers treat -1 as an unused probe
    slot. ``None`` returns ``cids`` untouched (bit-identical fixed-probe
    search).
    """
    if prune_margin is None:
        return cids
    valid = cids >= 0
    best = jnp.max(
        jnp.where(valid, scores, -jnp.inf), axis=-1, keepdims=True
    )  # (B, 1)
    keep = scores >= best - prune_margin
    return jnp.where(valid & keep, cids, -1)


def route_queries(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    n_probe: int,
    r0: int = 4,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    block_c: int | None = None,
) -> TopK:
    """Layer-1: centroids retriever -> (B, n_probe) cluster ids + scores.

    With ``prune_margin`` set, low-confidence probes come back masked to
    (-1, -inf) — the slot count stays ``n_probe`` so downstream shapes are
    static. The centroid table itself always stays full precision (it is
    KB–MB sized; quantizing it would risk routing quality for no traffic
    win).
    """
    routed = search_core_model(
        params.centroid_cm, params.centroids, queries, k=n_probe, r0=r0,
        use_fused=use_fused, block_c=block_c,
    )
    if prune_margin is None:
        return routed
    cids = prune_probes(routed.ids, routed.scores, prune_margin)
    return TopK(
        ids=cids, scores=jnp.where(cids >= 0, routed.scores, -jnp.inf)
    )


def _verify_bank_rows(
    bank: ClusterBank,
    flat_rows: jnp.ndarray,
    out_gids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    rescore_factor: int,
    block_c: int | None,
    use_pallas: bool | None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Verify ``(Bq, C)`` flat bank rows -> gid-space top-k ids + scores.

    The single verification funnel for both ``incluster_search`` shapes
    (merged and per-pair). On a float bank this is one ``verify_topk_op``
    call deduped by global id. On a quantized bank it is the two-stage pass
    (DESIGN.md §Quantized bank):

    1. int8 first pass over the code table, deduped by *flat row* — exact
       within the bank, since a passage occupies exactly one (cluster, slot)
       — keeping the provisional top-``k' = rescore_factor*k``;
    2. exact rescore of those k' rows from the full-precision side table
       (a gather k'/C the size of the first pass), reusing the same fused
       kernel; final rows map back to global ids through ``bank.gids``.

    Score ties between distinct passages break by smallest flat row on the
    quantized path (vs smallest gid on the float path) — both deterministic.
    """
    c, lp = bank.gids.shape
    flat_table = bank.embs.reshape(c * lp, -1)
    if not bank.quantized:
        return verify_topk_op(
            flat_table,
            flat_rows,
            queries,
            k=k,
            out_ids=out_gids,
            block_c=block_c,
            use_pallas=use_pallas,
        )
    out_rows = jnp.where(out_gids >= 0, flat_rows, -1)
    kp = min(max(rescore_factor, 1) * k, out_rows.shape[-1])
    prov_rows, _ = verify_topk_op(
        flat_table,
        flat_rows,
        queries,
        k=kp,
        out_ids=out_rows,
        scales=bank.emb_scales.reshape(-1),
        block_c=block_c,
        use_pallas=use_pallas,
    )
    rescore_table = bank.rescore_embs.reshape(c * lp, -1)
    rows, scores = verify_topk_op(
        rescore_table,
        jnp.maximum(prov_rows, 0),
        queries,
        k=k,
        out_ids=prov_rows,
        block_c=block_c,
        use_pallas=use_pallas,
    )
    ids = jnp.where(rows >= 0, bank.gids.reshape(-1)[jnp.maximum(rows, 0)], -1)
    return ids, scores


def incluster_search(
    params: LiderParams,
    queries: jnp.ndarray,
    cids: jnp.ndarray,
    *,
    k: int,
    r0: int = 4,
    refine: bool = False,
    merge: bool = True,
    use_fused: bool | None = None,
    cid_scores: jnp.ndarray | None = None,
    prune_margin: float | None = None,
    rescore_factor: int = 4,
    block_c: int | None = None,
) -> TopK:
    """Layer-2: search the probed clusters for each query.

    ``queries``: (B, d); ``cids``: (B, P) cluster ids (-1 = unused probe slot).
    With ``merge=False`` returns the per-pair top-k (B, P, k) — the shape the
    distributed capacity-dispatch path scatters back before merging.
    With ``cid_scores`` (the layer-1 routing scores) and ``prune_margin``
    both set, probes outside the margin are masked to -1 here instead of by
    the caller — either spelling yields the same candidate mask.

    Verification goes through ``verify_topk_op`` (``use_fused`` as in
    ``LiderConfig``): the fused kernel streams the gathered rows through VMEM
    and emits only the (B, k) result, instead of materializing the
    (B, P, H, R, d) candidate tensor in HBM before the einsum. On an int8
    bank the pass runs in the compressed domain and is followed by an exact
    rescore of the provisional top-``rescore_factor * k`` rows
    (:func:`_verify_bank_rows`); ``block_c`` tunes the kernel's candidate
    block size.
    """
    if prune_margin is not None:
        if cid_scores is None:
            raise ValueError("prune_margin needs cid_scores (layer-1 scores)")
        cids = prune_probes(cids, cid_scores, prune_margin)
    bank = params.bank
    c, h, lp = bank.sorted_keys.shape
    b, p = cids.shape
    r = min(r0 * k, lp)

    qkeys = lsh_lib.hash_vectors(bank.lsh, queries)  # (B, H)
    safe_cid = jnp.clip(cids, 0, c - 1)
    cvalid = cids >= 0  # (B, P)

    # Gather per-(query, probe) rescale + RMI models out of the bank, then
    # predict positions with the banked RMI form.
    resc = jax.tree.map(lambda leaf: leaf[safe_cid], bank.rescale)  # (B, P, H)
    scaled = rescale_lib.rescale(resc, qkeys[:, None, :])  # (B, P, H)
    pos = rmi_lib.predict_banked(
        rmi_lib.gather_banked(bank.rmi, safe_cid), scaled
    )  # (B, P, H)

    h_idx = jnp.arange(h, dtype=jnp.int32)[None, None, :, None]
    if refine:
        # Beyond-paper last-mile: gather a 2R key window around the RMI
        # prediction (keys are 4 B vs d*4 B embeddings) and binary-search the
        # exact position inside it, then expand only R around the truth.
        w1 = min(2 * r, lp)
        start1 = jnp.clip(jnp.round(pos).astype(jnp.int32) - w1 // 2, 0, lp - w1)
        idx1 = start1[..., None] + jnp.arange(w1, dtype=jnp.int32)
        flat1 = (safe_cid[:, :, None, None] * h + h_idx) * lp + idx1
        keys_win = jnp.take(bank.sorted_keys.reshape(-1), flat1)  # (B,P,H,W1)
        qk = jnp.broadcast_to(qkeys[:, None, :], (b, p, h)).reshape(-1)
        rows = keys_win.reshape(-1, w1)
        off = jax.vmap(lambda row, q: jnp.searchsorted(row, q))(rows, qk)
        pos = (start1 + off.reshape(b, p, h).astype(jnp.int32)).astype(jnp.float32)

    start = jnp.clip(jnp.round(pos).astype(jnp.int32) - r // 2, 0, lp - r)
    idx = start[..., None] + jnp.arange(r, dtype=jnp.int32)  # (B, P, H, R)
    flat = (safe_cid[:, :, None, None] * h + h_idx) * lp + idx
    local_pos = jnp.take(bank.sorted_pos.reshape(-1), flat)  # (B, P, H, R)

    valid = (local_pos >= 0) & cvalid[:, :, None, None]
    flat_emb = safe_cid[:, :, None, None] * lp + jnp.maximum(local_pos, 0)
    gids = jnp.take(bank.gids.reshape(-1), flat_emb)
    gids = jnp.where(valid, gids, -1)

    # Verification: gather rows from the flat (c*Lp, d) table (row_ids =
    # flat_emb), dedup/report by global passage id (out_ids = gids, -1 where
    # invalid — tombstoned rows carry gid -1 and are suppressed here).
    # Scoring happens in the embedding storage dtype (bf16 stays bf16 on the
    # MXU, int8 runs int8xint8->int32 + exact rescore) with fp32 accumulation
    # for a stable top-k ordering.
    if merge:
        ids, sc = _verify_bank_rows(
            bank,
            flat_emb.reshape(b, -1),
            gids.reshape(b, -1),
            queries,
            k=k,
            rescore_factor=rescore_factor,
            block_c=block_c,
            use_pallas=use_fused,
        )
        return TopK(ids=ids, scores=sc)
    # Per-pair top-k: flatten (query, probe) pairs into the batch axis so the
    # same kernel covers the shape the distributed path scatters back.
    pair_q = jnp.broadcast_to(queries[:, None, :], (b, p, queries.shape[-1]))
    ids, sc = _verify_bank_rows(
        bank,
        flat_emb.reshape(b * p, -1),
        gids.reshape(b * p, -1),
        pair_q.reshape(b * p, -1),
        k=k,
        rescore_factor=rescore_factor,
        block_c=block_c,
        use_pallas=use_fused,
    )
    return TopK(ids=ids.reshape(b, p, k), scores=sc.reshape(b, p, k))


@partial(
    jax.jit,
    static_argnames=(
        "k", "n_probe", "r0", "r0_centroid", "refine", "use_fused",
        "with_stats", "rescore_factor", "block_c",
    ),
)
def search_lider(
    params: LiderParams,
    queries: jnp.ndarray,
    *,
    k: int,
    n_probe: int = 20,
    r0: int = 4,
    r0_centroid: int = 4,
    refine: bool = False,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    with_stats: bool = False,
    rescore_factor: int = 4,
    block_c: int | None = None,
) -> TopK | tuple[TopK, jnp.ndarray]:
    """End-to-end LIDER ANN search (paper Sec. 3.3.2), single device.

    ``prune_margin`` enables adaptive probe pruning (see :func:`prune_probes`;
    traced, so sweeping margins does not recompile; ``None`` is bit-identical
    to the fixed-probe search). ``with_stats=True`` additionally returns the
    (B, n_probe) bool mask of probes that were routed but pruned — serving
    aggregates it into the per-batch pruned-probe fraction.

    On an int8 bank (``LiderConfig.storage_dtype="int8"``) layer-2
    verification runs compressed-domain first, then exactly rescores the
    provisional top-``rescore_factor * k``; the knobs are static so each
    (rescore_factor, block_c) pair is one compile.
    """
    routed = route_queries(
        params, queries, n_probe=n_probe, r0=r0_centroid, use_fused=use_fused,
        block_c=block_c,
    )
    cids = prune_probes(routed.ids, routed.scores, prune_margin)
    out = incluster_search(
        params, queries, cids, k=k, r0=r0, refine=refine,
        use_fused=use_fused, rescore_factor=rescore_factor, block_c=block_c,
    )
    if with_stats:
        pruned = (routed.ids >= 0) & (cids < 0)
        return out, pruned
    return out

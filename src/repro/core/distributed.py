"""Distributed LIDER: cluster-parallel sharded search + sharded k-means build.

Sharding layout (DESIGN.md §2):

- **cluster axis** ``c`` of every in-cluster tensor is sharded over
  ``cluster_axes`` (default the ``data`` mesh axis, plus ``pod`` multi-pod) —
  the paper's "parallelise across clusters" mapped onto devices.
- **query batch** is sharded over ``query_axes`` (default ``model``) — each
  (cluster-shard, query-shard) device pair owns a disjoint (clusters ×
  queries) tile, so the full bipartite search is covered exactly once.
- centroids retriever + LSH banks are replicated (they are KB-to-MB sized).

Search dataflow per device:
  1. route local queries on the replicated centroids retriever (redundant
     across cluster shards — cheaper than broadcasting routed ids),
  2. **capacity dispatch**: of the ``B_loc * n_probe`` (query, cluster) pairs,
     keep those owned by this shard, packed to a static capacity — the exact
     MoE expert-capacity trick; overflow drops are counted and psum'd,
  3. per-pair in-cluster search (gather + MXU scoring, static shapes),
  4. scatter pair results back per query, local top-k,
  5. one all-gather of (B_loc, k) id/score pairs over the cluster axes +
     final merge — the only collective in the hot path.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import compat, faults
from .bank import replicated_field_names
from .clustering import update_centroids
from .core_model import TopK, search_core_model
from .lider import (
    LiderParams,
    _cluster_major_first_pass,
    incluster_search,
    provisional_rows,
    prune_probes,
    rescore_fetched_rows,
)
from .utils import dedup_topk


def _path_name(entry) -> str:
    return entry.name if hasattr(entry, "name") else str(entry)


def lider_param_specs(params: LiderParams, cluster_axes: Sequence[str]):
    """PartitionSpec pytree matching ``params``.

    The spec is derived from the :class:`~repro.core.bank.ClusterBank` field
    metadata rather than a hard-coded name list: every leaf under a bank
    field whose ``cluster_axis`` metadata is 0 is sharded
    ``P(cluster_axes, None, ...)``; bank fields marked replicated (the shared
    LSH bank, scalar bank metadata like ``next_gid``) and everything outside
    the bank (centroids + centroids retriever) get ``P()``. New bank fields
    therefore pick the right layout from their own declaration instead of
    silently cluster-sharding.
    """
    caxes = tuple(cluster_axes)
    replicated_bank_fields = set(replicated_field_names())

    def spec_for(path, leaf):
        if _path_name(path[0]) != "bank":
            return P()  # centroid retriever + centroids: replicated
        if len(path) < 2 or _path_name(path[1]) in replicated_bank_fields:
            return P()
        return P(caxes, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shard_lider_params(
    mesh: jax.sharding.Mesh, params: LiderParams, cluster_axes: Sequence[str]
) -> LiderParams:
    """device_put every leaf onto the mesh with the LIDER layout.

    The host tier (a host-tier bank's off-device rescore table — static
    pytree aux, not a leaf) stays process-local, sharded *by process*
    alongside the device shards: each process keeps the host rows for the
    clusters its devices own (in this single-process codebase that is the
    whole table, exactly like the checkpoint writer's single-process note).
    No device placement and no collectives are involved — the distributed
    search fetches from it between its two device phases.
    """
    specs = lider_param_specs(params, cluster_axes)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs
    )


def _flat_axis_index(axes: Sequence[str]) -> jnp.ndarray:
    return jax.lax.axis_index(tuple(axes))


def make_sharded_search(
    mesh: jax.sharding.Mesh,
    params_like: LiderParams,
    *,
    k: int,
    n_probe: int,
    r0: int = 4,
    r0_centroid: int = 4,
    capacity_factor: float = 2.0,
    cluster_axes: Sequence[str] = ("data",),
    query_axes: Sequence[str] = ("model",),
    refine: bool = False,
    use_fused: bool | None = None,
    prune_margin: float | None = None,
    rescore_factor: int = 4,
    block_c: int | None = None,
    block_q: int | None = None,
    sketch_factor: int | None = None,
):
    """Build the jitted multi-device search fn: (params, queries) -> (TopK, drops).

    ``params_like`` supplies the pytree structure/shapes (ShapeDtypeStructs are
    fine — used by the dry-run). Returned fn expects the query batch to be a
    multiple of the query-axis size.

    ``use_fused`` selects the verification path inside the shard_map body
    (None -> fused Pallas kernel on TPU, materialized reference elsewhere;
    DESIGN.md §Verification-kernel). Both the per-pair in-cluster search and
    the replicated centroid routing honor it.

    ``prune_margin`` applies the adaptive margin rule (DESIGN.md §Adaptive)
    to the routed probes *before* capacity dispatch: pruned pairs never enter
    a shard's pair budget, so pruning additionally shrinks dispatch pressure
    — fewer live pairs means fewer capacity-overflow drops at a given
    ``capacity_factor``.

    Quantized banks (int8 ``embs`` + ``emb_scales``/``rescore_embs``) work
    unchanged: the new bank fields carry ``cluster_axis`` metadata, so their
    PartitionSpecs derive automatically, and the per-pair in-cluster search
    runs the compressed-domain + exact-rescore pass shard-locally
    (``rescore_factor``/``block_c`` tune it) — provisional rows always live
    in the shard that found them, so no extra collective appears.

    **Host-tier banks** (DESIGN.md §Tiered embedding store) split the search
    in two device phases around a host fetch, with *no new collectives*: the
    shard_map phase runs route -> dispatch -> compressed first pass and
    merges per-shard provisional candidates through the *same* single
    all-gather (carrying k' = rescore_factor*k entries instead of k; rows
    offset to global flat ids so the row-dedup stays exact across shards);
    then the front-end fetches the k' exact rows + their gids from the
    process-local host tier and a small top-level jit rescores them
    (dedup/tie-break by gid, the float-path convention). The returned
    ``search`` is therefore a two-phase callable; its jit'd device phase is
    exposed as ``search.stage1`` (what the dry-run lowers).

    ``block_q`` (quantized banks only) runs the shard-local compressed
    first pass on the cluster-major schedule (``fused_verify_grouped``):
    pairs dispatched to a shard that probe the same cluster share one DMA
    of its code rows. The routing + capacity dispatch is replicated on the
    host in NumPy (bit-identical to the device rule — same stable argsort)
    so the per-shard schedules can be built in the host pre-pass; schedule
    arrays ride into the one shard_map as sharded inputs, and the merge
    collective is unchanged — NO new collectives appear. Results are
    bit-identical to the per-query sharded path (tests/test_distributed.py
    gates this in a subprocess). ``sketch_factor`` similarly threads the
    binary-sketch pre-filter into the shard-local first pass, both
    spellings.

    **Degraded mode** (DESIGN.md §Failure model): both tiers accept an
    optional ``shard_health`` bool mask of length ``n_cluster_shards``
    (default: all live). A dead shard's local contribution is masked to
    (-1, -inf) *before* the all-gather, so the merge returns partial
    results over the live shards instead of aborting — and the mask is a
    traced input, so flipping shard health never recompiles. The health of
    the last call is reported as ``search.shard_stats =
    {"shards_live", "shards_total"}``; an active fault plan
    (``faults.SHARD_SEARCH``, mode ``kill_shard``) marks shards dead
    through the same mask.
    """
    caxes = tuple(cluster_axes)
    qaxes = tuple(query_axes)  # may be empty: replicated queries (batch-1)
    n_cluster_shards = math.prod(mesh.shape[a] for a in caxes)
    n_query_shards = math.prod(mesh.shape[a] for a in qaxes) if qaxes else 1
    c_total = params_like.bank.gids.shape[0]
    if c_total % n_cluster_shards:
        raise ValueError(
            f"n_clusters={c_total} must divide cluster shards={n_cluster_shards}"
        )

    param_specs = lider_param_specs(params_like, caxes)
    host_tier = getattr(params_like.bank, "rescore_tier", "device") == "host"

    def _dispatch(local_params, q_loc):
        """Route + prune + capacity dispatch (shared by both tiers)."""
        c_local = local_params.bank.gids.shape[0]
        my = _flat_axis_index(caxes)
        routed = search_core_model(
            local_params.centroid_cm,
            local_params.centroids,
            q_loc,
            k=n_probe,
            r0=r0_centroid,
            use_fused=use_fused,
            block_c=block_c,
        )
        # Adaptive probe pruning before dispatch: a pruned pair is -1, i.e.
        # never "mine" on any shard, so it consumes no capacity slot.
        cids = prune_probes(routed.ids, routed.scores, prune_margin)
        b_loc, p = cids.shape
        n_pairs = b_loc * p
        flat_cids = cids.reshape(-1)
        valid = flat_cids >= 0
        owner = jnp.where(valid, flat_cids // c_local, -1)
        mine = owner == my

        cap = min(
            n_pairs, int(math.ceil(n_pairs / n_cluster_shards * capacity_factor))
        )
        order = jnp.argsort(~mine, stable=True)  # my pairs first
        sel = order[:cap]
        sel_valid = mine[sel]
        sel_b = (sel // p).astype(jnp.int32)
        sel_cid_local = jnp.where(
            sel_valid, flat_cids[sel] - my * c_local, -1
        ).astype(jnp.int32)
        dropped = jnp.sum(mine) - jnp.sum(sel_valid)
        return my, b_loc, p, sel, sel_valid, sel_b, sel_cid_local, dropped

    def _resolve_health(shard_health) -> np.ndarray:
        """Host-side health mask: caller's mask + any injected shard kill."""
        if shard_health is None:
            health = np.ones(n_cluster_shards, np.bool_)
        else:
            health = np.array(shard_health, np.bool_).reshape(-1).copy()
            if health.shape[0] != n_cluster_shards:
                raise ValueError(
                    f"shard_health has {health.shape[0]} entries, expected "
                    f"{n_cluster_shards} cluster shards"
                )
        spec = faults.fire(faults.SHARD_SEARCH)
        if spec is not None and spec.mode == "kill_shard":
            payload = spec.payload or {}
            dead = payload.get("shards")
            if dead is None:
                dead = [payload.get("shard", 0)]
            for s in dead:
                health[int(s) % n_cluster_shards] = False
        return health

    def body(
        local_params: LiderParams, q_loc: jnp.ndarray, shard_health: jnp.ndarray
    ):
        my, b_loc, p, sel, sel_valid, sel_b, sel_cid_local, dropped = _dispatch(
            local_params, q_loc
        )
        n_pairs = b_loc * p

        pair_topk = incluster_search(
            local_params,
            q_loc[sel_b],
            sel_cid_local[:, None],
            k=k,
            r0=r0,
            refine=refine,
            use_fused=use_fused,
            rescore_factor=rescore_factor,
            block_c=block_c,
            sketch_factor=sketch_factor,
        )  # (cap, k)

        # Scatter per-pair results back to their (query, probe-slot) rows.
        scatter_idx = jnp.where(sel_valid, sel, n_pairs)
        ids_buf = (
            jnp.full((n_pairs + 1, k), -1, dtype=jnp.int32)
            .at[scatter_idx]
            .set(pair_topk.ids)
        )
        sc_buf = (
            jnp.full((n_pairs + 1, k), -jnp.inf, dtype=jnp.float32)
            .at[scatter_idx]
            .set(pair_topk.scores)
        )
        l_ids, l_sc = dedup_topk(
            ids_buf[:-1].reshape(b_loc, -1), sc_buf[:-1].reshape(b_loc, -1), k
        )

        # Degraded mode: a dead shard contributes nothing to the merge (and
        # its capacity drops don't count — that work was never owed).
        alive = shard_health[my]
        l_ids = jnp.where(alive, l_ids, -1)
        l_sc = jnp.where(alive, l_sc, -jnp.inf)
        dropped = jnp.where(alive, dropped, 0)

        # The one hot-path collective: merge (B_loc, k) across cluster shards.
        g_ids = jax.lax.all_gather(l_ids, caxes)  # (S, B_loc, k)
        g_sc = jax.lax.all_gather(l_sc, caxes)
        ids, sc = dedup_topk(
            jnp.moveaxis(g_ids, 0, 1).reshape(b_loc, -1),
            jnp.moveaxis(g_sc, 0, 1).reshape(b_loc, -1),
            k,
        )
        dropped = jax.lax.psum(dropped, caxes + qaxes if qaxes else caxes)
        return ids, sc, dropped

    def body_provisional(
        local_params: LiderParams, q_loc: jnp.ndarray, shard_health: jnp.ndarray
    ):
        """Host-tier device phase: compressed pass + provisional merge.

        Identical dataflow to ``body`` but stops at the provisional
        top-k' *flat bank rows* (offset to global row ids, so the row-level
        dedup of the merges stays exact across shards). The all-gather is
        the same single collective, just k' wide.
        """
        my, b_loc, p, sel, sel_valid, sel_b, sel_cid_local, dropped = _dispatch(
            local_params, q_loc
        )
        n_pairs = b_loc * p
        c_local, lp = local_params.bank.gids.shape

        pair_prov = provisional_rows(
            local_params,
            q_loc[sel_b],
            sel_cid_local[:, None],
            k=k,
            r0=r0,
            refine=refine,
            use_fused=use_fused,
            rescore_factor=rescore_factor,
            block_c=block_c,
            sketch_factor=sketch_factor,
        )  # (cap, k') local flat rows + compressed scores
        kp = pair_prov.ids.shape[-1]
        g_rows_pair = jnp.where(
            pair_prov.ids >= 0, pair_prov.ids + my * c_local * lp, -1
        )

        scatter_idx = jnp.where(sel_valid, sel, n_pairs)
        rows_buf = (
            jnp.full((n_pairs + 1, kp), -1, dtype=jnp.int32)
            .at[scatter_idx]
            .set(g_rows_pair)
        )
        sc_buf = (
            jnp.full((n_pairs + 1, kp), -jnp.inf, dtype=jnp.float32)
            .at[scatter_idx]
            .set(pair_prov.scores)
        )
        l_rows, l_sc = dedup_topk(
            rows_buf[:-1].reshape(b_loc, -1), sc_buf[:-1].reshape(b_loc, -1), kp
        )

        alive = shard_health[my]
        l_rows = jnp.where(alive, l_rows, -1)
        l_sc = jnp.where(alive, l_sc, -jnp.inf)
        dropped = jnp.where(alive, dropped, 0)

        g_rows = jax.lax.all_gather(l_rows, caxes)  # (S, B_loc, k')
        g_sc = jax.lax.all_gather(l_sc, caxes)
        rows, sc = dedup_topk(
            jnp.moveaxis(g_rows, 0, 1).reshape(b_loc, -1),
            jnp.moveaxis(g_sc, 0, 1).reshape(b_loc, -1),
            kp,
        )
        dropped = jax.lax.psum(dropped, caxes + qaxes if qaxes else caxes)
        return rows, sc, dropped

    qspec = P(qaxes, None) if qaxes else P(None, None)
    # shard_health is a small replicated (S,) bool vector — a *traced*
    # input, so flipping shard liveness reuses the compiled program.
    sharded = compat.shard_map(
        body_provisional if host_tier else body,
        mesh=mesh,
        in_specs=(param_specs, qspec, P()),
        out_specs=(qspec, qspec, P()),
    )
    run = jax.jit(sharded)

    def _note_health(fn, health: np.ndarray) -> None:
        fn.shard_stats = {
            "shards_live": int(health.sum()),
            "shards_total": n_cluster_shards,
        }

    if block_q is not None:
        if not params_like.bank.quantized:
            raise ValueError(
                "block_q (cluster-major schedule) on the sharded path "
                "requires a quantized (int8/int4) bank — use the per-query "
                "spelling (block_q=None) for float banks"
            )
        return _make_grouped_search(
            mesh=mesh,
            param_specs=param_specs,
            qspec=qspec,
            host_tier=host_tier,
            caxes=caxes,
            qaxes=qaxes,
            n_cluster_shards=n_cluster_shards,
            n_query_shards=n_query_shards,
            c_total=c_total,
            k=k,
            n_probe=n_probe,
            r0=r0,
            r0_centroid=r0_centroid,
            capacity_factor=capacity_factor,
            refine=refine,
            use_fused=use_fused,
            prune_margin=prune_margin,
            rescore_factor=rescore_factor,
            block_c=block_c,
            block_q=block_q,
            sketch_factor=sketch_factor,
            resolve_health=_resolve_health,
            note_health=_note_health,
        )

    if not host_tier:

        def search(params: LiderParams, queries: jnp.ndarray, shard_health=None):
            health = _resolve_health(shard_health)
            _note_health(search, health)
            ids, sc, dropped = run(params, queries, jnp.asarray(health))
            return TopK(ids=ids, scores=sc), dropped

        return search

    def stage1(params: LiderParams, queries: jnp.ndarray, shard_health=None):
        # Plain wrapper (not the raw jit) so the dry-run can lower it with
        # the legacy two-argument signature — the default all-live mask
        # folds to a constant.
        health = _resolve_health(shard_health)
        _note_health(stage1, health)
        return run(params, queries, jnp.asarray(health))

    def search(params: LiderParams, queries: jnp.ndarray, shard_health=None):
        rows, _, dropped = stage1(params, queries, shard_health)
        search.shard_stats = dict(stage1.shard_stats)
        rows_np = np.asarray(rows)
        store = params.bank.store
        fetched = store.fetch(rows_np)  # host np.take on the local shard
        out_gids = store.take_gids(rows_np)  # host row->gid map
        out = _rescore_fetched(
            jnp.asarray(fetched),
            jnp.asarray(out_gids),
            queries,
            k=k,
            use_fused=use_fused,
            block_c=block_c,
        )
        return out, dropped

    search.stage1 = stage1
    return search


@partial(jax.jit, static_argnames=("k", "use_fused", "block_c"))
def _rescore_fetched(
    fetched: jnp.ndarray,
    out_gids: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    k: int,
    use_fused: bool | None,
    block_c: int | None,
) -> TopK:
    """Top-level exact rescore of host-fetched rows (distributed front-end).

    Dedups/reports by global id — gids are globally unique, so no cross-
    shard coordination is needed; ties break by smallest gid (the float-path
    convention)."""
    ids, sc = rescore_fetched_rows(
        fetched, out_gids, queries, k=k, use_fused=use_fused, block_c=block_c
    )
    return TopK(ids=ids, scores=sc)


def _make_grouped_search(
    *,
    mesh,
    param_specs,
    qspec,
    host_tier,
    caxes,
    qaxes,
    n_cluster_shards,
    n_query_shards,
    c_total,
    k,
    n_probe,
    r0,
    r0_centroid,
    capacity_factor,
    refine,
    use_fused,
    prune_margin,
    rescore_factor,
    block_c,
    block_q,
    sketch_factor,
    resolve_health,
    note_health,
):
    """Cluster-major spelling of the sharded search (``block_q`` set).

    Same dataflow as the per-query bodies with one structural change: the
    route + capacity dispatch moves OUT of the shard_map into a host
    pre-pass, because the cluster-major schedule is data-dependent host
    bookkeeping (exactly like the single-device staged search). Routing
    runs once in a small top-level jit over the replicated centroids; the
    per-shard capacity selection is replicated in NumPy with the identical
    rule the device body uses (stable argsort, my-pairs-first, same cap
    formula), so the dispatched pair list the schedules describe is
    bit-identical to what the device would have selected. Every
    (cluster shard, query shard) cell's schedule is padded to the common
    worst case ``_pad_pow2(cap)`` so all shards run one kernel shape, and
    the schedule arrays enter the single shard_map as sharded inputs —
    the merge all-gather stays the only hot-path collective.
    """
    from ..kernels.ops import verify_topk_op
    from ..kernels.schedule import _pad_pow2, build_cluster_schedule

    c_local = c_total // n_cluster_shards

    def _route(params, queries):
        routed = search_core_model(
            params.centroid_cm,
            params.centroids,
            queries,
            k=n_probe,
            r0=r0_centroid,
            use_fused=use_fused,
            block_c=block_c,
        )
        return prune_probes(routed.ids, routed.scores, prune_margin)

    route_jit = jax.jit(_route)

    _CELL_KEYS = (
        "sel", "sel_valid", "sel_b", "sel_cid_local", "dropped",
        "sched_cids", "sched_qids", "pair_step", "pair_slot",
    )

    def _host_cells(cids_np: np.ndarray) -> dict:
        """Replicated dispatch + per-cell schedules for one routed batch."""
        b, p = cids_np.shape
        if b % n_query_shards:
            raise ValueError(
                f"batch {b} must divide query shards={n_query_shards}"
            )
        b_loc = b // n_query_shards
        n_pairs = b_loc * p
        cap = min(
            n_pairs,
            int(math.ceil(n_pairs / n_cluster_shards * capacity_factor)),
        )
        pad_steps = _pad_pow2(cap)  # n_steps <= cap pairs: always fits
        cs_n, qs_n = n_cluster_shards, n_query_shards
        out = {
            "sel": np.zeros((cs_n, qs_n, cap), np.int32),
            "sel_valid": np.zeros((cs_n, qs_n, cap), bool),
            "sel_b": np.zeros((cs_n, qs_n, cap), np.int32),
            "sel_cid_local": np.zeros((cs_n, qs_n, cap), np.int32),
            "dropped": np.zeros((cs_n, qs_n), np.int32),
            "sched_cids": np.zeros((cs_n, qs_n, pad_steps), np.int32),
            "sched_qids": np.full(
                (cs_n, qs_n, pad_steps, block_q), -1, np.int32
            ),
            "pair_step": np.full((cs_n, qs_n, cap, 1), -1, np.int32),
            "pair_slot": np.full((cs_n, qs_n, cap, 1), -1, np.int32),
        }
        for qs in range(qs_n):
            flat = cids_np[qs * b_loc:(qs + 1) * b_loc].reshape(-1)
            valid = flat >= 0
            owner = np.where(valid, flat // c_local, -1)
            for cs in range(cs_n):
                mine = owner == cs
                # np stable argsort on ~mine == the device dispatch's
                # jnp.argsort(~mine, stable=True): my pairs first, original
                # (query asc, probe asc) order preserved — the replication
                # that keeps schedule and dispatched pair list in lockstep.
                order = np.argsort(~mine, kind="stable")
                sel = order[:cap].astype(np.int32)
                sv = mine[sel]
                scl = np.where(sv, flat[sel] - cs * c_local, -1).astype(
                    np.int32
                )
                out["sel"][cs, qs] = sel
                out["sel_valid"][cs, qs] = sv
                out["sel_b"][cs, qs] = (sel // p).astype(np.int32)
                out["sel_cid_local"][cs, qs] = scl
                out["dropped"][cs, qs] = int(mine.sum()) - int(sv.sum())
                sched = build_cluster_schedule(
                    scl[:, None], block_q=block_q, pad_to=pad_steps
                )
                out["sched_cids"][cs, qs] = sched.sched_cids
                out["sched_qids"][cs, qs] = sched.sched_qids
                out["pair_step"][cs, qs] = sched.pair_step
                out["pair_slot"][cs, qs] = sched.pair_slot
        return out

    def gbody(local_params, q_loc, shard_health, *cells):
        cell = {key: arr[0, 0] for key, arr in zip(_CELL_KEYS, cells)}
        my = _flat_axis_index(caxes)
        b_loc = q_loc.shape[0]
        n_pairs = b_loc * n_probe
        c_loc, lp = local_params.bank.gids.shape
        q_pairs = q_loc[cell["sel_b"]]
        prov = _cluster_major_first_pass(
            local_params,
            q_pairs,
            cell["sel_cid_local"][:, None],
            cell["sched_cids"],
            cell["sched_qids"],
            cell["pair_step"],
            cell["pair_slot"],
            k=k,
            r0=r0,
            refine=refine,
            use_fused=use_fused,
            rescore_factor=rescore_factor,
            block_c=block_c,
            block_q=block_q,
            sketch_factor=sketch_factor,
        )  # (cap, k') local flat rows + compressed scores
        scatter_idx = jnp.where(cell["sel_valid"], cell["sel"], n_pairs)
        alive = shard_health[my]

        def _merge(l_ids, l_sc, kk):
            g_ids = jax.lax.all_gather(l_ids, caxes)
            g_sc = jax.lax.all_gather(l_sc, caxes)
            return dedup_topk(
                jnp.moveaxis(g_ids, 0, 1).reshape(b_loc, -1),
                jnp.moveaxis(g_sc, 0, 1).reshape(b_loc, -1),
                kk,
            )

        if host_tier:
            # Stop at provisional global rows, exactly as body_provisional.
            kp = prov.ids.shape[-1]
            g_rows_pair = jnp.where(
                prov.ids >= 0, prov.ids + my * c_loc * lp, -1
            )
            rows_buf = (
                jnp.full((n_pairs + 1, kp), -1, dtype=jnp.int32)
                .at[scatter_idx]
                .set(g_rows_pair)
            )
            sc_buf = (
                jnp.full((n_pairs + 1, kp), -jnp.inf, dtype=jnp.float32)
                .at[scatter_idx]
                .set(prov.scores)
            )
            l_rows, l_sc = dedup_topk(
                rows_buf[:-1].reshape(b_loc, -1),
                sc_buf[:-1].reshape(b_loc, -1),
                kp,
            )
            l_rows = jnp.where(alive, l_rows, -1)
            l_sc = jnp.where(alive, l_sc, -jnp.inf)
            out_ids, out_sc = _merge(l_rows, l_sc, kp)
        else:
            # Device tier: exact rescore of each pair's provisional rows —
            # the same stage-2 math as _verify_bank_rows — then the per-query
            # scatter + merge of body.
            rescore_table = local_params.bank.rescore_embs.reshape(
                c_loc * lp, -1
            )
            rows, sc = verify_topk_op(
                rescore_table,
                jnp.maximum(prov.ids, 0),
                q_pairs,
                k=k,
                out_ids=prov.ids,
                block_c=block_c,
                use_pallas=use_fused,
            )
            gid_tab = local_params.bank.gids.reshape(-1)
            pair_ids = jnp.where(rows >= 0, gid_tab[jnp.maximum(rows, 0)], -1)
            ids_buf = (
                jnp.full((n_pairs + 1, k), -1, dtype=jnp.int32)
                .at[scatter_idx]
                .set(pair_ids)
            )
            sc_buf = (
                jnp.full((n_pairs + 1, k), -jnp.inf, dtype=jnp.float32)
                .at[scatter_idx]
                .set(sc)
            )
            l_ids, l_sc = dedup_topk(
                ids_buf[:-1].reshape(b_loc, -1),
                sc_buf[:-1].reshape(b_loc, -1),
                k,
            )
            l_ids = jnp.where(alive, l_ids, -1)
            l_sc = jnp.where(alive, l_sc, -jnp.inf)
            out_ids, out_sc = _merge(l_ids, l_sc, k)

        dropped = jnp.where(alive, cell["dropped"], 0)
        dropped = jax.lax.psum(dropped, caxes + qaxes if qaxes else caxes)
        return out_ids, out_sc, dropped

    cqs = qaxes if qaxes else None
    spec2 = P(caxes, cqs)
    spec3 = P(caxes, cqs, None)
    spec4 = P(caxes, cqs, None, None)
    cell_specs = (
        spec3, spec3, spec3, spec3, spec2, spec3, spec4, spec4, spec4
    )
    run = jax.jit(
        compat.shard_map(
            gbody,
            mesh=mesh,
            in_specs=(param_specs, qspec, P(), *cell_specs),
            out_specs=(qspec, qspec, P()),
        )
    )

    def search(params: LiderParams, queries: jnp.ndarray, shard_health=None):
        health = resolve_health(shard_health)
        note_health(search, health)
        cids_np = np.asarray(jax.device_get(route_jit(params, queries)))
        cells = _host_cells(cids_np)
        cell_args = tuple(jnp.asarray(cells[key]) for key in _CELL_KEYS)
        rows_or_ids, sc, dropped = run(
            params, queries, jnp.asarray(health), *cell_args
        )
        if not host_tier:
            return TopK(ids=rows_or_ids, scores=sc), dropped
        rows_np = np.asarray(rows_or_ids)
        store = params.bank.store
        fetched = store.fetch(rows_np)
        out_gids = store.take_gids(rows_np)
        out = _rescore_fetched(
            jnp.asarray(fetched),
            jnp.asarray(out_gids),
            queries,
            k=k,
            use_fused=use_fused,
            block_c=block_c,
        )
        return out, dropped

    return search


# ---------------------------------------------------------------------------
# Distributed build: sharded Lloyd iterations (Stage 1 at scale)
# ---------------------------------------------------------------------------


def make_sharded_kmeans_step(
    mesh: jax.sharding.Mesh,
    *,
    n_clusters: int,
    data_axes: Sequence[str] = ("data",),
    chunk: int = 4096,
):
    """One Lloyd iteration with points sharded over ``data_axes``; the
    sufficient statistics are psum'd so every shard gets identical centroids
    (gradient-compression hook: stats are cast to fp32 regardless of input)."""
    daxes = tuple(data_axes)

    def body(x_loc, centroids):
        from .clustering import kmeans_step

        sums, counts, _ = kmeans_step(
            x_loc, centroids, n_clusters=n_clusters, chunk=chunk
        )
        sums = jax.lax.psum(sums.astype(jnp.float32), daxes)
        counts = jax.lax.psum(counts.astype(jnp.float32), daxes)
        return update_centroids(centroids, sums, counts)

    return jax.jit(
        compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(daxes, None), P()),
            out_specs=P(),
        )
    )

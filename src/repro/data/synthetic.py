"""Deterministic synthetic data generators for every model family.

Real MS MARCO / Wiki-21M embeddings are not available offline; the retrieval
generators produce mixture-of-Gaussians corpora (dense-retrieval embeddings
are strongly clustered — the regime LIDER exploits) and queries that are
perturbed corpus points with known relevant sets, so recall/MRR metrics are
meaningful. ``load_embeddings`` accepts a ``.npy`` drop-in to run the same
benchmarks on real embeddings.

Everything is keyed by (seed, step) — ``batch_at(step)`` is a pure function,
which is what makes restart replay exact (fault_tolerance contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.utils import l2_normalize


def load_embeddings(path: str) -> jnp.ndarray:
    return l2_normalize(jnp.asarray(np.load(path), dtype=jnp.float32))


def retrieval_corpus(
    seed: int, n: int, dim: int, *, n_modes: int | None = None, spread: float = 0.35
) -> jnp.ndarray:
    """Clustered unit-norm corpus (N, d). ~256 points/mode approximates the
    local neighborhood density of real passage-embedding spaces."""
    n_modes = n_modes or max(16, n // 256)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    modes = jax.random.normal(k1, (n_modes, dim))
    assign = jax.random.randint(k2, (n,), 0, n_modes)
    pts = modes[assign] + spread * jax.random.normal(k3, (n, dim))
    return l2_normalize(pts)


def retrieval_queries(
    seed: int, corpus: jnp.ndarray, n_queries: int, *, noise: float = 0.08
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Queries near known corpus points -> (queries (Q,d), seed ids (Q,))."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed ^ 0x5EED))
    ids = jax.random.choice(k1, corpus.shape[0], (n_queries,), replace=False)
    q = corpus[ids] + noise * jax.random.normal(k2, (n_queries, corpus.shape[1]))
    return l2_normalize(q), ids


def lm_batch(seed: int, step: int, *, batch: int, seq: int, vocab: int) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    tokens = jax.random.randint(key, (batch, seq + 1), 0, vocab)
    return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}


def recsys_batch(seed: int, step: int, *, kind: str, batch: int, cfg) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 17), step)
    ks = jax.random.split(key, 6)
    if kind == "sasrec":
        return {
            "seq": jax.random.randint(ks[0], (batch, cfg.seq_len), 1, cfg.item_vocab),
            "pos": jax.random.randint(ks[1], (batch, cfg.seq_len), 1, cfg.item_vocab),
            "neg": jax.random.randint(ks[2], (batch, cfg.seq_len), 1, cfg.item_vocab),
        }
    if kind == "two_tower":
        return {
            "user_fields": jax.random.randint(
                ks[0], (batch, cfg.n_user_fields), 0, cfg.field_vocab
            ),
            "item_fields": jnp.concatenate(
                [
                    jax.random.randint(ks[1], (batch, 1), 0, cfg.item_vocab),
                    jax.random.randint(
                        ks[2], (batch, cfg.n_item_fields - 1), 0, cfg.field_vocab
                    ),
                ],
                axis=1,
            ),
        }
    if kind == "din":
        return {
            "history": jax.random.randint(
                ks[0], (batch, cfg.seq_len), 0, cfg.item_vocab
            ),
            "target": jax.random.randint(ks[1], (batch,), 0, cfg.item_vocab),
            "label": jax.random.bernoulli(ks[2], 0.5, (batch,)).astype(jnp.float32),
        }
    if kind == "xdeepfm":
        return {
            "fields": jax.random.randint(
                ks[0], (batch, cfg.n_sparse), 0, cfg.field_vocab
            ),
            "label": jax.random.bernoulli(ks[1], 0.5, (batch,)).astype(jnp.float32),
        }
    raise ValueError(kind)


def random_graph(
    seed: int, n_nodes: int, n_edges: int, d_feat: int, n_classes: int
) -> dict:
    """Random sparse graph with CSR arrays (for the neighbour sampler)."""
    key = jax.random.PRNGKey(seed + 31)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    src = jax.random.randint(k1, (n_edges,), 0, n_nodes)
    dst = jax.random.randint(k2, (n_edges,), 0, n_nodes)
    feat = jax.random.normal(k3, (n_nodes, d_feat))
    labels = jax.random.randint(k4, (n_nodes,), 0, n_classes)
    # CSR by src (for sampling): sort edges by src.
    order = jnp.argsort(src)
    src_s, dst_s = src[order], dst[order]
    counts = jnp.bincount(src_s, length=n_nodes)
    indptr = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])
    return {
        "node_feat": feat,
        "edge_index": jnp.stack([src, dst]).astype(jnp.int32),
        "labels": labels,
        "indptr": indptr.astype(jnp.int32),
        "indices": dst_s.astype(jnp.int32),
    }


def molecule_batch(
    seed: int, step: int, *, n_graphs: int, nodes_per: int, edges_per: int, d_feat: int
) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 47), step)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    n = n_graphs * nodes_per
    e = n_graphs * edges_per
    base = jnp.repeat(jnp.arange(n_graphs) * nodes_per, edges_per)
    src = jax.random.randint(k1, (e,), 0, nodes_per) + base
    dst = jax.random.randint(k2, (e,), 0, nodes_per) + base
    return {
        "node_feat": jax.random.normal(k3, (n, d_feat)),
        "edge_index": jnp.stack([src, dst]).astype(jnp.int32),
        "edge_feat": jax.random.normal(k5, (e, 4)),
        "graph_ids": jnp.repeat(jnp.arange(n_graphs), nodes_per).astype(jnp.int32),
        "n_graphs": n_graphs,
        "graph_targets": jax.random.normal(k4, (n_graphs,)),
    }

"""Step-indexed data pipeline: deterministic, skippable, checkpointable.

The pipeline is a pure function of (seed, step) plus a host-side prefetch
queue. Its checkpoint state is a single integer; restoring a run replays the
exact batch stream (fault_tolerance contract) and a replacement node at any
step sees the same data as the node it replaced.
"""
from __future__ import annotations

import threading
import queue
from typing import Callable, Iterator


class DataPipeline:
    """Wraps ``batch_at(step) -> batch`` into a prefetching iterator."""

    def __init__(
        self,
        batch_at: Callable[[int], dict],
        *,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self.batch_at = batch_at
        self.step = start_step
        self.prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        if prefetch > 0:
            self._start_worker()

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step}

    @classmethod
    def from_state(cls, batch_at, state: dict, **kw) -> "DataPipeline":
        return cls(batch_at, start_step=state["step"], **kw)

    # -- iteration -----------------------------------------------------------
    def _start_worker(self):
        def work():
            s = self.step
            while not self._stop.is_set():
                try:
                    self._q.put((s, self.batch_at(s)), timeout=0.1)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self.prefetch > 0:
            while True:
                s, batch = self._q.get()
                if s == self.step:  # drop stale prefetches after a restore
                    break
        else:
            batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def close(self):
        self._stop.set()

"""Health-checked query router over a replica set, with hedged requests,
failover, and zero-downtime rolling index updates (DESIGN.md §Replica
fabric).

The router duck-types the engine's serving surface — ``submit`` /
``pending_requests`` / ``drain`` / ``result`` — so the open-loop traffic
driver and ``launch/serve.py`` run unchanged against N replicas. Scheduling
stays centralized: the router owns one :class:`~.scheduler.Scheduler`
(admission control, weighted-fair tenants, dynamic batch sizing) and
dispatches each admitted batch onto one replica engine via
:meth:`~.engine.RetrievalEngine.execute_chunk`, on a small thread pool so
replicas serve concurrently and a straggling batch can be *hedged*:

* **Hedging** — once enough batch latencies are observed, a dispatch that
  has not answered within the ``hedge_quantile`` latency deadline is
  re-sent to a second replica serving the *same index generation*. The
  first non-degraded answer wins; the loser's answers are discarded
  bit-safely (never delivered, never cached at router level — replicas at
  one generation are bit-identical, so the winner's bytes are the loser's
  bytes). Hedging loses when load is high (no idle replica to hedge onto)
  or batches are tiny (the deadline floor dominates); see DESIGN.md.
* **Failover** — a dispatch that errors (or lands on a replica killed
  mid-flight) is retried on the next-best replica, bounded by
  ``max_retries``; a degraded answer is kept as fallback rather than
  retried. When every attempt fails the batch is shed with a structured
  ``"no_replica"`` reason — the router-level rung below the engine's own
  degradation ladder (which already ran inside each attempt).
* **Zero wrong-generation answers** — every answer is stamped by its
  engine with the generation that computed it; the router verifies the
  stamp against the generation captured at dispatch and discards (then
  fails over) on mismatch. During a rolling update the mixed-generation
  window is explicit: :meth:`QueryRouter.generation_window` reports the
  live span.

**Rolling updates** (:meth:`RouterControl.apply_updates`) drain and update
one replica at a time behind the health mask: mask the replica from
routing, wait for its in-flight batches (hedge losers included) to finish,
run the engine's transactional ``apply_updates`` off-thread, unmask, move
on. At most one replica is ever masked, so N-1 replicas keep serving —
zero downtime. Dead/killed replicas are skipped and marked *stale* (they
never rejoin routing at the wrong generation). A failed per-replica update
is retried once, then the replica is marked stale and the roll continues.
Once the roll completes every replica serves the new generation and
results are bit-identical to a single updated engine.
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from .. import faults
from .engine import EVICTED, QueryResult, Shed
from .replica import DEAD, HEALTHY, HealthPolicy, ReplicaDead, ReplicaSet
from .scheduler import DEFAULT_TENANT, Request, Scheduler, SchedulerConfig

import threading


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Router knobs.

    ``hedge_quantile`` sets the hedging deadline as a quantile of recent
    per-batch service times (``None`` disables hedging); the deadline
    never drops below ``hedge_floor_s`` and hedging stays off until
    ``hedge_min_samples`` batches have been observed. ``max_retries``
    bounds failover re-dispatches per batch (attempts = 1 + retries).
    ``deadline_s``/``max_queue`` feed the router scheduler's admission
    control, mirroring the engine's ``DegradePolicy`` knobs.
    """

    hedge_quantile: Optional[float] = 0.95
    hedge_min_samples: int = 12
    hedge_floor_s: float = 1e-3
    max_retries: int = 2
    deadline_s: Optional[float] = None
    max_queue: Optional[int] = None
    max_results: int = 65536


@dataclasses.dataclass
class RouterStats:
    """Router-level accounting (per-engine stats live on each replica)."""

    n_queries: int = 0  # answered (delivered, non-shed) requests
    n_batches: int = 0
    n_shed: int = 0  # admission sheds + no-replica sheds
    n_dispatches: int = 0  # batch->replica attempts (hedges/retries incl.)
    n_dispatch_failures: int = 0
    n_failovers: int = 0  # batches re-dispatched after a failed attempt
    n_hedges: int = 0
    n_hedge_wins: int = 0  # hedge answered first (non-degraded)
    n_hedge_losses: int = 0  # hedged batch answered by the primary
    n_wrong_generation: int = 0  # answers discarded by the generation guard
    n_replica_kills: int = 0
    n_degraded: int = 0
    n_rolls_started: int = 0
    n_rolls_completed: int = 0
    n_roll_replicas_updated: int = 0
    n_roll_replicas_skipped: int = 0  # dead/failed replicas marked stale
    n_roll_update_failures: int = 0
    recent_latency_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=4096)
    )
    # Per-dispatch wall times (stragglers included) — the hedging
    # deadline's sample distribution.
    recent_batch_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=512)
    )

    @property
    def availability(self) -> float:
        """Fraction of finished requests that got an answer (vs shed)."""
        return self.n_queries / max(self.n_queries + self.n_shed, 1)

    def latency_quantile(self, q: float) -> float:
        if not self.recent_latency_s:
            return 0.0
        return float(np.quantile(np.asarray(self.recent_latency_s), q))


class QueryRouter:
    """Spread scheduler batches across a health-tracked replica set.

    ``engines`` is a list of :class:`~.engine.RetrievalEngine` (or
    ``(name, engine)`` pairs, or a prebuilt :class:`ReplicaSet`). Replicas
    should be built identically (same params) — the fleet guarantees
    assume one logical index. ``fault_plan`` drives the ``replica_*``
    chaos sites and is fired directly (not via the module-global
    activation) so worker-thread timing never changes the schedule.
    """

    def __init__(
        self,
        engines,
        *,
        config: RouterConfig | None = None,
        health: HealthPolicy | None = None,
        scheduler: SchedulerConfig | None = None,
        fault_plan=None,
    ):
        self.cfg = config if config is not None else RouterConfig()
        self.fault_plan = fault_plan
        self._lock = threading.RLock()
        if isinstance(engines, ReplicaSet):
            self.replicas = engines
            self.replicas.lock = self._lock
            if self.replicas.fault_plan is None:
                self.replicas.fault_plan = fault_plan
        else:
            self.replicas = ReplicaSet(
                engines,
                policy=health,
                fault_plan=fault_plan,
                lock=self._lock,
            )
        first = self.replicas.replicas[0].engine
        self.batch_size = first.batch_size
        self.k = first.k
        self.sched_cfg = (
            scheduler if scheduler is not None else SchedulerConfig()
        )
        if self.sched_cfg.cache_size:
            # Result caching stays per-engine: a router-level cache would
            # need its own cross-replica generation keying for no win.
            self.sched_cfg = dataclasses.replace(
                self.sched_cfg, cache_size=0
            )
        self.scheduler = Scheduler(
            self.sched_cfg,
            batch_size=self.batch_size,
            deadline_s=self.cfg.deadline_s,
            max_queue=self.cfg.max_queue,
        )
        self.stats = RouterStats()
        self.results: collections.OrderedDict = collections.OrderedDict()
        self._evicted: collections.OrderedDict = collections.OrderedDict()
        self._next_id = 0
        self._seq = 0  # dispatch sequence for LRU round-robin
        self._roll: Optional[dict] = None
        # One worker per replica covers full fan-out; +2 leaves headroom
        # for a hedge racing a straggler plus a rolling-update task.
        self._pool = cf.ThreadPoolExecutor(
            max_workers=len(self.replicas) + 2,
            thread_name_prefix="router",
        )
        self.control = RouterControl(self)

    # -- engine-compatible serving surface ---------------------------------

    @property
    def pending_requests(self) -> int:
        return len(self.scheduler)

    def warmup(self, *, warm_ladder: bool = True) -> None:
        for r in self.replicas:
            r.engine.warmup(warm_ladder=warm_ladder)

    def submit(self, query, *, tenant: str = DEFAULT_TENANT) -> int:
        rid = self._next_id
        self._next_id += 1
        vec = np.asarray(query, np.float32)
        req = Request(
            rid=rid,
            query=vec,
            t_submit=time.perf_counter(),
            tenant=tenant,
            fp=self.scheduler.fingerprint(vec),
        )
        reason = self.scheduler.admit(req)
        if reason is not None:
            with self._lock:
                self.stats.n_shed += 1
                self._put_result(rid, Shed(rid=rid, reason=reason))
        return rid

    def drain(self, max_dispatches: int | None = None) -> None:
        """Dispatch queued batches across the fleet; also the router's
        clock tick — fires the ``replica_kill`` site once per call,
        advances health reprobes, and steps any in-flight rolling update."""
        self._maybe_kill()
        self.replicas.tick()
        self._advance_roll()
        n_disp = 0
        while len(self.scheduler):
            if max_dispatches is not None and n_disp >= max_dispatches:
                break
            chunk = self.scheduler.take(self.scheduler.pick_batch_size())
            if not chunk:
                break
            n_disp += 1
            self._dispatch_batch(chunk)
            self._advance_roll()

    def result(self, rid: int, *, keep: bool = False):
        if rid in self._evicted:
            return EVICTED
        if keep:
            return self.results.get(rid)
        return self.results.pop(rid, None)

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def generation_window(self) -> tuple:
        """(min, max) index generation across serveable replicas — the
        explicit mixed-generation window during a rolling update (equal
        outside one)."""
        with self._lock:
            gens = [r.generation for r in self.replicas if r.serveable()]
        if not gens:
            return (None, None)
        return (min(gens), max(gens))

    def stats_dict(self) -> dict:
        """JSON-friendly snapshot: router counters + per-replica health."""
        d = {
            f.name: getattr(self.stats, f.name)
            for f in dataclasses.fields(RouterStats)
            if not isinstance(getattr(self.stats, f.name), collections.deque)
        }
        d["availability"] = self.stats.availability
        d["p50_s"] = self.stats.latency_quantile(0.5)
        d["p99_s"] = self.stats.latency_quantile(0.99)
        lo, hi = self.generation_window()
        d["generation_window"] = [lo, hi]
        d["rolling_update_active"] = self._roll is not None
        d["n_heartbeats"] = self.replicas.n_heartbeats
        d["n_heartbeat_misses"] = self.replicas.n_heartbeat_misses
        d["replicas"] = self.replicas.health_snapshot()
        return d

    # -- dispatch ----------------------------------------------------------

    def _put_result(self, rid: int, value) -> None:
        self.results[rid] = value
        while len(self.results) > self.cfg.max_results:
            old_rid, _ = self.results.popitem(last=False)
            self._evicted[old_rid] = None
            while len(self._evicted) > self.cfg.max_results:
                self._evicted.popitem(last=False)

    def _dispatch_batch(self, chunk: list) -> None:
        """Run one batch to an answer: primary dispatch, hedge after the
        latency-quantile deadline, bounded failover, then shed."""
        with self._lock:
            self.stats.n_batches += 1
        fallback = None  # first degraded (answers, rep, dt) seen
        tried: list[str] = []
        for attempt in range(self.cfg.max_retries + 1):
            if attempt > 0:
                with self._lock:
                    self.stats.n_failovers += 1
            primary = self.replicas.pick(exclude=tried)
            if primary is None:
                # Whole fleet tried once: retries may revisit replicas.
                primary = self.replicas.pick()
            if primary is None:
                break  # nothing serveable at all
            fut, gen = self._launch(primary, chunk)
            futures = {fut: (primary, gen)}
            if primary.name not in tried:
                tried.append(primary.name)
            hedge = None
            deadline = self._hedge_deadline()
            if deadline is not None:
                done, _ = cf.wait([fut], timeout=deadline)
                if not done:
                    # Straggler: race a second replica at the SAME
                    # generation so either answer is bit-safe to deliver.
                    # Idle replicas only — a busy candidate would queue
                    # behind its in-flight batch and lose the race.
                    hedge = self.replicas.pick(
                        exclude=tried, generation=gen, idle_only=True
                    )
                    if hedge is not None:
                        with self._lock:
                            self.stats.n_hedges += 1
                        hfut, hgen = self._launch(hedge, chunk)
                        futures[hfut] = (hedge, hgen)
                        tried.append(hedge.name)
            winner = None
            while futures and winner is None:
                done, _ = cf.wait(
                    list(futures), return_when=cf.FIRST_COMPLETED
                )
                for f in done:
                    rep, g = futures.pop(f)
                    settled = self._settle(f, rep, g)
                    if settled is None:
                        continue  # failed attempt (health recorded)
                    answers, dt = settled
                    if all(a.degraded for a in answers):
                        # Keep as fallback; a non-degraded answer from the
                        # other in-flight attempt still wins.
                        if fallback is None:
                            fallback = (answers, rep, dt)
                        continue
                    winner = (answers, rep, dt)
                    break
            for f, (rep, g) in futures.items():
                # Bit-safe discard: the loser finishes in the background,
                # contributes health/latency signal, delivers nothing.
                f.add_done_callback(self._discard_cb(rep, g))
            if winner is not None:
                answers, rep, dt = winner
                self._deliver(chunk, answers, rep, dt, hedge_win=rep is hedge)
                return
            if fallback is not None:
                answers, rep, dt = fallback
                self._deliver(chunk, answers, rep, dt, hedge_win=False)
                return
        # Bounded retries exhausted below the engines' own degradation
        # ladders: answer structurally rather than hang.
        self._shed_chunk(chunk, "no_replica")

    def _launch(self, rep, chunk):
        """Submit one dispatch attempt; returns (future, generation at
        dispatch) — the stamp every answer must match."""
        gen = rep.engine.generation
        with self._lock:
            self._seq += 1
            rep.last_used = self._seq
            rep.outstanding += 1
            self.stats.n_dispatches += 1
        self._set_rung(rep)
        return self._pool.submit(self._run_on, rep, chunk), gen

    def _run_on(self, rep, chunk):
        """Worker-thread body: fire the dispatch fault site, execute the
        batch under the replica's lock, re-check liveness."""
        t0 = time.perf_counter()
        try:
            plan = self.fault_plan
            if plan is not None:
                spec = plan.fire(faults.REPLICA_DISPATCH)
                if spec is not None and faults.spec_targets(spec, rep.name):
                    if spec.mode == "straggle":
                        time.sleep(spec.delay_s)
                    elif spec.mode == "fail":
                        raise faults.InjectedFault(
                            faults.REPLICA_DISPATCH,
                            f"injected dispatch failure on {rep.name!r}",
                        )
            if rep.killed:
                raise ReplicaDead(rep.name)
            with rep.lock:
                if rep.killed:
                    raise ReplicaDead(rep.name)
                answers = rep.engine.execute_chunk(list(chunk))
            if rep.killed:
                # Killed mid-flight: the device may have answered, but the
                # replica is gone — fail over instead of delivering.
                raise ReplicaDead(
                    rep.name, f"replica {rep.name!r} killed mid-flight"
                )
            return answers, time.perf_counter() - t0
        finally:
            with self._lock:
                rep.outstanding -= 1

    def _settle(self, fut, rep, gen):
        """Resolve one finished attempt: record health, verify the
        generation stamp. Returns (answers, dt) or None on failure."""
        try:
            answers, dt = fut.result()
        except Exception:
            with self._lock:
                self.stats.n_dispatch_failures += 1
            self.replicas.record_failure(rep)
            return None
        self.replicas.record_success(rep, dt)
        with self._lock:
            self.stats.recent_batch_s.append(dt)
        bad = sum(
            1
            for a in answers
            if isinstance(a, QueryResult) and a.generation != gen
        )
        if bad:
            # The wrong-generation guard: an update raced this dispatch
            # (e.g. apply_updates called directly on the engine, outside
            # RouterControl). Discard and fail over — never deliver.
            with self._lock:
                self.stats.n_wrong_generation += bad
            return None
        return answers, dt

    def _discard_cb(self, rep, gen):
        def cb(fut):
            if self._settle(fut, rep, gen) is not None:
                with self._lock:
                    self.stats.n_hedge_losses += 1

        return cb

    def _deliver(self, chunk, answers, rep, dt, *, hedge_win):
        with self._lock:
            self.stats.n_queries += len(chunk)
            if hedge_win:
                self.stats.n_hedge_wins += 1
            for req, a in zip(chunk, answers):
                if isinstance(a, QueryResult):
                    a.replica = rep.name
                    if a.degraded:
                        self.stats.n_degraded += 1
                    if a.latency_s is not None:
                        self.stats.recent_latency_s.append(a.latency_s)
                self._put_result(req.rid, a)
        self.scheduler.observe_service(len(chunk), dt)

    def _shed_chunk(self, chunk, reason: str) -> None:
        with self._lock:
            self.stats.n_shed += len(chunk)
            for req in chunk:
                self._put_result(req.rid, Shed(rid=req.rid, reason=reason))

    def _hedge_deadline(self) -> Optional[float]:
        q = self.cfg.hedge_quantile
        if q is None or self.replicas.n_serveable() < 2:
            return None
        with self._lock:
            if len(self.stats.recent_batch_s) < self.cfg.hedge_min_samples:
                return None
            lat = np.asarray(self.stats.recent_batch_s)
        return max(float(np.quantile(lat, q)), self.cfg.hedge_floor_s)

    def _set_rung(self, rep) -> None:
        """Per-replica operating point: navigate the replica's materialized
        ``select_operating_point`` chain (``DegradePolicy.ladder``, built
        from the swept Pareto frontier) by the scheduler's load signal,
        stepping one rung cheaper on a not-fully-healthy replica while it
        proves itself out."""
        ladder = getattr(rep.engine.policy, "ladder", ())
        if not ladder or self.sched_cfg.slo_s is None:
            return
        load = self.scheduler.load_signal(time.perf_counter())
        target = min(int(round(load * len(ladder))), len(ladder))
        if rep.state != HEALTHY:
            target = min(target + 1, len(ladder))
        rep.engine.rung = target

    # -- chaos hooks -------------------------------------------------------

    def _maybe_kill(self) -> None:
        """Fire the ``replica_kill`` site (once per drain call)."""
        plan = self.fault_plan
        if plan is None:
            return
        spec = plan.fire(faults.REPLICA_KILL)
        if spec is None or spec.mode != "kill_replica":
            return
        payload = spec.payload if isinstance(spec.payload, dict) else {}
        name = payload.get("replica")
        if name is None:
            live = [r for r in self.replicas if not r.killed]
            if not live:
                return
            name = live[0].name
        try:
            rep = self.replicas.get(name)
        except KeyError:
            return
        if not rep.killed:
            self.replicas.kill(name)
            with self._lock:
                self.stats.n_replica_kills += 1

    # -- rolling updates ---------------------------------------------------

    def _advance_roll(self) -> None:
        """One step of the rolling-update state machine (driven from
        ``drain``): finish/react to an in-flight per-replica update, else
        mask the next eligible replica, wait out its in-flight batches,
        and launch its transactional update off-thread."""
        with self._lock:
            roll = self._roll
        if roll is None:
            return
        fut = roll["future"]
        if fut is not None:
            if not fut.done():
                return
            rep = roll["replica"]
            roll["future"] = None
            roll["replica"] = None
            try:
                fut.result()
            except Exception:
                with self._lock:
                    self.stats.n_roll_update_failures += 1
                # The engine rolled its transaction back (old generation
                # intact). Retry once; then drop the replica from the
                # fleet rather than stall the roll.
                if rep.name not in roll["retried"]:
                    roll["retried"].add(rep.name)
                else:
                    with self._lock:
                        rep.stale = True
                        rep.updating = False
                        self.stats.n_roll_replicas_skipped += 1
                    roll["i"] += 1
            else:
                with self._lock:
                    rep.updating = False
                    self.stats.n_roll_replicas_updated += 1
                roll["i"] += 1
            return
        order = roll["order"]
        while roll["i"] < len(order):
            cand = self.replicas.get(order[roll["i"]])
            # Eligibility checks actual health, NOT serveable(): the roll
            # itself sets the `updating` mask, which must not read as
            # ill-health when a failed first attempt comes back for its
            # retry.
            if cand.killed or cand.stale or cand.state == DEAD:
                # Skipped behind the health mask. Mark stale: if it later
                # recovered it would serve the pre-roll generation.
                with self._lock:
                    if not cand.stale:
                        cand.stale = True
                        self.stats.n_roll_replicas_skipped += 1
                roll["i"] += 1
                continue
            break
        if roll["i"] >= len(order):
            with self._lock:
                self._roll = None
                self.stats.n_rolls_completed += 1
            return
        cand = self.replicas.get(order[roll["i"]])
        with self._lock:
            cand.updating = True  # mask from routing before waiting idle
            busy = cand.outstanding > 0
        if busy:
            return  # in-flight batches (hedge losers too) must finish
        roll["replica"] = cand
        roll["future"] = self._pool.submit(
            self._locked_update, cand, roll["update_fn"]
        )

    @staticmethod
    def _locked_update(rep, update_fn):
        # The replica lock serializes the swap against any execute_chunk
        # that raced past the updating mask; the generation guard would
        # catch (and discard) such an answer either way.
        with rep.lock:
            return rep.engine.apply_updates(update_fn)


class RouterControl:
    """Operator control plane: rolling index updates over the fleet."""

    def __init__(self, router: QueryRouter):
        self.router = router

    @property
    def rolling(self) -> bool:
        return self.router._roll is not None

    def apply_updates(
        self,
        update_fn: Callable,
        *,
        block: bool = True,
        poll_s: float = 2e-3,
        timeout: Optional[float] = None,
    ) -> None:
        """Start a rolling update: every live replica is drained and
        updated in turn, one at a time (zero downtime — N-1 replicas keep
        serving throughout). ``update_fn`` must be deterministic: it runs
        once per replica and post-roll bit-identity across the fleet (and
        vs a single updated engine) depends on it. With ``block=False``
        the roll advances inside subsequent ``drain`` calls — serving
        continues while the fleet rolls; use :meth:`wait` to finish."""
        r = self.router
        with r._lock:
            if r._roll is not None:
                raise RuntimeError("a rolling update is already in flight")
            r._roll = {
                "update_fn": update_fn,
                "order": [rep.name for rep in r.replicas],
                "i": 0,
                "replica": None,
                "future": None,
                "retried": set(),
            }
            r.stats.n_rolls_started += 1
        if block:
            self.wait(poll_s=poll_s, timeout=timeout)

    def wait(
        self, *, poll_s: float = 2e-3, timeout: Optional[float] = None
    ) -> None:
        """Pump drains until the in-flight roll completes (queued traffic
        keeps being served while waiting)."""
        r = self.router
        t0 = time.perf_counter()
        while True:
            r.drain()
            with r._lock:
                if r._roll is None:
                    return
            if timeout is not None and time.perf_counter() - t0 > timeout:
                raise TimeoutError("rolling update did not complete")
            time.sleep(poll_s)

"""Open-loop arrival traffic for the serving front end.

Real serving load is not a closed loop (submit everything, drain once):
requests arrive on their own clock, skewed toward popular queries, in
bursts, from tenants of different sizes. This module generates seeded,
replayable traces of that shape and drives an engine through them in
real time — shared by ``launch.serve --arrival {zipf,burst}`` and the
headline ``benchmarks.serve_scale``.

- Arrival times: Poisson at ``mean_rate``, or alternating normal/burst
  episodes (``pattern="burst"``) where bursts arrive ``burst_factor``x
  faster — the workload that separates an adaptive scheduler from a
  fixed-batch loop.
- Query popularity: Zipf over a finite pool (rank-``r`` weight
  ``r^-zipf_a``), the distribution that makes a result cache pay.
- Tenants: geometric skew (tenant ``i`` submits ``tenant_skew``x more
  than tenant ``i+1``), the distribution that makes fair queueing pay.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

ARRIVAL_PATTERNS = ("closed", "zipf", "burst")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: at trace time ``t`` (seconds from start),
    submit pool query ``query_idx`` on behalf of ``tenant``."""

    t: float
    query_idx: int
    tenant: str


def zipf_weights(pool_size: int, a: float) -> np.ndarray:
    """Normalized rank-frequency weights: rank r gets r^-a."""
    w = np.arange(1, pool_size + 1, dtype=np.float64) ** -a
    return w / w.sum()


def tenant_names(n_tenants: int) -> list[str]:
    return [f"tenant{i}" for i in range(n_tenants)]


def make_trace(
    *,
    seed: int,
    n_arrivals: int,
    pool_size: int,
    mean_rate: float,
    pattern: str = "zipf",
    zipf_a: float = 1.1,
    burst_factor: float = 4.0,
    episode_len: int = 64,
    n_tenants: int = 1,
    tenant_skew: float = 2.0,
) -> list[Arrival]:
    """Seeded open-loop trace of ``n_arrivals`` requests.

    ``pattern="zipf"``: constant-rate Poisson arrivals. ``"burst"``:
    alternating episodes of ``episode_len`` arrivals at ``mean_rate`` and
    at ``burst_factor * mean_rate`` (same long-run count, spikier queue).
    ``"closed"`` puts every arrival at t=0 — the legacy submit-all shape,
    kept so one driver serves all three. Query indices are Zipf-skewed in
    every pattern; popularity is what the result cache monetizes.
    """
    if pattern not in ARRIVAL_PATTERNS:
        raise ValueError(
            f"pattern {pattern!r} not in {ARRIVAL_PATTERNS}"
        )
    if mean_rate <= 0:
        raise ValueError(f"mean_rate must be > 0, got {mean_rate}")
    rng = np.random.default_rng(seed)
    qidx = rng.choice(
        pool_size, size=n_arrivals, p=zipf_weights(pool_size, zipf_a)
    )
    tnames = tenant_names(n_tenants)
    tw = tenant_skew ** -np.arange(n_tenants, dtype=np.float64)
    tidx = rng.choice(n_tenants, size=n_arrivals, p=tw / tw.sum())
    if pattern == "closed":
        times = np.zeros(n_arrivals)
    else:
        rates = np.full(n_arrivals, mean_rate)
        if pattern == "burst":
            episode = (np.arange(n_arrivals) // max(episode_len, 1)) % 2
            rates = np.where(episode == 1, mean_rate * burst_factor, rates)
        times = np.cumsum(rng.exponential(1.0 / rates))
    return [
        Arrival(t=float(times[i]), query_idx=int(qidx[i]), tenant=tnames[tidx[i]])
        for i in range(n_arrivals)
    ]


def run_open_loop(
    engine,
    trace: Sequence[Arrival],
    pool: np.ndarray,
    *,
    drain_chunk: int = 1,
) -> list[int]:
    """Replay ``trace`` against ``engine`` in real time; returns rids in
    trace order.

    The loop interleaves submission with bounded drains
    (``drain(max_dispatches=drain_chunk)``): arrivals whose time has come
    are submitted, then at most ``drain_chunk`` batches execute, then the
    clock is checked again — so a long backlog never blocks admission
    (open loop), and the scheduler sees the queue depth each arrival
    pattern actually produces. Sleeps only when idle before the next
    arrival.

    Host-tier engines overlap batch i's exact-row fetch with batch i+1's
    compressed first pass — which needs at least two batches dispatched in
    one drain call, so ``drain_chunk`` is raised to the engine's pipeline
    depth when the served params are host-tier (``drain_chunk=1`` used to
    collapse the overlap to zero under open-loop replay).
    """
    staged = getattr(engine, "_staged_host_serving", None)
    if (
        drain_chunk is not None
        and staged is not None
        and staged()
    ):
        drain_chunk = max(drain_chunk, getattr(engine, "_pipeline_depth", 2))
    t0 = time.perf_counter()
    rids: list[int] = []
    i = 0
    n = len(trace)
    while i < n or engine.pending_requests:
        now = time.perf_counter() - t0
        while i < n and trace[i].t <= now:
            a = trace[i]
            rids.append(engine.submit(pool[a.query_idx], tenant=a.tenant))
            i += 1
        if engine.pending_requests:
            engine.drain(max_dispatches=drain_chunk)
        elif i < n:
            time.sleep(min(max(trace[i].t - now, 0.0), 1e-3))
    return rids

"""Admission, fairness, caching, and batch sizing for the serving engine.

This is the control layer of the async continuous-batching front end
(DESIGN.md §Serving front end). The engine owns *execution* (device
dispatch, the host-tier pipeline, degradation); the :class:`Scheduler`
owns every decision about *what enters a batch and when*:

- **Admission**: queue-cap and deadline-based shedding decided at submit
  time (subsumes the engine's old ``max_queue`` check — the engine still
  wraps the refusal in its structured :class:`~.engine.Shed` answer).
- **Per-tenant weighted-fair queues**: start-time fair queueing over a
  virtual clock; a tenant submitting 10x faster than its peers gets its
  weight's share of batch slots, not 10x.
- **Result cache**: bounded LRU keyed by the exact query bytes plus the
  ``(k, generation, rung)`` serving context, so a hit is *bit-identical*
  to recomputing and a generation bump (``apply_updates``) naturally
  invalidates every cached answer.
- **Dynamic batch sizing**: per dispatch, the smallest pre-warmed pow2
  batch size covering the queue depth, capped by SLO headroom — small
  bursts stop paying full-batch padding latency. Every size in
  :func:`batch_ladder` is compiled once in ``warmup``, so sizing
  decisions never re-trace on the query path.

Everything here is plain host-side Python — no jax, no device state —
so it is cheap per dispatch and trivially testable in isolation.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Mapping, Optional

import numpy as np

DEFAULT_TENANT = "default"

# EMA smoothing for observed per-query service time (the signal behind
# deadline admission and the SLO headroom cap on batch size).
_SERVICE_EMA_ALPHA = 0.3


def batch_ladder(batch_size: int, min_batch: int = 1) -> tuple[int, ...]:
    """Pow2 batch sizes from ``min_batch`` up to (and always including)
    ``batch_size``. Each entry is compiled once at warmup; dispatch picks
    from this ladder so dynamic sizing never re-traces."""
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    min_batch = max(1, min(min_batch, batch_size))
    sizes = []
    b = min_batch
    while b < batch_size:
        sizes.append(b)
        b *= 2
    sizes.append(batch_size)
    return tuple(sizes)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Front-end knobs. The default config reproduces the legacy engine
    byte-for-byte: one FIFO tenant, fixed ``batch_size`` batches, no
    cache, no SLO — so existing callers and tests see identical behavior.

    ``dynamic_batch`` turns on ladder-based batch sizing. ``cache_size``
    > 0 enables the result cache. ``slo_s`` is the per-request latency
    objective: it feeds the load signal (frontier navigation in
    ``tuning.pareto.select_operating_point``), caps dynamic batch growth
    when the oldest request is short on headroom, and — with
    ``deadline_admission`` — sheds requests predicted to miss the SLO
    even if queued now. ``max_queue`` caps total queued requests
    (the engine also honors its ``DegradePolicy.max_queue``; the tighter
    bound wins). ``tenant_weights`` maps tenant name -> relative share of
    batch slots (unlisted tenants get weight 1.0).
    """

    dynamic_batch: bool = False
    min_batch: int = 1
    cache_size: int = 0
    slo_s: Optional[float] = None
    max_queue: Optional[int] = None
    deadline_admission: bool = False
    tenant_weights: Mapping[str, float] = dataclasses.field(
        default_factory=dict
    )
    # Queue depth mapped to load_signal == 1.0; defaults to 4 * batch_size.
    depth_reference: Optional[int] = None


@dataclasses.dataclass
class Request:
    """One admitted query. ``fp`` is the cache fingerprint (None when the
    cache is off); ``tenant`` picks the fair queue it waits in."""

    rid: int
    query: np.ndarray
    t_submit: float
    tenant: str = DEFAULT_TENANT
    fp: Optional[bytes] = None


class ResultCache:
    """Bounded LRU of answered queries.

    Keys are ``(query-bytes, k, generation, rung)`` — the full serving
    context — so a hit is bit-identical to re-running the search: same
    float32 bytes in, same index generation, same operating point. The
    engine clears the cache on every ``apply_updates`` (the generation in
    the key already prevents stale hits; clearing also stops a dead
    generation's entries from occupying the bound).
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._map: collections.OrderedDict[tuple, tuple] = (
            collections.OrderedDict()
        )

    @staticmethod
    def fingerprint(query: np.ndarray) -> bytes:
        """Exact-bytes fingerprint of a float32 query vector. Exactness is
        deliberate: a rounded/near-duplicate fingerprint would trade away
        the bit-identical-to-fresh-search guarantee the cache is gated on."""
        return np.ascontiguousarray(query, np.float32).tobytes()

    def get(self, fp: bytes, ctx: tuple):
        key = (fp, *ctx)
        hit = self._map.get(key)
        if hit is not None:
            self._map.move_to_end(key)
        return hit

    def put(self, fp: bytes, ctx: tuple, ids, scores) -> None:
        key = (fp, *ctx)
        self._map[key] = (ids, scores)
        self._map.move_to_end(key)
        while len(self._map) > self.capacity:
            self._map.popitem(last=False)

    def clear(self) -> None:
        self._map.clear()

    def __len__(self) -> int:
        return len(self._map)


class _TenantQueue:
    __slots__ = ("queue", "weight", "vtime")

    def __init__(self, weight: float):
        self.queue: collections.deque[Request] = collections.deque()
        self.weight = weight
        self.vtime = 0.0


class Scheduler:
    """Per-tenant weighted-fair queues + admission + batch sizing.

    Fairness is start-time fair queueing over a virtual clock: each
    tenant's ``vtime`` advances by ``1/weight`` per dequeued request, and
    ``take`` always serves the lowest-vtime backlogged tenant (ties break
    by name, deterministically). A tenant going idle does not bank
    credit: on re-enqueue its vtime catches up to the global virtual
    clock, so a burst after idling competes fairly instead of starving
    everyone else. With one tenant this degenerates to the engine's old
    FIFO exactly.
    """

    def __init__(
        self,
        cfg: SchedulerConfig,
        *,
        batch_size: int,
        deadline_s: Optional[float] = None,
        max_queue: Optional[int] = None,
    ):
        self.cfg = cfg
        self.batch_size = batch_size
        # The engine's DegradePolicy may carry its own deadline / queue cap
        # (the PR 6 spelling); the scheduler honors the tighter of the two.
        self.deadline_s = deadline_s
        caps = [c for c in (cfg.max_queue, max_queue) if c is not None]
        self.max_queue = min(caps) if caps else None
        self.ladder = (
            batch_ladder(batch_size, cfg.min_batch)
            if cfg.dynamic_batch
            else (batch_size,)
        )
        self.cache = (
            ResultCache(cfg.cache_size) if cfg.cache_size > 0 else None
        )
        self._tenants: dict[str, _TenantQueue] = {}
        self._n_queued = 0
        self._vclock = 0.0  # global virtual time = max served vtime
        # Observed per-query service seconds (EMA at full batch); None
        # until the engine reports the first completed batch.
        self._per_query_s: Optional[float] = None

    # -- admission ---------------------------------------------------------

    def fingerprint(self, query: np.ndarray) -> Optional[bytes]:
        if self.cache is None:
            return None
        return ResultCache.fingerprint(query)

    def admit(self, req: Request) -> Optional[str]:
        """Admit (enqueue) or refuse ``req``; returns the shed reason
        (``"queue_full"`` / ``"deadline"``) or None on admission."""
        if self.max_queue is not None and self._n_queued >= self.max_queue:
            return "queue_full"
        slo = self.cfg.slo_s if self.cfg.slo_s is not None else self.deadline_s
        if (
            self.cfg.deadline_admission
            and slo is not None
            and self._per_query_s is not None
            and self._n_queued * self._per_query_s > slo
        ):
            # Predicted queueing delay alone already blows the SLO: refuse
            # now (cheap, honest) instead of serving a guaranteed miss.
            return "deadline"
        t = self._tenants.get(req.tenant)
        if t is None:
            t = self._tenants[req.tenant] = _TenantQueue(
                float(self.cfg.tenant_weights.get(req.tenant, 1.0))
            )
        if not t.queue:
            # No banked credit for idle tenants: catch up to the clock.
            t.vtime = max(t.vtime, self._vclock)
        t.queue.append(req)
        self._n_queued += 1
        return None

    # -- dequeue -----------------------------------------------------------

    def take(self, n: int) -> list[Request]:
        """Pop up to ``n`` requests, weighted-fair across tenants."""
        out: list[Request] = []
        while len(out) < n and self._n_queued:
            t = min(
                (t for t in self._tenants.items() if t[1].queue),
                key=lambda kv: (kv[1].vtime, kv[0]),
            )[1]
            out.append(t.queue.popleft())
            t.vtime += 1.0 / t.weight
            self._vclock = max(self._vclock, t.vtime)
            self._n_queued -= 1
        return out

    def __len__(self) -> int:
        return self._n_queued

    def oldest_submit(self) -> Optional[float]:
        """Submit time of the oldest queued request (across tenants)."""
        heads = [t.queue[0].t_submit for t in self._tenants.values() if t.queue]
        return min(heads) if heads else None

    # -- sizing & load -----------------------------------------------------

    def observe_service(self, batch_size: int, seconds: float) -> None:
        """Engine feedback: one batch of ``batch_size`` took ``seconds``."""
        per_q = seconds / max(batch_size, 1)
        if self._per_query_s is None:
            self._per_query_s = per_q
        else:
            self._per_query_s += _SERVICE_EMA_ALPHA * (
                per_q - self._per_query_s
            )

    def pick_batch_size(self, now: Optional[float] = None) -> int:
        """Batch size for the next dispatch: smallest ladder rung covering
        the queue depth, shrunk while the predicted batch time exceeds the
        oldest request's SLO headroom (serving a small batch *now* beats
        waiting to fill — continuous batching's core trade)."""
        if not self.cfg.dynamic_batch:
            return self.batch_size
        depth = max(self._n_queued, 1)
        bs = next((b for b in self.ladder if b >= depth), self.ladder[-1])
        slo = self.cfg.slo_s
        if slo is not None and self._per_query_s is not None:
            oldest = self.oldest_submit()
            if oldest is not None:
                if now is None:
                    now = time.perf_counter()
                headroom = slo - (now - oldest)
                i = self.ladder.index(bs)
                while i > 0 and self.ladder[i] * self._per_query_s > headroom:
                    i -= 1
                bs = self.ladder[i]
        return bs

    def load_signal(self, now: Optional[float] = None) -> float:
        """Queue pressure in [0, 1] — the control-plane input to
        ``tuning.pareto.select_operating_point``. Max of (a) depth against
        ``depth_reference`` and (b) oldest-request age against the SLO."""
        ref = self.cfg.depth_reference or 4 * self.batch_size
        sig = self._n_queued / max(ref, 1)
        slo = self.cfg.slo_s if self.cfg.slo_s is not None else self.deadline_s
        if slo is not None:
            oldest = self.oldest_submit()
            if oldest is not None:
                if now is None:
                    now = time.perf_counter()
                sig = max(sig, (now - oldest) / slo)
        return min(sig, 1.0)

"""Replica set + health model for the multi-replica serving fabric.

One :class:`Replica` wraps one :class:`~.engine.RetrievalEngine` (its own
params and generation counter); a :class:`ReplicaSet` tracks per-replica
health and picks dispatch targets for the :class:`~.router.QueryRouter`
(DESIGN.md §Replica fabric).

Health is a four-state machine driven by two signal families — heartbeat
probes and per-batch dispatch outcomes (an EWMA of latency plus
consecutive-failure streaks):

    healthy -> suspect      first dispatch failure / missed heartbeat
    suspect -> dead         ``dead_after`` consecutive failures
    dead -> recovering      reprobe after a seeded-jitter exponential
                            backoff window
    recovering -> healthy   ``recover_successes`` consecutive successes
    recovering -> dead      failed reprobe; backoff doubles (capped)

Suspect replicas still serve (deprioritized by routing); dead replicas
take no traffic. A *killed* replica (the ``replica_kill`` fault, or an
operator action) is dead and never reprobed. A replica that misses a
rolling update while dead is marked *stale* and stays out of routing even
if it later recovers — serving it again would violate the zero
wrong-generation guarantee.

All transitions run under the set's lock: the router records outcomes
from dispatch worker threads (hedge losers complete asynchronously).
Backoff jitter is drawn from a per-replica seeded RNG, so a chaos replay
schedules the same reprobe windows regardless of thread interleaving.
"""
from __future__ import annotations

import collections
import dataclasses
import random
import threading
import time
from typing import Iterable, Optional, Sequence

from .. import faults

# Health states.
HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"
RECOVERING = "recovering"

_STATE_RANK = {HEALTHY: 0, RECOVERING: 1, SUSPECT: 2, DEAD: 3}

# EWMA smoothing for per-batch dispatch latency.
_LATENCY_EWMA_ALPHA = 0.3


def clone_params(params):
    """Independent per-replica copy of served params.

    Device-tier leaves are immutable jax arrays — sharing them across
    replica engines is safe and free. A host-tier :class:`EmbStore`
    mutates IN PLACE on ``apply_updates``, so each replica needs its own
    copy of the store or one replica's update would bleed into another's
    serving generation.
    """
    import dataclasses as _dc

    from ..core.bank import EmbStore

    bank = getattr(params, "bank", None)
    store = getattr(bank, "store", None)
    if store is None or store.rescore is None:
        return params
    new_store = EmbStore(
        store.tier,
        rescore=store.rescore.copy(),
        gids=None if store.gids is None else store.gids.copy(),
    )
    return _dc.replace(params, bank=_dc.replace(bank, store=new_store))


class ReplicaDead(RuntimeError):
    """Dispatch hit a dead/killed replica; the router fails the batch over."""

    def __init__(self, name: str, message: str = ""):
        super().__init__(message or f"replica {name!r} is dead")
        self.replica = name


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Thresholds and backoff knobs for the replica health machine.

    ``dead_after`` counts *consecutive* failures (dispatch errors or
    heartbeat misses); a single success resets the streak. Reprobe backoff
    is ``reprobe_backoff_s * mult**k`` (capped) scaled by a deterministic
    jitter in [1, 2) from a per-replica seeded RNG. ``heartbeat_interval_s``
    paces liveness probes of serving replicas (0 disables them; dead
    replicas are always reprobed on their backoff schedule).
    """

    ewma_alpha: float = _LATENCY_EWMA_ALPHA
    dead_after: int = 3
    recover_successes: int = 2
    reprobe_backoff_s: float = 0.05
    reprobe_backoff_mult: float = 2.0
    reprobe_backoff_max_s: float = 5.0
    heartbeat_interval_s: float = 0.0
    seed: int = 0


class Replica:
    """One serving replica: an engine plus its health bookkeeping."""

    def __init__(self, name: str, engine):
        self.name = name
        self.engine = engine
        self.state = HEALTHY
        self.killed = False
        self.stale = False  # missed a rolling update while dead
        self.updating = False  # masked out while apply_updates runs
        self.outstanding = 0  # dispatched batches not yet completed
        self.lock = threading.Lock()  # serializes engine execution
        self.lat_ewma: Optional[float] = None
        self.err_streak = 0
        self.ok_streak = 0
        self.reprobe_at: Optional[float] = None
        self.backoff_s: Optional[float] = None
        self.last_used = 0  # router dispatch sequence (LRU round-robin)
        self.last_heartbeat = 0.0
        self.n_dispatches = 0
        self.n_failures = 0

    @property
    def generation(self) -> int:
        return self.engine.generation

    def serveable(self) -> bool:
        """Eligible for routing (dead/killed/stale/updating are masked)."""
        return (
            not self.killed
            and not self.stale
            and not self.updating
            and self.state != DEAD
        )

    def health(self) -> dict:
        """Snapshot for stats reporting."""
        return {
            "state": self.state,
            "killed": self.killed,
            "stale": self.stale,
            "generation": self.generation,
            "lat_ewma_s": self.lat_ewma,
            "n_dispatches": self.n_dispatches,
            "n_failures": self.n_failures,
        }


class ReplicaSet:
    """Health-tracked replica collection with deterministic reprobe backoff.

    ``engines`` may be engines (auto-named ``r0..rN``) or ``(name, engine)``
    pairs. ``fault_plan`` (shared with the router and usually with every
    engine) drives the ``replica_heartbeat`` site.
    """

    def __init__(
        self,
        engines: Iterable,
        *,
        policy: HealthPolicy | None = None,
        fault_plan=None,
        lock: threading.RLock | None = None,
    ):
        self.policy = policy if policy is not None else HealthPolicy()
        self.fault_plan = fault_plan
        self.lock = lock if lock is not None else threading.RLock()
        self.replicas: list[Replica] = []
        for i, item in enumerate(engines):
            if isinstance(item, Replica):
                self.replicas.append(item)
            elif isinstance(item, tuple):
                self.replicas.append(Replica(item[0], item[1]))
            else:
                self.replicas.append(Replica(f"r{i}", item))
        if not self.replicas:
            raise ValueError("ReplicaSet needs at least one replica")
        names = [r.name for r in self.replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        self._rngs = {
            r.name: random.Random(f"{self.policy.seed}:{r.name}")
            for r in self.replicas
        }
        self.n_heartbeats = 0
        self.n_heartbeat_misses = 0
        self.transitions: collections.deque = collections.deque(maxlen=256)

    def __iter__(self):
        return iter(self.replicas)

    def __len__(self) -> int:
        return len(self.replicas)

    def get(self, name: str) -> Replica:
        for r in self.replicas:
            if r.name == name:
                return r
        raise KeyError(f"no replica named {name!r}")

    # -- routing -----------------------------------------------------------

    def pick(
        self,
        *,
        exclude: Sequence[str] = (),
        generation: Optional[int] = None,
        idle_only: bool = False,
    ) -> Optional[Replica]:
        """Best dispatch target, or None when no serveable replica matches.

        Preference order: fewest in-flight batches, then health rank
        (healthy < recovering < suspect), then least-recently-used — which
        degenerates to round-robin across idle healthy replicas.
        ``generation`` restricts to replicas serving that index generation
        (the hedging constraint: a hedge must be bit-safe to swap in).
        ``idle_only`` additionally requires zero in-flight batches — the
        router's hedging constraint: a hedge onto a busy replica queues
        behind its in-flight work (execution is serialized per replica)
        and loses the race by construction, so it is better not sent.
        """
        with self.lock:
            eligible = [
                r
                for r in self.replicas
                if r.serveable()
                and r.name not in exclude
                and (generation is None or r.generation == generation)
                and (not idle_only or r.outstanding == 0)
            ]
            if not eligible:
                return None
            return min(
                eligible,
                key=lambda r: (
                    r.outstanding,
                    _STATE_RANK[r.state],
                    r.last_used,
                ),
            )

    def n_serveable(self) -> int:
        with self.lock:
            return sum(r.serveable() for r in self.replicas)

    # -- outcome recording -------------------------------------------------

    def _transition(self, r: Replica, state: str) -> None:
        if r.state != state:
            self.transitions.append((r.name, r.state, state))
            r.state = state

    def record_success(self, r: Replica, latency_s: Optional[float]) -> None:
        """One successful dispatch (or heartbeat) outcome."""
        with self.lock:
            r.n_dispatches += latency_s is not None
            if latency_s is not None:
                if r.lat_ewma is None:
                    r.lat_ewma = latency_s
                else:
                    r.lat_ewma += self.policy.ewma_alpha * (
                        latency_s - r.lat_ewma
                    )
            r.err_streak = 0
            r.ok_streak += 1
            if r.state == SUSPECT:
                self._transition(r, HEALTHY)
            elif (
                r.state == RECOVERING
                and r.ok_streak >= self.policy.recover_successes
            ):
                self._transition(r, HEALTHY)
                r.backoff_s = None  # healthy again: backoff resets

    def record_failure(self, r: Replica, now: Optional[float] = None) -> None:
        """One failed dispatch/heartbeat; advances the state machine."""
        if now is None:
            now = time.perf_counter()
        with self.lock:
            r.n_failures += 1
            r.ok_streak = 0
            r.err_streak += 1
            if r.killed:
                self._transition(r, DEAD)
                r.reprobe_at = None  # killed replicas are never reprobed
                return
            if r.state == RECOVERING or r.err_streak >= self.policy.dead_after:
                # A failed reprobe goes straight back to dead with a doubled
                # window; a serving replica dies after dead_after strikes.
                self._transition(r, DEAD)
                base = self.policy.reprobe_backoff_s
                r.backoff_s = min(
                    base
                    if r.backoff_s is None
                    else r.backoff_s * self.policy.reprobe_backoff_mult,
                    self.policy.reprobe_backoff_max_s,
                )
                jitter = 1.0 + self._rngs[r.name].random()
                r.reprobe_at = now + r.backoff_s * jitter
            elif r.state == HEALTHY:
                self._transition(r, SUSPECT)

    def kill(self, name: str) -> Replica:
        """Hard-kill: dead immediately, never reprobed, in-flight batches
        fail over (the dispatch worker re-checks ``killed`` on completion)."""
        r = self.get(name)
        with self.lock:
            r.killed = True
            self._transition(r, DEAD)
            r.reprobe_at = None
        return r

    # -- heartbeats --------------------------------------------------------

    def heartbeat(self, r: Replica) -> bool:
        """Probe one replica; returns liveness. Fires ``replica_heartbeat``
        (generic ``error`` = missed heartbeat; ``miss`` targets one replica
        via payload)."""
        self.n_heartbeats += 1
        ok = True
        if self.fault_plan is not None:
            try:
                spec = self.fault_plan.fire(faults.REPLICA_HEARTBEAT)
            except faults.InjectedFault:
                ok = False
            else:
                if (
                    spec is not None
                    and spec.mode == "miss"
                    and faults.spec_targets(spec, r.name)
                ):
                    ok = False
        if r.killed:
            ok = False
        if not ok:
            self.n_heartbeat_misses += 1
        return ok

    def tick(self, now: Optional[float] = None) -> None:
        """Advance time-driven health work: reprobe dead replicas whose
        backoff window has passed, and (if configured) heartbeat serving
        replicas on the ``heartbeat_interval_s`` cadence."""
        if now is None:
            now = time.perf_counter()
        for r in self.replicas:
            if r.killed or r.updating:
                continue
            if r.state == DEAD:
                if r.reprobe_at is not None and now >= r.reprobe_at:
                    with self.lock:
                        self._transition(r, RECOVERING)
                        r.ok_streak = 0
                    if self.heartbeat(r):
                        self.record_success(r, None)
                    else:
                        self.record_failure(r, now)
            elif (
                self.policy.heartbeat_interval_s > 0
                and now - r.last_heartbeat >= self.policy.heartbeat_interval_s
            ):
                r.last_heartbeat = now
                if self.heartbeat(r):
                    self.record_success(r, None)
                else:
                    self.record_failure(r, now)

    def health_snapshot(self) -> dict:
        with self.lock:
            return {r.name: r.health() for r in self.replicas}

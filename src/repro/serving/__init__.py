from .engine import RetrievalEngine, make_backend

__all__ = ["RetrievalEngine", "make_backend"]

from .engine import (
    EVICTED,
    DegradePolicy,
    QueryResult,
    RetrievalEngine,
    Shed,
    make_backend,
)
from .scheduler import (
    DEFAULT_TENANT,
    Request,
    ResultCache,
    Scheduler,
    SchedulerConfig,
    batch_ladder,
)

__all__ = [
    "EVICTED",
    "DEFAULT_TENANT",
    "DegradePolicy",
    "QueryResult",
    "Request",
    "ResultCache",
    "RetrievalEngine",
    "Scheduler",
    "SchedulerConfig",
    "Shed",
    "batch_ladder",
    "make_backend",
]

from .engine import (
    EVICTED,
    DegradePolicy,
    QueryResult,
    RetrievalEngine,
    Shed,
    make_backend,
)

__all__ = [
    "EVICTED",
    "DegradePolicy",
    "QueryResult",
    "RetrievalEngine",
    "Shed",
    "make_backend",
]

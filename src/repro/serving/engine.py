"""Batched retrieval serving engine.

Wraps an index backend (LIDER or any baseline) behind one API:
``submit`` queues requests, ``drain`` executes them in batches — the
latency-vs-throughput batching knob real serving stacks tune. AQT
(average query time, the paper's efficiency metric) is measured here.

Execution is split from scheduling (DESIGN.md §Serving front end): a
:class:`~.scheduler.Scheduler` decides admission, per-tenant fairness,
result-cache hits, and the batch size of each dispatch; the engine owns
the execution core (:meth:`RetrievalEngine._execute_batch`, tier-
dispatched), the double-buffered host-tier pipeline, the degradation
ladder, and transactional updates. The default ``SchedulerConfig``
reproduces the legacy fixed-batch FIFO engine byte-for-byte.

Backends share the signature ``search(queries (B, d), k) -> TopK``; an
*updatable* LIDER backend takes ``search(params, queries, k)`` and the engine
owns the served params so ``apply_updates`` can swap them between batches
(checkpointed serving + online upsert/delete — DESIGN.md §Index lifecycle).
"""
from __future__ import annotations

import collections
import dataclasses
import random
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import faults
from ..core import lider as lider_lib
from ..core.baselines import (
    flat_search,
    ivfpq_search,
    mplsh_search,
    pq_search,
    sklsh_search,
)
from ..core.core_model import TopK
from .scheduler import DEFAULT_TENANT, Request, Scheduler, SchedulerConfig


@dataclasses.dataclass
class EngineStats:
    n_queries: int = 0
    n_batches: int = 0
    total_time_s: float = 0.0
    n_padded: int = 0  # pad slots executed for partial batches
    # Adaptive probe pruning (DESIGN.md §Adaptive speed-quality control
    # plane): probes routed by layer 1 but masked by the margin rule. The
    # per-batch trace is a bounded deque (newest batches) — a long-running
    # server must not grow per-batch state without bound; the lifetime
    # aggregate lives in the two counters.
    n_probes_total: int = 0
    n_probes_pruned: int = 0
    batch_pruned_fraction: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=256)
    )
    n_results_evicted: int = 0  # results dropped by the bounded results map
    # Tiered serving (DESIGN.md §Tiered embedding store): host-side exact-row
    # fetch accounting. A fetch is "overlapped" when the next batch's
    # compressed first pass was already dispatched to the device before the
    # fetch ran — the double-buffered pipeline's payoff condition.
    host_fetch_us: float = 0.0
    n_host_fetches: int = 0
    n_overlapped_fetches: int = 0
    # Fault tolerance (DESIGN.md §Failure model): update transactions,
    # host-fetch retry/degrade, admission control, deadline accounting.
    n_update_rollbacks: int = 0  # failed apply_updates rolled back
    n_fetch_retries: int = 0  # host fetches retried after a failure
    n_fetch_failures: int = 0  # batches whose fetch exhausted all retries
    n_degraded: int = 0  # queries answered compressed-only (degraded=True)
    n_shed: int = 0  # requests rejected by admission control
    n_deadline_misses: int = 0  # answered, but past the per-request deadline
    n_rung_steps: int = 0  # degradation-ladder step-downs
    # Front-end scheduler counters (DESIGN.md §Serving front end). Cache
    # hits count in n_queries (they are answered traffic) but add zero
    # device time. Like batch_pruned_fraction above, the per-batch /
    # per-request traces are bounded deques: lifetime aggregates live in
    # counters, recent windows in deques — nothing grows with uptime.
    n_cache_hits: int = 0
    n_cache_misses: int = 0  # admitted-to-queue (executed on device)
    batch_size_trace: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=256)
    )
    recent_latency_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=1024)
    )
    # Cluster-major schedule accounting (DESIGN.md §Cluster-major schedule):
    # scheduled (query, probe) pairs vs the grouped-kernel steps that served
    # them. pairs/steps is the measured DMA-sharing ratio — the signal the
    # online block_q autotuner feeds on. Aggregates in counters, recent
    # per-batch ratios in a bounded deque, same policy as above.
    n_sched_pairs: int = 0
    n_sched_steps: int = 0
    sharing_trace: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=256)
    )

    @property
    def aqt(self) -> float:
        return self.total_time_s / max(self.n_queries, 1)

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of answered (non-shed) requests served from the cache."""
        return self.n_cache_hits / max(
            self.n_cache_hits + self.n_cache_misses, 1
        )

    def latency_quantile(self, q: float) -> float:
        """Latency quantile (e.g. 0.5 / 0.99) over the recent window."""
        if not self.recent_latency_s:
            return 0.0
        return float(np.quantile(np.asarray(self.recent_latency_s), q))

    @property
    def overlap_fraction(self) -> float:
        """Fraction of host fetches that ran under a dispatched next batch."""
        return self.n_overlapped_fetches / max(self.n_host_fetches, 1)

    @property
    def padding_fraction(self) -> float:
        """Fraction of executed batch slots that were padding (wasted work)."""
        return self.n_padded / max(self.n_queries + self.n_padded, 1)

    @property
    def pruned_probe_fraction(self) -> float:
        """Fraction of routed probes the margin rule pruned (all batches)."""
        return self.n_probes_pruned / max(self.n_probes_total, 1)

    @property
    def sharing_ratio(self) -> float:
        """Measured cluster-tile DMA sharing across all cluster-major
        batches: scheduled pairs per grouped-kernel step (>= 1; 1.0 means
        no two queries in a batch ever probed the same cluster)."""
        return self.n_sched_pairs / max(self.n_sched_steps, 1)


class QueryResult:
    """One answered request. Unpacks like the legacy ``(ids, scores)`` pair
    (``ids, scores = engine.result(rid)`` / ``engine.result(rid)[0]``) and
    additionally carries the fault-tolerance metadata: ``degraded`` is True
    when the answer came from the compressed-only fallback (no exact
    rescore), ``rung`` is the degradation-ladder rung it was served at
    (0 = nominal), ``latency_s`` is submit-to-answer wall time, ``cached``
    marks answers served from the scheduler's result cache (bit-identical
    to a fresh search at the same generation and rung). ``generation`` is
    the engine generation the answer was computed at — the replica
    router's wrong-generation guard (DESIGN.md §Replica fabric) — and
    ``replica`` names the serving replica when a router dispatched it."""

    __slots__ = (
        "ids", "scores", "degraded", "rung", "latency_s", "cached",
        "generation", "replica",
    )

    def __init__(
        self, ids, scores, *, degraded=False, rung=0, latency_s=0.0,
        cached=False, generation=None, replica=None,
    ):
        self.ids = ids
        self.scores = scores
        self.degraded = degraded
        self.rung = rung
        self.latency_s = latency_s
        self.cached = cached
        self.generation = generation
        self.replica = replica

    def __iter__(self):
        return iter((self.ids, self.scores))

    def __getitem__(self, i):
        return (self.ids, self.scores)[i]

    def __len__(self):
        return 2

    def __repr__(self):
        tag = f", degraded rung={self.rung}" if self.degraded else ""
        return f"QueryResult(k={len(np.asarray(self.ids))}{tag})"


@dataclasses.dataclass(frozen=True)
class Shed:
    """Structured rejection: queue-cap admission control refused the
    request instead of growing the queue without bound. Returned by
    ``result(rid)`` for shed rids."""

    rid: int
    reason: str = "queue_full"


class _EvictedType:
    """Singleton sentinel: the answer existed but was evicted by the
    bounded results map. Falsy, so ``if engine.result(rid):`` treats it
    like a missing answer, while ``is EVICTED`` distinguishes it from a
    never-submitted/already-collected rid (``None``)."""

    def __repr__(self):
        return "EVICTED"

    def __bool__(self):
        return False


EVICTED = _EvictedType()


@dataclasses.dataclass
class _PendingBatch:
    """One stage1-dispatched batch in the host-tier pipeline. ``rung``/
    ``bs`` are captured at dispatch (the live rung may step before the
    batch finishes); ``retry_at`` is the earliest wall time a failed fetch
    may be retried (None = ready now); ``overlap_armed`` is set when a
    later batch's stage 1 was dispatched under this batch's fetch."""

    chunk: list
    bs: int
    q: jnp.ndarray
    prov: object
    pruned: object
    rung: int
    attempts: int = 0
    retry_at: Optional[float] = None
    overlap_armed: bool = False
    blocked: bool = False


# Operating-point knobs a degradation-ladder rung may override (the PR-3
# control-plane axes; anything else in a rung dict — e.g. the modeled
# ``expected_recall`` floor — is bench/report metadata the engine ignores).
_POINT_KEYS = frozenset(
    {
        "n_probe", "r0", "prune_margin", "refine", "rescore_factor",
        "block_c", "block_q", "sketch_factor",
    }
)


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """Fault-tolerance policy for :class:`RetrievalEngine`.

    ``ladder`` is a sequence of operating-point override dicts (cheapest
    last), typically from ``tuning.pareto.degradation_ladder``; under
    deadline pressure or repeated host-fetch failure the engine steps down
    one rung at a time, and past the last rung (or when a batch's fetch
    exhausts its retries) answers compressed-only with ``degraded=True``.
    ``deadline_s`` is the per-request answer deadline driving both the
    rung controller (queue age thresholds as fractions of the deadline)
    and ``n_deadline_misses``. ``max_queue`` enables admission control
    (:class:`Shed`). Backoff jitter is seeded — replays deterministically.
    """

    ladder: tuple = ()
    deadline_s: Optional[float] = None
    degrade_age_fraction: float = 0.5
    recover_age_fraction: float = 0.25
    fetch_retries: int = 2
    fetch_backoff_s: float = 0.002
    fetch_backoff_mult: float = 2.0
    max_queue: Optional[int] = None
    seed: int = 0


# Searchable knobs each backend accepts; anything else in **kw is a typo and
# raises instead of being silently ignored. All probing backends take the
# same ``n_probe`` spelling (mplsh's search fn calls it n_probes internally).
_BACKEND_KWARGS: dict[str, frozenset[str]] = {
    "lider": frozenset({
        "n_probe", "r0", "refine", "use_fused", "prune_margin",
        "rescore_factor", "block_c", "block_q", "sketch_factor",
    }),
    "flat": frozenset(),
    "pq": frozenset(),
    "ivfpq": frozenset({"n_probe"}),
    "sklsh": frozenset(),
    "mplsh": frozenset({"n_probe"}),
}


def make_backend(
    kind: str,
    index,
    embs: jnp.ndarray | None = None,
    *,
    updatable: bool = False,
    **kw,
) -> Callable:
    """Uniform search closure over any index type.

    ``updatable=True`` (LIDER only) returns ``search(params, q, k)`` instead
    of closing over the index — pass the params to ``RetrievalEngine`` so
    ``apply_updates`` can swap them between batches.
    """
    if kind not in _BACKEND_KWARGS:
        raise ValueError(
            f"unknown backend {kind!r}; expected one of "
            f"{sorted(_BACKEND_KWARGS)}"
        )
    unknown = set(kw) - _BACKEND_KWARGS[kind]
    if unknown:
        allowed = sorted(_BACKEND_KWARGS[kind]) or "none"
        raise TypeError(
            f"backend {kind!r} got unexpected kwargs {sorted(unknown)}; "
            f"allowed: {allowed}"
        )
    if updatable and kind != "lider":
        raise ValueError(f"updatable backends require kind='lider', got {kind!r}")

    if kind == "lider":

        def _effective(point):
            # A degradation-ladder rung overrides the base operating point
            # (n_probe / prune_margin / rescore_factor / ...); the nominal
            # path (point=None) is byte-for-byte the base kwargs.
            if not point:
                return kw
            eff = dict(kw)
            eff.update(point)
            return eff

        def lider_search(params, q, k, point=None):
            # With pruning on, the search also returns the (B, P) bool mask
            # of routed-but-pruned probes; the engine folds it into
            # EngineStats (per-batch pruned-probe fraction).
            eff = _effective(point)
            margin = eff.get("prune_margin")
            return lider_lib.search_lider(
                params,
                q,
                k=k,
                n_probe=eff.get("n_probe", 20),
                r0=eff.get("r0", 4),
                refine=eff.get("refine", False),
                use_fused=eff.get("use_fused"),
                prune_margin=margin,
                with_stats=margin is not None,
                rescore_factor=eff.get("rescore_factor", 4),
                block_c=eff.get("block_c"),
                block_q=eff.get("block_q"),
                sketch_factor=eff.get("sketch_factor"),
            )

        lider_search.accepts_point = True
        # The engine's block_q autotuner consults this: an explicit static
        # block_q in the backend kwargs overrides the auto choice.
        lider_search.static_point = kw

        if updatable:
            # Staged spelling of the same operating point, for host-tier
            # (rescore_tier="host") params: the engine pipelines stage1 of
            # batch i+1 over batch i's host fetch + rescore (DESIGN.md
            # §Tiered embedding store). search_lider composes the identical
            # stages serially, so results match the unpipelined call.
            def host_stage1(params, q, k, point=None, stats_out=None):
                eff = _effective(point)
                margin = eff.get("prune_margin")
                block_q = eff.get("block_q")
                # block_q flips stage 1 to the cluster-major spelling; the
                # (prov, pruned) contract — and therefore the fetch/rescore
                # pipeline downstream — is identical. ``stats_out`` (the
                # online block_q autotuner's hook) only applies there: it
                # returns the drained schedule's measured sharing and flips
                # the schedule to worst-case fixed-shape padding so swapping
                # block_q between drains never re-traces (see
                # host_first_pass_cluster_major).
                stage1_fn = (
                    lider_lib.host_first_pass
                    if block_q is None
                    else partial(
                        lider_lib.host_first_pass_cluster_major,
                        block_q=block_q,
                        stats_out=stats_out,
                    )
                )
                prov, pruned = stage1_fn(
                    params,
                    q,
                    k=k,
                    n_probe=eff.get("n_probe", 20),
                    r0=eff.get("r0", 4),
                    refine=eff.get("refine", False),
                    use_fused=eff.get("use_fused"),
                    prune_margin=margin,
                    rescore_factor=eff.get("rescore_factor", 4),
                    block_c=eff.get("block_c"),
                    sketch_factor=eff.get("sketch_factor"),
                )
                # Same contract as the serial path: probe stats only when
                # the margin rule is actually configured.
                return prov, (pruned if margin is not None else None)

            def host_stage2(params, fetched, prov_rows, q, k):
                return lider_lib.host_rescore(
                    params.bank.gids,
                    fetched,
                    prov_rows,
                    q,
                    k=k,
                    use_fused=kw.get("use_fused"),
                    block_c=kw.get("block_c"),
                )

            lider_search.host_stage1 = host_stage1
            lider_search.host_fetch = lider_lib.host_fetch
            lider_search.host_stage2 = host_stage2
            return lider_search

        def search(q, k, point=None):
            return lider_search(index, q, k, point=point)

        search.accepts_point = True
    elif kind == "flat":
        def search(q, k):
            return flat_search(embs, q, k=k)
    elif kind == "pq":
        def search(q, k):
            return pq_search(index, q, k=k)
    elif kind == "ivfpq":
        def search(q, k):
            return ivfpq_search(index, q, k=k, n_probe=kw.get("n_probe", 8))
    elif kind == "sklsh":
        def search(q, k):
            return sklsh_search(index, embs, q, k=k)
    else:  # mplsh
        def search(q, k):
            return mplsh_search(index, embs, q, k=k, n_probes=kw.get("n_probe", 8))
    return search


# Relative weight of one grouped-kernel step's cluster-tile DMA vs one
# query slot's MXU work in the block_q cost model below. A step always
# streams the cluster's Lp rows once (the DMA term) and scores block_q query
# slots whether or not they are filled (the slot term) — so the model is
# cost(bq) = steps(bq) · (DMA_WEIGHT + bq), with steps(bq) =
# Σ_clusters ceil(pairs_c / bq) computed exactly from observed probe counts.
DMA_WEIGHT = 4.0


def pick_block_q(counts_list, ladder) -> int:
    """Pick the cheapest ``block_q`` from ``ladder`` for the observed probe
    distribution (online autotuning, DESIGN.md §Cluster-major schedule).

    ``counts_list``: iterable of per-batch cluster pair-count arrays (how
    many (query, probe) pairs landed on each probed cluster — the engine
    keeps a bounded window of these). Steps are additive across batches, so
    the exact step count each candidate ``block_q`` *would have* taken on
    the window is ``Σ ceil(count / bq)`` — no schedule rebuild needed. A
    wide ``block_q`` shares more DMA but pads more dead query slots on
    sparse clusters; the cost model weighs both. Empty window -> first rung.
    """
    counts = [np.asarray(c, np.int64) for c in counts_list if len(c)]
    allc = np.concatenate(counts) if counts else np.zeros((0,), np.int64)
    best_bq, best_cost = ladder[0], float("inf")
    for bq in ladder:
        steps = int(np.sum(-(-allc // bq))) if allc.size else 0
        cost = steps * (DMA_WEIGHT + bq)
        if cost < best_cost:
            best_bq, best_cost = int(bq), cost
    return best_bq


class RetrievalEngine:
    """Batched serving with scheduled admission and AQT accounting.

    With ``params`` set, ``search_fn`` must take ``(params, q, k)`` and the
    engine serves whatever params it currently holds — ``apply_updates``
    swaps them atomically between batches, tracking a generation counter and
    recompiling (re-warming) only when an update grew array shapes (capacity
    growth); same-shape updates reuse the compiled search.

    ``scheduler`` (a :class:`SchedulerConfig`) configures the front end:
    per-tenant weighted-fair queues, the result cache, dynamic batch
    sizing, and SLO-driven admission. The default config is the legacy
    fixed-batch FIFO behavior exactly.
    """

    def __init__(
        self,
        search_fn: Callable,
        *,
        batch_size: int,
        k: int,
        dim: int,
        params=None,
        max_results: int = 65536,
        policy: DegradePolicy | None = None,
        fault_plan=None,
        scheduler: SchedulerConfig | None = None,
        block_q_ladder: tuple | None = None,
    ):
        self.search_fn = search_fn
        self.batch_size = batch_size
        self.k = k
        self.dim = dim
        self.params = params
        # Fault tolerance (DESIGN.md §Failure model): ``policy`` drives
        # retry/degrade/shed behavior; ``fault_plan`` (a faults.FaultPlan)
        # is activated around drain/apply_updates for chaos testing.
        self.policy = policy if policy is not None else DegradePolicy()
        self.fault_plan = fault_plan
        self.rung = 0  # current degradation-ladder rung (0 = nominal)
        self._rng = random.Random(self.policy.seed)  # backoff jitter
        self.generation = 0  # bumped on every apply_updates
        # The tier split (DESIGN.md §Tiered embedding store): device-tier
        # state (pytree leaves) and host-tier state (the EmbStore content)
        # change independently, and only device *shape* changes ever force a
        # recompile — a host-content-only update must not re-trace anything.
        self.device_generation = 0  # pytree leaves changed
        self.host_generation = 0  # host EmbStore content changed
        self.recompiles = 0  # bumped only when shapes changed
        self.sched_cfg = scheduler if scheduler is not None else SchedulerConfig()
        self.scheduler = Scheduler(
            self.sched_cfg,
            batch_size=batch_size,
            deadline_s=self.policy.deadline_s,
            max_queue=self.policy.max_queue,
        )
        # How many stage1-dispatched batches the host-tier pipeline keeps in
        # flight (2 = the PR 5 double buffer).
        self._pipeline_depth = 2
        # Online block_q autotuning (DESIGN.md §Cluster-major schedule):
        # with a ladder set (staged host-tier serving only), each dispatch
        # runs the cluster-major first pass at the current auto choice with
        # worst-case fixed-shape schedule padding, the drained schedule's
        # measured probe distribution lands in ``_probe_counts``, and
        # ``pick_block_q`` re-picks for the next dispatch. A static
        # ``block_q`` in the backend kwargs or a ladder rung overrides the
        # auto choice (the merge order in ``_effective_point``). Every
        # (batch size, rung, ladder block_q) trace is pre-compiled by
        # ``warmup`` so re-picks never re-trace on the query path.
        self.block_q_ladder = (
            tuple(int(b) for b in block_q_ladder)
            if block_q_ladder is not None
            else None
        )
        if self.block_q_ladder is not None and not self.block_q_ladder:
            raise ValueError("block_q_ladder must be non-empty or None")
        self._auto_block_q = (
            self.block_q_ladder[0] if self.block_q_ladder else None
        )
        self._probe_counts: collections.deque = collections.deque(maxlen=32)
        # Bounded FIFO of answered (ids, scores) pairs. ``result()`` pops by
        # default, so a well-behaved client keeps this near-empty; the bound
        # is the backstop for clients that never collect (a long-running
        # server must not leak every answer it has ever produced).
        if max_results < batch_size:
            raise ValueError(
                f"max_results={max_results} must hold at least one batch "
                f"({batch_size})"
            )
        self.max_results = max_results
        self.results: collections.OrderedDict[int, object] = (
            collections.OrderedDict()
        )
        # Rids whose answers were computed but evicted by the bound above —
        # itself bounded, oldest-first, so the eviction metadata cannot
        # become the leak the bound prevents.
        self._evicted: collections.OrderedDict[int, None] = (
            collections.OrderedDict()
        )
        self.stats = EngineStats()
        self._next_id = 0
        # Preallocated padded batch buffer: drain fills it in place instead
        # of allocating (batch, dim) floats per batch.
        self._batch_buf = np.zeros((batch_size, dim), np.float32)

    @property
    def _accepts_point(self) -> bool:
        return getattr(self.search_fn, "accepts_point", False)

    def _rung_point(self) -> dict | None:
        """Operating-point override for the current ladder rung (None at
        rung 0 — the nominal path takes zero extra kwargs)."""
        ladder = self.policy.ladder
        if self.rung <= 0 or not ladder or not self._accepts_point:
            return None
        raw = ladder[min(self.rung, len(ladder)) - 1]
        return {k: v for k, v in raw.items() if k in _POINT_KEYS}

    def _effective_point(self) -> dict | None:
        """Rung point merged with the autotuner's current block_q choice.

        Precedence (most specific wins): ladder rung > static backend
        ``block_q`` kwarg > autotuned choice — the static flag stays a
        hard override, and a rung that pins block_q pins it."""
        point = self._rung_point()
        auto = self._auto_block_q
        if auto is None:
            return point
        static = getattr(self.search_fn, "static_point", None) or {}
        if static.get("block_q") is not None:
            return point
        merged = {"block_q": auto}
        if point:
            merged.update(point)
        return merged

    def _search(self, q: jnp.ndarray):
        point = self._rung_point()
        if self.params is not None:
            if point is not None:
                return self.search_fn(self.params, q, self.k, point=point)
            return self.search_fn(self.params, q, self.k)
        if point is not None:
            return self.search_fn(q, self.k, point=point)
        return self.search_fn(q, self.k)

    @staticmethod
    def _split_out(out) -> tuple[TopK, jnp.ndarray | None]:
        """Backends return TopK or (TopK, pruned-probe mask)."""
        if isinstance(out, tuple) and not isinstance(out, TopK):
            return out[0], out[1]
        return out, None

    def warmup(self, *, warm_ladder: bool = True):
        """Pre-compile every reachable query-path trace: each batch size on
        the scheduler's pow2 ladder, at the nominal point and (with
        ``warm_ladder``) every degradation-ladder rung. After this, neither
        a rung step nor a dynamic batch-size choice ever re-traces on the
        query path — both ladders are bounded, so this is a bounded number
        of compiles, eaten once off the serving path."""
        saved = self.rung
        staged = self._staged_host_serving()
        try:
            for bs in self.scheduler.ladder:
                q = jnp.zeros((bs, self.dim), jnp.float32)
                rungs = [0]
                if warm_ladder and self.policy.ladder and self._accepts_point:
                    rungs += list(range(1, len(self.policy.ladder) + 1))
                for r in rungs:
                    self.rung = r
                    out, _ = self._split_out(self._search(q))
                    jax.block_until_ready(out.ids)
                    if staged:
                        # The pipelined drain runs the STAGED spelling
                        # (host_first_pass -> fetch -> host_rescore), whose
                        # stage jits are separate traces from the serial
                        # search warmed above. Warm them here too — outside
                        # any faults.activate window, so chaos-plan call
                        # counters are untouched — or the first live
                        # dispatch pays the trace on the query path. With
                        # the block_q autotuner on, warm EVERY ladder
                        # choice (each is one fixed-shape trace per batch
                        # size thanks to the worst-case schedule padding)
                        # so online re-picks never re-trace.
                        saved_auto = self._auto_block_q
                        bqs = (
                            list(self.block_q_ladder)
                            if saved_auto is not None
                            else [None]
                        )
                        for bq in bqs:
                            self._auto_block_q = bq
                            extra = (
                                {"stats_out": {}} if bq is not None else {}
                            )
                            prov, _ = self.search_fn.host_stage1(
                                self.params, q, self.k,
                                point=self._effective_point(), **extra,
                            )
                            fetched = self.search_fn.host_fetch(
                                self.params, prov.ids
                            )
                            out2 = self.search_fn.host_stage2(
                                self.params, jnp.asarray(fetched), prov.ids,
                                q, self.k,
                            )
                            jax.block_until_ready(out2.ids)
                        self._auto_block_q = saved_auto
        finally:
            self.rung = saved

    @property
    def pending_requests(self) -> int:
        """Queued (admitted, not yet executed) request count."""
        return len(self.scheduler)

    def submit(self, query: np.ndarray, *, tenant: str = DEFAULT_TENANT) -> int:
        rid = self._next_id
        self._next_id += 1
        vec = np.asarray(query, np.float32)
        req = Request(
            rid=rid,
            query=vec,
            t_submit=time.perf_counter(),
            tenant=tenant,
            fp=self.scheduler.fingerprint(vec),
        )
        reason = self.scheduler.admit(req)
        if reason is not None:
            # Admission control: refuse now with a structured answer rather
            # than queueing work we cannot serve within the deadline.
            self.stats.n_shed += 1
            self._put_result(rid, Shed(rid=rid, reason=reason))
        return rid

    def apply_updates(self, update_fn: Callable) -> bool:
        """Transactionally swap served params to ``update_fn(params)``
        between batches.

        ``update_fn`` returns either new params or ``(new_params, stats)``
        (the ``core.update`` convention). Device-tier state is functional
        (new leaves), but lifecycle ops mutate the host ``EmbStore`` IN
        PLACE — so the store is wrapped in a transaction: if ``update_fn``
        raises, every in-place host write is rolled back (bit-identical
        table, gids, and ``version``) and the engine keeps serving the old
        generation; the exception then propagates to the updater. Commit
        happens atomically with the params swap, between batches. Returns
        True when leaf shapes changed (capacity growth) — the one case the
        compiled search must re-trace; the engine eats that recompile here,
        off the query path.
        """
        if self.params is None:
            raise ValueError(
                "engine was not built with params (make_backend(..., "
                "updatable=True) + RetrievalEngine(..., params=...))"
            )
        old_leaves = jax.tree_util.tree_leaves(self.params)
        old_store = self._host_store(self.params)
        # Capture the version BEFORE the update runs: lifecycle ops mutate
        # the store in place, so the object identity alone can't tell us
        # whether its content changed.
        old_hver = None if old_store is None else old_store.version
        # Transaction covers the concrete host table only: device leaves
        # are functional and growth is copy-on-grow (a failed grown update
        # is rolled back simply by not swapping params).
        txn_store = (
            old_store
            if old_store is not None
            and old_store.tier == "host"
            and old_store.rescore is not None
            else None
        )
        if txn_store is not None:
            txn_store.begin_txn()
        try:
            with faults.activate(self.fault_plan):
                out = update_fn(self.params)
        except Exception:
            if txn_store is not None:
                txn_store.rollback()
            self.stats.n_update_rollbacks += 1
            raise
        if txn_store is not None:
            txn_store.commit()
        new_params = out[0] if isinstance(out, tuple) else out
        new_leaves = jax.tree_util.tree_leaves(new_params)
        grew = [jnp.shape(l) for l in old_leaves] != [
            jnp.shape(l) for l in new_leaves
        ]
        device_changed = grew or any(
            a is not b for a, b in zip(old_leaves, new_leaves)
        )
        new_store = self._host_store(new_params)
        host_changed = (new_store is not old_store) or (
            new_store is not None and new_store.version != old_hver
        )
        self.params = new_params
        self.generation += 1
        if device_changed:
            self.device_generation += 1
        if host_changed:
            self.host_generation += 1
        # Cache coherence: the generation is part of every cache key, so a
        # stale hit is already impossible — clearing additionally frees the
        # dead generation's entries from the bounded capacity.
        if self.scheduler.cache is not None:
            self.scheduler.cache.clear()
        if grew:
            self.recompiles += 1
            self.warmup()
        return grew

    @staticmethod
    def _host_store(params):
        return getattr(getattr(params, "bank", None), "store", None)

    def _take_batch(self, bs: int) -> list[Request]:
        """Pop up to ``bs`` requests (weighted-fair across tenants),
        answering cache hits inline and topping the batch back up from the
        queue — repeated queries never occupy device batch slots."""
        chunk: list[Request] = []
        cache = self.scheduler.cache
        while len(chunk) < bs:
            reqs = self.scheduler.take(bs - len(chunk))
            if not reqs:
                break
            for req in reqs:
                hit = (
                    cache.get(req.fp, (self.k, self.generation, self.rung))
                    if cache is not None and req.fp is not None
                    else None
                )
                if hit is not None:
                    self._answer_cached(req, hit)
                else:
                    if cache is not None:
                        self.stats.n_cache_misses += 1
                    chunk.append(req)
        return chunk

    def _answer_cached(self, req: Request, hit) -> None:
        """Serve ``req`` from the result cache: bit-identical answer
        (same bytes, generation, and rung in the key), zero device time.
        Counts in n_queries but adds nothing to total_time_s, so cache hits
        pull AQT down exactly as they pull real latency down."""
        ids, scores = hit
        now = time.perf_counter()
        latency = now - req.t_submit
        self.stats.n_cache_hits += 1
        self.stats.n_queries += 1
        self.stats.recent_latency_s.append(latency)
        deadline = self.policy.deadline_s
        if deadline is not None and latency > deadline:
            self.stats.n_deadline_misses += 1
        self._put_result(
            req.rid,
            QueryResult(
                ids.copy(),  # clients may mutate; never hand out the
                scores.copy(),  # cached arrays themselves
                rung=self.rung,
                latency_s=latency,
                cached=True,
                # The generation is in the cache key, so a hit is always
                # at the engine's current generation.
                generation=self.generation,
            ),
        )

    def _device_batch(self, chunk: list[Request], bs: int) -> jnp.ndarray:
        """Fill the padded (bs, dim) device batch from ``chunk``.

        The device array must be a COPY of the preallocated buffer, never an
        alias (CPU jax can zero-copy suitably-aligned NumPy arrays): the
        pipelined drain refills the buffer for batch i+1 while batch i's
        device input is still pending in its rescore stage.
        """
        q = self._batch_buf[:bs]
        for i, req in enumerate(chunk):
            q[i] = req.query
        if len(chunk) < bs:  # zero stale rows from the last batch
            q[len(chunk):] = 0.0
        return jnp.array(q)  # jnp.array copies; asarray may alias

    def _put_result(self, rid: int, value) -> None:
        """Insert one answer, enforcing the results-map bound."""
        self.results[rid] = value
        while len(self.results) > self.max_results:
            old_rid, _ = self.results.popitem(last=False)  # evict oldest
            self.stats.n_results_evicted += 1
            self._evicted[old_rid] = None
            while len(self._evicted) > self.max_results:
                self._evicted.popitem(last=False)

    def _record_batch(
        self, chunk, n, out, pruned, *, bs=None, rung=None, degraded=False,
    ) -> None:
        """Account one completed batch and route its answers (outside the
        AQT window — this includes the result D2H conversion).

        ``bs``/``rung`` are the batch size and ladder rung the batch was
        *dispatched* with — under the pipelined drain the controller may
        have stepped the live rung between dispatch and completion, and the
        recorded rung must match the operating point that actually computed
        the answer."""
        bs = self.batch_size if bs is None else bs
        rung = self.rung if rung is None else rung
        faults.fire(faults.D2H)  # "delay" here models a slow __array__
        ids = np.asarray(out.ids)
        scores = np.asarray(out.scores)
        self.stats.n_queries += n
        self.stats.n_batches += 1
        self.stats.n_padded += bs - n
        self.stats.batch_size_trace.append(bs)
        if degraded:
            self.stats.n_degraded += n
        if pruned is not None:
            # Count only the n real queries — padded rows route too, but
            # their probes are not served traffic.
            pmask = np.asarray(pruned)[:n]
            self.stats.n_probes_total += int(pmask.size)
            self.stats.n_probes_pruned += int(pmask.sum())
            self.stats.batch_pruned_fraction.append(
                float(pmask.sum()) / max(pmask.size, 1)
            )
        now = time.perf_counter()
        deadline = self.policy.deadline_s
        cache = self.scheduler.cache
        for i, req in enumerate(chunk):
            latency = now - req.t_submit
            self.stats.recent_latency_s.append(latency)
            if deadline is not None and latency > deadline:
                self.stats.n_deadline_misses += 1
            self._put_result(
                req.rid,
                QueryResult(
                    ids[i],
                    scores[i],
                    degraded=degraded,
                    rung=rung,
                    latency_s=latency,
                    generation=self.generation,
                ),
            )
            # Only full-fidelity answers are cacheable: a degraded
            # (compressed-only) answer at the same key would violate the
            # bit-identical-to-fresh-search guarantee.
            if cache is not None and req.fp is not None and not degraded:
                cache.put(
                    req.fp, (self.k, self.generation, rung), ids[i], scores[i]
                )

    def _staged_host_serving(self) -> bool:
        """Host-tier LIDER params + a backend exposing the staged search."""
        return (
            self.params is not None
            and getattr(self.search_fn, "host_stage1", None) is not None
            and getattr(
                getattr(self.params, "bank", None), "rescore_tier", "device"
            )
            == "host"
        )

    def _adjust_rung(self) -> None:
        """Operating-point controller, called once per dispatch.

        Two modes. Legacy (no scheduler SLO): the PR 6 deadline-pressure
        hysteresis — step down (cheaper point) when the oldest queued
        request has aged past ``degrade_age_fraction`` of the deadline,
        step back up below ``recover_age_fraction``. Frontier navigation
        (``SchedulerConfig.slo_s`` set): map the scheduler's continuous
        load signal directly onto the ladder — rung = round(load * len) —
        so the engine rides the measured speed-quality frontier instead of
        walking it one reactive step at a time. Either way every rung was
        pre-compiled in warmup."""
        pol = self.policy
        if not pol.ladder or not self._accepts_point:
            return
        if self.sched_cfg.slo_s is not None:
            load = self.scheduler.load_signal(time.perf_counter())
            target = min(int(round(load * len(pol.ladder))), len(pol.ladder))
            if target > self.rung:
                self.stats.n_rung_steps += target - self.rung
            self.rung = target
            return
        if pol.deadline_s is None:
            return
        oldest = self.scheduler.oldest_submit()
        if oldest is None:
            if self.rung > 0:
                self.rung -= 1
            return
        age = time.perf_counter() - oldest
        if age >= pol.deadline_s * pol.degrade_age_fraction:
            if self.rung < len(pol.ladder):
                self.rung += 1
                self.stats.n_rung_steps += 1
        elif age <= pol.deadline_s * pol.recover_age_fraction and self.rung > 0:
            self.rung -= 1

    def drain(self, max_dispatches: int | None = None) -> None:
        """Execute queued requests in scheduler-sized batches.

        Host-tier LIDER indexes (``rescore_tier="host"``) drain through the
        double-buffered fetch->rescore pipeline (:meth:`_drain_pipelined`);
        everything else executes serially through the same per-dispatch
        plumbing (:meth:`_execute_batch`). ``max_dispatches`` bounds the
        number of batches executed this call — the open-loop driver's
        hook: submit newly-arrived traffic, drain one dispatch, repeat.
        The engine's fault plan (chaos testing) is active for the duration
        of the drain.
        """
        with faults.activate(self.fault_plan):
            if self._staged_host_serving():
                return self._drain_pipelined(max_dispatches)
            n_disp = 0
            while len(self.scheduler):
                if max_dispatches is not None and n_disp >= max_dispatches:
                    break
                self._adjust_rung()
                chunk = self._take_batch(self.scheduler.pick_batch_size())
                if not chunk:  # everything was answered from the cache
                    continue
                n_disp += 1
                self._execute_batch(chunk)

    def execute_chunk(self, chunk: list[Request]) -> list:
        """Synchronously execute one already-admitted batch and return its
        answers in request order.

        The replica router's dispatch primitive (DESIGN.md §Replica
        fabric): the router owns admission/fairness/batching in its own
        scheduler and hands fully-formed chunks to whichever replica
        engine its health mask selects; the engine runs its normal
        execution core — serial or staged host-tier, including the
        fetch-retry/degrade ladder — and the answers are popped (never
        left in the results map, so router-assigned rids can overlap
        across replicas). The engine's fault plan stays active for the
        duration, exactly as in :meth:`drain`.
        """
        with faults.activate(self.fault_plan):
            if self._staged_host_serving():
                t0 = time.perf_counter()
                e = self._dispatch_stage1(chunk)
                d2h_s = 0.0
                while True:
                    if e.retry_at is not None:
                        wait = e.retry_at - time.perf_counter()
                        if wait > 0:
                            time.sleep(wait)
                    d2h = self._finish_host_batch(e)
                    if d2h is not None:
                        d2h_s = d2h
                        break
                self.stats.total_time_s += max(
                    time.perf_counter() - t0 - d2h_s, 0.0
                )
            else:
                self._execute_batch(chunk)
        return [self.results.pop(r.rid) for r in chunk]

    def _execute_batch(self, chunk: list[Request]) -> None:
        """The serial execution core: pad to the smallest pre-warmed batch
        size, search, block, account. One compiled trace per ladder size —
        dispatching ``len(chunk)`` directly would re-trace per distinct
        depth."""
        bs = next(
            (b for b in self.scheduler.ladder if b >= len(chunk)),
            self.scheduler.ladder[-1],
        )
        q = self._device_batch(chunk, bs)
        t0 = time.perf_counter()
        out, pruned = self._split_out(self._search(q))
        # Block on BOTH outputs so AQT covers all device time — blocking on
        # ids alone under-counts when scores finish later. The AQT window
        # closes HERE: D2H conversion (np.asarray) is host-side transfer
        # the paper's efficiency metric must not include.
        jax.block_until_ready((out.ids, out.scores))
        dt = time.perf_counter() - t0
        self.stats.total_time_s += dt
        self.scheduler.observe_service(bs, dt)
        self._record_batch(chunk, len(chunk), out, pruned, bs=bs)

    def _drain_pipelined(self, max_dispatches: int | None = None) -> None:
        """Double-buffered host-tier drain (§Tiered embedding store).

        Batch *i+1*'s compressed first pass is dispatched to the device
        *before* batch *i*'s provisional rows come back D2H and its exact
        rows are fetched from the host tier — so the host fetch (and the
        B·k'·d H2D of the fetched rows) hides behind device work for every
        batch but the last. The AQT window spans the whole pipelined drain
        (per-batch windows would double-count the overlapped regions) and
        still excludes the result D2H conversions, which are measured and
        subtracted.

        A batch whose host fetch fails is NOT finished in place: it is
        parked with a ``retry_at`` backoff stamp while other pending
        batches keep fetching/rescoring and new stage1 work keeps
        dispatching — a host brownout slows one batch, not the pipeline
        (the engine only sleeps when every pending batch is backing off
        and there is nothing else to do).
        """
        t0 = time.perf_counter()
        d2h_s = 0.0
        pending: collections.deque[_PendingBatch] = collections.deque()
        n_disp = 0
        while len(self.scheduler) or pending:
            may_dispatch = (
                len(self.scheduler)
                and len(pending) < self._pipeline_depth
                and (max_dispatches is None or n_disp < max_dispatches)
            )
            if may_dispatch:
                self._adjust_rung()
                chunk = self._take_batch(self.scheduler.pick_batch_size())
                if chunk:
                    # Async dispatch: host_stage1 returns before the device
                    # finishes, so every already-pending batch's host fetch
                    # below overlaps this compute.
                    for e in pending:
                        e.overlap_armed = True
                    pending.append(self._dispatch_stage1(chunk))
                    n_disp += 1
                continue
            if not pending:
                break  # queue non-empty but dispatch budget exhausted
            now = time.perf_counter()
            entry = next(
                (
                    e
                    for e in pending
                    if e.retry_at is None or e.retry_at <= now
                ),
                None,
            )
            if entry is None:
                # Every pending batch is in fetch backoff and the dispatch
                # window is closed — nothing useful to overlap; sleep to
                # the earliest retry stamp.
                wait = min(e.retry_at for e in pending) - now
                if wait > 0:
                    time.sleep(wait)
                continue
            finished_d2h = self._finish_host_batch(entry)
            if finished_d2h is not None:
                pending.remove(entry)
                d2h_s += finished_d2h
        self.stats.total_time_s += max(time.perf_counter() - t0 - d2h_s, 0.0)

    def _dispatch_stage1(self, chunk: list[Request]) -> "_PendingBatch":
        """Pad + dispatch the compressed first pass; capture the operating
        point (rung) the batch is computed with so its answers are recorded
        against that point even if the controller steps the live rung
        before the batch completes."""
        bs = next(
            (b for b in self.scheduler.ladder if b >= len(chunk)),
            self.scheduler.ladder[-1],
        )
        q = self._device_batch(chunk, bs)
        t0 = time.perf_counter()
        stats_out = {} if self._auto_block_q is not None else None
        if stats_out is not None:
            prov, pruned = self.search_fn.host_stage1(
                self.params, q, self.k, point=self._effective_point(),
                stats_out=stats_out,
            )
        else:
            prov, pruned = self.search_fn.host_stage1(
                self.params, q, self.k, point=self._rung_point()
            )
        self.scheduler.observe_service(bs, time.perf_counter() - t0)
        if stats_out:
            # Feed the drained schedule's measured sharing into the stats
            # and re-pick block_q for the NEXT dispatch from the bounded
            # window of observed probe distributions. The pick is pure host
            # arithmetic over small count arrays; every ladder choice was
            # pre-warmed, so swapping costs zero query-path retraces.
            self.stats.n_sched_pairs += stats_out["n_pairs"]
            self.stats.n_sched_steps += stats_out["n_steps"]
            self.stats.sharing_trace.append(
                stats_out["n_pairs"] / max(stats_out["n_steps"], 1)
            )
            self._probe_counts.append(stats_out["cluster_counts"])
            self._auto_block_q = pick_block_q(
                self._probe_counts, self.block_q_ladder
            )
        return _PendingBatch(
            chunk=chunk, bs=bs, q=q, prov=prov, pruned=pruned, rung=self.rung
        )

    def _finish_host_batch(self, e: "_PendingBatch") -> float | None:
        """Fetch + rescore one stage1-dispatched batch. Returns the result
        D2H conversion seconds (excluded from the AQT window), or None when
        the fetch failed and the batch was parked for a backoff retry.

        A host fetch that exhausts all its retries does NOT abort the
        drain: the batch is answered compressed-only from its provisional
        top-k' (``degraded=True``) and the rung controller steps down one
        rung for subsequent batches. Backoff is exponential with
        deterministic (seeded) jitter so chaos runs replay identically."""
        pol = self.policy
        if not e.blocked:
            # Close the device wait BEFORE the fetch timer: np.asarray(prov)
            # inside host_fetch would otherwise block on the batch's first
            # pass and charge device compute to the host-fetch stat.
            jax.block_until_ready(e.prov)
            e.blocked = True
        try:
            tf0 = time.perf_counter()
            fetched = self.search_fn.host_fetch(self.params, e.prov.ids)
            self.stats.host_fetch_us += (time.perf_counter() - tf0) * 1e6
        except Exception:
            e.attempts += 1
            if e.attempts > pol.fetch_retries:
                self.stats.n_fetch_failures += 1
                return self._record_degraded(e)
            self.stats.n_fetch_retries += 1
            delay = pol.fetch_backoff_s * (
                pol.fetch_backoff_mult ** (e.attempts - 1)
            )
            delay *= 1.0 + self._rng.random()
            e.retry_at = time.perf_counter() + delay
            return None  # parked; the drain loop keeps other batches moving
        self.stats.n_host_fetches += 1
        if e.overlap_armed:
            self.stats.n_overlapped_fetches += 1
        out = self.search_fn.host_stage2(
            self.params, jnp.asarray(fetched), e.prov.ids, e.q, self.k
        )
        jax.block_until_ready((out.ids, out.scores))
        tc0 = time.perf_counter()
        self._record_batch(
            e.chunk, len(e.chunk), out, e.pruned, bs=e.bs, rung=e.rung
        )
        return time.perf_counter() - tc0

    def _record_degraded(self, e: "_PendingBatch") -> float:
        """Answer a fetch-exhausted batch compressed-only: stage 1 already
        holds the compressed-domain top-k' — no fetch, no exact rescore
        (DESIGN.md §Failure model, last ladder rung)."""
        if self.policy.ladder and self.rung < len(self.policy.ladder):
            self.rung += 1
            self.stats.n_rung_steps += 1
        out = lider_lib.compressed_only_topk(
            self.params.bank.gids, e.prov, k=self.k
        )
        jax.block_until_ready((out.ids, out.scores))
        tc0 = time.perf_counter()
        self._record_batch(
            e.chunk, len(e.chunk), out, e.pruned,
            bs=e.bs, rung=e.rung, degraded=True,
        )
        return time.perf_counter() - tc0

    def result(self, rid: int, *, keep: bool = False):
        """Fetch (and by default release) the answer for ``rid``.

        Popping on read is what keeps a long-running server's memory flat;
        ``keep=True`` leaves the entry in the map (it then stays until
        re-read or evicted by the ``max_results`` bound). Return values:
        a :class:`QueryResult` (unpacks as ``(ids, scores)``), a
        :class:`Shed` for admission-control rejections, the falsy
        :data:`EVICTED` sentinel when the answer existed but was evicted by
        the ``max_results`` bound, or ``None`` for never-submitted /
        already-collected ids.
        """
        out = self.results.get(rid) if keep else self.results.pop(rid, None)
        if out is not None:
            return out
        if rid in self._evicted:
            return EVICTED
        return None

"""Batched retrieval serving engine.

Wraps an index backend (LIDER or any baseline) behind one API:
``submit`` queues requests, ``drain`` pads to the compiled batch size and
executes — the latency-vs-throughput batching knob real serving stacks tune.
AQT (average query time, the paper's efficiency metric) is measured here.

Backends share the signature ``search(queries (B, d), k) -> TopK``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import lider as lider_lib
from ..core.baselines import (
    flat_search,
    ivfpq_search,
    mplsh_search,
    pq_search,
    sklsh_search,
)
from ..core.core_model import TopK


@dataclasses.dataclass
class EngineStats:
    n_queries: int = 0
    n_batches: int = 0
    total_time_s: float = 0.0
    n_padded: int = 0  # pad slots executed for partial batches

    @property
    def aqt(self) -> float:
        return self.total_time_s / max(self.n_queries, 1)

    @property
    def padding_fraction(self) -> float:
        """Fraction of executed batch slots that were padding (wasted work)."""
        return self.n_padded / max(self.n_queries + self.n_padded, 1)


def make_backend(kind: str, index, embs: jnp.ndarray | None = None, **kw) -> Callable:
    """Uniform search closure over any index type."""
    if kind == "lider":
        def search(q, k):
            return lider_lib.search_lider(
                index,
                q,
                k=k,
                n_probe=kw.get("n_probe", 20),
                r0=kw.get("r0", 4),
                refine=kw.get("refine", False),
                use_fused=kw.get("use_fused"),
            )
    elif kind == "flat":
        def search(q, k):
            return flat_search(embs, q, k=k)
    elif kind == "pq":
        def search(q, k):
            return pq_search(index, q, k=k)
    elif kind == "ivfpq":
        def search(q, k):
            return ivfpq_search(index, q, k=k, n_probe=kw.get("n_probe", 8))
    elif kind == "sklsh":
        def search(q, k):
            return sklsh_search(index, embs, q, k=k)
    elif kind == "mplsh":
        def search(q, k):
            return mplsh_search(index, embs, q, k=k, n_probes=kw.get("n_probes", 8))
    else:
        raise ValueError(f"unknown backend {kind}")
    return search


class RetrievalEngine:
    """Fixed-batch serving with request queueing and AQT accounting."""

    def __init__(self, search_fn: Callable, *, batch_size: int, k: int, dim: int):
        self.search_fn = search_fn
        self.batch_size = batch_size
        self.k = k
        self.dim = dim
        self.queue: list[tuple[int, np.ndarray]] = []
        self.results: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self.stats = EngineStats()
        self._next_id = 0
        # Preallocated padded batch buffer: drain fills it in place instead
        # of allocating (batch, dim) floats per batch.
        self._batch_buf = np.zeros((batch_size, dim), np.float32)

    def warmup(self):
        q = jnp.zeros((self.batch_size, self.dim), jnp.float32)
        jax.block_until_ready(self.search_fn(q, self.k).ids)

    def submit(self, query: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, np.asarray(query, np.float32)))
        return rid

    def drain(self) -> None:
        """Execute queued requests in fixed-size (padded) batches."""
        while self.queue:
            chunk = self.queue[: self.batch_size]
            self.queue = self.queue[self.batch_size:]
            n = len(chunk)
            q = self._batch_buf
            for i, (_, vec) in enumerate(chunk):
                q[i] = vec
            if n < self.batch_size:  # zero stale rows from the last batch
                q[n:] = 0.0
            t0 = time.perf_counter()
            out: TopK = self.search_fn(jnp.asarray(q), self.k)
            # Block on BOTH outputs so AQT covers all device time — blocking
            # on ids alone under-counts when scores finish later.
            ids = np.asarray(jax.block_until_ready(out.ids))
            scores = np.asarray(jax.block_until_ready(out.scores))
            dt = time.perf_counter() - t0
            self.stats.n_queries += n
            self.stats.n_batches += 1
            self.stats.n_padded += self.batch_size - n
            self.stats.total_time_s += dt
            for i, (rid, _) in enumerate(chunk):
                self.results[rid] = (ids[i], scores[i])

    def result(self, rid: int):
        return self.results.get(rid)

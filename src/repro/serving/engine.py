"""Batched retrieval serving engine.

Wraps an index backend (LIDER or any baseline) behind one API:
``submit`` queues requests, ``drain`` pads to the compiled batch size and
executes — the latency-vs-throughput batching knob real serving stacks tune.
AQT (average query time, the paper's efficiency metric) is measured here.

Backends share the signature ``search(queries (B, d), k) -> TopK``; an
*updatable* LIDER backend takes ``search(params, queries, k)`` and the engine
owns the served params so ``apply_updates`` can swap them between batches
(checkpointed serving + online upsert/delete — DESIGN.md §Index lifecycle).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import lider as lider_lib
from ..core.baselines import (
    flat_search,
    ivfpq_search,
    mplsh_search,
    pq_search,
    sklsh_search,
)
from ..core.core_model import TopK


@dataclasses.dataclass
class EngineStats:
    n_queries: int = 0
    n_batches: int = 0
    total_time_s: float = 0.0
    n_padded: int = 0  # pad slots executed for partial batches
    # Adaptive probe pruning (DESIGN.md §Adaptive speed-quality control
    # plane): probes routed by layer 1 but masked by the margin rule. The
    # per-batch trace is a bounded deque (newest batches) — a long-running
    # server must not grow per-batch state without bound; the lifetime
    # aggregate lives in the two counters.
    n_probes_total: int = 0
    n_probes_pruned: int = 0
    batch_pruned_fraction: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=256)
    )
    n_results_evicted: int = 0  # results dropped by the bounded results map

    @property
    def aqt(self) -> float:
        return self.total_time_s / max(self.n_queries, 1)

    @property
    def padding_fraction(self) -> float:
        """Fraction of executed batch slots that were padding (wasted work)."""
        return self.n_padded / max(self.n_queries + self.n_padded, 1)

    @property
    def pruned_probe_fraction(self) -> float:
        """Fraction of routed probes the margin rule pruned (all batches)."""
        return self.n_probes_pruned / max(self.n_probes_total, 1)


# Searchable knobs each backend accepts; anything else in **kw is a typo and
# raises instead of being silently ignored. All probing backends take the
# same ``n_probe`` spelling (mplsh's search fn calls it n_probes internally).
_BACKEND_KWARGS: dict[str, frozenset[str]] = {
    "lider": frozenset({
        "n_probe", "r0", "refine", "use_fused", "prune_margin",
        "rescore_factor", "block_c",
    }),
    "flat": frozenset(),
    "pq": frozenset(),
    "ivfpq": frozenset({"n_probe"}),
    "sklsh": frozenset(),
    "mplsh": frozenset({"n_probe"}),
}


def make_backend(
    kind: str,
    index,
    embs: jnp.ndarray | None = None,
    *,
    updatable: bool = False,
    **kw,
) -> Callable:
    """Uniform search closure over any index type.

    ``updatable=True`` (LIDER only) returns ``search(params, q, k)`` instead
    of closing over the index — pass the params to ``RetrievalEngine`` so
    ``apply_updates`` can swap them between batches.
    """
    if kind not in _BACKEND_KWARGS:
        raise ValueError(
            f"unknown backend {kind!r}; expected one of "
            f"{sorted(_BACKEND_KWARGS)}"
        )
    unknown = set(kw) - _BACKEND_KWARGS[kind]
    if unknown:
        allowed = sorted(_BACKEND_KWARGS[kind]) or "none"
        raise TypeError(
            f"backend {kind!r} got unexpected kwargs {sorted(unknown)}; "
            f"allowed: {allowed}"
        )
    if updatable and kind != "lider":
        raise ValueError(f"updatable backends require kind='lider', got {kind!r}")

    if kind == "lider":
        prune_margin = kw.get("prune_margin")

        def lider_search(params, q, k):
            # With pruning on, the search also returns the (B, P) bool mask
            # of routed-but-pruned probes; the engine folds it into
            # EngineStats (per-batch pruned-probe fraction).
            return lider_lib.search_lider(
                params,
                q,
                k=k,
                n_probe=kw.get("n_probe", 20),
                r0=kw.get("r0", 4),
                refine=kw.get("refine", False),
                use_fused=kw.get("use_fused"),
                prune_margin=prune_margin,
                with_stats=prune_margin is not None,
                rescore_factor=kw.get("rescore_factor", 4),
                block_c=kw.get("block_c"),
            )

        if updatable:
            return lider_search

        def search(q, k):
            return lider_search(index, q, k)
    elif kind == "flat":
        def search(q, k):
            return flat_search(embs, q, k=k)
    elif kind == "pq":
        def search(q, k):
            return pq_search(index, q, k=k)
    elif kind == "ivfpq":
        def search(q, k):
            return ivfpq_search(index, q, k=k, n_probe=kw.get("n_probe", 8))
    elif kind == "sklsh":
        def search(q, k):
            return sklsh_search(index, embs, q, k=k)
    else:  # mplsh
        def search(q, k):
            return mplsh_search(index, embs, q, k=k, n_probes=kw.get("n_probe", 8))
    return search


class RetrievalEngine:
    """Fixed-batch serving with request queueing and AQT accounting.

    With ``params`` set, ``search_fn`` must take ``(params, q, k)`` and the
    engine serves whatever params it currently holds — ``apply_updates``
    swaps them atomically between batches, tracking a generation counter and
    recompiling (re-warming) only when an update grew array shapes (capacity
    growth); same-shape updates reuse the compiled search.
    """

    def __init__(
        self,
        search_fn: Callable,
        *,
        batch_size: int,
        k: int,
        dim: int,
        params=None,
        max_results: int = 65536,
    ):
        self.search_fn = search_fn
        self.batch_size = batch_size
        self.k = k
        self.dim = dim
        self.params = params
        self.generation = 0  # bumped on every apply_updates
        self.recompiles = 0  # bumped only when shapes changed
        self.queue: collections.deque[tuple[int, np.ndarray]] = collections.deque()
        # Bounded FIFO of answered (ids, scores) pairs. ``result()`` pops by
        # default, so a well-behaved client keeps this near-empty; the bound
        # is the backstop for clients that never collect (a long-running
        # server must not leak every answer it has ever produced).
        if max_results < batch_size:
            raise ValueError(
                f"max_results={max_results} must hold at least one batch "
                f"({batch_size})"
            )
        self.max_results = max_results
        self.results: collections.OrderedDict[
            int, tuple[np.ndarray, np.ndarray]
        ] = collections.OrderedDict()
        self.stats = EngineStats()
        self._next_id = 0
        # Preallocated padded batch buffer: drain fills it in place instead
        # of allocating (batch, dim) floats per batch.
        self._batch_buf = np.zeros((batch_size, dim), np.float32)

    def _search(self, q: jnp.ndarray):
        if self.params is not None:
            return self.search_fn(self.params, q, self.k)
        return self.search_fn(q, self.k)

    @staticmethod
    def _split_out(out) -> tuple[TopK, jnp.ndarray | None]:
        """Backends return TopK or (TopK, pruned-probe mask)."""
        if isinstance(out, tuple) and not isinstance(out, TopK):
            return out[0], out[1]
        return out, None

    def warmup(self):
        q = jnp.zeros((self.batch_size, self.dim), jnp.float32)
        out, _ = self._split_out(self._search(q))
        jax.block_until_ready(out.ids)

    def submit(self, query: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, np.asarray(query, np.float32)))
        return rid

    def apply_updates(self, update_fn: Callable) -> bool:
        """Swap served params to ``update_fn(params)`` between batches.

        ``update_fn`` returns either new params or ``(new_params, stats)``
        (the ``core.update`` convention). Returns True when leaf shapes
        changed (capacity growth) — the one case the compiled search must
        re-trace; the engine eats that recompile here, off the query path.
        """
        if self.params is None:
            raise ValueError(
                "engine was not built with params (make_backend(..., "
                "updatable=True) + RetrievalEngine(..., params=...))"
            )
        out = update_fn(self.params)
        new_params = out[0] if isinstance(out, tuple) else out
        old_shapes = [jnp.shape(l) for l in jax.tree_util.tree_leaves(self.params)]
        new_shapes = [jnp.shape(l) for l in jax.tree_util.tree_leaves(new_params)]
        grew = old_shapes != new_shapes
        self.params = new_params
        self.generation += 1
        if grew:
            self.recompiles += 1
            self.warmup()
        return grew

    def drain(self) -> None:
        """Execute queued requests in fixed-size (padded) batches."""
        while self.queue:
            n = min(len(self.queue), self.batch_size)
            chunk = [self.queue.popleft() for _ in range(n)]
            q = self._batch_buf
            for i, (_, vec) in enumerate(chunk):
                q[i] = vec
            if n < self.batch_size:  # zero stale rows from the last batch
                q[n:] = 0.0
            t0 = time.perf_counter()
            out, pruned = self._split_out(self._search(jnp.asarray(q)))
            # Block on BOTH outputs so AQT covers all device time — blocking
            # on ids alone under-counts when scores finish later. The AQT
            # window closes HERE: D2H conversion (np.asarray) is host-side
            # transfer the paper's efficiency metric must not include.
            jax.block_until_ready((out.ids, out.scores))
            dt = time.perf_counter() - t0
            ids = np.asarray(out.ids)
            scores = np.asarray(out.scores)
            self.stats.n_queries += n
            self.stats.n_batches += 1
            self.stats.n_padded += self.batch_size - n
            self.stats.total_time_s += dt
            if pruned is not None:
                # Count only the n real queries — padded rows route too, but
                # their probes are not served traffic.
                pmask = np.asarray(pruned)[:n]
                self.stats.n_probes_total += int(pmask.size)
                self.stats.n_probes_pruned += int(pmask.sum())
                self.stats.batch_pruned_fraction.append(
                    float(pmask.sum()) / max(pmask.size, 1)
                )
            for i, (rid, _) in enumerate(chunk):
                self.results[rid] = (ids[i], scores[i])
            while len(self.results) > self.max_results:
                self.results.popitem(last=False)  # evict oldest un-collected
                self.stats.n_results_evicted += 1

    def result(self, rid: int, *, keep: bool = False):
        """Fetch (and by default release) the answer for ``rid``.

        Popping on read is what keeps a long-running server's memory flat;
        ``keep=True`` leaves the entry in the map (it then stays until
        re-read or evicted by the ``max_results`` bound). Returns None for
        unknown/already-collected/evicted ids.
        """
        if keep:
            return self.results.get(rid)
        return self.results.pop(rid, None)

"""Batched retrieval serving engine.

Wraps an index backend (LIDER or any baseline) behind one API:
``submit`` queues requests, ``drain`` pads to the compiled batch size and
executes — the latency-vs-throughput batching knob real serving stacks tune.
AQT (average query time, the paper's efficiency metric) is measured here.

Backends share the signature ``search(queries (B, d), k) -> TopK``; an
*updatable* LIDER backend takes ``search(params, queries, k)`` and the engine
owns the served params so ``apply_updates`` can swap them between batches
(checkpointed serving + online upsert/delete — DESIGN.md §Index lifecycle).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import lider as lider_lib
from ..core.baselines import (
    flat_search,
    ivfpq_search,
    mplsh_search,
    pq_search,
    sklsh_search,
)
from ..core.core_model import TopK


@dataclasses.dataclass
class EngineStats:
    n_queries: int = 0
    n_batches: int = 0
    total_time_s: float = 0.0
    n_padded: int = 0  # pad slots executed for partial batches
    # Adaptive probe pruning (DESIGN.md §Adaptive speed-quality control
    # plane): probes routed by layer 1 but masked by the margin rule. The
    # per-batch trace is a bounded deque (newest batches) — a long-running
    # server must not grow per-batch state without bound; the lifetime
    # aggregate lives in the two counters.
    n_probes_total: int = 0
    n_probes_pruned: int = 0
    batch_pruned_fraction: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=256)
    )
    n_results_evicted: int = 0  # results dropped by the bounded results map
    # Tiered serving (DESIGN.md §Tiered embedding store): host-side exact-row
    # fetch accounting. A fetch is "overlapped" when the next batch's
    # compressed first pass was already dispatched to the device before the
    # fetch ran — the double-buffered pipeline's payoff condition.
    host_fetch_us: float = 0.0
    n_host_fetches: int = 0
    n_overlapped_fetches: int = 0

    @property
    def aqt(self) -> float:
        return self.total_time_s / max(self.n_queries, 1)

    @property
    def overlap_fraction(self) -> float:
        """Fraction of host fetches that ran under a dispatched next batch."""
        return self.n_overlapped_fetches / max(self.n_host_fetches, 1)

    @property
    def padding_fraction(self) -> float:
        """Fraction of executed batch slots that were padding (wasted work)."""
        return self.n_padded / max(self.n_queries + self.n_padded, 1)

    @property
    def pruned_probe_fraction(self) -> float:
        """Fraction of routed probes the margin rule pruned (all batches)."""
        return self.n_probes_pruned / max(self.n_probes_total, 1)


# Searchable knobs each backend accepts; anything else in **kw is a typo and
# raises instead of being silently ignored. All probing backends take the
# same ``n_probe`` spelling (mplsh's search fn calls it n_probes internally).
_BACKEND_KWARGS: dict[str, frozenset[str]] = {
    "lider": frozenset({
        "n_probe", "r0", "refine", "use_fused", "prune_margin",
        "rescore_factor", "block_c",
    }),
    "flat": frozenset(),
    "pq": frozenset(),
    "ivfpq": frozenset({"n_probe"}),
    "sklsh": frozenset(),
    "mplsh": frozenset({"n_probe"}),
}


def make_backend(
    kind: str,
    index,
    embs: jnp.ndarray | None = None,
    *,
    updatable: bool = False,
    **kw,
) -> Callable:
    """Uniform search closure over any index type.

    ``updatable=True`` (LIDER only) returns ``search(params, q, k)`` instead
    of closing over the index — pass the params to ``RetrievalEngine`` so
    ``apply_updates`` can swap them between batches.
    """
    if kind not in _BACKEND_KWARGS:
        raise ValueError(
            f"unknown backend {kind!r}; expected one of "
            f"{sorted(_BACKEND_KWARGS)}"
        )
    unknown = set(kw) - _BACKEND_KWARGS[kind]
    if unknown:
        allowed = sorted(_BACKEND_KWARGS[kind]) or "none"
        raise TypeError(
            f"backend {kind!r} got unexpected kwargs {sorted(unknown)}; "
            f"allowed: {allowed}"
        )
    if updatable and kind != "lider":
        raise ValueError(f"updatable backends require kind='lider', got {kind!r}")

    if kind == "lider":
        prune_margin = kw.get("prune_margin")

        def lider_search(params, q, k):
            # With pruning on, the search also returns the (B, P) bool mask
            # of routed-but-pruned probes; the engine folds it into
            # EngineStats (per-batch pruned-probe fraction).
            return lider_lib.search_lider(
                params,
                q,
                k=k,
                n_probe=kw.get("n_probe", 20),
                r0=kw.get("r0", 4),
                refine=kw.get("refine", False),
                use_fused=kw.get("use_fused"),
                prune_margin=prune_margin,
                with_stats=prune_margin is not None,
                rescore_factor=kw.get("rescore_factor", 4),
                block_c=kw.get("block_c"),
            )

        if updatable:
            # Staged spelling of the same operating point, for host-tier
            # (rescore_tier="host") params: the engine pipelines stage1 of
            # batch i+1 over batch i's host fetch + rescore (DESIGN.md
            # §Tiered embedding store). search_lider composes the identical
            # stages serially, so results match the unpipelined call.
            def host_stage1(params, q, k):
                prov, pruned = lider_lib.host_first_pass(
                    params,
                    q,
                    k=k,
                    n_probe=kw.get("n_probe", 20),
                    r0=kw.get("r0", 4),
                    refine=kw.get("refine", False),
                    use_fused=kw.get("use_fused"),
                    prune_margin=prune_margin,
                    rescore_factor=kw.get("rescore_factor", 4),
                    block_c=kw.get("block_c"),
                )
                # Same contract as the serial path: probe stats only when
                # the margin rule is actually configured.
                return prov, (pruned if prune_margin is not None else None)

            def host_stage2(params, fetched, prov_rows, q, k):
                return lider_lib.host_rescore(
                    params.bank.gids,
                    fetched,
                    prov_rows,
                    q,
                    k=k,
                    use_fused=kw.get("use_fused"),
                    block_c=kw.get("block_c"),
                )

            lider_search.host_stage1 = host_stage1
            lider_search.host_fetch = lider_lib.host_fetch
            lider_search.host_stage2 = host_stage2
            return lider_search

        def search(q, k):
            return lider_search(index, q, k)
    elif kind == "flat":
        def search(q, k):
            return flat_search(embs, q, k=k)
    elif kind == "pq":
        def search(q, k):
            return pq_search(index, q, k=k)
    elif kind == "ivfpq":
        def search(q, k):
            return ivfpq_search(index, q, k=k, n_probe=kw.get("n_probe", 8))
    elif kind == "sklsh":
        def search(q, k):
            return sklsh_search(index, embs, q, k=k)
    else:  # mplsh
        def search(q, k):
            return mplsh_search(index, embs, q, k=k, n_probes=kw.get("n_probe", 8))
    return search


class RetrievalEngine:
    """Fixed-batch serving with request queueing and AQT accounting.

    With ``params`` set, ``search_fn`` must take ``(params, q, k)`` and the
    engine serves whatever params it currently holds — ``apply_updates``
    swaps them atomically between batches, tracking a generation counter and
    recompiling (re-warming) only when an update grew array shapes (capacity
    growth); same-shape updates reuse the compiled search.
    """

    def __init__(
        self,
        search_fn: Callable,
        *,
        batch_size: int,
        k: int,
        dim: int,
        params=None,
        max_results: int = 65536,
    ):
        self.search_fn = search_fn
        self.batch_size = batch_size
        self.k = k
        self.dim = dim
        self.params = params
        self.generation = 0  # bumped on every apply_updates
        # The tier split (DESIGN.md §Tiered embedding store): device-tier
        # state (pytree leaves) and host-tier state (the EmbStore content)
        # change independently, and only device *shape* changes ever force a
        # recompile — a host-content-only update must not re-trace anything.
        self.device_generation = 0  # pytree leaves changed
        self.host_generation = 0  # host EmbStore content changed
        self.recompiles = 0  # bumped only when shapes changed
        self.queue: collections.deque[tuple[int, np.ndarray]] = collections.deque()
        # Bounded FIFO of answered (ids, scores) pairs. ``result()`` pops by
        # default, so a well-behaved client keeps this near-empty; the bound
        # is the backstop for clients that never collect (a long-running
        # server must not leak every answer it has ever produced).
        if max_results < batch_size:
            raise ValueError(
                f"max_results={max_results} must hold at least one batch "
                f"({batch_size})"
            )
        self.max_results = max_results
        self.results: collections.OrderedDict[
            int, tuple[np.ndarray, np.ndarray]
        ] = collections.OrderedDict()
        self.stats = EngineStats()
        self._next_id = 0
        # Preallocated padded batch buffer: drain fills it in place instead
        # of allocating (batch, dim) floats per batch.
        self._batch_buf = np.zeros((batch_size, dim), np.float32)

    def _search(self, q: jnp.ndarray):
        if self.params is not None:
            return self.search_fn(self.params, q, self.k)
        return self.search_fn(q, self.k)

    @staticmethod
    def _split_out(out) -> tuple[TopK, jnp.ndarray | None]:
        """Backends return TopK or (TopK, pruned-probe mask)."""
        if isinstance(out, tuple) and not isinstance(out, TopK):
            return out[0], out[1]
        return out, None

    def warmup(self):
        q = jnp.zeros((self.batch_size, self.dim), jnp.float32)
        out, _ = self._split_out(self._search(q))
        jax.block_until_ready(out.ids)

    def submit(self, query: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, np.asarray(query, np.float32)))
        return rid

    def apply_updates(self, update_fn: Callable) -> bool:
        """Swap served params to ``update_fn(params)`` between batches.

        ``update_fn`` returns either new params or ``(new_params, stats)``
        (the ``core.update`` convention). Returns True when leaf shapes
        changed (capacity growth) — the one case the compiled search must
        re-trace; the engine eats that recompile here, off the query path.
        """
        if self.params is None:
            raise ValueError(
                "engine was not built with params (make_backend(..., "
                "updatable=True) + RetrievalEngine(..., params=...))"
            )
        old_leaves = jax.tree_util.tree_leaves(self.params)
        old_store = self._host_store(self.params)
        # Capture the version BEFORE the update runs: lifecycle ops mutate
        # the store in place, so the object identity alone can't tell us
        # whether its content changed.
        old_hver = None if old_store is None else old_store.version
        out = update_fn(self.params)
        new_params = out[0] if isinstance(out, tuple) else out
        new_leaves = jax.tree_util.tree_leaves(new_params)
        grew = [jnp.shape(l) for l in old_leaves] != [
            jnp.shape(l) for l in new_leaves
        ]
        device_changed = grew or any(
            a is not b for a, b in zip(old_leaves, new_leaves)
        )
        new_store = self._host_store(new_params)
        host_changed = (new_store is not old_store) or (
            new_store is not None and new_store.version != old_hver
        )
        self.params = new_params
        self.generation += 1
        if device_changed:
            self.device_generation += 1
        if host_changed:
            self.host_generation += 1
        if grew:
            self.recompiles += 1
            self.warmup()
        return grew

    @staticmethod
    def _host_store(params):
        return getattr(getattr(params, "bank", None), "store", None)

    def _next_batch(self):
        """Pop up to ``batch_size`` requests into the padded device batch.

        The device array must be a COPY of the preallocated buffer, never an
        alias (CPU jax can zero-copy suitably-aligned NumPy arrays): the
        pipelined drain refills the buffer for batch i+1 while batch i's
        device input is still pending in its rescore stage.
        """
        n = min(len(self.queue), self.batch_size)
        chunk = [self.queue.popleft() for _ in range(n)]
        q = self._batch_buf
        for i, (_, vec) in enumerate(chunk):
            q[i] = vec
        if n < self.batch_size:  # zero stale rows from the last batch
            q[n:] = 0.0
        return chunk, n, jnp.array(q)  # jnp.array copies; asarray may alias

    def _record_batch(self, chunk, n, out, pruned) -> None:
        """Account one completed batch and route its answers (outside the
        AQT window — this includes the result D2H conversion)."""
        ids = np.asarray(out.ids)
        scores = np.asarray(out.scores)
        self.stats.n_queries += n
        self.stats.n_batches += 1
        self.stats.n_padded += self.batch_size - n
        if pruned is not None:
            # Count only the n real queries — padded rows route too, but
            # their probes are not served traffic.
            pmask = np.asarray(pruned)[:n]
            self.stats.n_probes_total += int(pmask.size)
            self.stats.n_probes_pruned += int(pmask.sum())
            self.stats.batch_pruned_fraction.append(
                float(pmask.sum()) / max(pmask.size, 1)
            )
        for i, (rid, _) in enumerate(chunk):
            self.results[rid] = (ids[i], scores[i])
        while len(self.results) > self.max_results:
            self.results.popitem(last=False)  # evict oldest un-collected
            self.stats.n_results_evicted += 1

    def _staged_host_serving(self) -> bool:
        """Host-tier LIDER params + a backend exposing the staged search."""
        return (
            self.params is not None
            and getattr(self.search_fn, "host_stage1", None) is not None
            and getattr(
                getattr(self.params, "bank", None), "rescore_tier", "device"
            )
            == "host"
        )

    def drain(self) -> None:
        """Execute queued requests in fixed-size (padded) batches.

        Host-tier LIDER indexes (``rescore_tier="host"``) drain through the
        double-buffered fetch->rescore pipeline (:meth:`_drain_pipelined`);
        everything else executes serially.
        """
        if self._staged_host_serving():
            return self._drain_pipelined()
        while self.queue:
            chunk, n, q = self._next_batch()
            t0 = time.perf_counter()
            out, pruned = self._split_out(self._search(q))
            # Block on BOTH outputs so AQT covers all device time — blocking
            # on ids alone under-counts when scores finish later. The AQT
            # window closes HERE: D2H conversion (np.asarray) is host-side
            # transfer the paper's efficiency metric must not include.
            jax.block_until_ready((out.ids, out.scores))
            self.stats.total_time_s += time.perf_counter() - t0
            self._record_batch(chunk, n, out, pruned)

    def _drain_pipelined(self) -> None:
        """Double-buffered host-tier drain (§Tiered embedding store).

        Batch *i+1*'s compressed first pass is dispatched to the device
        *before* batch *i*'s provisional rows come back D2H and its exact
        rows are fetched from the host tier — so the host fetch (and the
        B·k'·d H2D of the fetched rows) hides behind device work for every
        batch but the last. The AQT window spans the whole pipelined drain
        (per-batch windows would double-count the overlapped regions) and
        still excludes the result D2H conversions, which are measured and
        subtracted.
        """
        t0 = time.perf_counter()
        d2h_s = 0.0
        pending = None  # the batch whose fetch + rescore are still due
        while self.queue or pending is not None:
            nxt = None
            if self.queue:
                chunk, n, q = self._next_batch()
                # Async dispatch: returns before the device finishes, so the
                # pending batch's host fetch below overlaps this compute.
                prov, pruned = self.search_fn.host_stage1(
                    self.params, q, self.k
                )
                nxt = (chunk, n, q, prov, pruned)
            if pending is not None:
                d2h_s += self._finish_host_batch(
                    pending, overlapped=nxt is not None
                )
            pending = nxt
        self.stats.total_time_s += max(time.perf_counter() - t0 - d2h_s, 0.0)

    def _finish_host_batch(self, entry, *, overlapped: bool) -> float:
        """Fetch + rescore one stage1-dispatched batch; returns the result
        D2H conversion seconds (excluded from the AQT window)."""
        chunk, n, q, prov, pruned = entry
        # Close the device wait BEFORE the fetch timer: np.asarray(prov)
        # inside host_fetch would otherwise block on the batch's first pass
        # and charge device compute to the host-fetch stat.
        jax.block_until_ready(prov)
        tf0 = time.perf_counter()
        fetched = self.search_fn.host_fetch(self.params, prov)
        self.stats.host_fetch_us += (time.perf_counter() - tf0) * 1e6
        self.stats.n_host_fetches += 1
        if overlapped:
            self.stats.n_overlapped_fetches += 1
        out = self.search_fn.host_stage2(
            self.params, jnp.asarray(fetched), prov, q, self.k
        )
        jax.block_until_ready((out.ids, out.scores))
        tc0 = time.perf_counter()
        self._record_batch(chunk, n, out, pruned)
        return time.perf_counter() - tc0

    def result(self, rid: int, *, keep: bool = False):
        """Fetch (and by default release) the answer for ``rid``.

        Popping on read is what keeps a long-running server's memory flat;
        ``keep=True`` leaves the entry in the map (it then stays until
        re-read or evicted by the ``max_results`` bound). Returns None for
        unknown/already-collected/evicted ids.
        """
        if keep:
            return self.results.get(rid)
        return self.results.pop(rid, None)

"""qwen2-72b [dense] — GQA kv=8, QKV bias [arXiv:2407.10671]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    arch_id="qwen2-72b",
    family="lm",
    config=LMConfig(
        name="qwen2-72b",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab=152_064,
        d_head=128,
        qkv_bias=True,
        dtype=jnp.bfloat16,
        # bf16 parameter storage: halves the per-layer FSDP weight gather
        # (3.5 GB -> 1.75 GB live) — fp32 Adam moments retain precision.
        param_dtype=jnp.bfloat16,
    ),
    shapes=LM_SHAPES,
    skip_shapes=("long_500k",),
    notes="Pure full attention; long_500k skipped (see DESIGN.md).",
    source="arXiv:2407.10671",
)

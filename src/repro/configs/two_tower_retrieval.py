"""two-tower-retrieval [recsys] — sampled-softmax retrieval
[Yi et al., RecSys'19 (YouTube)]. The flagship LIDER arch: retrieval_cand is
exactly the paper's workload (1 query vs 1M dense candidates)."""
from ..models.recsys import RecsysConfig
from .base import ArchSpec, RECSYS_SHAPES

ARCH = ArchSpec(
    arch_id="two-tower-retrieval",
    family="recsys",
    config=RecsysConfig(
        name="two-tower-retrieval",
        kind="two_tower",
        embed_dim=256,
        tower_dims=(1024, 512, 256),
        item_vocab=2_097_152,
        field_vocab=131_072,
        n_user_fields=4,
        n_item_fields=2,
    ),
    shapes=RECSYS_SHAPES,
    notes="retrieval_cand served brute-force (Flat) or via LIDER over the "
    "item-tower embeddings — the paper-representative hillclimb cell.",
    source="RecSys'19 (YouTube two-tower; unverified tier)",
)

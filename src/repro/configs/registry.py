"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from . import (
    din,
    gatedgcn,
    lider_msmarco,
    llama4_scout_17b_a16e,
    minitron_4b,
    qwen2_5_3b,
    qwen2_72b,
    qwen3_moe_235b_a22b,
    sasrec,
    two_tower_retrieval,
    xdeepfm,
)
from .base import ArchSpec

_ALL = (
    minitron_4b.ARCH,
    qwen2_5_3b.ARCH,
    qwen2_72b.ARCH,
    qwen3_moe_235b_a22b.ARCH,
    llama4_scout_17b_a16e.ARCH,
    gatedgcn.ARCH,
    sasrec.ARCH,
    two_tower_retrieval.ARCH,
    din.ARCH,
    xdeepfm.ARCH,
    lider_msmarco.ARCH,
)

ARCHS: dict[str, ArchSpec] = {a.arch_id: a for a in _ALL}
ASSIGNED = [a.arch_id for a in _ALL if a.arch_id != "lider-msmarco"]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]

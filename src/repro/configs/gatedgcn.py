"""gatedgcn [gnn] — 16 layers, d_hidden=70, gated aggregation
[arXiv:2003.00982 benchmarking-GNNs]. Per-shape feature/label dims are bound
at step construction (cora / reddit / ogbn-products / ZINC-like molecule)."""
from ..models.gnn import GNNConfig
from .base import ArchSpec, GNN_SHAPES

ARCH = ArchSpec(
    arch_id="gatedgcn",
    family="gnn",
    config=GNNConfig(
        name="gatedgcn",
        n_layers=16,
        d_hidden=70,
        d_feat=1433,  # overridden per shape
        n_classes=7,
    ),
    shapes=GNN_SHAPES,
    notes="LIDER inapplicable (explicit-graph message passing, no kNN "
    "retrieval stage) — built without the technique per the assignment.",
    source="arXiv:2003.00982",
)

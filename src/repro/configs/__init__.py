from .base import ArchSpec, ShapeSpec
from .registry import ARCHS, ASSIGNED, get_arch

__all__ = ["ArchSpec", "ShapeSpec", "ARCHS", "ASSIGNED", "get_arch"]

"""xdeepfm [recsys] — CIN + DNN CTR model [arXiv:1803.05170]."""
from ..models.recsys import RecsysConfig
from .base import ArchSpec, RECSYS_SHAPES

ARCH = ArchSpec(
    arch_id="xdeepfm",
    family="recsys",
    config=RecsysConfig(
        name="xdeepfm",
        kind="xdeepfm",
        embed_dim=10,
        n_sparse=39,
        cin_dims=(200, 200, 200),
        dnn_dims=(400, 400),
        field_vocab=1_048_576,  # Criteo-scale: 39 x 2^20 ~ 41M rows
    ),
    shapes=RECSYS_SHAPES,
    notes="Pointwise CTR scorer, no embedding-space kNN stage: LIDER "
    "inapplicable (DESIGN.md §Arch-applicability).",
    source="arXiv:1803.05170",
)

"""qwen2.5-3b [dense] — GQA kv=2, QKV bias [hf:Qwen/Qwen2.5 family]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    arch_id="qwen2.5-3b",
    family="lm",
    config=LMConfig(
        name="qwen2.5-3b",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        d_ff=11008,
        vocab=151_936,
        d_head=128,
        qkv_bias=True,
        dtype=jnp.bfloat16,
    ),
    shapes=LM_SHAPES,
    skip_shapes=("long_500k",),
    notes="Pure full attention; long_500k skipped (see DESIGN.md).",
    source="hf:Qwen/Qwen2.5-3B",
)

"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert,
interleaved chunked-local attention (iRoPE) [hf:meta-llama/Llama-4-Scout]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig, MoEConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    arch_id="llama4-scout-17b-a16e",
    family="lm",
    config=LMConfig(
        name="llama4-scout-17b-a16e",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,  # per-expert ff
        vocab=202_048,
        d_head=128,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1),
        window=8192,  # 3 local : 1 global chunked attention -> long_500k runs
        local_ratio=4,
        dtype=jnp.bfloat16,
    ),
    shapes=LM_SHAPES,
    notes="Long-context arch: chunked local attention (window 8192, every "
    "4th layer global) makes long_500k sub-quadratic in the local layers — "
    "the one LM arch that runs the 512k cell.",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified tier)",
)

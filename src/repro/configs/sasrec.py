"""sasrec [recsys] — self-attentive sequential recommendation
[arXiv:1808.09781]."""
from ..models.recsys import RecsysConfig
from .base import ArchSpec, RECSYS_SHAPES

ARCH = ArchSpec(
    arch_id="sasrec",
    family="recsys",
    config=RecsysConfig(
        name="sasrec",
        kind="sasrec",
        embed_dim=50,
        n_blocks=2,
        n_heads=1,
        seq_len=50,
        item_vocab=1_048_576,
    ),
    shapes=RECSYS_SHAPES,
    notes="retrieval_cand scores the last hidden state against candidate "
    "item embeddings — LIDER-servable (optional backend).",
    source="arXiv:1808.09781",
)

"""din [recsys] — deep interest network, target attention over user history
[arXiv:1706.06978]."""
from ..models.recsys import RecsysConfig
from .base import ArchSpec, RECSYS_SHAPES

ARCH = ArchSpec(
    arch_id="din",
    family="recsys",
    config=RecsysConfig(
        name="din",
        kind="din",
        embed_dim=18,
        seq_len=100,
        attn_dims=(80, 40),
        mlp_dims=(200, 80),
        item_vocab=1_048_576,
    ),
    shapes=RECSYS_SHAPES,
    source="arXiv:1706.06978",
)

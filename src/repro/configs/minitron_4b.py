"""minitron-4b [dense] — pruned Nemotron [arXiv:2407.14679; hf]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    arch_id="minitron-4b",
    family="lm",
    config=LMConfig(
        name="minitron-4b",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab=256_000,
        d_head=128,
        dtype=jnp.bfloat16,
    ),
    shapes=LM_SHAPES,
    skip_shapes=("long_500k",),
    notes="Pure full attention; long_500k (512k dense attention) is "
    "architecturally undefined — skipped per DESIGN.md §Arch-applicability.",
    source="arXiv:2407.14679",
)

"""Config schema: an architecture = model config + its input-shape set.

Every assigned architecture gets a ``<id>.py`` exporting ``ARCH``; the
registry collects them for ``--arch`` selection. Shapes carry the exact
dimensions from the assignment; ``skip_shapes`` documents cells that are
architecturally undefined (e.g. 512k dense attention) per DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval | graph_train
    dims: dict


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | retrieval
    config: Any
    shapes: tuple[ShapeSpec, ...]
    notes: str = ""
    skip_shapes: tuple[str, ...] = ()
    source: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name}")


# Shared LM shape set (seq_len x global_batch per the assignment).
LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES = (
    ShapeSpec(
        "full_graph_sm",
        "graph_train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7},
    ),
    ShapeSpec(
        "minibatch_lg",
        "graph_train",
        {
            "n_nodes": 232_965,
            "n_edges": 114_615_892,
            "batch_nodes": 1024,
            "fanout": (15, 10),
            "d_feat": 602,
            "n_classes": 41,
        },
    ),
    ShapeSpec(
        "ogb_products",
        "graph_train",
        {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100, "n_classes": 47},
    ),
    ShapeSpec(
        "molecule",
        "graph_train",
        {
            "n_nodes": 30,
            "n_edges": 64,
            "batch": 128,
            "d_feat": 28,
            "d_edge": 4,
            "regression": True,
        },
    ),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65_536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262_144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)

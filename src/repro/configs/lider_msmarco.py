"""lider-msmarco [retrieval] — the paper's own architecture: LIDER over an
MS-MARCO-scale corpus (8.8M x 768-d embeddings, paper Sec. 7.2.1 settings:
c=1024 (paper: 1000, rounded to shard evenly), c0=20, H=10, W_c=10, W_i=5)."""
import dataclasses

from ..core.lider import LiderConfig
from .base import ArchSpec, ShapeSpec


@dataclasses.dataclass(frozen=True)
class RetrievalArchConfig:
    lider: LiderConfig
    corpus_size: int
    dim: int
    capacity: int  # padded cluster capacity Lp
    k: int = 100


ARCH = ArchSpec(
    arch_id="lider-msmarco",
    family="retrieval",
    config=RetrievalArchConfig(
        lider=LiderConfig(
            n_clusters=1024,
            n_probe=20,
            n_arrays=10,
            n_arrays_centroid=10,
            key_len=16,
            key_len_centroid=10,
            n_leaves=5,
            n_leaves_centroid=10,
            r0=4,
        ),
        corpus_size=8_847_360,  # 8.8M padded to cluster grid
        dim=768,
        capacity=12_288,  # ~1.4x mean cluster size
        k=100,
    ),
    shapes=(
        ShapeSpec("serve_online", "retrieval_serve", {"batch": 256}),
        ShapeSpec("serve_bulk", "retrieval_serve", {"batch": 8192}),
        ShapeSpec("build_kmeans_step", "build", {}),
    ),
    notes="The paper's system itself, as dry-runnable cells: distributed "
    "search (cluster-parallel shard_map) and the sharded Stage-1 build step.",
    source="LIDER paper Sec. 7",
)

"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4
[hf:Qwen/Qwen3-235B-A22B family]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig, MoEConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    arch_id="qwen3-moe-235b-a22b",
    family="lm",
    config=LMConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,  # per-expert ff (assignment spec)
        vocab=151_936,
        d_head=128,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
        dtype=jnp.bfloat16,
        # 235B params: bf16 storage + fp32 Adam moments keeps ZeRO-3 state
        # within the 16 GB/chip budget (see EXPERIMENTS.md §Dry-run).
        param_dtype=jnp.bfloat16,
    ),
    shapes=LM_SHAPES,
    skip_shapes=("long_500k",),
    notes="MoE every layer (expert-parallel over the model axis); pure full "
    "attention so long_500k is skipped (see DESIGN.md).",
    source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)",
)

"""Deterministic, seedable fault injection for the serving stack.

A :class:`FaultPlan` is a schedule of :class:`FaultSpec` entries, each bound
to a named injection *site*.  Production code calls :func:`fire` at each
site; when no plan is active this is a single ``None`` check (zero cost).
When a plan is active, ``fire`` consults the plan deterministically — per-site
invocation counters plus a per-site seeded RNG — so the same plan replays the
same schedule regardless of wall-clock time or interleaving across sites.

Sites used by the repo:

================  ===========================================================
``host_fetch``    ``EmbStore.fetch`` — host gather for the rescore stage.
                  Modes: ``error`` (raise), ``delay`` (latency spike).
``host_write``    ``EmbStore.write_rows`` — fires *after* the in-place host
                  mutation, modelling an ``update_fn`` crash mid-update.
``checkpoint_write``  ``checkpoint.save`` / ``save_index`` — ``truncate``
                  corrupts a leaf file before the atomic rename;
                  ``torn_write`` additionally crashes inside the
                  ``index.old`` swap window.
``shard_search``  ``make_sharded_search`` wrapper — ``kill_shard`` marks
                  shards dead in the health mask (payload ``{"shard": i}``
                  or ``{"shards": [...]}``).
``d2h``           engine result recording — ``delay`` models a slow
                  ``__array__`` device-to-host copy.
``replica_dispatch``  ``QueryRouter`` batch dispatch onto one replica —
                  ``error``/``delay`` hit whichever replica the matching
                  call lands on; ``straggle`` (sleep) and ``fail`` (raise)
                  target one replica via payload ``{"replica": name}``.
``replica_heartbeat``  replica health probe — ``error`` is a missed
                  heartbeat (drives suspect/dead transitions).
``replica_kill``  fired once per router drain — ``kill_replica`` with
                  payload ``{"replica": name}`` hard-kills that replica:
                  in-flight batches fail over, it never rejoins routing.
================  ===========================================================

Fault modes ``error`` and ``delay`` are handled generically inside
:func:`fire` (raise :class:`InjectedFault` / ``time.sleep``).  Any other
mode is site-specific: ``fire`` returns the matching spec and the call site
interprets it.

The router dispatches batches from worker threads, so scheduling state
(per-site counters, per-site RNGs, fired log) is guarded by a lock; the
generic sleep/raise happen *outside* it, so one replica's injected
straggle never serializes another replica's dispatch.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import random
import threading
import time
from typing import Any, Optional, Tuple

# Canonical site names (import these rather than retyping strings).
HOST_FETCH = "host_fetch"
HOST_WRITE = "host_write"
CHECKPOINT_WRITE = "checkpoint_write"
SHARD_SEARCH = "shard_search"
D2H = "d2h"
REPLICA_DISPATCH = "replica_dispatch"
REPLICA_HEARTBEAT = "replica_heartbeat"
REPLICA_KILL = "replica_kill"

SITES = (
    HOST_FETCH,
    HOST_WRITE,
    CHECKPOINT_WRITE,
    SHARD_SEARCH,
    D2H,
    REPLICA_DISPATCH,
    REPLICA_HEARTBEAT,
    REPLICA_KILL,
)


class InjectedFault(RuntimeError):
    """Raised by ``mode="error"`` faults (and ``torn_write`` crashes)."""

    def __init__(self, site: str, message: str = ""):
        super().__init__(message or f"injected fault at site {site!r}")
        self.site = site


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault at one site.

    ``times`` selects specific 0-based per-site invocation indices; when
    ``None``, ``probability`` draws from the plan's per-site RNG instead.
    ``count`` caps the total number of firings of this spec.  ``delay_s``
    applies to ``mode="delay"``; ``payload`` carries site-specific data
    (e.g. which shard to kill, which checkpoint leaf to truncate).
    """

    site: str
    mode: str = "error"
    times: Optional[Tuple[int, ...]] = None
    probability: float = 0.0
    count: Optional[int] = None
    delay_s: float = 0.0
    payload: Any = None

    def to_dict(self) -> dict:
        d = {"site": self.site, "mode": self.mode}
        if self.times is not None:
            d["times"] = list(self.times)
        if self.probability:
            d["probability"] = self.probability
        if self.count is not None:
            d["count"] = self.count
        if self.delay_s:
            d["delay_s"] = self.delay_s
        if self.payload is not None:
            d["payload"] = self.payload
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        times = d.get("times")
        return cls(
            site=d["site"],
            mode=d.get("mode", "error"),
            times=None if times is None else tuple(int(t) for t in times),
            probability=float(d.get("probability", 0.0)),
            count=d.get("count"),
            delay_s=float(d.get("delay_s", 0.0)),
            payload=d.get("payload"),
        )


class FaultPlan:
    """A deterministic schedule of faults across sites.

    The plan keeps one invocation counter and one seeded RNG per site, so
    probabilistic faults replay identically for a given seed no matter how
    calls to different sites interleave.  ``fired`` records every firing as
    ``(site, call_index, mode)`` for post-hoc assertions.
    """

    def __init__(self, specs=(), *, seed: int = 0):
        self.seed = int(seed)
        self.specs = tuple(
            s if isinstance(s, FaultSpec) else FaultSpec.from_dict(s) for s in specs
        )
        self._calls: dict = {}
        self._rngs: dict = {}
        self._n_fired_by_spec = [0] * len(self.specs)
        self.fired: list = []
        # The router fires sites from dispatch worker threads; the lock
        # keeps counter/RNG/log state consistent. Generic sleep/raise run
        # outside it (see fire) so injected delays never serialize sites.
        self._lock = threading.Lock()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_json(cls, source) -> "FaultPlan":
        """Build from a dict, a JSON string, or a path to a JSON file.

        Format: ``{"seed": 0, "faults": [{"site": ..., "mode": ..., ...}]}``.
        """
        if isinstance(source, dict):
            obj = source
        else:
            text = str(source)
            if text.lstrip().startswith("{"):
                obj = json.loads(text)
            else:
                with open(text) as f:
                    obj = json.load(f)
        return cls(obj.get("faults", ()), seed=obj.get("seed", 0))

    def to_json(self) -> dict:
        return {"seed": self.seed, "faults": [s.to_dict() for s in self.specs]}

    # -- scheduling --------------------------------------------------------
    def _rng_for(self, site: str) -> random.Random:
        rng = self._rngs.get(site)
        if rng is None:
            rng = self._rngs[site] = random.Random(f"{self.seed}:{site}")
        return rng

    def fire(self, site: str):
        """Advance the site counter; raise/sleep/return per matching spec.

        Returns the first matching spec whose mode is *not* handled
        generically (for the call site to interpret), else ``None``.
        """
        pending = None
        delay_s = 0.0
        err = None
        with self._lock:
            idx = self._calls.get(site, 0)
            self._calls[site] = idx + 1
            for i, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if (
                    spec.count is not None
                    and self._n_fired_by_spec[i] >= spec.count
                ):
                    continue
                if spec.times is not None:
                    hit = idx in spec.times
                elif spec.probability > 0.0:
                    hit = self._rng_for(site).random() < spec.probability
                else:
                    hit = False
                if not hit:
                    continue
                self._n_fired_by_spec[i] += 1
                self.fired.append((site, idx, spec.mode))
                if spec.mode == "delay":
                    delay_s += spec.delay_s
                elif spec.mode == "error":
                    if err is None:
                        err = InjectedFault(
                            site, f"injected {site} fault (call {idx})"
                        )
                elif pending is None:
                    pending = spec
        if delay_s > 0.0:
            time.sleep(delay_s)
        if err is not None:
            raise err
        return pending

    @property
    def n_fired(self) -> int:
        return len(self.fired)

    def site_counts(self) -> dict:
        """Firings per site, zero-filled over every *configured* site.

        Covers the union of the canonical :data:`SITES` and any site named
        by a spec — a site that never fired reports 0 rather than being
        omitted, so chaos CI stats diffs are stable run-to-run.
        """
        with self._lock:
            counts = {site: 0 for site in SITES}
            for spec in self.specs:
                counts.setdefault(spec.site, 0)
            for site, _idx, _mode in self.fired:
                counts[site] = counts.get(site, 0) + 1
        return counts


def spec_targets(spec: Optional[FaultSpec], name: str) -> bool:
    """Does a site-specific spec target replica/shard ``name``?

    A spec with no payload (or no ``replica`` key) targets everything;
    payload ``{"replica": <name>}`` targets exactly that replica. The
    router uses this to interpret ``straggle``/``fail``/``kill_replica``
    specs returned by :func:`fire`.
    """
    if spec is None:
        return False
    payload = spec.payload
    if not isinstance(payload, dict) or "replica" not in payload:
        return True
    return payload["replica"] == name


# ---------------------------------------------------------------------------
# Module-global activation.  Call sites use the module-level ``fire`` which
# is a no-op (one ``None`` check) unless a plan is active.
# ---------------------------------------------------------------------------
_ACTIVE: Optional[FaultPlan] = None


def set_plan(plan: Optional[FaultPlan]) -> None:
    """Install ``plan`` globally (``None`` disables injection)."""
    global _ACTIVE
    _ACTIVE = plan


def get_plan() -> Optional[FaultPlan]:
    return _ACTIVE


@contextlib.contextmanager
def activate(plan: Optional[FaultPlan]):
    """Scoped activation; no-op when ``plan`` is None (keeps any global plan)."""
    global _ACTIVE
    if plan is None:
        yield None
        return
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


def fire(site: str):
    """Zero-cost hook: forwards to the active plan, if any."""
    if _ACTIVE is None:
        return None
    return _ACTIVE.fire(site)

"""JAX version-compatibility shims (single home for API drift).

The repo targets current jax, but must also run on older 0.4.x releases
(the pinned accelerator images lag upstream). Every call site that touched a
moved/renamed jax API goes through this module instead of sniffing versions
locally, so a future cleanup is one file:

- ``shard_map``: ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (old), and the replication-check
  kwarg rename (``check_vma`` vs ``check_rep``) — we always disable it.
- ``get_abstract_mesh``: ``jax.sharding.get_abstract_mesh`` (new) vs the
  thread-resources physical mesh set by the ``with mesh:`` context (old).
  Either way the return value supports ``.empty``, ``.axis_names``,
  ``.shape`` and can be handed to :func:`shard_map`.
- ``set_mesh``: ``jax.sharding.set_mesh(mesh)`` (new) vs entering the mesh
  itself as a context manager (old).
- ``make_mesh`` / ``mesh_from_devices``: construct a Mesh with
  ``AxisType.Auto`` axis types where the kwarg exists, without it otherwise
  (old jax has no AxisType and treats every axis as auto).
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def _auto_axis_kwargs(n_axes: int) -> dict:
    if _HAS_AXIS_TYPE:
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with auto axis types where supported."""
    try:
        return jax.make_mesh(tuple(shape), tuple(axes), **_auto_axis_kwargs(len(axes)))
    except TypeError:  # old jax.make_mesh: no axis_types kwarg
        return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_from_devices(devices, axes: Sequence[str]) -> jax.sharding.Mesh:
    """Mesh over an explicit (already reshaped) device array."""
    return jax.sharding.Mesh(
        np.asarray(devices), tuple(axes), **_auto_axis_kwargs(len(axes))
    )


def shard_map(body, *, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with replication checking disabled."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def get_abstract_mesh():
    """The ambient mesh, or None when there is none (old jax outside
    ``with mesh:``). Callers must handle both None and ``mesh.empty``."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    try:  # old jax: the `with mesh:` context sets the thread-resource env
        from jax.interpreters.pxla import thread_resources

        mesh = thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:  # noqa: BLE001 — no ambient-mesh concept at all
        return None


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient for sharding resolution."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh  # old jax: Mesh is itself the context manager

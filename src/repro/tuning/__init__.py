"""Offline autotuning for the serving control plane.

``tuning.pareto`` sweeps the speed-quality knobs (n_probe, r0, prune_margin,
refine) on held-out queries, maps the Pareto frontier (AQT vs recall@k /
MRR@10), and selects an operating point for a target recall — the bridge
between the paper's offline trade-off tables (benchmarks/fig5_tradeoff.py)
and a runtime operating point for ``launch.serve`` (DESIGN.md §Adaptive
speed-quality control plane).
"""
from .pareto import (
    OperatingPoint,
    SweepResult,
    default_grid,
    pareto_frontier,
    select_operating_point,
    sweep,
)

__all__ = [
    "OperatingPoint",
    "SweepResult",
    "default_grid",
    "pareto_frontier",
    "select_operating_point",
    "sweep",
]

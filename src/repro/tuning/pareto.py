"""Pareto autotuner: sweep the speed-quality knobs, pick an operating point.

LIDER's headline claim is a better speed-quality trade-off, but a fixed
``n_probe`` pays the worst-case candidate cost for every query. The adaptive
control plane (DESIGN.md §Adaptive speed-quality control plane) adds a
``prune_margin`` whose block-skipping verification kernel turns per-query
routing confidence into wall-clock savings. This module closes the loop:

1. **sweep** ``(n_probe, r0, prune_margin, refine, rescore_factor,
   block_c, block_q, sketch_factor)`` on held-out queries over a built
   index, measuring AQT,
   recall@k, MRR@10, and the pruned-probe fraction per operating point; the
   CLI additionally sweeps ``--storage-dtypes`` (one built index per dtype,
   DESIGN.md §Quantized bank) and tags every point with the bank storage it
   ran against;
2. **pareto_frontier** keeps the non-dominated points (min AQT, max recall)
   across *all* storage dtypes — a quantized bank earns frontier spots only
   by actually beating the full-precision points;
3. **select_operating_point** returns the cheapest point meeting a recall
   target — what ``launch.serve --recall-target`` feeds into the engine.

The CLI emits ``BENCH_tradeoff.json`` and exits non-zero when the frontier
contains a point strictly dominated by a fixed-``n_probe`` baseline (CI runs
``--smoke``) — the regression guard that adaptivity keeps paying for itself.

AQT accounting: on TPU the fused block-skip kernel realizes pruning savings
directly, so ``aqt_s`` is the measured wall AQT. On CPU/GPU the materialized
reference path cannot skip statically-shaped work, so ``aqt_s`` is the
device-cost model ``route + (full - route) * live_fraction`` built from two
measured walls (routing-only and full unpruned search at the same
``n_probe``) — the savings the kernel contract guarantees on the target
hardware. Both walls and the model inputs land in the JSON (``aqt_metric``
says which convention a run used), so nothing is silently extrapolated.

Usage:
    PYTHONPATH=src python -m repro.tuning.pareto [--smoke]
        [--out BENCH_tradeoff.json] [--recall-target 0.95] ...
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import lider as lider_lib
from ..core.utils import mrr_at_10, recall_at_k


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One point of the speed-quality control plane.

    ``rescore_factor`` only affects quantized (int8/int4) indexes
    (k' = factor * k provisional candidates exactly rescored); ``block_c``
    is the verification kernel's candidate block size (None -> kernel
    default); ``block_q`` switches the first pass to the cluster-major
    multi-query schedule with that many query slots per cluster tile
    (None -> per-query schedule; quantized banks only); ``sketch_factor``
    turns on the 1-bit Hamming pre-filter keeping ``sketch_factor * k'``
    survivors ahead of the code pass (None -> no pre-filter; quantized
    banks only — DESIGN.md §Binary sketch tier). All are static search
    knobs, so each distinct combo is one compile.
    """

    n_probe: int
    r0: int = 4
    prune_margin: float | None = None
    refine: bool = False
    rescore_factor: int = 4
    block_c: int | None = None
    block_q: int | None = None
    sketch_factor: int | None = None

    @property
    def adaptive(self) -> bool:
        return self.prune_margin is not None

    def search_kwargs(self) -> dict:
        return dict(
            n_probe=self.n_probe,
            r0=self.r0,
            refine=self.refine,
            prune_margin=self.prune_margin,
            rescore_factor=self.rescore_factor,
            block_c=self.block_c,
            block_q=self.block_q,
            sketch_factor=self.sketch_factor,
        )

    def label(self) -> str:
        tag = f"probe{self.n_probe}/r{self.r0}"
        if self.refine:
            tag += "/refine"
        if self.adaptive:
            tag += f"/margin{self.prune_margin:g}"
        if self.rescore_factor != 4:
            tag += f"/rescore{self.rescore_factor}"
        if self.block_c is not None:
            tag += f"/blk{self.block_c}"
        if self.block_q is not None:
            tag += f"/bq{self.block_q}"
        if self.sketch_factor is not None:
            tag += f"/sk{self.sketch_factor}"
        return tag


@dataclasses.dataclass(frozen=True)
class SweepResult:
    point: OperatingPoint
    aqt_s: float  # frontier metric (measured on TPU, modeled on CPU/GPU)
    wall_aqt_s: float  # wall AQT measured on this host, pruning applied
    wall_route_s: float  # routing-only wall AQT (model input)
    wall_full_s: float  # unpruned wall AQT at the same n_probe (model input)
    recall: float
    mrr10: float
    pruned_fraction: float
    storage_dtype: str = "float32"  # bank storage the point ran against
    # Tier axis (DESIGN.md §Tiered embedding store): which tier held the
    # rescore table, and the measured host fetch overhead per query (D2H of
    # the provisional rows + the np.take; 0.0 on the device tier).
    rescore_tier: str = "device"
    host_fetch_s: float = 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(d.pop("point"))
        d["adaptive"] = self.point.adaptive
        return d


def default_grid(
    n_probes: Sequence[int] = (2, 5, 10, 20, 40),
    margins: Sequence[float] = (0.02, 0.05, 0.1, 0.2),
    r0: int = 4,
    refine: bool = False,
    rescore_factors: Sequence[int] = (4,),
    block_cs: Sequence[int | None] = (None,),
    block_qs: Sequence[int | None] = (None,),
    sketch_factors: Sequence[int | None] = (None,),
) -> list[OperatingPoint]:
    """Fixed baselines (margin=None) plus adaptive variants per n_probe.

    ``rescore_factors``/``block_cs``/``block_qs``/``sketch_factors`` extend
    the sweep over the quantized bank's rescore depth, the kernel block
    size, the cluster-major query-tile width, and the 1-bit pre-filter's
    survivor multiple (defaults keep the grid size unchanged); every
    (n_probe, margin) combo is crossed with them.
    """
    fixed = [
        OperatingPoint(p, r0, None, refine, rf, bc, bq, sf)
        for p in n_probes
        for rf in rescore_factors
        for bc in block_cs
        for bq in block_qs
        for sf in sketch_factors
    ]
    adaptive = [
        OperatingPoint(p, r0, m, refine, rf, bc, bq, sf)
        for p in n_probes
        if p > 1  # pruning a single probe can only be a no-op
        for m in margins
        for rf in rescore_factors
        for bc in block_cs
        for bq in block_qs
        for sf in sketch_factors
    ]
    return fixed + adaptive


def _time_fn(fn, queries, repeats: int) -> float:
    """Wall seconds per query of a jitted callable (compile excluded).

    ``fn`` must return every device output it is accountable for —
    ``block_until_ready`` walks the whole pytree, and timing a search by its
    ids alone under-counts when scores finish later (the same bug the
    serving engine's AQT window guards against).
    """
    jax.block_until_ready(fn(queries))
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(queries)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / (repeats * queries.shape[0])


def sweep(
    params,
    queries: jnp.ndarray,
    gt_ids: jnp.ndarray,
    grid: Sequence[OperatingPoint],
    *,
    k: int,
    relevant: jnp.ndarray | None = None,
    repeats: int = 3,
    use_fused: bool | None = None,
) -> list[SweepResult]:
    """Measure every operating point on the held-out ``queries``.

    ``gt_ids``: exact top-k ids (Flat search) for recall@k; ``relevant``:
    optional (B,) known-relevant ids for MRR@10. Routing-only and unpruned
    walls are measured once per (n_probe, r0, refine) combo and shared by
    that combo's margin variants.
    """
    on_tpu = jax.default_backend() == "tpu"
    storage_dtype = params.bank.storage_dtype
    rescore_tier = getattr(params.bank, "rescore_tier", "device")
    base_walls: dict[tuple, tuple[float, float]] = {}
    host_fetch_walls: dict[tuple, float] = {}
    results = []
    for point in grid:
        base_key = (
            point.n_probe, point.r0, point.refine,
            point.rescore_factor, point.block_c, point.block_q,
            point.sketch_factor,
        )
        if base_key not in base_walls:
            route = jax.jit(
                lambda q, p=point: lider_lib.route_queries(
                    params, q, n_probe=p.n_probe, use_fused=use_fused,
                    block_c=p.block_c,
                )
            )
            full = lambda q, p=point: lider_lib.search_lider(
                params, q, k=k, n_probe=p.n_probe, r0=p.r0, refine=p.refine,
                use_fused=use_fused, rescore_factor=p.rescore_factor,
                block_c=p.block_c, block_q=p.block_q,
                sketch_factor=p.sketch_factor,
            )
            base_walls[base_key] = (
                _time_fn(route, queries, repeats),
                _time_fn(full, queries, repeats),
            )
        wall_route, wall_full = base_walls[base_key]

        def run(q, p=point):
            return lider_lib.search_lider(
                params, q, k=k, use_fused=use_fused, with_stats=True,
                **p.search_kwargs(),
            )
        out, pruned = run(queries)
        pruned_frac = float(np.asarray(pruned).mean())
        # A fixed point's pruned search IS the base full search (margin=None
        # masks nothing) — reuse its wall instead of timing it twice.
        wall = (
            _time_fn(lambda q: run(q)[0], queries, repeats)
            if point.adaptive
            else wall_full
        )
        if on_tpu:
            aqt = wall  # block-skip kernel realizes the savings in silicon
        else:
            live = 1.0 - pruned_frac
            aqt = wall_route + max(wall_full - wall_route, 0.0) * live
        host_fetch_s = 0.0
        if rescore_tier == "host":
            # Measured fetch overhead of the tiered pipeline at this point:
            # D2H of the provisional rows + the host-side np.take (shared
            # across margin variants — pruning doesn't change k').
            fetch_key = (
                point.n_probe, point.rescore_factor, point.block_c,
                point.block_q, point.sketch_factor,
            )
            if fetch_key not in host_fetch_walls:
                stage1_kwargs = dict(
                    k=k, n_probe=point.n_probe, r0=point.r0,
                    refine=point.refine, use_fused=use_fused,
                    rescore_factor=point.rescore_factor,
                    block_c=point.block_c,
                    sketch_factor=point.sketch_factor,
                )
                if point.block_q is None:
                    prov, _ = lider_lib.host_first_pass(
                        params, queries, **stage1_kwargs
                    )
                else:
                    prov, _ = lider_lib.host_first_pass_cluster_major(
                        params, queries, block_q=point.block_q,
                        **stage1_kwargs,
                    )
                t0 = time.perf_counter()
                for _ in range(repeats):
                    lider_lib.host_fetch(params, prov.ids)
                host_fetch_walls[fetch_key] = (
                    time.perf_counter() - t0
                ) / (repeats * queries.shape[0])
            host_fetch_s = host_fetch_walls[fetch_key]
        ids = np.asarray(out.ids)
        results.append(
            SweepResult(
                point=point,
                aqt_s=aqt,
                wall_aqt_s=wall,
                wall_route_s=wall_route,
                wall_full_s=wall_full,
                recall=float(recall_at_k(out.ids, jnp.asarray(gt_ids))),
                mrr10=mrr_at_10(ids, relevant) if relevant is not None else -1.0,
                pruned_fraction=pruned_frac,
                storage_dtype=storage_dtype,
                rescore_tier=rescore_tier,
                host_fetch_s=host_fetch_s,
            )
        )
    return results


def _dominates(a: SweepResult, b: SweepResult) -> bool:
    """a weakly better on both axes, strictly better on at least one."""
    ge = a.recall >= b.recall and a.aqt_s <= b.aqt_s
    return ge and (a.recall > b.recall or a.aqt_s < b.aqt_s)


def pareto_frontier(results: Sequence[SweepResult]) -> list[SweepResult]:
    """Non-dominated subset (min AQT, max recall), sorted by AQT.

    Computed over ALL swept points — fixed baselines included — so a frontier
    point can never be strictly dominated by a fixed-``n_probe`` config; the
    CLI re-checks that invariant explicitly as a regression guard.
    """
    front = [
        r
        for r in results
        if not any(_dominates(o, r) for o in results if o is not r)
    ]
    return sorted(front, key=lambda r: r.aqt_s)


def select_operating_point(
    results: Sequence[SweepResult],
    recall_target: float,
    load_signal: float | None = None,
) -> SweepResult:
    """Pick the operating point for one dispatch.

    Offline spelling (``load_signal=None``, the PR 3 behavior): cheapest
    point meeting the recall target; highest-recall point if none does.

    Online spelling (``load_signal`` in [0, 1], from
    ``serving.Scheduler.load_signal``): navigate the measured frontier
    instead of holding one point. Load 0 is the nominal (recall-target)
    point; rising load walks toward cheaper frontier points, reaching the
    cheapest at load 1 — the engine trades recall for latency exactly when
    queue pressure says the SLO is at risk, and every point on the walk is
    a frontier point (never a dominated config). This is the 1-D rung
    controller generalized: the ladder was "step down one rung under
    deadline pressure"; this maps a continuous load signal onto the whole
    frontier in one shot.
    """
    meeting = [r for r in results if r.recall >= recall_target]
    nominal = (
        min(meeting, key=lambda r: r.aqt_s)
        if meeting
        else max(results, key=lambda r: (r.recall, -r.aqt_s))
    )
    if load_signal is None:
        return nominal
    load = min(max(float(load_signal), 0.0), 1.0)
    # Walk: nominal first, then strictly-cheaper frontier points ordered
    # best-recall first (the same chain degradation_ladder materializes).
    chain = [nominal] + sorted(
        (r for r in pareto_frontier(results) if r.aqt_s < nominal.aqt_s),
        key=lambda r: -r.recall,
    )
    return chain[int(round(load * (len(chain) - 1)))]


def degradation_ladder(
    results: Sequence[SweepResult],
    *,
    nominal: SweepResult | None = None,
    max_rungs: int = 3,
) -> list[dict]:
    """Operating-point rungs for the serving degradation ladder
    (``serving.DegradePolicy.ladder`` — DESIGN.md §Failure model).

    Walks the Pareto frontier *downward* from the nominal point: each rung
    is strictly cheaper (lower AQT) than the last, ordered best-recall
    first, capped at ``max_rungs``. Each rung dict carries the search-knob
    overrides the engine applies (``n_probe`` / ``prune_margin`` /
    ``rescore_factor`` / ...) plus the swept ``expected_recall`` — the
    *modeled floor* chaos benchmarks gate recall-under-faults against. The
    engine itself ignores non-knob keys.
    """
    front = pareto_frontier(results)
    if nominal is None:
        nominal = front[-1] if front else None
    if nominal is None:
        return []
    cheaper = [r for r in front if r.aqt_s < nominal.aqt_s]
    cheaper.sort(key=lambda r: -r.recall)  # step down quality gradually
    if len(cheaper) > max_rungs:
        # Evenly spaced picks keep the full quality range with few rungs.
        idx = np.linspace(0, len(cheaper) - 1, max_rungs).round().astype(int)
        cheaper = [cheaper[i] for i in dict.fromkeys(idx.tolist())]
    rungs = []
    for r in cheaper:
        rung = r.point.search_kwargs()
        rung["expected_recall"] = r.recall
        rungs.append(rung)
    return rungs


def dominated_frontier_points(
    frontier: Sequence[SweepResult], results: Sequence[SweepResult]
) -> list[tuple[SweepResult, SweepResult]]:
    """(frontier point, fixed baseline that strictly dominates it) pairs.

    Non-empty means the adaptive machinery made the trade-off *worse*
    somewhere — the CI failure condition.
    """
    fixed = [r for r in results if not r.point.adaptive]
    bad = []
    for p in frontier:
        for f in fixed:
            if f.recall >= p.recall and f.aqt_s < p.aqt_s:
                bad.append((p, f))
                break
    return bad


def adaptive_beats_fixed(results: Sequence[SweepResult]) -> bool:
    """Is there an adaptive point cheaper than every fixed config of
    equal-or-better recall? (The PR's acceptance condition.)"""
    fixed = [r for r in results if not r.point.adaptive]
    for a in results:
        if not a.point.adaptive:
            continue
        rivals = [f for f in fixed if f.recall >= a.recall]
        if all(a.aqt_s < f.aqt_s for f in rivals):
            return True
    return False


def make_report(
    results: Sequence[SweepResult],
    *,
    k: int,
    n_queries: int,
    recall_target: float | None = None,
) -> dict:
    """Frontier + checks + selection over already-swept results.

    ``results`` may span several built indexes (e.g. one per storage dtype
    — the CLI's ``--storage-dtypes`` sweep); the frontier is computed over
    all of them, so a quantized bank earns its place only by actually
    beating the full-precision points somewhere on the curve.
    """
    results = list(results)
    frontier = pareto_frontier(results)
    frontier_set = {id(r) for r in frontier}
    report = {
        "backend": jax.default_backend(),
        "aqt_metric": (
            "measured_wall"
            if jax.default_backend() == "tpu"
            else "modeled_from_measured_walls"
        ),
        "k": k,
        "n_queries": n_queries,
        "storage_dtypes": sorted({r.storage_dtype for r in results}),
        "rescore_tiers": sorted({r.rescore_tier for r in results}),
        "points": [
            {**r.to_json(), "on_frontier": id(r) in frontier_set}
            for r in results
        ],
        "frontier": [r.to_json() for r in frontier],
        "checks": {
            "frontier_not_dominated_by_fixed": not dominated_frontier_points(
                frontier, results
            ),
            "adaptive_beats_fixed_at_equal_or_better_recall":
                adaptive_beats_fixed(results),
        },
    }
    if recall_target is not None:
        sel = select_operating_point(results, recall_target)
        report["recall_target"] = recall_target
        report["selected"] = {
            **sel.to_json(),
            "meets_target": sel.recall >= recall_target,
        }
    return report


def tune(
    params,
    queries,
    gt_ids,
    *,
    k: int,
    grid: Sequence[OperatingPoint] | None = None,
    recall_target: float | None = None,
    relevant=None,
    repeats: int = 3,
    use_fused: bool | None = None,
) -> dict:
    """Sweep + frontier + selection, as one JSON-ready report dict."""
    grid = list(grid) if grid is not None else default_grid()
    results = sweep(
        params, queries, gt_ids, grid, k=k, relevant=relevant,
        repeats=repeats, use_fused=use_fused,
    )
    return make_report(
        results, k=k, n_queries=int(queries.shape[0]),
        recall_target=recall_target,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus + coarse grid (CI)")
    ap.add_argument("--out", default="BENCH_tradeoff.json")
    ap.add_argument("--corpus-size", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--n-clusters", type=int, default=None,
                    help="default: corpus_size // 1000 (>= 16)")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--recall-target", type=float, default=0.9)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--n-probes", type=int, nargs="+", default=None)
    ap.add_argument("--margins", type=float, nargs="+", default=None)
    ap.add_argument(
        "--storage-dtypes", nargs="+", default=["float32"],
        choices=["float32", "bfloat16", "int8", "int4"],
        help="build + sweep one index per storage dtype; the frontier spans "
        "all of them (DESIGN.md §Quantized bank)",
    )
    ap.add_argument(
        "--rescore-factors", type=int, nargs="+", default=None,
        help="k' = factor*k exact-rescore depths to sweep (int8 banks)",
    )
    ap.add_argument(
        "--rescore-tiers", nargs="+", default=["device"],
        choices=["device", "host"],
        help="storage tiers for the int8 rescore table (DESIGN.md §Tiered "
        "embedding store): every int8 point is swept per tier, tagged with "
        "the tier and its measured host fetch overhead (host is skipped "
        "for float banks, which have no rescore table)",
    )
    ap.add_argument(
        "--block-cs", type=int, nargs="+", default=None,
        help="verification-kernel candidate block sizes to sweep",
    )
    ap.add_argument(
        "--block-qs", type=int, nargs="+", default=None,
        help="cluster-major query-tile widths to sweep IN ADDITION to the "
        "per-query schedule (quantized banks only; float banks always run "
        "per-query — DESIGN.md §Cluster-major schedule), so a cluster-major "
        "point must beat its per-query twin to reach the frontier",
    )
    ap.add_argument(
        "--sketch-factors", type=int, nargs="+", default=None,
        help="1-bit pre-filter survivor multiples (m = factor*k') to sweep "
        "IN ADDITION to the unfiltered pass (quantized banks only; float "
        "banks carry no sketches — DESIGN.md §Binary sketch tier), so a "
        "sketch point must beat its unfiltered twin to reach the frontier",
    )
    ap.add_argument("--no-check", action="store_true",
                    help="report only; do not exit non-zero when a check "
                    "fails (dominated frontier, or no adaptive point beating "
                    "the fixed baselines)")
    args = ap.parse_args()
    if args.smoke:
        args.corpus_size = min(args.corpus_size, 8_000)
        args.dim = min(args.dim, 32)
        args.queries = min(args.queries, 64)
        args.repeats = min(args.repeats, 2)

    from ..core.baselines import flat_search
    from ..data import synthetic

    corpus = synthetic.retrieval_corpus(0, args.corpus_size, args.dim)
    queries, relevant = synthetic.retrieval_queries(1, corpus, args.queries)
    gt = flat_search(corpus, queries, k=args.k)

    n_clusters = args.n_clusters or max(16, args.corpus_size // 1000)
    n_probes = tuple(args.n_probes) if args.n_probes else (
        (2, 4, 8, 16) if args.smoke else (2, 5, 10, 20, 40)
    )
    n_probes = tuple(p for p in n_probes if p <= n_clusters)
    margins = tuple(args.margins) if args.margins else (
        (0.05, 0.1, 0.2) if args.smoke else (0.02, 0.05, 0.1, 0.2)
    )
    block_cs = tuple(args.block_cs) if args.block_cs else (None,)
    block_qs = (None, *args.block_qs) if args.block_qs else (None,)
    sketch_factors = (
        (None, *args.sketch_factors) if args.sketch_factors else (None,)
    )

    # One built index per storage dtype; the frontier spans all of them
    # (and, for int8, every requested rescore tier — the tier move is a
    # pure conversion of the same bank, so points differ only in where the
    # rescore rows live).
    results = []
    for sd in args.storage_dtypes:
        cfg = lider_lib.LiderConfig(
            n_clusters=n_clusters, n_arrays=4, n_leaves=4, kmeans_iters=10,
            storage_dtype=sd,
        )
        t0 = time.time()
        params = lider_lib.build_lider(jax.random.PRNGKey(0), corpus, cfg)
        print(f"[pareto] built n={args.corpus_size} c={n_clusters} "
              f"storage={sd} in {time.time() - t0:.1f}s")
        # rescore_factor and block_q are no-ops (resp. errors) on float
        # banks — crossing them in would only duplicate identical points.
        quantized = sd in ("int8", "int4")
        if quantized:
            rescore_factors = (
                tuple(args.rescore_factors) if args.rescore_factors else (2, 4)
            )
        else:
            rescore_factors = (4,)
        grid = default_grid(
            n_probes=n_probes, margins=margins,
            rescore_factors=rescore_factors, block_cs=block_cs,
            block_qs=block_qs if quantized else (None,),
            sketch_factors=sketch_factors if quantized else (None,),
        )
        for tier in args.rescore_tiers:
            if tier == "host" and not quantized:
                continue  # float banks have no rescore table to move
            p_t = (
                params if tier == "device"
                else lider_lib.set_rescore_tier(params, "host")
            )
            results.extend(
                sweep(p_t, queries, gt.ids, grid, k=args.k,
                      relevant=relevant, repeats=args.repeats)
            )

    report = make_report(
        results, k=args.k, n_queries=int(queries.shape[0]),
        recall_target=args.recall_target,
    )
    report["build"] = {
        "corpus_size": args.corpus_size, "dim": args.dim,
        "n_clusters": n_clusters, "storage_dtypes": args.storage_dtypes,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)

    for p in report["points"]:
        star = "*" if p["on_frontier"] else " "
        kind = "adapt" if p["adaptive"] else "fixed"
        fetch = (
            f" fetch={p['host_fetch_s'] * 1e6:.1f}us"
            if p["rescore_tier"] == "host"
            else ""
        )
        print(
            f"[pareto]{star} {kind} {p['storage_dtype']:>8}"
            f"/{p['rescore_tier']} "
            f"probe={p['n_probe']:3d} "
            f"margin={p['prune_margin'] if p['prune_margin'] is not None else '-':>5} "
            f"rescore={p['rescore_factor']} "
            f"sketch={p['sketch_factor'] if p.get('sketch_factor') is not None else '-':>2} "
            f"aqt={p['aqt_s'] * 1e6:9.1f}us recall@{args.k}={p['recall']:.4f} "
            f"mrr10={p['mrr10']:.4f} pruned={p['pruned_fraction']:.2%}{fetch}"
        )
    sel = report.get("selected")
    if sel:
        sel_point = OperatingPoint(
            sel["n_probe"], sel["r0"], sel["prune_margin"], sel["refine"],
            sel["rescore_factor"], sel["block_c"], sel.get("block_q"),
            sel.get("sketch_factor"),
        )
        print(
            f"[pareto] operating point for recall>={args.recall_target}: "
            f"{sel['storage_dtype']}/{sel_point.label()} "
            f"(aqt={sel['aqt_s'] * 1e6:.1f}us recall={sel['recall']:.4f}, "
            f"meets_target={sel['meets_target']})"
        )
    checks = report["checks"]
    print(f"[pareto] checks: {checks} -> {args.out}")
    # Both checks gate CI. The frontier-domination check is a structural
    # invariant of pareto_frontier (it can only fail if the frontier code
    # regresses); the adaptive-beats-fixed check is the payoff condition —
    # without it, adaptivity regressing to "never cheaper than a fixed
    # n_probe" would still pass.
    failed = [name for name, ok in checks.items() if not ok]
    if failed and not args.no_check:
        raise SystemExit(f"speed-quality regression, failed checks: {failed}")


if __name__ == "__main__":
    main()

"""Batched retrieval serving across index backends (deliverable b, serving
driver — the paper's kind): queued requests, fixed-batch execution, AQT and
quality per backend.

    PYTHONPATH=src python examples/serve_retrieval.py [--n 30000]
"""
import argparse

import jax
import numpy as np

from repro.core import lider
from repro.core.baselines import build_ivfpq, build_mplsh, build_sklsh, flat_search
from repro.core.utils import recall_at_k
from repro.data import synthetic
from repro.serving import RetrievalEngine, make_backend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--k", type=int, default=100)
    args = ap.parse_args()

    corpus = synthetic.retrieval_corpus(0, args.n, args.dim)
    queries, _ = synthetic.retrieval_queries(1, corpus, args.queries)
    gt = flat_search(corpus, queries, k=args.k)
    rng = jax.random.PRNGKey(0)

    backends = {}
    idx = lider.build_lider(
        rng, corpus,
        lider.LiderConfig(n_clusters=max(16, args.n // 1000), n_probe=20,
                          n_arrays=10, n_leaves=5, kmeans_iters=10),
    )
    backends["lider"] = make_backend("lider", idx, n_probe=20, r0=4)
    backends["flat"] = make_backend("flat", None, corpus)
    backends["ivfpq"] = make_backend(
        "ivfpq", build_ivfpq(rng, corpus, kmeans_iters=8), n_probe=20
    )
    backends["sklsh"] = make_backend("sklsh", build_sklsh(rng, corpus), corpus)
    backends["mplsh"] = make_backend(
        "mplsh", build_mplsh(rng, corpus), corpus, n_probe=8
    )

    print(f"{'backend':8s} {'AQT(ms)':>9s} {'recall@10':>10s} {'batches':>8s}")
    for name, fn in backends.items():
        engine = RetrievalEngine(fn, batch_size=args.batch_size, k=args.k,
                                 dim=args.dim)
        engine.warmup()
        # Submit/drain/collect in windows: result() pops and the results map
        # is bounded, so collecting right after each drain keeps the engine's
        # memory flat however large --queries is.
        rows, qarr = [], np.asarray(queries)
        window = min(4096, engine.max_results)
        for start in range(0, len(qarr), window):
            rids = [engine.submit(v) for v in qarr[start:start + window]]
            engine.drain()
            rows.extend(engine.result(r)[0] for r in rids)
        got = np.stack(rows)
        rec = float(recall_at_k(got[:, :10], gt.ids[:, :10]))
        print(f"{name:8s} {engine.stats.aqt*1e3:9.3f} {rec:10.4f} "
              f"{engine.stats.n_batches:8d}")


if __name__ == "__main__":
    main()

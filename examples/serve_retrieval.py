"""Batched retrieval serving across index backends (deliverable b, serving
driver — the paper's kind), through the async scheduler front end: queued
requests from skewed tenants with Zipf-repeated queries, result caching,
dynamic batch sizing, AQT / latency / quality per backend.

    PYTHONPATH=src python examples/serve_retrieval.py [--n 30000]
"""
import argparse

import jax
import numpy as np

from repro.core import lider
from repro.core.baselines import build_ivfpq, build_mplsh, build_sklsh, flat_search
from repro.core.utils import recall_at_k
from repro.data import synthetic
from repro.serving import QueryResult, RetrievalEngine, SchedulerConfig, make_backend
from repro.serving.traffic import zipf_weights


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--arrivals", type=int, default=1024,
                    help="Zipf-skewed requests drawn from the query pool")
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--k", type=int, default=100)
    args = ap.parse_args()

    corpus = synthetic.retrieval_corpus(0, args.n, args.dim)
    queries, _ = synthetic.retrieval_queries(1, corpus, args.queries)
    gt = np.asarray(flat_search(corpus, queries, k=args.k).ids)
    rng = jax.random.PRNGKey(0)

    backends = {}
    idx = lider.build_lider(
        rng, corpus,
        lider.LiderConfig(n_clusters=max(16, args.n // 1000), n_probe=20,
                          n_arrays=10, n_leaves=5, kmeans_iters=10),
    )
    backends["lider"] = make_backend("lider", idx, n_probe=20, r0=4)
    backends["flat"] = make_backend("flat", None, corpus)
    backends["ivfpq"] = make_backend(
        "ivfpq", build_ivfpq(rng, corpus, kmeans_iters=8), n_probe=20
    )
    backends["sklsh"] = make_backend("sklsh", build_sklsh(rng, corpus), corpus)
    backends["mplsh"] = make_backend(
        "mplsh", build_mplsh(rng, corpus), corpus, n_probe=8
    )

    # The serving workload: arrivals repeat popular pool queries (Zipf) from
    # three tenants of very different submit rates — the shape the result
    # cache and the weighted-fair queues exist for.
    trng = np.random.default_rng(7)
    qarr = np.asarray(queries)
    pool_idx = trng.choice(
        len(qarr), size=args.arrivals, p=zipf_weights(len(qarr), 1.1)
    )
    tenants = trng.choice(
        ["free", "pro", "enterprise"], size=args.arrivals, p=[0.6, 0.3, 0.1]
    )

    print(f"{'backend':8s} {'AQT(ms)':>9s} {'p99(ms)':>8s} {'recall@10':>10s} "
          f"{'cache':>6s} {'batches':>8s}")
    for name, fn in backends.items():
        engine = RetrievalEngine(
            fn, batch_size=args.batch_size, k=args.k, dim=args.dim,
            scheduler=SchedulerConfig(
                dynamic_batch=True,
                min_batch=max(1, args.batch_size // 8),
                cache_size=4 * len(qarr),
                tenant_weights={"free": 1.0, "pro": 2.0, "enterprise": 4.0},
            ),
        )
        engine.warmup()  # compiles every pow2 batch size once, off-path
        # Submit/drain/collect in windows: result() pops and the results map
        # is bounded, so collecting right after each drain keeps the engine's
        # memory flat however many arrivals there are.
        rows, idx_rows = [], []
        window = min(4096, engine.max_results)
        for start in range(0, args.arrivals, window):
            sl = slice(start, min(start + window, args.arrivals))
            rids = [
                engine.submit(qarr[i], tenant=t)
                for i, t in zip(pool_idx[sl], tenants[sl])
            ]
            engine.drain()
            for i, r in zip(pool_idx[sl], rids):
                res = engine.result(r)
                if isinstance(res, QueryResult):
                    rows.append(np.asarray(res.ids))
                    idx_rows.append(i)
        got = np.stack(rows)
        rec = float(recall_at_k(got[:, :10], gt[idx_rows, :10]))
        s = engine.stats
        print(f"{name:8s} {s.aqt*1e3:9.3f} "
              f"{s.latency_quantile(0.99)*1e3:8.2f} {rec:10.4f} "
              f"{s.cache_hit_rate:6.0%} {s.n_batches:8d}")


if __name__ == "__main__":
    main()

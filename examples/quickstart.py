"""Quickstart: build a LIDER index over a corpus and search it.

    PYTHONPATH=src python examples/quickstart.py [--n 20000]

Builds the two-layer learned index (k-means -> centroids retriever ->
in-cluster retrievers), runs batched ANN queries, and reports recall@10 and
AQT against exact (Flat) search.
"""
import argparse
import time

import jax

from repro.core import lider
from repro.core.baselines import flat_search
from repro.core.utils import recall_at_k
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    print(f"corpus: {args.n} x {args.dim} clustered embeddings (synthetic)")
    corpus = synthetic.retrieval_corpus(0, args.n, args.dim)
    queries, _ = synthetic.retrieval_queries(1, corpus, args.queries)

    cfg = lider.LiderConfig(
        n_clusters=max(16, args.n // 1000),
        n_probe=20,
        n_arrays=10,
        n_leaves=5,
        kmeans_iters=10,
    )
    t0 = time.time()
    index = lider.build_lider(jax.random.PRNGKey(0), corpus, cfg)
    print(f"build: {time.time()-t0:.1f}s "
          f"(c={cfg.n_clusters}, capacity={index.capacity}, H={cfg.n_arrays})")

    search = jax.jit(
        lambda q: lider.search_lider(index, q, k=args.k, n_probe=20, r0=8)
    )
    jax.block_until_ready(search(queries).ids)  # compile
    t0 = time.time()
    out = search(queries)
    jax.block_until_ready(out.ids)
    aqt = (time.time() - t0) / args.queries
    gt = flat_search(corpus, queries, k=args.k)
    rec = float(recall_at_k(out.ids, gt.ids))
    print(f"LIDER: recall@{args.k} vs Flat = {rec:.4f}, AQT = {aqt*1e3:.3f} ms")

    refined = lider.search_lider(index, queries, k=args.k, n_probe=20, r0=8, refine=True)
    print(f"LIDER(+last-mile refine): recall@{args.k} = "
          f"{float(recall_at_k(refined.ids, gt.ids)):.4f}")


if __name__ == "__main__":
    main()

"""Fault-tolerance walkthrough (DESIGN.md §Failure model): a seeded fault
plan injects a mid-update crash and a host-fetch outage into a live serving
engine; the update rolls back bit-identically, the outage batch degrades to a
compressed-only answer instead of failing, and serving continues on the old
generation until a clean retry lands.

    PYTHONPATH=src python examples/chaos_demo.py [--n 4000]
"""
import argparse

import jax
import numpy as np

from repro import faults
from repro.core import lider, update
from repro.serving import DegradePolicy, RetrievalEngine, make_backend
from repro.data import synthetic


def serve(engine, queries):
    rids = [engine.submit(v) for v in queries]
    engine.drain()
    return [engine.result(r) for r in rids]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    corpus = synthetic.retrieval_corpus(0, args.n, args.dim)
    queries, _ = synthetic.retrieval_queries(1, corpus, 32)
    qarr = np.asarray(jax.device_get(queries))
    base, held = corpus[:-400], corpus[-400:]

    # int8 storage with the rescore table on the host: the tier with the
    # most failure surface (host fetch, in-place lifecycle writes).
    params = lider.build_lider(
        jax.random.PRNGKey(0), base,
        lider.LiderConfig(n_clusters=16, n_probe=4, storage_dtype="int8",
                          rescore_tier="host"),
    )

    # The schedule is seeded and indexed by per-site call counts, so this
    # demo replays identically every run: the first host write of the next
    # update crashes (after mutating the host table in place!), and fetch
    # calls 2..4 fail — one batch's worth of retries, exhausted.
    plan = faults.FaultPlan(
        [
            faults.FaultSpec("host_write", mode="error", times=(0,)),
            faults.FaultSpec("host_fetch", mode="error", times=(2, 3, 4)),
        ],
        seed=7,
    )
    engine = RetrievalEngine(
        make_backend("lider", None, updatable=True, n_probe=4),
        batch_size=32, k=args.k, dim=args.dim, params=params,
        policy=DegradePolicy(fetch_retries=2, fetch_backoff_s=0.001),
        fault_plan=plan,
    )
    engine.warmup()

    before = serve(engine, qarr)
    print(f"serving generation {engine.generation}: "
          f"top-1 ids {[int(r.ids[0]) for r in before[:6]]} ...")

    # --- mid-update crash -> transactional rollback -----------------------
    try:
        engine.apply_updates(lambda p: update.upsert(p, held))
    except faults.InjectedFault as e:
        print(f"update crashed mid-write ({e}) -> host tier rolled back, "
              f"rollbacks={engine.stats.n_update_rollbacks}")

    after = serve(engine, qarr)
    identical = all(
        np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
        and np.array_equal(np.asarray(a.scores), np.asarray(b.scores))
        for a, b in zip(before, after)
    )
    print(f"post-rollback serving bit-identical to pre-update: {identical}")
    assert identical, "rollback must restore the exact pre-update answers"

    # --- retry the update: the schedule has moved on, it lands cleanly ----
    engine.apply_updates(lambda p: update.upsert(p, held))
    print(f"retried update committed: generation {engine.generation}, "
          f"{engine.params.bank.store.shape} host rows")

    # --- host-fetch outage -> degraded compressed-only answer -------------
    out = serve(engine, qarr)
    n_deg = sum(r.degraded for r in out)
    print(f"fetch outage batch: {engine.stats.n_fetch_retries} retries, "
          f"{engine.stats.n_fetch_failures} exhausted -> {n_deg} queries "
          f"answered compressed-only (degraded=True), drain never raised")

    # --- and the outage is over: full-quality answers again ---------------
    out2 = serve(engine, qarr)
    print(f"next batch back to full quality: degraded="
          f"{any(r.degraded for r in out2)}, "
          f"faults fired in total: {plan.n_fired}")


if __name__ == "__main__":
    main()

"""End-to-end driver (deliverable b): train a two-tower *text* encoder with
in-batch contrastive loss, encode a passage corpus, index it with LIDER, and
serve queries — the paper's full dense-retrieval deployment.

    PYTHONPATH=src python examples/train_encoder_e2e.py              # CPU demo
    PYTHONPATH=src python examples/train_encoder_e2e.py --size 100m --steps 300

The 100m preset is the "train a ~100M model for a few hundred steps" driver
(sized for real hardware; the default preset runs in minutes on CPU).
Synthetic paired data: (query tokens, passage tokens) share a latent topic,
so retrieval quality is measurable (MRR of the true passage).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import lider
from repro.core.baselines import flat_search
from repro.core.utils import l2_normalize, recall_at_k
from repro.models import transformer as tfm
from repro.training import optimizer as opt_lib

PRESETS = {
    # ~1.6M params — CPU demo
    "tiny": tfm.LMConfig(name="enc-tiny", n_layers=2, d_model=128, n_heads=4,
                         n_kv_heads=4, d_ff=256, vocab=2048, dtype=jnp.float32),
    # ~110M params — the "100M for a few hundred steps" driver
    "100m": tfm.LMConfig(name="enc-100m", n_layers=12, d_model=768, n_heads=12,
                         n_kv_heads=12, d_ff=3072, vocab=30_522,
                         dtype=jnp.bfloat16),
}


def encode(params, cfg, tokens):
    """Mean-pool the decoder hidden states -> unit-norm embeddings."""
    hidden, _ = tfm.forward(params, cfg, tokens)
    return l2_normalize(jnp.mean(hidden.astype(jnp.float32), axis=1))


def paired_batch(key, *, batch, seq, vocab, n_topics=256):
    """Query/passage token pairs sharing a latent topic vocabulary slice."""
    kt, kq, kp = jax.random.split(key, 3)
    topic = jax.random.randint(kt, (batch, 1), 0, n_topics)
    span = max(vocab // n_topics, 4)
    q = topic * span + jax.random.randint(kq, (batch, seq), 0, span)
    p = topic * span + jax.random.randint(kp, (batch, seq), 0, span)
    return q % vocab, p % vocab


def contrastive_loss(params, cfg, batch):
    q = encode(params, cfg, batch["q"])
    p = encode(params, cfg, batch["p"])
    logits = (q @ p.T) / 0.05
    labels = jnp.arange(q.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--corpus", type=int, default=4096)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()
    cfg = PRESETS[args.size]

    params = tfm.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"encoder: {cfg.name}, {n_params/1e6:.1f}M params")

    ocfg = opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=args.steps // 10,
                                   decay_steps=args.steps)
    state = opt_lib.init_state(params)

    @jax.jit
    def step(p, s, b):
        loss, g = jax.value_and_grad(contrastive_loss)(p, cfg, b)
        p, s, m = opt_lib.apply_updates(p, g, s, ocfg)
        return p, s, loss

    t0 = time.time()
    for i in range(args.steps):
        kq, kp = paired_batch(jax.random.fold_in(jax.random.PRNGKey(1), i),
                              batch=args.batch, seq=args.seq, vocab=cfg.vocab)
        params, state, loss = step(params, state, {"q": kq, "p": kp})
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:4d}  contrastive loss {float(loss):.4f}")
    print(f"training: {time.time()-t0:.1f}s")

    # Encode the corpus (passages) and a held-out query set.
    n_pairs = args.corpus
    kq, kp = paired_batch(jax.random.PRNGKey(99), batch=n_pairs, seq=args.seq,
                          vocab=cfg.vocab)
    enc = jax.jit(lambda t: encode(params, cfg, t))
    corpus = enc(kp)
    queries = enc(kq)  # query i's relevant passage is i

    cfg_idx = lider.LiderConfig(n_clusters=max(16, n_pairs // 256), n_probe=10,
                                n_arrays=8, n_leaves=4, kmeans_iters=10)
    t0 = time.time()
    index = lider.build_lider(jax.random.PRNGKey(2), corpus, cfg_idx)
    print(f"LIDER build over {n_pairs} passages: {time.time()-t0:.1f}s")

    out = lider.search_lider(index, queries, k=args.k, n_probe=10, r0=4)
    gt = flat_search(corpus, queries, k=args.k)
    rec = float(recall_at_k(out.ids, gt.ids))
    import numpy as np
    ids = np.asarray(out.ids)
    rr = [1.0 / (list(row).index(i) + 1) if i in row else 0.0
          for i, row in enumerate(ids)]
    print(f"serving: recall@{args.k} vs Flat = {rec:.4f}, "
          f"MRR@{args.k} (true passage) = {float(np.mean(rr)):.4f}")


if __name__ == "__main__":
    main()

"""Distributed LIDER demo on 8 simulated devices: cluster-parallel sharding,
capacity dispatch, and the single all-gather merge — the exact program the
multi-pod dry-run lowers at 512 chips, executed end-to-end here.

    PYTHONPATH=src python examples/distributed_search_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import distributed, lider  # noqa: E402
from repro.core.baselines import flat_search  # noqa: E402
from repro.core.utils import l2_normalize, recall_at_k  # noqa: E402
from repro.data import synthetic  # noqa: E402


def main():
    mesh = compat.mesh_from_devices(
        np.array(jax.devices()).reshape(4, 2), ("data", "model")
    )
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"(clusters shard over 'data', queries over 'model')")

    corpus = synthetic.retrieval_corpus(0, 20_000, 64)
    queries, _ = synthetic.retrieval_queries(1, corpus, 128)
    cfg = lider.LiderConfig(n_clusters=64, n_probe=12, n_arrays=6, n_leaves=4,
                            kmeans_iters=10)
    params = lider.build_lider(jax.random.PRNGKey(0), corpus, cfg)

    sharded = distributed.shard_lider_params(mesh, params, ("data",))
    search = distributed.make_sharded_search(
        mesh, params, k=10, n_probe=12, r0=4, capacity_factor=2.0
    )
    out, dropped = search(sharded, queries)
    jax.block_until_ready(out.ids)
    t0 = time.time()
    out, dropped = search(sharded, queries)
    jax.block_until_ready(out.ids)
    dt = time.time() - t0

    ref = lider.search_lider(params, queries, k=10, n_probe=12, r0=4)
    gt = flat_search(corpus, queries, k=10)
    print(f"distributed search: {dt*1e3/128:.3f} ms/query, "
          f"capacity drops={int(dropped)}")
    print(f"recall@10 vs Flat: distributed={float(recall_at_k(out.ids, gt.ids)):.4f} "
          f"single-device={float(recall_at_k(ref.ids, gt.ids)):.4f}")
    overlap = np.mean([
        len(set(a[a >= 0]) & set(b[b >= 0])) / max(len(set(a[a >= 0])), 1)
        for a, b in zip(np.asarray(ref.ids), np.asarray(out.ids))
    ])
    print(f"distributed == single-device result overlap: {overlap:.4f}")


if __name__ == "__main__":
    main()

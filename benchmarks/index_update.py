"""Index-update benchmark: incremental upsert vs full rebuild.

Emits ``BENCH_update.json`` so the cost of keeping a live LIDER index fresh
is recorded per commit (CI runs ``--smoke``). The scenario matches the
lifecycle acceptance test: build on an 80% base corpus, then absorb the
remaining 20% either by

- **upsert** — route + append + dirty-cluster refit (``core.update``), or
- **full rebuild** — ``build_lider`` over the combined corpus (layer-1
  frozen, same centroids, same capacity),

and compare wall time, update throughput (passages/s), and recall@k against
the exact Flat search over the combined corpus. With exact routing the two
index states are slot-identical, so the recall delta should be ~0 — the
report records it so a routing/refit regression shows up as a nonzero delta,
alongside the delete path (tombstone + eager compaction, never-surfaced
check).

Usage:
    PYTHONPATH=src python -m benchmarks.index_update [--smoke]
        [--out BENCH_update.json] [--n 100000] [--dim 128] [--k 10]
        [--n-clusters 64] [--update-fraction 0.2] [--batches 4]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time


def _bench(n, dim, k, n_clusters, update_fraction, batches, queries=256):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import clustering, lider, update
    from repro.core.baselines import flat_search
    from repro.core.utils import l2_normalize, recall_at_k

    rng = jax.random.PRNGKey(0)
    kc, kx, kn, kq = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (n_clusters, dim))
    assign = jax.random.randint(kx, (n,), 0, n_clusters)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kn, (n, dim)))
    q = l2_normalize(
        x[:queries] + 0.05 * jax.random.normal(kq, (queries, dim))
    )

    n_base = int(n * (1 - update_fraction))
    base_x, new_x = x[:n_base], x[n_base:]
    cfg0 = lider.LiderConfig(
        n_clusters=n_clusters, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=10
    )
    km = clustering.kmeans(jax.random.PRNGKey(2), base_x, n_clusters, iters=10)
    # Pin the capacity both indexes need on the combined corpus (no throwaway
    # build — just the assignment histogram build_lider itself would compute).
    assignment, _ = clustering.assign_chunked(x, km.centroids)
    max_size = int(jnp.bincount(assignment, length=n_clusters).max())
    cfg = dataclasses.replace(
        cfg0, capacity=lider.padded_capacity(max_size, None, cfg0.pad_multiple)
    )

    def timed(fn):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        return out, time.perf_counter() - t0

    base, t_base = timed(
        lambda: lider.build_lider(jax.random.PRNGKey(2), base_x, cfg,
                                  centroids=km.centroids)
    )
    full, t_rebuild = timed(
        lambda: lider.build_lider(jax.random.PRNGKey(2), x, cfg,
                                  centroids=km.centroids)
    )

    # Upsert the holdout in ``batches`` slices (the serving-shaped pattern);
    # first slice pays the refit jit, so report both total and steady-state.
    slices = np.array_split(np.asarray(jax.device_get(new_x)), batches)
    up = base
    slice_times = []
    for s in slices:
        (up, _), dt = timed(lambda up=up, s=s: update.upsert(up, jnp.asarray(s)))
        slice_times.append(dt)
    t_upsert = sum(slice_times)
    t_steady = sum(slice_times[1:]) / max(len(slice_times) - 1, 1)

    gt = flat_search(x, q, k=k)
    rec = {
        name: float(recall_at_k(
            lider.search_lider(p, q, k=k, n_probe=8, r0=8).ids, gt.ids
        ))
        for name, p in (("base", base), ("upserted", up), ("rebuilt", full))
    }

    # Delete path: tombstone 1% of the corpus with eager compaction and make
    # sure nothing dead is ever surfaced.
    dead = jnp.arange(0, max(n // 100, 1), dtype=jnp.int32)
    (deleted, dstats), t_delete = timed(
        lambda: update.delete(up, dead, refit_threshold=0.0)
    )
    post = lider.search_lider(deleted, q, k=k, n_probe=8, r0=8)
    leaked = int(
        np.intersect1d(np.asarray(post.ids), np.asarray(dead)).size
    )

    n_new = int(new_x.shape[0])
    return {
        "shape": {
            "n": n, "dim": dim, "k": k, "n_clusters": n_clusters,
            "update_fraction": update_fraction, "batches": batches,
            "capacity": up.capacity,
        },
        "wall_s": {
            "build_base": t_base,
            "rebuild_full": t_rebuild,
            "upsert_total": t_upsert,
            "upsert_steady_per_batch": t_steady,
            "delete_1pct_compact": t_delete,
        },
        "upsert_throughput_per_s": n_new / max(t_upsert, 1e-9),
        # first slice pays the refit jit; steady-state is the serving number
        "upsert_throughput_steady_per_s": (n_new / batches) / max(t_steady, 1e-9),
        "upsert_speedup_vs_rebuild": t_rebuild / max(t_upsert, 1e-9),
        "recall_at_k": rec,
        "recall_delta_upsert_vs_rebuild": rec["upserted"] - rec["rebuilt"],
        "deleted_ids_leaked": leaked,
        "clusters_compacted": dstats.n_refit,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small shapes (CI)")
    ap.add_argument("--out", default="BENCH_update.json")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--n-clusters", type=int, default=64)
    ap.add_argument("--update-fraction", type=float, default=0.2)
    ap.add_argument("--batches", type=int, default=4)
    args = ap.parse_args()

    if args.smoke:
        report = _bench(n=4000, dim=64, k=10, n_clusters=32,
                        update_fraction=args.update_fraction, batches=2,
                        queries=64)
    else:
        report = _bench(n=args.n, dim=args.dim, k=args.k,
                        n_clusters=args.n_clusters,
                        update_fraction=args.update_fraction,
                        batches=args.batches)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)

    w = report["wall_s"]
    print(
        f"index update @ n={report['shape']['n']} "
        f"f={report['shape']['update_fraction']}\n"
        f"  rebuild {w['rebuild_full']:.3f}s | upsert {w['upsert_total']:.3f}s "
        f"({report['upsert_throughput_per_s']:,.0f} passages/s total, "
        f"{report['upsert_throughput_steady_per_s']:,.0f}/s steady, "
        f"{report['upsert_speedup_vs_rebuild']:.2f}x vs rebuild)\n"
        f"  recall@{report['shape']['k']}: upserted "
        f"{report['recall_at_k']['upserted']:.4f} vs rebuilt "
        f"{report['recall_at_k']['rebuilt']:.4f} "
        f"(delta {report['recall_delta_upsert_vs_rebuild']:+.4f})\n"
        f"  delete: {report['clusters_compacted']} clusters compacted, "
        f"{report['deleted_ids_leaked']} dead ids leaked\n"
        f"-> {args.out}"
    )
    if report["deleted_ids_leaked"]:
        raise SystemExit("tombstoned ids surfaced in search results")


if __name__ == "__main__":
    main()

"""Chaos serving benchmark: mixed search/update traffic under injected faults.

Emits ``BENCH_chaos.json`` so the serving fault-tolerance layer (DESIGN.md
§Failure model) is exercised and its guarantees gated per commit (CI runs
``--smoke``). The scenario:

- build an int8 host-tier LIDER index (the tier with the most failure
  surface: host fetch, in-place lifecycle writes, D2H),
- serve batched queries while upserting corpus slices between batches,
- under a **seeded** ``faults.FaultPlan``: host-fetch errors (retry path), a
  retry-exhausting error burst (degraded compressed-only answers), a
  mid-update ``host_write`` fault (transactional rollback), and D2H delay —
  plus a separate checkpoint-integrity scenario (CRC-detected truncation with
  ``restore_latest`` fallback, torn ``save_index`` swap with ``load_index``
  auto-recovery).

Every non-degraded answer is checked **bit-identical** against a direct
``search_lider`` on the engine's served params at the batch's ladder rung —
any mismatch is a *wrong-generation* result (served stale/partially-updated
state) and fails the run. Recall under faults is gated against the
degradation ladder's modeled floor: the measured recall of the worst rung
the engine may serve (including the compressed-only last resort).

Gates (non-zero exit):
- ``wrong_generation == 0``
- availability >= 0.99 (answered, not shed, within deadline)
- recall-under-faults >= ladder floor - tolerance
- rollback bit-identity; checkpoint corruption detected + recovered

Usage:
    PYTHONPATH=src python -m benchmarks.chaos_serve [--smoke]
        [--out BENCH_chaos.json] [--n 20000] [--dim 64] [--k 10]
        [--fault-plan PLAN.json] [--deadline-s 2.0]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

RECALL_TOLERANCE = 0.02  # slack under the measured worst-rung floor


def _default_plan(faults):
    """Seeded schedule hitting every injection site the serve path owns.

    ``times`` index per-site *calls inside the engine's activation window*
    (warmup and reference searches run outside it), so the schedule replays
    identically run-to-run. host_fetch call indices count retries too:
    [2, 3] is one batch retried twice then succeeding; [8, 9, 10] exhausts
    fetch_retries=2 and degrades that batch to compressed-only.
    """
    return faults.FaultPlan(
        [
            faults.FaultSpec("host_fetch", mode="error", times=(2, 3)),
            faults.FaultSpec("host_fetch", mode="error", times=(8, 9, 10)),
            faults.FaultSpec(
                "host_fetch", mode="delay", delay_s=0.005, times=(11,)
            ),
            faults.FaultSpec("host_write", mode="error", times=(0,)),
            faults.FaultSpec("d2h", mode="delay", delay_s=0.002, times=(1,)),
        ],
        seed=7,
    )


def _point_kwargs(point):
    """Ladder-rung dict -> search_lider kwargs (drop report metadata)."""
    keys = (
        "n_probe", "r0", "prune_margin", "refine", "rescore_factor", "block_c"
    )
    return {k: point[k] for k in keys if k in point}


def _reference_ids(lider, params, q, k, base_kw, point):
    """Direct (unfaulted, serial-path) answer at one operating point."""
    eff = dict(base_kw)
    if point:
        eff.update(point)
    out = lider.search_lider(params, q, k=k, **_point_kwargs(eff))
    # TopK is a NamedTuple; with_stats searches return (TopK, pruned mask).
    return out.ids if hasattr(out, "ids") else out[0].ids


def _measure_floor(lider, np, params, queries, gt_ids, k, base_kw, ladder):
    """Measured recall of every servable mode; the min is the modeled floor.

    Modes: nominal, each ladder rung, and the compressed-only last resort at
    the cheapest rung (what a retry-exhausted batch is answered with)."""
    from repro.core.utils import recall_at_k

    per_mode = {}
    for name, point in [("nominal", None)] + [
        (f"rung{i + 1}", r) for i, r in enumerate(ladder)
    ]:
        ids = _reference_ids(lider, params, queries, k, base_kw, point)
        per_mode[name] = float(recall_at_k(ids[:, :k], gt_ids[:, :k]))
    worst = dict(base_kw)
    if ladder:
        worst.update(_point_kwargs(ladder[-1]))
    prov, _ = lider.host_first_pass(
        params, queries, k=k, **_point_kwargs(worst)
    )
    comp = lider.compressed_only_topk(params.bank.gids, prov, k=k)
    per_mode["compressed_only"] = float(
        recall_at_k(comp.ids[:, :k], gt_ids[:, :k])
    )
    return per_mode, min(per_mode.values())


def _run_workload(
    *, build, queries, slices, k, batch, base_kw, ladder, deadline_s, plan
):
    """Serve ``queries`` one batch per drain, upserting ``slices`` at evenly
    spaced points; verify every non-degraded batch bit-matches the direct
    search on the engine's current params. Returns (report, answered_ids)."""
    import jax.numpy as jnp
    import numpy as np

    from repro import faults
    from repro.core import lider
    from repro.core import update as update_lib
    from repro.serving import DegradePolicy, RetrievalEngine, make_backend

    params = build()
    policy = DegradePolicy(
        ladder=tuple(ladder), deadline_s=deadline_s, fetch_retries=2,
        fetch_backoff_s=0.001,
    )
    search = make_backend("lider", None, updatable=True, **base_kw)
    engine = RetrievalEngine(
        search, batch_size=batch, k=k, dim=queries.shape[1], params=params,
        policy=policy, fault_plan=plan,
    )
    engine.warmup()  # pre-compiles every rung: no re-trace on the hot path

    n_batches = (len(queries) + batch - 1) // batch
    update_at = {
        (i + 1) * n_batches // (len(slices) + 1) for i in range(len(slices))
    }
    slices = list(slices)
    wrong_generation = 0
    rollback_identical = True
    n_update_failures = 0
    answered = np.full((len(queries), k), -1, np.int64)
    degraded_rows = np.zeros(len(queries), bool)
    probe_q = jnp.asarray(queries[:batch])  # rollback bit-identity probe

    for b in range(n_batches):
        if b in update_at and slices:
            s = slices.pop(0)
            before = np.asarray(
                _reference_ids(lider, engine.params, probe_q, k, base_kw, None)
            )
            try:
                engine.apply_updates(lambda p: update_lib.upsert(p, s))
            except faults.InjectedFault:
                # Transaction rolled the host tier back; serving must be
                # bit-identical to the pre-update generation, and the retry
                # (fault schedule has moved on) must land cleanly.
                n_update_failures += 1
                after = np.asarray(
                    _reference_ids(
                        lider, engine.params, probe_q, k, base_kw, None
                    )
                )
                rollback_identical &= bool(np.array_equal(before, after))
                engine.apply_updates(lambda p: update_lib.upsert(p, s))
        lo, hi = b * batch, min((b + 1) * batch, len(queries))
        rids = [engine.submit(q) for q in queries[lo:hi]]
        engine.drain()
        results = [engine.result(r) for r in rids]
        got = np.stack([np.asarray(r.ids) for r in results])
        answered[lo:hi] = got
        if all(r.degraded for r in results):
            degraded_rows[lo:hi] = True
            continue  # compressed-only answers are exempt from the bit-check
        # Wrong-generation check: the engine's answer must bit-match the
        # direct serial search on the params it claims to have served, at
        # the rung it claims to have served them (one batch -> one rung).
        qpad = np.zeros((batch, queries.shape[1]), np.float32)
        qpad[: hi - lo] = queries[lo:hi]
        point = (
            ladder[min(results[0].rung, len(ladder)) - 1]
            if results[0].rung > 0 and ladder
            else None
        )
        ref = np.asarray(
            _reference_ids(
                lider, engine.params, jnp.asarray(qpad), k, base_kw, point
            )
        )[: hi - lo]
        wrong_generation += int((got != ref).any(axis=1).sum())

    s = engine.stats
    submitted = s.n_queries + s.n_shed
    availability = (
        (submitted - s.n_shed - s.n_deadline_misses) / max(submitted, 1)
    )
    report = {
        "availability": availability,
        "wrong_generation": wrong_generation,
        "rollback_bit_identical": rollback_identical,
        "n_update_failures_injected": n_update_failures,
        "n_degraded": s.n_degraded,
        "n_fetch_retries": s.n_fetch_retries,
        "n_fetch_failures": s.n_fetch_failures,
        "n_update_rollbacks": s.n_update_rollbacks,
        "n_shed": s.n_shed,
        "n_deadline_misses": s.n_deadline_misses,
        "n_rung_steps": s.n_rung_steps,
        "n_faults_fired": plan.n_fired if plan is not None else 0,
        "aqt_s": s.aqt,
        "generation": engine.generation,
    }
    return report, engine.params, answered, degraded_rows


def _checkpoint_scenario(tmp):
    """Checkpoint-integrity leg: CRC detection + both recovery paths."""
    import jax
    import numpy as np

    from repro import faults
    from repro.core import lider
    from repro.core.utils import l2_normalize
    from repro.training import checkpoint

    x = l2_normalize(jax.random.normal(jax.random.PRNGKey(3), (512, 16)))
    params = lider.build_lider(
        jax.random.PRNGKey(0), x, lider.LiderConfig(n_clusters=4, n_probe=2)
    )

    # (a) Step checkpoints: truncate one leaf mid-save; restore_latest must
    # name the corrupt leaf on direct restore and fall back to the newest
    # *verified* step.
    mgr_dir = os.path.join(tmp, "steps")
    mgr = checkpoint.CheckpointManager(mgr_dir, keep=4)
    state = {"w": np.arange(32, dtype=np.float32)}
    mgr.save(1, state)
    plan = faults.FaultPlan(
        [faults.FaultSpec("checkpoint_write", mode="truncate", times=(0,))]
    )
    with faults.activate(plan):
        mgr.save(2, {"w": state["w"] + 1})
    try:
        checkpoint.restore(mgr_dir, 2, {"w": np.zeros(32, np.float32)})
        detected, leaf = False, None
    except checkpoint.CheckpointCorruptError as e:
        detected, leaf = True, e.leaf
    step, rec = mgr.restore_latest({"w": np.zeros(32, np.float32)})
    fallback_ok = step == 1 and np.array_equal(rec["w"], state["w"])

    # (b) Index checkpoint: crash inside the index.old swap window (leaf
    # truncated + process dies before cleanup); load_index must auto-recover
    # the previous generation.
    idx_dir = os.path.join(tmp, "index")
    checkpoint.save_index(idx_dir, params)
    plan2 = faults.FaultPlan(
        [faults.FaultSpec("checkpoint_write", mode="torn_write", times=(0,))]
    )
    torn = False
    try:
        with faults.activate(plan2):
            checkpoint.save_index(idx_dir, params)
    except faults.InjectedFault:
        torn = True
    loaded = checkpoint.load_index(idx_dir)
    out_a = lider.search_lider(params, x[:8], k=5, n_probe=2)
    out_b = lider.search_lider(loaded, x[:8], k=5, n_probe=2)
    torn_recovered = torn and bool(
        np.array_equal(np.asarray(out_a.ids), np.asarray(out_b.ids))
    )
    return {
        "corrupt_detected": detected,
        "corrupt_leaf": leaf,
        "restore_fallback_ok": bool(fallback_ok),
        "torn_write_recovered": torn_recovered,
    }


def _bench(n, dim, k, n_clusters, queries, batch, deadline_s, plan_path,
           sweep_ladder):
    import jax
    import numpy as np

    from repro import faults
    from repro.core import clustering, lider
    from repro.core.baselines import flat_search
    from repro.core.utils import l2_normalize, recall_at_k

    rng = jax.random.PRNGKey(0)
    kc, kx, kn, kq = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (n_clusters, dim))
    assign = jax.random.randint(kx, (n,), 0, n_clusters)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kn, (n, dim)))
    q = np.asarray(
        l2_normalize(x[:queries] + 0.05 * jax.random.normal(kq, (queries, dim)))
    )

    n_base = int(n * 0.9)  # 10% held out for the mid-traffic upserts
    base_x, new_x = x[:n_base], x[n_base:]
    cfg = lider.LiderConfig(
        n_clusters=n_clusters, n_probe=8, storage_dtype="int8",
        rescore_tier="host", rescore_factor=4,
    )
    base_kw = dict(n_probe=8, rescore_factor=4)
    build = lambda: lider.build_lider(jax.random.PRNGKey(2), base_x, cfg)

    # Degradation ladder: from a Pareto sweep (full mode) or hand-built
    # (smoke); either way each rung's recall floor is MEASURED below, so the
    # gate never trusts a stale model.
    if sweep_ladder:
        from repro.tuning import pareto as pareto_lib

        ref = build()
        gt0 = flat_search(base_x, jax.numpy.asarray(q[:128]), k=k)
        grid = pareto_lib.default_grid(
            n_probes=tuple(p for p in (2, 4, 8) if p <= n_clusters),
            margins=(0.1,), rescore_factors=(4,),
        )
        results = pareto_lib.sweep(
            ref, jax.numpy.asarray(q[:128]), gt0.ids, grid, k=k, repeats=2
        )
        ladder = pareto_lib.degradation_ladder(results, max_rungs=2)
    else:
        ladder = [
            {"n_probe": 4},
            {"n_probe": 2, "rescore_factor": 2},
        ]

    plan = (
        faults.FaultPlan.from_json(plan_path)
        if plan_path
        else _default_plan(faults)
    )

    # Fault-free reference pass: same workload, same ladder, no plan.
    n_slices = 2
    slices = np.array_split(np.asarray(jax.device_get(new_x)), n_slices)
    clean, clean_params, clean_ids, _ = _run_workload(
        build=build, queries=q, slices=slices, k=k, batch=batch,
        base_kw=base_kw, ladder=ladder, deadline_s=deadline_s, plan=None,
    )
    faulted, f_params, f_ids, f_degraded = _run_workload(
        build=build, queries=q, slices=slices, k=k, batch=batch,
        base_kw=base_kw, ladder=ladder, deadline_s=deadline_s, plan=plan,
    )

    # Recall vs the exact search over the FINAL corpus (everything upserted).
    gt = flat_search(x, jax.numpy.asarray(q), k=k)
    gt_ids = np.asarray(gt.ids)
    rec_clean = float(
        recall_at_k(jax.numpy.asarray(clean_ids), gt.ids[:, :k])
    )
    rec_fault = float(recall_at_k(jax.numpy.asarray(f_ids), gt.ids[:, :k]))
    per_mode, floor = _measure_floor(
        lider, np, f_params, jax.numpy.asarray(q), gt_ids, k, base_kw, ladder
    )

    with tempfile.TemporaryDirectory() as tmp:
        ckpt = _checkpoint_scenario(tmp)

    report = {
        "shape": {
            "n": n, "dim": dim, "k": k, "n_clusters": n_clusters,
            "queries": queries, "batch": batch, "deadline_s": deadline_s,
            "ladder": ladder, "plan_seed": plan.seed,
            "n_plan_specs": len(plan.specs),
        },
        "fault_free": clean,
        "faulted": faulted,
        "recall_fault_free": rec_clean,
        "recall_under_faults": rec_fault,
        "recall_floor_by_mode": per_mode,
        "recall_floor": floor,
        "degraded_fraction": float(f_degraded.mean()),
        "checkpoint": ckpt,
    }

    failures = []
    if faulted["wrong_generation"]:
        failures.append(
            f"{faulted['wrong_generation']} wrong-generation results"
        )
    if clean["wrong_generation"]:
        failures.append(
            f"{clean['wrong_generation']} wrong-generation results (fault-free)"
        )
    if faulted["availability"] < 0.99:
        failures.append(f"availability {faulted['availability']:.4f} < 0.99")
    if rec_fault < floor - RECALL_TOLERANCE:
        failures.append(
            f"recall under faults {rec_fault:.4f} < ladder floor "
            f"{floor:.4f} - {RECALL_TOLERANCE}"
        )
    if not faulted["rollback_bit_identical"]:
        failures.append("post-rollback serving not bit-identical")
    if faulted["n_update_rollbacks"] < 1:
        failures.append("fault plan never exercised the update rollback")
    if faulted["n_fetch_retries"] < 1:
        failures.append("fault plan never exercised the fetch retry")
    if not all(
        ckpt[f] for f in
        ("corrupt_detected", "restore_fallback_ok", "torn_write_recovered")
    ):
        failures.append(f"checkpoint integrity scenario failed: {ckpt}")
    report["failures"] = failures
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small shapes (CI)")
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--n-clusters", type=int, default=32)
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument(
        "--deadline-s", type=float, default=2.0,
        help="per-request deadline (generous: CPU CI must not miss on jit "
        "jitter — warmup pre-compiles every rung)",
    )
    ap.add_argument(
        "--fault-plan", default=None,
        help="FaultPlan JSON path/object (default: built-in seeded schedule)",
    )
    args = ap.parse_args()

    if args.smoke:
        report = _bench(
            n=4000, dim=32, k=10, n_clusters=16, queries=256,
            batch=args.batch_size, deadline_s=args.deadline_s,
            plan_path=args.fault_plan, sweep_ladder=False,
        )
    else:
        report = _bench(
            n=args.n, dim=args.dim, k=args.k, n_clusters=args.n_clusters,
            queries=args.queries, batch=args.batch_size,
            deadline_s=args.deadline_s, plan_path=args.fault_plan,
            sweep_ladder=True,
        )
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)

    fl = report["faulted"]
    print(
        f"chaos serve @ n={report['shape']['n']} "
        f"({report['shape']['n_plan_specs']} fault specs, "
        f"seed={report['shape']['plan_seed']})\n"
        f"  availability {fl['availability']:.4f} | "
        f"wrong-generation {fl['wrong_generation']} | "
        f"rollbacks {fl['n_update_rollbacks']} | "
        f"retries {fl['n_fetch_retries']} | "
        f"degraded batches->queries {fl['n_degraded']} | "
        f"shed {fl['n_shed']}\n"
        f"  recall: fault-free {report['recall_fault_free']:.4f}, "
        f"under faults {report['recall_under_faults']:.4f} "
        f"(ladder floor {report['recall_floor']:.4f})\n"
        f"  checkpoint: corrupt leaf {report['checkpoint']['corrupt_leaf']!r} "
        f"detected={report['checkpoint']['corrupt_detected']} "
        f"fallback={report['checkpoint']['restore_fallback_ok']} "
        f"torn-write-recovered="
        f"{report['checkpoint']['torn_write_recovered']}\n"
        f"-> {args.out}"
    )
    if report["failures"]:
        raise SystemExit("chaos gates failed: " + "; ".join(report["failures"]))


if __name__ == "__main__":
    main()

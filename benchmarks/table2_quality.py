"""Paper Table 2: end-to-end retrieval quality (MRR@10 + recall@10 vs Flat)
for LIDER and every baseline, across corpus scales.

Real MS MARCO / Wiki-21M embeddings are unavailable offline; corpora are
clustered synthetic embeddings at CPU-feasible scales (the paper's relative
ordering claims are what we validate — LIDER above IVFPQ/SK-LSH quality,
near OPQ, below Flat).
"""
from __future__ import annotations

import jax

from repro.core import lider
from repro.core.baselines import (
    build_ivfpq, build_mplsh, build_pq, build_sklsh, flat_search,
    ivfpq_search, mplsh_search, pq_search, sklsh_search,
)
from .common import csv_line, make_task, mrr_at_10, recall_vs_flat, time_search


def run(sizes=(20_000, 50_000), k: int = 100, verbose: bool = True) -> list[str]:
    lines = []
    for n in sizes:
        corpus, queries, rel, gt = make_task(n)
        rng = jax.random.PRNGKey(0)
        c = max(16, n // 1000)

        idx = lider.build_lider(
            rng, corpus, lider.LiderConfig(n_clusters=c, n_probe=20, n_arrays=10,
                                           n_leaves=5, kmeans_iters=10)
        )
        methods = {
            "flat": lambda q: flat_search(corpus, q, k=k),
            "lider": lambda q: lider.search_lider(idx, q, k=k, n_probe=20, r0=4),
        }
        pq = build_pq(rng, corpus, n_subspaces=8, bits=8, kmeans_iters=8)
        opq = build_pq(rng, corpus, n_subspaces=8, bits=8, kmeans_iters=8, opq_iters=1)
        ppq = build_pq(rng, corpus, n_subspaces=8, bits=8, kmeans_iters=8, pca_dim=32)
        ivf = build_ivfpq(rng, corpus, n_subspaces=8, bits=8, kmeans_iters=8)
        sk = build_sklsh(rng, corpus, n_arrays=24)
        mp = build_mplsh(rng, corpus, n_tables=24)
        methods.update(
            pq=lambda q: pq_search(pq, q, k=k),
            opq=lambda q: pq_search(opq, q, k=k),
            pca_pq=lambda q: pq_search(ppq, q, k=k),
            ivfpq=lambda q: ivfpq_search(ivf, q, k=k, n_probe=20),
            sklsh=lambda q: sklsh_search(sk, corpus, q, k=k, n_candidates=400),
            mplsh=lambda q: mplsh_search(mp, corpus, q, k=k, n_probes=8),
        )
        for name, fn in methods.items():
            out = fn(queries)
            mrr = mrr_at_10(out.ids, rel)
            rec = recall_vs_flat(out.ids, gt.ids, k=10)
            aqt = time_search(fn, queries)
            lines.append(
                csv_line(
                    f"table2/{name}/n{n}", aqt * 1e6,
                    f"mrr10={mrr:.4f};recall10={rec:.4f}",
                )
            )
            if verbose:
                print(lines[-1])
    return lines


if __name__ == "__main__":
    run()

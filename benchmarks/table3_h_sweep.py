"""Paper Table 3: effect of H (number of ESK-LSH arrays) on a standalone
core model — quality should rise with H at small time cost (the parallel
per-array expansion of Sec. 4.3)."""
from __future__ import annotations

import jax

from repro.core import core_model
from .common import csv_line, make_task, mrr_at_10, time_search


def run(n: int = 30_000, k: int = 100, hs=(4, 8, 16, 32), verbose: bool = True):
    corpus, queries, rel, _ = make_task(n)
    lines = []
    for h in hs:
        cm = core_model.build_core_model(
            jax.random.PRNGKey(1), corpus, n_arrays=h, n_leaves=10
        )
        fn = lambda q: core_model.search_core_model(cm, corpus, q, k=k, r0=4)
        aqt = time_search(fn, queries)
        mrr = mrr_at_10(fn(queries).ids, rel)
        lines.append(csv_line(f"table3/H{h}", aqt * 1e6, f"mrr10={mrr:.4f}"))
        if verbose:
            print(lines[-1])
    return lines


if __name__ == "__main__":
    run()

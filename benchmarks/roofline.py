"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run JSON and derives, per cell (single-pod mesh):

    compute term    = corrected_HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = corrected_HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

Hardware constants (TPU v5e-like): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Method note (documented in EXPERIMENTS.md): XLA cost_analysis counts a
while-loop body ONCE, so scanned programs (layer scans, grad-accum scans,
k-means chunk scans) under-report flops/bytes by the static trip count. Each
step bundle records its dominant ``loop_factor``; corrected = raw x factor.
This over-counts the (small) outside-loop portion — for layer-scan-dominated
programs the bias is <5% and it is the conservative direction for a roofline.
Collectives *inside* the scanned body are corrected by the same factor;
collectives outside (e.g. the final grad all-reduce) are over-counted by it,
so the collective term is an upper bound.

Usage: PYTHONPATH=src python -m benchmarks.roofline \
           [--dryrun experiments/dryrun.json] [--mesh single_pod_16x16]
"""
from __future__ import annotations

import argparse
import json
import math

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link

_LOOP_FACTORS_CACHE: dict[tuple[str, str], float] = {}


def loop_factor_for(arch_id: str, shape_name: str, mesh_name: str) -> float:
    """Recompute each bundle's loop factor without jax device init."""
    key = (arch_id, shape_name, mesh_name)
    if key in _LOOP_FACTORS_CACHE:
        return _LOOP_FACTORS_CACHE[key]
    from repro.configs import get_arch

    arch = get_arch(arch_id)
    n_dp = 32 if "multi" in mesh_name else 16
    f = 1.0
    if arch.family == "lm":
        cfg = arch.config
        if shape_name == "train_4k":
            ga = max(1, arch.shape(shape_name).dims["global_batch"] // n_dp)
            f = float(cfg.n_layers * ga)
        else:
            f = float(cfg.n_layers)
    elif arch.family == "gnn":
        f = float(arch.config.n_layers)
    elif arch.family == "retrieval" and shape_name == "build_kmeans_step":
        f = float(arch.config.corpus_size // n_dp // 4096)
    _LOOP_FACTORS_CACHE[key] = f
    return f


def _analytic_model_flops(arch_id: str, shape_name: str) -> float | None:
    try:
        from repro.configs import get_arch
        from repro.launch.flops import model_flops as mf

        arch = get_arch(arch_id)
        return mf(arch, arch.shape(shape_name))
    except Exception:  # noqa: BLE001 — fall back to the recorded value
        return None


def analyze(record: dict) -> dict | None:
    if record["status"] != "ok":
        return None
    lf = loop_factor_for(record["arch"], record["shape"], record["mesh"])
    flops_raw = record["cost"].get("flops", -1.0)
    bytes_raw = record["cost"].get("bytes_accessed", -1.0)
    coll = record.get("collectives", {})
    coll_bytes_raw = sum(v["bytes"] for v in coll.values())
    flops = flops_raw * lf  # per-chip (post-SPMD module)
    byts = bytes_raw * lf
    coll_bytes = coll_bytes_raw * lf
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_bound = max(terms.values())
    model_flops = _analytic_model_flops(record["arch"], record["shape"])
    if model_flops is None:
        model_flops = record.get("model_flops", 0.0)
    n_dev = record["n_devices"]
    model_flops_per_chip = model_flops / max(n_dev, 1)
    useful_ratio = model_flops_per_chip / flops if flops > 0 else float("nan")
    roofline_fraction = (
        (model_flops_per_chip / PEAK_FLOPS) / t_bound if t_bound > 0 else float("nan")
    )
    return {
        "arch": record["arch"],
        "shape": record["shape"],
        "mesh": record["mesh"],
        "loop_factor": lf,
        "flops_per_chip": flops,
        "bytes_per_chip": byts,
        "coll_bytes_per_chip": coll_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_compute_ratio": useful_ratio,
        "roofline_fraction": roofline_fraction,
        "temp_gib_per_dev": record["memory"].get("temp_bytes", 0) / 2**30,
        "collective_mix": {k: v["bytes"] for k, v in coll.items()},
    }


def format_table(rows: list[dict]) -> str:
    hdr = (
        f"{'arch':24s} {'shape':14s} {'comp(s)':>9s} {'mem(s)':>9s} "
        f"{'coll(s)':>9s} {'bound':>6s} {'useful':>7s} {'roofl%':>7s} {'GiB/dev':>8s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:14s} {r['t_compute_s']:9.3g} "
            f"{r['t_memory_s']:9.3g} {r['t_collective_s']:9.3g} "
            f"{r['bottleneck'][:6]:>6s} {r['useful_compute_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:7.1f} {r['temp_gib_per_dev']:8.2f}"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun.json")
    ap.add_argument("--mesh", default="single_pod_16x16")
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()
    with open(args.dryrun) as f:
        records = json.load(f)
    rows = [
        a
        for r in records
        if r["mesh"] == args.mesh and (a := analyze(r)) is not None
    ]
    print(format_table(rows))
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n-> {args.out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()

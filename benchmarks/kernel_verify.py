"""Verification-kernel benchmark: fused vs materialized einsum, bytes + time.

Emits ``BENCH_verify.json`` so the perf trajectory of the LIDER hot path is
recorded per commit (CI runs ``--smoke``). Two measurements:

1. **HBM traffic model** (analytic, paper-default shapes B=32, P=20, H=10,
   R=400, d=768 unless overridden) — the byte model from DESIGN.md
   §Verification-kernel, split into:

   - ``emitted_bytes``: HBM write+read traffic the verification stage *emits*
     — intermediates (candidate tensor, score matrix, dedup/sort scratch)
     plus the final top-k. This is the traffic fusion eliminates: the fused
     kernel keeps every intermediate in VMEM and emits only the (B, k)
     result. The headline ratio in this report.
   - ``total_bytes``: emitted + the compulsory traffic both paths share
     (candidate-row reads, id reads, query reads).

2. **Wall time + parity** (measured, smoke shapes) — fused kernel (interpret
   on CPU, compiled on TPU) vs the materialized reference, with an exact
   top-k id equality check.

Usage:
    PYTHONPATH=src python -m benchmarks.kernel_verify [--smoke]
        [--out BENCH_verify.json] [--b 32] [--p 20] [--h-arrays 10]
        [--r 400] [--d 768] [--k 100] [--dtype float32|bfloat16]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def traffic_model(
    b: int, c: int, d: int, k: int, elem_bytes: int
) -> dict[str, dict[str, float]]:
    """HBM bytes per batch for both verification paths (DESIGN.md model).

    ``c`` is candidates per query (P*H*R), ``elem_bytes`` the embedding
    storage dtype width. Id/score words are 4 B; top-k rows are 8 B (id +
    score). ``DEDUP_PASSES`` approximates the argsort + take_along_axis +
    top_k round-trips dedup_topk makes over the (B, C) id/score arrays.
    """
    DEDUP_PASSES = 10  # argsort r/w + 3x take_along_axis r/w + top_k read
    bc = b * c
    bcd = b * c * d

    gather_read = bcd * elem_bytes  # candidate rows HBM->chip (both paths)
    ids_read = bc * 4
    query_read = b * d * elem_bytes
    topk_write = b * k * 8

    cand_write = bcd * elem_bytes  # (B, C, d) materialization ...
    cand_read = bcd * elem_bytes  # ... re-read by the einsum
    score_write = bc * 4  # (B, C) score matrix ...
    score_read = bc * 4  # ... re-read by dedup/top-k
    dedup_bytes = DEDUP_PASSES * bc * 4

    unfused_emitted = (
        cand_write + cand_read + score_write + score_read + dedup_bytes + topk_write
    )
    fused_emitted = topk_write  # everything else stays in VMEM
    shared = gather_read + ids_read + query_read
    return {
        "unfused": {
            "emitted_bytes": unfused_emitted,
            "total_bytes": unfused_emitted + shared,
        },
        "fused": {
            "emitted_bytes": fused_emitted,
            "total_bytes": fused_emitted + shared,
        },
    }


def _measure(b, c, n, d, k, dtype_name, block_c, iters=3):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import fused_verify, ref

    dtype = jnp.bfloat16 if dtype_name == "bfloat16" else jnp.float32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    embs = jax.random.normal(k1, (n, d), dtype)
    ids = jax.random.randint(k2, (b, c), -1, n)
    q = jax.random.normal(k3, (b, d), dtype)

    def run_fused():
        return fused_verify(embs, ids, q, k=k, block_c=block_c)

    def run_unfused():
        return ref.verify_topk_ref(embs, ids, q, k=k)

    out = {}
    ids_by_path = {}
    for name, fn in (("fused", run_fused), ("unfused", run_unfused)):
        top_ids, top_sc = jax.block_until_ready(fn())  # compile/warm
        t0 = time.perf_counter()
        for _ in range(iters):
            top_ids, top_sc = fn()
        jax.block_until_ready((top_ids, top_sc))
        out[f"wall_s_{name}"] = (time.perf_counter() - t0) / iters
        ids_by_path[name] = np.asarray(top_ids)
    out["ids_match"] = bool(
        (ids_by_path["fused"] == ids_by_path["unfused"]).all()
    )
    out["shape"] = {"B": b, "C": c, "N": n, "d": d, "k": k, "dtype": dtype_name}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small measured shapes (CI); model stays at paper defaults")
    ap.add_argument("--out", default="BENCH_verify.json")
    ap.add_argument("--b", type=int, default=32)
    ap.add_argument("--p", type=int, default=20)
    ap.add_argument("--h-arrays", type=int, default=10)
    ap.add_argument("--r", type=int, default=400)
    ap.add_argument("--d", type=int, default=768)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--dtype", default="float32", choices=["float32", "bfloat16"])
    args = ap.parse_args()

    elem = 2 if args.dtype == "bfloat16" else 4
    c = args.p * args.h_arrays * args.r
    model = traffic_model(args.b, c, args.d, args.k, elem)
    emitted_ratio = (
        model["unfused"]["emitted_bytes"] / model["fused"]["emitted_bytes"]
    )
    total_ratio = model["unfused"]["total_bytes"] / model["fused"]["total_bytes"]

    import jax

    full_measure = not args.smoke and jax.default_backend() == "tpu"
    if not args.smoke and not full_measure:
        print(
            "warning: paper-shape measurement needs a TPU (interpret-mode "
            "Pallas at B=32, C=80000 would take hours on CPU); measuring at "
            "smoke shapes instead — the traffic model above is unaffected",
            file=sys.stderr,
        )
    if full_measure:
        measured = _measure(b=args.b, c=c, n=200_000, d=args.d, k=args.k,
                            dtype_name=args.dtype, block_c=256)
    else:
        measured = _measure(b=4, c=608, n=4096, d=64, k=10,
                            dtype_name=args.dtype, block_c=128)

    report = {
        "paper_shape": {
            "B": args.b, "P": args.p, "H": args.h_arrays, "R": args.r,
            "C": c, "d": args.d, "k": args.k, "dtype": args.dtype,
        },
        "traffic_model": model,
        "hbm_bytes_ratio_emitted": emitted_ratio,
        "hbm_bytes_ratio_total": total_ratio,
        "measured": measured,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)

    u, fu = model["unfused"], model["fused"]
    print(
        f"verification @ B={args.b} C={c} d={args.d} k={args.k} ({args.dtype})\n"
        f"  unfused emits {u['emitted_bytes']/2**30:8.2f} GiB "
        f"(total {u['total_bytes']/2**30:.2f} GiB)\n"
        f"  fused   emits {fu['emitted_bytes']/2**30:8.2f} GiB "
        f"(total {fu['total_bytes']/2**30:.2f} GiB)\n"
        f"  fused moves {emitted_ratio:,.0f}x fewer emitted HBM bytes "
        f"({total_ratio:.2f}x total)\n"
        f"  measured {measured['shape']}: "
        f"fused {measured['wall_s_fused']*1e3:.2f} ms, "
        f"unfused {measured['wall_s_unfused']*1e3:.2f} ms, "
        f"ids_match={measured['ids_match']}\n"
        f"-> {args.out}"
    )
    if not measured["ids_match"]:
        raise SystemExit("fused/unfused top-k ids diverged")


if __name__ == "__main__":
    main()

"""Verification-kernel benchmark: fused vs materialized, bytes + time, per
storage dtype.

Emits ``BENCH_verify.json`` so the perf trajectory of the LIDER hot path is
recorded per commit (CI runs ``--smoke``). Three measurements:

1. **HBM traffic model** (analytic, paper-default shapes B=32, P=20, H=10,
   R=400, d=768 unless overridden) — the byte model from DESIGN.md
   §Verification-kernel, evaluated for every storage dtype
   (f32 / bf16 / int8+rescore), split into:

   - ``emitted_bytes``: HBM write+read traffic the verification stage *emits*
     — intermediates (candidate tensor, score matrix, dedup/sort scratch,
     and on int8 the gathered scale array + provisional top-k') plus the
     final top-k. This is the traffic fusion eliminates.
   - ``total_bytes``: emitted + the compulsory traffic both paths share
     (candidate-row reads at the storage width — the term quantization
     shrinks — plus id/query reads and, on int8, the exact-rescore gather).

2. **Wall time + parity** (measured, smoke shapes) — fused kernel (interpret
   on CPU, compiled on TPU) vs the materialized reference at every storage
   dtype, with an exact top-k id equality check, plus the measured rescore
   overhead of the int8 second stage.

3. **Recall floor** (measured, smoke shapes) — recall@k of the quantized
   (int8 / packed int4) +rescore two-stage verification against exact f32
   over the same candidates, and the same for bf16. CI fails when any
   parity check is false, when int8+rescore recall drops below bf16
   recall − eps, or when int4+rescore drops below int8+rescore − eps.

4. **Cluster-major schedule** (measured, smoke shapes) — bit parity of the
   cluster-major multi-query loop order against the per-query one, plus the
   measured cluster-tile DMA-sharing ratio under Zipf-skewed probe traffic
   (CI gates ratio > 1.5 and the modeled int4 first-pass total ≥ 1.7x
   below int8 at the paper shape).

5. **Binary-sketch tier** (modeled + measured) — the traffic model re-run
   with the 1-bit Hamming pre-filter in front of the quantized pass
   (CI gates the modeled sketch+int4 total ≥ 3x below plain int4 at the
   paper shape), exact (ids, scores) parity of ``sketch_prefilter``
   against the natural-order oracle, and the sketch->int4->rescore
   recall floor vs plain int4+rescore (same eps).

Usage:
    PYTHONPATH=src python -m benchmarks.kernel_verify [--smoke]
        [--out BENCH_verify.json] [--b 32] [--p 20] [--h-arrays 10]
        [--r 400] [--d 768] [--k 100] [--rescore-factor 4]
        [--storage-dtypes float32 bfloat16 int8 int4] [--block-q 8]
        [--sketch-factor 4]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

STORAGE_BYTES = {"float32": 4, "bfloat16": 2, "int8": 1, "int4": 0.5}
QUANTIZED_DTYPES = ("int8", "int4")
RECALL_EPS = 0.02  # int8+rescore may trail bf16 recall by at most this
# (and int4+rescore may trail int8+rescore by the same eps)
# Modeled int4 first-pass total traffic must be at least this far below int8
# at the paper shape (the sub-int8 floor's acceptance gate).
INT4_VS_INT8_TOTAL_MIN = 1.7
# Measured cluster-tile DMA-sharing ratio of the cluster-major schedule vs
# the per-query schedule under Zipf-skewed probe traffic.
SHARED_DMA_RATIO_MIN = 1.5
# Modeled sketch+int4 first-pass total traffic must be at least this far
# below plain int4 at the paper shape (the 1-bit tier's acceptance gate).
SKETCH_VS_INT4_TOTAL_MIN = 3.0
# int8+host device-resident embedding-store bytes must stay at or below
# this fraction of the f32 store (the tier dimension's CI gate; actual
# ratio at d=768 is (d+4)/(4d) ~ 0.25 — DESIGN.md §Tiered embedding store).
HOST_TIER_DEVICE_BYTES_MAX_VS_F32 = 0.45
MSMARCO_N = 8_847_360  # paper corpus (lider-msmarco arch config)


def storage_tier_model(
    n: int, d: int, storage_dtype: str, rescore_tier: str = "device"
) -> dict[str, float]:
    """Embedding-store bytes by tier for an ``n x d`` corpus.

    Codes at the storage width, plus (quantized dtypes only) the per-row
    f32 scales and the full-precision rescore table — device-resident on
    the "device" tier, host RAM on the "host" tier (DESIGN.md §Tiered
    embedding store). The learned-index arrays (sorted keys/positions, RMI
    fits) are tier-independent and excluded, matching the paper's
    index-memory convention.
    """
    s = STORAGE_BYTES[storage_dtype]
    device = float(n * d * s)
    host = 0.0
    if storage_dtype in QUANTIZED_DTYPES:
        device += n * 4  # per-row symmetric scales
        if rescore_tier == "device":
            device += n * d * 4
        else:
            host = float(n * d * 4)
    return {"device_bytes": device, "host_bytes": host}


def traffic_model(
    b: int,
    c: int,
    d: int,
    k: int,
    storage_dtype: str,
    rescore_factor: int = 4,
    sketch_factor: int | None = None,
) -> dict[str, dict[str, float]]:
    """HBM bytes per batch for both verification paths (DESIGN.md model).

    ``c`` is candidates per query (P*H*R). Id/score words are 4 B; top-k
    rows are 8 B (id + score). ``DEDUP_PASSES`` approximates the argsort +
    take_along_axis + top_k round-trips dedup_topk makes over the (B, C)
    id/score arrays. For quantized dtypes the model adds the per-candidate
    scale array (one gather read + one write + one kernel read), the
    provisional top-k' round-trip, and the exact-rescore gather of k'
    full-precision rows — k'/C (~1% at paper shape) of the first-pass row
    traffic. int4 halves only the candidate-row term (codes are packed two
    per byte; scales, ids, and the f32 rescore gather are width-independent),
    which is exactly why its total-traffic win over int8 lands below 2x.

    Queries are never stored, so the query read is width-INDEPENDENT of the
    storage dtype on the quantized paths: the kernel reads int8 query codes
    at both int8 and int4 table widths (only the table side unpacks
    nibbles) plus one f32 scale per query.

    ``sketch_factor`` (quantized dtypes only; DESIGN.md §Binary sketch
    tier) models the 1-bit pre-filter pass: the packed sketch rows stream
    at ceil(d/32) uint32 words per candidate, the survivor (row, score)
    set round-trips once, and every downstream per-candidate term — the
    code-row gather, the scale array, the score/dedup scratch — shrinks
    from C to ``m = min(sketch_factor*k', C)`` survivors.
    """
    DEDUP_PASSES = 10  # argsort r/w + 3x take_along_axis r/w + top_k read
    s = STORAGE_BYTES[storage_dtype]
    quantized = storage_dtype in QUANTIZED_DTYPES
    bc = b * c

    ids_read = bc * 4
    if quantized:
        # int8 query codes at both quantized widths + one f32 scale per row.
        query_read = b * (d + 4)
    else:
        query_read = b * d * s
    topk_write = b * k * 8

    # The candidate count the code pass actually touches: all C, or the
    # sketch pass's m survivors.
    m = c
    sketch_shared = 0.0
    sketch_emitted = 0.0
    if quantized and sketch_factor is not None:
        kp = min(rescore_factor * k, c)
        m = min(sketch_factor * kp, c)
        w_bytes = -(-d // 32) * 4  # packed words per row
        # 1-bit candidate rows + the query sketches (compulsory reads of
        # the pre-filter pass; it shares the bc id read issued above)
        sketch_shared += bc * w_bytes + b * w_bytes
        # survivor (row, negated-Hamming) round-trip between the passes
        sketch_emitted += 2 * b * m * 8

    bm = b * m
    bmd = b * m * d
    gather_read = bmd * s  # candidate code rows HBM->chip (both paths)

    quant_extra_emitted = 0.0
    quant_extra_shared = 0.0
    if quantized:
        kp = min(rescore_factor * k, c)
        # gathered (B, m) f32 combined-scale array: scale-table read + write
        # + kernel read
        quant_extra_emitted += 3 * bm * 4
        # provisional (B, k') top-k write + read between the passes
        quant_extra_emitted += 2 * b * kp * 8
        # exact-rescore gather: k' full-precision rows + their ids
        quant_extra_shared += b * kp * (d * 4 + 4)

    cand_write = bmd * s  # (B, m, d) materialization ...
    cand_read = bmd * s  # ... re-read by the einsum
    score_write = bm * 4  # (B, m) score matrix ...
    score_read = bm * 4  # ... re-read by dedup/top-k
    dedup_bytes = DEDUP_PASSES * bm * 4

    unfused_emitted = (
        cand_write + cand_read + score_write + score_read + dedup_bytes
        + topk_write + quant_extra_emitted + sketch_emitted
    )
    fused_emitted = topk_write + quant_extra_emitted + sketch_emitted
    shared = (
        gather_read + ids_read + query_read + quant_extra_shared
        + sketch_shared
    )
    return {
        "unfused": {
            "emitted_bytes": unfused_emitted,
            "total_bytes": unfused_emitted + shared,
        },
        "fused": {
            "emitted_bytes": fused_emitted,
            "total_bytes": fused_emitted + shared,
        },
    }


def _time(fn, iters=3):
    import jax

    out = jax.block_until_ready(fn())  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _measure(b, c, n, d, k, dtype_name, block_c, rescore_factor, iters=3):
    """Fused-vs-oracle wall + parity for one storage dtype (+ the quantized
    rescore stage's overhead, measured as its own fused pass)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import fused_verify, ref
    from repro.kernels.quant import quantize_rows, quantize_rows_int4

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    embs_f = jax.random.normal(k1, (n, d))
    ids = jax.random.randint(k2, (b, c), -1, n)
    q = jax.random.normal(k3, (b, d))

    scales = None
    code_dtype = "int8"
    if dtype_name == "int8":
        table, scales = quantize_rows(embs_f)
    elif dtype_name == "int4":
        table, scales = quantize_rows_int4(embs_f)
        code_dtype = "int4"
    else:
        table = embs_f.astype(jnp.dtype(dtype_name))

    def run_fused():
        return fused_verify(table, ids, q, k=k, scales=scales,
                            block_c=block_c, code_dtype=code_dtype)

    def run_unfused():
        return ref.verify_topk_ref(table, ids, q, k=k, scales=scales,
                                   code_dtype=code_dtype)

    out = {}
    ids_by_path = {}
    for name, fn in (("fused", run_fused), ("unfused", run_unfused)):
        out[f"wall_s_{name}"] = _time(fn, iters)
        ids_by_path[name] = np.asarray(fn()[0])
    out["ids_match"] = bool(
        (ids_by_path["fused"] == ids_by_path["unfused"]).all()
    )
    if dtype_name in QUANTIZED_DTYPES:
        # The exact second stage: rescore the provisional top-k' rows from
        # the full-precision table (k'/c the gather of the first pass). The
        # provisional set comes from a k'-deep first pass — the pipeline
        # lider._verify_bank_rows actually runs — not from truncating the
        # k-deep parity run above.
        kp = min(rescore_factor * k, c)

        def run_first_kp():
            return fused_verify(table, ids, q, k=kp, scales=scales,
                                block_c=block_c, code_dtype=code_dtype)

        prov = run_first_kp()[0]

        def run_rescore():
            return fused_verify(
                embs_f, jnp.maximum(prov, 0), q, k=k, out_ids=prov,
                block_c=block_c,
            )

        # Overhead relative to the k'-deep first pass the real pipeline
        # (lider._verify_bank_rows) runs — not the k-deep parity run above,
        # whose smaller top-k accumulator would inflate the fraction.
        wall_first = _time(run_first_kp, iters)
        wall = _time(run_rescore, iters)
        out["wall_s_fused_kp"] = wall_first
        out["wall_s_rescore"] = wall
        out["rescore_overhead_frac"] = wall / max(wall_first, 1e-12)
    out["shape"] = {"B": b, "C": c, "N": n, "d": d, "k": k, "dtype": dtype_name}
    return out


def _measure_host_tier(
    b, c, n, d, k, block_c, rescore_factor, iters=3, code_dtype="int8"
):
    """The tiered search's staged rescore vs the device-resident one: bit
    parity of (ids, scores) plus the measured host fetch (D2H of the
    provisional rows + the np.take) and staged-rescore walls."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.ops import verify_topk_op
    from repro.kernels.quant import quantize_rows, quantize_rows_int4

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    embs_f = jax.random.normal(k1, (n, d))
    ids = jax.random.randint(k2, (b, c), -1, n)
    q = jax.random.normal(k3, (b, d))
    if code_dtype == "int4":
        table, scales = quantize_rows_int4(embs_f)
    else:
        table, scales = quantize_rows(embs_f)
    host_table = np.ascontiguousarray(np.asarray(embs_f, np.float32))
    kp = min(rescore_factor * k, c)

    def first_pass():
        return verify_topk_op(table, ids, q, k=kp, scales=scales,
                              block_c=block_c, code_dtype=code_dtype)

    prov = first_pass()[0]

    def device_rescore():
        return verify_topk_op(
            embs_f, jnp.maximum(prov, 0), q, k=k, out_ids=prov,
            block_c=block_c,
        )

    def host_fetch():
        rows = np.asarray(prov)  # D2H of the provisional rows
        return host_table.take(np.maximum(rows, 0).reshape(-1), axis=0
                               ).reshape(b, kp, d)

    fetched = jnp.asarray(host_fetch())  # H2D of only B*k'*d floats
    row_ids = jnp.arange(b * kp, dtype=jnp.int32).reshape(b, kp)

    def host_rescore():
        return verify_topk_op(
            fetched.reshape(b * kp, d), row_ids, q, k=k, out_ids=prov,
            block_c=block_c,
        )

    di, ds = device_rescore()
    hi, hs = host_rescore()
    out = {
        "ids_match": bool((np.asarray(di) == np.asarray(hi)).all()),
        "scores_match": bool((np.asarray(ds) == np.asarray(hs)).all()),
        "wall_s_device_rescore": _time(device_rescore, iters),
        "wall_s_host_rescore": _time(host_rescore, iters),
        "host_fetch_us": _time(host_fetch, iters) * 1e6,
        "h2d_floats": b * kp * d,
        "shape": {"B": b, "C": c, "N": n, "d": d, "k": k, "kp": kp},
    }
    return out


def _measure_sketch(b, c, n, d, k, block_c, iters=3):
    """1-bit Hamming pre-filter kernel vs the natural-order oracle: exact
    (ids, scores) parity plus walls (DESIGN.md §Binary sketch tier)."""
    import jax
    import numpy as np

    from repro.kernels import ref
    from repro.kernels.fused_verify import sketch_prefilter
    from repro.kernels.quant import sketch_rows

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    embs_f = jax.random.normal(k1, (n, d))
    ids = jax.random.randint(k2, (b, c), -1, n)
    q = jax.random.normal(k3, (b, d))
    table = sketch_rows(embs_f)

    def run_kernel():
        return sketch_prefilter(table, ids, q, k=k, block_c=block_c)

    def run_ref():
        return ref.sketch_topk_ref(table, ids, q, k=k)

    gi, gs = run_kernel()
    wi, ws = run_ref()
    return {
        "ids_match": bool((np.asarray(gi) == np.asarray(wi)).all()),
        "scores_match": bool((np.asarray(gs) == np.asarray(ws)).all()),
        "wall_s_kernel": _time(run_kernel, iters),
        "wall_s_ref": _time(run_ref, iters),
        "shape": {"B": b, "C": c, "N": n, "d": d, "k": k},
    }


def _measure_sketch_e2e(n, d, b, k, n_clusters):
    """Covering-sketch end-to-end parity: with ``sketch_factor`` large
    enough that every routed candidate survives the pre-filter, the full
    search must return (ids, scores) bit-identical to the unfiltered int4
    path (the tier's correctness contract, DESIGN.md §Binary sketch tier)."""
    import jax
    import numpy as np

    from repro.core import lider as lider_lib
    from repro.data import synthetic

    corpus = synthetic.retrieval_corpus(3, n, d)
    queries, _ = synthetic.retrieval_queries(4, corpus, b)
    cfg = lider_lib.LiderConfig(
        n_clusters=n_clusters, n_arrays=4, n_leaves=4, kmeans_iters=5,
        storage_dtype="int4",
    )
    params = lider_lib.build_lider(jax.random.PRNGKey(0), corpus, cfg)
    plain = lider_lib.search_lider(params, queries, k=k, n_probe=4)
    filt = lider_lib.search_lider(
        params, queries, k=k, n_probe=4, sketch_factor=10**6
    )
    return {
        "ids_match": bool(
            (np.asarray(plain.ids) == np.asarray(filt.ids)).all()
        ),
        "scores_match": bool(
            (np.asarray(plain.scores) == np.asarray(filt.scores)).all()
        ),
        "shape": {"N": n, "d": d, "B": b, "k": k, "clusters": n_clusters},
    }


def _recall_floor(n, d, b, k, rescore_factor):
    """Recall@k vs exact f32 of one-shot verification over the same
    candidate set, per storage dtype (the quality side of the sweep)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.utils import l2_normalize, recall_at_k
    from repro.kernels.ops import verify_topk_op
    from repro.kernels.quant import quantize_rows, quantize_rows_int4

    k1, k2 = jax.random.split(jax.random.PRNGKey(1), 2)
    x = l2_normalize(jax.random.normal(k1, (n, d)))
    q = l2_normalize(x[:b] + 0.05 * jax.random.normal(k2, (b, d)))
    cand = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (b, n))
    gt_ids, _ = verify_topk_op(x, cand, q, k=k, use_pallas=False)

    out = {}
    for dtype_name in ("bfloat16", "int8", "int4"):
        if dtype_name in QUANTIZED_DTYPES:
            if dtype_name == "int4":
                codes, scales = quantize_rows_int4(x)
            else:
                codes, scales = quantize_rows(x)
            kp = min(rescore_factor * k, n)
            prov, _ = verify_topk_op(
                codes, cand, q, k=kp, scales=scales, use_pallas=False,
                code_dtype=dtype_name,
            )
            ids, _ = verify_topk_op(
                x, jnp.maximum(prov, 0), q, k=k, out_ids=prov, use_pallas=False
            )
        else:
            ids, _ = verify_topk_op(
                x.astype(jnp.bfloat16), cand, q, k=k, use_pallas=False
            )
        out[dtype_name] = float(np.asarray(recall_at_k(ids, gt_ids)))
    return out


def _recall_floor_sketch(n, d, b, k, rescore_factor, sketch_factor):
    """sketch->int4->rescore recall@k vs plain int4->rescore, same data.

    The corpus plants ``n // b`` genuinely similar rows (cos ~0.8) around
    each query — the neighbor regime dense-retrieval corpora put the true
    top-k in. A pure random-Gaussian corpus would put the "true" top-k at
    cos ~ sqrt(2 ln n / d), which no 1-bit sign sketch can separate from
    the bulk — the failure mode DESIGN.md §Binary sketch tier documents
    under "when the pre-filter loses", not a serving regression — so the
    quality gate is measured where the tier is actually operable.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.utils import l2_normalize, recall_at_k
    from repro.kernels.ops import sketch_topk_op, verify_topk_op
    from repro.kernels.quant import quantize_rows_int4, sketch_rows

    g = n // b
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    base = l2_normalize(jax.random.normal(k1, (b, d)))
    sigma = 0.45 / d**0.5  # noise VECTOR norm ~0.45 vs the unit base
    x = l2_normalize(
        jnp.repeat(base, g, axis=0) + sigma * jax.random.normal(k2, (n, d))
    )
    q = l2_normalize(base + sigma * jax.random.normal(k3, (b, d)))
    cand = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (b, n))
    gt_ids, _ = verify_topk_op(x, cand, q, k=k, use_pallas=False)

    codes, scales = quantize_rows_int4(x)
    kp = min(rescore_factor * k, n)

    def two_stage(first_rows):
        prov, _ = verify_topk_op(
            codes, jnp.maximum(first_rows, 0), q, k=kp, out_ids=first_rows,
            scales=scales, use_pallas=False, code_dtype="int4",
        )
        ids, _ = verify_topk_op(
            x, jnp.maximum(prov, 0), q, k=k, out_ids=prov, use_pallas=False
        )
        return float(np.asarray(recall_at_k(ids, gt_ids)))

    m = min(sketch_factor * kp, n)
    surv, _ = sketch_topk_op(sketch_rows(x), cand, q, k=m, use_pallas=False)
    return {
        "int4": two_stage(cand),
        "sketch_int4": two_stage(surv),
        "shape": {"N": n, "d": d, "B": b, "k": k, "group": g,
                  "sketch_factor": sketch_factor},
    }


def _measure_shared_dma(
    b, n_clusters, lp, d, k, n_probe, block_q, zipf_a=1.3, iters=3
):
    """Cluster-major vs per-query schedule under Zipf-skewed probe traffic.

    Routed probe lists are sampled from a Zipf(``zipf_a``) cluster
    popularity (production query traffic concentrates on hot clusters —
    the regime the cluster-major schedule exists for), then BOTH loop
    orders are built from the same lists and run through the same grouped
    kernel: ``block_q=1`` *is* the per-query loop order (one cluster-tile
    stream per (query, probe) pair), so the measured cluster-tile rows of
    the two schedules are directly comparable and the shared-DMA ratio is
    ``pair streams / step streams``. Every pair's per-cluster top-k'
    scatters back through its (step, slot) coordinates and merges per
    query — the final (ids, scores) of the two schedules must match
    bit-for-bit (the ISSUE's schedule-parity acceptance gate, measured
    here on top of the unit tests).
    """
    import jax
    import numpy as np

    from repro.core.utils import dedup_topk
    from repro.kernels.ops import verify_topk_grouped_op
    from repro.kernels.quant import quantize_rows
    from repro.kernels.schedule import build_cluster_schedule

    rng = np.random.default_rng(0)
    weights = 1.0 / np.arange(1, n_clusters + 1) ** zipf_a
    weights /= weights.sum()
    cids = np.stack(
        [
            rng.choice(n_clusters, size=n_probe, replace=False, p=weights)
            for _ in range(b)
        ]
    ).astype(np.int32)

    embs_f = jax.random.normal(jax.random.PRNGKey(2), (n_clusters, lp, d))
    q = jax.random.normal(jax.random.PRNGKey(3), (b, d))
    table, scales = quantize_rows(embs_f)  # codes (c,lp,d), scales (c,lp)
    kp = min(4 * k, lp)

    def dense_step_slot_ids(sched):
        # Every scheduled pair's candidate set = its cluster's full Lp rows
        # (flat ids cid*lp + local), the densest sharing case.
        out = np.full((sched.n_padded_steps, sched.block_q, lp), -1, np.int64)
        qs, ps = np.nonzero(sched.pair_step >= 0)
        st, sl = sched.pair_step[qs, ps], sched.pair_slot[qs, ps]
        out[st, sl, :] = (
            cids[qs, ps][:, None].astype(np.int64) * lp + np.arange(lp)[None]
        )
        return out.astype(np.int32)

    import jax.numpy as jnp

    def run(sched):
        ssi = dense_step_slot_ids(sched)
        ids_g, sc_g = verify_topk_grouped_op(
            table,
            scales,
            q,
            jnp.asarray(sched.sched_cids),
            jnp.asarray(sched.sched_qids),
            jnp.asarray(ssi),
            kp=kp,
            block_q=sched.block_q,
        )
        # Scatter-back + per-query merge, same semantics as the search path.
        safe_st = jnp.maximum(jnp.asarray(sched.pair_step), 0)
        safe_sl = jnp.maximum(jnp.asarray(sched.pair_slot), 0)
        pids = ids_g[safe_st, safe_sl]
        psc = sc_g[safe_st, safe_sl]
        dead = (jnp.asarray(sched.pair_step) < 0)[..., None]
        pids = jnp.where(dead, -1, pids)
        psc = jnp.where(dead, -jnp.inf, psc)
        return dedup_topk(pids.reshape(b, -1), psc.reshape(b, -1), k)

    sched_g = build_cluster_schedule(cids, block_q=block_q)
    sched_1 = build_cluster_schedule(cids, block_q=1)
    gi, gs = run(sched_g)
    pi, ps_ = run(sched_1)
    out = {
        "ids_match": bool((np.asarray(gi) == np.asarray(pi)).all()),
        "scores_match": bool((np.asarray(gs) == np.asarray(ps_)).all()),
        # Cluster-tile rows each schedule streams for the same routed batch.
        "rows_per_query_schedule": sched_1.n_steps * lp,
        "rows_cluster_major": sched_g.n_steps * lp,
        "shared_dma_ratio": sched_1.n_steps / max(sched_g.n_steps, 1),
        "wall_s_cluster_major": _time(lambda: run(sched_g), iters),
        "wall_s_per_query": _time(lambda: run(sched_1), iters),
        "n_pairs": sched_g.n_pairs,
        "n_steps": sched_g.n_steps,
        "shape": {
            "B": b, "clusters": n_clusters, "Lp": lp, "d": d, "k": k,
            "n_probe": n_probe, "block_q": block_q, "zipf_a": zipf_a,
        },
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small measured shapes (CI); model stays at paper defaults")
    ap.add_argument("--out", default="BENCH_verify.json")
    ap.add_argument("--b", type=int, default=32)
    ap.add_argument("--p", type=int, default=20)
    ap.add_argument("--h-arrays", type=int, default=10)
    ap.add_argument("--r", type=int, default=400)
    ap.add_argument("--d", type=int, default=768)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--rescore-factor", type=int, default=4)
    ap.add_argument(
        "--corpus-n", type=int, default=MSMARCO_N,
        help="corpus rows for the storage-tier byte model (default: the "
        "paper's MS-MARCO scale)",
    )
    ap.add_argument("--dtypes", "--storage-dtypes", nargs="+",
                    default=["float32", "bfloat16", "int8", "int4"],
                    choices=list(STORAGE_BYTES))
    ap.add_argument("--block-q", type=int, default=8,
                    help="query-tile width of the measured cluster-major "
                    "schedule (DESIGN.md §Cluster-major schedule)")
    ap.add_argument("--zipf-a", type=float, default=1.3,
                    help="Zipf exponent of the probe-popularity skew the "
                    "shared-DMA measurement samples")
    ap.add_argument("--sketch-factor", type=int, default=4,
                    help="survivor multiple m = sketch_factor*k' of the "
                    "1-bit pre-filter pass (DESIGN.md §Binary sketch tier)")
    args = ap.parse_args()

    c = args.p * args.h_arrays * args.r
    model = {
        sd: traffic_model(args.b, c, args.d, args.k, sd, args.rescore_factor)
        for sd in args.dtypes
    }
    # Same model with the 1-bit pre-filter in front (quantized dtypes only).
    model_sketch = {
        sd: traffic_model(args.b, c, args.d, args.k, sd, args.rescore_factor,
                          sketch_factor=args.sketch_factor)
        for sd in args.dtypes
        if sd in QUANTIZED_DTYPES
    }
    # Storage-tier dimension (DESIGN.md §Tiered embedding store): where the
    # embedding-store bytes live per (dtype, tier) config at paper scale.
    tier_configs = [(sd, "device") for sd in args.dtypes]
    for sd in QUANTIZED_DTYPES:
        if sd in args.dtypes:
            tier_configs.append((sd, "host"))
    storage_tiers = {
        f"{sd}_{tier}": storage_tier_model(args.corpus_n, args.d, sd, tier)
        for sd, tier in tier_configs
    }
    f32_model = traffic_model(args.b, c, args.d, args.k, "float32",
                              args.rescore_factor)
    ratios = {
        sd: {
            "emitted_vs_unfused": m["unfused"]["emitted_bytes"]
            / m["fused"]["emitted_bytes"],
            "total_vs_unfused": m["unfused"]["total_bytes"]
            / m["fused"]["total_bytes"],
            "fused_total_vs_f32_fused": f32_model["fused"]["total_bytes"]
            / m["fused"]["total_bytes"],
        }
        for sd, m in model.items()
    }

    import jax

    full_measure = not args.smoke and jax.default_backend() == "tpu"
    if not args.smoke and not full_measure:
        print(
            "warning: paper-shape measurement needs a TPU (interpret-mode "
            "Pallas at B=32, C=80000 would take hours on CPU); measuring at "
            "smoke shapes instead — the traffic model above is unaffected",
            file=sys.stderr,
        )
    measured = {}
    for sd in args.dtypes:
        if full_measure:
            measured[sd] = _measure(
                b=args.b, c=c, n=200_000, d=args.d, k=args.k, dtype_name=sd,
                block_c=256, rescore_factor=args.rescore_factor,
            )
        else:
            measured[sd] = _measure(
                b=4, c=608, n=4096, d=64, k=10, dtype_name=sd, block_c=128,
                rescore_factor=args.rescore_factor,
            )
    for sd in QUANTIZED_DTYPES:
        if sd not in args.dtypes:
            continue
        if full_measure:
            measured[f"{sd}_host"] = _measure_host_tier(
                b=args.b, c=c, n=200_000, d=args.d, k=args.k, block_c=256,
                rescore_factor=args.rescore_factor, code_dtype=sd,
            )
        else:
            measured[f"{sd}_host"] = _measure_host_tier(
                b=4, c=608, n=4096, d=64, k=10, block_c=128,
                rescore_factor=args.rescore_factor, code_dtype=sd,
            )
    if full_measure:
        measured["sketch"] = _measure_sketch(
            b=args.b, c=c, n=200_000, d=args.d, k=args.k, block_c=256
        )
    else:
        measured["sketch"] = _measure_sketch(
            b=4, c=608, n=4096, d=64, k=10, block_c=128
        )
    measured["sketch_e2e"] = _measure_sketch_e2e(
        n=4096, d=64, b=16, k=10, n_clusters=16
    )
    recall = _recall_floor(
        n=4096, d=64, b=32, k=10, rescore_factor=args.rescore_factor
    )
    recall_sketch = _recall_floor_sketch(
        n=4096, d=64, b=32, k=10, rescore_factor=args.rescore_factor,
        sketch_factor=args.sketch_factor,
    )
    # Cluster-major schedule: parity + shared-DMA ratio under Zipf probes
    # (shape-independent of the dtype sweep; int8 codes, small bank).
    shared = _measure_shared_dma(
        b=32, n_clusters=64, lp=128, d=64, k=10, n_probe=8,
        block_q=args.block_q, zipf_a=args.zipf_a,
    )

    checks = {
        f"parity_{sd}": measured[sd]["ids_match"] for sd in args.dtypes
    }
    for sd in QUANTIZED_DTYPES:
        if sd in args.dtypes:
            checks[f"parity_{sd}_host_vs_device_rescore"] = (
                measured[f"{sd}_host"]["ids_match"]
                and measured[f"{sd}_host"]["scores_match"]
            )
    if "int8" in args.dtypes and "float32" in args.dtypes:
        checks["int8_host_device_bytes_le_045x_f32"] = (
            storage_tiers["int8_host"]["device_bytes"]
            <= HOST_TIER_DEVICE_BYTES_MAX_VS_F32
            * storage_tiers["float32_device"]["device_bytes"]
        )
    if "int8" in args.dtypes and "bfloat16" in args.dtypes:
        checks["int8_rescore_recall_floor"] = (
            recall["int8"] >= recall["bfloat16"] - RECALL_EPS
        )
    if "int8" in args.dtypes:
        checks["int8_total_traffic_at_least_2x_below_f32"] = (
            ratios["int8"]["fused_total_vs_f32_fused"] >= 2.0
        )
    if "int4" in args.dtypes:
        # int4's quality floor is gated against int8 (both run the exact
        # f32 rescore; only the first pass got narrower).
        checks["int4_rescore_recall_floor_vs_int8"] = (
            recall["int4"] >= recall["int8"] - RECALL_EPS
        )
        if "int8" in args.dtypes:
            checks["int4_total_traffic_at_least_1p7x_below_int8"] = (
                model["int8"]["fused"]["total_bytes"]
                >= INT4_VS_INT8_TOTAL_MIN
                * model["int4"]["fused"]["total_bytes"]
            )
    checks["cluster_major_schedule_parity"] = (
        shared["ids_match"] and shared["scores_match"]
    )
    checks["shared_dma_ratio_above_1p5_zipf"] = (
        shared["shared_dma_ratio"] > SHARED_DMA_RATIO_MIN
    )
    checks["parity_sketch"] = (
        measured["sketch"]["ids_match"] and measured["sketch"]["scores_match"]
    )
    checks["sketch_covering_end_to_end_parity"] = (
        measured["sketch_e2e"]["ids_match"]
        and measured["sketch_e2e"]["scores_match"]
    )
    if "int4" in args.dtypes:
        checks["sketch_int4_recall_floor_vs_int4"] = (
            recall_sketch["sketch_int4"] >= recall_sketch["int4"] - RECALL_EPS
        )
        checks["sketch_int4_total_traffic_at_least_3x_below_int4"] = (
            model["int4"]["fused"]["total_bytes"]
            >= SKETCH_VS_INT4_TOTAL_MIN
            * model_sketch["int4"]["fused"]["total_bytes"]
        )

    report = {
        "paper_shape": {
            "B": args.b, "P": args.p, "H": args.h_arrays, "R": args.r,
            "C": c, "d": args.d, "k": args.k,
            "rescore_factor": args.rescore_factor,
        },
        "traffic_model": model,
        "traffic_ratios": ratios,
        "storage_tiers": {
            "corpus_n": args.corpus_n,
            "d": args.d,
            "max_host_device_ratio_vs_f32": HOST_TIER_DEVICE_BYTES_MAX_VS_F32,
            "configs": storage_tiers,
        },
        "measured": measured,
        "recall_vs_exact": recall,
        "recall_eps": RECALL_EPS,
        "cluster_major": {
            **shared,
            "min_shared_dma_ratio": SHARED_DMA_RATIO_MIN,
        },
        "int4_vs_int8_total_ratio": (
            model["int8"]["fused"]["total_bytes"]
            / model["int4"]["fused"]["total_bytes"]
            if "int8" in model and "int4" in model
            else None
        ),
        "sketch": {
            "sketch_factor": args.sketch_factor,
            "traffic_model": model_sketch,
            "recall_planted_neighbors": recall_sketch,
            "min_total_ratio_vs_int4": SKETCH_VS_INT4_TOTAL_MIN,
            "sketch_int4_vs_int4_total_ratio": (
                model["int4"]["fused"]["total_bytes"]
                / model_sketch["int4"]["fused"]["total_bytes"]
                if "int4" in model_sketch
                else None
            ),
        },
        "checks": checks,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)

    for sd in args.dtypes:
        m, r = model[sd], ratios[sd]
        extra = ""
        if sd in QUANTIZED_DTYPES:
            extra = (
                f" rescore_overhead={measured[sd]['rescore_overhead_frac']:.1%}"
                f" recall={recall[sd]:.4f}"
            )
        print(
            f"[verify] {sd:>8}: fused total {m['fused']['total_bytes']/2**30:7.2f} GiB "
            f"({r['fused_total_vs_f32_fused']:.2f}x below f32), emits "
            f"{m['fused']['emitted_bytes']/2**20:8.2f} MiB "
            f"({r['emitted_vs_unfused']:,.0f}x less than unfused); "
            f"measured fused {measured[sd]['wall_s_fused']*1e3:.2f} ms, "
            f"ids_match={measured[sd]['ids_match']}{extra}"
        )
    f32_dev = storage_tiers.get("float32_device", {}).get("device_bytes")
    for name, tb in storage_tiers.items():
        ratio = (
            f" ({tb['device_bytes'] / f32_dev:.2f}x of f32 device)"
            if f32_dev
            else ""
        )
        print(
            f"[verify] store {name:>15}: device {tb['device_bytes']/2**30:6.2f} GiB"
            f", host {tb['host_bytes']/2**30:6.2f} GiB{ratio}"
        )
    for sd in QUANTIZED_DTYPES:
        if f"{sd}_host" not in measured:
            continue
        mh = measured[f"{sd}_host"]
        print(
            f"[verify] {sd}_host staged rescore: ids_match={mh['ids_match']} "
            f"scores_match={mh['scores_match']} "
            f"fetch={mh['host_fetch_us']:.0f}us "
            f"rescore={mh['wall_s_host_rescore']*1e3:.2f}ms "
            f"(device-resident rescore {mh['wall_s_device_rescore']*1e3:.2f}ms)"
        )
    if "int4" in model_sketch:
        ms = measured["sketch"]
        print(
            f"[verify] sketch+int4 (factor={args.sketch_factor}): fused total "
            f"{model_sketch['int4']['fused']['total_bytes']/2**30:7.2f} GiB "
            f"({model['int4']['fused']['total_bytes'] / model_sketch['int4']['fused']['total_bytes']:.2f}x"
            f" below plain int4), recall={recall_sketch['sketch_int4']:.4f} "
            f"(int4 {recall_sketch['int4']:.4f}, planted neighbors); kernel "
            f"{ms['wall_s_kernel']*1e3:.2f} ms, ids_match={ms['ids_match']} "
            f"scores_match={ms['scores_match']}, covering e2e "
            f"ids_match={measured['sketch_e2e']['ids_match']} "
            f"scores_match={measured['sketch_e2e']['scores_match']}"
        )
    print(
        f"[verify] cluster-major (zipf a={shared['shape']['zipf_a']}, "
        f"block_q={shared['shape']['block_q']}): "
        f"shared-DMA ratio {shared['shared_dma_ratio']:.2f}x "
        f"({shared['n_pairs']} pair streams -> {shared['n_steps']} step "
        f"streams), ids_match={shared['ids_match']} "
        f"scores_match={shared['scores_match']}"
    )
    print(f"[verify] checks: {checks} -> {args.out}")
    failed = [name for name, ok in checks.items() if not ok]
    if failed:
        raise SystemExit(f"verification regression, failed checks: {failed}")


if __name__ == "__main__":
    main()

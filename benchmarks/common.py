"""Shared benchmark utilities: corpora, metrics, timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import flat_search
from repro.data import synthetic


def make_task(n: int, dim: int = 64, n_queries: int = 200, seed: int = 0):
    """Corpus + queries + (exact top-100, relevant seed ids)."""
    corpus = synthetic.retrieval_corpus(seed, n, dim)
    queries, seed_ids = synthetic.retrieval_queries(seed + 1, corpus, n_queries)
    gt = flat_search(corpus, queries, k=100)
    return corpus, queries, seed_ids, gt


# Single metric definition shared with the autotuner (repro.core.utils).
from repro.core.utils import mrr_at_10  # noqa: E402,F401


def recall_vs_flat(pred_ids, gt_ids, k: int = 10) -> float:
    from repro.core.utils import recall_at_k

    return float(recall_at_k(jnp.asarray(pred_ids)[:, :k], jnp.asarray(gt_ids)[:, :k]))


def time_search(fn, queries, *, batch: int = 64, repeats: int = 3) -> float:
    """Average per-query time (AQT, seconds) of a jitted search callable."""
    q = queries[:batch]
    jax.block_until_ready(fn(q))  # compile + warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(q)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / (repeats * batch)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"

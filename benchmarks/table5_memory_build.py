"""Paper Table 5: construction time per LIDER stage + index memory footprint,
vs the original SK-LSH.

Memory is computed exactly from the index arrays (embeddings excluded, as in
the paper). The paper's claim: LIDER's clustered layout needs fewer/shorter
arrays than flat SK-LSH (H=10/M~log(Lp) vs H=24/M~log(N)) -> ~2x memory
saving, at the cost of the Stage-1 clustering time.

Beyond-paper storage-tier column (DESIGN.md §Tiered embedding store): per
storage config, *where* the index bytes live — device HBM vs host RAM —
measured exactly from built indexes via ``ClusterBank.nbytes_by_tier``. The
int8+host row is the capacity story: device-resident bytes drop to ~0.25x of
f32 while the full-precision rescore table sits in host RAM.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import clustering, core_model, lider, lsh
from repro.core.baselines import build_sklsh
from .common import csv_line, make_task


def _tree_bytes(tree, exclude=()) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        if any(e in name for e in exclude):
            continue
        total += leaf.size * leaf.dtype.itemsize
    return total


def run(n: int = 50_000, verbose: bool = True):
    corpus, _, _, _ = make_task(n)
    cfg = lider.LiderConfig(
        n_clusters=max(16, n // 1000), n_probe=20, n_arrays=10, n_leaves=5,
        kmeans_iters=10,
    )
    lines = []

    # Stage 1: clustering
    t0 = time.perf_counter()
    km = clustering.kmeans(jax.random.PRNGKey(0), corpus, cfg.n_clusters,
                           iters=cfg.kmeans_iters)
    jax.block_until_ready(km.centroids)
    t_stage1 = time.perf_counter() - t0
    m_stage1 = km.centroids.size * 4 + km.assignment.size * 4

    # Stage 2: centroids retriever
    t0 = time.perf_counter()
    cr = core_model.build_core_model(
        jax.random.PRNGKey(1), km.centroids,
        n_arrays=cfg.n_arrays_centroid, n_leaves=cfg.n_leaves_centroid,
    )
    jax.block_until_ready(cr.sorted_keys)
    t_stage2 = time.perf_counter() - t0
    m_stage2 = m_stage1 + _tree_bytes(cr)

    # Stage 3: all in-cluster retrievers (full build; includes stage 1+2 work)
    t0 = time.perf_counter()
    idx = lider.build_lider(jax.random.PRNGKey(0), corpus, cfg)
    jax.block_until_ready(idx.bank.sorted_keys)
    t_stage3 = time.perf_counter() - t0
    # paper convention: index memory excludes the data embeddings
    m_stage3 = _tree_bytes(idx, exclude=("bank/embs",))

    sk_t0 = time.perf_counter()
    sk = build_sklsh(jax.random.PRNGKey(2), corpus, n_arrays=24)
    jax.block_until_ready(sk.sorted_keys)
    t_sk = time.perf_counter() - sk_t0
    m_sk = _tree_bytes(sk)

    lines.append(csv_line("table5/lider_stage1_clustering", t_stage1 * 1e6,
                          f"mem_mb={m_stage1/2**20:.1f}"))
    lines.append(csv_line("table5/lider_stage2_cr", t_stage2 * 1e6,
                          f"mem_mb={m_stage2/2**20:.1f}"))
    lines.append(csv_line("table5/lider_stage3_irs", t_stage3 * 1e6,
                          f"mem_mb={m_stage3/2**20:.1f}"))
    lines.append(csv_line("table5/sklsh", t_sk * 1e6, f"mem_mb={m_sk/2**20:.1f}"))
    saving = 1 - m_stage3 / m_sk
    lines.append(csv_line("table5/memory_saving_vs_sklsh", 0.0,
                          f"saving={saving:.2%}"))

    # Storage-tier column: device HBM vs host RAM per storage config (the
    # full bank accounting, embeddings *included* — this row is about where
    # the corpus lives, not the paper's index-only convention above).
    import dataclasses as _dc

    tier_cfgs = {
        "float32_device": _dc.replace(cfg, storage_dtype="float32"),
        "int8_device": _dc.replace(cfg, storage_dtype="int8"),
        "int8_host": _dc.replace(
            cfg, storage_dtype="int8", rescore_tier="host"
        ),
    }
    f32_dev = None
    for name, tcfg in tier_cfgs.items():
        t0 = time.perf_counter()
        tidx = lider.build_lider(jax.random.PRNGKey(0), corpus, tcfg)
        jax.block_until_ready(tidx.bank.embs)
        t_build = time.perf_counter() - t0
        tiers = tidx.bank.nbytes_by_tier()
        if name == "float32_device":
            f32_dev = tiers["device"]
        lines.append(csv_line(
            f"table5/storage_tier/{name}", t_build * 1e6,
            f"device_mb={tiers['device']/2**20:.1f} "
            f"host_mb={tiers['host']/2**20:.1f} "
            f"device_vs_f32={tiers['device']/max(f32_dev, 1):.2f}",
        ))
    if verbose:
        for ln in lines:
            print(ln)
    return lines


if __name__ == "__main__":
    run()

"""Serving-scale benchmark: open-loop Zipf + burst traffic vs the SLO.

The headline number for the async continuous-batching front end
(DESIGN.md §Serving front end; ROADMAP names this file). Two engines
serve the SAME seeded arrival trace — Poisson arrivals with bursty
episodes, Zipf-popular queries, skewed tenants — in real time:

- **fixed**: the legacy front end — FIFO queue, every batch padded to
  ``batch_size``, one operating point, no cache.
- **adaptive**: the scheduler front end — result cache, pow2 dynamic
  batch sizing under the SLO, per-tenant fair queues.

The workload is calibrated at runtime: a warm full batch is timed, the
arrival rate is set to ``--load-mult``x the fixed engine's max
throughput (so the fixed engine is overloaded by construction) and the
SLO to ``--slo-mult`` batch-times. Both engines search the identical
operating point, so quality differences are zero by construction and
the benchmark isolates *scheduling*: what the cache, the batch-size
ladder, and admission buy under pressure.

Report: p50/p99 latency, availability, shed/degraded fractions, cache
hit rate, recall, and recall-at-SLO (recall credited only to answers
inside the SLO — the number a user actually experiences).

Gates (--check, non-zero exit; CI runs --smoke):
- adaptive p99 <= SLO while fixed p99 > SLO (same trace, same hardware)
- adaptive recall >= fixed recall
- every adaptive answer (cache hits and dynamically-sized batches
  alike) bit-identical to a direct ``search_lider`` of that query
- zero query-path recompiles across the run after warmup
  (``lider.query_path_cache_size`` delta == 0)

With ``--replicas N`` (N > 1) a third leg runs the same trace through an
N-replica ``QueryRouter`` (the same adaptive scheduler, centralized, over
N identical engines — serving/router.py): reported alongside, gated on
bit-identity and recall, to show what pure fan-out buys on one trace.

Usage:
    PYTHONPATH=src python -m benchmarks.serve_scale [--smoke]
        [--out BENCH_scale.json] [--n 20000] [--dim 64] [--pool 256]
        [--arrivals 4000] [--batch-size 32] [--k 10] [--replicas N]
"""
from __future__ import annotations

import argparse
import json
import time


def _build(n, dim, n_clusters, pool, seed=0):
    import jax
    import numpy as np

    from repro.core import lider
    from repro.core.baselines import flat_search
    from repro.core.utils import l2_normalize

    rng = jax.random.PRNGKey(seed)
    kc, kx, kn, kq = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (n_clusters, dim))
    assign = jax.random.randint(kx, (n,), 0, n_clusters)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kn, (n, dim)))
    q = np.asarray(
        l2_normalize(x[:pool] + 0.05 * jax.random.normal(kq, (pool, dim))),
        np.float32,
    )
    cfg = lider.LiderConfig(n_clusters=n_clusters, n_probe=4)
    params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
    gt = np.asarray(flat_search(x, jax.numpy.asarray(q), k=10).ids)
    return params, q, gt


def _calibrate(engine, batch, dim, repeats=5):
    """Median warm full-batch service time (seconds) — the unit every
    workload knob is expressed in, so the benchmark self-scales to the
    machine it runs on."""
    import jax
    import jax.numpy as jnp

    q = jnp.zeros((batch, dim), jnp.float32)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out, _ = engine._split_out(engine._search(q))
        jax.block_until_ready((out.ids, out.scores))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _metrics(results, trace, gt, k, slo_s):
    """Per-run serving metrics from collected QueryResult/Shed answers."""
    import numpy as np

    from repro.serving import QueryResult

    lat, recalls, rec_at_slo, n_shed, n_degraded, n_cached = (
        [], [], [], 0, 0, 0,
    )
    for res, arr in zip(results, trace):
        if not isinstance(res, QueryResult):
            n_shed += 1
            rec_at_slo.append(0.0)  # a shed request delivers nothing
            continue
        lat.append(res.latency_s)
        n_degraded += bool(res.degraded)
        n_cached += bool(res.cached)
        got = set(np.asarray(res.ids)[:k].tolist())
        r = len(got & set(gt[arr.query_idx][:k].tolist())) / k
        recalls.append(r)
        rec_at_slo.append(r if res.latency_s <= slo_s else 0.0)
    lat = np.asarray(lat) if lat else np.zeros(1)
    n = len(results)
    return {
        "n_arrivals": n,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "availability": (n - n_shed) / max(n, 1),
        "shed_fraction": n_shed / max(n, 1),
        "degraded_fraction": n_degraded / max(n, 1),
        "cache_hit_fraction": n_cached / max(n, 1),
        "recall": float(np.mean(recalls)) if recalls else 0.0,
        "recall_at_slo": float(np.mean(rec_at_slo)),
    }


def _bit_identity(results, trace, ref_ids, ref_scores):
    """Every answered (non-degraded) result must bit-match the direct
    serial search of its pool query — cache hits and dynamically-sized
    batches are not allowed to change a single ulp."""
    import numpy as np

    from repro.serving import QueryResult

    n_checked = n_bad = 0
    for res, arr in zip(results, trace):
        if not isinstance(res, QueryResult) or res.degraded:
            continue
        n_checked += 1
        ok = np.array_equal(
            np.asarray(res.ids), ref_ids[arr.query_idx]
        ) and np.array_equal(np.asarray(res.scores), ref_scores[arr.query_idx])
        n_bad += not ok
    return n_checked, n_bad


def _run(engine, trace, q, warm=True):
    from repro.serving.traffic import run_open_loop

    rids = run_open_loop(engine, trace, q)
    return [engine.result(r) for r in rids]


def _bench(args):
    import jax.numpy as jnp
    import numpy as np

    from repro.core import lider
    from repro.serving import (
        DegradePolicy, QueryRouter, RetrievalEngine, RouterConfig,
        SchedulerConfig, clone_params, make_backend,
    )
    from repro.serving.traffic import make_trace

    params, q, gt = _build(args.n, args.dim, args.n_clusters, args.pool)
    search = make_backend("lider", None, updatable=True, n_probe=4)

    def engine_for(sched=None, p=params):
        return RetrievalEngine(
            search, batch_size=args.batch_size, k=args.k, dim=args.dim,
            params=p, policy=DegradePolicy(), scheduler=sched,
        )

    fixed = engine_for()
    min_batch = max(1, args.batch_size // 8)
    s_batch = None  # calibrated after warmup below
    # Warm both engines BEFORE freezing the recompile baseline: the
    # adaptive warmup compiles every pow2 ladder size once, off-path.
    fixed.warmup()
    s_batch = _calibrate(fixed, args.batch_size, args.dim)
    slo_s = args.slo_mult * s_batch
    sched_cfg = SchedulerConfig(
        dynamic_batch=True,
        min_batch=min_batch,
        cache_size=4 * args.pool,
        slo_s=slo_s,
    )
    adaptive = engine_for(sched_cfg)
    adaptive.warmup()

    # Optional N-replica leg: the same adaptive scheduler centralized in a
    # QueryRouter spreading batches over N identical engines (serving/
    # router.py). No result cache (caching stays per-engine by design) and
    # no chaos — this leg isolates what pure fan-out buys on the same
    # trace. Warmed before the recompile baseline is frozen.
    replicated = None
    if args.replicas > 1:
        replicated = QueryRouter(
            [
                engine_for(p=params if i == 0 else clone_params(params))
                for i in range(args.replicas)
            ],
            config=RouterConfig(hedge_quantile=None),
            scheduler=sched_cfg,
        )
        replicated.warmup()

    # Direct serial reference over the whole pool (its own shape, so it
    # must run before the recompile baseline is captured).
    ref = lider.search_lider(params, jnp.asarray(q), k=args.k, n_probe=4)
    ref_ids, ref_scores = np.asarray(ref.ids), np.asarray(ref.scores)
    compiled_before = lider.query_path_cache_size()

    # Overload by construction: arrivals come --load-mult x faster than
    # the fixed engine can serve them at full batch.
    mean_rate = args.load_mult * args.batch_size / s_batch
    trace = make_trace(
        seed=args.seed, n_arrivals=args.arrivals, pool_size=args.pool,
        mean_rate=mean_rate, pattern="burst", zipf_a=args.zipf_a,
        burst_factor=4.0, episode_len=64, n_tenants=args.tenants,
    )

    fixed_res = _run(fixed, trace, q)
    adaptive_res = _run(adaptive, trace, q)
    replicated_res = (
        _run(replicated, trace, q) if replicated is not None else None
    )
    compiled_after = lider.query_path_cache_size()

    m_fixed = _metrics(fixed_res, trace, gt, args.k, slo_s)
    m_adapt = _metrics(adaptive_res, trace, gt, args.k, slo_s)
    n_checked, n_bad = _bit_identity(adaptive_res, trace, ref_ids, ref_scores)
    nf_checked, nf_bad = _bit_identity(fixed_res, trace, ref_ids, ref_scores)
    m_repl = nr_checked = nr_bad = None
    if replicated_res is not None:
        m_repl = _metrics(replicated_res, trace, gt, args.k, slo_s)
        nr_checked, nr_bad = _bit_identity(
            replicated_res, trace, ref_ids, ref_scores
        )

    s = adaptive.stats
    report = {
        "shape": {
            "n": args.n, "dim": args.dim, "n_clusters": args.n_clusters,
            "pool": args.pool, "arrivals": args.arrivals,
            "batch_size": args.batch_size, "min_batch": min_batch,
            "k": args.k, "tenants": args.tenants, "zipf_a": args.zipf_a,
            "seed": args.seed,
        },
        "calibration": {
            "batch_service_s": s_batch,
            "slo_s": slo_s,
            "slo_mult": args.slo_mult,
            "load_mult": args.load_mult,
            "mean_arrival_rate_qps": mean_rate,
        },
        "fixed": m_fixed,
        "adaptive": m_adapt,
        "adaptive_engine": {
            "cache_hit_rate": s.cache_hit_rate,
            "n_cache_hits": s.n_cache_hits,
            "n_batches": s.n_batches,
            "padding_fraction": s.padding_fraction,
            "batch_size_trace_tail": list(s.batch_size_trace)[-16:],
            "aqt_s": s.aqt,
        },
        "fixed_engine": {
            "n_batches": fixed.stats.n_batches,
            "padding_fraction": fixed.stats.padding_fraction,
            "aqt_s": fixed.stats.aqt,
        },
        "bit_identity": {
            "adaptive_checked": n_checked, "adaptive_mismatches": n_bad,
            "fixed_checked": nf_checked, "fixed_mismatches": nf_bad,
        },
        "replicated": (
            None
            if replicated is None
            else {
                "n_replicas": args.replicas,
                **m_repl,
                "bit_checked": nr_checked,
                "bit_mismatches": nr_bad,
                "router": replicated.stats_dict(),
            }
        ),
        "recompiles": {
            "compiled_traces_before": compiled_before,
            "compiled_traces_after": compiled_after,
            "engine_recompiles": adaptive.recompiles + fixed.recompiles,
        },
    }

    failures = []
    if m_adapt["p99_latency_s"] > slo_s:
        failures.append(
            f"adaptive p99 {m_adapt['p99_latency_s'] * 1e3:.1f}ms misses the "
            f"SLO {slo_s * 1e3:.1f}ms"
        )
    if m_fixed["p99_latency_s"] <= slo_s:
        failures.append(
            f"fixed p99 {m_fixed['p99_latency_s'] * 1e3:.1f}ms meets the SLO "
            f"{slo_s * 1e3:.1f}ms — workload not separating (raise --load-mult)"
        )
    if m_adapt["recall"] < m_fixed["recall"]:
        failures.append(
            f"adaptive recall {m_adapt['recall']:.4f} < fixed "
            f"{m_fixed['recall']:.4f}"
        )
    if n_bad or nf_bad:
        failures.append(
            f"{n_bad} adaptive + {nf_bad} fixed answers not bit-identical "
            "to direct search"
        )
    if nr_bad:
        failures.append(
            f"{nr_bad} replicated answers not bit-identical to direct search"
        )
    if m_repl is not None and m_repl["recall"] < m_fixed["recall"]:
        failures.append(
            f"replicated recall {m_repl['recall']:.4f} < fixed "
            f"{m_fixed['recall']:.4f}"
        )
    if replicated is not None:
        replicated.close()
    if compiled_after != compiled_before:
        failures.append(
            f"query path re-traced: {compiled_before} -> {compiled_after} "
            "compiled traces after warmup"
        )
    report["failures"] = failures
    report["ok"] = not failures
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small shape + gates (CI)")
    ap.add_argument("--out", default="BENCH_scale.json")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--n-clusters", type=int, default=32)
    ap.add_argument("--pool", type=int, default=256,
                    help="distinct queries behind the Zipf popularity")
    ap.add_argument("--arrivals", type=int, default=4000)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--replicas", type=int, default=1,
                    help="also run an N-replica QueryRouter leg (>1)")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--zipf-a", type=float, default=1.1)
    ap.add_argument("--slo-mult", type=float, default=8.0,
                    help="SLO as a multiple of the warm batch service time")
    ap.add_argument("--load-mult", type=float, default=4.0,
                    help="arrival rate as a multiple of fixed max throughput")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-check", dest="check", action="store_false",
                    help="report only; do not gate")
    args = ap.parse_args()
    if args.smoke:
        args.n = 8000
        args.arrivals = 800
        args.pool = 48

    report = _bench(args)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    a, fx = report["adaptive"], report["fixed"]
    print(
        f"serve_scale: slo={report['calibration']['slo_s'] * 1e3:.1f}ms  "
        f"adaptive p99={a['p99_latency_s'] * 1e3:.1f}ms "
        f"recall@slo={a['recall_at_slo']:.3f} "
        f"cache_hit={a['cache_hit_fraction']:.2f}  |  "
        f"fixed p99={fx['p99_latency_s'] * 1e3:.1f}ms "
        f"recall@slo={fx['recall_at_slo']:.3f}"
    )
    if report.get("replicated"):
        rp = report["replicated"]
        print(
            f"replicated x{rp['n_replicas']}: "
            f"p99={rp['p99_latency_s'] * 1e3:.1f}ms "
            f"recall@slo={rp['recall_at_slo']:.3f} "
            f"bit-mismatches={rp['bit_mismatches']}/{rp['bit_checked']}"
        )
    print(f"wrote {args.out}")
    if report["failures"]:
        for msg in report["failures"]:
            print(f"FAIL: {msg}")
        if args.check:
            raise SystemExit(1)
    else:
        print("all serving-scale gates passed")


if __name__ == "__main__":
    main()

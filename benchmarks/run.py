"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--fast`` (default when run under
the repo check) trims corpus sizes so the whole suite stays CPU-friendly;
``--full`` uses the larger sweeps. The multi-pod roofline numbers come from
``benchmarks.roofline`` (reads the dry-run artifact, no execution).
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only", default=None,
        help="comma-separated subset: table2,fig4,fig5,table3,table4,fig78,table5",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (
        fig4_aqt,
        fig5_tradeoff,
        fig7_fig8_clustering,
        table2_quality,
        table3_h_sweep,
        table4_rescaling,
        table5_memory_build,
    )

    print("name,us_per_call,derived")
    lines: list[str] = []

    def want(key):
        return only is None or key in only

    if want("table2"):
        lines += table2_quality.run(
            sizes=(20_000, 50_000) if args.full else (8_000, 20_000), verbose=True
        )
    if want("fig4"):
        lines += fig4_aqt.run(
            sizes=(10_000, 30_000, 60_000) if args.full else (5_000, 10_000, 20_000),
            verbose=True,
        )
    if want("fig5"):
        lines += fig5_tradeoff.run(n=30_000 if args.full else 10_000, verbose=True)
    if want("table3"):
        lines += table3_h_sweep.run(
            n=30_000 if args.full else 10_000,
            hs=(4, 8, 16, 32) if args.full else (4, 8, 16),
            verbose=True,
        )
    if want("table4"):
        # Table 4 needs the n/key-magnitude regime where naive fp32 fits
        # actually lose precision — not shrunk in fast mode.
        lines += table4_rescaling.run(n=30_000, verbose=True)
    if want("fig78"):
        lines += fig7_fig8_clustering.run(n=30_000 if args.full else 10_000, verbose=True)
    if want("table5"):
        lines += table5_memory_build.run(n=50_000 if args.full else 15_000, verbose=True)

    print(f"# {len(lines)} benchmark rows", file=sys.stderr)


if __name__ == "__main__":
    main()

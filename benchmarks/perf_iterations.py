import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing (deliverable g): hypothesis -> change -> re-lower ->
measure, on the three chosen cells (single-pod production mesh).

Cells (chosen per the assignment criteria):
  A. lider-msmarco:serve_bulk + two-tower-retrieval:retrieval_cand — most
     representative of the paper's technique (LIDER serving itself).
  B. qwen2-72b:prefill_32k — most collective-bound baseline cell.
  C. qwen2-72b:train_4k — worst roofline fraction among the train cells.

Each variant is re-lowered on the 16x16 mesh and its roofline terms
recomputed; results land in experiments/perf_iterations.json and are
narrated (hypothesis / predicted delta / measured delta / verdict) in
EXPERIMENTS.md §Perf.

Usage: PYTHONPATH=src python -m benchmarks.perf_iterations
"""
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_arch
from repro.configs.base import ArchSpec, ShapeSpec
from repro.configs.lider_msmarco import RetrievalArchConfig
from repro.core.lider import LiderConfig
from repro.launch.dryrun import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_lm_bundle,
    make_recsys_bundle,
    make_retrieval_bundle,
)

PEAK_FLOPS, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def measure(bundle, mesh, loop_factor=None) -> dict:
    lf = loop_factor if loop_factor is not None else bundle.loop_factor
    t0 = time.time()
    with compat.set_mesh(mesh):
        jf = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums,
        )
        compiled = jf.lower(*bundle.args).compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_stats(compiled.as_text())
    coll_bytes = sum(v["bytes"] for v in coll.values()) * lf
    flops = float(cost.get("flops", 0)) * lf
    byts = float(cost.get("bytes accessed", 0)) * lf
    return {
        "compile_s": round(time.time() - t0, 1),
        "loop_factor": lf,
        "flops_per_chip": flops,
        "bytes_per_chip": byts,
        "coll_bytes_per_chip": coll_bytes,
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": byts / HBM_BW,
        "t_collective_s": coll_bytes / LINK_BW,
        "hbm_gib": (mem.temp_size_in_bytes + mem.argument_size_in_bytes) / 2**30,
        "collectives": {k: v["bytes"] for k, v in coll.items()},
    }


def two_tower_lider_arch() -> ArchSpec:
    """LIDER over the 1M-item two-tower embedding space (d=256)."""
    return ArchSpec(
        arch_id="two-tower-lider",
        family="retrieval",
        config=RetrievalArchConfig(
            lider=LiderConfig(
                n_clusters=512, n_probe=20, n_arrays=10, key_len=12,
                key_len_centroid=9, n_leaves=5, n_leaves_centroid=10, r0=4,
            ),
            corpus_size=1_000_000,
            dim=256,
            capacity=2752,
            k=100,
        ),
        shapes=(ShapeSpec("retrieval_cand", "retrieval_serve", {"batch": 1}),),
    )


def main() -> None:
    mesh = make_production_mesh(multi_pod=False)
    results: dict[str, dict] = {}

    def record(cell, variant, m):
        results[f"{cell}/{variant}"] = m
        print(
            f"[perf] {cell}/{variant}: comp={m['t_compute_s']:.3g}s "
            f"mem={m['t_memory_s']:.3g}s coll={m['t_collective_s']:.3g}s "
            f"hbm={m['hbm_gib']:.1f}GiB (compile {m['compile_s']}s)",
            flush=True,
        )

    # ---------------- Cell A: the paper's technique --------------------
    lider_arch = get_arch("lider-msmarco")
    sb = lider_arch.shape("serve_bulk")
    record("A.lider_serve_bulk", "baseline_f32_r04",
           measure(make_retrieval_bundle(lider_arch, sb, mesh), mesh))
    record("A.lider_serve_bulk", "A1_bf16_embs",
           measure(make_retrieval_bundle(lider_arch, sb, mesh,
                                         emb_dtype=jnp.bfloat16), mesh))
    record("A.lider_serve_bulk", "A2_bf16_r02_refine",
           measure(make_retrieval_bundle(lider_arch, sb, mesh,
                                         emb_dtype=jnp.bfloat16, r0=2,
                                         refine=True), mesh))

    tt = get_arch("two-tower-retrieval")
    rc = tt.shape("retrieval_cand")
    record("A.two_tower_retrieval_cand", "baseline_flat",
           measure(make_recsys_bundle(tt, rc, mesh), mesh))
    la = two_tower_lider_arch()
    record("A.two_tower_retrieval_cand", "A3_lider_index",
           measure(make_retrieval_bundle(la, la.shapes[0], mesh,
                                         emb_dtype=jnp.bfloat16,
                                         capacity_factor=40.0), mesh))

    # ---------------- Cell B: collective-bound prefill ------------------
    q72 = get_arch("qwen2-72b")
    pf = q72.shape("prefill_32k")
    seq_cfg_b = dataclasses.replace(q72.config, seq_shard_activations=True)
    record("B.qwen2_72b_prefill", "baseline_fsdp",
           measure(make_lm_bundle(q72, pf, mesh), mesh))
    record("B.qwen2_72b_prefill", "B1_tp_only_serving_params",
           measure(make_lm_bundle(q72, pf, mesh, fsdp=False), mesh))
    record("B.qwen2_72b_prefill", "B2_seqparallel_activations",
           measure(make_lm_bundle(q72, pf, mesh, cfg_override=seq_cfg_b), mesh))

    # ---------------- Cell C: worst-roofline train ----------------------
    tr = q72.shape("train_4k")
    record("C.qwen2_72b_train", "baseline_ga16",
           measure(make_lm_bundle(q72, tr, mesh), mesh))
    record("C.qwen2_72b_train", "C1_ga8",
           measure(make_lm_bundle(q72, tr, mesh, grad_accum=8), mesh,
                   loop_factor=80 * 8))
    seq_cfg = dataclasses.replace(q72.config, seq_shard_activations=True)
    record("C.qwen2_72b_train", "C2_seqparallel_ga4",
           measure(make_lm_bundle(q72, tr, mesh, grad_accum=4,
                                  cfg_override=seq_cfg), mesh,
                   loop_factor=80 * 4))
    record("C.qwen2_72b_train", "C3_seqparallel_ga1",
           measure(make_lm_bundle(q72, tr, mesh, grad_accum=1,
                                  cfg_override=seq_cfg), mesh,
                   loop_factor=80 * 1))
    record("C.qwen2_72b_train", "C4_seqparallel_ga8",
           measure(make_lm_bundle(q72, tr, mesh, grad_accum=8,
                                  cfg_override=seq_cfg), mesh,
                   loop_factor=80 * 8))

    with open("experiments/perf_iterations.json", "w") as f:
        json.dump(results, f, indent=1)
    print(f"[perf] wrote experiments/perf_iterations.json ({len(results)} rows)")


if __name__ == "__main__":
    main()

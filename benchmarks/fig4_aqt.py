"""Paper Fig. 4: AQT growth with corpus size, LIDER vs baselines.

The paper's claim: LIDER's AQT grows slowest with N (Sec. 6 complexity —
near-log until N ~ 1e7). We sweep CPU-feasible sizes and report AQT per
method; the derived field carries the growth ratio AQT(n_max)/AQT(n_min).
"""
from __future__ import annotations

import jax

from repro.core import lider
from repro.core.baselines import build_ivfpq, build_sklsh, flat_search, ivfpq_search, sklsh_search
from .common import csv_line, make_task, time_search


def run(sizes=(10_000, 30_000, 60_000), k: int = 100, verbose: bool = True):
    lines = []
    aqts: dict[str, list[float]] = {}
    for n in sizes:
        corpus, queries, _, _ = make_task(n)
        rng = jax.random.PRNGKey(0)
        c = max(16, n // 1000)
        idx = lider.build_lider(
            rng, corpus,
            lider.LiderConfig(n_clusters=c, n_probe=20, n_arrays=10, n_leaves=5,
                              kmeans_iters=10),
        )
        ivf = build_ivfpq(rng, corpus, n_subspaces=8, bits=8, kmeans_iters=8)
        sk = build_sklsh(rng, corpus, n_arrays=24)
        methods = {
            "flat": lambda q: flat_search(corpus, q, k=k),
            "lider": lambda q: lider.search_lider(idx, q, k=k, n_probe=20, r0=4),
            "ivfpq": lambda q: ivfpq_search(ivf, q, k=k, n_probe=20),
            "sklsh": lambda q: sklsh_search(sk, corpus, q, k=k, n_candidates=400),
        }
        for name, fn in methods.items():
            aqt = time_search(fn, queries)
            aqts.setdefault(name, []).append(aqt)
            lines.append(csv_line(f"fig4/{name}/n{n}", aqt * 1e6, f"n={n}"))
            if verbose:
                print(lines[-1])
    for name, series in aqts.items():
        growth = series[-1] / series[0]
        lines.append(
            csv_line(f"fig4/{name}/growth", series[-1] * 1e6,
                     f"aqt_ratio_{sizes[-1]}v{sizes[0]}={growth:.2f}")
        )
        if verbose:
            print(lines[-1])
    return lines


if __name__ == "__main__":
    run()

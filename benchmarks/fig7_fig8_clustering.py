"""Paper Figs. 7/8: effect of the clustering knobs — retrieved centroids c0
(quality and time rise with c0, with diminishing returns) and total clusters
c (AQT falls with c; quality peaks at a moderate c)."""
from __future__ import annotations

import jax

from repro.core import lider
from .common import csv_line, make_task, mrr_at_10, time_search


def run(n: int = 30_000, k: int = 100, verbose: bool = True):
    corpus, queries, rel, _ = make_task(n)
    rng = jax.random.PRNGKey(0)
    lines = []

    # Fig 7: fix c, sweep c0 (n_probe).
    c = max(16, n // 1000)
    idx = lider.build_lider(
        rng, corpus,
        lider.LiderConfig(n_clusters=c, n_probe=40, n_arrays=10, n_leaves=5,
                          kmeans_iters=10),
    )
    for c0 in (1, 2, 5, 10, 20):
        fn = lambda q, c0=c0: lider.search_lider(idx, q, k=k, n_probe=c0, r0=4)
        lines.append(csv_line(
            f"fig7/c0_{c0}", time_search(fn, queries) * 1e6,
            f"mrr10={mrr_at_10(fn(queries).ids, rel):.4f}"))
        if verbose:
            print(lines[-1])

    # Fig 8: fix c0, sweep c.
    for c in (8, 16, 32, 64, 128):
        idx = lider.build_lider(
            rng, corpus,
            lider.LiderConfig(n_clusters=c, n_probe=10, n_arrays=6, n_leaves=4,
                              kmeans_iters=8),
        )
        fn = lambda q: lider.search_lider(idx, q, k=k, n_probe=10, r0=4)
        lines.append(csv_line(
            f"fig8/c_{c}", time_search(fn, queries) * 1e6,
            f"mrr10={mrr_at_10(fn(queries).ids, rel):.4f}"))
        if verbose:
            print(lines[-1])
    return lines


if __name__ == "__main__":
    run()

"""Paper Fig. 5/6: speed-quality trade-off curves (AQT vs MRR@10) obtained by
sweeping each method's knob — LIDER (n_probe, plus adaptive prune_margin
points), IVFPQ (n_probe), MP-LSH (n_probes), SK-LSH (n_candidates).

The fixed-knob sweep here is the paper's offline table; the *runtime*
trade-off (adaptive margin + Pareto operating-point selection, with
device-accurate AQT accounting) lives in ``repro.tuning.pareto`` /
``BENCH_tradeoff.json`` (DESIGN.md §Adaptive speed-quality control plane)."""
from __future__ import annotations

import jax

from repro.core import lider
from repro.core.baselines import (
    build_ivfpq, build_mplsh, build_sklsh, ivfpq_search, mplsh_search, sklsh_search,
)
from .common import csv_line, make_task, mrr_at_10, time_search


def run(n: int = 30_000, k: int = 100, verbose: bool = True):
    corpus, queries, rel, _ = make_task(n)
    rng = jax.random.PRNGKey(0)
    lines = []

    idx = lider.build_lider(
        rng, corpus,
        lider.LiderConfig(n_clusters=max(16, n // 1000), n_probe=40, n_arrays=10,
                          n_leaves=5, kmeans_iters=10),
    )
    for p in (2, 5, 10, 20, 40):
        fn = lambda q, p=p: lider.search_lider(idx, q, k=k, n_probe=p, r0=4)
        lines.append(csv_line(
            f"fig5/lider/probe{p}", time_search(fn, queries) * 1e6,
            f"mrr10={mrr_at_10(fn(queries).ids, rel):.4f}"))
        if verbose:
            print(lines[-1])

    # Adaptive points: a wide probe budget whose low-confidence probes the
    # margin rule masks per query (wall savings need the block-skipping
    # kernel, i.e. TPU — on CPU these rows show the quality axis only).
    for p, m in ((20, 0.05), (40, 0.05), (40, 0.1)):
        fn = lambda q, p=p, m=m: lider.search_lider(
            idx, q, k=k, n_probe=p, r0=4, prune_margin=m)
        lines.append(csv_line(
            f"fig5/lider/probe{p}-margin{m:g}",
            time_search(fn, queries) * 1e6,
            f"mrr10={mrr_at_10(fn(queries).ids, rel):.4f}"))
        if verbose:
            print(lines[-1])

    ivf = build_ivfpq(rng, corpus, n_subspaces=8, bits=8, kmeans_iters=8)
    for p in (2, 8, 32):
        fn = lambda q, p=p: ivfpq_search(ivf, q, k=k, n_probe=p)
        lines.append(csv_line(
            f"fig5/ivfpq/probe{p}", time_search(fn, queries) * 1e6,
            f"mrr10={mrr_at_10(fn(queries).ids, rel):.4f}"))
        if verbose:
            print(lines[-1])

    mp = build_mplsh(rng, corpus, n_tables=16)
    for p in (1, 4, 16):
        fn = lambda q, p=p: mplsh_search(mp, corpus, q, k=k, n_probes=p)
        lines.append(csv_line(
            f"fig5/mplsh/probe{p}", time_search(fn, queries) * 1e6,
            f"mrr10={mrr_at_10(fn(queries).ids, rel):.4f}"))
        if verbose:
            print(lines[-1])

    sk = build_sklsh(rng, corpus, n_arrays=16)
    for t in (100, 400, 1600):
        fn = lambda q, t=t: sklsh_search(sk, corpus, q, k=k, n_candidates=t)
        lines.append(csv_line(
            f"fig5/sklsh/cand{t}", time_search(fn, queries) * 1e6,
            f"mrr10={mrr_at_10(fn(queries).ids, rel):.4f}"))
        if verbose:
            print(lines[-1])
    return lines


if __name__ == "__main__":
    run()

"""Paper Table 4: the key re-scaling module removes out-of-range (OOR)
predictions and with them most large-error (LE) predictions.

OOR: unclipped prediction <= 0 or >= L-1 (the paper's truncation criterion).
LE: |pred - true position| > k (k=100). Reported: N_OOR, N_LE, N_overlap.

Three arms:
  * ``naive_raw``   — regression on raw decimal keys with textbook
    (uncentered) fp32 normal equations: the paper's failure mode (sum(x^2)
    ~ n*2^48 destroys fp32 precision -> wild slopes -> OOR).
  * ``centered_raw``— our closed-form *centered* fit on raw keys: a repo
    finding — centering alone removes most of the blow-up the paper
    attributes to raw keys (but keeps worse conditioning than rescaling).
  * ``rescaled``    — the paper's module (min-max to [0, L-1]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lsh, rescale, rmi
from .common import csv_line, make_task


def _counts(pred, qpos, length, k=100):
    oor = (pred <= 0) | (pred >= length - 1)
    le = jnp.abs(pred - qpos) > k
    return int(oor.sum()), int(le.sum()), int((oor & le).sum())


def _naive_fit_predict(x_train, x_query, length):
    """Textbook single linear regression, uncentered fp32 sums (the paper's
    no-rescaling arm)."""
    n = x_train.shape[0]
    y = jnp.arange(n, dtype=jnp.float32)
    sx = jnp.sum(x_train)
    sy = jnp.sum(y)
    sxx = jnp.sum(x_train * x_train)
    sxy = jnp.sum(x_train * y)
    denom = n * sxx - sx * sx
    slope = jnp.where(jnp.abs(denom) > 0, (n * sxy - sx * sy) / denom, 0.0)
    inter = (sy - slope * sx) / n
    return slope * x_query + inter


def run(n: int = 30_000, n_queries: int = 2000, verbose: bool = True):
    from repro.data import synthetic

    # Coarse-mode corpus (few clusters) + M=30: decimal keys ~1e9 with a
    # clumped distribution — the regime where uncentered fp32 normal
    # equations lose precision (the paper's Table-4 key magnitudes).
    corpus = synthetic.retrieval_corpus(0, n, 64, n_modes=max(8, n // 1000))
    queries, _ = synthetic.retrieval_queries(1, corpus, n_queries)
    params = lsh.make_lsh(jax.random.PRNGKey(0), corpus.shape[1], 1, 30)
    keys = lsh.hash_vectors(params, corpus)[:, 0]
    skeys, _ = lsh.sort_hashkeys(keys)
    qkeys = lsh.hash_vectors(params, queries)[:, 0]
    qpos = lsh.query_position(skeys, qkeys).astype(jnp.float32)

    lines = []
    raw = skeys.astype(jnp.float32)
    qraw = qkeys.astype(jnp.float32)

    pred_naive = _naive_fit_predict(raw, qraw, n)
    o0, l0, ov0 = _counts(pred_naive, qpos, n)
    lines.append(csv_line("table4/naive_raw", 0.0, f"oor={o0};le={l0};overlap={ov0}"))

    p_raw = rmi.fit_rmi(raw, jnp.ones_like(raw), n_leaves=5)
    pred_raw = rmi.predict_raw(p_raw, qraw)
    o1, l1, ov1 = _counts(pred_raw, qpos, n)
    lines.append(csv_line("table4/centered_raw", 0.0, f"oor={o1};le={l1};overlap={ov1}"))

    resc = rescale.fit_rescale(skeys)
    scaled = rescale.rescale(resc, skeys)
    p = rmi.fit_rmi(scaled, jnp.ones_like(scaled), n_leaves=5)
    pred = rmi.predict_raw(p, rescale.rescale(resc, qkeys))
    o2, l2, ov2 = _counts(pred, qpos, n)
    lines.append(csv_line("table4/rescaled", 0.0, f"oor={o2};le={l2};overlap={ov2}"))

    # Paper's claim, scale-adjusted: re-scaling (nearly) eliminates OOR and
    # the OOR/LE overlap; remaining LE are RMI capacity (W), not range error.
    assert o2 <= o0 and ov2 <= ov0, "re-scaling must beat the naive raw fit on OOR"
    if verbose:
        for ln in lines:
            print(ln)
    return lines


if __name__ == "__main__":
    run()

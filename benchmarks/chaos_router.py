"""Chaos router benchmark: replica kill + rolling update + hedged stragglers.

Emits ``BENCH_router.json`` so the multi-replica serving fabric (DESIGN.md
§Replica fabric) is exercised and its guarantees gated per commit (CI runs
``--smoke``). Two legs over the same seeded corpus:

**Leg A — failover under a rolling update.** Three replicas serve a seeded
open-loop burst trace while a rolling index update is in flight and the
seeded fault plan injects two dispatch failures and hard-kills ``r1`` a few
drain ticks in (plus scheduled heartbeat misses). Every delivered answer is
checked **bit-identical** against a direct ``search_lider`` on the params of
the *generation it claims to have been served at*, at the ladder rung it
claims — any mismatch is a wrong-generation answer and fails the run. After
the roll, every still-serveable replica must answer bit-identically to a
single engine updated once with the same ``update_fn``.

**Leg B/C — hedging vs a straggling replica.** Two replicas, same trace,
``r0`` straggles on a seeded quarter of its dispatches (targeted
``straggle`` spec). Leg B hedges at the ``hedge_quantile`` latency
deadline; leg C runs the identical workload with hedging disabled.
Hedging must not lose: hedged p99 <= unhedged p99, with at least one
hedge win recorded.

Gates (non-zero exit):
- leg A: availability >= 0.99; delivered wrong-generation == 0 (router
  guard discards count separately); replica kill observed and the fleet
  kept answering; roll completed with every replica updated or explicitly
  skipped-as-stale; post-roll bit-identity vs a single updated engine;
  recall >= measured ladder floor (worst generation x worst rung) - tol
- leg B/C: hedged p99 <= unhedged p99; >=1 hedge win; both legs answer
  every query (availability == 1)

Usage:
    PYTHONPATH=src python -m benchmarks.chaos_router [--smoke]
        [--out BENCH_router.json] [--n 20000] [--dim 64] [--k 10]
"""
from __future__ import annotations

import argparse
import json
import time

RECALL_TOLERANCE = 0.02  # slack under the measured worst-mode floor
KILL_AT_DRAIN = 8  # drain tick that hard-kills r1 (roll still in flight)


def _build(n, dim, n_clusters, pool, seed=0):
    import jax
    import numpy as np

    from repro.core import lider
    from repro.core.baselines import flat_search
    from repro.core.utils import l2_normalize

    rng = jax.random.PRNGKey(seed)
    kc, kx, kn, kq = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (n_clusters, dim))
    assign = jax.random.randint(kx, (n,), 0, n_clusters)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kn, (n, dim)))
    q = np.asarray(
        l2_normalize(x[:pool] + 0.05 * jax.random.normal(kq, (pool, dim))),
        np.float32,
    )
    n_base = int(n * 0.9)  # 10% held out for the rolling upsert
    cfg = lider.LiderConfig(n_clusters=n_clusters, n_probe=8)
    params = lider.build_lider(jax.random.PRNGKey(2), x[:n_base], cfg)
    gt = np.asarray(flat_search(x, jax.numpy.asarray(q), k=10).ids)
    return params, np.asarray(jax.device_get(x[n_base:])), q, gt


def _point_kwargs(point):
    keys = (
        "n_probe", "r0", "prune_margin", "refine", "rescore_factor", "block_c"
    )
    return {k: point[k] for k in keys if k in point}


def _ref_search(params, q, k, base_kw, point=None):
    """Direct serial-path (ids, scores) at one operating point."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import lider

    eff = dict(base_kw)
    if point:
        eff.update(_point_kwargs(point))
    out = lider.search_lider(params, jnp.asarray(q), k=k, **eff)
    top = out if hasattr(out, "ids") else out[0]
    return np.asarray(top.ids), np.asarray(top.scores)


def _calibrate(params, q, batch, k, base_kw, repeats=3):
    """Median warm full-batch search time — the workload's unit of time."""
    _ref_search(params, q[:batch], k, base_kw)  # compile
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _ref_search(params, q[:batch], k, base_kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _collect(router, rids):
    return [router.result(r) for r in rids]


def _answer_metrics(results, trace, gt, k):
    import numpy as np

    from repro.serving import QueryResult

    recalls, n_shed, n_degraded = [], 0, 0
    lat = []
    for res, arr in zip(results, trace):
        if not isinstance(res, QueryResult):
            n_shed += 1
            continue
        n_degraded += bool(res.degraded)
        lat.append(res.latency_s)
        got = set(np.asarray(res.ids)[:k].tolist())
        recalls.append(len(got & set(gt[arr.query_idx][:k].tolist())) / k)
    lat = np.asarray(lat) if lat else np.zeros(1)
    n = len(results)
    return {
        "n_arrivals": n,
        "availability": (n - n_shed) / max(n, 1),
        "shed_fraction": n_shed / max(n, 1),
        "degraded_fraction": n_degraded / max(n, 1),
        "recall": float(np.mean(recalls)) if recalls else 0.0,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
    }


def _generation_bit_check(results, trace, refs, q, k, base_kw, ladder):
    """Every delivered non-degraded answer must bit-match the direct search
    on the params of the generation it was stamped with, at its rung.

    ``refs`` maps generation -> params; rung references are computed
    lazily per (generation, rung). A stamp outside ``refs`` (a generation
    that never legitimately served) counts as wrong-generation outright.
    """
    from repro.serving import QueryResult

    import numpy as np

    ref_cache: dict = {}
    n_checked = wrong = 0
    for res, arr in zip(results, trace):
        if not isinstance(res, QueryResult) or res.degraded:
            continue
        key = (res.generation, res.rung)
        if res.generation not in refs:
            wrong += 1
            continue
        if key not in ref_cache:
            point = (
                ladder[min(res.rung, len(ladder)) - 1]
                if res.rung > 0 and ladder
                else None
            )
            ref_cache[key] = _ref_search(
                refs[res.generation], q, k, base_kw, point
            )
        ids, scores = ref_cache[key]
        n_checked += 1
        ok = np.array_equal(
            np.asarray(res.ids), ids[arr.query_idx]
        ) and np.array_equal(np.asarray(res.scores), scores[arr.query_idx])
        wrong += not ok
    return n_checked, wrong


def _measure_floor(refs, q, gt, k, base_kw, ladder, weights):
    """Measured recall of every mode the router may serve during the run:
    each live generation x (nominal + every ladder rung), weighted by how
    often each pool query actually arrives (delivered recall is
    arrival-weighted, so the floor must be too). The min is the floor the
    delivered recall is gated against."""
    import numpy as np

    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    per_mode = {}
    for gen, params in refs.items():
        for name, point in [("nominal", None)] + [
            (f"rung{i + 1}", r) for i, r in enumerate(ladder)
        ]:
            ids, _ = _ref_search(params, q, k, base_kw, point)
            rows = np.asarray([
                len(set(ids[i, :k]) & set(gt[i, :k])) / k
                for i in range(len(q))
            ])
            per_mode[f"gen{gen}:{name}"] = float((rows * w).sum())
    return per_mode, min(per_mode.values())


def _make_router(params, n_replicas, *, batch, k, dim, ladder, sched_cfg,
                 router_cfg, health, plan):
    from repro.serving import (
        DegradePolicy, QueryRouter, RetrievalEngine, clone_params,
        make_backend,
    )

    engines = []
    for i in range(n_replicas):
        engines.append(
            RetrievalEngine(
                make_backend("lider", None, updatable=True, n_probe=8),
                batch_size=batch, k=k, dim=dim,
                params=params if i == 0 else clone_params(params),
                policy=DegradePolicy(ladder=tuple(ladder)),
            )
        )
    router = QueryRouter(
        engines,
        config=router_cfg,
        health=health,
        scheduler=sched_cfg,
        fault_plan=plan,
    )
    router.warmup()
    return router


def _leg_failover_roll(params, new_x, q, gt, *, args, base_kw, ladder,
                       sched_cfg, s_batch):
    """Leg A: 3 replicas, kill r1 mid-trace while a rolling update is in
    flight; verify availability, generation bit-identity, the roll's
    terminal state, and post-roll bit-identity vs a single updated engine."""
    import jax.numpy as jnp
    import numpy as np

    from repro import faults
    from repro.core import update as update_lib
    from repro.serving import HealthPolicy, RouterConfig
    from repro.serving.traffic import make_trace, run_open_loop

    plan = faults.FaultPlan(
        [
            # Two isolated dispatch failures: bounded failover, no deaths.
            faults.FaultSpec("replica_dispatch", mode="fail", times=(4, 9)),
            # Hard-kill r1 a few drain ticks in — while the roll (started
            # just before replay) is still updating r0, so the kill lands
            # inside the roll window and r1 is skipped-as-stale.
            faults.FaultSpec(
                "replica_kill", mode="kill_replica",
                times=(KILL_AT_DRAIN,), payload={"replica": "r1"},
            ),
            # A few scheduled heartbeat misses (suspect churn, recovery).
            faults.FaultSpec(
                "replica_heartbeat", mode="miss", probability=0.25, count=3,
            ),
        ],
        seed=13,
    )
    router = _make_router(
        params, 3, batch=args.batch_size, k=args.k, dim=q.shape[1],
        ladder=ladder, sched_cfg=sched_cfg,
        router_cfg=RouterConfig(hedge_quantile=0.9, hedge_min_samples=8),
        health=HealthPolicy(heartbeat_interval_s=0.005), plan=plan,
    )
    trace = make_trace(
        seed=args.seed, n_arrivals=args.arrivals, pool_size=len(q),
        mean_rate=2.0 * args.batch_size / s_batch, pattern="burst",
        n_tenants=2,
    )
    new_rows = jnp.asarray(new_x)

    def up(p):
        return update_lib.upsert(p, new_rows)

    router.control.apply_updates(up, block=False)
    rids = run_open_loop(router, trace, q)
    router.control.wait(timeout=300.0)
    # Post-roll tail: traffic that must be answered at the NEW generation,
    # so the bit-identity check below covers both sides of the
    # mixed-generation window.
    tail = make_trace(
        seed=args.seed + 7, n_arrivals=max(64, args.arrivals // 4),
        pool_size=len(q), mean_rate=2.0 * args.batch_size / s_batch,
    )
    rids_tail = run_open_loop(router, tail, q)
    while router.pending_requests:
        router.drain()
    results = _collect(router, rids) + _collect(router, rids_tail)
    trace = list(trace) + list(tail)

    st = router.stats
    refs = {0: params, 1: update_lib.upsert(params, new_rows)[0]}
    gens_served = sorted({
        r.generation for r in results if hasattr(r, "generation")
    })
    n_checked, wrong = _generation_bit_check(
        results, trace, refs, q, args.k, base_kw, ladder
    )
    weights = np.bincount(
        [a.query_idx for a in trace], minlength=len(q)
    )
    per_mode, floor = _measure_floor(
        refs, q, gt, args.k, base_kw, ladder, weights
    )
    m = _answer_metrics(results, trace, gt, args.k)

    # Post-roll: every replica still in routing serves the new generation
    # bit-identically to one engine updated once with the same update_fn.
    post_roll = {}
    ref_ids, ref_scores = _ref_search(refs[1], q, args.k, base_kw)
    for rep in router.replicas:
        if not rep.serveable():
            continue
        ids, scores = _ref_search(rep.engine.params, q, args.k, base_kw)
        post_roll[rep.name] = bool(
            rep.generation == 1
            and np.array_equal(ids, ref_ids)
            and np.array_equal(scores, ref_scores)
        )
    stats = router.stats_dict()
    router.close()

    report = {
        "metrics": m,
        "recall_floor_by_mode": per_mode,
        "recall_floor": floor,
        "bit_checked": n_checked,
        "generations_served": gens_served,
        "wrong_generation_delivered": wrong,
        "post_roll_bit_identical": post_roll,
        "router": stats,
        "fault_sites": plan.site_counts(),
    }
    failures = []
    if m["availability"] < 0.99:
        failures.append(f"leg A availability {m['availability']:.4f} < 0.99")
    if wrong:
        failures.append(f"leg A delivered {wrong} wrong-generation answers")
    if not n_checked:
        failures.append("leg A bit-identity check never ran")
    if 1 not in gens_served:
        failures.append(
            f"leg A never delivered a post-roll answer ({gens_served})"
        )
    if st.n_replica_kills != 1:
        failures.append(
            f"leg A kill site fired {st.n_replica_kills} times (want 1)"
        )
    if st.n_rolls_completed != 1:
        failures.append("leg A rolling update did not complete")
    updated, skipped = st.n_roll_replicas_updated, st.n_roll_replicas_skipped
    if updated < 2 or updated + skipped != 3:
        failures.append(
            f"leg A roll terminal state updated={updated} skipped={skipped}"
        )
    if not post_roll or not all(post_roll.values()):
        failures.append(f"leg A post-roll bit-identity failed: {post_roll}")
    if m["recall"] < floor - RECALL_TOLERANCE:
        failures.append(
            f"leg A recall {m['recall']:.4f} < floor {floor:.4f} - "
            f"{RECALL_TOLERANCE}"
        )
    return report, failures


def _leg_hedging(params, q, gt, *, args, base_kw, ladder, sched_cfg,
                 s_batch):
    """Legs B/C: identical straggling workload with and without hedging."""
    from repro import faults
    from repro.serving import RouterConfig
    from repro.serving.traffic import make_trace, run_open_loop

    straggle_s = max(8.0 * s_batch, 0.04)
    trace = make_trace(
        seed=args.seed + 1, n_arrivals=args.arrivals_hedge, pool_size=len(q),
        mean_rate=2.0 * args.batch_size / s_batch, pattern="zipf",
    )

    def one(hedge_quantile):
        # Plans are stateful (per-site call counters): build one per run so
        # both legs see the same seeded straggle process. The straggler is
        # intermittent — a constant one would never be picked as primary
        # (it is always busy sleeping) and hedging would have nothing to
        # rescue.
        plan = faults.FaultPlan(
            [
                faults.FaultSpec(
                    "replica_dispatch", mode="straggle",
                    delay_s=straggle_s, probability=0.25,
                    payload={"replica": "r0"},
                ),
            ],
            seed=29,
        )
        router = _make_router(
            params, 2, batch=args.batch_size, k=args.k, dim=q.shape[1],
            ladder=ladder, sched_cfg=sched_cfg,
            router_cfg=RouterConfig(
                hedge_quantile=hedge_quantile, hedge_min_samples=4,
            ),
            health=None, plan=plan,
        )
        rids = run_open_loop(router, trace, q)
        while router.pending_requests:
            router.drain()
        results = _collect(router, rids)
        m = _answer_metrics(results, trace, gt, args.k)
        stats = router.stats_dict()
        router.close()
        return m, stats

    # p80-of-recent deadline: straggles poison ~10% of the batch-time
    # samples, so p80 sits just above the honest service time — true
    # stragglers get hedged early, while most honest batches (dynamic
    # batch sizes vary) do not trigger wasteful hedges.
    hedged, hedged_stats = one(0.8)
    unhedged, unhedged_stats = one(None)

    report = {
        "straggle_s": straggle_s,
        "hedged": hedged,
        "unhedged": unhedged,
        "hedged_router": hedged_stats,
        "unhedged_router": unhedged_stats,
    }
    failures = []
    if hedged["p99_latency_s"] > unhedged["p99_latency_s"]:
        failures.append(
            f"hedged p99 {hedged['p99_latency_s'] * 1e3:.1f}ms > unhedged "
            f"{unhedged['p99_latency_s'] * 1e3:.1f}ms"
        )
    if hedged_stats["n_hedge_wins"] < 1:
        failures.append("hedging never won against the straggler")
    if unhedged_stats["n_hedges"] != 0:
        failures.append("control leg hedged despite hedge_quantile=None")
    for name, m in (("hedged", hedged), ("unhedged", unhedged)):
        if m["availability"] < 1.0:
            failures.append(
                f"{name} leg shed queries (availability "
                f"{m['availability']:.4f})"
            )
    return report, failures


def _bench(args):
    from repro.serving import SchedulerConfig

    params, new_x, q, gt = _build(
        args.n, args.dim, args.n_clusters, args.pool, seed=args.seed
    )
    base_kw = dict(n_probe=8)
    ladder = [{"n_probe": 4}, {"n_probe": 2}]
    s_batch = _calibrate(params, q, args.batch_size, args.k, base_kw)
    sched_cfg = SchedulerConfig(
        dynamic_batch=True, min_batch=max(1, args.batch_size // 8),
        slo_s=8.0 * s_batch,
    )

    leg_a, fail_a = _leg_failover_roll(
        params, new_x, q, gt, args=args, base_kw=base_kw, ladder=ladder,
        sched_cfg=sched_cfg, s_batch=s_batch,
    )
    leg_bc, fail_bc = _leg_hedging(
        params, q, gt, args=args, base_kw=base_kw, ladder=ladder,
        sched_cfg=sched_cfg, s_batch=s_batch,
    )

    report = {
        "shape": {
            "n": args.n, "dim": args.dim, "n_clusters": args.n_clusters,
            "pool": args.pool, "arrivals": args.arrivals,
            "arrivals_hedge": args.arrivals_hedge,
            "batch_size": args.batch_size, "k": args.k, "seed": args.seed,
            "ladder": ladder, "kill_at_drain": KILL_AT_DRAIN,
        },
        "calibration": {
            "batch_service_s": s_batch, "slo_s": sched_cfg.slo_s,
        },
        "failover_roll": leg_a,
        "hedging": leg_bc,
        "failures": fail_a + fail_bc,
    }
    report["ok"] = not report["failures"]
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true", help="small shapes (CI)")
    ap.add_argument("--out", default="BENCH_router.json")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--n-clusters", type=int, default=32)
    ap.add_argument("--pool", type=int, default=256,
                    help="distinct queries behind the Zipf popularity")
    ap.add_argument("--arrivals", type=int, default=2000)
    ap.add_argument("--arrivals-hedge", type=int, default=1200,
                    help="arrivals per hedging leg (run twice: on/off)")
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-check", dest="check", action="store_false",
                    help="report only; do not gate")
    args = ap.parse_args()
    if args.smoke:
        args.n = 4000
        args.dim = 32
        args.n_clusters = 16
        args.pool = 64
        args.arrivals = 600
        args.arrivals_hedge = 400

    report = _bench(args)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)

    a = report["failover_roll"]
    h = report["hedging"]
    print(
        f"chaos router @ n={report['shape']['n']} "
        f"(kill@drain{report['shape']['kill_at_drain']})\n"
        f"  leg A: availability {a['metrics']['availability']:.4f} | "
        f"delivered wrong-generation {a['wrong_generation_delivered']} "
        f"({a['bit_checked']} checked) | "
        f"roll updated={a['router']['n_roll_replicas_updated']} "
        f"skipped={a['router']['n_roll_replicas_skipped']} | "
        f"failovers {a['router']['n_failovers']} | "
        f"recall {a['metrics']['recall']:.4f} "
        f"(floor {a['recall_floor']:.4f})\n"
        f"  leg B/C: hedged p99 {h['hedged']['p99_latency_s'] * 1e3:.1f}ms "
        f"vs unhedged {h['unhedged']['p99_latency_s'] * 1e3:.1f}ms | "
        f"hedges {h['hedged_router']['n_hedges']} "
        f"wins {h['hedged_router']['n_hedge_wins']} "
        f"(straggle {h['straggle_s'] * 1e3:.0f}ms)\n"
        f"-> {args.out}"
    )
    if report["failures"]:
        for msg in report["failures"]:
            print(f"FAIL: {msg}")
        if args.check:
            raise SystemExit(1)
    print("all chaos-router gates passed" if report["ok"] else "")


if __name__ == "__main__":
    main()

"""Fused gather-score-reduce verification kernel: parity with the
materialized reference across padding/dtype/blocking edge cases, the
cluster-major grouped kernel and its schedule pre-pass, plus the end-to-end
LIDER regressions (DESIGN.md §Verification-kernel, §Cluster-major
schedule)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lider
from repro.core.utils import l2_normalize
from repro.kernels import fused_verify, fused_verify_grouped, ref
from repro.kernels.quant import quantize_rows, quantize_rows_int4
from repro.kernels.schedule import build_cluster_schedule


def _case(seed, n, d, b, c, dtype, id_lo=-1):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    embs = jax.random.normal(k1, (n, d), dtype)
    ids = jax.random.randint(k2, (b, c), id_lo, n)
    q = jax.random.normal(k3, (b, d), dtype)
    return embs, ids, q


def _assert_parity(embs, row_ids, q, k, block_c, out_ids=None, rtol=1e-6):
    gi, gs = fused_verify(
        embs, row_ids, q, k=k, out_ids=out_ids, block_c=block_c, interpret=True
    )
    wi, ws = ref.verify_topk_ref(embs, row_ids, q, k=k, out_ids=out_ids)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), rtol=rtol, atol=rtol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_padded_ids(dtype):
    """-1 slots are excluded and never win a top-k slot."""
    embs, ids, q = _case(0, 40, 32, 3, 17, dtype)
    ids = ids.at[:, ::3].set(-1)
    _assert_parity(embs, ids, q, k=5, block_c=8)


@pytest.mark.parametrize("c,block_c", [(17, 8), (21, 4), (7, 16), (64, 16)])
def test_parity_c_not_multiple_of_block(c, block_c):
    embs, ids, q = _case(c, 50, 16, 2, c, jnp.float32)
    _assert_parity(embs, ids, q, k=4, block_c=block_c)


def test_parity_k_exceeds_valid_candidates():
    """k > #unique valid ids: tail slots are (-1, -inf), same as the ref."""
    embs, ids, q = _case(3, 30, 16, 2, 6, jnp.float32)
    ids = ids.at[:, 3:].set(-1)  # 3 valid per row, duplicates possible
    gi, gs = fused_verify(embs, ids, q, k=8, block_c=4, interpret=True)
    wi, ws = ref.verify_topk_ref(embs, ids, q, k=8)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert (np.asarray(gi)[:, 3:] == -1).all()
    assert np.isneginf(np.asarray(gs)[:, 3:]).all()


def test_parity_duplicate_ids_deduped():
    """Duplicate candidates occupy one top-k slot, not several."""
    embs, ids, q = _case(4, 25, 16, 2, 12, jnp.float32, id_lo=0)
    ids = ids.at[:, 6:].set(ids[:, :6])  # every candidate duplicated
    gi, _ = fused_verify(embs, ids, q, k=6, block_c=4, interpret=True)
    _assert_parity(embs, ids, q, k=6, block_c=4)
    for row in np.asarray(gi):
        v = row[row >= 0]
        assert len(set(v.tolist())) == len(v)


def test_parity_score_ties_break_by_smallest_id():
    """Distinct ids with bit-equal scores (duplicate table rows) must come
    out in the reference order: smallest id first."""
    k1, k3 = jax.random.split(jax.random.PRNGKey(11), 2)
    embs = jax.random.normal(k1, (20, 16))
    embs = embs.at[7].set(embs[2]).at[13].set(embs[2])  # 3-way score tie
    ids = jnp.asarray([[13, 2, 0, 7, 5, 13]])
    q = jax.random.normal(k3, (1, 16))
    _assert_parity(embs, ids, q, k=5, block_c=2)


def test_parity_out_ids_mapping():
    """row_ids gather rows; out_ids name/dedup them (the LIDER shape: flat
    (cluster, slot) rows in, global passage ids out)."""
    embs, rows, q = _case(5, 40, 16, 3, 10, jnp.float32, id_lo=0)
    out_ids = rows + 100  # distinct id space
    out_ids = out_ids.at[:, 1].set(-1)  # padding marked on out_ids only
    _assert_parity(embs, rows, q, k=4, block_c=4, out_ids=out_ids)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_large_shape_sweep(dtype):
    embs, ids, q = _case(6, 200, 64, 4, 70, dtype)
    rtol = 1e-6 if dtype == jnp.float32 else 2e-2
    _assert_parity(embs, ids, q, k=10, block_c=16, rtol=rtol)


@pytest.mark.parametrize("code_dtype", ["int8", "int4"])
def test_quantized_parity_block_c_exceeds_c(code_dtype):
    """Regression for the lane-aligned clamp ``bc = min(block_c, c)``: a
    block size larger than the candidate count (the kernel default 256 vs a
    tiny provisional list) must clamp, not pad the grid with out-of-range
    reads — and the clamp must stay exact on the quantized paths where the
    table width differs from the logical width (packed int4)."""
    embs_f, ids, q = _case(9, 40, 32, 3, 10, jnp.float32)
    quant = quantize_rows if code_dtype == "int8" else quantize_rows_int4
    table, scales = quant(embs_f)
    gi, gs = fused_verify(
        table, ids, q, k=4, scales=scales, block_c=64,
        code_dtype=code_dtype, interpret=True,
    )
    wi, ws = ref.verify_topk_ref(
        table, ids, q, k=4, scales=scales, code_dtype=code_dtype
    )
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


# ---------------------------------------------------------------------------
# Cluster-major schedule (DESIGN.md §Cluster-major schedule)
# ---------------------------------------------------------------------------


def _zipf_cids(rng, b, p, n_clusters, a=1.3):
    w = 1.0 / np.arange(1, n_clusters + 1) ** a
    w /= w.sum()
    return np.stack(
        [rng.choice(n_clusters, size=p, replace=False, p=w) for _ in range(b)]
    ).astype(np.int32)


def test_build_cluster_schedule_invariants():
    """The schedule is a bijection over kept pairs: every kept (query,
    probe) pair lands in exactly one (step, slot) that points back at it,
    pruned pairs are excluded, steps stream clusters in ascending order, and
    Zipf-skewed probe lists actually share steps (ratio > 1)."""
    rng = np.random.default_rng(3)
    cids = _zipf_cids(rng, 24, 4, 16)
    pruned = rng.random(cids.shape) < 0.2
    sched = build_cluster_schedule(cids, block_q=8, pruned=pruned)
    keep = ~pruned
    qs, ps = np.nonzero(keep)
    st, sl = sched.pair_step[qs, ps], sched.pair_slot[qs, ps]
    assert (st >= 0).all() and (sl >= 0).all() and (sl < 8).all()
    np.testing.assert_array_equal(sched.sched_cids[st], cids[qs, ps])
    np.testing.assert_array_equal(sched.sched_qids[st, sl], qs)
    assert (sched.pair_step[pruned] == -1).all()
    assert (sched.pair_slot[pruned] == -1).all()
    # each scheduled (step, slot) is used by at most one pair
    assert len(set(zip(st.tolist(), sl.tolist()))) == len(st)
    real = sched.sched_cids[: sched.n_steps]
    assert (np.diff(real) >= 0).all()
    assert sched.n_pairs == int(keep.sum())
    assert sched.sharing_ratio > 1.0
    # padding steps carry empty query tiles
    assert (sched.sched_qids[sched.n_steps :] == -1).all()
    # block_q=1 degenerates to the per-query loop order: one pair per step
    s1 = build_cluster_schedule(cids, block_q=1, pruned=pruned)
    assert s1.n_steps == s1.n_pairs == int(keep.sum())


def _dense_slot_ids(sched, lp):
    """Every scheduled slot scores its cluster's full Lp flat rows."""
    s = sched.sched_cids.shape[0]
    out = np.full((s, sched.block_q, lp), -1, np.int32)
    step, slot = np.nonzero(sched.sched_qids >= 0)
    out[step, slot] = sched.sched_cids[step, None] * lp + np.arange(lp)
    return out


@pytest.mark.parametrize("code_dtype", ["int8", "int4"])
def test_grouped_kernel_matches_ref(code_dtype):
    """fused_verify_grouped (interpret) is bit-exact — ids AND scores —
    against the materialized grouped oracle on a Zipf-skewed schedule, for
    both code dtypes."""
    c, lp, d, b, p, block_q = 6, 16, 32, 5, 3, 4
    k1, k2 = jax.random.split(jax.random.PRNGKey(17), 2)
    embs_f = jax.random.normal(k1, (c, lp, d))
    q = jax.random.normal(k2, (b, d))
    quant = quantize_rows if code_dtype == "int8" else quantize_rows_int4
    codes, scales = quant(embs_f)
    sched = build_cluster_schedule(
        _zipf_cids(np.random.default_rng(5), b, p, c), block_q=block_q
    )
    slot_ids = jnp.asarray(_dense_slot_ids(sched, lp))
    args = (
        codes, scales, q,
        jnp.asarray(sched.sched_cids), jnp.asarray(sched.sched_qids),
        slot_ids,
    )
    gi, gs = fused_verify_grouped(
        *args, kp=6, block_q=block_q, block_c=8, code_dtype=code_dtype,
        interpret=True,
    )
    wi, ws = ref.verify_topk_grouped_ref(*args, kp=6, code_dtype=code_dtype)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


@pytest.fixture(scope="module", params=["int8", "int4"])
def quantized_lider(request):
    rng = jax.random.PRNGKey(7)
    kc, kx, kq, kb = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (16, 32))
    assign = jax.random.randint(kx, (1500,), 0, 16)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kq, (1500, 32)))
    q = l2_normalize(x[:8] + 0.05 * jax.random.normal(kb, (8, 32)))
    cfg = lider.LiderConfig(
        n_clusters=16, n_probe=4, n_arrays=2, n_leaves=2, kmeans_iters=5,
        storage_dtype=request.param,
    )
    params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
    return params, q


def test_cluster_major_matches_per_query_schedule(quantized_lider):
    """Acceptance: the cluster-major search is bit-exact — ids AND scores —
    against the per-query schedule; block_q is a pure loop-order change."""
    params, q = quantized_lider
    base = lider.search_lider(params, q, k=10, n_probe=4, r0=8)
    for bq in (1, 4, 8):
        got = lider.search_lider(params, q, k=10, n_probe=4, r0=8, block_q=bq)
        np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(base.ids))
        np.testing.assert_array_equal(
            np.asarray(got.scores), np.asarray(base.scores)
        )


def test_cluster_major_invariant_to_query_order(quantized_lider):
    """Shuffling the batch only permutes the outputs: the schedule's
    determinism contract (cluster asc, query asc, probe asc) means a query's
    results cannot depend on where it sits in the batch or which other
    queries share its steps."""
    params, q = quantized_lider
    base = lider.search_lider(params, q, k=10, n_probe=4, r0=8, block_q=4)
    perm = np.random.default_rng(0).permutation(q.shape[0])
    got = lider.search_lider(
        params, q[jnp.asarray(perm)], k=10, n_probe=4, r0=8, block_q=4
    )
    np.testing.assert_array_equal(
        np.asarray(got.ids), np.asarray(base.ids)[perm]
    )
    np.testing.assert_array_equal(
        np.asarray(got.scores), np.asarray(base.scores)[perm]
    )


def test_cluster_major_parity_under_prune_margin(quantized_lider):
    """Pruned probes drop out of the schedule (pair_step = -1) instead of
    being masked in-kernel; outputs and the pruned-stats mask must still
    match the per-query path exactly."""
    params, q = quantized_lider
    base, pruned_b = lider.search_lider(
        params, q, k=10, n_probe=4, r0=8, prune_margin=0.15, with_stats=True
    )
    got, pruned_g = lider.search_lider(
        params, q, k=10, n_probe=4, r0=8, prune_margin=0.15, with_stats=True,
        block_q=4,
    )
    np.testing.assert_array_equal(np.asarray(pruned_g), np.asarray(pruned_b))
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(base.ids))
    np.testing.assert_array_equal(
        np.asarray(got.scores), np.asarray(base.scores)
    )
    assert np.asarray(pruned_g).any()  # the margin actually pruned probes


def test_cluster_major_rejects_float_banks(small_lider):
    params, q = small_lider
    with pytest.raises(ValueError, match="quantized"):
        lider.search_lider(params, q, k=10, n_probe=4, r0=8, block_q=4)


@pytest.fixture(scope="module")
def small_lider():
    rng = jax.random.PRNGKey(7)
    kc, kx, kq, kb = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (16, 32))
    assign = jax.random.randint(kx, (1500,), 0, 16)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kq, (1500, 32)))
    q = l2_normalize(x[:8] + 0.05 * jax.random.normal(kb, (8, 32)))
    cfg = lider.LiderConfig(
        n_clusters=16, n_probe=4, n_arrays=2, n_leaves=2, kmeans_iters=5
    )
    params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
    return params, q


def test_search_lider_fused_matches_unfused(small_lider):
    """Regression: the end-to-end fused path returns the exact unfused ids."""
    params, q = small_lider
    unfused = lider.search_lider(params, q, k=10, n_probe=4, r0=8, use_fused=False)
    fused = lider.search_lider(params, q, k=10, n_probe=4, r0=8, use_fused=True)
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(unfused.ids))
    np.testing.assert_allclose(
        np.asarray(fused.scores), np.asarray(unfused.scores), rtol=1e-6
    )


def test_incluster_merge_false_fused_matches_unfused(small_lider):
    """The per-pair (B, P, k) shape the distributed path scatters back."""
    params, q = small_lider
    routed = lider.route_queries(params, q, n_probe=4)
    unfused = lider.incluster_search(
        params, q, routed.ids, k=5, r0=8, merge=False, use_fused=False
    )
    fused = lider.incluster_search(
        params, q, routed.ids, k=5, r0=8, merge=False, use_fused=True
    )
    assert fused.ids.shape == (q.shape[0], 4, 5)
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(unfused.ids))

"""Fused gather-score-reduce verification kernel: parity with the
materialized reference across padding/dtype/blocking edge cases, plus the
end-to-end LIDER regression (DESIGN.md §Verification-kernel)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lider
from repro.core.utils import l2_normalize
from repro.kernels import fused_verify, ref


def _case(seed, n, d, b, c, dtype, id_lo=-1):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    embs = jax.random.normal(k1, (n, d), dtype)
    ids = jax.random.randint(k2, (b, c), id_lo, n)
    q = jax.random.normal(k3, (b, d), dtype)
    return embs, ids, q


def _assert_parity(embs, row_ids, q, k, block_c, out_ids=None, rtol=1e-6):
    gi, gs = fused_verify(
        embs, row_ids, q, k=k, out_ids=out_ids, block_c=block_c, interpret=True
    )
    wi, ws = ref.verify_topk_ref(embs, row_ids, q, k=k, out_ids=out_ids)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), rtol=rtol, atol=rtol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_padded_ids(dtype):
    """-1 slots are excluded and never win a top-k slot."""
    embs, ids, q = _case(0, 40, 32, 3, 17, dtype)
    ids = ids.at[:, ::3].set(-1)
    _assert_parity(embs, ids, q, k=5, block_c=8)


@pytest.mark.parametrize("c,block_c", [(17, 8), (21, 4), (7, 16), (64, 16)])
def test_parity_c_not_multiple_of_block(c, block_c):
    embs, ids, q = _case(c, 50, 16, 2, c, jnp.float32)
    _assert_parity(embs, ids, q, k=4, block_c=block_c)


def test_parity_k_exceeds_valid_candidates():
    """k > #unique valid ids: tail slots are (-1, -inf), same as the ref."""
    embs, ids, q = _case(3, 30, 16, 2, 6, jnp.float32)
    ids = ids.at[:, 3:].set(-1)  # 3 valid per row, duplicates possible
    gi, gs = fused_verify(embs, ids, q, k=8, block_c=4, interpret=True)
    wi, ws = ref.verify_topk_ref(embs, ids, q, k=8)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert (np.asarray(gi)[:, 3:] == -1).all()
    assert np.isneginf(np.asarray(gs)[:, 3:]).all()


def test_parity_duplicate_ids_deduped():
    """Duplicate candidates occupy one top-k slot, not several."""
    embs, ids, q = _case(4, 25, 16, 2, 12, jnp.float32, id_lo=0)
    ids = ids.at[:, 6:].set(ids[:, :6])  # every candidate duplicated
    gi, _ = fused_verify(embs, ids, q, k=6, block_c=4, interpret=True)
    _assert_parity(embs, ids, q, k=6, block_c=4)
    for row in np.asarray(gi):
        v = row[row >= 0]
        assert len(set(v.tolist())) == len(v)


def test_parity_score_ties_break_by_smallest_id():
    """Distinct ids with bit-equal scores (duplicate table rows) must come
    out in the reference order: smallest id first."""
    k1, k3 = jax.random.split(jax.random.PRNGKey(11), 2)
    embs = jax.random.normal(k1, (20, 16))
    embs = embs.at[7].set(embs[2]).at[13].set(embs[2])  # 3-way score tie
    ids = jnp.asarray([[13, 2, 0, 7, 5, 13]])
    q = jax.random.normal(k3, (1, 16))
    _assert_parity(embs, ids, q, k=5, block_c=2)


def test_parity_out_ids_mapping():
    """row_ids gather rows; out_ids name/dedup them (the LIDER shape: flat
    (cluster, slot) rows in, global passage ids out)."""
    embs, rows, q = _case(5, 40, 16, 3, 10, jnp.float32, id_lo=0)
    out_ids = rows + 100  # distinct id space
    out_ids = out_ids.at[:, 1].set(-1)  # padding marked on out_ids only
    _assert_parity(embs, rows, q, k=4, block_c=4, out_ids=out_ids)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_large_shape_sweep(dtype):
    embs, ids, q = _case(6, 200, 64, 4, 70, dtype)
    rtol = 1e-6 if dtype == jnp.float32 else 2e-2
    _assert_parity(embs, ids, q, k=10, block_c=16, rtol=rtol)


@pytest.fixture(scope="module")
def small_lider():
    rng = jax.random.PRNGKey(7)
    kc, kx, kq, kb = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (16, 32))
    assign = jax.random.randint(kx, (1500,), 0, 16)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kq, (1500, 32)))
    q = l2_normalize(x[:8] + 0.05 * jax.random.normal(kb, (8, 32)))
    cfg = lider.LiderConfig(
        n_clusters=16, n_probe=4, n_arrays=2, n_leaves=2, kmeans_iters=5
    )
    params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
    return params, q


def test_search_lider_fused_matches_unfused(small_lider):
    """Regression: the end-to-end fused path returns the exact unfused ids."""
    params, q = small_lider
    unfused = lider.search_lider(params, q, k=10, n_probe=4, r0=8, use_fused=False)
    fused = lider.search_lider(params, q, k=10, n_probe=4, r0=8, use_fused=True)
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(unfused.ids))
    np.testing.assert_allclose(
        np.asarray(fused.scores), np.asarray(unfused.scores), rtol=1e-6
    )


def test_incluster_merge_false_fused_matches_unfused(small_lider):
    """The per-pair (B, P, k) shape the distributed path scatters back."""
    params, q = small_lider
    routed = lider.route_queries(params, q, n_probe=4)
    unfused = lider.incluster_search(
        params, q, routed.ids, k=5, r0=8, merge=False, use_fused=False
    )
    fused = lider.incluster_search(
        params, q, routed.ids, k=5, r0=8, merge=False, use_fused=True
    )
    assert fused.ids.shape == (q.shape[0], 4, 5)
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(unfused.ids))

"""Index lifecycle: upsert/delete parity, tombstones, checkpointed serving,
capacity growth, and the serving-engine update hooks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering, lider, update
from repro.core.utils import recall_at_k
from repro.serving import RetrievalEngine, make_backend
from repro.training import checkpoint

CFG = lider.LiderConfig(
    n_clusters=64, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=10
)


@pytest.fixture(scope="module")
def split_indexes(corpus):
    """80/20 split sharing one set of centroids (layer-1-frozen lifecycle)."""
    x, q, gt = corpus
    n80 = int(x.shape[0] * 0.8)
    base_x, new_x = x[:n80], x[n80:]
    km = clustering.kmeans(jax.random.PRNGKey(2), base_x, CFG.n_clusters, iters=10)
    # Fix the capacity so the incremental index and the full rebuild agree on
    # shapes (the acceptance criterion's "given identical capacity").
    assignment, _ = clustering.assign_chunked(x, km.centroids)
    max_size = int(jnp.bincount(assignment, length=CFG.n_clusters).max())
    cfg = dataclasses.replace(
        CFG, capacity=lider.padded_capacity(max_size, None, CFG.pad_multiple)
    )
    full = lider.build_lider(jax.random.PRNGKey(2), x, cfg, centroids=km.centroids)
    base = lider.build_lider(jax.random.PRNGKey(2), base_x, cfg, centroids=km.centroids)
    return x, q, gt, base, new_x, full


def test_upsert_matches_full_rebuild(split_indexes):
    """build(80%) -> upsert(20%) == build(100%) — same bank, same results."""
    x, q, _, base, new_x, full = split_indexes
    up, stats = update.upsert(base, new_x)
    assert stats.n_added == new_x.shape[0]
    assert stats.n_refit >= 1
    assert not stats.capacity_grew
    for name in ("sorted_keys", "sorted_pos", "gids", "sizes"):
        np.testing.assert_array_equal(
            np.asarray(getattr(up.bank, name)),
            np.asarray(getattr(full.bank, name)),
            err_msg=name,
        )
    a = lider.search_lider(up, q, k=10, n_probe=8, r0=8)
    b = lider.search_lider(full, q, k=10, n_probe=8, r0=8)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_upsert_finds_new_passages(split_indexes):
    x, q, gt, base, new_x, _ = split_indexes
    up, _ = update.upsert(base, new_x)
    out = lider.search_lider(up, q, k=10, n_probe=8, r0=8)
    assert float(recall_at_k(out.ids, gt)) > 0.9


def test_upsert_learned_route(split_indexes):
    """The centroids-retriever route also lands every point in a cluster."""
    x, q, gt, base, new_x, _ = split_indexes
    up, stats = update.upsert(base, new_x, route="learned")
    assert stats.n_added == new_x.shape[0]
    assert int(up.bank.sizes.sum()) == int(base.bank.sizes.sum()) + new_x.shape[0]
    out = lider.search_lider(up, q, k=10, n_probe=8, r0=8)
    assert float(recall_at_k(out.ids, gt)) > 0.85


@pytest.mark.parametrize("threshold", [1.0, 0.0])
def test_deleted_ids_never_surface(corpus, threshold):
    """Tombstoned (and, at threshold 0, compacted) ids never appear."""
    x, q, _, = corpus
    p = lider.build_lider(jax.random.PRNGKey(2), x, CFG)
    before = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    dead = np.unique(np.asarray(before.ids)[:, :3].ravel())
    dead = dead[dead >= 0]
    d, stats = update.delete(p, jnp.asarray(dead), refit_threshold=threshold)
    assert stats.n_deleted == len(dead)
    assert (stats.n_refit > 0) == (threshold == 0.0)
    after = lider.search_lider(d, q, k=10, n_probe=8, r0=8)
    assert not np.intersect1d(np.asarray(after.ids), dead).size
    # live points are still served
    ids = np.asarray(after.ids)
    assert (ids >= 0).any(axis=-1).all()


def test_delete_then_upsert_reuses_capacity(corpus):
    x, _, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(2), x, CFG)
    d, _ = update.delete(p, jnp.arange(100, dtype=jnp.int32), refit_threshold=0.0)
    # compaction freed the slots: same capacity can absorb 100 new rows
    up, stats = update.upsert(d, x[:100] * 0.99)
    assert int(up.bank.sizes.sum()) == x.shape[0]
    assert int(up.bank.next_gid) == x.shape[0] + 100


def test_capacity_growth_keeps_pad_multiple(corpus):
    """Overflowing one cluster grows Lp in pad_multiple steps and the grown
    index still finds the new points."""
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(2), x, CFG)
    old_cap = p.capacity
    # aim a burst at one spot: clones of one corpus vector overflow its cluster
    burst = jnp.tile(x[:1], (2 * CFG.pad_multiple + old_cap, 1))
    up, stats = update.upsert(p, burst, pad_multiple=CFG.pad_multiple)
    assert stats.capacity_grew
    assert up.capacity > old_cap
    assert up.capacity % CFG.pad_multiple == 0
    assert int(up.bank.sizes.sum()) == x.shape[0] + burst.shape[0]
    out = lider.search_lider(up, x[:1], k=10, n_probe=8, r0=8)
    new_gids = set(range(x.shape[0], x.shape[0] + burst.shape[0]))
    assert new_gids & set(np.asarray(out.ids).ravel().tolist())


def test_checkpoint_roundtrip_bit_identical(corpus, tmp_path):
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(2), x, CFG)
    p, _ = update.upsert(p, x[:32] * 0.98)  # persist a *mutated* index
    checkpoint.save_index(str(tmp_path), p)
    p2 = checkpoint.load_index(str(tmp_path))
    for (path_a, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(p)[0],
        jax.tree_util.tree_flatten_with_path(p2)[0],
    ):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=str(path_a)
        )
    before = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    after = lider.search_lider(p2, q, k=10, n_probe=8, r0=8)
    np.testing.assert_array_equal(np.asarray(before.ids), np.asarray(after.ids))
    np.testing.assert_array_equal(
        np.asarray(before.scores), np.asarray(after.scores)
    )


def test_engine_apply_updates_generations(corpus):
    """Same-shape updates bump only the generation; growth also recompiles."""
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(2), x, CFG)
    search = make_backend("lider", None, updatable=True, n_probe=8, r0=8)
    engine = RetrievalEngine(search, batch_size=16, k=10, dim=x.shape[1], params=p)
    engine.warmup()
    grew = engine.apply_updates(lambda pr: update.upsert(pr, x[:8] * 0.97))
    assert not grew
    assert engine.generation == 1 and engine.recompiles == 0
    burst = jnp.tile(x[:1], (p.capacity + 8, 1))
    grew = engine.apply_updates(lambda pr: update.upsert(pr, burst))
    assert grew
    assert engine.generation == 2 and engine.recompiles == 1
    rids = [engine.submit(v) for v in np.asarray(q)[:16]]
    engine.drain()
    assert all(engine.result(r) is not None for r in rids)


def test_engine_requires_params_for_updates(corpus):
    x, _, _ = corpus
    search = make_backend("flat", None, x)
    engine = RetrievalEngine(search, batch_size=8, k=5, dim=x.shape[1])
    with pytest.raises(ValueError, match="params"):
        engine.apply_updates(lambda p: p)


def test_make_backend_rejects_unknown_kwargs(corpus):
    x, _, _ = corpus
    with pytest.raises(TypeError, match="n_prove"):
        make_backend("lider", None, n_prove=8)  # typo'd n_probe
    with pytest.raises(TypeError, match="refine"):
        make_backend("flat", None, x, refine=True)
    with pytest.raises(ValueError, match="unknown backend"):
        make_backend("annoy", None)
    with pytest.raises(ValueError, match="updatable"):
        make_backend("flat", None, x, updatable=True)
    # the mplsh probe knob is spelled n_probe like every other backend
    from repro.core.baselines import build_mplsh
    mp = build_mplsh(jax.random.PRNGKey(0), x)
    make_backend("mplsh", mp, x, n_probe=4)


def test_init_centroids_clear_error():
    x = jnp.zeros((10, 8))
    with pytest.raises(ValueError, match="10 points"):
        clustering.init_centroids(jax.random.PRNGKey(0), x, 32)


def test_param_specs_derived_from_bank_metadata():
    """Replicated-vs-sharded layout comes from ClusterBank field metadata:
    the shared LSH bank and scalar bank metadata stay replicated, every
    stacked per-cluster tensor is sharded on its leading axis."""
    from jax import ShapeDtypeStruct as SDS
    from jax.sharding import PartitionSpec as P
    from repro.core import bank as bank_lib
    from repro.core.core_model import CoreModelParams
    from repro.core.distributed import lider_param_specs
    from repro.core.lsh import LSHParams
    from repro.core.rescale import RescaleParams
    from repro.core.rmi import RMIParams

    c, h, lp, d, w = 8, 2, 16, 4, 3
    resc = lambda lead: RescaleParams(
        key_min=SDS(lead, jnp.uint32),
        key_max=SDS(lead, jnp.uint32),
        length=SDS(lead, jnp.float32),
    )
    rmi = lambda lead: RMIParams(
        root_w=SDS(lead, jnp.float32), root_b=SDS(lead, jnp.float32),
        leaf_w=SDS(lead + (w,), jnp.float32), leaf_b=SDS(lead + (w,), jnp.float32),
        length=SDS(lead, jnp.float32), max_err=SDS(lead + (w,), jnp.float32),
        n_leaves=w,
    )
    params = lider.LiderParams(
        centroid_cm=CoreModelParams(
            lsh=LSHParams(projections=SDS((d, 4), jnp.float32), n_arrays=2, key_len=2),
            rescale=resc((h,)), rmi=rmi((h,)),
            sorted_keys=SDS((h, c), jnp.uint32), sorted_ids=SDS((h, c), jnp.int32),
        ),
        centroids=SDS((c, d), jnp.float32),
        bank=bank_lib.ClusterBank(
            lsh=LSHParams(projections=SDS((d, 4), jnp.float32), n_arrays=2, key_len=2),
            rescale=resc((c, h)), rmi=rmi((c, h)),
            sorted_keys=SDS((c, h, lp), jnp.uint32),
            sorted_pos=SDS((c, h, lp), jnp.int32),
            embs=SDS((c, lp, d), jnp.float32),
            gids=SDS((c, lp), jnp.int32),
            sizes=SDS((c,), jnp.int32),
            tombstones=SDS((c,), jnp.int32),
            next_gid=SDS((), jnp.int32),
        ),
    )
    specs = lider_param_specs(params, ("data",))
    # everything outside the bank + the shared LSH + scalar metadata: replicated
    assert specs.centroid_cm.sorted_keys == P()
    assert specs.centroids == P()
    assert specs.bank.lsh.projections == P()
    assert specs.bank.next_gid == P()
    # stacked per-cluster tensors: sharded on the leading (cluster) axis
    assert specs.bank.sorted_keys == P(("data",), None, None)
    assert specs.bank.embs == P(("data",), None, None)
    assert specs.bank.sizes == P(("data",))
    assert specs.bank.tombstones == P(("data",))
    assert specs.bank.rmi.leaf_w == P(("data",), None, None)

"""LIDER two-layer index: build integrity + end-to-end search quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lider
from repro.core.utils import recall_at_k

CFG = lider.LiderConfig(
    n_clusters=64, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=10
)


@pytest.fixture(scope="module")
def built(corpus):
    x, q, gt = corpus
    params = lider.build_lider(jax.random.PRNGKey(2), x, CFG)
    return x, q, gt, params


def test_build_integrity(built):
    x, _, _, p = built
    n = x.shape[0]
    gids = np.asarray(p.bank.gids)
    valid = gids[gids >= 0]
    # every point indexed exactly once (no capacity drops at default Lp)
    assert len(valid) == n
    assert len(set(valid.tolist())) == n
    assert int(p.bank.next_gid) == n
    assert (np.asarray(p.bank.tombstones) == 0).all()
    # cluster embeddings match the corpus rows
    c, lp = gids.shape
    embs = np.asarray(p.bank.embs)
    xs = np.asarray(x)
    for ci in range(0, c, 13):
        for li in range(0, lp, 17):
            g = gids[ci, li]
            if g >= 0:
                np.testing.assert_allclose(embs[ci, li], xs[g], rtol=1e-6)
    # sorted arrays are sorted with pads at the end
    keys = np.asarray(p.bank.sorted_keys)
    pos = np.asarray(p.bank.sorted_pos)
    assert (np.diff(keys.astype(np.int64), axis=-1) >= 0).all()
    sizes = np.asarray(p.bank.sizes)
    for ci in range(c):
        row_pos = pos[ci]  # (H, Lp)
        assert ((row_pos >= 0).sum(axis=-1) == sizes[ci]).all()


def test_end_to_end_recall(built):
    x, q, gt, p = built
    out = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    assert float(recall_at_k(out.ids, gt)) > 0.9


def test_no_duplicates_and_sorted(built):
    _, q, _, p = built
    out = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    ids = np.asarray(out.ids)
    scores = np.asarray(out.scores)
    assert (np.diff(scores, axis=1) <= 1e-6).all()
    for row in ids:
        v = row[row >= 0]
        assert len(set(v.tolist())) == len(v)


def test_more_probes_improve_recall(built):
    """Paper Fig. 7: recall increases with c0."""
    x, q, gt, p = built
    r1 = float(recall_at_k(lider.search_lider(p, q, k=10, n_probe=1, r0=8).ids, gt))
    r8 = float(recall_at_k(lider.search_lider(p, q, k=10, n_probe=8, r0=8).ids, gt))
    assert r8 >= r1


def test_refine_variant(built):
    x, q, gt, p = built
    out = lider.search_lider(p, q, k=10, n_probe=8, r0=8, refine=True)
    assert float(recall_at_k(out.ids, gt)) > 0.9


def test_capacity_overflow_raises_without_allow_drops(corpus):
    """Silent data loss guard: a lossy pack must raise unless the caller
    explicitly opts in — dropped passages are permanently unretrievable."""
    from repro.core.bank import CapacityOverflowError

    x, _, _ = corpus
    cfg = lider.LiderConfig(
        n_clusters=16, n_probe=4, n_arrays=2, n_leaves=2, kmeans_iters=5, capacity=64
    )
    with pytest.raises(CapacityOverflowError) as ei:
        lider.build_lider(jax.random.PRNGKey(3), x, cfg)
    assert ei.value.n_dropped > 0
    assert ei.value.capacity == 64


def test_capacity_overflow_drops_are_counted(corpus):
    x, _, _ = corpus
    cfg = lider.LiderConfig(
        n_clusters=16, n_probe=4, n_arrays=2, n_leaves=2, kmeans_iters=5,
        capacity=64, allow_drops=True,
    )
    p, stats = lider.build_lider(jax.random.PRNGKey(3), x, cfg, return_stats=True)
    gids = np.asarray(p.bank.gids)
    kept = (gids >= 0).sum()
    assert kept <= x.shape[0]
    assert p.capacity == 64
    # sizes clamped to capacity
    assert (np.asarray(p.bank.sizes) <= 64).all()
    # drop accounting: every corpus point is either packed or counted dropped
    assert stats.n_dropped == x.shape[0] - kept
    assert stats.n_indexed == kept
    assert stats.n_dropped > 0  # this config genuinely overflows


def test_no_overflow_build_reports_zero_drops(corpus):
    x, _, _ = corpus
    p, stats = lider.build_lider(
        jax.random.PRNGKey(2), x, CFG, return_stats=True
    )
    assert stats.n_dropped == 0
    assert stats.n_indexed == x.shape[0]
    assert stats.capacity == p.capacity


def test_route_then_incluster_equals_search(built):
    x, q, _, p = built
    routed = lider.route_queries(p, q, n_probe=8, r0=4)
    a = lider.incluster_search(p, q, routed.ids, k=10, r0=8)
    b = lider.search_lider(p, q, k=10, n_probe=8, r0=8, r0_centroid=4)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))

"""ESK-LSH properties: packing, linear order, and the paper's Lemmas 4.3/4.4
for the extended hashkey distance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings, strategies as st

from repro.core import lsh


@given(
    st.integers(1, 31),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(m, value):
    value = value % (2**m)
    key = jnp.asarray([value], jnp.uint32)
    bits = lsh.unpack_bits(key, m)
    packed = lsh.pack_bits(bits)
    assert int(packed[0]) == value


@given(st.integers(2, 20), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_lexicographic_order_is_numeric_order(m, seed):
    """SK-LSH's element-wise significant-first order == packed numeric order."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(32, m)).astype(np.uint32)
    packed = np.asarray(lsh.pack_bits(jnp.asarray(bits)))
    # lexicographic comparison of bit tuples must order like the integers
    order_lex = sorted(range(32), key=lambda i: tuple(bits[i]))
    order_num = list(np.argsort(packed, kind="stable"))
    assert [int(packed[i]) for i in order_lex] == [int(packed[i]) for i in order_num]


@given(st.integers(3, 24), st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_dist_e_linear_order_lemmas(m, b, seed):
    """Paper Lemmas 4.3/4.4: for sorted hashkeys K <= K1 <= K2 the extended
    distance satisfies dist_e(K2, K) >= dist_e(K1, K) (and mirrored)."""
    b = min(b, m)
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.integers(0, 2**m, size=8).astype(np.uint32))
    k, k1, k2 = keys[0], keys[3], keys[7]
    d21 = float(lsh.dist_e(jnp.uint32(k2), jnp.uint32(k), m, b))
    d11 = float(lsh.dist_e(jnp.uint32(k1), jnp.uint32(k), m, b))
    assert d21 >= d11 - 1e-6
    # mirrored (Lemma 4.4): K2 <= K1 <= K ordered descending
    d_far = float(lsh.dist_e(jnp.uint32(keys[0]), jnp.uint32(keys[7]), m, b))
    d_near = float(lsh.dist_e(jnp.uint32(keys[4]), jnp.uint32(keys[7]), m, b))
    assert d_far >= d_near - 1e-6


def test_dist_e_fixes_low_resolution_problem():
    """The paper's Sec 4.2 example: K_q=000000, K_1=111111, K_2=100000.
    Original KD cannot separate them; dist_e must rank K_2 closer."""
    m = 6
    kq = jnp.uint32(0b000000)
    k1 = jnp.uint32(0b111111)
    k2 = jnp.uint32(0b100000)
    d1 = float(lsh.dist_e(kq, k1, m, 3))
    d2 = float(lsh.dist_e(kq, k2, m, 3))
    assert d1 > d2
    # both share zero common prefix -> same KL=6; difference is in KD_e
    assert int(d1) == 6 and int(d2) == 6


def test_common_prefix_len():
    m = 8
    assert int(lsh.common_prefix_len(jnp.uint32(0b10110000), jnp.uint32(0b10111111), m)) == 4
    assert int(lsh.common_prefix_len(jnp.uint32(5), jnp.uint32(5), m)) == m
    assert int(lsh.common_prefix_len(jnp.uint32(0), jnp.uint32(0b10000000), m)) == 0


def test_hash_collision_probability_monotone_in_angle():
    """Charikar LSH: P[h(u)=h(v)] = 1 - theta/pi — closer vectors share more
    hash bits (statistical check, fixed seed)."""
    rng = jax.random.PRNGKey(3)
    params = lsh.make_lsh(rng, 32, n_arrays=1, key_len=31)
    base = jax.random.normal(jax.random.PRNGKey(4), (1, 32))
    near = base + 0.1 * jax.random.normal(jax.random.PRNGKey(5), (1, 32))
    far = jax.random.normal(jax.random.PRNGKey(6), (1, 32))
    kb, kn, kf = (lsh.hash_vectors(params, v)[0, 0] for v in (base, near, far))
    ham = lambda a, b: int(jax.lax.population_count(jnp.uint32(a) ^ jnp.uint32(b)))
    assert ham(kb, kn) < ham(kb, kf)


def test_query_position_exact():
    keys = jnp.asarray([1, 5, 9, 9, 20], jnp.uint32)
    assert int(lsh.query_position(keys, jnp.uint32(9))) == 2
    assert int(lsh.query_position(keys, jnp.uint32(0))) == 0
    assert int(lsh.query_position(keys, jnp.uint32(25))) == 5


def test_sorted_arrays_group_similar_vectors(corpus):
    """Locality property: adjacent keys in a sorted array are closer on
    average than random pairs."""
    x, _, _ = corpus
    params = lsh.make_lsh(jax.random.PRNGKey(7), x.shape[1], n_arrays=1, key_len=20)
    keys = lsh.hash_vectors(params, x)[:, 0]
    skeys, order = lsh.sort_hashkeys(keys)
    xs = x[order]
    adjacent_sim = jnp.mean(jnp.sum(xs[:-1] * xs[1:], axis=-1))
    perm = jax.random.permutation(jax.random.PRNGKey(8), x.shape[0])
    random_sim = jnp.mean(jnp.sum(x * x[perm], axis=-1))
    assert float(adjacent_sim) > float(random_sim) + 0.1

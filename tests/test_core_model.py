"""Core model (ESK-LSH + rescale + RMI) end-to-end search behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import core_model
from repro.core.utils import recall_at_k


def test_core_model_recall_vs_flat(corpus):
    x, q, gt = corpus
    cm = core_model.build_core_model(
        jax.random.PRNGKey(1), x, n_arrays=8, n_leaves=8
    )
    res = core_model.search_core_model(cm, x, q, k=10, r0=8)
    assert float(recall_at_k(res.ids, gt)) > 0.75


def test_refine_not_worse(corpus):
    x, q, gt = corpus
    cm = core_model.build_core_model(jax.random.PRNGKey(1), x, n_arrays=8, n_leaves=8)
    base = recall_at_k(core_model.search_core_model(cm, x, q, k=10, r0=4).ids, gt)
    ref = recall_at_k(
        core_model.search_core_model(cm, x, q, k=10, r0=4, refine=True).ids, gt
    )
    assert float(ref) >= float(base) - 0.02


def test_larger_r0_improves_recall(corpus):
    x, q, gt = corpus
    cm = core_model.build_core_model(jax.random.PRNGKey(1), x, n_arrays=6, n_leaves=8)
    r_small = recall_at_k(core_model.search_core_model(cm, x, q, k=10, r0=2).ids, gt)
    r_large = recall_at_k(core_model.search_core_model(cm, x, q, k=10, r0=16).ids, gt)
    assert float(r_large) >= float(r_small)


def test_more_arrays_improve_recall(corpus):
    """Paper Table 3: larger H -> better quality."""
    x, q, gt = corpus
    r = {}
    for h in (2, 8):
        cm = core_model.build_core_model(
            jax.random.PRNGKey(2), x, n_arrays=h, n_leaves=8
        )
        r[h] = float(
            recall_at_k(core_model.search_core_model(cm, x, q, k=10, r0=4).ids, gt)
        )
    assert r[8] >= r[2]


def test_search_outputs_well_formed(corpus):
    x, q, _ = corpus
    cm = core_model.build_core_model(jax.random.PRNGKey(1), x, n_arrays=4, n_leaves=4)
    res = core_model.search_core_model(cm, x, q, k=10, r0=4)
    ids = np.asarray(res.ids)
    scores = np.asarray(res.scores)
    assert ids.shape == (q.shape[0], 10)
    # scores sorted descending; ids valid & unique per row
    assert (np.diff(scores, axis=1) <= 1e-6).all()
    for row in ids:
        v = row[row >= 0]
        assert len(set(v.tolist())) == len(v)
        assert (v < x.shape[0]).all()

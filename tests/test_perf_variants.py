"""Guards for the §Perf optimization variants: quality of the bf16 index,
the last-mile refine trade-off, and the roofline analytics plumbing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lider
from repro.core.baselines import flat_search
from repro.core.utils import recall_at_k

_JAX_VERSION = tuple(int(p) for p in jax.__version__.split(".")[:2])


def _setup(corpus):
    x, q, gt = corpus
    cfg = lider.LiderConfig(
        n_clusters=64, n_probe=12, n_arrays=6, n_leaves=4, kmeans_iters=10
    )
    return x, q, gt, lider.build_lider(jax.random.PRNGKey(0), x, cfg)


def test_bf16_index_recall_close_to_f32(corpus):
    x, q, gt, params = _setup(corpus)
    base = recall_at_k(
        lider.search_lider(params, q, k=10, n_probe=12, r0=8).ids, gt
    )
    p16 = dataclasses.replace(
        params,
        bank=dataclasses.replace(
            params.bank, embs=params.bank.embs.astype(jnp.bfloat16)
        ),
    )
    got = recall_at_k(lider.search_lider(p16, q, k=10, n_probe=12, r0=8).ids, gt)
    assert float(got) >= float(base) - 0.03  # A1 quality guard


@pytest.mark.skipif(
    _JAX_VERSION < (0, 5),
    reason="jax<0.5 PRNG/compiler numerics shift this corpus's refine recall "
    "by ~0.03, past the 0.02 guard band (the refine path itself is exercised "
    "and parity-checked elsewhere); the guard is meaningful on current jax",
)
def test_refine_halves_window_at_small_recall_cost(corpus):
    x, q, gt, params = _setup(corpus)
    wide = recall_at_k(lider.search_lider(params, q, k=10, n_probe=12, r0=8).ids, gt)
    narrow_refined = recall_at_k(
        lider.search_lider(params, q, k=10, n_probe=12, r0=4, refine=True).ids, gt
    )
    narrow_plain = recall_at_k(
        lider.search_lider(params, q, k=10, n_probe=12, r0=4).ids, gt
    )
    # A2: refine at half width must not be (meaningfully) worse than plain
    # half width, and stay near the full-width recall.
    assert float(narrow_refined) >= float(narrow_plain) - 0.02
    assert float(narrow_refined) >= float(wide) - 0.08


def test_model_flops_analytics():
    from repro.configs import ARCHS, get_arch
    from repro.launch.flops import model_flops

    for arch_id, arch in ARCHS.items():
        for shape in arch.shapes:
            if shape.name in arch.skip_shapes:
                continue
            f = model_flops(arch, shape)
            assert f > 0, (arch_id, shape.name)
    # 6*N*D sanity for a dense LM train cell
    arch = get_arch("qwen2.5-3b")
    f = model_flops(arch, arch.shape("train_4k"))
    n = arch.config.flops_params()
    d = 256 * 4096
    assert f >= 6 * n * d  # matmuls + attention


def test_roofline_analyze_roundtrip():
    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "roofline",
        pathlib.Path(__file__).parent.parent / "benchmarks" / "roofline.py",
    )
    roofline = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(roofline)
    rec = {
        "status": "ok",
        "arch": "qwen2.5-3b",
        "shape": "decode_32k",
        "mesh": "single_pod_16x16",
        "n_devices": 256,
        "cost": {"flops": 1e9, "bytes_accessed": 1e10},
        "collectives": {"all-gather": {"count": 2, "bytes": 1e8}},
        "memory": {"temp_bytes": 2**30},
        "model_flops": 1e12,
    }
    out = roofline.analyze(rec)
    assert out["bottleneck"] in ("compute", "memory", "collective")
    assert out["loop_factor"] == 36.0  # qwen2.5-3b layer count
    assert out["t_memory_s"] > 0 and out["t_collective_s"] > 0
    assert roofline.analyze({"status": "failed"}) is None

"""Multi-device correctness (8 fake CPU devices via subprocess — the unit
test process keeps its single real device)."""
import subprocess
import sys
import textwrap

import pytest


def _run(body: str) -> str:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        mesh = compat.mesh_from_devices(
            np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        """
    ) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(__file__)),
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_sharded_search_equals_single_device():
    out = _run(
        """
        from repro.core import lider, distributed
        from repro.core.utils import l2_normalize
        rng = jax.random.PRNGKey(0)
        kc, kx, kq, kb = jax.random.split(rng, 4)
        centers = jax.random.normal(kc, (32, 64))
        assign = jax.random.randint(kx, (4000,), 0, 32)
        x = l2_normalize(centers[assign] + 0.3*jax.random.normal(kq, (4000, 64)))
        q = l2_normalize(x[:64] + 0.05*jax.random.normal(kb, (64, 64)))
        cfg = lider.LiderConfig(n_clusters=64, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=10)
        params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
        ref = lider.search_lider(params, q, k=10, n_probe=8, r0=8)
        sp = distributed.shard_lider_params(mesh, params, ("data",))
        search = distributed.make_sharded_search(mesh, params, k=10, n_probe=8, r0=8, capacity_factor=3.0)
        out, dropped = search(sp, q)
        assert int(dropped) == 0, f"dropped {dropped}"
        rs = np.sort(np.asarray(ref.scores)); os_ = np.sort(np.asarray(out.scores))
        assert np.allclose(rs, os_, atol=1e-5), np.abs(rs-os_).max()
        ov = np.mean([len(set(a[a>=0]) & set(b[b>=0]))/max(len(set(a[a>=0])),1)
                      for a, b in zip(np.asarray(ref.ids), np.asarray(out.ids))])
        assert ov == 1.0, ov
        print("EQUIV_OK")
        """
    )
    assert "EQUIV_OK" in out


def test_sharded_search_quantized_bank_matches_single_device():
    """int8 bank (DESIGN.md §Quantized bank): the new emb_scales /
    rescore_embs fields derive cluster-sharded specs from their metadata and
    the compressed-domain + exact-rescore pass runs shard-locally."""
    out = _run(
        """
        from repro.core import lider, distributed
        from repro.core.utils import l2_normalize
        rng = jax.random.PRNGKey(0)
        kc, kx, kq, kb = jax.random.split(rng, 4)
        centers = jax.random.normal(kc, (32, 64))
        assign = jax.random.randint(kx, (4000,), 0, 32)
        x = l2_normalize(centers[assign] + 0.3*jax.random.normal(kq, (4000, 64)))
        q = l2_normalize(x[:64] + 0.05*jax.random.normal(kb, (64, 64)))
        cfg = lider.LiderConfig(n_clusters=64, n_probe=8, n_arrays=4,
                                n_leaves=4, kmeans_iters=10,
                                storage_dtype="int8")
        params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
        assert params.bank.quantized
        ref = lider.search_lider(params, q, k=10, n_probe=8, r0=8)
        sp = distributed.shard_lider_params(mesh, params, ("data",))
        specs = distributed.lider_param_specs(params, ("data",))
        assert specs.bank.emb_scales == P(("data",), None)
        assert specs.bank.rescore_embs == P(("data",), None, None)
        search = distributed.make_sharded_search(
            mesh, params, k=10, n_probe=8, r0=8, capacity_factor=3.0)
        out, dropped = search(sp, q)
        assert int(dropped) == 0, f"dropped {dropped}"
        rs = np.sort(np.asarray(ref.scores)); os_ = np.sort(np.asarray(out.scores))
        assert np.allclose(rs, os_, atol=1e-5), np.abs(rs-os_).max()
        ov = np.mean([len(set(a[a>=0]) & set(b[b>=0]))/max(len(set(a[a>=0])),1)
                      for a, b in zip(np.asarray(ref.ids), np.asarray(out.ids))])
        assert ov == 1.0, ov
        print("INT8_EQUIV_OK")
        """
    )
    assert "INT8_EQUIV_OK" in out


def test_sharded_search_host_tier_matches_single_device():
    """Host-tier bank (DESIGN.md §Tiered embedding store): the two-phase
    sharded search — compressed shard_map pass + host fetch + top-level
    rescore — matches the single-device staged search, with the rescore
    table never device-resident and no change to the collective set."""
    out = _run(
        """
        from repro.core import lider, distributed
        from repro.core.utils import l2_normalize
        rng = jax.random.PRNGKey(0)
        kc, kx, kq, kb = jax.random.split(rng, 4)
        centers = jax.random.normal(kc, (32, 64))
        assign = jax.random.randint(kx, (4000,), 0, 32)
        x = l2_normalize(centers[assign] + 0.3*jax.random.normal(kq, (4000, 64)))
        q = l2_normalize(x[:64] + 0.05*jax.random.normal(kb, (64, 64)))
        cfg = lider.LiderConfig(n_clusters=64, n_probe=8, n_arrays=4,
                                n_leaves=4, kmeans_iters=10,
                                storage_dtype="int8", rescore_tier="host")
        params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
        assert params.bank.rescore_tier == "host"
        assert params.bank.rescore_embs is None  # never a device leaf
        ref = lider.search_lider(params, q, k=10, n_probe=8, r0=8)
        sp = distributed.shard_lider_params(mesh, params, ("data",))
        search = distributed.make_sharded_search(
            mesh, params, k=10, n_probe=8, r0=8, capacity_factor=3.0)
        assert hasattr(search, "stage1")  # the lowerable device phase
        out, dropped = search(sp, q)
        assert int(dropped) == 0, f"dropped {dropped}"
        rs = np.sort(np.asarray(ref.scores)); os_ = np.sort(np.asarray(out.scores))
        assert np.allclose(rs, os_, atol=1e-5), np.abs(rs-os_).max()
        ov = np.mean([len(set(a[a>=0]) & set(b[b>=0]))/max(len(set(a[a>=0])),1)
                      for a, b in zip(np.asarray(ref.ids), np.asarray(out.ids))])
        assert ov == 1.0, ov
        print("HOST_TIER_EQUIV_OK")
        """
    )
    assert "HOST_TIER_EQUIV_OK" in out


def test_sharded_search_grouped_matches_per_query_sharded():
    """Cluster-major grouped spelling on the distributed path (``block_q``):
    the host-replicated dispatch + per-cell schedules feed the grouped
    kernel inside the same shard_map, and results — ids AND scores — are
    bit-identical to the per-query sharded path on both tiers, with and
    without the binary-sketch pre-filter (covering factor)."""
    out = _run(
        """
        from repro.core import lider, distributed
        from repro.core.utils import l2_normalize
        rng = jax.random.PRNGKey(0)
        kc, kx, kq, kb = jax.random.split(rng, 4)
        centers = jax.random.normal(kc, (32, 64))
        assign = jax.random.randint(kx, (4000,), 0, 32)
        x = l2_normalize(centers[assign] + 0.3*jax.random.normal(kq, (4000, 64)))
        q = l2_normalize(x[:64] + 0.05*jax.random.normal(kb, (64, 64)))
        cfg = lider.LiderConfig(n_clusters=64, n_probe=8, n_arrays=4,
                                n_leaves=4, kmeans_iters=10,
                                storage_dtype="int8")
        params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
        sp = distributed.shard_lider_params(mesh, params, ("data",))
        base = distributed.make_sharded_search(
            mesh, params, k=10, n_probe=8, r0=8, capacity_factor=3.0)
        ref, d0 = base(sp, q)
        grouped = distributed.make_sharded_search(
            mesh, params, k=10, n_probe=8, r0=8, capacity_factor=3.0,
            block_q=8)
        out, d1 = grouped(sp, q)
        assert int(d0) == int(d1) == 0, (int(d0), int(d1))
        assert np.array_equal(np.asarray(ref.ids), np.asarray(out.ids))
        assert np.array_equal(np.asarray(ref.scores), np.asarray(out.scores))
        sk = distributed.make_sharded_search(
            mesh, params, k=10, n_probe=8, r0=8, capacity_factor=3.0,
            block_q=8, sketch_factor=64)
        outs, _ = sk(sp, q)
        assert np.array_equal(np.asarray(ref.ids), np.asarray(outs.ids))

        # Host tier: grouped first pass + the same fetch->rescore pipeline.
        cfg_h = lider.LiderConfig(n_clusters=64, n_probe=8, n_arrays=4,
                                  n_leaves=4, kmeans_iters=10,
                                  storage_dtype="int8", rescore_tier="host")
        ph = lider.build_lider(jax.random.PRNGKey(2), x, cfg_h)
        sph = distributed.shard_lider_params(mesh, ph, ("data",))
        base_h = distributed.make_sharded_search(
            mesh, ph, k=10, n_probe=8, r0=8, capacity_factor=3.0)
        ref_h, _ = base_h(sph, q)
        grp_h = distributed.make_sharded_search(
            mesh, ph, k=10, n_probe=8, r0=8, capacity_factor=3.0,
            block_q=8, sketch_factor=64)
        out_h, _ = grp_h(sph, q)
        assert np.array_equal(np.asarray(ref_h.ids), np.asarray(out_h.ids))
        assert np.array_equal(np.asarray(ref_h.scores), np.asarray(out_h.scores))

        # Float banks cannot take the grouped path.
        cfg_f = lider.LiderConfig(n_clusters=64, n_probe=8, n_arrays=4,
                                  n_leaves=4, kmeans_iters=10)
        pf = lider.build_lider(jax.random.PRNGKey(2), x, cfg_f)
        try:
            distributed.make_sharded_search(
                mesh, pf, k=10, n_probe=8, r0=8, block_q=8)
            raise AssertionError("float bank should reject block_q")
        except ValueError:
            pass
        print("GROUPED_SHARDED_OK")
        """
    )
    assert "GROUPED_SHARDED_OK" in out


def test_capacity_drops_reduce_recall_gracefully():
    out = _run(
        """
        from repro.core import lider, distributed
        from repro.core.utils import l2_normalize, recall_at_k
        rng = jax.random.PRNGKey(1)
        x = l2_normalize(jax.random.normal(rng, (2000, 32)))
        q = l2_normalize(x[:32] + 0.01)
        cfg = lider.LiderConfig(n_clusters=32, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=5)
        params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
        sp = distributed.shard_lider_params(mesh, params, ("data",))
        tight = distributed.make_sharded_search(mesh, params, k=10, n_probe=8, r0=4, capacity_factor=0.5)
        out, dropped = tight(sp, q)
        assert int(dropped) > 0  # tight capacity must drop pairs...
        ids = np.asarray(out.ids)
        assert (ids[ids >= 0] < 2000).all()  # ...but results stay well-formed
        print("DROPS_OK", int(dropped))
        """
    )
    assert "DROPS_OK" in out


def test_sharded_kmeans_step_equals_reference():
    out = _run(
        """
        from repro.core import clustering, distributed
        x = jax.random.normal(jax.random.PRNGKey(0), (1024, 16))
        cen = clustering.init_centroids(jax.random.PRNGKey(1), x, 16)
        step = distributed.make_sharded_kmeans_step(mesh, n_clusters=16)
        got = step(jax.device_put(x, NamedSharding(mesh, P(("data",), None))), cen)
        sums, counts, _ = clustering.kmeans_step(x, cen, n_clusters=16)
        want = clustering.update_centroids(cen, sums, counts)
        assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-5)
        print("KMEANS_OK")
        """
    )
    assert "KMEANS_OK" in out


def test_sharded_embedding_lookup_equals_take():
    out = _run(
        """
        from repro.models.recsys import embedding_lookup
        table = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
        ids = jax.random.randint(jax.random.PRNGKey(1), (16, 3), 0, 64)
        plain = table[ids]
        with compat.set_mesh(mesh):
            sharded = jax.jit(embedding_lookup)(table, ids)
        assert np.allclose(np.asarray(plain), np.asarray(sharded), atol=1e-6)
        # gradient path through the shard_map lookup
        g_plain = jax.grad(lambda t: jnp.sum(t[ids] ** 2))(table)
        with compat.set_mesh(mesh):
            g_shard = jax.jit(
                jax.grad(lambda t: jnp.sum(embedding_lookup(t, ids) ** 2))
            )(table)
        assert np.allclose(np.asarray(g_plain), np.asarray(g_shard), atol=1e-5)
        print("EMB_OK")
        """
    )
    assert "EMB_OK" in out


def test_lm_train_step_runs_sharded():
    """A reduced LM train step executes (not just compiles) on the mesh and
    matches the single-device loss."""
    out = _run(
        """
        from repro.models import transformer as T
        from repro.data import synthetic
        cfg = T.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=256, dtype=jnp.float32)
        params = T.init(jax.random.PRNGKey(0), cfg)
        batch = synthetic.lm_batch(0, 0, batch=8, seq=32, vocab=256)
        ref = float(T.train_loss(params, cfg, batch))
        pspec = T.param_specs(cfg, mesh.axis_names)
        ns = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                          is_leaf=lambda x: isinstance(x, P))
        sp = jax.tree.map(lambda x, s: jax.device_put(x, s), params, ns)
        sb = jax.tree.map(lambda x: jax.device_put(x, NamedSharding(mesh, P(("data",), None))), batch)
        with compat.set_mesh(mesh):
            got = float(jax.jit(lambda p, b: T.train_loss(p, cfg, b))(sp, sb))
        assert abs(ref - got) < 1e-3, (ref, got)
        print("LM_SHARD_OK")
        """
    )
    assert "LM_SHARD_OK" in out


def test_sharded_search_degraded_shard_serves_partial_results():
    """Shard-health degraded mode (DESIGN.md §Failure model): a dead shard's
    contribution is masked before the all-gather, so the merge returns
    partial results over the live shards — no abort, no fabricated ids, and
    every full-search answer not owned by the dead shard survives. The
    injected ``kill_shard`` fault drives the exact same mask."""
    out = _run(
        """
        from repro import faults
        from repro.core import lider, distributed
        from repro.core.utils import l2_normalize
        rng = jax.random.PRNGKey(0)
        kc, kx, kq, kb = jax.random.split(rng, 4)
        centers = jax.random.normal(kc, (32, 64))
        assign = jax.random.randint(kx, (4000,), 0, 32)
        x = l2_normalize(centers[assign] + 0.3*jax.random.normal(kq, (4000, 64)))
        q = l2_normalize(x[:64] + 0.05*jax.random.normal(kb, (64, 64)))
        cfg = lider.LiderConfig(n_clusters=64, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=10)
        params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
        sp = distributed.shard_lider_params(mesh, params, ("data",))
        search = distributed.make_sharded_search(mesh, params, k=10, n_probe=8, r0=8, capacity_factor=3.0)
        full, _ = search(sp, q)
        assert search.shard_stats == {"shards_live": 4, "shards_total": 4}

        health = np.array([True, False, True, True])
        part, _ = search(sp, q, shard_health=health)
        assert search.shard_stats == {"shards_live": 3, "shards_total": 4}
        # Shard 1 owns clusters [16, 32): its gids must never be served...
        dead_gids = set(np.asarray(params.bank.gids)[16:32].ravel().tolist()) - {-1}
        fids, pids = np.asarray(full.ids), np.asarray(part.ids)
        assert not (set(pids.ravel().tolist()) & dead_gids)
        assert set(fids.ravel().tolist()) & dead_gids  # ...and were in the full answer
        # ...while every live-shard answer from the full search survives the merge.
        for f, p in zip(fids, pids):
            assert set(f[f >= 0]) - dead_gids <= set(p[p >= 0])

        # The injected kill drives the same mask -> bit-identical answers.
        plan = faults.FaultPlan([faults.FaultSpec(
            "shard_search", mode="kill_shard", payload={"shard": 1}, times=(0,))])
        with faults.activate(plan):
            killed, _ = search(sp, q)
        assert search.shard_stats == {"shards_live": 3, "shards_total": 4}
        assert np.array_equal(np.asarray(killed.ids), pids)
        print("DEGRADED_OK")
        """
    )
    assert "DEGRADED_OK" in out

"""Adaptive probe pruning: margin-rule parity, candidate-subset guarantees,
speed-quality monotonicity, and the block-skipping verification kernel
(DESIGN.md §Adaptive speed-quality control plane)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lider
from repro.core.utils import l2_normalize, recall_at_k
from repro.kernels import fused_verify, ref

CFG = lider.LiderConfig(
    n_clusters=32, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=8
)


@pytest.fixture(scope="module")
def built(corpus):
    x, q, gt = corpus
    params = lider.build_lider(jax.random.PRNGKey(2), x, CFG)
    return x, q, gt, params


# ---------------------------------------------------------------------------
# Margin rule on the core search path
# ---------------------------------------------------------------------------


def test_margin_none_bit_identical(built):
    """prune_margin=None must be bit-identical to the fixed-probe search."""
    _, q, _, p = built
    base = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    off = lider.search_lider(p, q, k=10, n_probe=8, r0=8, prune_margin=None)
    np.testing.assert_array_equal(np.asarray(off.ids), np.asarray(base.ids))
    assert (
        np.asarray(off.scores).tobytes() == np.asarray(base.scores).tobytes()
    )
    routed = lider.route_queries(p, q, n_probe=8)
    routed_off = lider.route_queries(p, q, n_probe=8, prune_margin=None)
    np.testing.assert_array_equal(
        np.asarray(routed_off.ids), np.asarray(routed.ids)
    )
    assert (
        np.asarray(routed_off.scores).tobytes()
        == np.asarray(routed.scores).tobytes()
    )


def test_prune_probes_masks_only_below_margin(built):
    _, q, _, p = built
    routed = lider.route_queries(p, q, n_probe=8)
    cids = lider.prune_probes(routed.ids, routed.scores, 0.1)
    scores = np.asarray(routed.scores)
    best = scores.max(axis=-1, keepdims=True)
    kept, orig = np.asarray(cids), np.asarray(routed.ids)
    # kept slots are unchanged; masked slots are exactly those below margin
    np.testing.assert_array_equal(kept[kept >= 0], orig[kept >= 0])
    assert ((scores >= best - 0.1) == (kept >= 0)).all()
    # the per-query best probe always survives
    assert (kept.max(axis=-1) >= 0).all()


def test_pruned_results_are_subset_of_unpruned_candidates(built):
    """Every id a pruned search returns must come from a cluster the
    unpruned routing probed AND the margin rule kept."""
    x, q, _, p = built
    routed = lider.route_queries(p, q, n_probe=8)
    kept = np.asarray(lider.prune_probes(routed.ids, routed.scores, 0.05))
    out = lider.search_lider(p, q, k=10, n_probe=8, r0=8, prune_margin=0.05)
    gids = np.asarray(p.bank.gids)
    cluster_of = np.full((x.shape[0],), -1, np.int32)
    for ci in range(gids.shape[0]):
        live = gids[ci][gids[ci] >= 0]
        cluster_of[live] = ci
    ids = np.asarray(out.ids)
    for b in range(ids.shape[0]):
        kept_set = set(kept[b][kept[b] >= 0].tolist())
        for i in ids[b][ids[b] >= 0]:
            assert cluster_of[i] in kept_set


def test_incluster_prune_spelling_matches_search_lider(built):
    """Pruning inside incluster_search (cid_scores + margin) equals pruning
    at the routing layer — one candidate mask, two spellings."""
    _, q, _, p = built
    routed = lider.route_queries(p, q, n_probe=8)
    a = lider.incluster_search(
        p, q, routed.ids, k=10, r0=8, cid_scores=routed.scores,
        prune_margin=0.1,
    )
    b = lider.search_lider(p, q, k=10, n_probe=8, r0=8, prune_margin=0.1)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_recall_monotone_non_increasing_as_margin_tightens(built):
    """Tightening the margin shrinks the candidate set; recall@k must not
    improve as probes are pruned away."""
    _, q, gt, p = built
    margins = [1.0, 0.5, 0.2, 0.1, 0.05, 0.02, 0.0]
    recalls = [
        float(
            recall_at_k(
                lider.search_lider(
                    p, q, k=10, n_probe=8, r0=8, prune_margin=m
                ).ids,
                gt,
            )
        )
        for m in margins
    ]
    for wide, tight in zip(recalls, recalls[1:]):
        assert tight <= wide + 1e-9, recalls
    # sanity: an infinite margin prunes nothing ...
    none = float(
        recall_at_k(lider.search_lider(p, q, k=10, n_probe=8, r0=8).ids, gt)
    )
    assert recalls[0] == pytest.approx(none)
    # ... and a zero margin still serves the best probe per query
    assert recalls[-1] > 0


def test_with_stats_returns_pruned_mask(built):
    _, q, _, p = built
    out, pruned = lider.search_lider(
        p, q, k=10, n_probe=8, r0=8, prune_margin=0.1, with_stats=True
    )
    pruned = np.asarray(pruned)
    assert pruned.shape == (q.shape[0], 8)
    assert pruned.dtype == bool
    assert 0 < pruned.sum() < pruned.size  # something, but not everything
    _, none_pruned = lider.search_lider(
        p, q, k=10, n_probe=8, r0=8, prune_margin=None, with_stats=True
    )
    assert not np.asarray(none_pruned).any()


def test_margin_sweep_does_not_recompile(built):
    """The margin is traced: sweeping values must reuse one compilation."""
    _, q, _, p = built
    with jax.log_compiles(False):
        pass  # silence any ambient logging
    fn = lider.search_lider
    base = fn._cache_size() if hasattr(fn, "_cache_size") else None
    fn(p, q, k=10, n_probe=8, r0=8, prune_margin=0.3)
    after_first = fn._cache_size() if base is not None else None
    fn(p, q, k=10, n_probe=8, r0=8, prune_margin=0.07)
    fn(p, q, k=10, n_probe=8, r0=8, prune_margin=0.9)
    if base is not None:
        assert fn._cache_size() == after_first


# ---------------------------------------------------------------------------
# Block-skipping fused kernel on pruned inputs
# ---------------------------------------------------------------------------


def _case(seed, n, d, b, c):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    embs = jax.random.normal(k1, (n, d))
    rows = jax.random.randint(k2, (b, c), 0, n)
    q = jax.random.normal(k3, (b, d))
    return embs, rows, q


def _assert_parity(embs, rows, q, k, block_c, out_ids):
    gi, gs = fused_verify(
        embs, rows, q, k=k, out_ids=out_ids, block_c=block_c, interpret=True
    )
    wi, ws = ref.verify_topk_ref(embs, rows, q, k=k, out_ids=out_ids)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), rtol=1e-6)


def test_block_skip_parity_whole_blocks_pruned():
    """Fully-invalid blocks (a pruned probe's candidate span) are skipped by
    the kernel but the output must match the reference exactly."""
    embs, rows, q = _case(0, 60, 16, 3, 32)
    out_ids = rows
    # kill blocks 1 and 3 of 4 (block_c=8) on every row
    mask = jnp.arange(32) // 8
    out_ids = jnp.where((mask == 1) | (mask == 3), -1, out_ids)
    _assert_parity(embs, rows, q, k=5, block_c=8, out_ids=out_ids)


def test_block_skip_parity_mixed_blocks():
    """Blocks with a few valid candidates must still be processed."""
    embs, rows, q = _case(1, 60, 16, 2, 24)
    out_ids = rows.at[:, ::2].set(-1)  # half-dead everywhere, no dead block
    _assert_parity(embs, rows, q, k=4, block_c=8, out_ids=out_ids)
    out_ids = out_ids.at[:, 8:16].set(-1)  # now block 1 is fully dead
    _assert_parity(embs, rows, q, k=4, block_c=8, out_ids=out_ids)


def test_block_skip_all_probes_pruned_row():
    """A row whose probes were all pruned returns all (-1, -inf) — the
    edge case where every block of that row is skipped."""
    embs, rows, q = _case(2, 40, 16, 3, 16)
    out_ids = rows.at[1, :].set(-1)  # row 1: everything pruned
    gi, gs = fused_verify(
        embs, rows, q, k=4, out_ids=out_ids, block_c=4, interpret=True
    )
    assert (np.asarray(gi)[1] == -1).all()
    assert np.isneginf(np.asarray(gs)[1]).all()
    _assert_parity(embs, rows, q, k=4, block_c=4, out_ids=out_ids)


def test_block_skip_all_rows_all_pruned():
    embs, rows, q = _case(3, 30, 8, 2, 12)
    out_ids = jnp.full_like(rows, -1)
    gi, gs = fused_verify(
        embs, rows, q, k=3, out_ids=out_ids, block_c=4, interpret=True
    )
    assert (np.asarray(gi) == -1).all()
    assert np.isneginf(np.asarray(gs)).all()


@pytest.fixture(scope="module")
def small_lider():
    rng = jax.random.PRNGKey(7)
    kc, kx, kq, kb = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (16, 32))
    assign = jax.random.randint(kx, (1500,), 0, 16)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kq, (1500, 32)))
    q = l2_normalize(x[:8] + 0.05 * jax.random.normal(kb, (8, 32)))
    cfg = lider.LiderConfig(
        n_clusters=16, n_probe=4, n_arrays=2, n_leaves=2, kmeans_iters=5
    )
    params = lider.build_lider(jax.random.PRNGKey(2), x, cfg)
    return params, q


def test_search_lider_pruned_fused_matches_unfused(small_lider):
    """End-to-end: fused block-skip path == materialized reference under
    pruning (the pruned probes' spans are the skipped blocks)."""
    params, q = small_lider
    kw = dict(k=10, n_probe=4, r0=8, prune_margin=0.1)
    unfused = lider.search_lider(params, q, use_fused=False, **kw)
    fused = lider.search_lider(params, q, use_fused=True, **kw)
    np.testing.assert_array_equal(
        np.asarray(fused.ids), np.asarray(unfused.ids)
    )
    np.testing.assert_allclose(
        np.asarray(fused.scores), np.asarray(unfused.scores), rtol=1e-6
    )

"""Tiered embedding store (DESIGN.md §Tiered embedding store): host-tier
rescore table bit-parity with the device tier across the whole index
lifecycle, cross-tier checkpointing, per-tier byte accounting, the pipelined
serving engine, and the device/host generation split."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lider, update
from repro.core.bank import EmbStore, set_rescore_tier
from repro.core.utils import recall_at_k
from repro.serving import RetrievalEngine, make_backend
from repro.serving.traffic import make_trace, run_open_loop
from repro.training import checkpoint

CFG = lider.LiderConfig(
    n_clusters=32, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=10,
    storage_dtype="int8",
)


def _search(p, q, **kw):
    return lider.search_lider(p, q, k=10, n_probe=8, r0=8, **kw)


def _assert_bit_parity(pd, ph, q, **kw):
    a = _search(pd, q, **kw)
    b = _search(ph, q, **kw)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


@pytest.fixture(scope="module")
def tier_pair(corpus):
    """The same int8 index on both tiers (device-built, host-converted)."""
    x, q, gt = corpus
    pd = lider.build_lider(jax.random.PRNGKey(0), x, CFG)
    ph = lider.set_rescore_tier(pd, "host")
    return x, q, gt, pd, ph


# ---------------------------------------------------------------------------
# Tier plumbing & accounting
# ---------------------------------------------------------------------------


def test_tier_properties_and_store_shape(tier_pair):
    x, _, _, pd, ph = tier_pair
    assert pd.bank.rescore_tier == "device" and ph.bank.rescore_tier == "host"
    assert ph.bank.rescore_embs is None
    assert ph.bank.store.shape == tuple(pd.bank.rescore_embs.shape)
    np.testing.assert_array_equal(
        ph.bank.store.rescore, np.asarray(pd.bank.rescore_embs)
    )
    # the synced gid copy matches the device one
    np.testing.assert_array_equal(ph.bank.store.gids, np.asarray(ph.bank.gids))


def test_nbytes_by_tier_accounting(tier_pair):
    _, _, _, pd, ph = tier_pair
    dev = pd.bank.nbytes_by_tier()
    host = ph.bank.nbytes_by_tier()
    assert dev["host"] == 0
    # moving the table off-device shifts exactly its bytes between tiers
    assert host["host"] == pd.bank.rescore_embs.size * 4
    assert dev["device"] - host["device"] == host["host"]


def test_direct_host_build_matches_conversion(corpus):
    x, q, _, = corpus
    cfg = dataclasses.replace(CFG, rescore_tier="host")
    built = lider.build_lider(jax.random.PRNGKey(0), x, cfg)
    assert built.bank.rescore_tier == "host"
    converted = lider.set_rescore_tier(
        lider.build_lider(jax.random.PRNGKey(0), x, CFG), "host"
    )
    np.testing.assert_array_equal(built.bank.store.rescore,
                                  converted.bank.store.rescore)
    _assert_bit_parity(built, converted, q)


def test_host_tier_requires_int8(corpus):
    x, _, _ = corpus
    cfg = dataclasses.replace(
        CFG, storage_dtype="float32", rescore_tier="host"
    )
    with pytest.raises(ValueError, match="int8"):
        lider.build_lider(jax.random.PRNGKey(0), x, cfg)
    p32 = lider.build_lider(
        jax.random.PRNGKey(0), x, dataclasses.replace(CFG, storage_dtype="float32")
    )
    with pytest.raises(ValueError, match="int8|rescore"):
        lider.set_rescore_tier(p32, "host")


def test_incluster_search_rejects_host_tier(tier_pair):
    _, q, _, _, ph = tier_pair
    cids = jnp.zeros((q.shape[0], 2), jnp.int32)
    with pytest.raises(ValueError, match="host-tier"):
        lider.incluster_search(ph, q, cids, k=10)


def test_embstore_hash_is_content_stable(tier_pair):
    """The store rides the pytree as static aux: content writes must not
    change its identity-as-aux (or every host update would recompile)."""
    _, _, _, _, ph = tier_pair
    st = ph.bank.store
    before = hash(st)
    st.write_rows(np.array([0]), st.fetch(np.array([0])))
    assert hash(st) == before
    abstract = EmbStore("host", shape=st.shape)
    assert abstract == st and hash(abstract) == hash(st)
    with pytest.raises(ValueError, match="abstract"):
        abstract.fetch(np.array([0]))


# ---------------------------------------------------------------------------
# Bit-parity across the lifecycle (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_parity_all_live(tier_pair):
    _, q, _, pd, ph = tier_pair
    _assert_bit_parity(pd, ph, q)


def test_parity_with_pruning_and_stats(tier_pair):
    _, q, _, pd, ph = tier_pair
    a, pa = _search(pd, q, prune_margin=0.1, with_stats=True)
    b, pb = _search(ph, q, prune_margin=0.1, with_stats=True)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_parity_across_lifecycle(corpus):
    """Upsert -> tombstone -> compaction, applied to both tiers in parallel:
    every stage stays bit-identical (and the host gid map stays synced)."""
    x, q, _ = corpus
    n80 = int(x.shape[0] * 0.8)
    pd = lider.build_lider(jax.random.PRNGKey(0), x[:n80], CFG)
    ph = lider.set_rescore_tier(
        lider.build_lider(jax.random.PRNGKey(0), x[:n80], CFG), "host"
    )
    # post-upsert (grows capacity -> exercises EmbStore.grow)
    pd, sd = update.upsert(pd, x[n80:])
    ph, sh = update.upsert(ph, x[n80:])
    assert sd.capacity_grew == sh.capacity_grew
    _assert_bit_parity(pd, ph, q)
    np.testing.assert_array_equal(
        ph.bank.store.rescore, np.asarray(pd.bank.rescore_embs)
    )
    # tombstoned (no compaction)
    dead = jnp.arange(50, 150, dtype=jnp.int32)
    pd, _ = update.delete(pd, dead, refit_threshold=1.0)
    ph, _ = update.delete(ph, dead, refit_threshold=1.0)
    _assert_bit_parity(pd, ph, q)
    assert not np.isin(np.asarray(_search(ph, q).ids), np.asarray(dead)).any()
    # post-compaction (threshold 0 forces it)
    pd, s1 = update.delete(pd, jnp.arange(200, 260, dtype=jnp.int32),
                           refit_threshold=0.0)
    ph, s2 = update.delete(ph, jnp.arange(200, 260, dtype=jnp.int32),
                           refit_threshold=0.0)
    assert s1.n_refit == s2.n_refit > 0
    _assert_bit_parity(pd, ph, q)
    np.testing.assert_array_equal(
        ph.bank.store.rescore, np.asarray(pd.bank.rescore_embs)
    )
    np.testing.assert_array_equal(ph.bank.store.gids, np.asarray(ph.bank.gids))


def test_growth_preserves_pre_growth_snapshot(corpus):
    """Capacity growth is copy-on-grow on the host tier: a retained
    pre-growth params snapshot keeps its own consistent store (the flat-row
    arithmetic changes with Lp, so sharing the grown table would silently
    gather wrong rows)."""
    x, q, _ = corpus
    n80 = int(x.shape[0] * 0.8)
    cfg = dataclasses.replace(CFG, rescore_tier="host")
    snap = lider.build_lider(jax.random.PRNGKey(0), x[:n80], cfg)
    before = _search(snap, q)
    grown, stats = update.upsert(snap, x[n80:])
    assert stats.capacity_grew
    assert grown.bank.store is not snap.bank.store
    assert snap.bank.store.shape[1] == snap.bank.capacity
    after = _search(snap, q)  # the old snapshot must be unaffected
    np.testing.assert_array_equal(np.asarray(before.ids), np.asarray(after.ids))
    np.testing.assert_array_equal(
        np.asarray(before.scores), np.asarray(after.scores)
    )


def test_round_trip_tier_conversion_is_lossless(tier_pair):
    _, q, _, pd, ph = tier_pair
    back = lider.set_rescore_tier(ph, "device")
    np.testing.assert_array_equal(
        np.asarray(back.bank.rescore_embs), np.asarray(pd.bank.rescore_embs)
    )
    _assert_bit_parity(pd, back, q)


# ---------------------------------------------------------------------------
# Checkpoint round-trip across tier changes
# ---------------------------------------------------------------------------


def test_checkpoint_round_trip_across_tiers(tmp_path, tier_pair):
    _, q, _, pd, ph = tier_pair
    # host-saved -> loads as host (default) and as device
    checkpoint.save_index(str(tmp_path / "h"), ph)
    as_host = checkpoint.load_index(str(tmp_path / "h"))
    as_dev = checkpoint.load_index(str(tmp_path / "h"), rescore_tier="device")
    assert as_host.bank.rescore_tier == "host"
    assert as_dev.bank.rescore_tier == "device"
    _assert_bit_parity(pd, as_host, q)
    _assert_bit_parity(pd, as_dev, q)
    # device-saved -> loads as host
    checkpoint.save_index(str(tmp_path / "d"), pd)
    cross = checkpoint.load_index(str(tmp_path / "d"), rescore_tier="host")
    assert cross.bank.rescore_tier == "host"
    _assert_bit_parity(pd, cross, q)


def test_checkpoint_rejects_host_tier_for_float(tmp_path, corpus):
    x, _, _ = corpus
    p32 = lider.build_lider(
        jax.random.PRNGKey(0), x, dataclasses.replace(CFG, storage_dtype="float32")
    )
    checkpoint.save_index(str(tmp_path), p32)
    with pytest.raises(ValueError, match="int8"):
        checkpoint.load_index(str(tmp_path), rescore_tier="host")


# ---------------------------------------------------------------------------
# Serving: pipelined drain + generation split
# ---------------------------------------------------------------------------


def _host_engine(ph, dim, **kw):
    search = make_backend("lider", None, updatable=True, n_probe=8, r0=8, **kw)
    return RetrievalEngine(search, batch_size=16, k=10, dim=dim, params=ph)


def test_engine_serves_host_tier_with_overlap(tier_pair):
    """Multi-batch drain through the double-buffered pipeline: every batch
    but the last fetches under a dispatched next batch, results match the
    serial staged search, and recall holds."""
    x, q, gt, _, ph = tier_pair
    eng = _host_engine(ph, x.shape[1])
    eng.warmup()
    qs = np.asarray(q)[:48]
    rids = [eng.submit(v) for v in qs]
    eng.drain()
    got = np.stack([eng.result(r)[0] for r in rids])
    s = eng.stats
    assert s.n_batches == 3 and s.n_host_fetches == 3
    assert s.n_overlapped_fetches == 2
    assert s.overlap_fraction == pytest.approx(2 / 3)
    assert s.host_fetch_us > 0 and s.aqt > 0
    serial = _search(ph, jnp.asarray(qs))
    np.testing.assert_array_equal(got, np.asarray(serial.ids))
    assert float(recall_at_k(jnp.asarray(got), gt[:48])) > 0.85
    # no pruning configured -> no probe stats (same contract as serial)
    assert s.n_probes_total == 0


def test_open_loop_drain_chunk_one_keeps_overlap(tier_pair):
    """Satellite regression (ROADMAP): open-loop replay with
    ``drain_chunk=1`` used to dispatch one batch per drain call, which
    collapsed the host-tier fetch overlap to zero; the driver now raises
    the chunk to the engine's pipeline depth for host-tier params."""
    x, q, _, _, ph = tier_pair
    eng = _host_engine(ph, x.shape[1])
    eng.warmup()
    pool = np.asarray(q)[:32]
    trace = make_trace(
        seed=0, n_arrivals=64, pool_size=len(pool), mean_rate=1e5,
    )
    rids = run_open_loop(eng, trace, pool, drain_chunk=1)
    assert len(rids) == 64
    assert all(eng.result(r) is not None for r in rids)
    s = eng.stats
    assert s.n_host_fetches >= 2
    assert s.overlap_fraction > 0


def test_pick_block_q_cost_model():
    """The autotuner's cost model: singleton clusters (no sharing to
    exploit) pick the shallowest rung, a hot cluster picks the deepest,
    and an empty observation window falls back to the first rung."""
    from repro.serving.engine import pick_block_q

    assert pick_block_q([np.ones(64, np.int64)], (2, 4, 8)) == 2
    assert pick_block_q([np.full(4, 128, np.int64)], (2, 4, 8)) == 8
    assert pick_block_q([], (4, 8)) == 4


def test_engine_autotunes_block_q_without_retrace(tier_pair):
    """Online block_q autotuning (staged host-tier serving): each drained
    batch's measured probe distribution re-picks the rung for the next
    dispatch, hot traffic climbs to the deepest rung, the measured sharing
    ratio lands in EngineStats, and — because every rung was pre-warmed in
    ``warmup`` and the schedule padding is fixed worst-case — the whole
    adaptation costs ZERO query-path retraces."""
    from repro.core.lider import query_path_cache_size
    from repro.serving.engine import pick_block_q

    x, q, _, _, ph = tier_pair
    ladder = (2, 4, 8)
    search = make_backend("lider", None, updatable=True, n_probe=8, r0=8)
    eng = RetrievalEngine(
        search, batch_size=16, k=10, dim=x.shape[1], params=ph,
        block_q_ladder=ladder,
    )
    eng.warmup()
    before = query_path_cache_size()
    # Hot trace: every query is a perturbation of one point, so all probes
    # concentrate on the same n_probe clusters (counts ~16 per cluster).
    rng = np.random.default_rng(0)
    hot = np.asarray(q)[:1] + 1e-3 * rng.normal(size=(48, x.shape[1]))
    hot /= np.linalg.norm(hot, axis=-1, keepdims=True)
    rids = [eng.submit(v.astype(np.float32)) for v in hot]
    eng.drain()
    assert all(eng.result(r) is not None for r in rids)
    assert query_path_cache_size() == before  # zero retraces while adapting
    s = eng.stats
    assert s.n_sched_pairs == 48 * 8
    assert 0 < s.n_sched_steps < s.n_sched_pairs
    assert s.sharing_ratio > 2.0
    assert len(s.sharing_trace) == 3  # one measurement per drained batch
    assert eng._auto_block_q == 8  # hot traffic -> deepest rung...
    # ...and the live pick is exactly the cost-model argmin over the window.
    assert pick_block_q(eng._probe_counts, ladder) == 8


def test_engine_static_block_q_overrides_autotune(tier_pair):
    """A static backend ``block_q`` is an explicit operator override: the
    ladder never injects an auto rung over it (the engine still serves)."""
    x, q, _, _, ph = tier_pair
    search = make_backend(
        "lider", None, updatable=True, n_probe=8, r0=8, block_q=4
    )
    eng = RetrievalEngine(
        search, batch_size=16, k=10, dim=x.shape[1], params=ph,
        block_q_ladder=(2, 8),
    )
    eng.warmup()
    # The auto rung is suppressed — the static kwarg reaches the search
    # through the backend's own kwargs, not through an injected point.
    assert (eng._effective_point() or {}).get("block_q") is None
    assert search.static_point.get("block_q") == 4
    rids = [eng.submit(v) for v in np.asarray(q)[:16]]
    eng.drain()
    assert all(eng.result(r) is not None for r in rids)


def test_engine_host_tier_reports_pruned_probes(tier_pair):
    x, q, _, _, ph = tier_pair
    eng = _host_engine(ph, x.shape[1], prune_margin=0.1)
    rids = [eng.submit(v) for v in np.asarray(q)[:40]]
    eng.drain()
    s = eng.stats
    assert s.n_probes_total == 40 * 8
    assert 0 < s.n_probes_pruned < s.n_probes_total
    for rid in rids:
        assert eng.result(rid) is not None


def test_host_only_update_does_not_recompile(tier_pair):
    """Satellite regression: apply_updates with only host-tier content
    changes must bump the host generation alone — no device recompile, no
    device generation bump."""
    x, _, _, _, ph = tier_pair
    eng = _host_engine(ph, x.shape[1])
    eng.warmup()

    def host_only(params):
        st = params.bank.store
        st.write_rows(np.array([0]), st.fetch(np.array([0])))
        return params

    grew = eng.apply_updates(host_only)
    assert not grew
    assert eng.recompiles == 0
    assert eng.device_generation == 0
    assert eng.host_generation == 1
    assert eng.generation == 1


def test_generations_split_on_mixed_update(corpus):
    x, _, _ = corpus
    # generous capacity so the upsert cannot grow shapes
    cfg = dataclasses.replace(CFG, capacity=512)
    ph = lider.set_rescore_tier(
        lider.build_lider(jax.random.PRNGKey(0), x, cfg), "host"
    )
    eng = _host_engine(ph, x.shape[1])
    eng.warmup()
    grew = eng.apply_updates(lambda p: update.upsert(p, x[:8] + 0.01))
    assert not grew and eng.recompiles == 0
    assert eng.device_generation == 1  # codes/scales/gids changed
    assert eng.host_generation == 1  # rescore rows written in lockstep

"""Serving fault-tolerance layer (DESIGN.md §Failure model): deterministic
fault injection, EmbStore transactions, engine retry/degrade/shed, checkpoint
integrity, and the restart harness."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.core import lider, update
from repro.core.bank import EmbStore
from repro.serving import (
    EVICTED,
    DegradePolicy,
    QueryResult,
    RetrievalEngine,
    Shed,
    make_backend,
)
from repro.training import checkpoint
from repro.training.fault_tolerance import Preemption, run_with_restarts
from repro.core.utils import l2_normalize

import jax


# ---------------------------------------------------------------------------
# Shared small host-tier index (one build; tests that mutate it rebuild).
# ---------------------------------------------------------------------------
N, DIM, K, BATCH = 600, 16, 5, 8
CFG = lider.LiderConfig(
    n_clusters=8, n_probe=4, n_arrays=4, n_leaves=4, kmeans_iters=5,
    storage_dtype="int8", rescore_tier="host",
)


@pytest.fixture(scope="module")
def data():
    x = l2_normalize(jax.random.normal(jax.random.PRNGKey(0), (N + 64, DIM)))
    base, held = x[:N], x[N:]
    q = np.asarray(
        l2_normalize(base[:BATCH] + 0.02), np.float32
    )
    return np.asarray(base), np.asarray(held), q


def build_params(data):
    base, _, _ = data
    return lider.build_lider(jax.random.PRNGKey(1), jnp.asarray(base), CFG)


def build_engine(data, *, policy=None, fault_plan=None, max_results=65536):
    engine = RetrievalEngine(
        make_backend("lider", None, updatable=True, n_probe=4),
        batch_size=BATCH, k=K, dim=DIM, params=build_params(data),
        policy=policy, fault_plan=fault_plan, max_results=max_results,
    )
    engine.warmup()
    return engine


def serve(engine, q):
    rids = [engine.submit(v) for v in q]
    engine.drain()
    return [engine.result(r) for r in rids]


def ids_of(results):
    return np.stack([np.asarray(r.ids) for r in results])


# ---------------------------------------------------------------------------
# FaultPlan scheduling
# ---------------------------------------------------------------------------
def test_fault_plan_times_deterministic_and_json_roundtrip():
    plan = faults.FaultPlan(
        [
            faults.FaultSpec("host_fetch", mode="error", times=(1,)),
            faults.FaultSpec("d2h", mode="delay", delay_s=0.0, times=(0, 2)),
        ],
        seed=3,
    )
    rt = faults.FaultPlan.from_json(json.dumps(plan.to_json()))
    assert rt.seed == plan.seed
    assert [s.to_dict() for s in rt.specs] == [s.to_dict() for s in plan.specs]

    with faults.activate(plan):
        assert faults.fire("host_fetch") is None  # call 0: no spec
        with pytest.raises(faults.InjectedFault) as ei:
            faults.fire("host_fetch")  # call 1: scheduled error
        assert ei.value.site == "host_fetch"
        for _ in range(3):
            faults.fire("d2h")  # calls 0..2: delays at 0 and 2
    assert plan.fired == [
        ("host_fetch", 1, "error"), ("d2h", 0, "delay"), ("d2h", 2, "delay")
    ]
    assert plan.n_fired == 3
    # inactive outside the context: the hook is a no-op
    assert faults.fire("host_fetch") is None
    assert plan.n_fired == 3


def test_fault_plan_probability_replays_per_site():
    def firings(interleave):
        plan = faults.FaultPlan(
            [faults.FaultSpec("d2h", mode="delay", probability=0.5)], seed=11
        )
        with faults.activate(plan):
            for site in interleave:
                faults.fire(site)
        return [f for f in plan.fired if f[0] == "d2h"]

    # Per-site seeded RNGs: the d2h draw sequence is independent of how
    # calls to other sites interleave with it.
    a = firings(["d2h"] * 20)
    b = firings(["host_fetch", "d2h"] * 20)
    assert a == b and 0 < len(a) < 20


def test_fault_plan_count_caps_firings():
    plan = faults.FaultPlan(
        [faults.FaultSpec("d2h", mode="delay", probability=1.0, count=2)]
    )
    with faults.activate(plan):
        for _ in range(5):
            faults.fire("d2h")
    assert plan.n_fired == 2


# ---------------------------------------------------------------------------
# EmbStore transactions
# ---------------------------------------------------------------------------
def _small_store():
    rng = np.random.default_rng(0)
    store = EmbStore(
        "host",
        rescore=rng.standard_normal((4, 6, 3)).astype(np.float32),
        gids=rng.integers(0, 100, (4, 6)).astype(np.int32),
    )
    return store


def test_embstore_rollback_restores_bytes_gids_version():
    store = _small_store()
    before = store.rescore.copy()
    gids_before = store.gids.copy()
    v0 = store.version

    store.begin_txn()
    assert store.in_txn
    store.write_rows(np.array([0, 7, 13]), np.ones((3, 3), np.float32))
    store.sync_gids(np.full((4, 6), 9, np.int32))
    store.compact_clusters(
        np.array([1]), np.array([[3, -1, 5, -1, -1, -1]])
    )
    store.write_rows(np.array([7]), np.full((1, 3), 2.0, np.float32))
    assert not np.array_equal(store.rescore, before)
    store.rollback()

    np.testing.assert_array_equal(store.rescore, before)
    np.testing.assert_array_equal(store.gids, gids_before)
    assert store.version == v0 and not store.in_txn


def test_embstore_commit_keeps_writes_and_txn_misuse_raises():
    store = _small_store()
    store.begin_txn()
    with pytest.raises(RuntimeError):
        store.begin_txn()  # nested transactions are a bug
    store.write_rows(np.array([2]), np.full((1, 3), 5.0, np.float32))
    store.commit()
    assert store.rescore.reshape(-1, 3)[2][0] == 5.0
    for op in (store.commit, store.rollback):
        with pytest.raises(RuntimeError):
            op()  # no open transaction


# ---------------------------------------------------------------------------
# Engine: transactional updates
# ---------------------------------------------------------------------------
def test_apply_updates_rolls_back_on_injected_fault(data):
    _, held, q = data
    plan = faults.FaultPlan(
        [faults.FaultSpec("host_write", mode="error", times=(0,))]
    )
    engine = build_engine(data, fault_plan=plan)
    before = serve(engine, q)

    with pytest.raises(faults.InjectedFault):
        engine.apply_updates(lambda p: update.upsert(p, jnp.asarray(held)))
    assert engine.stats.n_update_rollbacks == 1
    assert engine.generation == 0  # still serving the old generation
    assert not engine.params.bank.store.in_txn

    after = serve(engine, q)
    np.testing.assert_array_equal(ids_of(before), ids_of(after))
    for a, b in zip(before, after):
        np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))

    # The schedule has moved on: the retried update commits cleanly and the
    # new passages become searchable.
    engine.apply_updates(lambda p: update.upsert(p, jnp.asarray(held)))
    assert engine.generation == 1
    hq = np.asarray(l2_normalize(jnp.asarray(held[:BATCH])), np.float32)
    got = ids_of(serve(engine, hq))
    assert (got >= N).any()  # upserted gids start at N


# ---------------------------------------------------------------------------
# Engine: host-fetch retry and degraded answers
# ---------------------------------------------------------------------------
def test_fetch_fault_retried_transparently(data):
    _, _, q = data
    plan = faults.FaultPlan(
        [faults.FaultSpec("host_fetch", mode="error", times=(0,))]
    )
    engine = build_engine(
        data, policy=DegradePolicy(fetch_retries=2, fetch_backoff_s=0.0),
        fault_plan=plan,
    )
    out = serve(engine, q)
    assert engine.stats.n_fetch_retries == 1
    assert engine.stats.n_fetch_failures == 0
    assert not any(r.degraded for r in out)
    ref = lider.search_lider(engine.params, jnp.asarray(q), k=K, n_probe=4)
    np.testing.assert_array_equal(ids_of(out), np.asarray(ref.ids))


def test_fetch_exhaustion_degrades_instead_of_raising(data):
    _, _, q = data
    plan = faults.FaultPlan(
        [faults.FaultSpec("host_fetch", mode="error", times=(0, 1, 2))]
    )
    engine = build_engine(
        data, policy=DegradePolicy(fetch_retries=2, fetch_backoff_s=0.0),
        fault_plan=plan,
    )
    out = serve(engine, q)  # must not raise
    assert engine.stats.n_fetch_failures == 1
    assert all(r.degraded for r in out)
    assert engine.stats.n_degraded == BATCH
    got = ids_of(out)
    assert ((got >= 0) & (got < N)).all()  # compressed-only, still real gids

    # Outage over: the next batch is full quality again.
    out2 = serve(engine, q)
    assert not any(r.degraded for r in out2)
    ref = lider.search_lider(engine.params, jnp.asarray(q), k=K, n_probe=4)
    np.testing.assert_array_equal(ids_of(out2), np.asarray(ref.ids))


def test_deadline_pressure_steps_down_ladder(data):
    _, _, q = data
    ladder = ({"n_probe": 2, "expected_recall": 0.5},)
    engine = build_engine(
        data,
        policy=DegradePolicy(
            ladder=ladder, deadline_s=1e-6, degrade_age_fraction=0.5
        ),
    )
    out = serve(engine, q)
    # Any queue age exceeds a 1us deadline: the controller steps to rung 1
    # before the batch executes, and the answer IS the rung-1 operating
    # point (expected_recall is report metadata the engine must ignore).
    assert engine.stats.n_rung_steps >= 1
    assert all(r.rung == 1 and not r.degraded for r in out)
    assert engine.stats.n_deadline_misses == BATCH
    ref = lider.search_lider(engine.params, jnp.asarray(q), k=K, n_probe=2)
    np.testing.assert_array_equal(ids_of(out), np.asarray(ref.ids))


def test_queue_cap_sheds_with_structured_answer(data):
    _, _, q = data
    engine = build_engine(data, policy=DegradePolicy(max_queue=4))
    rids = [engine.submit(v) for v in np.repeat(q, 2, axis=0)[:6]]
    engine.drain()
    served = [engine.result(r) for r in rids[:4]]
    shed = [engine.result(r) for r in rids[4:]]
    assert all(isinstance(r, QueryResult) for r in served)
    assert all(isinstance(r, Shed) and r.reason == "queue_full" for r in shed)
    assert engine.stats.n_shed == 2
    assert engine.stats.n_queries == 4


def test_result_edge_semantics(data):
    _, _, q = data
    engine = build_engine(data, max_results=BATCH)
    assert engine.result(999) is None  # never submitted

    rids = [engine.submit(v) for v in q]
    engine.drain()
    r0 = engine.result(rids[0], keep=True)
    assert isinstance(r0, QueryResult)
    assert engine.result(rids[0]) is r0  # keep=True left it readable; pops now
    assert engine.result(rids[0]) is None  # already collected

    # A second batch overflows max_results=BATCH: the uncollected answers
    # from batch 1 are evicted -> the falsy EVICTED sentinel, distinct from
    # None.
    rids2 = [engine.submit(v) for v in q]
    engine.drain()
    for r in rids[1:]:
        assert engine.result(r) is EVICTED
        assert not engine.result(r)
    assert isinstance(engine.result(rids2[-1]), QueryResult)


# ---------------------------------------------------------------------------
# Checkpoint integrity
# ---------------------------------------------------------------------------
def test_crc_detects_corrupt_leaf_and_restore_latest_falls_back(tmp_path):
    d = str(tmp_path)
    mgr = checkpoint.CheckpointManager(d, keep=4)
    state = {"w": np.arange(16, dtype=np.float32), "b": np.ones(3, np.float32)}
    mgr.save(1, state)
    mgr.save(2, {"w": state["w"] + 1, "b": state["b"] + 1})

    # Corrupt step 2's "w" leaf on disk (bit rot / partial write), located
    # through the manifest rather than assuming leaf ordering.
    step_dir = os.path.join(d, "step_00000002")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        name = next(
            m["name"] for m in json.load(f)["leaves"] if m["name"].endswith("w")
        )
    np.save(os.path.join(step_dir, f"{name}.npy"), np.zeros(16, np.float32))
    with pytest.raises(checkpoint.CheckpointCorruptError) as ei:
        checkpoint.restore(d, 2, state)
    assert "w" in ei.value.leaf

    step, rec = mgr.restore_latest(
        {"w": np.zeros(16, np.float32), "b": np.zeros(3, np.float32)}
    )
    assert step == 1
    np.testing.assert_array_equal(rec["w"], state["w"])


def test_injected_truncation_is_detected(tmp_path):
    d = str(tmp_path)
    plan = faults.FaultPlan(
        [faults.FaultSpec("checkpoint_write", mode="truncate", times=(0,))]
    )
    state = {"w": np.arange(64, dtype=np.float32)}
    with faults.activate(plan):
        checkpoint.save(d, 1, state)
    assert plan.n_fired == 1
    with pytest.raises(checkpoint.CheckpointCorruptError):
        checkpoint.restore(d, 1, state)


def test_torn_index_write_auto_recovers(data, tmp_path):
    params = build_params(data)
    d = os.path.join(str(tmp_path), "idx")
    checkpoint.save_index(d, params)
    want = lider.search_lider(params, jnp.asarray(data[2]), k=K, n_probe=4)

    plan = faults.FaultPlan(
        [faults.FaultSpec("checkpoint_write", mode="torn_write", times=(0,))]
    )
    with pytest.raises(faults.InjectedFault):
        with faults.activate(plan):
            checkpoint.save_index(d, params)  # crashes inside the swap window

    # load_index detects the corrupt new generation and promotes index.old.
    loaded = checkpoint.load_index(d)
    got = lider.search_lider(loaded, jnp.asarray(data[2]), k=K, n_probe=4)
    np.testing.assert_array_equal(np.asarray(want.ids), np.asarray(got.ids))
    assert not os.path.exists(os.path.join(d, "index.old"))
    # The recovered checkpoint is fully healthy: a fresh save + load works.
    checkpoint.save_index(d, loaded)
    checkpoint.load_index(d)


def test_orphan_tmp_dirs_are_swept(tmp_path):
    d = str(tmp_path)
    for name in (".tmp_ckpt_dead", ".tmp_index_dead"):
        os.makedirs(os.path.join(d, name))
        with open(os.path.join(d, name, "leaf.npy"), "wb") as f:
            f.write(b"x")
    assert checkpoint.sweep_orphan_tmp(d) == 2
    assert not any(n.startswith(".tmp") for n in os.listdir(d))

    # CheckpointManager.__init__ and save_index both sweep on entry.
    os.makedirs(os.path.join(d, ".tmp_ckpt_dead2"))
    checkpoint.CheckpointManager(d)
    assert not os.path.exists(os.path.join(d, ".tmp_ckpt_dead2"))


# ---------------------------------------------------------------------------
# Restart harness
# ---------------------------------------------------------------------------
def _counting_step(fail_at, exc, calls):
    def step_fn(state, i):
        calls.append(i)
        if i == fail_at and not any(c == fail_at for c in calls[:-1]):
            raise exc
        return {"x": state["x"] + 1}

    return step_fn


def test_run_with_restarts_retries_configured_exceptions(tmp_path):
    mgr = checkpoint.CheckpointManager(str(tmp_path))
    calls = []
    state, restarts = run_with_restarts(
        lambda: {"x": np.zeros(1, np.float32)},
        _counting_step(5, OSError("flaky storage"), calls),
        n_steps=8, manager=mgr, checkpoint_every=2, retryable=(OSError,),
    )
    assert restarts == 1
    # Restored from step 4 and replayed: the step-indexed stream is exact.
    assert float(state["x"][0]) == 8.0
    assert calls.count(4) == 2  # steps 4..5 re-executed after the restart


def test_run_with_restarts_propagates_non_retryable(tmp_path):
    mgr = checkpoint.CheckpointManager(str(tmp_path))
    with pytest.raises(ValueError):
        run_with_restarts(
            lambda: {"x": np.zeros(1, np.float32)},
            _counting_step(3, ValueError("real bug"), []),
            n_steps=8, manager=mgr, checkpoint_every=2, retryable=(OSError,),
        )


def test_run_with_restarts_backoff_is_deterministic(tmp_path, monkeypatch):
    sleeps = []
    monkeypatch.setattr(
        "repro.training.fault_tolerance.time.sleep", sleeps.append
    )

    def run(sub):
        mgr = checkpoint.CheckpointManager(os.path.join(str(tmp_path), sub))
        calls = []

        def step_fn(state, i):
            calls.append(i)
            if len(calls) in (2, 5):  # two transient failures
                raise Preemption()
            return {"x": state["x"] + 1}

        return run_with_restarts(
            lambda: {"x": np.zeros(1, np.float32)}, step_fn,
            n_steps=4, manager=mgr, checkpoint_every=2,
            backoff_s=0.1, backoff_mult=2.0, jitter_seed=7,
        )

    _, restarts = run("a")
    assert restarts == 2
    first = list(sleeps)
    assert len(first) == 2
    assert 0.1 <= first[0] < 0.2  # base * jitter in [1, 2)
    assert 0.2 <= first[1] < 0.4  # doubled
    sleeps.clear()
    run("b")
    assert sleeps == first  # seeded jitter: same schedule every replay


def _corrupt_step(directory, step):
    """Flip one byte of one leaf so the step's CRC verification fails."""
    sd = os.path.join(directory, f"step_{step:08d}")
    leaf = next(n for n in sorted(os.listdir(sd)) if n.endswith(".npy"))
    with open(os.path.join(sd, leaf), "r+b") as f:
        b = f.read(1)
        f.seek(0)
        f.write(bytes([b[0] ^ 0xFF]))


def test_run_with_restarts_falls_back_past_corrupt_newest(tmp_path):
    """run_with_restarts x restore_latest newest-verified fallback: when
    the newest checkpoint is corrupt at restart time, the harness must
    restore the previous verified step and converge within the restart
    bound — not re-restore the corrupt step forever."""
    d = str(tmp_path)
    mgr = checkpoint.CheckpointManager(d)
    calls = []

    def step_fn(state, i):
        calls.append(i)
        if i == 5 and calls.count(5) == 1:
            _corrupt_step(d, 4)  # newest checkpoint (step_4) goes bad
            raise Preemption()
        return {"x": state["x"] + 1}

    state, restarts = run_with_restarts(
        lambda: {"x": np.zeros(1, np.float32)}, step_fn,
        n_steps=8, manager=mgr, checkpoint_every=2, max_restarts=3,
    )
    assert restarts == 1  # bounded: one restart, no restore loop
    assert float(state["x"][0]) == 8.0  # exact convergence
    # Fallback restored step 2 (not the corrupt step 4): steps 2..5 were
    # re-executed once each, and total work is exactly 6 + 6 steps.
    assert calls.count(2) == 2 and calls.count(4) == 2
    assert len(calls) == 12

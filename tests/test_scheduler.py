"""Async serving front end (DESIGN.md §Serving front end): weighted-fair
queues, result cache coherence, dynamic batch sizing, SLO admission, the
zero-recompile warmup contract, and the non-blocking fetch-backoff path."""
import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.core import lider, update
from repro.core.utils import l2_normalize
from repro.serving import (
    DegradePolicy,
    QueryResult,
    RetrievalEngine,
    SchedulerConfig,
    make_backend,
)
from repro.serving.engine import EngineStats
from repro.serving.scheduler import (
    Request,
    ResultCache,
    Scheduler,
    batch_ladder,
)
from repro.tuning import pareto


# ---------------------------------------------------------------------------
# Shared small device-tier index (module scope: tests here never mutate it).
# ---------------------------------------------------------------------------
N, DIM, K, BATCH = 600, 16, 5, 16


@pytest.fixture(scope="module")
def served():
    x = l2_normalize(jax.random.normal(jax.random.PRNGKey(0), (N, DIM)))
    q = np.asarray(l2_normalize(x[:64] + 0.02), np.float32)
    params = lider.build_lider(
        jax.random.PRNGKey(1),
        x,
        lider.LiderConfig(
            n_clusters=8, n_probe=4, n_arrays=4, n_leaves=4, kmeans_iters=5
        ),
    )
    return params, q


def build_engine(params, *, sched=None, policy=None, fault_plan=None):
    engine = RetrievalEngine(
        make_backend("lider", None, updatable=True, n_probe=4),
        batch_size=BATCH, k=K, dim=DIM, params=params,
        policy=policy, fault_plan=fault_plan, scheduler=sched,
    )
    engine.warmup()
    return engine


def req(rid, tenant="t", t_submit=0.0):
    return Request(
        rid=rid, query=np.zeros(2, np.float32), t_submit=t_submit,
        tenant=tenant,
    )


# ---------------------------------------------------------------------------
# Scheduler unit: ladder, fairness, admission, sizing
# ---------------------------------------------------------------------------
def test_batch_ladder_pow2_and_includes_max():
    assert batch_ladder(32, 1) == (1, 2, 4, 8, 16, 32)
    assert batch_ladder(24, 4) == (4, 8, 16, 24)  # max always present
    assert batch_ladder(16, 16) == (16,)
    assert batch_ladder(8, 0) == (1, 2, 4, 8)  # min clamped to 1


def test_weighted_fair_take_interleaves_skewed_tenants():
    s = Scheduler(SchedulerConfig(), batch_size=8)
    for i in range(12):
        s.admit(req(i, tenant="heavy"))
    for i in range(12, 16):
        s.admit(req(i, tenant="light"))
    # Equal weights: despite heavy submitting 3x more, the first 8 slots
    # split 4/4 — arrival skew must not become service skew.
    tenants = [r.tenant for r in s.take(8)]
    assert tenants.count("heavy") == 4 and tenants.count("light") == 4
    # light's queue exhausts; heavy then gets the rest.
    rest = [r.tenant for r in s.take(12)]
    assert rest.count("light") == 0 and rest.count("heavy") == 8


def test_weighted_fair_honors_weights():
    cfg = SchedulerConfig(tenant_weights={"a": 3.0, "b": 1.0})
    s = Scheduler(cfg, batch_size=8)
    for i in range(16):
        s.admit(req(2 * i, tenant="a"))
        s.admit(req(2 * i + 1, tenant="b"))
    got = [r.tenant for r in s.take(8)]
    # 3:1 weights -> 6 of 8 slots for a.
    assert got.count("a") == 6 and got.count("b") == 2


def test_idle_tenant_banks_no_credit():
    s = Scheduler(SchedulerConfig(), batch_size=8)
    for i in range(8):
        s.admit(req(i, tenant="busy"))
    s.take(8)  # busy's vtime is now 8
    # A tenant that sat idle the whole time now bursts: it must share from
    # the current virtual clock, not replay its zero history and starve busy.
    for i in range(8, 16):
        s.admit(req(i, tenant="idler"))
    for i in range(16, 24):
        s.admit(req(i, tenant="busy"))
    got = [r.tenant for r in s.take(8)]
    assert got.count("idler") == 4 and got.count("busy") == 4


def test_queue_cap_and_deadline_admission():
    s = Scheduler(
        SchedulerConfig(slo_s=0.01, deadline_admission=True), batch_size=8
    )
    assert s.admit(req(0)) is None
    # Service estimate: 8 queries took 80ms -> 10ms each; with one request
    # queued the next waits ~10ms (exactly the SLO, admitted), but two
    # queued predicts 20ms of queueing -> a guaranteed miss -> "deadline".
    s.observe_service(8, 0.08)
    assert s.admit(req(1)) is None
    assert s.admit(req(2)) == "deadline"
    # Queue cap is reported as queue_full (checked before the deadline).
    s2 = Scheduler(SchedulerConfig(max_queue=2), batch_size=8)
    assert s2.admit(req(0)) is None and s2.admit(req(1)) is None
    assert s2.admit(req(2)) == "queue_full"


def test_pick_batch_size_tracks_depth_and_slo_headroom():
    cfg = SchedulerConfig(dynamic_batch=True, min_batch=2, slo_s=0.1)
    s = Scheduler(cfg, batch_size=16)
    assert s.ladder == (2, 4, 8, 16)
    now = time.perf_counter()
    for i in range(3):
        s.admit(req(i, t_submit=now))
    assert s.pick_batch_size(now) == 4  # smallest rung covering depth 3
    for i in range(3, 20):
        s.admit(req(i, t_submit=now))
    assert s.pick_batch_size(now) == 16  # saturated
    # SLO headroom: 10ms/query measured, oldest has 30ms headroom left ->
    # a 16-batch (160ms) would blow it; the largest safe rung is 2.
    s.observe_service(16, 0.16)
    assert s.pick_batch_size(now + 0.07) == 2


def test_load_signal_tracks_depth_and_age():
    cfg = SchedulerConfig(dynamic_batch=True, slo_s=0.1, depth_reference=10)
    s = Scheduler(cfg, batch_size=4)
    now = time.perf_counter()
    assert s.load_signal(now) == 0.0
    for i in range(5):
        s.admit(req(i, t_submit=now))
    assert s.load_signal(now) == pytest.approx(0.5)  # depth half of ref
    # Age pressure dominates when the oldest request nears the SLO.
    assert s.load_signal(now + 0.09) == pytest.approx(0.9)
    assert s.load_signal(now + 1.0) == 1.0  # clamped


# ---------------------------------------------------------------------------
# ResultCache unit
# ---------------------------------------------------------------------------
def test_result_cache_lru_bound_and_context_keys():
    c = ResultCache(2)
    fp = [ResultCache.fingerprint(np.full(4, i, np.float32)) for i in range(3)]
    ctx = (5, 0, 0)  # (k, generation, rung)
    c.put(fp[0], ctx, np.array([1]), np.array([0.5]))
    c.put(fp[1], ctx, np.array([2]), np.array([0.6]))
    assert c.get(fp[0], ctx) is not None  # refresh 0 -> 1 becomes LRU
    c.put(fp[2], ctx, np.array([3]), np.array([0.7]))
    assert len(c) == 2
    assert c.get(fp[1], ctx) is None  # evicted
    assert c.get(fp[0], ctx) is not None
    # Same query bytes under a different generation / rung / k is a miss:
    # the serving context is part of the key.
    assert c.get(fp[0], (5, 1, 0)) is None
    assert c.get(fp[0], (5, 0, 1)) is None
    assert c.get(fp[0], (10, 0, 0)) is None


# ---------------------------------------------------------------------------
# Engine: cache-hit bit-identity and generation invalidation
# ---------------------------------------------------------------------------
def test_cache_hits_bit_identical_and_invalidated_on_update(served):
    params, q = served
    engine = build_engine(params, sched=SchedulerConfig(cache_size=256))
    pool = q[:BATCH]

    def serve(vectors):
        rids = [engine.submit(v) for v in vectors]
        engine.drain()
        return [engine.result(r) for r in rids]

    first = serve(pool)
    assert engine.stats.n_cache_hits == 0
    second = serve(pool)  # same bytes, same generation -> all hits
    assert engine.stats.n_cache_hits == BATCH
    assert engine.stats.n_batches == 1  # round two never touched the device
    assert all(r.cached for r in second)
    ref = lider.search_lider(engine.params, jnp.asarray(pool), k=K, n_probe=4)
    for i, (a, b) in enumerate(zip(first, second)):
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(
            np.asarray(a.scores), np.asarray(b.scores)
        )
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(ref.ids)[i])

    # apply_updates bumps the generation: the same bytes MUST miss and be
    # recomputed against the new corpus.
    extra = l2_normalize(
        jax.random.normal(jax.random.PRNGKey(9), (32, DIM))
    )
    engine.apply_updates(lambda p: update.upsert(p, extra))
    third = serve(pool)
    assert engine.stats.n_cache_hits == BATCH  # no new hits
    assert not any(r.cached for r in third)
    ref2 = lider.search_lider(engine.params, jnp.asarray(pool), k=K, n_probe=4)
    np.testing.assert_array_equal(
        np.stack([np.asarray(r.ids) for r in third]), np.asarray(ref2.ids)
    )


# ---------------------------------------------------------------------------
# Engine: dynamic batch sizing bit-identity + zero recompiles under load sweep
# ---------------------------------------------------------------------------
def test_dynamic_batches_bit_identical_to_fixed(served):
    params, q = served
    fixed = build_engine(params)
    dyn = build_engine(
        params, sched=SchedulerConfig(dynamic_batch=True, min_batch=2)
    )

    def serve(engine, chunks):
        out = []
        for c in chunks:
            rids = [engine.submit(v) for v in c]
            engine.drain()
            out.extend(engine.result(r) for r in rids)
        return out

    chunks = [q[:3], q[3:10], q[10:26], q[26:27]]  # depths 3, 7, 16, 1
    a = serve(fixed, chunks)
    b = serve(dyn, chunks)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
        np.testing.assert_array_equal(
            np.asarray(ra.scores), np.asarray(rb.scores)
        )
    # The sizing actually engaged (4, 8, 16, 2) and padding shrank.
    assert list(dyn.stats.batch_size_trace) == [4, 8, 16, 2]
    assert dyn.stats.n_padded < fixed.stats.n_padded


def test_no_recompiles_across_load_sweep_after_warmup(served):
    params, q = served
    engine = build_engine(
        params,
        sched=SchedulerConfig(dynamic_batch=True, min_batch=2),
        policy=DegradePolicy(
            ladder=({"n_probe": 2},), deadline_s=10.0
        ),
    )
    compiled = lider.query_path_cache_size()
    assert compiled > 0  # the detector sees the warmed traces
    for depth in (1, 2, 3, 5, 8, 13, 16, 27):
        rids = [engine.submit(v) for v in q[:depth]]
        engine.drain()
        for r in rids:
            assert isinstance(engine.result(r), QueryResult)
    assert lider.query_path_cache_size() == compiled
    assert engine.recompiles == 0


# ---------------------------------------------------------------------------
# Engine: fetch backoff must yield to the pipeline (host-fetch brownout)
# ---------------------------------------------------------------------------
def test_fetch_backoff_does_not_block_other_batches():
    BACKOFF = 0.25
    n, dim, k, batch = 400, 16, 5, 8
    x = l2_normalize(jax.random.normal(jax.random.PRNGKey(2), (n, dim)))
    params = lider.build_lider(
        jax.random.PRNGKey(1),
        x,
        lider.LiderConfig(
            n_clusters=8, n_probe=4, n_arrays=4, n_leaves=4, kmeans_iters=5,
            storage_dtype="int8", rescore_tier="host",
        ),
    )
    q = np.asarray(l2_normalize(x[: 2 * batch] + 0.02), np.float32)
    # Batch A's first fetch fails (call 0); its retry backs off for
    # BACKOFF+ seconds. The old engine slept inline and stalled the whole
    # pipeline; the scheduler-driven drain must finish batch B during A's
    # backoff window.
    plan = faults.FaultPlan(
        [faults.FaultSpec("host_fetch", mode="error", times=(0,))]
    )
    engine = RetrievalEngine(
        make_backend("lider", None, updatable=True, n_probe=4),
        batch_size=batch, k=k, dim=dim, params=params,
        policy=DegradePolicy(
            fetch_retries=2, fetch_backoff_s=BACKOFF, fetch_backoff_mult=1.0
        ),
        fault_plan=plan,
    )
    engine.warmup()
    rids = [engine.submit(v) for v in q]
    engine.drain()
    out = [engine.result(r) for r in rids]
    a_lat = [r.latency_s for r in out[:batch]]
    b_lat = [r.latency_s for r in out[batch:]]
    # Both batches answered at full quality; A retried exactly once.
    assert engine.stats.n_fetch_retries == 1
    assert engine.stats.n_fetch_failures == 0
    assert not any(r.degraded for r in out)
    ref = lider.search_lider(engine.params, jnp.asarray(q), k=k, n_probe=4)
    np.testing.assert_array_equal(
        np.stack([np.asarray(r.ids) for r in out]), np.asarray(ref.ids)
    )
    # The yield: B (submitted after A) finished BEFORE A's backoff elapsed;
    # A's answer waited out the backoff.
    assert min(a_lat) >= BACKOFF
    assert max(b_lat) < BACKOFF


# ---------------------------------------------------------------------------
# Stats boundedness (long-running server must not grow per-batch state)
# ---------------------------------------------------------------------------
def test_all_engine_stat_traces_are_bounded(served):
    for f in dataclasses.fields(EngineStats):
        has_factory = f.default_factory is not dataclasses.MISSING
        default = f.default_factory() if has_factory else None
        if isinstance(default, collections.deque):
            assert default.maxlen is not None, (
                f"EngineStats.{f.name} is an unbounded deque — per-batch "
                "traces must carry a maxlen"
            )
        else:
            assert not isinstance(default, list), (
                f"EngineStats.{f.name} is an unbounded list"
            )
    params, q = served
    engine = build_engine(params, sched=SchedulerConfig(cache_size=8))
    for _ in range(3):
        rids = [engine.submit(v) for v in q[:4]]
        engine.drain()
        for r in rids:
            engine.result(r)
    s = engine.stats
    assert len(s.batch_size_trace) <= s.batch_size_trace.maxlen
    assert len(s.recent_latency_s) <= s.recent_latency_s.maxlen


# ---------------------------------------------------------------------------
# Control plane: load-aware operating-point selection
# ---------------------------------------------------------------------------
def _sweep_result(n_probe, aqt_s, recall):
    return pareto.SweepResult(
        point=pareto.OperatingPoint(n_probe=n_probe),
        aqt_s=aqt_s, wall_aqt_s=aqt_s, wall_route_s=0.0, wall_full_s=aqt_s,
        recall=recall, mrr10=recall, pruned_fraction=0.0,
    )


def test_select_operating_point_navigates_frontier_with_load():
    results = [
        _sweep_result(32, 8e-4, 0.99),
        _sweep_result(16, 4e-4, 0.97),
        _sweep_result(8, 2e-4, 0.93),
        _sweep_result(4, 1e-4, 0.85),
    ]
    # Offline spelling unchanged: cheapest point meeting the target.
    assert pareto.select_operating_point(results, 0.95).point.n_probe == 16
    # Online: load 0 == nominal; rising load walks to cheaper frontier
    # points; load 1 reaches the cheapest. AQT must be monotone non-
    # increasing in load — adaptivity never picks a pricier point under
    # MORE pressure.
    picks = [
        pareto.select_operating_point(results, 0.95, load_signal=l)
        for l in (0.0, 0.34, 0.67, 1.0)
    ]
    assert picks[0].point.n_probe == 16
    assert picks[-1].point.n_probe == 4
    aqts = [p.aqt_s for p in picks]
    assert aqts == sorted(aqts, reverse=True)

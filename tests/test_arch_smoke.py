"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one train/serve step on CPU with finite outputs and the right
shapes. The FULL configs are exercised (lower+compile only) by the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_arch
from repro.data import synthetic
from repro.launch.train import reduced_gnn, reduced_lm, reduced_recsys
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm

LM_ARCHS = [a for a in ASSIGNED if ARCHS[a].family == "lm"]
RECSYS_ARCHS = [a for a in ASSIGNED if ARCHS[a].family == "recsys"]


def _finite(tree) -> bool:
    return all(
        bool(jnp.all(jnp.isfinite(x)))
        for x in jax.tree.leaves(tree)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
    )


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_smoke(arch_id):
    arch = get_arch(arch_id)
    cfg = reduced_lm(arch.config)
    # keep the family traits: GQA ratio>1 where the full config has it, MoE
    # where the full config has it, local windows where it has them
    assert (cfg.moe is None) == (arch.config.moe is None)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    batch = synthetic.lm_batch(0, 0, batch=2, seq=32, vocab=cfg.vocab)
    loss, grads = jax.value_and_grad(tfm.train_loss)(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    assert _finite(grads)
    # serve path: prefill + one decode step
    logits, cache = tfm.prefill(params, cfg, batch["tokens"][:, :16])
    assert logits.shape == (2, cfg.vocab)
    cache = {
        "k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 16), (0, 0), (0, 0))),
        "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 16), (0, 0), (0, 0))),
        "length": cache["length"],
    }
    logits2, cache = tfm.decode_step(params, cfg, cache, batch["tokens"][:, 16:17])
    assert logits2.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache["length"]) == 17


def test_gatedgcn_smoke_node_and_graph():
    arch = get_arch("gatedgcn")
    cfg = reduced_gnn(arch.config)
    params = gnn_lib.init(jax.random.PRNGKey(0), cfg)
    g = synthetic.random_graph(0, 128, 512, cfg.d_feat, cfg.n_classes)
    graph = {k: g[k] for k in ("node_feat", "edge_index", "labels")}
    out = gnn_lib.forward(params, cfg, graph)
    assert out.shape == (128, cfg.n_classes)
    loss, grads = jax.value_and_grad(gnn_lib.train_loss)(params, cfg, graph)
    assert np.isfinite(float(loss)) and _finite(grads)
    # molecule-style graph readout
    mcfg = dataclasses.replace(cfg, d_edge=4, n_classes=1, readout="graph", d_feat=16)
    mparams = gnn_lib.init(jax.random.PRNGKey(1), mcfg)
    mb = synthetic.molecule_batch(0, 0, n_graphs=8, nodes_per=10, edges_per=16, d_feat=16)
    mloss = gnn_lib.train_loss(mparams, mcfg, mb)
    assert np.isfinite(float(mloss))


def test_gatedgcn_neighbor_sampler_block_trains():
    arch = get_arch("gatedgcn")
    cfg = reduced_gnn(arch.config)
    g = synthetic.random_graph(1, 256, 2048, cfg.d_feat, cfg.n_classes)
    block = gnn_lib.neighbor_sample(
        jax.random.PRNGKey(2),
        g["indptr"],
        g["indices"],
        g["node_feat"],
        g["labels"],
        jnp.arange(16, dtype=jnp.int32),
        (4, 3),
    )
    assert block["node_feat"].shape[0] == 16 + 64 + 192
    assert block["edge_index"].shape == (2, 64 + 192)
    assert int(block["edge_index"].max()) < block["node_feat"].shape[0]
    params = gnn_lib.init(jax.random.PRNGKey(0), cfg)
    loss = gnn_lib.train_loss(params, cfg, block)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_arch_smoke(arch_id):
    arch = get_arch(arch_id)
    cfg = reduced_recsys(arch.config)
    init = recsys_lib.INIT[cfg.kind]
    loss_fn = recsys_lib.LOSS[cfg.kind]
    params = init(jax.random.PRNGKey(0), cfg)
    batch = synthetic.recsys_batch(0, 0, kind=cfg.kind, batch=16, cfg=cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert _finite(grads)


def test_two_tower_retrieval_cand_smoke():
    arch = get_arch("two-tower-retrieval")
    cfg = reduced_recsys(arch.config)
    params = recsys_lib.two_tower_init(jax.random.PRNGKey(0), cfg)
    users = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.n_user_fields), 0, cfg.field_vocab)
    cands = jax.random.normal(jax.random.PRNGKey(2), (1000, cfg.tower_dims[-1]))
    scores, ids = recsys_lib.two_tower_score_candidates(params, cfg, users, cands, 10)
    assert scores.shape == (1, 10) and ids.shape == (1, 10)
    assert np.isfinite(np.asarray(scores)).all()


def test_all_ten_assigned_archs_registered():
    assert len(ASSIGNED) == 10
    families = {ARCHS[a].family for a in ASSIGNED}
    assert families == {"lm", "gnn", "recsys"}
    # every arch has its full shape set
    for a in ASSIGNED:
        assert len(ARCHS[a].shapes) == 4

"""End-to-end system behaviour: the paper's full pipeline on CPU-sized data
(encode -> build LIDER -> serve), plus structural checks that every assigned
(arch x shape) cell constructs a lowerable step bundle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_arch
from repro.core import lider
from repro.core.baselines import flat_search
from repro.core.utils import l2_normalize, recall_at_k
from repro.data import synthetic
from repro.models import recsys as recsys_lib
from repro.training import optimizer as opt_lib


def test_end_to_end_retrieval_pipeline(corpus):
    """Build LIDER over the corpus and verify the serving path beats the
    required quality bar at paper-style settings."""
    x, q, gt = corpus
    cfg = lider.LiderConfig(
        n_clusters=64, n_probe=12, n_arrays=6, n_leaves=4, kmeans_iters=10
    )
    params = lider.build_lider(jax.random.PRNGKey(0), x, cfg)
    out = lider.search_lider(params, q, k=10, n_probe=12, r0=8)
    assert float(recall_at_k(out.ids, gt)) > 0.9


def test_trained_encoder_plus_lider_end_to_end():
    """The paper's deployment: a two-tower encoder produces embeddings, LIDER
    indexes them, retrieval returns the trained-relevant items."""
    cfg = recsys_lib.RecsysConfig(
        name="tt", kind="two_tower", embed_dim=16, item_vocab=512,
        field_vocab=64, tower_dims=(64, 32), n_user_fields=4, n_item_fields=2,
    )
    params = recsys_lib.two_tower_init(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.OptimizerConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=60)
    state = opt_lib.init_state(params)

    @jax.jit
    def step(p, s, b):
        loss, g = jax.value_and_grad(recsys_lib.two_tower_loss)(p, cfg, b)
        p, s, m = opt_lib.apply_updates(p, g, s, ocfg)
        return p, s, loss

    losses = []
    for i in range(60):
        batch = synthetic.recsys_batch(0, i, kind="two_tower", batch=64, cfg=cfg)
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3  # encoder actually trained

    # Index all items through the item tower.
    all_items = jnp.stack(
        [jnp.arange(512, dtype=jnp.int32), jnp.zeros((512,), jnp.int32)], axis=1
    )
    item_embs = recsys_lib.item_embed(params, cfg, all_items)
    item_embs = l2_normalize(item_embs)
    idx_cfg = lider.LiderConfig(n_clusters=16, n_probe=6, n_arrays=4, n_leaves=2, kmeans_iters=8)
    index = lider.build_lider(jax.random.PRNGKey(1), item_embs, idx_cfg)
    users = synthetic.recsys_batch(0, 999, kind="two_tower", batch=16, cfg=cfg)["user_fields"]
    u = l2_normalize(recsys_lib.user_embed(params, cfg, users))
    got = lider.search_lider(index, u, k=10, n_probe=6, r0=8)
    gt = flat_search(item_embs, u, k=10)
    assert float(recall_at_k(got.ids, gt.ids)) > 0.85


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_every_cell_constructs_a_bundle(arch_id):
    """All 40 (arch x shape) cells produce a StepBundle whose abstract args,
    shardings and flops are well-formed (full lower/compile happens in the
    dry-run; this guards the construction path in unit tests)."""
    import numpy as np

    from repro import compat
    from repro.launch.steps import make_bundle

    mesh = compat.mesh_from_devices(
        np.array(jax.devices()).reshape(1, 1), ("data", "model")
    )
    arch = get_arch(arch_id)
    for shape in arch.shapes:
        with compat.set_mesh(mesh):
            b = make_bundle(arch, shape, mesh)
        assert b.model_flops > 0
        flat_args = jax.tree.leaves(b.args)
        flat_sh = jax.tree.leaves(
            b.in_shardings, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)
        )
        assert len(flat_args) == len(flat_sh)
        assert all(isinstance(s, jax.sharding.NamedSharding) for s in flat_sh)


def test_lider_msmarco_bundle_dims():
    from repro.launch.steps import lider_param_structs

    arch = get_arch("lider-msmarco")
    s = lider_param_structs(arch.config)
    assert s.bank.embs.shape == (1024, 12288, 768)
    assert s.bank.sorted_keys.shape == (1024, 10, 12288)
    # corpus fits the padded grid
    assert arch.config.corpus_size <= 1024 * 12288

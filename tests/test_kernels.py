"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fused_verify, kmeans_assign, lsh_hash, ref
from repro.kernels.ops import kmeans_assign_op, lsh_hash_op, verify_topk_op


@pytest.mark.parametrize(
    "n,d,h,m",
    [(64, 32, 2, 8), (100, 64, 4, 12), (257, 128, 10, 24), (16, 256, 1, 31), (8, 8, 3, 5)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lsh_hash_matches_ref(n, d, h, m, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + d))
    x = jax.random.normal(k1, (n, d), dtype)
    p = jax.random.normal(k2, (d, h * m), jnp.float32)
    got = lsh_hash(x, p, n_arrays=h, key_len=m, interpret=True, block_n=64)
    want = ref.lsh_hash_ref(x, p, h, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize(
    "n,c,d,bn,bc",
    [(64, 8, 16, 32, 8), (100, 16, 32, 64, 8), (513, 70, 64, 128, 32), (33, 7, 8, 16, 4)],
)
def test_kmeans_assign_matches_ref(n, c, d, bn, bc):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + c))
    x = jax.random.normal(k1, (n, d))
    cen = jax.random.normal(k2, (c, d))
    gi, gd = kmeans_assign(x, cen, block_n=bn, block_c=bc, interpret=True)
    wi, wd = ref.kmeans_assign_ref(x, cen)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,c,n,d,k", [(2, 8, 20, 16, 3), (4, 10, 50, 64, 5), (1, 3, 5, 128, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_verify_matches_ref(b, c, n, d, k, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * c), 3)
    embs = jax.random.normal(k1, (n, d), dtype)
    ids = jax.random.randint(k2, (b, c), -1, n)
    q = jax.random.normal(k3, (b, d), dtype)
    gi, gs = fused_verify(embs, ids, q, k=k, block_c=4, interpret=True)
    wi, ws = ref.verify_topk_ref(embs, ids, q, k=k)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(gs), np.asarray(ws), rtol=rtol, atol=rtol
    )


def test_ops_dispatch_to_ref_on_cpu():
    """On CPU (no TPU) the op wrappers must route to the oracle."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    p = jax.random.normal(jax.random.PRNGKey(1), (8, 12))
    got = lsh_hash_op(x, p, n_arrays=3, key_len=4)
    want = ref.lsh_hash_ref(x, p, 3, 4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    cen = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
    gi, _ = kmeans_assign_op(x, cen)
    wi, _ = ref.kmeans_assign_ref(x, cen)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    ids = jnp.asarray([[0, 1, -1]])
    q = x[:1]
    gi, gs = verify_topk_op(x, ids, q, k=2)
    wi, ws = ref.verify_topk_ref(x, ids, q, k=2)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), rtol=1e-6)


def test_lsh_hash_pallas_used_by_core_build(corpus):
    """The kernel and the core library agree on actual corpus hashing."""
    from repro.core import lsh as lsh_lib

    x, _, _ = corpus
    params = lsh_lib.make_lsh(jax.random.PRNGKey(9), x.shape[1], 4, 16)
    want = lsh_lib.hash_vectors(params, x)
    got = lsh_hash(
        x, params.projections, n_arrays=4, key_len=16, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

"""Quantized ClusterBank (DESIGN.md §Quantized bank): int8/int4 round-trip
error bounds, packed-nibble idempotence, kernel-vs-oracle parity across
storage dtypes and dead/mixed blocks, lifecycle (upsert/delete/checkpoint)
consistency of the code + scale + rescore tables, and the quantized+rescore
recall-parity acceptance checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering, lider, update
from repro.core.bank import store_rows
from repro.core.baselines import flat_search
from repro.core.utils import l2_normalize, recall_at_k
from repro.kernels import fused_verify, ref
from repro.kernels.quant import (
    INT4_MAX,
    INT8_MAX,
    dequantize_rows,
    dequantize_rows_int4,
    pack_int4,
    quantize_rows,
    quantize_rows_int4,
    unpack_int4,
)
from repro.serving import RetrievalEngine, make_backend
from repro.training import checkpoint


# ---------------------------------------------------------------------------
# Quantization scheme: round-trip error bound (hypothesis property test)
# ---------------------------------------------------------------------------


def test_int8_roundtrip_score_error_bounded_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 10_000), st.integers(1, 96), st.floats(0.01, 100.0))
    @settings(max_examples=60, deadline=None)
    def check(seed, d, magnitude):
        rng = np.random.default_rng(seed)
        x = (rng.normal(size=(4, d)) * magnitude).astype(np.float32)
        q = rng.normal(size=(d,)).astype(np.float32)
        codes, scales = quantize_rows(jnp.asarray(x))
        dq = np.asarray(dequantize_rows(codes, scales))
        # Per-element round-to-nearest error is <= scale/2, so the score
        # error of one quantized row against an exact query is bounded by
        # ||q||_1 * scale/2 — the §Quantized bank error model.
        got = dq @ q
        want = x @ q
        bound = np.abs(q).sum() * (np.asarray(scales) / 2.0) + 1e-4
        assert (np.abs(got - want) <= bound).all()
        # codes stay in the symmetric range (-128 never appears)
        assert np.abs(np.asarray(codes, np.int32)).max() <= INT8_MAX

    check()


def test_int4_roundtrip_score_error_bounded_hypothesis():
    """The 4-bit analogue of the §Quantized bank error model: per-element
    round-to-nearest error is <= scale/2 (with scale = max|x|/7), so a
    quantized row's score error against an exact query is bounded by
    ||q||_1 * scale/2 — identical bound shape, coarser scale."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 10_000), st.integers(1, 48), st.floats(0.01, 100.0))
    @settings(max_examples=60, deadline=None)
    def check(seed, half_d, magnitude):
        d = 2 * half_d  # packing needs an even row width
        rng = np.random.default_rng(seed)
        x = (rng.normal(size=(4, d)) * magnitude).astype(np.float32)
        q = rng.normal(size=(d,)).astype(np.float32)
        packed, scales = quantize_rows_int4(jnp.asarray(x))
        assert packed.shape == (4, d // 2) and packed.dtype == jnp.int8
        dq = np.asarray(dequantize_rows_int4(packed, scales))
        bound = np.abs(q).sum() * (np.asarray(scales) / 2.0) + 1e-4
        assert (np.abs(dq @ q - x @ q) <= bound).all()
        # unpacked nibbles stay in the symmetric range (-8 never appears)
        codes = np.asarray(unpack_int4(packed), np.int32)
        assert np.abs(codes).max() <= INT4_MAX

    check()


def test_int4_pack_unpack_idempotent():
    """pack/unpack are exact inverses over the full nibble range [-8, 7]
    (the packed carrier can hold -8 even though the quantizer never emits
    it), across arbitrary leading dims; odd widths are rejected."""
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(-8, 8, size=(5, 3, 24)), jnp.int8)
    packed = pack_int4(codes)
    assert packed.shape == (5, 3, 12) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), np.asarray(codes))
    np.testing.assert_array_equal(
        np.asarray(pack_int4(unpack_int4(packed))), np.asarray(packed)
    )
    with pytest.raises(ValueError, match="even"):
        pack_int4(jnp.zeros((2, 7), jnp.int8))


def test_int4_zero_rows_pack_to_zero_bytes():
    """All-zero (padded-slot) rows must pack to exact zero bytes, scale 1."""
    packed, scales = quantize_rows_int4(jnp.zeros((3, 16)))
    np.testing.assert_array_equal(np.asarray(packed), 0)
    np.testing.assert_array_equal(np.asarray(scales), 1.0)
    np.testing.assert_array_equal(
        np.asarray(dequantize_rows_int4(packed, scales)), 0.0
    )


def test_quantize_zero_rows_are_exact_padding():
    """All-zero (padded-slot) rows must quantize to exact zeros, scale 1."""
    x = jnp.zeros((3, 16))
    codes, scales = quantize_rows(x)
    np.testing.assert_array_equal(np.asarray(codes), 0)
    np.testing.assert_array_equal(np.asarray(scales), 1.0)
    np.testing.assert_array_equal(np.asarray(dequantize_rows(codes, scales)), 0.0)


# ---------------------------------------------------------------------------
# Kernel vs oracle parity: storage dtypes x block liveness patterns
# ---------------------------------------------------------------------------


def _case(seed, n, d, b, c):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    embs = jax.random.normal(k1, (n, d))
    ids = jax.random.randint(k2, (b, c), 0, n)
    q = jax.random.normal(k3, (b, d))
    return embs, ids, q


def _mask(ids, pattern, block_c):
    """Apply a liveness pattern in units of the kernel's candidate blocks."""
    if pattern == "all_live":
        return ids
    if pattern == "mixed":
        return ids.at[:, ::3].set(-1)
    if pattern == "dead_block":  # one fully-dead block per row
        return ids.at[:, block_c : 2 * block_c].set(-1)
    if pattern == "all_pruned_row":  # row 0 entirely dead
        return ids.at[0, :].set(-1)
    raise ValueError(pattern)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8", "int4"])
@pytest.mark.parametrize(
    "pattern", ["all_live", "mixed", "dead_block", "all_pruned_row"]
)
def test_fused_parity_across_dtypes_and_block_liveness(dtype, pattern):
    block_c = 8
    embs_f, ids, q = _case(11, 64, 32, 3, 4 * block_c)
    ids = _mask(ids, pattern, block_c)
    if dtype in ("int8", "int4"):
        quant = quantize_rows if dtype == "int8" else quantize_rows_int4
        table, scales = quant(embs_f)
    else:
        table = embs_f.astype(jnp.dtype(dtype))
        scales = None
    code_dtype = "int4" if dtype == "int4" else "int8"
    gi, gs = fused_verify(
        table, ids, q, k=6, scales=scales, block_c=block_c,
        code_dtype=code_dtype, interpret=True,
    )
    wi, ws = ref.verify_topk_ref(
        table, ids, q, k=6, scales=scales, code_dtype=code_dtype
    )
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(
        np.asarray(gs), np.asarray(ws), rtol=2e-2 if dtype == "bfloat16" else 1e-6
    )
    if pattern == "all_pruned_row":
        assert (np.asarray(gi)[0] == -1).all()
        assert np.isneginf(np.asarray(gs)[0]).all()


def test_int8_oracle_scores_near_exact():
    """Quantized scoring obeys the §Quantized bank error model against exact
    f32 scoring: |err| <= ||q||_1 s_x/2 + ||x||_1 s_q/2 + d s_x s_q / 4
    (two first-order rounding terms + the second-order cross term)."""
    rng = np.random.default_rng(5)
    d = 48
    x = rng.normal(size=(80, d)).astype(np.float32)
    q = rng.normal(size=(2, d)).astype(np.float32)
    codes, scales = quantize_rows(jnp.asarray(x))
    q_codes, q_scales = quantize_rows(jnp.asarray(q))
    got = (
        np.asarray(codes, np.int32) @ np.asarray(q_codes, np.int32).T
    ).astype(np.float32) * np.asarray(scales)[:, None] * np.asarray(q_scales)
    want = x @ q.T
    sx = np.asarray(scales)[:, None]
    sq = np.asarray(q_scales)[None, :]
    bound = (
        np.abs(q).sum(-1)[None, :] * sx / 2
        + np.abs(x).sum(-1)[:, None] * sq / 2
        + d * sx * sq / 4
        + 1e-4
    )
    assert (np.abs(got - want) <= bound).all()


# ---------------------------------------------------------------------------
# End-to-end LIDER: storage dtypes through build/search
# ---------------------------------------------------------------------------

CFG = lider.LiderConfig(
    n_clusters=32, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=10
)


def _cfg(storage_dtype, **kw):
    return dataclasses.replace(CFG, storage_dtype=storage_dtype, **kw)


@pytest.fixture(scope="module")
def built(corpus):
    x, q, gt = corpus
    params = {
        sd: lider.build_lider(jax.random.PRNGKey(0), x, _cfg(sd))
        for sd in ("float32", "bfloat16", "int8", "int4")
    }
    return x, q, gt, params


def test_bank_storage_dtypes(built):
    _, _, _, params = built
    assert params["float32"].bank.embs.dtype == jnp.float32
    assert params["float32"].bank.emb_scales is None
    assert params["bfloat16"].bank.embs.dtype == jnp.bfloat16
    assert params["bfloat16"].bank.rescore_embs is None
    b = params["int8"].bank
    assert b.embs.dtype == jnp.int8 and b.quantized
    assert b.emb_scales.shape == b.gids.shape
    assert b.rescore_embs.shape == b.embs.shape
    assert b.storage_dtype == "int8"
    b4 = params["int4"].bank
    assert b4.embs.dtype == jnp.int8 and b4.quantized
    assert b4.storage_dtype == "int4" and b4.code_dtype == "int4"
    # packed carrier is half the logical width; rescore table stays full
    assert b4.embs.shape[-1] * 2 == b4.rescore_embs.shape[-1]
    assert b4.dim == b.dim
    assert b4.emb_scales.shape == b4.gids.shape


def test_int8_rescore_recall_parity(built):
    """Acceptance: int8+rescore recall@k within eps of the bf16 path."""
    _, q, gt, params = built
    r16 = recall_at_k(
        lider.search_lider(params["bfloat16"], q, k=10, n_probe=8, r0=8).ids, gt
    )
    r8 = recall_at_k(
        lider.search_lider(params["int8"], q, k=10, n_probe=8, r0=8).ids, gt
    )
    assert float(r8) >= float(r16) - 0.02
    # and both stay near the full-precision path
    r32 = recall_at_k(
        lider.search_lider(params["float32"], q, k=10, n_probe=8, r0=8).ids, gt
    )
    assert float(r8) >= float(r32) - 0.03


def test_int4_rescore_recall_parity(built):
    """Acceptance: int4 first pass + exact rescore recall@k within 0.02 of
    the int8 path. The 4-bit codes only pick the rescore candidates, but
    their coarser ordering needs roughly twice the rescore window
    (rescore_factor 8 vs int8's default 4) to surface the same winners —
    still a traffic win: the wider exact gather is B·k'·d while the first
    pass streams half the bytes (DESIGN.md §Quantized bank, int4 column)."""
    _, q, gt, params = built
    r8 = recall_at_k(
        lider.search_lider(params["int8"], q, k=10, n_probe=8, r0=8).ids, gt
    )
    r4 = recall_at_k(
        lider.search_lider(
            params["int4"], q, k=10, n_probe=8, r0=8, rescore_factor=8
        ).ids, gt,
    )
    assert float(r4) >= float(r8) - 0.02


@pytest.mark.parametrize("sd", ["int8", "int4"])
def test_rescore_scores_are_exact(built, sd):
    """Returned scores come from the full-precision side table: every
    (id, score) the quantized path surfaces equals the exact f32 inner
    product."""
    x, q, _, params = built
    out = lider.search_lider(params[sd], q, k=10, n_probe=8, r0=8)
    ids = np.asarray(out.ids)
    scores = np.asarray(out.scores)
    exact = np.asarray(jnp.einsum("nd,bd->bn", jnp.asarray(x), q))
    for b in range(ids.shape[0]):
        for i, s in zip(ids[b], scores[b]):
            if i >= 0:
                np.testing.assert_allclose(s, exact[b, i], rtol=1e-5, atol=1e-5)


def test_rescore_factor_widens_recovery(built):
    """rescore_factor=1 rescores exactly k candidates (order-only recovery);
    larger factors can only help; both run and stay well-formed."""
    _, q, gt, params = built
    r1 = recall_at_k(
        lider.search_lider(
            params["int8"], q, k=10, n_probe=8, r0=8, rescore_factor=1
        ).ids, gt,
    )
    r4 = recall_at_k(
        lider.search_lider(
            params["int8"], q, k=10, n_probe=8, r0=8, rescore_factor=4
        ).ids, gt,
    )
    assert float(r4) >= float(r1) - 1e-6


def test_search_core_model_quantized_two_stage(corpus):
    """The standalone core-model spelling of the quantized search: int8
    first pass + exact rescore from the full-precision table. Returned
    scores must be exact f32 inner products and recall must track the float
    model."""
    from repro.core.core_model import build_core_model, search_core_model

    x, q, gt = corpus
    cm = build_core_model(jax.random.PRNGKey(0), x, n_arrays=6, n_leaves=4)
    base = search_core_model(cm, x, q, k=10, r0=8)
    codes, scales = quantize_rows(x)
    with pytest.raises(ValueError, match="rescore_embs"):
        search_core_model(cm, codes, q, k=10, r0=8, scales=scales)
    got = search_core_model(
        cm, codes, q, k=10, r0=8, scales=scales, rescore_embs=x,
        rescore_factor=4,
    )
    r_base = float(recall_at_k(base.ids, gt))
    r_got = float(recall_at_k(got.ids, gt))
    assert r_got >= r_base - 0.02
    exact = np.asarray(jnp.einsum("nd,bd->bn", x, q))
    ids, scores = np.asarray(got.ids), np.asarray(got.scores)
    for b in range(ids.shape[0]):
        for i, s in zip(ids[b], scores[b]):
            if i >= 0:
                np.testing.assert_allclose(s, exact[b, i], rtol=1e-5, atol=1e-5)


def test_block_c_threading_does_not_change_results(built):
    """block_c is a pure performance knob: any value gives identical ids."""
    _, q, _, params = built
    base = lider.search_lider(params["float32"], q, k=10, n_probe=8, r0=8)
    for bc in (32, 128, 1024):
        got = lider.search_lider(
            params["float32"], q, k=10, n_probe=8, r0=8, block_c=bc,
            use_fused=True,
        )
        np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(base.ids))


# ---------------------------------------------------------------------------
# Lifecycle: upsert / delete / checkpoint keep the quantized tables consistent
# ---------------------------------------------------------------------------


def _assert_bank_consistent(bank):
    """Invariants tying codes, scales, and the rescore side table together."""
    codes = np.asarray(bank.embs, np.int32)
    scales = np.asarray(bank.emb_scales)
    rescore = np.asarray(bank.rescore_embs)
    gids = np.asarray(bank.gids)
    assert (scales > 0).all()
    # dequantized codes approximate the rescore rows to half a step per elem
    dq = np.asarray(bank.float_rows())
    np.testing.assert_allclose(dq, codes * scales[..., None], rtol=1e-6)
    assert (np.abs(dq - rescore) <= scales[..., None] / 2 + 1e-6).all()
    # free/tombstoned slots hold exact zeros in both tables
    dead = gids < 0
    assert (codes[dead] == 0).all()
    assert (rescore[dead] == 0.0).all()
    # stored codes re-quantize to themselves (row-local scheme, no drift)
    c2, s2 = quantize_rows(jnp.asarray(rescore))
    np.testing.assert_array_equal(codes, np.asarray(c2, np.int32))
    np.testing.assert_allclose(scales, np.asarray(s2), rtol=1e-6)


@pytest.mark.parametrize("sd", ["int8", "int4"])
def test_quantized_upsert_matches_full_rebuild(corpus, sd):
    """build(80%) -> upsert(20%) is slot- and byte-identical to build(100%)
    on the quantized tables (quantization is row-local — for int4 the packed
    nibble bytes themselves must match)."""
    x, q, _ = corpus
    n80 = int(x.shape[0] * 0.8)
    km = clustering.kmeans(jax.random.PRNGKey(2), x[:n80], CFG.n_clusters, iters=10)
    assignment, _ = clustering.assign_chunked(x, km.centroids)
    max_size = int(jnp.bincount(assignment, length=CFG.n_clusters).max())
    cfg = _cfg(
        sd,
        capacity=lider.padded_capacity(max_size, None, CFG.pad_multiple),
    )
    full = lider.build_lider(jax.random.PRNGKey(2), x, cfg, centroids=km.centroids)
    base = lider.build_lider(
        jax.random.PRNGKey(2), x[:n80], cfg, centroids=km.centroids
    )
    up, stats = update.upsert(base, x[n80:])
    assert stats.n_added == x.shape[0] - n80
    for name in ("sorted_keys", "sorted_pos", "gids", "embs", "emb_scales",
                 "rescore_embs"):
        np.testing.assert_array_equal(
            np.asarray(getattr(up.bank, name)),
            np.asarray(getattr(full.bank, name)),
            err_msg=name,
        )
    if sd == "int8":
        _assert_bank_consistent(up.bank)
    else:
        # stored packed nibbles re-quantize to themselves from the rescore
        # table (row-local scheme, no drift through the upsert path)
        c2, s2 = quantize_rows_int4(jnp.asarray(up.bank.rescore_embs))
        np.testing.assert_array_equal(
            np.asarray(up.bank.embs), np.asarray(c2)
        )
        np.testing.assert_allclose(
            np.asarray(up.bank.emb_scales), np.asarray(s2), rtol=1e-6
        )
    a = lider.search_lider(up, q, k=10, n_probe=8, r0=8)
    b = lider.search_lider(full, q, k=10, n_probe=8, r0=8)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


@pytest.mark.parametrize("threshold", [1.0, 0.0])
def test_int8_delete_keeps_tables_consistent(corpus, threshold):
    """Tombstoning and (threshold 0) compaction never surface dead ids and
    keep codes/scales/rescore in lockstep."""
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(2), x, _cfg("int8"))
    before = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    dead = np.unique(np.asarray(before.ids)[:, :3].ravel())
    dead = dead[dead >= 0][:50]
    p2, stats = update.delete(
        p, jnp.asarray(dead, jnp.int32), refit_threshold=threshold
    )
    assert stats.n_deleted == len(dead)
    if threshold == 0.0:
        assert stats.n_refit > 0  # compaction actually ran
        _assert_bank_consistent(p2.bank)
    after = lider.search_lider(p2, q, k=10, n_probe=8, r0=8)
    assert not np.isin(np.asarray(after.ids), dead).any()


def test_int8_capacity_growth_preserves_tables(corpus):
    """An upsert that grows Lp pads scales with the zero-row convention and
    keeps every pre-existing slot byte-identical."""
    x, q, _ = corpus
    cfg = _cfg("int8", n_clusters=16, capacity=None)
    p = lider.build_lider(jax.random.PRNGKey(0), x, cfg)
    old = p.bank
    p2, stats = update.upsert(p, x[:300] + 0.01)
    assert stats.capacity_grew
    _assert_bank_consistent(p2.bank)
    lp = old.capacity
    touched = np.unique(
        np.asarray(clustering.assign_chunked(x[:300] + 0.01, p.centroids)[0])
    )
    untouched = np.setdiff1d(np.arange(16), touched)
    np.testing.assert_array_equal(
        np.asarray(p2.bank.embs)[untouched, :lp],
        np.asarray(old.embs)[untouched],
    )
    np.testing.assert_array_equal(
        np.asarray(p2.bank.emb_scales)[untouched, :lp],
        np.asarray(old.emb_scales)[untouched],
    )


@pytest.mark.parametrize("sd", ["int8", "int4"])
def test_quantized_checkpoint_roundtrip(tmp_path, corpus, sd):
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg(sd))
    checkpoint.save_index(str(tmp_path), p)
    p2 = checkpoint.load_index(str(tmp_path))
    assert p2.bank.quantized and p2.bank.embs.dtype == jnp.int8
    assert p2.bank.code_dtype == sd
    flat_a = jax.tree_util.tree_leaves(p)
    flat_b = jax.tree_util.tree_leaves(p2)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    a = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    b = lider.search_lider(p2, q, k=10, n_probe=8, r0=8)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_float_checkpoint_has_no_quantized_leaves(tmp_path, corpus):
    """f32 indexes round-trip without scale/rescore files (format compat)."""
    x, _, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg("float32"))
    checkpoint.save_index(str(tmp_path), p)
    p2 = checkpoint.load_index(str(tmp_path))
    assert p2.bank.emb_scales is None and p2.bank.rescore_embs is None


# ---------------------------------------------------------------------------
# Serving + store_rows argument validation
# ---------------------------------------------------------------------------


def test_store_rows_rejects_unknown_dtype():
    with pytest.raises(ValueError, match="storage_dtype"):
        store_rows(jnp.zeros((2, 4, 8)), "float16")


def test_serving_engine_serves_int8_with_rescore(corpus):
    x, q, gt = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg("int8"))
    search = make_backend(
        "lider", None, updatable=True, n_probe=8, r0=8, rescore_factor=4,
        block_c=128,
    )
    eng = RetrievalEngine(search, batch_size=16, k=10, dim=x.shape[1], params=p)
    eng.warmup()
    rids = [eng.submit(np.asarray(qq)) for qq in np.asarray(q)[:32]]
    eng.drain()
    got = np.stack([eng.result(r)[0] for r in rids])
    rec = float(recall_at_k(jnp.asarray(got), gt[:32]))
    assert rec > 0.85


def test_serving_engine_serves_int4_cluster_major(corpus):
    """int4 bank + cluster-major schedule threaded through backend kwargs:
    the serving path with ``block_q`` set returns the same ids the direct
    per-query search does, at serving recall."""
    x, q, gt = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg("int4"))
    search = make_backend(
        "lider", None, updatable=True, n_probe=8, r0=8, rescore_factor=4,
        block_c=128, block_q=4,
    )
    eng = RetrievalEngine(search, batch_size=16, k=10, dim=x.shape[1], params=p)
    eng.warmup()
    rids = [eng.submit(np.asarray(qq)) for qq in np.asarray(q)[:32]]
    eng.drain()
    got = np.stack([eng.result(r)[0] for r in rids])
    rec = float(recall_at_k(jnp.asarray(got), gt[:32]))
    assert rec > 0.85
    direct = lider.search_lider(p, q[:16], k=10, n_probe=8, r0=8,
                                rescore_factor=4, block_c=128)
    np.testing.assert_array_equal(got[:16], np.asarray(direct.ids))
